"""Quickstart: sparse GP regression through the `repro.gp` facade.

    PYTHONPATH=src python examples/quickstart.py [--steps 300]

Fits a sparse GP (Titsias bound, the paper's eq. (2)-(3)) to 1-D data via the
same distributed code path used on a pod (here the mesh is 1 CPU device —
the code is identical), then prints test RMSE and calibration. The facade
owns the wiring this example used to hand-roll across five modules.
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp

from repro.core.distributed import make_gp_mesh
from repro.gp import SparseGPRegression, get


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--n", type=int, default=2000)
    ap.add_argument("--backend", choices=("jnp", "pallas", "fused"),
                    default="jnp",
                    help="statistics path; 'fused' trains through the fused "
                         "suffstats kernel pair (fwd + hand-derived reverse, "
                         "exact statistics via S -> 0)")
    ap.add_argument("--pallas", action="store_true",
                    help="deprecated alias for --backend pallas")
    ap.add_argument("--max-rmse", type=float, default=0.1,
                    help="accuracy bar (smoke sizes/steps warrant a looser one)")
    args = ap.parse_args()
    if args.pallas and args.backend != "jnp":
        ap.error("--pallas is an alias for --backend pallas; don't pass both")
    backend = "pallas" if args.pallas else args.backend

    key = jax.random.PRNGKey(0)
    N, M = args.n, 32
    X = jnp.sort(jax.random.uniform(key, (N, 1), minval=-3.0, maxval=3.0), axis=0)
    f = jnp.sin(2.0 * X[:, 0]) + 0.3 * jnp.cos(5.0 * X[:, 0])
    Y = (f + 0.1 * jax.random.normal(jax.random.fold_in(key, 1), (N,)))[:, None]

    # --- the whole model setup: kernel by name, mesh + backend from the ctor
    gp = SparseGPRegression(kernel=get("rbf")(1), M=M, mesh=make_gp_mesh(),
                            backend=backend)
    loss0 = -gp.fit(X, Y, steps=0).elbo() / N  # initial nlml/point (0 steps)
    print(f"initial nlml/point: {loss0:.4f}")
    gp.fit(X, Y, steps=args.steps, lr=3e-2)
    print(f"final   nlml/point: {-gp.elbo() / N:.4f}")

    # --- prediction through the facade
    Xt = jnp.linspace(-3, 3, 200)[:, None]
    mean, var = gp.predict(Xt)
    f_true = jnp.sin(2.0 * Xt[:, 0]) + 0.3 * jnp.cos(5.0 * Xt[:, 0])
    rmse = float(jnp.sqrt(jnp.mean((mean[:, 0] - f_true) ** 2)))
    inside = float(jnp.mean((jnp.abs(mean[:, 0] - f_true) < 2 * jnp.sqrt(var))))
    print(f"test RMSE {rmse:.4f}; {inside*100:.0f}% of truth inside 2-sigma")
    kern_cls = type(gp.kernel)
    print(f"learned lengthscale {float(kern_cls.lengthscale(gp.params['kern'])[0]):.3f}, "
          f"noise std {float(jnp.exp(gp.params['log_beta']) ** -0.5):.3f}")
    assert rmse < args.max_rmse, (rmse, args.max_rmse)
    print("quickstart OK")


if __name__ == "__main__":
    main()
