"""Quickstart: sparse GP regression with the distributed collapsed bound.

    PYTHONPATH=src python examples/quickstart.py

Fits a sparse GP (Titsias bound, the paper's eq. (2)-(3)) to 1-D data via the
same distributed code path used on a pod (here the mesh is 1 CPU device —
the code is identical), then prints test RMSE and calibration.
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import distributed, inference, psi_stats, svgp
from repro.core.gp_kernels import RBF


def main() -> None:
    key = jax.random.PRNGKey(0)
    N, M = 2000, 32
    X = jnp.sort(jax.random.uniform(key, (N, 1), minval=-3.0, maxval=3.0), axis=0)
    f = jnp.sin(2.0 * X[:, 0]) + 0.3 * jnp.cos(5.0 * X[:, 0])
    Y = (f + 0.1 * jax.random.normal(jax.random.fold_in(key, 1), (N,)))[:, None]

    kern = RBF(1)
    params = {
        "kern": kern.init(variance=1.0, lengthscale=1.0),
        "Z": X[:: N // M][:M],
        "log_beta": jnp.asarray(2.0, jnp.float32),
    }

    mesh = distributed.make_gp_mesh()
    loss = distributed.sgpr_loss_dist(mesh)  # shard_map + psum, as on a pod
    print(f"initial nlml/point: {float(loss(params, X, Y)):.4f}")
    params, _ = inference.fit_adam(loss, params, (X, Y), steps=300, lr=3e-2)
    print(f"final   nlml/point: {float(loss(params, X, Y)):.4f}")

    # prediction
    stats = psi_stats.exact_stats_rbf(params["kern"], X, Y, params["Z"])
    beta = jnp.exp(params["log_beta"])
    terms = svgp.collapsed_bound(kern.K(params["kern"], params["Z"]), stats, beta, 1)
    post = svgp.optimal_qu(terms, beta)
    Xt = jnp.linspace(-3, 3, 200)[:, None]
    mean, var = svgp.predict_f(post, kern.K(params["kern"], Xt, params["Z"]),
                               kern.Kdiag(params["kern"], Xt))
    f_true = jnp.sin(2.0 * Xt[:, 0]) + 0.3 * jnp.cos(5.0 * Xt[:, 0])
    rmse = float(jnp.sqrt(jnp.mean((mean[:, 0] - f_true) ** 2)))
    inside = float(jnp.mean((jnp.abs(mean[:, 0] - f_true) < 2 * jnp.sqrt(var))))
    print(f"test RMSE {rmse:.4f}; {inside*100:.0f}% of truth inside 2-sigma")
    print(f"learned lengthscale {float(RBF.lengthscale(params['kern'])[0]):.3f}, "
          f"noise std {float(beta ** -0.5):.3f}")
    assert rmse < 0.1


if __name__ == "__main__":
    main()
