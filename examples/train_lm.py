"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps with
the full production stack — sharded step, fault-tolerant loop, checkpointing,
WSD schedule, synthetic data pipeline.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]

The model is a 12-layer / d=768 smollm-family config (~110M params). On this
CPU box a step takes ~1s at batch 8 x seq 256; the identical script drives a
pod by passing --mesh pod on TPU hosts.
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import dataclasses

import jax

from repro.configs.base import ModelConfig, ShapeCell
from repro.data.synthetic import TokenStream
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.launch.steps import make_train_step
from repro.models.model_zoo import build
from repro.optim import AdamConfig, adam_init, wsd_schedule
from repro.runtime.train_loop import LoopConfig, TrainLoop

CFG_100M = ModelConfig(
    name="lm-100m", family="dense",
    num_layers=12, d_model=768, num_heads=12, num_kv_heads=4, head_dim=64,
    d_ff=2048, vocab_size=32000, tie_embeddings=True,
    param_dtype="float32", compute_dtype="float32", remat=False, logits_chunk=128,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--mesh", default="host", choices=["host", "pod", "multipod"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = CFG_100M
    shape = ShapeCell("e2e", args.seq, args.batch, "train")
    mesh = (make_host_mesh() if args.mesh == "host"
            else make_production_mesh(multi_pod=args.mesh == "multipod"))

    adam = AdamConfig(lr=wsd_schedule(3e-4, warmup_steps=20,
                                      stable_steps=args.steps // 2,
                                      decay_steps=args.steps // 3),
                      weight_decay=0.1, clip_norm=1.0)
    with mesh:
        bundle = make_train_step(cfg, shape, mesh, adam=adam, batch=args.batch)
        params = jax.device_put(build(cfg).init(jax.random.PRNGKey(0)),
                                bundle.in_shardings[0])
        n = sum(int(x.size) for x in jax.tree.leaves(params))
        print(f"model: {n/1e6:.1f}M params; mesh {dict(mesh.shape)}")
        opt = jax.device_put(adam_init(params, adam), bundle.in_shardings[1])

        loop = TrainLoop(bundle.jitted(), params, opt,
                         TokenStream(cfg, shape, batch=args.batch),
                         LoopConfig(ckpt_dir=args.ckpt_dir, ckpt_every=100,
                                    log_every=20),
                         shardings=(bundle.in_shardings[0], bundle.in_shardings[1]))
        final = loop.run(args.steps)
    print(f"done: final loss {final['loss']:.4f} (random-chance ~ {jax.numpy.log(cfg.vocab_size):.2f})")


if __name__ == "__main__":
    main()
