"""The paper's §4 experiment: Bayesian GP-LVM dimensionality reduction on
synthetic data — recover the 1-D latent line from 3-D observations.

    PYTHONPATH=src python examples/gplvm_synthetic.py [--n 2048] [--pallas]

Setup mirrors the paper: Q=1 latent dim, M=100 inducing points, data sampled
through an RBF-kernel function. Optimizes the distributed bound with Adam
(use --lbfgs for the paper's optimizer) through the `repro.gp.BayesianGPLVM`
facade and reports the latent-recovery correlation (up to sign/scale, the
invariances of the model).
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import numpy as np

from repro.core.distributed import make_gp_mesh
from repro.data.synthetic import gplvm_synthetic
from repro.gp import BayesianGPLVM, get


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=2048)
    ap.add_argument("--m", type=int, default=100)
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--lbfgs", action="store_true", help="paper's optimizer")
    ap.add_argument("--backend", choices=("jnp", "pallas", "fused"),
                    default="jnp",
                    help="psi-stats path; 'fused' trains through the fused "
                         "suffstats kernel pair (fwd + hand-derived reverse)")
    ap.add_argument("--pallas", action="store_true",
                    help="deprecated alias for --backend pallas")
    ap.add_argument("--min-corr", type=float, default=0.95,
                    help="latent-recovery bar (smoke-mode CI relaxes it: the "
                         "recovery quality depends on the data draw and N)")
    args = ap.parse_args()

    key = jax.random.PRNGKey(0)
    X_true, Y = gplvm_synthetic(key, N=args.n, D=3, Q=1)
    print(f"data: N={args.n} 3-D points from a 1-D latent (paper §4)")

    if args.pallas and args.backend != "jnp":
        ap.error("--pallas is an alias for --backend pallas; don't pass both")
    backend = "pallas" if args.pallas else args.backend
    lvm = BayesianGPLVM(kernel=get("rbf")(1), M=args.m, mesh=make_gp_mesh(),
                        backend=backend)

    t0 = time.time()
    lvm.fit(Y, optimizer="lbfgs" if args.lbfgs else "adam", steps=args.steps,
            lr=2e-2, log_every=0 if args.lbfgs else max(args.steps // 8, 1), key=key)
    dt = time.time() - t0
    print(f"optimized {args.steps} steps in {dt:.1f}s "
          f"({dt/args.steps*1e3:.1f} ms/iter) final loss {lvm.history[-1]:.4f}")

    # latent recovery: correlation of q_mu with the true latent (sign/scale free)
    mu, _ = lvm.latent()
    corr = abs(np.corrcoef(np.asarray(mu[:, 0]), np.asarray(X_true[:, 0]))[0, 1])
    print(f"|corr(latent, truth)| = {corr:.3f}")
    assert corr > args.min_corr, f"latent line not recovered: {corr:.3f} <= {args.min_corr}"
    print("recovered the 1-D latent structure — paper reproduction OK")


if __name__ == "__main__":
    main()
