"""The paper's §4 experiment: Bayesian GP-LVM dimensionality reduction on
synthetic data — recover the 1-D latent line from 3-D observations.

    PYTHONPATH=src python examples/gplvm_synthetic.py [--n 2048] [--pallas]

Setup mirrors the paper: Q=1 latent dim, M=100 inducing points, data sampled
through an RBF-kernel function. Optimizes the distributed bound with Adam
(use --lbfgs for the paper's optimizer) and reports the latent-recovery
correlation (up to sign/scale, the invariances of the model).
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import distributed, gplvm, inference
from repro.data.synthetic import gplvm_synthetic


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=2048)
    ap.add_argument("--m", type=int, default=100)
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--lbfgs", action="store_true", help="paper's optimizer")
    ap.add_argument("--pallas", action="store_true", help="psi-stats via Pallas kernels")
    args = ap.parse_args()

    key = jax.random.PRNGKey(0)
    X_true, Y = gplvm_synthetic(key, N=args.n, D=3, Q=1)
    print(f"data: N={args.n} 3-D points from a 1-D latent (paper §4)")

    params = gplvm.init_params(key, np.asarray(Y), Q=1, M=args.m)
    backend = "pallas" if args.pallas else "jnp"
    mesh = distributed.make_gp_mesh()
    loss = distributed.gplvm_loss_dist(mesh, backend=backend)

    t0 = time.time()
    if args.lbfgs:
        params, final = inference.fit_lbfgs(lambda p, Y: loss(p, Y), params, (Y,),
                                            maxiter=args.steps)
    else:
        params, hist = inference.fit_adam(loss, params, (Y,), steps=args.steps,
                                          lr=2e-2, log_every=max(args.steps // 8, 1))
        final = hist[-1]
    dt = time.time() - t0
    print(f"optimized {args.steps} steps in {dt:.1f}s "
          f"({dt/args.steps*1e3:.1f} ms/iter) final loss {final:.4f}")

    # latent recovery: correlation of q_mu with the true latent (sign/scale free)
    mu = np.asarray(params["q_mu"][:, 0])
    xt = np.asarray(X_true[:, 0])
    corr = abs(np.corrcoef(mu, xt)[0, 1])
    print(f"|corr(latent, truth)| = {corr:.3f}")
    assert corr > 0.95, "latent line not recovered"
    print("recovered the 1-D latent structure — paper reproduction OK")


if __name__ == "__main__":
    main()
