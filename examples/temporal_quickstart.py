"""Temporal quickstart: streaming forecasts from a state-space GP.

    PYTHONPATH=src python examples/temporal_quickstart.py [--n 100000]

Fits `TemporalGPRegression` (backend="temporal") on the LEFT half of a
long, non-uniformly sampled time series — the O(N) parallel-scan Kalman
path, no (N, N) matrix anywhere — exports the O(d^2) `TemporalState`
into a `GPServer`, then streams the RIGHT half in chunks through
`server.update()`. After each chunk it forecasts the next window and
reports the rolling forecast RMSE: the error stays near the noise floor
because every update advances the filter to the newest timestamp.
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

from repro.gp import get, regression


def rmse(mean, truth) -> float:
    return float(jnp.sqrt(jnp.mean((mean[:, 0] - truth) ** 2)))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=100_000)
    ap.add_argument("--steps", type=int, default=60)
    args = ap.parse_args()

    from repro.serve import GPServer

    key = jax.random.PRNGKey(0)
    n = args.n
    # non-uniform timestamps: mean gap 1e-3, so ~half the series spans ~50
    # characteristic times of the signal below
    gaps = jax.random.uniform(key, (n,), jnp.float64,
                              minval=0.5e-3, maxval=1.5e-3)
    t = jnp.cumsum(gaps)[:, None]
    f = jnp.sin(2.0 * jnp.pi * 0.8 * t[:, 0])
    noise = 0.1 * jax.random.normal(jax.random.fold_in(key, 1), (n,),
                                    jnp.float64)
    Y = (f + noise)[:, None]
    half = n // 2

    # --- fit on the left half only; the right half arrives "in production"
    gp = regression(get("matern32")(1), backend="temporal")
    gp.fit(t[:half], Y[:half], steps=args.steps, lr=5e-2)
    print(f"fitted temporal GP on {half} points "
          f"(lml/N={float(gp.lml()) / half:.3f})")

    server = GPServer()
    server.register("sensor", gp)  # export_state(): terminal (m, P), O(d^2)
    state = server.state("sensor")
    print(f"registered TemporalState: d={state.d}, {state.nbytes} bytes, "
          f"n={int(state.n)} points absorbed")

    # --- stream the right half in chunks: before absorbing each chunk,
    # forecast a short window past the current frontier (a GP forecast is
    # only informative within ~a lengthscale of the last observation — a
    # long-horizon forecast correctly reverts to the prior mean), then
    # filter the whole chunk forward.
    chunk = max(64, (n - half) // 20)
    horizon = 64
    errors = []
    for start in range(half, n, chunk):
        sl = slice(start, min(start + chunk, n))
        h = slice(start, min(start + horizon, n))
        mean, var = server.predict("sensor", t[h])  # forecast BEFORE seeing
        errors.append(rmse(mean, f[h]))
        server.update("sensor", t[sl], Y[sl])  # filter forward
    print(f"streamed {n - half} points in {len(errors)} chunks; "
          f"{horizon}-point-ahead forecast RMSE "
          f"first={errors[0]:.3f} median={sorted(errors)[len(errors) // 2]:.3f} "
          f"last={errors[-1]:.3f}")

    # every forecast is made at the filter frontier, so the error sits near
    # the noise floor (0.1) throughout — it does not degrade as the series
    # grows, and no step ever touches more than one chunk of data
    assert max(errors) < 0.35, errors
    assert sorted(errors)[len(errors) // 2] < 0.2, errors
    n_final = int(server.state("sensor").n)
    assert n_final == n, (n_final, n)
    server.close()
    print("temporal quickstart OK")


if __name__ == "__main__":
    main()
