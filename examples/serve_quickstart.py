"""Serve quickstart: fit once, serve forever — online updates included.

    PYTHONPATH=src python examples/serve_quickstart.py [--steps 150]

Fits a sparse GP on the LEFT half of the input range only, exports the
O(M^2) posterior state into a `GPServer`, serves concurrent predictions
through the micro-batching queue, then streams the RIGHT half of the data
in through `server.update()` — no refit, no access to the original training
set — and shows the predictions on the new region snapping into place.
"""
import argparse
import sys
from concurrent.futures import Future
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp

from repro.gp import SparseGPRegression, get


def rmse(mean, truth) -> float:
    return float(jnp.sqrt(jnp.mean((mean[:, 0] - truth) ** 2)))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--n", type=int, default=2000)
    args = ap.parse_args()

    from repro.serve import GPServer

    key = jax.random.PRNGKey(0)
    N, M = args.n, 32
    X = jnp.sort(jax.random.uniform(key, (N, 1), minval=-3.0, maxval=3.0), axis=0)
    f = jnp.sin(2.0 * X[:, 0])
    Y = (f + 0.1 * jax.random.normal(jax.random.fold_in(key, 1), (N,)))[:, None]
    left = X[:, 0] < 0.0

    # --- fit on the left half only; the right half arrives "in production".
    # Inducing points span the FULL expected input domain (not just the
    # fitted half): online updates can only sharpen the posterior inside
    # span{k(., z_m)}, so serving deployments place Z over the domain they
    # intend to serve, not over the data they happen to start with.
    gp = SparseGPRegression(kernel=get("rbf")(1), M=M)
    params = gp.init_params(X[left], Y[left])
    params["Z"] = jnp.linspace(-3.0, 3.0, M)[:, None]
    gp.fit(X[left], Y[left], steps=args.steps, lr=3e-2, params=params)

    server = GPServer()
    server.register("demo", gp)  # export_state(): Choleskys + SuffStats
    print(f"registered state: M={server.state('demo').M}, "
          f"n={float(server.state('demo').stats.n):.0f} points absorbed")

    # --- concurrent predictions through the micro-batching queue
    Xt = jnp.linspace(0.1, 3.0, 128)[:, None]  # the UNSEEN region
    f_t = jnp.sin(2.0 * Xt[:, 0])
    futures: list[Future] = [server.submit("demo", Xt[i: i + 16])
                             for i in range(0, 128, 16)]
    mean_before = jnp.concatenate([fut.result(timeout=60)[0] for fut in futures])
    before = rmse(mean_before, f_t)
    print(f"RMSE on unseen region before update: {before:.3f}")

    # --- stream the right half in: monoid fold + O(M^3) refold, no refit
    right_idx = jnp.where(~left)[0]
    for start in range(0, int(right_idx.size), 256):
        sl = right_idx[start: start + 256]
        server.update("demo", X[sl], Y[sl])
    print(f"absorbed {int(right_idx.size)} new points online "
          f"(n={float(server.state('demo').stats.n):.0f})")

    mean_after, var_after = server.predict("demo", Xt)
    after = rmse(mean_after, f_t)
    inside = float(jnp.mean(jnp.abs(mean_after[:, 0] - f_t)
                            < 2.0 * jnp.sqrt(var_after)))
    print(f"RMSE on unseen region after update:  {after:.3f} "
          f"({inside * 100:.0f}% of truth inside 2-sigma)")
    server.close()

    assert after < 0.5 * before, (before, after)
    assert after < 0.2, after
    print("serve quickstart OK")


if __name__ == "__main__":
    main()
