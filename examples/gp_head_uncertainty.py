"""The paper's technique composed with an assigned LM architecture: a
distributed sparse-GP readout head (deep-kernel style) on smollm-360m
features, giving calibrated uncertainty on a regression target.

    PYTHONPATH=src python examples/gp_head_uncertainty.py

Pipeline: (1) run the (smoke-sized) smollm backbone to pool per-sequence
features; (2) train the SVGP head on the collapsed bound — the exact same
sufficient-statistics + psum machinery as the GP-LVM, features being
deterministic inputs; (3) show that predictive variance separates
in-distribution from out-of-distribution inputs.
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp

from repro.configs.base import ShapeCell, get_smoke_config
from repro.core import gp_head
from repro.core.inference import fit_adam
from repro.models import model_zoo
from repro.models.layers import rmsnorm


def pooled_features(model, params, tokens, cfg):
    """Mean-pooled final hidden state (backbone as a feature extractor)."""
    from repro.models import transformer

    x = transformer._input_embeddings(params, {"tokens": tokens}, cfg)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    h, _, _ = transformer._backbone(params, x, positions, cfg, mode="train",
                                    states=None, cur_pos=None)
    return jnp.mean(h, axis=1)  # (B, d)


def main() -> None:
    cfg = get_smoke_config("smollm-360m")
    model = model_zoo.build(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)

    # synthetic task: target = smooth function of token statistics
    B, S = 256, 32
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size // 2, jnp.int32)
    target = jnp.sin(jnp.mean(tokens, axis=1) / 50.0)

    feats = pooled_features(model, params, tokens, cfg)
    print(f"features: {feats.shape} from {cfg.name}")

    head = gp_head.init_head(key, feats.shape[1], M=32)
    l0 = float(gp_head.head_loss(head, feats, target))
    head, hist = fit_adam(gp_head.head_loss, head, (feats, target), steps=200, lr=2e-2)
    print(f"head loss {l0:.3f} -> {hist[-1]:.3f}")

    # calibration: in-distribution vs OOD tokens (disjoint vocab range)
    tokens_ood = jax.random.randint(jax.random.fold_in(key, 9), (32, S),
                                    cfg.vocab_size // 2, cfg.vocab_size, jnp.int32)
    feats_ood = pooled_features(model, params, tokens_ood, cfg)
    pred_in = gp_head.head_predict(head, feats, target, feats[:32])
    pred_ood = gp_head.head_predict(head, feats, target, feats_ood)
    v_in = float(jnp.mean(pred_in.var))
    v_ood = float(jnp.mean(pred_ood.var))
    print(f"mean predictive variance: in-dist {v_in:.4f} vs OOD {v_ood:.4f}")
    assert v_ood > v_in, "OOD inputs should be more uncertain"
    print("GP head is calibrated: higher uncertainty off-manifold")


if __name__ == "__main__":
    main()
