"""Regenerate the §Dry-run and §Roofline sections of EXPERIMENTS.md from the
dry-run JSONs. §Perf and the narrative sections are maintained by hand in
EXPERIMENTS.md — this script only rewrites between the AUTOGEN markers.

    PYTHONPATH=src python experiments/make_experiments_md.py
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from benchmarks.roofline_table import load_cells, table, useful_fraction  # noqa: E402

ROOT = Path(__file__).resolve().parents[1]
MD = ROOT / "EXPERIMENTS.md"
BEGIN = "<!-- AUTOGEN:DRYRUN BEGIN -->"
END = "<!-- AUTOGEN:DRYRUN END -->"


def dryrun_section() -> str:
    out = ["### Cell status (all 40 arch x shape cells, both meshes)", ""]
    out.append("| arch | shape | kind | pod (256 chips) | multipod (512 chips) |")
    out.append("|---|---|---|---|---|")
    pods = {(r["arch"], r["shape"]): r for r in load_cells("pod")}
    multis = {(r["arch"], r["shape"]): r for r in load_cells("multipod")}
    n_ok = n_skip = 0
    for key in pods:
        p, m = pods[key], multis.get(key)

        def cell(r):
            if r is None:
                return "—"
            if r["status"] == "skipped":
                return "skip (sub-quadratic gate)"
            if r["status"] != "ok":
                return "ERROR"
            hbm = r["memory"]["peak_hbm_bytes_est"] / 2**30
            return (f"ok: compile {r['compile_s']:.0f}s, {hbm:.1f} GiB/chip, "
                    f"{sum(r['collectives']['counts'].values())} colls")

        if p["status"] == "ok":
            n_ok += 1
        elif p["status"] == "skipped":
            n_skip += 1
        out.append(f"| {key[0]} | {key[1]} | {p['kind']} | {cell(p)} | {cell(m)} |")
    out.append("")
    out.append(f"`lower().compile()` succeeds for **{n_ok} runnable + {n_skip} "
               "gated** of 40 cells on the single-pod mesh AND the 2-pod mesh "
               "(the multipod column proves the `pod` axis shards).")
    return "\n".join(out)


def roofline_section() -> str:
    out = [
        "### Roofline terms — single-pod (16 data x 16 model = 256 chips)",
        "",
        "Hardware model: 197 TFLOP/s bf16, 819 GB/s HBM, 2x50 GB/s ICI ring "
        "per chip. FLOPs/bytes/collective-traffic are per-chip, from the "
        "trip-count-aware HLO walk (launch/hlo_cost.py) over the compiled "
        "SPMD module; `useful` = MODEL_FLOPS (6·N_active·D train, 2·N·D "
        "inference) / (HLO FLOPs x chips).",
        "",
        table("pod"),
        "",
        "**Dominant-term notes (one line per arch, train_4k):**",
    ]
    for rec in load_cells("pod"):
        if rec["shape"] != "train_4k" or rec["status"] != "ok":
            continue
        r = rec["roofline"]
        dom = r["dominant"]
        hints = {
            "compute": "MXU-bound: raise per-chip batch or cut padded-head waste",
            "memory": "HBM-bound: the fp32 attention-probability blocks and remat "
                      "stacks dominate traffic; a Pallas flash-attention kernel "
                      "keeps p in VMEM",
            "collective": "ICI-bound: FSDP weight re-gathers per microbatch; "
                          "2-D expert sharding or gather-once scheduling cuts it",
        }
        out.append(f"- **{rec['arch']}**: {dom}-bound "
                   f"(bound {r['step_lower_bound_s']:.2f}s, useful "
                   f"{useful_fraction(rec):.2f}) — {hints[dom]}.")
    return "\n".join(out)


def main() -> None:
    body = (f"{BEGIN}\n\n## §Dry-run\n\n{dryrun_section()}\n\n"
            f"## §Roofline\n\n{roofline_section()}\n\n{END}")
    text = MD.read_text() if MD.exists() else ""
    if BEGIN in text and END in text:
        pre = text.split(BEGIN)[0]
        post = text.split(END)[1]
        MD.write_text(pre + body + post)
    else:
        MD.write_text(text + "\n" + body + "\n")
    print(f"wrote {MD}")


if __name__ == "__main__":
    main()
