#!/usr/bin/env bash
# CI entry point: tier-1 tests + both GP examples in smoke mode, so the
# repro.gp facade path is exercised end-to-end on every PR.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
# includes the Pallas reverse-kernel parity tests (tests/test_suffstats_bwd.py)
python -m pytest -x -q

echo "== docs check (links resolve, docs/api.md symbols import) =="
python scripts/check_docs.py

echo "== static analysis (lint + concurrency + pallas audit + jaxpr-check) =="
# the four repro.analysis passes: AST lint rules ANL001-ANL004 (+ inferred
# ANL006) over src/repro, the whole-repo lock model (order cycles ANL005,
# guard-inferred races ANL006, blocking-under-lock ANL007), the per-kernel
# VMEM/tiling/dtype audit of every registered Pallas kernel, and the
# scaling-class check on the quickstart SGPR loss (no intermediate in
# value_and_grad may reach O(N*M)). Non-zero exit on any finding.
python -m repro.analysis --all

echo "== concurrency analysis (machine-readable lane) =="
# same lock-model pass, JSON document: findings empty, every lock ranked in
# the declared hierarchy, every statically visible edge order-respecting
CONC_JSON="$(mktemp -t concurrency.XXXXXX.json)"
python -m repro.analysis --concurrency --format json > "$CONC_JSON"
CONC_JSON="$CONC_JSON" python - <<'PY'
import json
import os

doc = json.load(open(os.environ["CONC_JSON"]))
conc = doc["passes"]["concurrency"]
assert doc["ok"] and conc["findings"] == [], conc["findings"]
rank = {n: i for i, n in enumerate(conc["hierarchy"])}
locks = {l["name"] for l in conc["locks"]}
assert locks == set(rank), f"unranked locks: {locks ^ set(rank)}"
for e in conc["edges"]:
    assert rank[e["held"]] < rank[e["acquired"]], e
print(f"concurrency JSON OK ({len(locks)} locks, "
      f"{len(conc['edges'])} edges, 0 findings)")
PY

echo "== lockdep-instrumented serve battery (runtime deadlock check) =="
# tests/conftest.py wraps every test_serve* test in lockdep.watch(): all
# locks the serving tier creates are instrumented and any acquisition
# inverting the declared hierarchy or an observed order fails the test.
# (These tests also run in tier-1; this lane re-runs them by name so a CI
# log shows the lockdep gate explicitly.)
python -m pytest -q tests/test_serve.py tests/test_serve_persist.py

echo "== quickstart (sparse GP regression, facade) =="
python examples/quickstart.py --steps 150

echo "== quickstart, fused backend (Pallas fwd + bwd kernels, interpret) =="
# small N so the interpret-mode kernel bodies (not the jnp twins) run the
# training step end-to-end; smoke bar loosened accordingly
python examples/quickstart.py --n 512 --steps 60 --backend fused --max-rmse 0.35

echo "== serve quickstart (online serving: export + submit + update) =="
python examples/serve_quickstart.py --steps 120 --n 1024

echo "== temporal quickstart (state-space GP: fit + stream + forecast) =="
python examples/temporal_quickstart.py --n 20000 --steps 40

echo "== gplvm_synthetic (Bayesian GP-LVM, facade, smoke size) =="
# smoke bar: at N=512 the latent-recovery correlation is draw-limited (~0.7
# even for the pre-facade code); the 0.95 bar is the full-size (default-args)
# target. Smoke mode checks the whole facade path runs and learns — on the
# fused backend, so the GP-LVM training step exercises the fused kernel's
# custom VJP under the mesh.
python examples/gplvm_synthetic.py --n 512 --m 32 --steps 150 --min-corr 0.55 \
    --backend fused

echo "== benchmark harness (streaming engine, smoke mode) =="
# smoke output goes to a scratch path: the repo-root BENCH_gp.json is the
# committed full-sweep trajectory and must not be clobbered with smoke rows
SMOKE_BENCH="$(mktemp -t BENCH_gp_smoke.XXXXXX.json)"
python -m benchmarks.run --smoke --only gp_stream --out "$SMOKE_BENCH" > /dev/null
SMOKE_BENCH="$SMOKE_BENCH" python - <<'PY'
import json
import os

doc = json.load(open(os.environ["SMOKE_BENCH"]))
rows = doc["rows"]
required = {"model", "backend", "pass", "N", "seconds", "us_per_point",
            "scaling_class", "peak_intermediate_bytes", "bwd_backend"}
assert rows, "BENCH_gp.json has no rows"
assert all(required <= set(r) for r in rows), "BENCH_gp.json rows malformed"
assert {r["backend"] for r in rows} >= {"jnp", "fused"}, "missing backend rows"
assert any(r["backend"] == "fused" and r["pass"] == "step" for r in rows), \
    "missing fused grad-step rows"
assert any(r["backend"].startswith("singlestat") and r["pass"] == "step"
           and r["bwd_backend"] == "pallas" for r in rows), \
    "missing single-statistic grad-step rows (kfu/psi1/psi2 reverse kernels)"
from benchmarks.common import SCHEMA_VERSION  # PYTHONPATH/cwd set above
assert doc["meta"]["schema_version"] == SCHEMA_VERSION, doc["meta"]
print(f"benchmark smoke JSON OK ({len(rows)} rows)")
PY

echo "== benchmark harness (serving latency, smoke mode) =="
SERVE_BENCH="$(mktemp -t BENCH_serve_smoke.XXXXXX.json)"
python -m benchmarks.run --smoke --only serve --serve-out "$SERVE_BENCH" > /dev/null
SERVE_BENCH="$SERVE_BENCH" python - <<'PY'
import json
import os

doc = json.load(open(os.environ["SERVE_BENCH"]))
rows = doc["rows"]
paths = {r.get("path") for r in rows if r.get("op") == "predict"}
assert paths >= {"facade", "server_bucketed", "server_nobucket"}, paths
assert any(r.get("op") == "derived" and r.get("name") == "speedup_vs_facade"
           for r in rows), "missing speedup row"
assert any(r.get("op") == "update" for r in rows), "missing update rows"
assert any(r.get("op") == "submit" for r in rows), "missing submit rows"
print(f"serve smoke JSON OK ({len(rows)} rows)")
PY

echo "== benchmark harness (serving sustained load, smoke mode) =="
# small concurrent predict+update streams against a scratch checkpoint
# store: asserts the budgeted run's peak resident bytes stayed UNDER the
# budget (the serving tier's hard acceptance bar) and that eviction/reload
# traffic actually happened
LOAD_BENCH="$(mktemp -t BENCH_serve_load_smoke.XXXXXX.json)"
python -m benchmarks.run --smoke --only serve_load --serve-out "$LOAD_BENCH" > /dev/null
LOAD_BENCH="$LOAD_BENCH" python - <<'PY'
import json
import os

doc = json.load(open(os.environ["LOAD_BENCH"]))
rows = [r for r in doc["rows"] if r.get("section") == "serve_load"]
assert {r["path"] for r in rows} == {"budgeted", "unbounded"}, rows
from benchmarks.run import SERVE_LOAD_ROW_KEYS
assert all(SERVE_LOAD_ROW_KEYS <= set(r) for r in rows), "load rows malformed"
assert all(r["errors"] == 0 for r in rows), rows
budgeted = next(r for r in rows if r["path"] == "budgeted")
assert budgeted["under_budget"], budgeted
assert budgeted["peak_resident_bytes"] <= budgeted["budget_bytes"], budgeted
assert budgeted["evictions"] > 0 and budgeted["lazy_loads"] > 0, budgeted
assert all(r["requests"] > 0 and r["updates"] > 0 for r in rows), rows
print(f"serve_load smoke JSON OK ({len(rows)} rows, "
      f"peak {budgeted['peak_resident_bytes']} <= budget "
      f"{budgeted['budget_bytes']})")
PY

echo "== benchmark harness (temporal parallel-vs-sequential, smoke mode) =="
TEMPORAL_BENCH="$(mktemp -t BENCH_temporal_smoke.XXXXXX.json)"
python -m benchmarks.run --smoke --only temporal --temporal-out "$TEMPORAL_BENCH" > /dev/null
TEMPORAL_BENCH="$TEMPORAL_BENCH" python - <<'PY'
import json
import os

doc = json.load(open(os.environ["TEMPORAL_BENCH"]))
rows = doc["rows"]
assert {r["op"] for r in rows} == {"lml", "predict"}, rows
assert {r["path"] for r in rows} == {"sequential", "parallel"}, rows
required = {"section", "op", "path", "N", "d", "us_per_call", "ns_per_point"}
assert all(required <= set(r) for r in rows), "temporal rows malformed"
assert all("speedup_vs_sequential" in r for r in rows
           if r["path"] == "parallel"), "missing speedup on parallel rows"
from benchmarks.common import SCHEMA_VERSION
assert doc["meta"]["schema_version"] == SCHEMA_VERSION, doc["meta"]
print(f"temporal smoke JSON OK ({len(rows)} rows)")
PY

echo "== benchmark harness (static VMEM budget table, smoke mode) =="
VMEM_BENCH="$(mktemp -t BENCH_vmem_smoke.XXXXXX.json)"
python -m benchmarks.run --smoke --only analysis --vmem-out "$VMEM_BENCH" > /dev/null
VMEM_BENCH="$VMEM_BENCH" python - <<'PY'
import json
import os

doc = json.load(open(os.environ["VMEM_BENCH"]))
rows = doc["rows"]
from repro.analysis.pallas_audit import KERNELS
assert [r["kernel"] for r in rows] == list(KERNELS), rows
assert all(r["fits"] and not r["findings"] for r in rows), rows
required = {"grid", "ct", "blocks", "streamed_bytes", "resident_bytes",
            "body_workspace_bytes", "vmem_estimate_bytes", "vmem_budget_bytes"}
assert all(required <= set(r) for r in rows), "vmem rows malformed"
print(f"vmem smoke JSON OK ({len(rows)} rows)")
PY

echo "== benchmark harness (autotuner tuned-vs-default, smoke mode) =="
# the 2-candidate smoke grid: candidate generation, timing, winner pick and
# roofline comparison all run on CPU interpret — fast, asserts the machinery
TUNE_BENCH="$(mktemp -t BENCH_tune_smoke.XXXXXX.json)"
TUNE_CACHE="$(mktemp -t tune_cache.XXXXXX.json)"
REPRO_TUNE=1 REPRO_TUNE_CACHE="$TUNE_CACHE" REPRO_TUNE_MAX_CANDIDATES=2 \
    python -m benchmarks.run --smoke --only tune --tune-out "$TUNE_BENCH" > /dev/null
TUNE_BENCH="$TUNE_BENCH" python - <<'PY'
import json
import os

doc = json.load(open(os.environ["TUNE_BENCH"]))
rows = doc["rows"]
from repro.analysis.pallas_audit import KERNELS
kernels = [r["kernel"] for r in rows]
assert kernels[:len(KERNELS)] == list(KERNELS), kernels
assert kernels[-1] == "streaming_suff_stats", kernels
block_req = {"default_block", "best_block", "t_default_s", "t_best_s",
             "speedup_vs_default", "achieved_flops", "roofline_peak_flops",
             "roofline_frac"}
assert all(block_req <= set(r) for r in rows[:-1]), "tune rows malformed"
assert {"default_chunk", "best_chunk", "speedup_vs_default"} <= set(rows[-1])
assert all(r["t_best_s"] <= r["t_default_s"] for r in rows), \
    "winner slower than default?"
from benchmarks.common import SCHEMA_VERSION
assert doc["meta"]["schema_version"] == SCHEMA_VERSION, doc["meta"]
print(f"tune smoke JSON OK ({len(rows)} rows)")
PY

echo "== compiled-kernel parity lane (hardware-gated) =="
# asserts compiled-vs-interpret numerics for every registered kernel in both
# directions on TPU/GPU; on CPU-only hosts every test skips (still verifies
# the marker wiring collects)
python -m pytest -q -m compiled tests/test_compiled_parity.py

echo "CI OK"
