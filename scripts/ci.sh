#!/usr/bin/env bash
# CI entry point: tier-1 tests + both GP examples in smoke mode, so the
# repro.gp facade path is exercised end-to-end on every PR.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== quickstart (sparse GP regression, facade) =="
python examples/quickstart.py --steps 150

echo "== gplvm_synthetic (Bayesian GP-LVM, facade, smoke size) =="
# smoke bar: at N=512 the latent-recovery correlation is draw-limited (~0.7
# even for the pre-facade code); the 0.95 bar is the full-size (default-args)
# target. Smoke mode checks the whole facade path runs and learns.
python examples/gplvm_synthetic.py --n 512 --m 32 --steps 150 --min-corr 0.55

echo "CI OK"
