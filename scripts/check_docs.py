#!/usr/bin/env python
"""Docs checks, run by scripts/ci.sh:

1. every relative markdown link in README.md and docs/**/*.md resolves to a
   real file;
2. every backtick-quoted dotted `repro.*` symbol named anywhere in docs/
   actually imports (modules import, attributes getattr) — so the API
   reference cannot drift from the code.
"""
from __future__ import annotations

import importlib
import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))

LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+?)(?:#[^)]*)?\)")
SYMBOL = re.compile(r"`(repro(?:\.[A-Za-z_][A-Za-z0-9_]*)+)`")

failures: list[str] = []
md_files = [ROOT / "README.md", *sorted((ROOT / "docs").rglob("*.md"))]

for md in md_files:
    text = md.read_text()
    for target in LINK.findall(text):
        if "://" in target or target.startswith(("mailto:", "#")):
            continue
        if not (md.parent / target).exists():
            failures.append(f"{md.relative_to(ROOT)}: broken link -> {target}")

symbols = sorted({s for md in md_files if md.is_relative_to(ROOT / "docs")
                  for s in SYMBOL.findall(md.read_text())})
for dotted in symbols:
    parts = dotted.split(".")
    # longest importable module prefix, then getattr the rest
    for cut in range(len(parts), 0, -1):
        try:
            obj = importlib.import_module(".".join(parts[:cut]))
            break
        except ImportError:
            continue
    else:
        failures.append(f"docs: {dotted} — no importable module prefix")
        continue
    try:
        for attr in parts[cut:]:
            obj = getattr(obj, attr)
    except AttributeError as e:
        failures.append(f"docs: {dotted} does not resolve ({e})")

if failures:
    print("\n".join(failures))
    sys.exit(1)
print(f"docs OK: {len(md_files)} markdown files, {len(symbols)} "
      f"import-checked symbols")
