"""Synthetic data: the paper's GP-LVM dataset and a checkpointable LM token
pipeline.

GP dataset (paper §4): N 1-D latent points mapped to 3-D by sampling function
draws under an RBF kernel. Exact GP sampling is O(N^3); beyond ~4k points we
use random Fourier features (Rahimi & Recht) — an unbiased RBF-kernel
approximation whose error is immaterial for the scaling experiments (the
paper's own data is one fixed draw).

LM pipeline: an infinite deterministic token stream. Batch t is a pure
function of (seed, t), so the iterator "state" is a single integer — restart
from a checkpoint reproduces the exact stream (fault tolerance is trivially
exact), and each data shard materializes only its slice.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# paper §4 synthetic GP-LVM data
# ---------------------------------------------------------------------------

def gplvm_synthetic(key, N: int, D: int = 3, Q: int = 1, lengthscale: float = 1.0,
                    noise_std: float = 0.05, n_features: int = 512):
    """Returns (X_true (N, Q), Y (N, D))."""
    kx, kw, kb, kw2, kn = jax.random.split(key, 5)
    X = jax.random.uniform(kx, (N, Q), jnp.float32, -2.0, 2.0)
    if N <= 4096:
        # exact GP draw — in host float64: the f32 Cholesky of a dense RBF
        # Gram matrix is indefinite beyond a few hundred points
        X64 = np.asarray(X, np.float64)
        d2 = ((X64[:, None] - X64[None, :]) ** 2).sum(-1)
        K = np.exp(-0.5 * d2 / lengthscale**2) + 1e-6 * np.eye(N)
        L = np.linalg.cholesky(K)
        F = jnp.asarray(L @ np.asarray(jax.random.normal(kw, (N, D)), np.float64),
                        jnp.float32)
    else:
        # random Fourier features: k(x,x') = E[cos(w x + b) cos(w x' + b)] * 2
        omega = jax.random.normal(kw, (Q, n_features)) / lengthscale
        b = jax.random.uniform(kb, (n_features,), maxval=2 * jnp.pi)
        phi = jnp.sqrt(2.0 / n_features) * jnp.cos(X @ omega + b)  # (N, F)
        W = jax.random.normal(kw2, (n_features, D))
        F = phi @ W
    Y = F + noise_std * jax.random.normal(kn, (N, D))
    return X, Y


# ---------------------------------------------------------------------------
# LM token pipeline
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TokenStreamState:
    seed: int
    step: int  # the only mutable state — exactly checkpointable


class TokenStream:
    """Deterministic synthetic LM batches: batch(t) = f(seed, t).

    `sharding` (optional NamedSharding) places each batch directly onto the
    mesh; with a real corpus this is where per-host file reads would live —
    the interface (stateless indexed batches + integer state) is the one a
    production loader must satisfy for exact restart.
    """

    def __init__(self, cfg, shape, *, seed: int = 0, batch: Optional[int] = None,
                 shardings=None):
        from repro.models.model_zoo import batch_shapes

        self.spec = batch_shapes(cfg, shape, batch)
        self.vocab = cfg.vocab_size
        self.state = TokenStreamState(seed=seed, step=0)
        self.shardings = shardings

    def checkpoint_state(self) -> Dict[str, int]:
        return dataclasses.asdict(self.state)

    def restore_state(self, st: Dict[str, int]) -> None:
        self.state = TokenStreamState(**st)

    def next(self) -> Dict[str, jax.Array]:
        key = jax.random.fold_in(jax.random.PRNGKey(self.state.seed), self.state.step)
        out = {}
        for name, (shp, dt) in self.spec.items():
            key, sub = jax.random.split(key)
            if dt == jnp.int32:
                arr = jax.random.randint(sub, shp, 0, self.vocab, dt)
            else:
                arr = jax.random.normal(sub, shp, jnp.float32).astype(dt)
            if self.shardings is not None and name in self.shardings:
                arr = jax.device_put(arr, self.shardings[name])
            out[name] = arr
        self.state.step += 1
        return out

    def __iter__(self) -> Iterator[Dict[str, jax.Array]]:
        while True:
            yield self.next()
