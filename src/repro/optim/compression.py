"""Gradient compression for cross-pod data parallelism.

At 2+ pods the DP all-reduce crosses the DCN (slow inter-pod links), so we
provide top-k sparsification with error feedback (Stich et al. style): keep
the k largest-magnitude entries per tensor, carry the residual into the next
step. Convergence-safe (error feedback makes it unbiased-in-the-limit) and
cuts cross-pod all-reduce bytes by 1/ratio.

Applied only to the *pod* axis reduction in the training step (see
launch/train.py); the intra-pod ICI all-reduce stays dense.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


class CompressionState(NamedTuple):
    residual: PyTree  # error-feedback accumulator, same structure as grads


def compression_init(grads_like: PyTree) -> CompressionState:
    return CompressionState(jax.tree.map(lambda g: jnp.zeros_like(g), grads_like))


def topk_compress_decompress(
    grads: PyTree, state: CompressionState, ratio: float = 0.01
) -> tuple[PyTree, CompressionState]:
    """Returns (sparsified-but-dense grads, new residual state).

    The output has the same dense layout (so it can feed an ordinary psum) but
    only ceil(ratio * n) nonzeros per tensor — a real deployment pairs this
    with a sparse collective; in XLA-land the win is modeled at the roofline
    level (collective_bytes * ratio) and validated numerically here.
    """

    def one(g: jax.Array, r: jax.Array) -> tuple[jax.Array, jax.Array]:
        acc = g.astype(jnp.float32) + r.astype(jnp.float32)
        flat = acc.reshape(-1)
        k = max(1, int(ratio * flat.size))
        thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
        mask = (jnp.abs(flat) >= thresh).astype(flat.dtype)
        kept = flat * mask
        new_resid = (flat - kept).reshape(g.shape)
        return kept.reshape(g.shape).astype(g.dtype), new_resid.astype(r.dtype)

    pairs = jax.tree.map(one, grads, state.residual)
    compressed = jax.tree.map(lambda p: p[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
    residual = jax.tree.map(lambda p: p[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
    return compressed, CompressionState(residual)
