"""Hand-rolled Adam(W) for pytrees (no optax on the box).

Production-relevant details:
  * optimizer-state dtype is configurable — `state_dtype="bfloat16"` halves
    the HBM footprint of m/v, which is what lets arctic-480b train on a
    single 256-chip v5e pod (see EXPERIMENTS.md §Dry-run);
  * global-norm clipping in fp32 regardless of state dtype;
  * decoupled weight decay (AdamW) with a mask callback;
  * bias correction folded into the step size (saves one pass over params).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class AdamConfig:
    lr: float | Callable[[jax.Array], jax.Array] = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    clip_norm: Optional[float] = 1.0
    state_dtype: Optional[str] = None  # None => same dtype as param
    # params matching this predicate get no weight decay (e.g. norms, biases)
    decay_mask: Optional[Callable[[str], bool]] = None


class AdamState(NamedTuple):
    step: jax.Array
    m: PyTree
    v: PyTree


def _state_like(p: jax.Array, dtype: Optional[str]) -> jax.Array:
    return jnp.zeros(p.shape, dtype or p.dtype)


def adam_init(params: PyTree, config: AdamConfig) -> AdamState:
    zeros = lambda p: _state_like(p, config.state_dtype)
    return AdamState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def global_norm(tree: PyTree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adam_update(
    grads: PyTree, state: AdamState, params: PyTree, config: AdamConfig
) -> tuple[PyTree, AdamState, jax.Array]:
    """Returns (new_params, new_state, pre-clip grad norm)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    if config.clip_norm is not None:
        scale = jnp.minimum(1.0, config.clip_norm / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)

    lr = config.lr(step) if callable(config.lr) else jnp.asarray(config.lr)
    b1, b2 = config.b1, config.b2
    # fold bias correction into the step size
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    alpha = lr * jnp.sqrt(bc2) / bc1

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m32 = m.astype(jnp.float32)
        v32 = v.astype(jnp.float32)
        m_new = b1 * m32 + (1.0 - b1) * g32
        v_new = b2 * v32 + (1.0 - b2) * g32 * g32
        delta = alpha * m_new / (jnp.sqrt(v_new) + config.eps)
        p_new = p.astype(jnp.float32) - delta
        return p_new, m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    # weight-decay mask keyed on the flattened path names
    paths = [
        "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        for path, _ in jax.tree_util.tree_flatten_with_path(params)[0]
    ]

    new_p, new_m, new_v = [], [], []
    for p, g, m, v, name in zip(flat_p, flat_g, flat_m, flat_v, paths):
        p_new, m_new, v_new = upd(p, g, m, v)
        if config.weight_decay > 0.0 and (config.decay_mask is None or config.decay_mask(name)):
            p_new = p_new - lr * config.weight_decay * p.astype(jnp.float32)
        new_p.append(p_new.astype(p.dtype))
        new_m.append(m_new.astype(m.dtype))
        new_v.append(v_new.astype(v.dtype))

    return (
        treedef.unflatten(new_p),
        AdamState(step, treedef.unflatten(new_m), treedef.unflatten(new_v)),
        gnorm,
    )
