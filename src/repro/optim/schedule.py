"""LR schedules. WSD (warmup-stable-decay) is required by minicpm-2b's
training recipe (arXiv:2404.06395); cosine is the default for the rest."""
from __future__ import annotations

import jax.numpy as jnp


def constant_schedule(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_schedule(peak_lr: float, warmup_steps: int, total_steps: int, min_ratio: float = 0.1):
    def fn(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup_steps, 1)
        frac = jnp.clip((step - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = peak_lr * (min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup_steps, warm, cos)

    return fn


def wsd_schedule(peak_lr: float, warmup_steps: int, stable_steps: int, decay_steps: int,
                 min_ratio: float = 0.01):
    """Warmup-Stable-Decay: linear warmup, flat plateau, exponential-ish
    (here: linear in log-space) decay tail."""
    def fn(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup_steps, 1)
        decay_start = warmup_steps + stable_steps
        frac = jnp.clip((step - decay_start) / max(decay_steps, 1), 0.0, 1.0)
        decay = peak_lr * jnp.exp(frac * jnp.log(min_ratio))
        return jnp.where(
            step < warmup_steps, warm, jnp.where(step < decay_start, peak_lr, decay)
        )

    return fn
