from repro.optim.adam import AdamConfig, AdamState, adam_init, adam_update
from repro.optim.schedule import wsd_schedule, cosine_schedule, constant_schedule
from repro.optim.compression import topk_compress_decompress, CompressionState

__all__ = [
    "AdamConfig",
    "AdamState",
    "adam_init",
    "adam_update",
    "wsd_schedule",
    "cosine_schedule",
    "constant_schedule",
    "topk_compress_decompress",
    "CompressionState",
]
