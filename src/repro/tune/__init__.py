"""repro.tune: empirical tile/chunk autotuner with a persistent cache.

The hand-picked Pallas tile constants and the magic streaming chunk become
MEASURED decisions: on first use of a kernel at a problem key the tuner
times every auditor-admissible block configuration and persists the winner
(`~/.cache/repro/tune.json`, override with $REPRO_TUNE_CACHE); every later
process is a pure lookup with zero timing runs. `kernels.ops` consults
`best_blocks()` for all seven registered kernels, and `chunk="auto"`
anywhere a chunk is accepted resolves through `best_chunk()`.

Measurement is on by default only on accelerator backends; set REPRO_TUNE=1
to force it elsewhere (the CI smoke lane does, with a 2-candidate grid via
$REPRO_TUNE_MAX_CANDIDATES). See docs/tuning.md.
"""
from repro.tune.autotune import (
    MEASURE_PROBLEM,
    best_blocks,
    best_chunk,
    cached_interpret_max_n,
    clear_memo,
    enabled,
    make_key,
    measure_blocks,
    measure_chunks,
    timing_runs,
)
from repro.tune.cache import (
    SCHEMA_VERSION,
    cache_path,
    load_entries,
    lookup,
    store,
)
from repro.tune.search import (
    CHUNK_CANDIDATES,
    DEFAULT_CHUNK,
    TILE_M_CANDIDATES,
    TILE_N_CANDIDATES,
    admissible,
    candidate_blocks,
    candidate_chunks,
    default_blocks,
)

__all__ = [
    "MEASURE_PROBLEM",
    "SCHEMA_VERSION",
    "CHUNK_CANDIDATES",
    "DEFAULT_CHUNK",
    "TILE_M_CANDIDATES",
    "TILE_N_CANDIDATES",
    "admissible",
    "best_blocks",
    "best_chunk",
    "cache_path",
    "cached_interpret_max_n",
    "candidate_blocks",
    "candidate_chunks",
    "clear_memo",
    "default_blocks",
    "enabled",
    "load_entries",
    "lookup",
    "make_key",
    "measure_blocks",
    "measure_chunks",
    "store",
    "timing_runs",
]
