"""Persistent winner store of the `repro.tune` autotuner.

One small JSON document holds every tuned decision this machine has made:

    {"schema_version": 1,
     "entries": {"blocks|kfu_pallas|float32|M=256|Q=4|cpu|cpu":
                     {"winner": [256, 128], ...}, ...}}

Location: ``$REPRO_TUNE_CACHE`` when set, else ``~/.cache/repro/tune.json``.
Writes are atomic (temp file + ``os.replace``) so a concurrent reader sees
either the previous or the new complete document, never a torn one. Reads
are tolerant by design: a missing, truncated, corrupt, or schema-mismatched
file loads as an empty store — a stale cache can cost a re-tune, but it must
never take the library down.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Dict, Optional

from repro.analysis import lockdep

__all__ = ["SCHEMA_VERSION", "cache_path", "load_entries", "lookup", "store"]

SCHEMA_VERSION = 1

_ENV_PATH = "REPRO_TUNE_CACHE"

# guards read-merge-write cycles within this process; cross-process safety
# comes from the atomic replace (last writer wins per whole document).
# Routed through lockdep so the runtime verifier sees the file lock; the
# canonical name is its position in concurrency.LOCK_HIERARCHY, and the
# read-merge-write I/O under it is declared in concurrency.BLOCKING_OK —
# serializing that I/O is this lock's documented job.
_LOCK = lockdep.named_lock("repro.tune.cache._LOCK", kind="rlock")


def cache_path() -> str:
    env = os.environ.get(_ENV_PATH)
    if env:
        return os.path.expanduser(env)
    return os.path.join(os.path.expanduser("~"), ".cache", "repro",
                        "tune.json")


def load_entries(path: Optional[str] = None) -> Dict[str, Any]:
    """The entries mapping of the store at `path` (default `cache_path()`);
    {} for missing, unreadable, corrupt, or schema-mismatched files."""
    path = cache_path() if path is None else path
    try:
        with open(path, "r", encoding="utf-8") as f:
            doc = json.load(f)
    except (OSError, ValueError):
        return {}
    if not isinstance(doc, dict) or doc.get("schema_version") != SCHEMA_VERSION:
        return {}
    entries = doc.get("entries")
    return dict(entries) if isinstance(entries, dict) else {}


def lookup(key: str, path: Optional[str] = None) -> Any:
    """The stored value for `key`, or None."""
    return load_entries(path).get(key)


def store(key: str, value: Any, path: Optional[str] = None) -> None:
    """Merge one winner into the store atomically."""
    path = cache_path() if path is None else path
    with _LOCK:
        entries = load_entries(path)
        entries[key] = value
        doc = {"schema_version": SCHEMA_VERSION, "entries": entries}
        directory = os.path.dirname(path) or "."
        os.makedirs(directory, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=directory, prefix=".tune-",
                                   suffix=".json")
        try:
            with os.fdopen(fd, "w", encoding="utf-8") as f:
                json.dump(doc, f, indent=2, sort_keys=True)
            os.replace(tmp, path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise
