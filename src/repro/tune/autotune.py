"""Empirical tile/chunk autotuner (ROADMAP item 2).

On the FIRST use of a kernel at a given problem key, the tuner times every
admissible block configuration (`repro.tune.search` — the auditor-gated
ladder) with a short micro-benchmark and persists the winner in the JSON
store (`repro.tune.cache`). Every later use, in this process or any other,
is a pure lookup: a warm cache performs ZERO timing runs (`timing_runs()`
is the witness the tests assert on).

Resolution order of `best_blocks` / `best_chunk`:

  1. in-process memo (dict hit — the per-training-step cost),
  2. persistent cache file,
  3. when tuning is `enabled()`: measure, store, return the winner,
  4. otherwise: memoize the fallback (module-default blocks / DEFAULT_CHUNK)
     without ever starting a stopwatch.

Measurement is opt-in off-accelerator (`REPRO_TUNE=1` or the test override):
interpret-mode wall times say nothing about the compiled kernels, and the
CPU test suite must not pay for micro-benchmarks it cannot use. On TPU/GPU
backends tuning is on by default — exactly where the measured numbers mean
something. Cache keys carry `(dtype, M, Q, backend, device_kind)` so winners
never leak across machines, dtypes, or problem shapes; N is deliberately
absent (the datapoint axis is streamed — block goodness is N-independent).
"""
from __future__ import annotations

import dataclasses
import functools
import os
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax

from repro.analysis import lockdep
from repro.analysis.pallas_audit import Problem, registry_entry
from repro.tune import cache, search

__all__ = [
    "MEASURE_PROBLEM",
    "best_blocks",
    "best_chunk",
    "cached_interpret_max_n",
    "clear_memo",
    "enabled",
    "measure_blocks",
    "measure_chunks",
    "timing_runs",
]

# Test-visible override: None = env/backend policy, True/False force.
_ENABLED_OVERRIDE: Optional[bool] = None

# One lock guards the whole resolve-measure-store cycle, so two threads
# racing the same cold key serialize and agree on one winner (the second
# thread lands on the memo the first one filled). Routed through lockdep
# (canonical name = its rank in concurrency.LOCK_HIERARCHY) so the serve
# battery's runtime verifier sees autotune -> cache acquisitions.
_LOCK = lockdep.named_lock("repro.tune.autotune._LOCK", kind="rlock")
_MEMO: Dict[Tuple[str, str], Any] = {}  # (cache path, key) -> winner

_TIMING_RUNS = 0

# Representative measurement sizes: N is streamed by every kernel, so a
# modest value keeps first-call tuning cheap without changing the ranking.
MEASURE_PROBLEM = Problem(N=1024, M=256, Q=4, D=2)

_WARMUP = 1
_ITERS = 3


def enabled() -> bool:
    """Is the measuring path live? $REPRO_TUNE wins when set ("0"/"false"/
    "off" disable, anything else enables); the test override wins over that;
    otherwise tuning is on exactly on accelerator backends. Disabled keys
    still resolve through the same lookup path — they just memoize the
    defaults with zero timing runs."""
    if _ENABLED_OVERRIDE is not None:
        return bool(_ENABLED_OVERRIDE)
    env = os.environ.get("REPRO_TUNE")
    if env is not None and env != "":
        return env.strip().lower() not in ("0", "false", "off")
    return jax.default_backend() in ("tpu", "gpu", "cuda", "rocm")


def timing_runs() -> int:
    """Micro-benchmark invocations this process has performed. The warm-
    cache contract is that a second process over the same cache file keeps
    this at zero."""
    return _TIMING_RUNS


def clear_memo() -> None:
    """Drop the in-process memo (NOT the persistent file) — tests use this
    to re-exercise the cache-file path within one process."""
    with _LOCK:
        _MEMO.clear()


def _device_kind() -> str:
    try:
        return jax.devices()[0].device_kind
    except Exception:
        return "unknown"


def make_key(kind: str, name: str, dtype, m: int, q: int,
             extra: str = "") -> str:
    """The persistent-store key: what the winner is FOR (kind+name) and
    what it was measured ON (dtype, M, Q, backend, device kind)."""
    import jax.numpy as jnp

    dt = str(jnp.dtype(jnp.float32 if dtype is None else dtype))
    parts = [kind, name, dt, f"M={int(m)}", f"Q={int(q)}",
             jax.default_backend(), _device_kind()]
    if extra:
        parts.append(extra)
    return "|".join(parts)


def _time_fn(fn: Callable[[], Any]) -> float:
    """Median-of-_ITERS wall time of one candidate, after warmup, with
    block_until_ready. Monkeypatchable in tests; `timing_runs` is counted
    by the measure_* callers, not here, so fake timers still register."""
    for _ in range(_WARMUP):
        jax.block_until_ready(fn())
    times = []
    for _ in range(_ITERS):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def measure_blocks(kernel_name: str, candidates, *,
                   problem: Problem = MEASURE_PROBLEM, dtype=None,
                   ) -> Dict[Tuple[int, int], float]:
    """Wall time per candidate block on the real kernel wrapper. Inputs are
    concrete ones (timing is value-independent); interpret mode follows
    `ops.interpret_mode()` so the CPU smoke lane drives the same code path
    the accelerators tune for real."""
    global _TIMING_RUNS
    import jax.numpy as jnp

    from repro.kernels import ops

    dtype = jnp.float32 if dtype is None else jnp.dtype(dtype)
    fn, build = registry_entry(kernel_name)
    args = [jnp.ones(a.shape, a.dtype) for a in build(problem, dtype)]
    interp = ops.interpret_mode()
    out: Dict[Tuple[int, int], float] = {}
    for blk in candidates:
        blk = (int(blk[0]), int(blk[1]))
        _TIMING_RUNS += 1
        out[blk] = _time_fn(
            functools.partial(fn, *args, interpret=interp, block=blk))
    return out


def measure_chunks(candidates, *, n: int, m: int, q: int, d: int,
                   dtype=None, backend: str = "jnp",
                   bwd_backend: str = "auto") -> Dict[int, float]:
    """Wall time per streaming chunk size through the real
    `gp.stats.streaming_suff_stats` scan (expected statistics under an RBF
    kernel — the paper's hot path)."""
    global _TIMING_RUNS
    import jax.numpy as jnp

    from repro.gp.kernels import RBF
    from repro.gp.stats import ExpectedBatch, streaming_suff_stats

    dtype = jnp.float32 if dtype is None else jnp.dtype(dtype)
    kern = RBF(int(q))
    params = {k: v.astype(dtype) for k, v in kern.init().items()}
    batch = ExpectedBatch(
        mu=jnp.ones((n, q), dtype),
        S=jnp.full((n, q), 0.5, dtype),
        Y=jnp.ones((n, d), dtype),
        Z=jnp.ones((m, q), dtype),
    )
    out: Dict[int, float] = {}
    for c in candidates:
        _TIMING_RUNS += 1
        out[int(c)] = _time_fn(functools.partial(
            streaming_suff_stats, kern, params, batch, backend=backend,
            chunk=int(c), bwd_backend=bwd_backend))
    return out


def _resolve(key: str, fallback, measure: Callable[[], Any]):
    """The shared memo -> file -> measure/store -> fallback ladder."""
    path = cache.cache_path()
    memo_key = (path, key)
    with _LOCK:
        if memo_key in _MEMO:
            return _MEMO[memo_key]
        hit = cache.lookup(key, path)
        if isinstance(hit, dict) and "winner" in hit:
            win = hit["winner"]
            _MEMO[memo_key] = win
            return win
        if not enabled():
            _MEMO[memo_key] = fallback
            return fallback
        value = measure()
        if value is None:
            value = fallback
        else:
            cache.store(key, value if isinstance(value, dict)
                        else {"winner": value}, path)
            value = value["winner"] if isinstance(value, dict) else value
        _MEMO[memo_key] = value
        return value


def best_blocks(kernel_name: str, *, dtype=None, m: int, q: int,
                problem: Optional[Problem] = None) -> Optional[Tuple[int, int]]:
    """The tuned (tile_n, tile_m) for one registered kernel at one problem
    key, or None meaning "use the module defaults". Every `kernels.ops`
    entry point resolves its blocks through here — in both directions."""
    key = make_key("blocks", kernel_name, dtype, m, q)

    def measure():
        prob = problem or dataclasses.replace(
            MEASURE_PROBLEM, M=int(m), Q=int(q))
        cands = search.candidate_blocks(kernel_name, problem=prob,
                                        dtype=dtype)
        if not cands:
            return None
        timings = measure_blocks(kernel_name, cands, problem=prob,
                                 dtype=dtype)
        win = min(timings, key=timings.get)
        return {"winner": list(win), "kernel": kernel_name,
                "timings_s": {f"{a}x{b}": t
                              for (a, b), t in timings.items()}}

    win = _resolve(key, None, measure)
    return None if win is None else (int(win[0]), int(win[1]))


def best_chunk(*, n: int, m: int, q: int, d: int, dtype=None,
               backend: str = "jnp", bwd_backend: str = "auto") -> int:
    """The tuned `lax.scan` chunk for the streaming suff-stats path —
    what `chunk="auto"` resolves to. Falls back to `search.DEFAULT_CHUNK`
    (the historical constant) when tuning is disabled and nothing is
    cached."""
    key = make_key("chunk", "streaming_suff_stats", dtype, m, q,
                   extra=f"backend={backend}")

    def measure():
        n_meas = max(1, min(int(n), 4 * max(search.CHUNK_CANDIDATES)))
        cands = search.candidate_chunks(n_meas)
        if not cands:
            return None
        timings = measure_chunks(cands, n=n_meas, m=m, q=q, d=d,
                                 dtype=dtype, backend=backend,
                                 bwd_backend=bwd_backend)
        win = min(timings, key=timings.get)
        return {"winner": int(win), "kernel": "streaming_suff_stats",
                "timings_s": {str(c): t for c, t in timings.items()}}

    return int(_resolve(key, search.DEFAULT_CHUNK, measure))


def cached_interpret_max_n() -> Optional[int]:
    """Optional tuned override of the off-accelerator interpret-vs-streaming
    dispatch threshold (`ops.fused_interpret_max_n`). Nothing writes this
    key automatically; pin it manually in the store under
    ``interpret_max_n|<backend>`` (docs/tuning.md) after measuring where
    interpret-mode cost crosses the streaming twin on a given host."""
    key = "|".join(["interpret_max_n", jax.default_backend()])
    path = cache.cache_path()
    memo_key = (path, key)
    with _LOCK:
        if memo_key in _MEMO:
            return _MEMO[memo_key]
        hit = cache.lookup(key, path)
        if isinstance(hit, dict):
            hit = hit.get("winner")
        value = int(hit) if isinstance(hit, (int, float)) else None
        _MEMO[memo_key] = value
        return value
