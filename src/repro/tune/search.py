"""Search-space construction for the tile autotuner.

Candidates are NOT guessed freely: the per-axis tile ladders below are
crossed and then filtered through the auditor's VMEM residency model and
tiling rules (`repro.analysis.pallas_audit.audit_candidate`) — only blocks
that fit the ~16 MiB/core budget and break no TILE001/IDX001 rule ever
reach the stopwatch. The auditor and the tuner therefore share ONE pricing
model (`vmem_estimate`); they cannot disagree about what is admissible.
"""
from __future__ import annotations

import os
from typing import List, Optional, Tuple

from repro.analysis.pallas_audit import Problem, audit_candidate

__all__ = [
    "TILE_N_CANDIDATES",
    "TILE_M_CANDIDATES",
    "CHUNK_CANDIDATES",
    "DEFAULT_CHUNK",
    "default_blocks",
    "admissible",
    "candidate_blocks",
    "candidate_chunks",
]

# f32 minimum TPU tile is (8, 128): datapoint tiles climb in multiples of 8
# from the VPU sublane count, inducing-point tiles in lane (=128) multiples.
TILE_N_CANDIDATES = (32, 64, 128, 256, 512)
TILE_M_CANDIDATES = (128, 256)

# lax.scan streaming chunk ladder; DEFAULT_CHUNK is the historical constant
# every chunked path used before chunk="auto" existed.
CHUNK_CANDIDATES = (1024, 2048, 4096, 8192)
DEFAULT_CHUNK = 4096

# env knob the CI smoke lane uses to cap grid size (candidate COUNT, not
# tile extent); unset means the full ladder cross-product
_ENV_MAX_CANDIDATES = "REPRO_TUNE_MAX_CANDIDATES"


def default_blocks(kernel_name: str) -> Tuple[int, int]:
    """The module-constant (TILE_N, TILE_M) a kernel falls back to when no
    tuned winner exists — also always the first candidate measured."""
    from repro.kernels import kfu, psi1, psi2, suffstats

    mod = {
        "kfu_pallas": kfu,
        "psi1_pallas": psi1,
        "psi2_pallas": psi2,
    }.get(kernel_name, suffstats)
    return (int(mod.TILE_N), int(mod.TILE_M))


def admissible(kernel_name: str, block: Tuple[int, int], *,
               problem: Problem = Problem(), dtype=None) -> bool:
    """Does `block` pass the auditor's gate — VMEM fits, no tiling/index
    finding — at these problem sizes? Nothing executes or lowers."""
    audit = audit_candidate(kernel_name, block, problem=problem, dtype=dtype)
    clean = not any(f.code in ("TILE001", "IDX001") for f in audit.findings)
    return audit.fits and clean


def _max_candidates(limit: Optional[int]) -> Optional[int]:
    if limit is not None:
        return int(limit)
    env = os.environ.get(_ENV_MAX_CANDIDATES)
    return int(env) if env else None


def candidate_blocks(kernel_name: str, *, problem: Problem = Problem(),
                     dtype=None, limit: Optional[int] = None,
                     ) -> List[Tuple[int, int]]:
    """Admissible (tile_n, tile_m) candidates worth timing, defaults first.

    `limit` (or $REPRO_TUNE_MAX_CANDIDATES) caps the list AFTER the default
    block, so even the 2-candidate CI smoke grid compares the shipped
    constant against one alternative.
    """
    limit = _max_candidates(limit)
    default = default_blocks(kernel_name)
    ladder = [default] + [
        (tn, tm)
        for tn in TILE_N_CANDIDATES
        for tm in TILE_M_CANDIDATES
        if (tn, tm) != default
    ]
    out: List[Tuple[int, int]] = []
    for blk in ladder:
        if limit is not None and len(out) >= limit:
            break
        if admissible(kernel_name, blk, problem=problem, dtype=dtype):
            out.append(blk)
    return out


def candidate_chunks(n: int, *, limit: Optional[int] = None) -> List[int]:
    """Streaming-chunk candidates for a length-N scan, defaults first.
    Chunks beyond N are pointless (a single ragged tail); N itself is added
    so small problems still get a one-chunk candidate."""
    limit = _max_candidates(limit)
    ladder = [DEFAULT_CHUNK] + [c for c in CHUNK_CANDIDATES
                                if c != DEFAULT_CHUNK]
    out: List[int] = []
    for c in ladder:
        if c <= n or c == DEFAULT_CHUNK:
            out.append(int(c))
    if n > 0 and int(n) not in out:
        out.append(int(n))
    if limit is not None:
        out = out[:limit]
    return out
