"""Durable servable-state storage: the serving tier's checkpoint store.

A fitted model is (kernel, state) — a `PosteriorState` (collapsed bound,
O(M²)) or a `repro.temporal.TemporalState` (state-space forecaster, O(d²)),
tagged by `state_kind` in the manifest — the state a plain pytree
of arrays, the kernel static code addressable by registry name. So the
store needs no new format: states ride `repro.checkpoint.manager.
CheckpointManager` (atomic rename, retention, manifest-validated reads)
under one sub-directory per model name, and the kernel travels as a small
JSON spec (`kernel_spec` / `kernel_from_spec`) in the manifest's `extra`
alongside a `persist_schema` version stamp.

    store = StateStore(path)
    store.save("demand", kernel, state)         # atomic, versioned
    kernel, state = store.load("demand")        # bit-exact round trip

`GPServer(store=..., budget_bytes=...)` uses the same store as the spill
target for LRU eviction and the source for lazy reloads, and
`GPServer.save_all()` / `GPServer.load()` make a kill-and-restart serve
bit-identical predictions (tests/test_serve_persist.py).

Corrupt or truncated checkpoints (torn manifest, truncated npz, missing
leaves, wrong schema stamp) raise `CheckpointCorruptError` with the
offending piece named — a restore must never hand back garbage arrays that
would quietly serve garbage predictions.
"""
from __future__ import annotations

import re
import shutil
import threading
from pathlib import Path
from typing import Dict, Tuple

import jax
import numpy as np

from repro.checkpoint.manager import (CheckpointCorruptError, CheckpointManager,
                                      _np_dtype, leaf_key)
from repro.core.psi_stats import SuffStats
from repro.gp import kernels as gp_kernels
from repro.gp.kernels import Kernel
from repro.serve.state import PosteriorState
from repro.temporal.model import TemporalState

# Stamped into every saved manifest's extra; load() rejects mismatches so a
# field added to a state (or a meaning change) can never be silently
# reinterpreted from an old file. Bump when a state schema changes.
# Schema history: 1 = PosteriorState only; 2 = adds `state_kind`
# ("posterior" | "temporal") — schema-1 manifests still load (no
# `state_kind` implies "posterior", the only kind that existed).
PERSIST_SCHEMA = 2
_READABLE_SCHEMAS = (1, 2)
_STATE_KINDS = ("posterior", "temporal")

# model names double as directory names — keep them filesystem-safe
_NAME_RE = re.compile(r"^[A-Za-z0-9][A-Za-z0-9._-]*$")


# ---------------------------------------------------------------------------
# kernel (de)serialization
# ---------------------------------------------------------------------------

def kernel_spec(kernel: Kernel) -> Dict:
    """JSON-able constructor description that `kernel_from_spec` inverts.

    Kernels are static code keyed by registry name — the hyperparameters
    live in the state — so the spec only records the constructor shape:
    `input_dim` for leaf kernels, recursive part specs for Sum/Product.
    """
    parts = getattr(kernel, "parts", None)
    if parts is not None:
        return {"name": kernel.name, "parts": [kernel_spec(p) for p in parts]}
    return {"name": kernel.name, "input_dim": int(kernel.input_dim)}


def kernel_from_spec(spec: Dict) -> Kernel:
    """Rebuild a kernel object from its `kernel_spec` description."""
    if not isinstance(spec, dict) or "name" not in spec:
        raise ValueError(f"malformed kernel spec: {spec!r}")
    cls = gp_kernels.get(spec["name"])  # KeyError lists the registry
    if "parts" in spec:
        return cls(*[kernel_from_spec(p) for p in spec["parts"]])
    return cls(int(spec["input_dim"]))


# ---------------------------------------------------------------------------
# the named store
# ---------------------------------------------------------------------------

def _dict_skeleton(d: Dict) -> Dict:
    """The nesting structure of a param dict with `None` at every leaf —
    JSON-able, and composite kernels (k0/k1/... sub-dicts) round-trip."""
    return {k: _dict_skeleton(v) if isinstance(v, dict) else None
            for k, v in d.items()}


def state_kind(state) -> str:
    """The manifest tag for a servable state's pytree schema."""
    if isinstance(state, TemporalState):
        return "temporal"
    if isinstance(state, PosteriorState):
        return "posterior"
    raise TypeError(
        f"not a servable state: {type(state).__name__} (expected "
        f"PosteriorState or TemporalState)")


def _skeleton(kern_tree: Dict, kind: str = "posterior"):
    """A structure-only state (of the named kind) whose flatten order (and
    therefore leaf keys) matches the saved state's — dict keys sort
    identically, and NamedTuple fields flatten in declaration order.
    `kern_tree` is the saved `_dict_skeleton` of the kernel params (nested
    for composites)."""
    z = np.zeros(())

    def fill(tree):
        return {k: fill(v) if isinstance(v, dict) else z
                for k, v in tree.items()}

    if kind == "temporal":
        return TemporalState(kern=fill(kern_tree), log_beta=z, t_last=z,
                             m=z, P=z, n=z)
    if kind == "posterior":
        return PosteriorState(kern=fill(kern_tree), Z=z, log_beta=z,
                              stats=SuffStats(z, z, z, z, z),
                              L=z, LA=z, Kuu_inv_mean=z)
    raise CheckpointCorruptError(
        f"unknown state_kind {kind!r}; this build reads {_STATE_KINDS}")


class StateStore:
    """Durable named (kernel, PosteriorState) store.

    Layout: `<dir>/<name>/step_<k>/` — one CheckpointManager per model, so
    each save is atomic (tmp + rename) and `keep` old versions survive for
    rollback. Thread-safe: one coarse lock serializes store I/O (saves are
    O(M²) bytes — serialization is not the serving hot path).
    """

    def __init__(self, directory: str | Path, *, keep: int = 2):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._managers: Dict[str, CheckpointManager] = {}
        self._lock = threading.Lock()

    def _manager_locked(self, name: str) -> CheckpointManager:
        # caller holds self._lock (the `_locked` suffix is the repo-wide
        # convention repro.analysis.concurrency exempts from ANL006)
        if not _NAME_RE.match(name):
            raise ValueError(
                f"model name {name!r} is not storable: names must match "
                f"{_NAME_RE.pattern} (they double as directory names)")
        if name not in self._managers:
            self._managers[name] = CheckpointManager(self.dir / name,
                                                     keep=self.keep)
        return self._managers[name]

    # -- write ---------------------------------------------------------------

    def save(self, name: str, kernel: Kernel,
             state: "PosteriorState | TemporalState") -> int:
        """Persist one model atomically; returns the step written. Each save
        gets a fresh monotone step so retention keeps `keep` versions."""
        with self._lock:
            mgr = self._manager_locked(name)
            step = (mgr.latest_step() or 0) + 1
            extra = {
                "persist_schema": PERSIST_SCHEMA,
                "state_kind": state_kind(state),
                "kernel": kernel_spec(kernel),
                "kern_tree": _dict_skeleton(state.kern),
            }
            mgr.save(step, state, extra=extra)
            return step

    def delete(self, name: str) -> None:
        with self._lock:
            self._managers.pop(name, None)
            shutil.rmtree(self.dir / name, ignore_errors=True)

    # -- read ----------------------------------------------------------------

    def names(self) -> Tuple[str, ...]:
        """Every model with at least one persisted step."""
        return tuple(sorted(
            p.name for p in self.dir.iterdir()
            if p.is_dir() and any(p.glob("step_*"))))

    def has(self, name: str) -> bool:
        return name in self.names()

    def _extra(self, manifest: Dict, name: str) -> Dict:
        extra = manifest.get("extra") or {}
        schema = extra.get("persist_schema")
        if schema not in _READABLE_SCHEMAS:
            raise CheckpointCorruptError(
                f"model {name!r}: persist_schema is {schema!r}, this build "
                f"reads {_READABLE_SCHEMAS} — refusing to reinterpret the "
                f"state")
        if "kernel" not in extra or "kern_tree" not in extra:
            raise CheckpointCorruptError(
                f"model {name!r}: manifest extra is missing the kernel spec")
        kind = extra.get("state_kind", "posterior")  # schema 1: pre-temporal
        if kind not in _STATE_KINDS:
            raise CheckpointCorruptError(
                f"model {name!r}: unknown state_kind {kind!r}; this build "
                f"reads {_STATE_KINDS}")
        return extra

    def load_meta(self, name: str) -> Tuple[Kernel, Dict]:
        """(kernel, manifest) from the manifest alone — no array I/O. What
        `GPServer.load` uses to register persisted models cold."""
        with self._lock:
            manifest = self._manager_locked(name).load_manifest()
            extra = self._extra(manifest, name)
            return kernel_from_spec(extra["kernel"]), manifest

    def load(self, name: str) -> Tuple[Kernel, "PosteriorState | TemporalState"]:
        """Bit-exact restore of (kernel, state). Raises FileNotFoundError if
        the model was never saved, CheckpointCorruptError if its newest
        checkpoint cannot be trusted."""
        with self._lock:
            mgr = self._manager_locked(name)
            arrays, manifest = mgr.load_arrays()
            extra = self._extra(manifest, name)
            kernel = kernel_from_spec(extra["kernel"])
            flat, treedef = jax.tree_util.tree_flatten_with_path(
                _skeleton(extra["kern_tree"],
                          extra.get("state_kind", "posterior")))
            leaves = []
            for path, _ in flat:
                key = leaf_key(path)
                if key not in arrays:
                    raise CheckpointCorruptError(
                        f"model {name!r}: checkpoint missing state leaf {key!r}")
                leaves.append(jax.device_put(arrays[key]))
            state = jax.tree_util.tree_unflatten(treedef, leaves)
            return kernel, state

    def nbytes(self, name: str) -> int:
        """Resident size of the stored state, from the manifest alone (no
        array I/O) — what the server's LRU accountant charges a cold entry."""
        with self._lock:
            manifest = self._manager_locked(name).load_manifest()
            self._extra(manifest, name)
            return int(sum(
                int(np.prod(meta["shape"])) * _np_dtype(meta["dtype"]).itemsize
                for meta in manifest["leaves"].values()))
