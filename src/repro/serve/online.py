"""Online learning on a served posterior: fold data in, fold data out,
touch up the noise level — all without revisiting the training set.

Every statistic in `SuffStats` is a plain sum over datapoints, so:

    update:    stats' = stats + suff_stats(new chunk)      (monoid combine)
    downdate:  stats' = stats - suff_stats(old chunk)      (monoid inverse)

followed by the O(M^3) refold (`serve.state.build_state`). The incremental
statistics ride the SAME engine training uses — any kernel, any backend
("jnp" / "pallas" / "fused"), `chunk=` streaming — so a million-point
update materializes nothing of size (N, M) (trace-asserted in
tests/test_serve.py, same style as tests/test_streaming.py).

`update` adds PSD mass to Kuu + beta Psi2 and is unconditionally safe, so
it stays a pure traceable function. `downdate` is subtraction: floating
cancellation can leave the downdated Psi2 indefinite (Cholesky -> NaN) or
ill-conditioned, so it runs eagerly behind a condition-number guard that
refolds from the downdated statistics with escalating jitter before giving
up. `refit` re-optimizes log_beta — the one hyperparameter the cached
statistics do NOT depend on — warm-started from the served value;
theta and Z gradients need the datapoints back (the statistics are
functions of them), i.e. a training pass, not a serving-layer touch-up.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import svgp
from repro.core.psi_stats import SuffStats
from repro.gp.kernels import Kernel
from repro.gp.stats import Batch, ExactBatch, suff_stats
from repro.serve.state import PosteriorState, build_state

# downdate guard: refold with jitter * 10^k, k = 0..ESCALATIONS, then raise
ESCALATIONS = 4
# LA diag-ratio^2 above this (~1/sqrt(eps) in f64) counts as ill-conditioned
MAX_CONDITION = 1e8


def _as_2d(Y: jax.Array) -> jax.Array:
    return Y[:, None] if Y.ndim == 1 else Y


def batch_stats(kernel: Kernel, state: PosteriorState, batch: Batch, *,
                backend: str = "jnp", chunk: Optional[int] = None,
                bwd_backend: str = "auto") -> SuffStats:
    """Statistics of an incremental batch under the state's hyperparameters,
    through the standard streaming engine (repro.gp.stats.suff_stats)."""
    return suff_stats(kernel, state.kern, batch, backend=backend,
                      chunk=chunk, bwd_backend=bwd_backend)


def update(kernel: Kernel, state: PosteriorState, X_new: jax.Array,
           Y_new: jax.Array, *, backend: str = "jnp",
           chunk: Optional[int] = None, bwd_backend: str = "auto",
           jitter: float = svgp.DEFAULT_JITTER) -> PosteriorState:
    """Absorb new observations: O(B M^2) statistics + O(M^3) refold.

    Equivalent (to roundoff) to rebuilding the statistics from scratch on
    the concatenated data at the same hyperparameters — the parity the
    tests assert at 1e-8. Pure and traceable: adding datapoints only adds
    PSD mass to Kuu + beta Psi2, so no conditioning guard is needed (unlike
    `downdate`).

    A `repro.temporal.TemporalState` dispatches to the Kalman path instead:
    filter forward from the stored terminal (m, P) — X_new must be sorted
    timestamps strictly after the state's forecast origin, and the
    statistics knobs (backend/chunk/bwd_backend/jitter) are ignored (the
    O(B d^3) sequential filter has no statistics pass to configure).
    """
    from repro.temporal.model import TemporalState, update_state

    if isinstance(state, TemporalState):
        return update_state(kernel, state, X_new, Y_new)
    batch = ExactBatch(X_new, _as_2d(Y_new), state.Z)
    new = batch_stats(kernel, state, batch, backend=backend, chunk=chunk,
                      bwd_backend=bwd_backend)
    params = {"kern": state.kern, "Z": state.Z, "log_beta": state.log_beta}
    return build_state(kernel, params, SuffStats.combine(state.stats, new),
                       jitter=jitter)


def _condition_estimate(LA: np.ndarray) -> float:
    """cond(LA LA^T) estimated from the Cholesky diagonal — O(M), and the
    diagonal of a Cholesky factor brackets its extreme eigenvalues well
    enough to flag a downdate that cancelled most of the PSD mass."""
    d = np.abs(np.diagonal(LA))
    lo = float(np.min(d))
    if lo == 0.0 or not np.all(np.isfinite(d)):
        return np.inf
    return (float(np.max(d)) / lo) ** 2


def refold(kernel: Kernel, state: PosteriorState, stats: SuffStats, *,
           jitter: float = svgp.DEFAULT_JITTER) -> PosteriorState:
    """Refactorize `state` around replacement statistics, behind the
    condition guard: if the Cholesky comes back NaN/Inf or with condition
    estimate above MAX_CONDITION, refold again with 10x the jitter (up to
    ESCALATIONS decades) before raising. Eager by design — the guard reads
    device values, and the O(M^3) refold is not the serving hot path."""
    params = {"kern": state.kern, "Z": state.Z, "log_beta": state.log_beta}
    for k in range(ESCALATIONS + 1):
        candidate = build_state(kernel, params, stats, jitter=jitter * 10.0**k)
        LA = np.asarray(candidate.LA)
        if np.all(np.isfinite(LA)) and _condition_estimate(LA) <= MAX_CONDITION:
            return candidate
    raise FloatingPointError(
        f"refold: downdated statistics are numerically indefinite even at "
        f"jitter={jitter * 10.0**ESCALATIONS:g} — the removed chunk carried "
        f"too much of the posterior's mass; rebuild the statistics from the "
        f"surviving data instead"
    )


def downdate(kernel: Kernel, state: PosteriorState, X_old: jax.Array,
             Y_old: jax.Array, *, backend: str = "jnp",
             chunk: Optional[int] = None,
             jitter: float = svgp.DEFAULT_JITTER) -> PosteriorState:
    """Remove previously-absorbed observations by subtracting their exact
    statistics contribution (SuffStats.subtract), then refold behind the
    condition guard. `downdate(update(s, b), b)` round-trips to `s` up to
    floating cancellation (tested at 1e-8 in f64)."""
    from repro.temporal.model import TemporalState

    if isinstance(state, TemporalState):
        raise TypeError(
            "downdate is a statistics-monoid operation; a TemporalState is "
            "a filtered terminal state with no per-chunk inverse (the "
            "Kalman recursion only runs forward) — re-fit "
            "TemporalGPRegression on the surviving data instead")
    batch = ExactBatch(X_old, _as_2d(Y_old), state.Z)
    old = batch_stats(kernel, state, batch, backend=backend, chunk=chunk)
    return refold(kernel, state, SuffStats.subtract(state.stats, old),
                  jitter=jitter)


def refit(kernel: Kernel, state: PosteriorState, *, steps: int = 50,
          lr: float = 5e-2,
          jitter: float = svgp.DEFAULT_JITTER) -> Tuple[PosteriorState, list]:
    """Warm-started noise touch-up from the cached statistics alone.

    The collapsed bound is an exact function of (stats, beta): the
    statistics depend on (theta, Z) but NOT on beta, so log_beta is the one
    hyperparameter that can be re-optimized without the datapoints. Runs
    `steps` Adam steps on the bound, warm-started at the served value, and
    refolds. Returns (new_state, loss_history)."""
    from repro.core import inference
    from repro.temporal.model import TemporalState

    if isinstance(state, TemporalState):
        raise TypeError(
            "refit re-optimizes log_beta against cached SuffStats; a "
            "TemporalState caches no statistics (its likelihood needs the "
            "whole timeline) — re-fit TemporalGPRegression instead")

    Kuu = kernel.K(state.kern, state.Z)
    D = state.D
    stats = state.stats

    def loss(params: dict) -> jax.Array:
        terms = svgp.collapsed_bound(Kuu, stats, jnp.exp(params["log_beta"]), D,
                                     jitter=jitter)
        return -terms.bound / stats.n

    start = float(loss({"log_beta": state.log_beta}))
    params, history = inference.fit_adam(loss, {"log_beta": state.log_beta},
                                         (), steps=steps, lr=lr)
    new = {"kern": state.kern, "Z": state.Z, "log_beta": params["log_beta"]}
    # history leads with the served value's loss so callers can see the gain
    return build_state(kernel, new, stats, jitter=jitter), [start, *history]
