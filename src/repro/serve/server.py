"""`GPServer`: named posterior states behind a low-latency predict front.

Two serving problems the raw jitted predict does not solve:

* **Variable batch sizes recompile.** jax caches executables per shape, so
  traffic with B in {1..256} would compile hundreds of variants. The server
  pads every request up to a small set of bucket shapes (powers of two by
  default) and slices the answer back — the compile cache is keyed on
  (model, bucket, diag) and tops out at len(buckets) entries per model.
  Oversized requests are served in largest-bucket slices, so no request
  size ever misses the cache.

* **Concurrent callers serialize badly.** One device call per caller pays
  dispatch overhead per request. `submit()` enqueues the request and
  returns a `Future`; a single worker thread drains the queue, coalesces
  every compatible pending request (same model, same diag, same feature
  dim) into ONE padded device call, and distributes the row slices back to
  the futures. Under concurrent load the device sees large batches; under
  light load the added latency is one queue hop.

State is swapped atomically under a per-model lock by `update()` /
`downdate()`, so readers never see a half-written posterior — a predict
either uses the old state or the new one, both self-consistent.
"""
from __future__ import annotations

import threading
from collections import deque
from concurrent.futures import Future
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.gp.kernels import Kernel
from repro.serve import online
from repro.serve.state import PosteriorState, _predict_closure

DEFAULT_BUCKETS = (1, 2, 4, 8, 16, 32, 64, 128, 256)


class _Entry:
    """A registered model: kernel (static), state (swapped atomically), and
    a per-entry dict of jitted predict closures keyed (diag,) — a plain
    attribute lookup on the request hot path instead of hashing the kernel
    through a global cache on every call. The jits are OWNED by the entry
    (not the module-level lru cache), so re-registering a name drops the
    old kernel's executables with the old entry instead of pinning them
    for the life of the process."""

    __slots__ = ("kernel", "state", "lock", "fns")

    def __init__(self, kernel: Kernel, state: PosteriorState):
        self.kernel = kernel
        self.state = state
        self.lock = threading.Lock()
        self.fns = {True: jax.jit(_predict_closure(kernel, True)),
                    False: jax.jit(_predict_closure(kernel, False))}


class _Request:
    __slots__ = ("name", "X", "diag", "future")

    def __init__(self, name: str, X: jax.Array, diag: bool, future: Future):
        self.name = name
        self.X = X
        self.diag = diag
        self.future = future


class GPServer:
    """Register `PosteriorState`s by name; serve batched low-latency
    predictions; fold new data in online.

    Args:
      buckets: allowed padded batch sizes, ascending. Each (model, bucket,
        diag) combination compiles exactly once.
      use_buckets: `False` disables padding (every distinct request shape
        compiles its own executable) — exists for the latency benchmark's
        buckets-on/off comparison, not for production use.
    """

    def __init__(self, *, buckets: Sequence[int] = DEFAULT_BUCKETS,
                 use_buckets: bool = True):
        if not buckets or list(buckets) != sorted(set(int(b) for b in buckets)):
            raise ValueError(f"buckets must be ascending and unique, got {buckets!r}")
        self.buckets = tuple(int(b) for b in buckets)
        self.use_buckets = bool(use_buckets)
        self._models: Dict[str, _Entry] = {}
        self._registry_lock = threading.Lock()
        # micro-batching queue (worker started lazily on first submit)
        self._queue: deque = deque()
        self._cv = threading.Condition()
        self._worker: Optional[threading.Thread] = None
        self._closed = False

    # ------------------------------------------------------------------ #
    # registry
    # ------------------------------------------------------------------ #

    def register(self, name: str, model=None, *, kernel: Kernel | None = None,
                 state: PosteriorState | None = None) -> None:
        """Register a fitted model under `name`: either a facade exposing
        `export_state()` (SparseGPRegression / BayesianGPLVM) or an explicit
        (kernel, state) pair."""
        if model is not None:
            if kernel is not None or state is not None:
                raise ValueError("pass either a fitted model or kernel=+state=, not both")
            kernel, state = model.kernel, model.export_state()
        if kernel is None or state is None:
            raise ValueError("register needs a fitted model or both kernel= and state=")
        with self._registry_lock:
            self._models[name] = _Entry(kernel, state)

    def state(self, name: str) -> PosteriorState:
        return self._entry(name).state

    def models(self) -> Tuple[str, ...]:
        # iterating the registry unlocked races a concurrent register():
        # CPython raises "dictionary changed size during iteration" (or
        # hands back a torn view), so snapshot under the lock
        with self._registry_lock:
            return tuple(sorted(self._models))

    def _entry(self, name: str) -> _Entry:
        with self._registry_lock:
            entry = self._models.get(name)
        if entry is None:
            # the error message enumerates the registry via models(), which
            # re-takes the (non-reentrant) lock — raise outside it
            raise KeyError(
                f"no model {name!r} registered; have {self.models()}")
        return entry

    # ------------------------------------------------------------------ #
    # bucketed predict
    # ------------------------------------------------------------------ #

    def _bucket(self, B: int) -> int:
        for b in self.buckets:
            if B <= b:
                return b
        return self.buckets[-1]

    @staticmethod
    def _check_batch(X) -> jax.Array:
        if not isinstance(X, jax.Array):
            X = jnp.asarray(X)
        if X.ndim != 2 or X.shape[0] == 0:
            raise ValueError(
                f"requests must be non-empty (B, Q) batches, got shape {X.shape}")
        return X

    def _predict_padded(self, entry: _Entry, X: jax.Array, diag: bool):
        """One device call at a bucket shape; returns unpadded (mean, var).
        Padding repeats the last row — benign values (no 0/0 in the kernel
        math), and the padded rows are sliced away. Row results are
        independent, so padding cannot perturb the real rows. The state is
        read ONCE here: oversized requests served in slices all use the
        same posterior even if a concurrent update() swaps it mid-request."""
        state = entry.state  # one atomic read per request
        fn = entry.fns[diag]
        if not self.use_buckets:
            return fn(state, X)
        return self._call_bucketed(fn, state, X, diag)

    def _call_bucketed(self, fn, state: PosteriorState, X: jax.Array, diag: bool):
        B = X.shape[0]
        bucket = self._bucket(B)
        if B == bucket:  # the hot path: exact bucket shape, no padding
            return fn(state, X)
        if B > bucket:  # oversized: serve in largest-bucket slices
            if not diag:
                raise ValueError(
                    f"diag=False requests must fit one bucket (B={B} > "
                    f"max bucket {bucket}): a full covariance does not "
                    f"concatenate across slices")
            parts = [self._call_bucketed(fn, state, X[i:i + bucket], diag)
                     for i in range(0, B, bucket)]
            return (jnp.concatenate([p[0] for p in parts]),
                    jnp.concatenate([p[1] for p in parts]))
        X = jnp.concatenate([X, jnp.repeat(X[-1:], bucket - B, axis=0)])
        mean, second = fn(state, X)
        if diag:
            return mean[:B], second[:B]
        return mean[:B], second[:B, :B]

    def predict(self, name: str, X, *, diag: bool = True):
        """Synchronous predict through the bucket cache: mean (B, D) and
        marginal variance (B,) (or (B, B) covariance with diag=False)."""
        return self._predict_padded(self._entry(name), self._check_batch(X), diag)

    # ------------------------------------------------------------------ #
    # micro-batching submit
    # ------------------------------------------------------------------ #

    def submit(self, name: str, X, *, diag: bool = True) -> Future:
        """Enqueue a predict; returns a Future of (mean, var). Concurrent
        submissions against the same model coalesce into one device call."""
        self._entry(name)  # fail fast on unknown names, in the caller
        fut: Future = Future()
        req = _Request(name, self._check_batch(X), bool(diag), fut)
        with self._cv:
            if self._closed:
                raise RuntimeError("GPServer is closed")
            self._queue.append(req)
            if self._worker is None:
                self._worker = threading.Thread(
                    target=self._serve_loop, name="gpserver-worker", daemon=True)
                self._worker.start()
            self._cv.notify()
        return fut

    def _serve_loop(self) -> None:
        while True:
            with self._cv:
                while not self._queue and not self._closed:
                    self._cv.wait()
                if self._closed and not self._queue:
                    return
                pending = list(self._queue)
                self._queue.clear()
            # claim each dequeued future: a caller may have cancel()ed while
            # the request sat in the queue, and set_result on a cancelled
            # Future raises InvalidStateError — which would abort delivery
            # for every later request in the same coalesced group. Marking
            # the survivors RUNNING here also makes them uncancellable, so
            # delivery below cannot race another cancel().
            pending = [r for r in pending
                       if r.future.set_running_or_notify_cancel()]
            # coalesce by (model, diag, feature-dim, dtype) — mixing dtypes
            # would silently promote the concatenated batch and hand some
            # callers a different dtype than predict() returns; diag=False
            # answers are per-request covariance blocks, so those run one
            # by one.
            # Defensive: nothing in this loop may escape and kill the worker
            # — a dead worker would strand every pending and future Future
            # (submit() only spawns it once). _check_batch makes a bad key
            # unreachable, but a request must never take the server down.
            groups: Dict[tuple, list] = {}
            for r in pending:
                try:
                    key = (r.name, r.diag, r.X.shape[1], r.X.dtype)
                except Exception as e:  # noqa: BLE001 — delivered to caller
                    r.future.set_exception(e)
                    continue
                groups.setdefault(key, []).append(r)
            for (name, diag, *_), reqs in groups.items():
                try:
                    entry = self._entry(name)
                    if not diag or len(reqs) == 1:
                        for r in reqs:
                            r.future.set_result(
                                self._predict_padded(entry, r.X, diag))
                        continue
                    X = jnp.concatenate([r.X for r in reqs])
                    mean, var = self._predict_padded(entry, X, True)
                    off = 0
                    for r in reqs:
                        b = r.X.shape[0]
                        r.future.set_result((mean[off:off + b], var[off:off + b]))
                        off += b
                except Exception as e:  # noqa: BLE001 — delivered to callers
                    for r in reqs:
                        if not r.future.done():
                            r.future.set_exception(e)

    def close(self) -> None:
        """Drain the queue and stop the worker thread."""
        with self._cv:
            self._closed = True
            self._cv.notify_all()
        if self._worker is not None:
            self._worker.join(timeout=5.0)
            self._worker = None

    def __enter__(self) -> "GPServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------------------ #
    # online learning
    # ------------------------------------------------------------------ #

    def update(self, name: str, X_new, Y_new, *, backend: str = "jnp",
               chunk: Optional[int] = None, bwd_backend: str = "auto") -> None:
        """Fold new observations into the named state (monoid combine +
        O(M^3) refold) and swap it in atomically."""
        entry = self._entry(name)
        with entry.lock:
            entry.state = online.update(
                entry.kernel, entry.state, jnp.asarray(X_new),
                jnp.asarray(Y_new), backend=backend, chunk=chunk,
                bwd_backend=bwd_backend)

    def downdate(self, name: str, X_old, Y_old, *, backend: str = "jnp",
                 chunk: Optional[int] = None) -> None:
        """Subtract previously-absorbed observations (guarded refold)."""
        entry = self._entry(name)
        with entry.lock:
            entry.state = online.downdate(
                entry.kernel, entry.state, jnp.asarray(X_old),
                jnp.asarray(Y_old), backend=backend, chunk=chunk)

    def refit(self, name: str, *, steps: int = 50, lr: float = 5e-2) -> list:
        """Noise-precision touch-up from the cached statistics (see
        repro.serve.online.refit); returns the loss history."""
        entry = self._entry(name)
        with entry.lock:
            entry.state, history = online.refit(entry.kernel, entry.state,
                                                steps=steps, lr=lr)
        return history
