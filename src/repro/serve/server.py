"""`GPServer`: named posterior states behind a low-latency predict front.

Two serving problems the raw jitted predict does not solve:

* **Variable batch sizes recompile.** jax caches executables per shape, so
  traffic with B in {1..256} would compile hundreds of variants. The server
  pads every request up to a small set of bucket shapes (powers of two by
  default) and slices the answer back — the compile cache is keyed on
  (model, bucket, diag) and tops out at len(buckets) entries per model.
  Oversized requests are served in largest-bucket slices, so no request
  size ever misses the cache.

* **Concurrent callers serialize badly.** One device call per caller pays
  dispatch overhead per request. `submit()` enqueues the request and
  returns a `Future`; a single worker thread drains the queue, coalesces
  every compatible pending request (same model, same diag, same feature
  dim) into ONE padded device call, and distributes the row slices back to
  the futures. Under concurrent load the device sees large batches; under
  light load the added latency is one queue hop.

State is swapped atomically under a per-model lock by `update()` /
`downdate()`, so readers never see a half-written posterior — a predict
either uses the old state or the new one, both self-consistent.

Three production concerns layered on top (docs/serving.md):

* **Durability** — `store=` names a `repro.serve.persist.StateStore`;
  `save_all()` persists every dirty state and `GPServer.load(store)`
  rebuilds a server after a restart that serves bit-identical predictions.
* **Memory budgeting** — `budget_bytes=` (or `REPRO_SERVE_BUDGET_BYTES`)
  bounds the bytes of resident `PosteriorState`s with a byte-accounted LRU:
  cold states are evicted to the store (persisted first if dirty) and
  lazily reloaded on their next predict/update. The model being touched is
  never its own victim, so a single state larger than the budget still
  serves (documented overshoot); everything else stays under budget.
* **Admission + deadlines** — `max_pending=` bounds queue depth
  (`QueueFullError` on overflow, in the caller), `timeout=` per submit (or
  `default_timeout=`) expires requests still queued past their deadline
  with `TimeoutError` on just their own future — claimed via
  `set_running_or_notify_cancel` first, so an expiry can never race a
  caller's cancel or poison the rest of a coalesced group. `close()` is
  idempotent, drains every accepted request before returning, and
  register/submit afterwards raise `ServerClosedError`.
"""
from __future__ import annotations

import os
import threading
import time
from collections import OrderedDict, deque
from concurrent.futures import Future
from pathlib import Path
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.gp.kernels import Kernel
from repro.serve import online, persist
from repro.serve.persist import StateStore
from repro.serve.state import PosteriorState, _predict_closure

DEFAULT_BUCKETS = (1, 2, 4, 8, 16, 32, 64, 128, 256)
BUDGET_ENV = "REPRO_SERVE_BUDGET_BYTES"


class ServerClosedError(RuntimeError):
    """register()/submit() after close(): the worker is gone; nothing may
    be enqueued. The message contains "closed" for RuntimeError matchers."""


class QueueFullError(RuntimeError):
    """Admission control: the submit queue is at max_pending. Rejected in
    the calling thread — the request never entered the queue."""


class _Entry:
    """A registered model: kernel (static), state (swapped atomically, or
    None while evicted to the store), and a per-entry dict of jitted predict
    closures keyed (diag,) — a plain attribute lookup on the request hot
    path instead of hashing the kernel through a global cache on every
    call. The jits are OWNED by the entry (not the module-level lru cache),
    so re-registering a name drops the old kernel's executables with the
    old entry instead of pinning them for the life of the process.

    `kind` selects the state schema and its predict closure: "posterior"
    (collapsed bound, diag or full covariance) or "temporal" (state-space
    forecaster — marginal forecasts only, diag=False raises per request).
    Inferred from the state object, or passed explicitly for cold
    registrations (state still on disk) from the manifest's `state_kind`.

    `nbytes` is the resident cost of the state pytree — constant per
    registration, because every field's shape is fixed by (M, Q, D) (or
    (d, D) for temporal) and online mutation only swaps same-shaped
    arrays. `dirty` marks state the store has not seen yet (fresh
    registration, or mutated since the last save); eviction persists dirty
    state before dropping it."""

    __slots__ = ("kernel", "state", "lock", "fns", "nbytes", "dirty", "kind")

    def __init__(self, kernel: Kernel, state=None, *,
                 nbytes: Optional[int] = None, dirty: bool = True,
                 kind: Optional[str] = None):
        self.kernel = kernel
        self.state = state
        self.nbytes = int(state.nbytes if nbytes is None else nbytes)
        self.dirty = dirty
        self.lock = threading.Lock()
        if kind is None:
            kind = persist.state_kind(state)
        self.kind = kind
        if kind == "temporal":
            from repro.temporal.model import forecast_closure

            def _no_full(state, Xt):
                raise ValueError(
                    "diag=False (full predictive covariance) is not "
                    "available for a temporal model: the served forecast "
                    "state carries per-timestamp marginals only; use "
                    "TemporalGPRegression.predict on the fitted model")

            self.fns = {True: jax.jit(forecast_closure(kernel)),
                        False: _no_full}
        else:
            self.fns = {True: jax.jit(_predict_closure(kernel, True)),
                        False: jax.jit(_predict_closure(kernel, False))}


class _Request:
    __slots__ = ("name", "X", "diag", "future", "deadline")

    def __init__(self, name: str, X: jax.Array, diag: bool, future: Future,
                 deadline: Optional[float] = None):
        self.name = name
        self.X = X
        self.diag = diag
        self.future = future
        self.deadline = deadline  # time.monotonic() timestamp, or None


class GPServer:
    """Register `PosteriorState`s by name; serve batched low-latency
    predictions; fold new data in online; optionally persist and budget.

    Args:
      buckets: allowed padded batch sizes, ascending. Each (model, bucket,
        diag) combination compiles exactly once.
      use_buckets: `False` disables padding (every distinct request shape
        compiles its own executable) — exists for the latency benchmark's
        buckets-on/off comparison, not for production use.
      store: a `StateStore` (or directory path) for persistence: the
        `save_all()` target, the eviction spill space, and the lazy-reload
        source. Required when `budget_bytes` is set.
      budget_bytes: byte cap on resident states (LRU eviction past it).
        `None` reads the REPRO_SERVE_BUDGET_BYTES env var; unset means
        unbounded.
      max_pending: admission bound on submit-queue depth; a submit that
        would exceed it raises `QueueFullError` in the caller.
      default_timeout: seconds a submitted request may wait in the queue
        before expiring with `TimeoutError` (per-call `timeout=` overrides).
    """

    def __init__(self, *, buckets: Sequence[int] = DEFAULT_BUCKETS,
                 use_buckets: bool = True,
                 store: StateStore | str | Path | None = None,
                 budget_bytes: Optional[int] = None,
                 max_pending: Optional[int] = None,
                 default_timeout: Optional[float] = None):
        if not buckets or list(buckets) != sorted(set(int(b) for b in buckets)):
            raise ValueError(f"buckets must be ascending and unique, got {buckets!r}")
        self.buckets = tuple(int(b) for b in buckets)
        self.use_buckets = bool(use_buckets)
        if isinstance(store, (str, Path)):
            store = StateStore(store)
        self.store = store
        if budget_bytes is None and os.environ.get(BUDGET_ENV):
            budget_bytes = int(os.environ[BUDGET_ENV])
        if budget_bytes is not None:
            if budget_bytes <= 0:
                raise ValueError(f"budget_bytes must be positive, got {budget_bytes}")
            if store is None:
                raise ValueError(
                    "budget_bytes needs a store= to evict cold states into "
                    "(pass a repro.serve.StateStore or a directory path)")
        self.budget_bytes = budget_bytes
        if max_pending is not None and max_pending < 1:
            raise ValueError(f"max_pending must be >= 1, got {max_pending}")
        self.max_pending = max_pending
        self.default_timeout = default_timeout
        self._models: Dict[str, _Entry] = {}
        self._registry_lock = threading.Lock()
        # residency: _lru maps name -> entry for RESIDENT states only, in
        # least-recently-used order; _resident_bytes is their byte sum.
        # Both are guarded by _registry_lock (a leaf lock: nothing else is
        # acquired while holding it). _budget_lock serializes residency
        # transitions (evict / lazy reload) and orders BEFORE entry locks.
        self._lru: "OrderedDict[str, _Entry]" = OrderedDict()
        self._resident_bytes = 0
        self._budget_lock = threading.Lock()
        self._evictions = 0
        self._lazy_loads = 0
        self._peak_resident = 0
        self._rejected = 0
        self._expired = 0
        # micro-batching queue (worker started lazily on first submit)
        self._queue: deque = deque()
        self._cv = threading.Condition()
        self._worker: Optional[threading.Thread] = None
        self._closed = False

    # ------------------------------------------------------------------ #
    # registry
    # ------------------------------------------------------------------ #

    def register(self, name: str, model=None, *, kernel: Kernel | None = None,
                 state=None) -> None:
        """Register a fitted model under `name`: either a facade exposing
        `export_state()` (SparseGPRegression / BayesianGPLVM /
        TemporalGPRegression) or an explicit (kernel, state) pair — the
        state a `PosteriorState` or a `repro.temporal.TemporalState`."""
        if model is not None:
            if kernel is not None or state is not None:
                raise ValueError("pass either a fitted model or kernel=+state=, not both")
            kernel, state = model.kernel, model.export_state()
        if kernel is None or state is None:
            raise ValueError("register needs a fitted model or both kernel= and state=")
        with self._cv:
            if self._closed:
                raise ServerClosedError(
                    "GPServer is closed: register() after close() would pair "
                    "a model with a dead worker")
        self._insert(name, _Entry(kernel, state))

    def _register_cold(self, name: str) -> None:
        """Register a persisted model WITHOUT loading its state: the kernel
        comes from the stored spec, the byte charge from the manifest, and
        the state stays on disk until the first predict/update touches it.
        This is how `load()` restarts within budget regardless of how many
        models the store holds."""
        kernel, manifest = self.store.load_meta(name)
        kind = (manifest.get("extra") or {}).get("state_kind", "posterior")
        entry = _Entry(kernel, None, nbytes=self.store.nbytes(name),
                       dirty=False, kind=kind)
        self._insert(name, entry)

    def _insert(self, name: str, entry: _Entry) -> None:
        with self._budget_lock:
            if entry.state is not None:
                # make room FIRST: resident bytes never overshoot the budget,
                # not even transiently (the load-benchmark asserts peak)
                self._make_room(entry.nbytes, exclude=name)
            with self._registry_lock:
                old = self._models.pop(name, None)
                if old is not None and self._lru.pop(name, None) is not None:
                    self._resident_bytes -= old.nbytes
                self._models[name] = entry
                if entry.state is not None:
                    self._lru[name] = entry
                    self._resident_bytes += entry.nbytes
                    self._peak_resident = max(self._peak_resident,
                                              self._resident_bytes)

    def state(self, name: str) -> PosteriorState:
        entry = self._entry(name)
        return self._resident_state(name, entry)

    def models(self) -> Tuple[str, ...]:
        # iterating the registry unlocked races a concurrent register():
        # CPython raises "dictionary changed size during iteration" (or
        # hands back a torn view), so snapshot under the lock
        with self._registry_lock:
            return tuple(sorted(self._models))

    def _entry(self, name: str) -> _Entry:
        with self._registry_lock:
            entry = self._models.get(name)
        if entry is None:
            # the error message enumerates the registry via models(), which
            # re-takes the (non-reentrant) lock — raise outside it
            raise KeyError(
                f"no model {name!r} registered; have {self.models()}")
        return entry

    # ------------------------------------------------------------------ #
    # residency: byte-accounted LRU over the store
    # ------------------------------------------------------------------ #

    def _touch(self, name: str, entry: _Entry) -> None:
        """Refresh the LRU position of a resident entry."""
        with self._registry_lock:
            if entry.state is not None and self._models.get(name) is entry:
                self._lru[name] = entry
                self._lru.move_to_end(name)

    def _load_locked(self, name: str, entry: _Entry) -> PosteriorState:
        """Reload an evicted state from the store and account it resident.
        Caller holds _budget_lock AND entry.lock (every residency
        transition is serialized through _budget_lock, so accounting can
        never tear); takes only the leaf registry lock inside."""
        _, state = self.store.load(name)
        entry.state = state
        entry.dirty = False  # disk copy is exactly what we just loaded
        with self._registry_lock:
            self._lazy_loads += 1
            self._resident_bytes += entry.nbytes
            self._peak_resident = max(self._peak_resident, self._resident_bytes)
            self._lru[name] = entry
            self._lru.move_to_end(name)
        return state

    def _resident_state(self, name: str, entry: _Entry) -> PosteriorState:
        """The entry's state, lazily reloaded if evicted. Room is made
        BEFORE the reload, so resident bytes never overshoot the budget.
        Lock order on the slow path: _budget_lock -> entry.lock ->
        _registry_lock."""
        state = entry.state  # one atomic read: the hot path takes no lock
        if state is None:
            with self._budget_lock:
                if entry.state is None:
                    self._make_room(entry.nbytes, exclude=name)
                with entry.lock:
                    state = entry.state
                    if state is None:
                        state = self._load_locked(name, entry)
        self._touch(name, entry)
        return state

    def _make_room(self, incoming: int = 0, exclude: Optional[str] = None) -> None:
        """Evict least-recently-used states until `incoming` more resident
        bytes fit the budget. Caller holds _budget_lock. `exclude` protects
        the entry being served right now from becoming its own victim — if
        it alone exceeds the budget it still serves (the one documented
        overshoot, see docs/serving.md) rather than thrashing."""
        if self.budget_bytes is None:
            return
        while True:
            with self._registry_lock:
                if self._resident_bytes + incoming <= self.budget_bytes:
                    return
                victim = next((n for n in self._lru if n != exclude), None)
            if victim is None:
                return
            self._evict(victim)

    def _evict(self, name: str) -> None:
        """Persist (if dirty) and drop one resident state. Caller holds
        _budget_lock; the victim's entry lock excludes concurrent
        update()/reload, and the accounting happens inside it so a reload
        racing right behind the eviction can never double-count."""
        with self._registry_lock:
            entry = self._models.get(name)
        if entry is None:
            return
        with entry.lock:
            state = entry.state
            if state is None:
                return
            if entry.dirty:
                self.store.save(name, entry.kernel, state)
                entry.dirty = False
            entry.state = None
            with self._registry_lock:
                if self._lru.pop(name, None) is not None:
                    self._resident_bytes -= entry.nbytes
                self._evictions += 1

    def metrics(self) -> Dict[str, Optional[int]]:
        """Residency and admission counters, snapshotted: registered /
        resident model counts, resident / peak-resident / budget bytes,
        evictions, lazy reloads, admission rejections, queue expiries."""
        with self._registry_lock:
            return {
                "registered": len(self._models),
                "resident_models": len(self._lru),
                "resident_bytes": self._resident_bytes,
                "peak_resident_bytes": self._peak_resident,
                "budget_bytes": self.budget_bytes,
                "evictions": self._evictions,
                "lazy_loads": self._lazy_loads,
                "rejected": self._rejected,
                "expired": self._expired,
            }

    # ------------------------------------------------------------------ #
    # persistence: save_all / load
    # ------------------------------------------------------------------ #

    def _require_store(self) -> StateStore:
        if self.store is None:
            raise ValueError(
                "GPServer has no store= — construct it with a "
                "repro.serve.StateStore (or directory path) to persist")
        return self.store

    def save_all(self) -> Tuple[str, ...]:
        """Persist every registered model whose state the store has not
        seen; returns the names written. Evicted entries are clean by
        construction (eviction persists dirty state first), so a
        save_all() + process death loses nothing."""
        store = self._require_store()
        saved = []
        for name in self.models():
            with self._registry_lock:
                entry = self._models.get(name)
            if entry is None:
                continue
            with entry.lock:
                if entry.state is not None and entry.dirty:
                    store.save(name, entry.kernel, entry.state)
                    entry.dirty = False
                    saved.append(name)
        return tuple(saved)

    @classmethod
    def load(cls, store: StateStore | str | Path, **kwargs) -> "GPServer":
        """Rebuild a server from a checkpoint store after a restart.

        Every persisted model is registered COLD — kernel and jit closures
        live, state still on disk — so the restarted process starts within
        any budget no matter how many models the store holds, and pays one
        lazy reload per model on first use. Predictions after the reload
        are bit-identical to the pre-restart server's
        (tests/test_serve_persist.py)."""
        srv = cls(store=store, **kwargs)
        for name in srv.store.names():
            srv._register_cold(name)
        return srv

    # ------------------------------------------------------------------ #
    # bucketed predict
    # ------------------------------------------------------------------ #

    def _bucket(self, B: int) -> int:
        for b in self.buckets:
            if B <= b:
                return b
        return self.buckets[-1]

    @staticmethod
    def _check_batch(X) -> jax.Array:
        if not isinstance(X, jax.Array):
            X = jnp.asarray(X)
        if X.ndim != 2 or X.shape[0] == 0:
            raise ValueError(
                f"requests must be non-empty (B, Q) batches, got shape {X.shape}")
        return X

    def _predict_padded(self, name: str, entry: _Entry, X: jax.Array, diag: bool):
        """One device call at a bucket shape; returns unpadded (mean, var).
        Padding repeats the last row — benign values (no 0/0 in the kernel
        math), and the padded rows are sliced away. Row results are
        independent, so padding cannot perturb the real rows. The state is
        read ONCE here (lazily reloaded if evicted): oversized requests
        served in slices all use the same posterior even if a concurrent
        update() swaps it mid-request."""
        state = self._resident_state(name, entry)
        fn = entry.fns[diag]
        if not self.use_buckets:
            return fn(state, X)
        return self._call_bucketed(fn, state, X, diag)

    def _call_bucketed(self, fn, state: PosteriorState, X: jax.Array, diag: bool):
        B = X.shape[0]
        bucket = self._bucket(B)
        if B == bucket:  # the hot path: exact bucket shape, no padding
            return fn(state, X)
        if B > bucket:  # oversized: serve in largest-bucket slices
            if not diag:
                raise ValueError(
                    f"diag=False requests must fit one bucket (B={B} > "
                    f"max bucket {bucket}): a full covariance does not "
                    f"concatenate across slices")
            parts = [self._call_bucketed(fn, state, X[i:i + bucket], diag)
                     for i in range(0, B, bucket)]
            return (jnp.concatenate([p[0] for p in parts]),
                    jnp.concatenate([p[1] for p in parts]))
        X = jnp.concatenate([X, jnp.repeat(X[-1:], bucket - B, axis=0)])
        mean, second = fn(state, X)
        if diag:
            return mean[:B], second[:B]
        return mean[:B], second[:B, :B]

    def predict(self, name: str, X, *, diag: bool = True):
        """Synchronous predict through the bucket cache: mean (B, D) and
        marginal variance (B,) (or (B, B) covariance with diag=False)."""
        return self._predict_padded(name, self._entry(name),
                                    self._check_batch(X), diag)

    # ------------------------------------------------------------------ #
    # micro-batching submit
    # ------------------------------------------------------------------ #

    def submit(self, name: str, X, *, diag: bool = True,
               timeout: Optional[float] = None) -> Future:
        """Enqueue a predict; returns a Future of (mean, var). Concurrent
        submissions against the same model coalesce into one device call.

        `timeout` (seconds, default `default_timeout`) bounds how long the
        request may WAIT IN THE QUEUE: a request still queued past its
        deadline fails with TimeoutError on its own future only. Raises
        QueueFullError if the queue is at max_pending (admission control)
        and ServerClosedError after close()."""
        self._entry(name)  # fail fast on unknown names, in the caller
        if timeout is None:
            timeout = self.default_timeout
        deadline = None if timeout is None else time.monotonic() + float(timeout)
        fut: Future = Future()
        req = _Request(name, self._check_batch(X), bool(diag), fut, deadline)
        with self._cv:
            if self._closed:
                raise ServerClosedError("GPServer is closed")
            if self.max_pending is not None and len(self._queue) >= self.max_pending:
                self._rejected += 1
                raise QueueFullError(
                    f"GPServer queue is full ({len(self._queue)} pending >= "
                    f"max_pending={self.max_pending}); retry later or raise "
                    f"max_pending")
            self._queue.append(req)
            if self._worker is None:
                self._worker = threading.Thread(
                    target=self._serve_loop, name="gpserver-worker", daemon=True)
                self._worker.start()
            self._cv.notify()
        return fut

    def _claim(self, pending: list) -> list:
        """Claim each dequeued request and weed out the dead ones.

        A caller may have cancel()ed while the request sat in the queue, and
        set_result on a cancelled Future raises InvalidStateError — which
        would abort delivery for every later request in the same coalesced
        group. set_running_or_notify_cancel marks the survivors RUNNING,
        which also makes them uncancellable, so neither expiry here nor
        delivery below can race another cancel(). Requests whose deadline
        passed while queued expire with TimeoutError on their own future —
        the rest of the group is untouched."""
        claimed = []
        now = time.monotonic()
        for r in pending:
            if not r.future.set_running_or_notify_cancel():
                continue  # caller cancelled while queued
            if r.deadline is not None and now > r.deadline:
                r.future.set_exception(TimeoutError(
                    f"request for {r.name!r} expired after waiting past its "
                    f"deadline in the GPServer queue"))
                with self._registry_lock:
                    self._expired += 1
                continue
            claimed.append(r)
        return claimed

    def _serve_loop(self) -> None:
        while True:
            with self._cv:
                while not self._queue and not self._closed:
                    self._cv.wait()
                if self._closed and not self._queue:
                    return
                pending = list(self._queue)
                self._queue.clear()
            pending = self._claim(pending)
            # coalesce by (model, diag, feature-dim, dtype) — mixing dtypes
            # would silently promote the concatenated batch and hand some
            # callers a different dtype than predict() returns; diag=False
            # answers are per-request covariance blocks, so those run one
            # by one.
            # Defensive: nothing in this loop may escape and kill the worker
            # — a dead worker would strand every pending and future Future
            # (submit() only spawns it once). _check_batch makes a bad key
            # unreachable, but a request must never take the server down.
            groups: Dict[tuple, list] = {}
            for r in pending:
                try:
                    key = (r.name, r.diag, r.X.shape[1], r.X.dtype)
                except Exception as e:  # noqa: BLE001 — delivered to caller
                    r.future.set_exception(e)
                    continue
                groups.setdefault(key, []).append(r)
            for (name, diag, *_), reqs in groups.items():
                try:
                    entry = self._entry(name)
                    if not diag or len(reqs) == 1:
                        for r in reqs:
                            r.future.set_result(
                                self._predict_padded(name, entry, r.X, diag))
                        continue
                    X = jnp.concatenate([r.X for r in reqs])
                    mean, var = self._predict_padded(name, entry, X, True)
                    off = 0
                    for r in reqs:
                        b = r.X.shape[0]
                        r.future.set_result((mean[off:off + b], var[off:off + b]))
                        off += b
                except Exception as e:  # noqa: BLE001 — delivered to callers
                    # a device failure mid-batch fails ITS OWN group only:
                    # other groups in this drain keep going, and the worker
                    # survives to serve the next drain
                    for r in reqs:
                        if not r.future.done():
                            r.future.set_exception(e)

    def close(self, timeout: Optional[float] = None) -> None:
        """Drain the queue and stop the worker thread. Idempotent. Every
        request accepted before close() completes (the worker processes the
        remaining queue before exiting — graceful drain); register() and
        submit() afterwards raise ServerClosedError. `timeout` bounds the
        drain wait (None = wait for full drain)."""
        with self._cv:
            self._closed = True
            self._cv.notify_all()
            worker = self._worker
        if worker is not None:
            worker.join(timeout)
            if not worker.is_alive():
                # _worker is _cv-guarded state: a concurrent close() must
                # not see a half-cleared slot, and submit() restarts the
                # worker it reads under the same lock
                with self._cv:
                    if self._worker is worker:
                        self._worker = None

    def __enter__(self) -> "GPServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------------------ #
    # online learning
    # ------------------------------------------------------------------ #

    def _mutate(self, name: str, fn):
        """Shared update/downdate/refit skeleton: swap the state atomically
        under the entry lock, reloading an evicted state first through the
        budgeted `_resident_state` path (never while holding the entry
        lock, which would invert the _budget_lock -> entry.lock order). The
        retry handles the rare eviction that lands between the reload and
        the lock; the swap keeps nbytes constant, so no room-making is
        needed afterwards."""
        entry = self._entry(name)
        while True:
            if entry.state is None:
                self._resident_state(name, entry)
            with entry.lock:
                state = entry.state
                if state is None:
                    continue  # evicted under our feet — reload and retry
                result = fn(entry, state)
                entry.dirty = True
                self._touch(name, entry)
                return result

    def update(self, name: str, X_new, Y_new, *, backend: str = "jnp",
               chunk: Optional[int] = None, bwd_backend: str = "auto") -> None:
        """Fold new observations into the named state (monoid combine +
        O(M^3) refold) and swap it in atomically. Reloads an evicted state
        first; the result is dirty until the next save/eviction persists it."""
        def fold(entry, state):
            entry.state = online.update(
                entry.kernel, state, jnp.asarray(X_new), jnp.asarray(Y_new),
                backend=backend, chunk=chunk, bwd_backend=bwd_backend)

        self._mutate(name, fold)

    def downdate(self, name: str, X_old, Y_old, *, backend: str = "jnp",
                 chunk: Optional[int] = None) -> None:
        """Subtract previously-absorbed observations (guarded refold)."""
        def fold(entry, state):
            entry.state = online.downdate(
                entry.kernel, state, jnp.asarray(X_old), jnp.asarray(Y_old),
                backend=backend, chunk=chunk)

        self._mutate(name, fold)

    def refit(self, name: str, *, steps: int = 50, lr: float = 5e-2) -> list:
        """Noise-precision touch-up from the cached statistics (see
        repro.serve.online.refit); returns the loss history."""
        def fold(entry, state):
            entry.state, history = online.refit(entry.kernel, state,
                                                steps=steps, lr=lr)
            return history

        return self._mutate(name, fold)
