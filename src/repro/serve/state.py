"""Cached posterior state for online serving (paper §2, pushed to its
logical conclusion).

The collapsed bound consumes only the O(M^2) `SuffStats` summary, and the
posterior epilogue (`svgp.posterior_factors`) is a pure function of that
summary — so a *fitted* model is fully described by

    PosteriorState = (kernel hyperparams, Z, log_beta,
                      SuffStats,                      # the raw monoid
                      L, LA, Kuu_inv_mean)            # factorized epilogue

Everything per-request is then O(M B + M^2 B): one cross-covariance block,
two triangular solves, no Cholesky. The raw `SuffStats` rides along so the
state can absorb new data (`repro.serve.online.update`) or shed old data
(`downdate`) and refactorize in O(M^3) without ever revisiting the training
set — the monoid structure that makes the paper's MPI decomposition work is
exactly what makes online serving work.

The kernel OBJECT is deliberately not a field: `PosteriorState` is a plain
pytree (jit-traceable, checkpointable, psum-able), and kernels are static
code, not data. Every function here takes the kernel alongside the state;
`GPServer` (repro.serve.server) pairs them up under a registered name.
"""
from __future__ import annotations

import functools
from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core import svgp
from repro.core.psi_stats import SuffStats
from repro.gp.kernels import Kernel

Params = Dict[str, jax.Array]


class PosteriorState(NamedTuple):
    """Everything a fitted collapsed-bound GP needs to serve and to learn
    online. A pure pytree of arrays (see module docstring)."""

    kern: Params  # kernel hyperparameters (log-transformed)
    Z: jax.Array  # (M, Q) inducing inputs
    log_beta: jax.Array  # scalar log noise precision
    stats: SuffStats  # the raw sufficient-statistics monoid
    L: jax.Array  # (M, M) chol(Kuu + jitter)
    LA: jax.Array  # (M, M) chol(Kuu + beta Psi2 + jitter)
    Kuu_inv_mean: jax.Array  # (M, D) woodbury vector Kuu^-1 mean_u

    @property
    def M(self) -> int:
        return self.Z.shape[0]

    @property
    def D(self) -> int:
        return self.Kuu_inv_mean.shape[1]

    @property
    def nbytes(self) -> int:
        """Resident bytes of the whole state pytree — what the server's
        byte-budgeted LRU charges per model. Constant for a registration:
        every field's shape is fixed by (M, Q, D), and online
        update/downdate swap same-shaped arrays."""
        return int(sum(x.nbytes for x in jax.tree_util.tree_leaves(self)))


def build_state(kernel: Kernel, params: Params, stats: SuffStats, *,
                jitter: float = svgp.DEFAULT_JITTER) -> PosteriorState:
    """The O(M^3) refold: statistics -> factorized posterior state.

    `params` needs the model keys ("kern", "Z", "log_beta"); extra keys
    (e.g. the GP-LVM's q(X)) are ignored — the state never holds per-
    datapoint parameters. Used both at export time (facade
    `export_state()`) and after every online update/downdate.
    """
    kern_p, Z, log_beta = params["kern"], params["Z"], params["log_beta"]
    beta = jnp.exp(log_beta)
    Kuu = kernel.K(kern_p, Z)
    factors = svgp.posterior_factors(Kuu, stats, beta, jitter=jitter)
    post = svgp.optimal_qu(factors, beta)
    return PosteriorState(kern=kern_p, Z=Z, log_beta=log_beta, stats=stats,
                          L=post.L, LA=post.LA, Kuu_inv_mean=post.Kuu_inv_mean)


def _as_posterior(state: PosteriorState) -> svgp.Posterior:
    """View the state through the svgp.Posterior lens prediction expects.
    mean_u / cov_u are not needed by predict_f — fill with the woodbury
    vector's shape-compatible factors to keep the NamedTuple total."""
    return svgp.Posterior(mean_u=state.Kuu_inv_mean, cov_u=state.LA,
                          Kuu_inv_mean=state.Kuu_inv_mean,
                          L=state.L, LA=state.LA)


def _predict_closure(kernel: Kernel, diag: bool):
    """The (unjitted) predict epilogue closed over a kernel. `GPServer`
    entries jit their own copy so dropping a registration frees its XLA
    executables; the module-level `predict` shares one via the lru cache
    below (jit adds the per-shape level in both cases)."""

    def fn(state: PosteriorState, Xt: jax.Array):
        Ksu = kernel.K(state.kern, Xt, state.Z)
        post = _as_posterior(state)
        if diag:
            return svgp.predict_f(post, Ksu, kernel.Kdiag(state.kern, Xt))
        return svgp.predict_f_full(post, Ksu, kernel.K(state.kern, Xt))

    return fn


@functools.lru_cache(maxsize=None)
def _predict_fn(kernel: Kernel, diag: bool):
    """One jitted predict closure per (kernel, diag), for the functional
    `predict` API. Process-lifetime cache — value-hashable kernels (the
    frozen dataclasses) share entries, so repeated `get("rbf")(Q)` lookups
    cost one compile."""
    return jax.jit(_predict_closure(kernel, bool(diag)))


def predict(kernel: Kernel, state, Xt: jax.Array, *,
            diag: bool = True) -> Tuple[jax.Array, jax.Array]:
    """Posterior p(f*) at Xt from the cached state: mean (B, D) plus either
    the marginal variance (B,) (`diag=True`) or the full (B, B) covariance.

    For a `PosteriorState`: O(M B + M^2 B) per call — cross-covariances and
    triangular solves against the cached Cholesky factors; no per-request
    factorization. For a `repro.temporal.TemporalState`: O(B d^3) marginal
    forecasts from the terminal filtered state (diag only — per-row
    forecasts are independent, so there is no full joint to return without
    the training timeline; use `TemporalGPRegression.predict`). The jitted
    closure is cached per (kernel, diag) either way, so repeated calls at
    the same batch shape reuse one XLA executable.
    """
    from repro.temporal.model import TemporalState, forecast

    if isinstance(state, TemporalState):
        if not diag:
            raise ValueError(
                "diag=False (full predictive covariance) is not available "
                "for a TemporalState: the served forecast state carries "
                "per-timestamp marginals only; use "
                "TemporalGPRegression.predict on the fitted model for "
                "smoothed joint structure")
        return forecast(kernel, state, Xt)
    return _predict_fn(kernel, bool(diag))(state, Xt)
