"""Online prediction subsystem: cached posterior state, incremental
sufficient-statistics updates, batched low-latency serving.

    gp = SparseGPRegression(...).fit(X, Y)
    server = GPServer()
    server.register("demand", gp)            # exports + caches the state
    mean, var = server.predict("demand", Xt) # bucket-padded, jit-cached
    server.update("demand", X_new, Y_new)    # monoid fold + O(M^3) refold

Persistence and budgeting ride the same state pytree:

    store = StateStore("/srv/gp-states")
    server = GPServer(store=store, budget_bytes=64 << 20)
    server.register("demand", gp)            # resident, byte-accounted
    server.save_all()                        # durable: survives restarts
    server = GPServer.load(store)            # restart: bit-identical serving

Layering: `state` (the cached-posterior pytree + jitted predict epilogue),
`online` (update / downdate / refit on the SuffStats monoid), `persist`
(the durable named store over repro.checkpoint.manager + kernel specs),
`server` (the named-model registry, bucket compile cache, micro-batching
queue, byte-budgeted LRU residency, and admission control). See
docs/serving.md.

Temporal models serve through the same tier: register a fitted
`TemporalGPRegression` (its `TemporalState` is the O(d^2) analogue of
`PosteriorState`), `predict` forecasts marginals at new timestamps, and
`update` filters new observations forward — streaming forecasting, see
docs/temporal.md.
"""
from repro.serve.online import batch_stats, downdate, refit, refold, update
from repro.serve.persist import (PERSIST_SCHEMA, StateStore, kernel_from_spec,
                                 kernel_spec, state_kind)
from repro.serve.server import GPServer, QueueFullError, ServerClosedError
from repro.serve.state import PosteriorState, build_state, predict
from repro.temporal.model import TemporalState

__all__ = [
    "PosteriorState", "TemporalState", "build_state", "predict",
    "update", "downdate", "refit", "refold", "batch_stats",
    "GPServer", "QueueFullError", "ServerClosedError",
    "StateStore", "PERSIST_SCHEMA", "kernel_spec", "kernel_from_spec",
    "state_kind",
]
