"""Online prediction subsystem: cached posterior state, incremental
sufficient-statistics updates, batched low-latency serving.

    gp = SparseGPRegression(...).fit(X, Y)
    server = GPServer()
    server.register("demand", gp)            # exports + caches the state
    mean, var = server.predict("demand", Xt) # bucket-padded, jit-cached
    server.update("demand", X_new, Y_new)    # monoid fold + O(M^3) refold

Layering: `state` (the cached-posterior pytree + jitted predict epilogue),
`online` (update / downdate / refit on the SuffStats monoid), `server` (the
named-model registry, bucket compile cache, and micro-batching queue). See
docs/serving.md.
"""
from repro.serve.online import batch_stats, downdate, refit, refold, update
from repro.serve.server import GPServer
from repro.serve.state import PosteriorState, build_state, predict

__all__ = [
    "PosteriorState", "build_state", "predict",
    "update", "downdate", "refit", "refold", "batch_stats",
    "GPServer",
]
