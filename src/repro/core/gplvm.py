"""Bayesian GP-LVM (paper eq. (4)) — the unsupervised model the paper's
experiments use.

X is latent with prior p(x_n) = N(0, I_Q) and factorized Gaussian variational
posterior q(x_n) = N(mu_n, diag(S_n)). The collapsed bound of svgp.py is
reused verbatim; the only changes are (a) the sufficient statistics become
expectations under q(X) (kernel.expected_suff_stats), and (b) the KL term:

    log p(Y) >= <F>_q(X) - sum_n KL(q(x_n) || p(x_n))

Both changes preserve the sum-over-n structure, so the same distributed
accumulation applies (mu, S are *local* parameters living on the shard that
owns datapoint n — exactly the paper's local/global parameter split).

Every entry point takes an optional `kernel` (any `repro.gp.kernels.Kernel`
with closed-form psi statistics); the default is the paper's RBF.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import psi_stats, svgp
from repro.gp.kernels import Kernel, default_rbf
from repro.gp.stats import ExpectedBatch, suff_stats

Params = Dict[str, jax.Array]


def init_params(
    key: jax.Array,
    Y: jax.Array,
    Q: int,
    M: int,
    *,
    init_X: jax.Array | None = None,
    kernel: Optional[Kernel] = None,
) -> Params:
    """PCA-style init of q(X) means (or user-provided), Z from q(X) samples."""
    N, D = Y.shape
    if init_X is None:
        # PCA init: project Y onto its top-Q principal directions
        Yc = Y - jnp.mean(Y, 0)
        _, _, Vt = jnp.linalg.svd(Yc, full_matrices=False)
        init_X = Yc @ Vt[:Q].T
        init_X = init_X / (jnp.std(init_X, 0) + 1e-6)
    kern = default_rbf(kernel, Q).init()
    idx = jax.random.choice(key, N, (M,), replace=N < M)
    return {
        "kern": kern,
        "Z": init_X[idx],
        "log_beta": jnp.asarray(jnp.log(100.0), jnp.float32),
        "q_mu": init_X,
        "q_logS": jnp.full((N, Q), jnp.log(0.1), jnp.float32),
    }


def kl_qp(q_mu: jax.Array, q_logS: jax.Array) -> jax.Array:
    """sum_n KL(N(mu_n, diag(S_n)) || N(0, I)) — also a plain sum over n."""
    S = jnp.exp(q_logS)
    return 0.5 * jnp.sum(S + q_mu**2 - q_logS - 1.0)


def local_stats(params: Params, Y_local: jax.Array, *,
                kernel: Optional[Kernel] = None,
                backend: str = "jnp",
                chunk: Optional[int] = None,
                bwd_backend: str = "auto") -> psi_stats.SuffStats:
    """Sufficient statistics for the local data shard, kernel-dispatched.
    `chunk=` streams the shard's datapoints (O(chunk * M) live memory);
    `bwd_backend` picks the reverse-pass implementation of the kernelized
    backends ("pallas" single-statistic ops and the "fused" op alike)."""
    kern = default_rbf(kernel, params["q_mu"].shape[1])
    S = jnp.exp(params["q_logS"])
    return suff_stats(kern, params["kern"],
                      ExpectedBatch(params["q_mu"], S, Y_local, params["Z"]),
                      backend=backend, chunk=chunk, bwd_backend=bwd_backend)


def bound(params: Params, Y: jax.Array, *, kernel: Optional[Kernel] = None,
          backend: str = "jnp", chunk: Optional[int] = None,
          bwd_backend: str = "auto") -> jax.Array:
    """Single-device (or per-shard-complete) GP-LVM evidence lower bound."""
    stats = local_stats(params, Y, kernel=kernel, backend=backend, chunk=chunk,
                        bwd_backend=bwd_backend)
    return bound_from_stats(params, stats, kl_qp(params["q_mu"], params["q_logS"]),
                            Y.shape[1], kernel=kernel)


def bound_from_stats(
    params: Params, stats: psi_stats.SuffStats, kl: jax.Array, D: int,
    *, kernel: Optional[Kernel] = None,
) -> jax.Array:
    """The indistributable epilogue: O(M^3), runs replicated after the psum."""
    kern = default_rbf(kernel, params["Z"].shape[1])
    Kuu = kern.K(params["kern"], params["Z"])
    beta = jnp.exp(params["log_beta"])
    terms = svgp.collapsed_bound(Kuu, stats, beta, D)
    return terms.bound - kl


def loss(params: Params, Y: jax.Array, *, kernel: Optional[Kernel] = None,
         backend: str = "jnp", chunk: Optional[int] = None,
         bwd_backend: str = "auto") -> jax.Array:
    """Negative ELBO per datapoint (scale-stable objective for Adam)."""
    return -bound(params, Y, kernel=kernel, backend=backend, chunk=chunk,
                  bwd_backend=bwd_backend) / Y.shape[0]
