"""Collapsed variational bound for sparse GPs (paper eq. (2)-(3)).

Implemented via direct Cholesky of (Kuu + beta Psi2) — NOT the whitened
GPy form chol(I + beta L^-1 Psi2 L^-T): in float32 the whitening squares
Kuu's condition number and I + beta A goes numerically indefinite for
closely-spaced inducing points (NaN at step 0 of the quickstart). The
direct matrix gains PSD mass from beta Psi2 and factors robustly; the
trace term still uses chol(Kuu + jitter), whose failure mode is additive
error, not NaN. Jitter is relative to mean(diag Kuu) and dtype-aware.

    L   = chol(Kuu + jitter I)
    LA  = chol(Kuu + beta Psi2 + jitter I)
    c   = LA^-1 PsiY                             (M, D)

    F = D N/2 log(beta / 2 pi) - D/2 (log|LA LA^T| - log|L L^T|)
        - beta/2 yy + beta^2/2 ||c||_F^2
        - beta D/2 psi0 + beta D/2 tr(L^-1 Psi2 L^-T)

The bound consumes only a `SuffStats` — it never sees the N datapoints. That
separation IS the paper's contribution: stats are produced shard-locally
(core.distributed) or on-accelerator (repro.kernels), combined by a psum, and
this O(M^3 + M^2 D) "indistributable" epilogue runs replicated on every
device (paper Fig 1b measures exactly this epilogue's share of runtime).

Gradients w.r.t. (theta, Z, beta, q(X)) come from jax.grad straight through
this function + the statistics code — the transpose of the psum reproduces the
paper's "broadcast dL/dPsi, dL/dPhi back to workers" step automatically.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.psi_stats import SuffStats

DEFAULT_JITTER = 1e-6


class BoundTerms(NamedTuple):
    bound: jax.Array
    logdet_term: jax.Array
    quad_term: jax.Array
    trace_term: jax.Array
    # epilogue intermediates reused by prediction
    L: jax.Array  # chol(Kuu + jitter)
    LA: jax.Array  # chol(Kuu + beta Psi2 + jitter)
    c: jax.Array  # LA^-1 PsiY


class PosteriorFactors(NamedTuple):
    """The O(M^3) factorization epilogue on its own: everything prediction
    (and the serving layer's cached `PosteriorState`) needs, without the
    bound value. `collapsed_bound` builds on exactly these factors, so a
    posterior refold after an online statistics update is the same code
    path the training loss exercises."""

    L: jax.Array  # chol(Kuu + jitter)
    LA: jax.Array  # chol(Kuu + beta Psi2 + jitter)
    c: jax.Array  # LA^-1 PsiY


def _jitter_eff(Kuu: jax.Array, jitter: float) -> jax.Array:
    """Relative, dtype-aware jitter: f32 needs ~100x f64's."""
    scale = jnp.mean(jnp.diagonal(Kuu))
    boost = 1.0 if Kuu.dtype == jnp.float64 else 100.0
    return jitter * boost * jnp.maximum(scale, 1e-12)


def posterior_factors(
    Kuu: jax.Array,
    stats: SuffStats,
    beta: jax.Array,
    *,
    jitter: float = DEFAULT_JITTER,
) -> PosteriorFactors:
    """Factorize the posterior epilogue from sufficient statistics alone:
    L = chol(Kuu + jit I), LA = chol(Kuu + beta Psi2 + jit I), c = LA^-1 PsiY.
    O(M^3 + M^2 D); never sees the N datapoints."""
    dtype = Kuu.dtype
    M = Kuu.shape[0]
    eye = jnp.eye(M, dtype=dtype)
    jit_eff = _jitter_eff(Kuu, jitter)

    # ONE consistent jittered model: every consumer below works on
    # Kuu_j = Kuu + jit I (mixing different jitters across terms breaks the
    # lower-bound property when Kuu is near-singular, e.g. Z = X).
    Kuu_j = Kuu + jit_eff * eye
    L = jnp.linalg.cholesky(Kuu_j)
    psi2 = 0.5 * (stats.psi2 + stats.psi2.T)
    Abig = Kuu_j + beta * psi2
    # eps-scaled floor for Psi2's own roundoff (~eps * ||Psi2||): negligible
    # in f64 (preserves the bound to ~1e-10), adequate in f32.
    eps = jnp.finfo(dtype).eps
    LA = jnp.linalg.cholesky(Abig + 100.0 * eps * jnp.mean(jnp.diagonal(Abig)) * eye)
    c = jax.scipy.linalg.solve_triangular(LA, stats.psiY, lower=True)  # (M, D)
    return PosteriorFactors(L, LA, c)


def collapsed_bound(
    Kuu: jax.Array,
    stats: SuffStats,
    beta: jax.Array,
    D: int,
    *,
    jitter: float = DEFAULT_JITTER,
) -> BoundTerms:
    """The paper's eq. (3), evaluated from sufficient statistics.

    Args:
      Kuu: (M, M) inducing covariance k(Z, Z).
      stats: accumulated sufficient statistics (possibly psum'd).
      beta: noise precision (scalar).
      D: number of output dimensions.
    """
    N = stats.n
    L, LA, c = posterior_factors(Kuu, stats, beta, jitter=jitter)
    psi2 = 0.5 * (stats.psi2 + stats.psi2.T)

    # log|Kuu + beta Psi2| - log|Kuu| (== log|B| of the whitened form)
    logdetB = 2.0 * (jnp.sum(jnp.log(jnp.diagonal(LA)))
                     - jnp.sum(jnp.log(jnp.diagonal(L))))
    # tr(Kuu^-1 Psi2) via the (jittered) Kuu factor
    tmp = jax.scipy.linalg.solve_triangular(L, psi2, lower=True)
    A = jax.scipy.linalg.solve_triangular(L, tmp.T, lower=True).T

    logdet_term = 0.5 * D * N * jnp.log(beta / (2.0 * jnp.pi)) - 0.5 * D * logdetB
    quad_term = -0.5 * beta * stats.yy + 0.5 * beta**2 * jnp.sum(c * c)
    trace_term = -0.5 * beta * D * stats.psi0 + 0.5 * beta * D * jnp.trace(A)

    bound = logdet_term + quad_term + trace_term
    return BoundTerms(bound, logdet_term, quad_term, trace_term, L, LA, c)


class Posterior(NamedTuple):
    """Optimal q(u) = N(mean_u, cov_u) implied by the collapsed bound."""

    mean_u: jax.Array  # (M, D)
    cov_u: jax.Array  # (M, M)
    Kuu_inv_mean: jax.Array  # (M, D)  Kuu^-1 mean_u, cached for prediction
    L: jax.Array
    LA: jax.Array


def optimal_qu(terms: "BoundTerms | PosteriorFactors", beta: jax.Array) -> Posterior:
    """q(u): mean = beta Kuu (Kuu + beta Psi2)^-1 PsiY,
    cov = Kuu (Kuu + beta Psi2)^-1 Kuu — in Cholesky factors.

    Accepts either the full `BoundTerms` (training path) or the bare
    `PosteriorFactors` (serving path) — both carry (L, LA, c)."""
    L, LA, c = terms.L, terms.LA, terms.c
    # Kuu^-1 mean_u = beta (Kuu + beta Psi2)^-1 PsiY = beta LA^-T c
    Kuu_inv_mean = beta * jax.scipy.linalg.solve_triangular(LA, c, lower=True, trans=1)
    Kuu = L @ L.T
    mean_u = Kuu @ Kuu_inv_mean
    # cov_u = Kuu (Kuu + beta Psi2)^-1 Kuu = (LA^-1 Kuu)^T (LA^-1 Kuu)
    LAiK = jax.scipy.linalg.solve_triangular(LA, Kuu, lower=True)
    cov_u = LAiK.T @ LAiK
    return Posterior(mean_u, cov_u, Kuu_inv_mean, L, LA)


def predict_f(
    post: Posterior,
    Ksu: jax.Array,
    Kss_diag: jax.Array,
) -> tuple[jax.Array, jax.Array]:
    """Posterior p(f*) at test points: mean (N*, D) and marginal var (N*,).

    mean = Ksu Kuu^-1 mean_u
    var  = Kss_diag - diag(Ksu [Kuu^-1 - (Kuu + beta Psi2)^-1] Kus)
    """
    mean = Ksu @ post.Kuu_inv_mean
    v1 = jax.scipy.linalg.solve_triangular(post.L, Ksu.T, lower=True)
    v2 = jax.scipy.linalg.solve_triangular(post.LA, Ksu.T, lower=True)
    var = Kss_diag - jnp.sum(v1 * v1, axis=0) + jnp.sum(v2 * v2, axis=0)
    return mean, var


def predict_f_full(
    post: Posterior,
    Ksu: jax.Array,
    Kss: jax.Array,
) -> tuple[jax.Array, jax.Array]:
    """Posterior p(f*) with the FULL (N*, N*) covariance:

    mean = Ksu Kuu^-1 mean_u
    cov  = Kss - Ksu [Kuu^-1 - (Kuu + beta Psi2)^-1] Kus

    Same triangular-solve structure as `predict_f` (no new factorization);
    the serving layer uses this for `diag=False` requests.
    """
    mean = Ksu @ post.Kuu_inv_mean
    v1 = jax.scipy.linalg.solve_triangular(post.L, Ksu.T, lower=True)
    v2 = jax.scipy.linalg.solve_triangular(post.LA, Ksu.T, lower=True)
    cov = Kss - v1.T @ v1 + v2.T @ v2
    return mean, cov


def exact_gp_log_marginal(
    Kff: jax.Array, Y: jax.Array, beta: jax.Array, *, jitter: float = DEFAULT_JITTER
) -> jax.Array:
    """O(N^3) exact GP log marginal likelihood — the oracle the collapsed
    bound must lower-bound (tests) and converge to as Z -> X."""
    N, D = Y.shape
    Ky = Kff + (1.0 / beta + jitter) * jnp.eye(N, dtype=Kff.dtype)
    L = jnp.linalg.cholesky(Ky)
    alpha = jax.scipy.linalg.solve_triangular(L, Y, lower=True)
    logdet = 2.0 * jnp.sum(jnp.log(jnp.diagonal(L)))
    return -0.5 * D * N * jnp.log(2.0 * jnp.pi) - 0.5 * D * logdet - 0.5 * jnp.sum(alpha**2)
