"""Data-parallel sparse-GP inference (paper §2) on a JAX device mesh.

The paper's MPI scheme, translated:

  * every device owns a contiguous shard of (Y, q_mu, q_logS) [GP-LVM] or
    (X, Y) [sparse GP regression];
  * each device computes its local `SuffStats` (the only O(N) work);
  * one `jax.lax.psum` over the data axes combines them — this is the paper's
    single Allreduce of {phi, Phi, Psi, yy};
  * the O(M^3) epilogue (Cholesky, logdet, quadratic form) is evaluated
    replicated on every device — cheaper than broadcasting its result, and it
    keeps the whole step SPMD;
  * jax.grad through the psum reproduces the reverse path of paper Table 2:
    dL/dPhi etc. are *replicated* cotangents that each shard contracts against
    its local kernel-derivative terms. Global-parameter gradients (theta, Z,
    beta) emerge psum'd; local-parameter gradients (mu_n, S_n) stay sharded.

No parameter server, no gradient gathering to rank 0: the optimizer step is
SPMD too (the paper notes its rank-0 L-BFGS collector is a stopgap).

Both losses are kernel-generic: pass any `repro.gp.kernels.Kernel` (default
RBF, the paper's choice); `backend=` / `bwd_backend=` / `chunk=` thread
through to the statistics engine unchanged, so each shard's kernelized
statistics backward through their hand-derived reverse kernels (or the
streaming jnp twins) under the shard_map transpose. Shard_map in/out specs
derive from the declarative
`PARAM_ROLES` table below instead of per-model hand-written spec dicts —
kernel parameter trees of any shape ride on the `P()` pytree prefix.
"""
from __future__ import annotations

import functools
from typing import Dict, Iterable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat
from repro.core import gplvm, svgp
from repro.gp.kernels import Kernel, default_rbf
from repro.gp.stats import ExactBatch, suff_stats

Params = Dict[str, jax.Array]

# ---------------------------------------------------------------------------
# declarative parameter-spec table (the paper's local/global split)
# ---------------------------------------------------------------------------
# "local"  — per-datapoint parameters, sharded over the data axes;
# "global" — model parameters, replicated (grads emerge psum'd).
# A single P() / P(axes) acts as a pytree *prefix*, so arbitrarily-shaped
# kernel parameter trees need no per-leaf spec.
PARAM_ROLES: Dict[str, str] = {
    "kern": "global",
    "Z": "global",
    "log_beta": "global",
    "q_mu": "local",
    "q_logS": "local",
}

SGPR_PARAM_NAMES = ("kern", "Z", "log_beta")
GPLVM_PARAM_NAMES = SGPR_PARAM_NAMES + ("q_mu", "q_logS")


def _data_axes(mesh: Mesh) -> tuple[str, ...]:
    """All mesh axes used for data parallelism (everything except 'model')."""
    return tuple(a for a in mesh.axis_names if a != "model")


def make_param_specs(names: Iterable[str], mesh: Mesh) -> Dict[str, P]:
    """in_specs for a param dict, derived from PARAM_ROLES."""
    axes = _data_axes(mesh)
    return {n: P(axes) if PARAM_ROLES[n] == "local" else P() for n in names}


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


def data_sharded(mesh: Mesh):
    return NamedSharding(mesh, P(_data_axes(mesh)))


def shard_gp_params(params: Params, mesh: Mesh) -> Params:
    """Device placement mirroring PARAM_ROLES: locals on the data axes,
    globals replicated."""
    out = {}
    for k, v in params.items():
        if PARAM_ROLES.get(k) == "local":
            out[k] = jax.device_put(v, data_sharded(mesh))
        else:
            out[k] = jax.device_put(v, jax.tree.map(lambda _: replicated(mesh), v)
                                     if isinstance(v, dict) else replicated(mesh))
    return out


# back-compat alias (pre-facade name)
shard_gplvm_params = shard_gp_params


def gplvm_loss_dist(mesh: Mesh, *, kernel: Optional[Kernel] = None,
                    backend: str = "jnp", chunk: Optional[int] = None,
                    bwd_backend: str = "auto"):
    """Distributed GP-LVM negative-ELBO: shard_map over the data axes.

    Returns loss(params, Y) with Y and q(X) sharded over the data axes and a
    replicated scalar output. Differentiable; grads of global params are
    automatically psum'd by the shard_map transpose. `chunk=` streams each
    shard's datapoints (per-shard scan, then the one psum).
    """
    axes = _data_axes(mesh)
    local_spec = P(axes)
    gspec = make_param_specs(GPLVM_PARAM_NAMES, mesh)

    @functools.partial(
        compat.shard_map,
        mesh=mesh,
        in_specs=(gspec, local_spec),
        out_specs=P(),
    )
    def loss(params: Params, Y_local: jax.Array) -> jax.Array:
        D = Y_local.shape[1]
        stats = gplvm.local_stats(params, Y_local, kernel=kernel,
                                  backend=backend, chunk=chunk,
                                  bwd_backend=bwd_backend)
        kl = gplvm.kl_qp(params["q_mu"], params["q_logS"])
        # --- the paper's single collective: combine sufficient statistics ---
        stats = jax.tree.map(lambda x: jax.lax.psum(x, axes), stats)
        kl = jax.lax.psum(kl, axes)
        # --- indistributable epilogue, replicated ---
        bound = gplvm.bound_from_stats(params, stats, kl, D, kernel=kernel)
        return -bound / stats.n

    return loss


def sgpr_loss_dist(mesh: Mesh, *, kernel: Optional[Kernel] = None,
                   backend: str = "jnp", chunk: Optional[int] = None,
                   bwd_backend: str = "auto"):
    """Distributed sparse-GP-regression negative log-bound (deterministic X)."""
    axes = _data_axes(mesh)
    local_spec = P(axes)
    gspec = make_param_specs(SGPR_PARAM_NAMES, mesh)

    @functools.partial(
        compat.shard_map,
        mesh=mesh,
        in_specs=(gspec, local_spec, local_spec),
        out_specs=P(),
    )
    def loss(params: Params, X_local: jax.Array, Y_local: jax.Array) -> jax.Array:
        D = Y_local.shape[1]
        kern = default_rbf(kernel, params["Z"].shape[1])
        stats = suff_stats(kern, params["kern"],
                           ExactBatch(X_local, Y_local, params["Z"]),
                           backend=backend, chunk=chunk,
                           bwd_backend=bwd_backend)
        stats = jax.tree.map(lambda x: jax.lax.psum(x, axes), stats)
        Kuu = kern.K(params["kern"], params["Z"])
        terms = svgp.collapsed_bound(Kuu, stats, jnp.exp(params["log_beta"]), D)
        return -terms.bound / stats.n

    return loss


# ---------------------------------------------------------------------------
# predict-time statistics (same decomposition, no epilogue)
# ---------------------------------------------------------------------------

def sgpr_stats_dist(mesh: Mesh, *, kernel: Optional[Kernel] = None,
                    backend: str = "jnp", chunk: Optional[int] = None,
                    bwd_backend: str = "auto"):
    """Distributed O(N M^2) statistics pass for SGPR posterior/prediction.

    `posterior()` needs the same psum'd `SuffStats` the training loss
    consumes, so prediction shards the pass identically: per-device (and
    optionally per-chunk) statistics, one psum, replicated output.
    """
    axes = _data_axes(mesh)
    local_spec = P(axes)
    gspec = make_param_specs(SGPR_PARAM_NAMES, mesh)

    @functools.partial(
        compat.shard_map,
        mesh=mesh,
        in_specs=(gspec, local_spec, local_spec),
        out_specs=P(),
    )
    def stats_fn(params: Params, X_local: jax.Array, Y_local: jax.Array):
        kern = default_rbf(kernel, params["Z"].shape[1])
        stats = suff_stats(kern, params["kern"],
                           ExactBatch(X_local, Y_local, params["Z"]),
                           backend=backend, chunk=chunk,
                           bwd_backend=bwd_backend)
        return jax.tree.map(lambda x: jax.lax.psum(x, axes), stats)

    return stats_fn


def gplvm_stats_dist(mesh: Mesh, *, kernel: Optional[Kernel] = None,
                     backend: str = "jnp", chunk: Optional[int] = None,
                     bwd_backend: str = "auto"):
    """Distributed statistics pass for the GP-LVM posterior (see above)."""
    axes = _data_axes(mesh)
    local_spec = P(axes)
    gspec = make_param_specs(GPLVM_PARAM_NAMES, mesh)

    @functools.partial(
        compat.shard_map,
        mesh=mesh,
        in_specs=(gspec, local_spec),
        out_specs=P(),
    )
    def stats_fn(params: Params, Y_local: jax.Array):
        stats = gplvm.local_stats(params, Y_local, kernel=kernel,
                                  backend=backend, chunk=chunk,
                                  bwd_backend=bwd_backend)
        return jax.tree.map(lambda x: jax.lax.psum(x, axes), stats)

    return stats_fn


def make_gp_mesh(n_devices: int | None = None, axis: str = "data") -> Mesh:
    """1-D data mesh over however many devices exist (1 on this CPU box,
    hundreds of chips in production — the code path is identical)."""
    devs = jax.devices() if n_devices is None else jax.devices()[:n_devices]
    return compat.make_mesh((len(devs),), (axis,), devices=devs)
