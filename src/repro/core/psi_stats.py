"""Sufficient statistics of the collapsed sparse-GP bound (paper §2).

Everything the bound needs from the N datapoints is reduced to:

    stats.psi0   scalar   sum_n <k(x_n, x_n)>
    stats.psi2   (M, M)   sum_n <k_fu(x_n)^T k_fu(x_n)>     ("Phi" in the paper)
    stats.psiY   (M, D)   sum_n <k_fu(x_n)>^T y_n           ("Psi" in the paper)
    stats.yy     scalar   sum_n y_n y_n^T
    stats.n      scalar   number of datapoints accumulated

All five are plain sums over n, which is precisely what makes the paper's
MPI/GPU decomposition work: `SuffStats` forms a commutative monoid under
`combine` (used by `core.distributed` with jax.lax.psum and by the data
chunking here).

Two computation modes:
  * exact      — deterministic inputs X (supervised sparse GP): K_fu matmuls.
  * expected   — Gaussian q(X) = prod_n N(mu_n, diag(S_n)) (Bayesian GP-LVM):
                 closed-form RBF/Linear expectations.

`backend="pallas"` routes the hot statistics through the single-statistic
Pallas TPU kernels (repro.kernels.ops — kernelized in both directions:
their reverse passes specialize the fused op's hand-derived rules);
`backend="fused"` through the fused suffstats op (one pass over N for
psi2 + psiY, exact path included via S -> 0, differentiable through its
hand-derived reverse pass); `backend="jnp"` uses memory-lean jnp (scan
over N chunks for Psi2 — never materializes (N, M, M)). For both kernel
backends `bwd_backend` selects the reverse-pass implementation (Pallas
reverse kernel vs streaming jnp twin). O(chunk)-memory streaming over N
for every backend lives one layer up, in
`repro.gp.stats.suff_stats(chunk=...)`.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels import ref


def _rbf_variance(kern_params) -> jax.Array:
    return jnp.exp(kern_params["log_variance"])


def _rbf_lengthscale(kern_params) -> jax.Array:
    return jnp.exp(kern_params["log_lengthscale"])


class SuffStats(NamedTuple):
    psi0: jax.Array  # scalar
    psi2: jax.Array  # (M, M)
    psiY: jax.Array  # (M, D)
    yy: jax.Array  # scalar
    n: jax.Array  # scalar (float for psum-ability)

    @staticmethod
    def combine(a: "SuffStats", b: "SuffStats") -> "SuffStats":
        return SuffStats(*(x + y for x, y in zip(a, b)))

    @staticmethod
    def subtract(a: "SuffStats", b: "SuffStats") -> "SuffStats":
        """Monoid inverse: remove `b`'s datapoints from `a`. Exact algebra —
        every statistic is a plain sum over n — but floating cancellation can
        leave `a - b` indefinite when b carries most of a's mass, which is
        why the serving-layer downdate (repro.serve.online) re-factorizes
        behind a condition guard."""
        return SuffStats(*(x - y for x, y in zip(a, b)))


# ---------------------------------------------------------------------------
# exact statistics (deterministic X)
# ---------------------------------------------------------------------------

def exact_stats_rbf(
    kern_params, X: jax.Array, Y: jax.Array, Z: jax.Array, *,
    backend: str = "jnp", bwd_backend: str = "auto"
) -> SuffStats:
    variance = _rbf_variance(kern_params)
    lengthscale = _rbf_lengthscale(kern_params)
    if backend == "fused":
        # S -> 0 collapses the expected statistics to the exact ones
        # (psi1 -> K_fu, per-point psi2 -> k_fu k_fu^T; see
        # docs/derivations/suffstats_vjp.md §"Exact statistics"), so the
        # supervised path rides the same fused kernel + hand-derived VJP.
        from repro.kernels import ops

        psi2, psiY = ops.suffstats(X, jnp.zeros_like(X), Y, Z, variance,
                                   lengthscale, bwd_backend=bwd_backend)
        return SuffStats(
            psi0=X.shape[0] * variance,
            psi2=psi2,
            psiY=psiY,
            yy=jnp.sum(Y * Y),
            n=jnp.asarray(X.shape[0], X.dtype),
        )
    if backend == "pallas":
        from repro.kernels import ops

        # differentiable through the kfu reverse kernel / jnp twin —
        # `bwd_backend` dispatches exactly like the fused op's
        Kfu = ops.kfu(X, Z, variance, lengthscale, bwd_backend=bwd_backend)
    else:
        Kfu = ref.kfu_rbf(X, Z, variance, lengthscale)
    return SuffStats(
        psi0=X.shape[0] * variance,
        psi2=Kfu.T @ Kfu,
        psiY=Kfu.T @ Y,
        yy=jnp.sum(Y * Y),
        n=jnp.asarray(X.shape[0], Kfu.dtype),
    )


# ---------------------------------------------------------------------------
# expected statistics under q(X) (Bayesian GP-LVM)
# ---------------------------------------------------------------------------

def _psi2_rbf_chunked(mu, S, Z, variance, lengthscale, *, chunk: int = 256) -> jax.Array:
    """Psi2 accumulated over N in chunks: O(chunk * M^2) live memory.

    Mirrors the paper's GPU kernel structure (Table 1): the (M, M) accumulator
    stays resident while datapoints stream through.
    """
    N, Q = mu.shape
    M = Z.shape[0]
    l2 = lengthscale**2
    zdiff = Z[:, None, :] - Z[None, :, :]  # (M, M, Q)
    zterm = -jnp.sum(zdiff**2 / (4.0 * l2), axis=-1)  # (M, M)
    zbar = 0.5 * (Z[:, None, :] + Z[None, :, :])  # (M, M, Q)

    pad = (-N) % chunk
    mu_p = jnp.pad(mu, ((0, pad), (0, 0)))
    # pad S with ones (any positive value) and mask via weight w
    S_p = jnp.pad(S, ((0, pad), (0, 0)), constant_values=1.0)
    w = jnp.pad(jnp.ones((N,), mu.dtype), ((0, pad),))
    mu_c = mu_p.reshape(-1, chunk, Q)
    S_c = S_p.reshape(-1, chunk, Q)
    w_c = w.reshape(-1, chunk)

    # checkpoint: the transpose re-derives each chunk's (chunk, M, M) tensor
    # instead of stacking it across scan steps — without this, reverse-mode
    # saves O(N * M^2 / chunk) residuals and the memory claim is void
    @jax.checkpoint
    def body(acc, xs):
        mu_i, S_i, w_i = xs  # (chunk, Q), (chunk, Q), (chunk,)
        denom = l2[None, :] + 2.0 * S_i  # (chunk, Q)
        lognorm = -0.5 * jnp.sum(jnp.log1p(2.0 * S_i / l2[None, :]), axis=-1)  # (chunk,)
        # accumulate exponent over q without a (chunk, M, M, Q) intermediate
        expo = jnp.zeros((mu_i.shape[0], M, M), mu.dtype)
        for q in range(Q):  # Q is small (latent dim); unrolled
            d = mu_i[:, None, None, q] - zbar[None, :, :, q]
            expo = expo - d * d / denom[:, None, None, q]
        contrib = w_i[:, None, None] * jnp.exp(lognorm[:, None, None] + expo)
        return acc + jnp.sum(contrib, axis=0), None

    # `+ 0 * mu[0, 0]` inherits mu's varying-manual-axes type so the scan
    # carry is well-typed when this runs inside shard_map (see shard_map-vma).
    acc0 = jnp.zeros((M, M), mu.dtype) + 0.0 * mu[0, 0]
    acc, _ = jax.lax.scan(body, acc0, (mu_c, S_c, w_c))
    return variance**2 * jnp.exp(zterm) * acc


def expected_stats_rbf(
    kern_params,
    mu: jax.Array,
    S: jax.Array,
    Y: jax.Array,
    Z: jax.Array,
    *,
    backend: str = "jnp",
    bwd_backend: str = "auto",
    psi2_chunk: int = 256,
) -> SuffStats:
    variance = _rbf_variance(kern_params)
    lengthscale = _rbf_lengthscale(kern_params)
    if backend == "pallas":
        from repro.kernels import ops

        # single-statistic ops: kernelized in BOTH directions — the reverse
        # passes specialize the fused rules (same tile helpers, same
        # `bwd_backend` dispatch; docs/derivations/suffstats_vjp.md)
        psi1 = ops.psi1(mu, S, Z, variance, lengthscale,
                        bwd_backend=bwd_backend)
        psi2 = ops.psi2(mu, S, Z, variance, lengthscale,
                        bwd_backend=bwd_backend)
    elif backend == "fused":
        # single pass over N producing (psi2, psiY) together — the
        # beyond-paper fusion (§Perf C2): one read of (mu, S, Y) per
        # datapoint instead of two. Differentiable: the op carries the
        # hand-derived reverse pass, itself kernelized (kernels/ops.py;
        # `bwd_backend` picks the Pallas reverse kernel vs the jnp scan).
        from repro.kernels import ops

        psi2, psiY = ops.suffstats(mu, S, Y, Z, variance, lengthscale,
                                   bwd_backend=bwd_backend)
        return SuffStats(
            psi0=mu.shape[0] * variance,
            psi2=psi2,
            psiY=psiY,
            yy=jnp.sum(Y * Y),
            n=jnp.asarray(mu.shape[0], mu.dtype),
        )
    else:
        psi1 = ref.psi1_rbf(mu, S, Z, variance, lengthscale)
        psi2 = _psi2_rbf_chunked(mu, S, Z, variance, lengthscale, chunk=psi2_chunk)
    return SuffStats(
        psi0=mu.shape[0] * variance,
        psi2=psi2,
        psiY=psi1.T @ Y,
        yy=jnp.sum(Y * Y),
        n=jnp.asarray(mu.shape[0], mu.dtype),
    )


def expected_stats_linear(
    kern_params, mu: jax.Array, S: jax.Array, Y: jax.Array, Z: jax.Array
) -> SuffStats:
    ard = jnp.exp(kern_params["log_ard"])
    psi1 = ref.psi1_linear(mu, S, Z, ard)
    return SuffStats(
        psi0=ref.psi0_linear(mu, S, ard),
        psi2=ref.psi2_linear(mu, S, Z, ard),
        psiY=psi1.T @ Y,
        yy=jnp.sum(Y * Y),
        n=jnp.asarray(mu.shape[0], mu.dtype),
    )
