"""The paper's primary contribution: distributed variational inference for
sparse GP models (Titsias bound + Bayesian GP-LVM), decomposed into
shard-local sufficient statistics + one psum + a replicated O(M^3) epilogue,
with the hot statistics implemented as Pallas TPU kernels (repro.kernels)."""
from repro.core import distributed, gp_head, gp_kernels, gplvm, inference, psi_stats, svgp

__all__ = ["distributed", "gp_head", "gp_kernels", "gplvm", "inference", "psi_stats", "svgp"]
