"""The paper's primary contribution: distributed variational inference for
sparse GP models (Titsias bound + Bayesian GP-LVM), decomposed into
shard-local sufficient statistics + one psum + a replicated O(M^3) epilogue,
with the hot statistics implemented as Pallas TPU kernels (repro.kernels)."""
import importlib

__all__ = ["distributed", "gp_head", "gp_kernels", "gplvm", "inference", "psi_stats", "svgp"]


def __getattr__(name):
    # Lazy (PEP 562) so that repro.gp.kernels can import repro.core.psi_stats
    # without dragging in the whole core layer (gp_kernels shims back to
    # repro.gp.kernels — an eager import here would be circular).
    if name in __all__:
        return importlib.import_module(f"repro.core.{name}")
    raise AttributeError(f"module 'repro.core' has no attribute {name!r}")
