"""Covariance functions for sparse GP models — compatibility shim.

The kernel classes moved to `repro.gp.kernels`, which adds the full Kernel
protocol (exact/expected sufficient statistics), the Matern family,
Sum/Product composites, and the string registry. This module keeps the old
import path (`from repro.core.gp_kernels import RBF`) working.
"""
from __future__ import annotations

from repro.gp.kernels import Linear, Params, RBF  # noqa: F401
