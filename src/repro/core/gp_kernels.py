"""Covariance functions for sparse GP models.

The paper (and GPy) parameterize the RBF/ARD kernel as

    k(x, x') = sigma_f^2 * exp(-0.5 * sum_q (x_q - x'_q)^2 / l_q^2)

Parameters are stored as unconstrained log-values so gradient-based
optimizers (Adam here, L-BFGS-B in the paper) work on R^n.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp

Params = Dict[str, jax.Array]


@dataclasses.dataclass(frozen=True)
class RBF:
    """RBF (squared exponential) kernel with ARD lengthscales.

    Closed-form psi statistics under Gaussian q(X) exist for this kernel,
    which is why the paper's GP-LVM experiments use it.
    """

    input_dim: int

    def init(self, variance: float = 1.0, lengthscale: float = 1.0) -> Params:
        return {
            "log_variance": jnp.asarray(jnp.log(variance), jnp.float32),
            "log_lengthscale": jnp.full((self.input_dim,), jnp.log(lengthscale), jnp.float32),
        }

    @staticmethod
    def variance(params: Params) -> jax.Array:
        return jnp.exp(params["log_variance"])

    @staticmethod
    def lengthscale(params: Params) -> jax.Array:
        return jnp.exp(params["log_lengthscale"])

    def K(self, params: Params, X: jax.Array, X2: jax.Array | None = None) -> jax.Array:
        """Dense covariance matrix k(X, X2)."""
        ls = self.lengthscale(params)
        Xs = X / ls
        X2s = Xs if X2 is None else X2 / ls
        # squared euclidean distances via the stable (a-b)^2 expansion
        d2 = (
            jnp.sum(Xs**2, -1)[:, None]
            + jnp.sum(X2s**2, -1)[None, :]
            - 2.0 * Xs @ X2s.T
        )
        d2 = jnp.maximum(d2, 0.0)
        return self.variance(params) * jnp.exp(-0.5 * d2)

    def Kdiag(self, params: Params, X: jax.Array) -> jax.Array:
        return jnp.full((X.shape[0],), self.variance(params))


@dataclasses.dataclass(frozen=True)
class Linear:
    """Linear kernel k(x,x') = sum_q a_q x_q x'_q (ARD variances).

    Also admits closed-form psi statistics; used in tests to make sure the
    psi-statistics layer is kernel-generic.
    """

    input_dim: int

    def init(self, variance: float = 1.0) -> Params:
        return {"log_ard": jnp.full((self.input_dim,), jnp.log(variance), jnp.float32)}

    @staticmethod
    def ard(params: Params) -> jax.Array:
        return jnp.exp(params["log_ard"])

    def K(self, params: Params, X: jax.Array, X2: jax.Array | None = None) -> jax.Array:
        a = self.ard(params)
        X2 = X if X2 is None else X2
        return (X * a) @ X2.T

    def Kdiag(self, params: Params, X: jax.Array) -> jax.Array:
        return jnp.sum(self.ard(params) * X * X, -1)
