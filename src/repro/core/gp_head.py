"""SVGP readout head on transformer features (deep-kernel integration).

This is how the paper's technique plugs into the assigned LM architectures:
the backbone produces pooled features h_n in R^Q; a sparse-GP regression layer
with inducing points in feature space gives a calibrated predictive
distribution over a scalar/vector target (reward modelling, value heads,
uncertainty-aware regression). Features are deterministic, so the *exact*
statistics path applies — Phi/Psi are plain matmuls that shard over the data
axes exactly like the GP-LVM case (core.distributed).

The head is trained jointly with (or frozen on top of) the backbone: the
collapsed bound is differentiable w.r.t. the features, so gradients flow into
the transformer.
"""
from __future__ import annotations

from typing import Dict, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import svgp
from repro.core.gp_kernels import RBF
from repro.gp.stats import ExactBatch, suff_stats

Params = Dict[str, jax.Array]


def init_head(key: jax.Array, feature_dim: int, M: int = 256, D: int = 1) -> Params:
    zkey, _ = jax.random.split(key)
    return {
        "kern": RBF(feature_dim).init(variance=1.0, lengthscale=float(feature_dim) ** 0.5),
        "Z": jax.random.normal(zkey, (M, feature_dim), jnp.float32),
        "log_beta": jnp.asarray(jnp.log(10.0), jnp.float32),
    }


def head_loss(params: Params, features: jax.Array, targets: jax.Array,
              *, axis_names: tuple = ()) -> jax.Array:
    """Negative collapsed bound per datapoint.

    If `axis_names` is non-empty the statistics are psum'd over those mesh
    axes (call under shard_map/pjit with features sharded on them).
    """
    feats = features.astype(jnp.float32)
    tgts = targets.astype(jnp.float32)
    if tgts.ndim == 1:
        tgts = tgts[:, None]
    kern = RBF(params["Z"].shape[1])
    stats = suff_stats(kern, params["kern"], ExactBatch(feats, tgts, params["Z"]))
    if axis_names:
        stats = jax.tree.map(lambda x: jax.lax.psum(x, axis_names), stats)
    Kuu = kern.K(params["kern"], params["Z"])
    terms = svgp.collapsed_bound(Kuu, stats, jnp.exp(params["log_beta"]), tgts.shape[1])
    return -terms.bound / stats.n


class HeadPrediction(NamedTuple):
    mean: jax.Array
    var: jax.Array


def head_predict(params: Params, train_features: jax.Array, train_targets: jax.Array,
                 test_features: jax.Array) -> HeadPrediction:
    feats = train_features.astype(jnp.float32)
    tgts = train_targets.astype(jnp.float32)
    if tgts.ndim == 1:
        tgts = tgts[:, None]
    kern = RBF(params["Z"].shape[1])
    stats = suff_stats(kern, params["kern"], ExactBatch(feats, tgts, params["Z"]))
    Kuu = kern.K(params["kern"], params["Z"])
    beta = jnp.exp(params["log_beta"])
    terms = svgp.collapsed_bound(Kuu, stats, beta, tgts.shape[1])
    post = svgp.optimal_qu(terms, beta)
    Ksu = kern.K(params["kern"], test_features.astype(jnp.float32), params["Z"])
    Kss = kern.Kdiag(params["kern"], test_features.astype(jnp.float32))
    mean, var = svgp.predict_f(post, Ksu, Kss)
    return HeadPrediction(mean, var)
