"""Optimization drivers for the GP models.

Two paths, mirroring the paper:
  * `fit_lbfgs`  — scipy L-BFGS-B on the (negative) bound, gradients from JAX.
                   This is the paper's optimizer (§2 end). Parameters are
                   gathered/flattened to the host — fine at GP scale, and it
                   reproduces the paper's experiment exactly.
  * `fit_adam`   — SPMD Adam on the distributed bound: no collector node, the
                   production path. Works with any loss(params, *batch).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Iterable

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import AdamConfig, adam_init, adam_update

PyTree = Any


def fit_adam(
    loss_fn: Callable[..., jax.Array],
    params: PyTree,
    data: tuple,
    *,
    steps: int = 200,
    lr: float = 1e-2,
    log_every: int = 0,
    donate: bool = True,
) -> tuple[PyTree, list[float]]:
    """SPMD Adam driver. `donate=` donates the (params, state) buffers to the
    jitted step so each iteration updates in place instead of holding two
    copies of the model state (the caller's pytrees are copied once up
    front, so references the caller keeps stay valid). The returned history
    ends with the loss the final step computed (at its pre-update
    parameters) — no extra full statistics pass is spent on logging; with
    `steps=0` no loss is ever evaluated and the history is empty.
    """
    config = AdamConfig(lr=lr, clip_norm=None, weight_decay=0.0)
    state = adam_init(params, config)

    # the CPU backend does not implement buffer donation (XLA would warn and
    # copy anyway), so only request it where it is real
    donate_argnums = (0, 1) if donate and jax.default_backend() != "cpu" else ()
    if donate_argnums:
        # the first step would otherwise donate the CALLER's buffers — copy
        # once up front so only loop-internal state is recycled
        params = jax.tree.map(jnp.array, params)
        state = jax.tree.map(jnp.array, state)

    @functools.partial(jax.jit, donate_argnums=donate_argnums)
    def step(params, state, *batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, *batch)
        params, state, _ = adam_update(grads, state, params, config)
        return params, state, loss

    history = []
    loss = None
    for i in range(steps):
        params, state, loss = step(params, state, *data)
        if log_every and i % log_every == 0:
            history.append(float(loss))
            print(f"  step {i:5d}  loss {float(loss):.4f}")
    if loss is not None and not (log_every and (steps - 1) % log_every == 0):
        history.append(float(loss))
    return params, history


def fit_lbfgs(
    loss_fn: Callable[..., jax.Array],
    params: PyTree,
    data: tuple,
    *,
    maxiter: int = 200,
) -> tuple[PyTree, float]:
    """scipy L-BFGS-B driver (the paper's optimizer)."""
    from scipy.optimize import minimize

    flat, treedef = jax.tree.flatten(params)
    shapes = [p.shape for p in flat]
    sizes = [int(np.prod(s)) if s else 1 for s in shapes]
    dtypes = [p.dtype for p in flat]

    def pack(tree_leaves) -> np.ndarray:
        return np.concatenate([np.asarray(p, np.float64).reshape(-1) for p in tree_leaves])

    def unpack(x: np.ndarray) -> PyTree:
        out, off = [], 0
        for s, n, dt in zip(shapes, sizes, dtypes):
            out.append(jnp.asarray(x[off : off + n].reshape(s), dt))
            off += n
        return treedef.unflatten(out)

    vg = jax.jit(jax.value_and_grad(loss_fn))

    def objective(x: np.ndarray):
        p = unpack(x)
        val, grads = vg(p, *data)
        return float(val), pack(treedef.flatten_up_to(grads))

    res = minimize(objective, pack(flat), jac=True, method="L-BFGS-B",
                   options={"maxiter": maxiter})
    return unpack(res.x), float(res.fun)
