"""repro: GP models with parallelization and GPU acceleration (jax/pallas).

A regular package (not a namespace package) so `repro.__file__` resolves —
subprocess-based tests locate the source tree through it.
"""
