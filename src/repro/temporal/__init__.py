"""`repro.temporal` — state-space GP backend: kernel -> LTI SDE -> parallel
associative-scan Kalman filter/smoother (log depth), with a sequential twin.

The second compute backend beside the collapsed bound: exact O(N) inference
for 1-D stationary kernels (Matern12/32/52 + Sum/Product), selected via
`repro.gp.regression(backend="temporal")`, served through `repro.serve`
via `TemporalState`. See docs/temporal.md.
"""
from repro.temporal.model import (TemporalGPRegression, TemporalState,
                                  forecast, forecast_closure, update_state)
from repro.temporal.pskf import FilterResult, kalman_filter, rts_smoother
from repro.temporal.sde import LTISDE, discretize

__all__ = [
    "LTISDE",
    "discretize",
    "FilterResult",
    "kalman_filter",
    "rts_smoother",
    "TemporalGPRegression",
    "TemporalState",
    "forecast",
    "forecast_closure",
    "update_state",
]
