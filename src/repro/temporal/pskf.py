"""Kalman filtering/smoothing for state-space GPs — parallel associative
scan (log depth) with a sequential `lax.scan` twin.

Model (from `repro.temporal.sde.discretize`): per-step transition/noise
(A_k, Q_k), shared observation row H (d,) and noise variance R, prior
x_0 ~ N(m0, P0) at the step before the first timestamp:

    x_k = A_k x_{k-1} + q_k,  q_k ~ N(0, Q_k)
    y_k = H x_k + r_k,        r_k ~ N(0, R)          (k = 1..N)

Observations are (N, D) matrices: D independent output columns SHARE the
covariance recursion (P, S, K never depend on y), so the state mean is
carried as a (d, D) matrix and the whole filter runs once for all columns.
A boolean `mask` marks which steps carry an observation — masked steps are
pure predictions, which is how `TemporalGPRegression.predict` interpolates
at test timestamps.

The parallel path follows Sarkka & Garcia-Fernandez (2021, *Temporal
Parallelization of Bayesian Smoothers*), the formulation the parallel-gps
exemplar implements (SNIPPETS.md snippet 1): filtering becomes a PREFIX
scan of five-tuples (A, b, C, eta, J) under the associative combine
(eq. (6), docs/temporal.md), smoothing a SUFFIX scan of triples (E, g, L)
under eq. (8) — both through
`jax.lax.associative_scan`, O(N) work and O(log N) depth. The sequential
twin runs the textbook recursions through `lax.scan`; `parallel=` picks
the path, and tests/test_temporal.py pins the two to <= 1e-10 in f64.
Derivations with numbered equations: docs/temporal.md.

Both paths are pure and jittable, and both return the EXACT log marginal
likelihood  sum_k log N(y_k | H m^-_k, S_k)  computed from the one-step
predicted moments — shared post-hoc code (`_lml`), so the two paths
evaluate the same formula on their own filtered moments.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax


class FilterResult(NamedTuple):
    means: jax.Array  # (N, d, D) filtered state means
    covs: jax.Array  # (N, d, d) filtered state covariances (shared over D)
    lml: jax.Array  # scalar: exact log marginal likelihood of observed steps


def _sym(P: jax.Array) -> jax.Array:
    return 0.5 * (P + jnp.swapaxes(P, -1, -2))


def _lml(A, Q, H, R, y, mask, m0, P0, means, covs) -> jax.Array:
    """Exact lml from filtered moments: shift (means, covs) one step right,
    predict through (A, Q), and sum the Gaussian log-densities of observed
    steps. O(N d^2) and identical code for both filter paths."""
    prev_m = jnp.concatenate([m0[None], means[:-1]])
    prev_P = jnp.concatenate([P0[None], covs[:-1]])
    mp = A @ prev_m  # (N, d, D)
    Pp = jnp.einsum("nij,njk,nlk->nil", A, prev_P, A) + Q
    S = jnp.einsum("i,nij,j->n", H, Pp, H) + R  # (N,)
    v = y - jnp.einsum("i,nid->nd", H, mp)  # (N, D)
    D = y.shape[1]
    ll = -0.5 * (D * jnp.log(2.0 * jnp.pi * S) + jnp.sum(v * v, axis=1) / S)
    return jnp.sum(jnp.where(mask, ll, 0.0))


def _filter_sequential(A, Q, H, R, y, mask, m0, P0):
    """Textbook predict/update recursion under `lax.scan` (O(N) depth)."""

    def step(carry, inp):
        m, P = carry
        A_k, Q_k, y_k, obs = inp
        mp = A_k @ m
        Pp = _sym(A_k @ P @ A_k.T + Q_k)
        S = H @ Pp @ H + R
        K = jnp.where(obs, Pp @ H / S, jnp.zeros_like(H))
        m_f = mp + jnp.outer(K, y_k - H @ mp)
        P_f = _sym(Pp - jnp.outer(K, H) @ Pp)
        return (m_f, P_f), (m_f, P_f)

    _, (means, covs) = lax.scan(step, (m0, P0), (A, Q, y, mask))
    return means, covs


def _filter_elements(A, Q, H, R, y, mask, m0, P0):
    """Per-step associative filtering elements (A, b, C, eta, J).

    Generic step (eq. (5), docs/temporal.md), with S = H Q H^T + R and
    K = Q H^T / S:  A_el = (I - K H) A,  b = K y,  C = (I - K H) Q,
    eta = A^T H^T y / S,  J = A^T H^T H A / S. A masked step is the pure
    prediction element (A, 0, Q, 0, 0) — uniformly reached by zeroing K
    and H/S. The first element instead folds in the prior: it is built
    from the one-step predicted moments (m1p, P1p)."""
    y = jnp.where(mask[:, None], y, 0.0)  # masked y may be padding/NaN

    def generic(A_k, Q_k, y_k, obs):
        S = H @ Q_k @ H + R
        K = jnp.where(obs, Q_k @ H / S, jnp.zeros_like(H))
        A_el = A_k - jnp.outer(K, H) @ A_k
        b = jnp.outer(K, y_k)
        C = _sym(Q_k - jnp.outer(K, H) @ Q_k)
        HS = jnp.where(obs, H / S, jnp.zeros_like(H))
        AtHS = A_k.T @ HS
        eta = jnp.outer(AtHS, y_k)
        J = _sym(jnp.outer(AtHS, H @ A_k))
        return A_el, b, C, eta, J

    A_el, b, C, eta, J = jax.vmap(generic)(A, Q, y, mask)

    # first element: fold the prior through step 1's predict + update
    m1p = A[0] @ m0
    P1p = _sym(A[0] @ P0 @ A[0].T + Q[0])
    S1 = H @ P1p @ H + R
    K1 = jnp.where(mask[0], P1p @ H / S1, jnp.zeros_like(H))
    b1 = m1p + jnp.outer(K1, y[0] - H @ m1p)
    C1 = _sym(P1p - jnp.outer(K1, H) @ P1p)
    zero_d = jnp.zeros_like(A[0])
    A_el = A_el.at[0].set(zero_d)
    b = b.at[0].set(b1)
    C = C.at[0].set(C1)
    eta = eta.at[0].set(jnp.zeros_like(m0))
    J = J.at[0].set(zero_d)
    return A_el, b, C, eta, J


def _filter_op(a, b):
    """Associative filtering combine (eq. (6), docs/temporal.md): `a` is the
    earlier prefix, `b` the later element. Batched over a leading axis."""
    A1, b1, C1, e1, J1 = a
    A2, b2, C2, e2, J2 = b
    d = A1.shape[-1]
    I = jnp.eye(d, dtype=A1.dtype)
    # G = A2 (I + C1 J2)^-1, from the right via a transposed solve
    IpCJ = I + C1 @ J2
    G = jnp.swapaxes(
        jnp.linalg.solve(jnp.swapaxes(IpCJ, -1, -2), jnp.swapaxes(A2, -1, -2)),
        -1, -2)
    # Et^T = A1^T (I + J2 C1)^-1
    Et = jnp.linalg.solve(jnp.swapaxes(I + J2 @ C1, -1, -2), A1)
    EtT = jnp.swapaxes(Et, -1, -2)
    A_new = G @ A1
    b_new = G @ (b1 + C1 @ e2) + b2
    C_new = _sym(G @ C1 @ jnp.swapaxes(A2, -1, -2) + C2)
    e_new = EtT @ (e2 - J2 @ b1) + e1
    J_new = _sym(EtT @ J2 @ A1 + J1)
    return A_new, b_new, C_new, e_new, J_new


def kalman_filter(A: jax.Array, Q: jax.Array, H: jax.Array, R: jax.Array,
                  y: jax.Array, m0: jax.Array, P0: jax.Array, *,
                  mask: Optional[jax.Array] = None,
                  parallel: bool = True) -> FilterResult:
    """Kalman filter over N steps; `parallel=` picks associative scan
    (log depth) or the sequential `lax.scan` twin. See module docstring
    for shapes; `m0` is (d, D) (one column per output), `P0` (d, d)."""
    if mask is None:
        mask = jnp.ones(y.shape[0], dtype=bool)
    # one common dtype up front: f32 hyperparameters with f64 data would
    # otherwise promote mid-recursion (a lax.scan carry type error)
    dtype = jnp.result_type(A.dtype, Q.dtype, y.dtype, m0.dtype, P0.dtype)
    A, Q, y, m0, P0 = (x.astype(dtype) for x in (A, Q, y, m0, P0))
    H, R = jnp.asarray(H, dtype), jnp.asarray(R, dtype)
    if parallel:
        elems = _filter_elements(A, Q, H, R, y, mask, m0, P0)
        _, means, covs, _, _ = lax.associative_scan(_filter_op, elems)
    else:
        means, covs = _filter_sequential(A, Q, H, R, y, mask, m0, P0)
    y_eff = jnp.where(mask[:, None], y, 0.0)
    return FilterResult(means, covs,
                        _lml(A, Q, H, R, y_eff, mask, m0, P0, means, covs))


def _smooth_sequential(A, Q, means, covs):
    """Textbook RTS backward recursion under a reversed `lax.scan`."""

    def step(carry, inp):
        ms_next, Ps_next = carry
        m_k, P_k, A_next, Q_next = inp
        Pp = _sym(A_next @ P_k @ A_next.T + Q_next)
        G = jnp.linalg.solve(Pp, A_next @ P_k).T  # P_k A_next^T Pp^-1
        m = m_k + G @ (ms_next - A_next @ m_k)
        P = _sym(P_k + G @ (Ps_next - Pp) @ G.T)
        return (m, P), (m, P)

    init = (means[-1], covs[-1])
    _, (ms, Ps) = lax.scan(step, init,
                           (means[:-1], covs[:-1], A[1:], Q[1:]),
                           reverse=True)
    return (jnp.concatenate([ms, means[-1:]]),
            jnp.concatenate([Ps, covs[-1:]]))


def _smooth_elements(A, Q, means, covs):
    """Associative smoothing elements (E, g, L) (eq. (7), docs/temporal.md):
    for k < N the RTS gain triple, for k = N the filtered terminal."""

    def make(m_k, P_k, A_next, Q_next):
        Pp = _sym(A_next @ P_k @ A_next.T + Q_next)
        E = jnp.linalg.solve(Pp, A_next @ P_k).T
        g = m_k - E @ (A_next @ m_k)
        L = _sym(P_k - E @ Pp @ E.T)
        return E, g, L

    E, g, L = jax.vmap(make)(means[:-1], covs[:-1], A[1:], Q[1:])
    E = jnp.concatenate([E, jnp.zeros_like(E[-1:])])
    g = jnp.concatenate([g, means[-1:]])
    L = jnp.concatenate([L, covs[-1:]])
    return E, g, L


def _smooth_op(a, b):
    """Associative smoothing combine (eq. (8), docs/temporal.md). Under
    `associative_scan(..., reverse=True)` the first argument is the
    already-combined LATER suffix and the second the earlier element."""
    Ea, ga, La = a
    Eb, gb, Lb = b
    E = Eb @ Ea
    g = Eb @ ga + gb
    L = _sym(Eb @ La @ jnp.swapaxes(Eb, -1, -2) + Lb)
    return E, g, L


def rts_smoother(A: jax.Array, Q: jax.Array, means: jax.Array,
                 covs: jax.Array, *,
                 parallel: bool = True) -> Tuple[jax.Array, jax.Array]:
    """RTS smoother over filtered moments: (N, d, D) means, (N, d, d) covs
    -> same shapes, now conditioned on ALL observations. `A`/`Q` are the
    same per-step discretization the filter consumed."""
    if parallel:
        elems = _smooth_elements(A, Q, means, covs)
        _, ms, Ps = lax.associative_scan(_smooth_op, elems, reverse=True)
        return ms, Ps
    return _smooth_sequential(A, Q, means, covs)
