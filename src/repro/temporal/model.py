"""`TemporalGPRegression`: the state-space GP facade, plus the O(d^2)
`TemporalState` the serving tier ships.

The facade matches the `SparseGPRegression` surface (fit / elbo / predict /
posterior / export_state) but swaps the collapsed-bound engine for the
kernel->SDE->Kalman path of `repro.temporal.sde` / `repro.temporal.pskf`:
O(N d^3) work, O(N d^2) memory, EXACT inference (elbo() == lml() — the
"bound" is tight), and `parallel=` picks log-depth associative scans or
the sequential twin. Select it through `repro.gp.models.regression(...,
backend="temporal")` or construct it directly.

Serving: `export_state()` freezes the TERMINAL filtered state — kernel
hyperparameters, noise, last timestamp, m (d, D), P (d, d) — which is all
a forecaster needs. `forecast()` predicts the latent marginal at any
future timestamp in O(d^3) per row (rows independent, so `GPServer`'s
batch coalescing/padding apply unchanged), and `update_state()` folds new
observations by filtering forward from the stored terminal state: the
streamed state is EXACTLY the one-shot fit's (tested <= 1e-10), which is
what makes `serve.online` a true streaming forecaster. Timestamps earlier
than the forecast origin are answered with the origin's nowcast (dt
clamped to 0) — interpolation into the past needs the smoother and
therefore the training data, i.e. the facade's `predict`, not the served
state.
"""
from __future__ import annotations

import functools
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import inference
from repro.gp.kernels import Kernel, Matern32
from repro.temporal import pskf, sde

Params = Dict[str, jax.Array]

_OPTIMIZERS = ("adam", "lbfgs")


def _as_2d(Y: jax.Array) -> jax.Array:
    return Y[:, None] if Y.ndim == 1 else Y


def _as_times(X) -> jax.Array:
    """Accept (N,) timestamps or the facade-standard (N, 1) column."""
    X = jnp.asarray(X)
    if X.ndim == 2 and X.shape[1] == 1:
        return X[:, 0]
    if X.ndim == 1:
        return X
    raise ValueError(
        f"temporal models take 1-D inputs: X must be (N,) or (N, 1) "
        f"timestamps, got shape {X.shape}")


def _validate_times(t: jax.Array, *, what: str = "X") -> None:
    """Eager sort-order/duplicate validation (host-side, fit/update time)."""
    tn = np.asarray(t)
    if tn.size < 1:
        raise ValueError(f"{what} must contain at least one timestamp")
    d = np.diff(tn)
    if np.any(d < 0):
        i = int(np.argmax(d < 0))
        raise ValueError(
            f"{what} timestamps must be sorted ascending; {what}[{i + 1}] = "
            f"{tn[i + 1]!r} < {what}[{i}] = {tn[i]!r} (sort the series — the "
            f"Kalman recursion runs in time order)")
    if np.any(d == 0):
        i = int(np.argmax(d == 0))
        raise ValueError(
            f"duplicate timestamp in {what}: {what}[{i}] == {what}[{i + 1}] "
            f"== {tn[i]!r}; aggregate duplicate observations (e.g. average "
            f"them) before fitting — a zero gap makes the transition "
            f"degenerate (Q_k = 0)")


class TemporalState(NamedTuple):
    """Everything a fitted temporal GP needs to FORECAST and to keep
    learning online: O(d^2) regardless of how many points were absorbed.
    A pure pytree (jit-traceable, checkpointable) — the kernel object
    stays outside, exactly like `repro.serve.state.PosteriorState`."""

    kern: Params  # kernel hyperparameters (log-transformed)
    log_beta: jax.Array  # scalar log noise precision
    t_last: jax.Array  # scalar: the forecast origin (last absorbed time)
    m: jax.Array  # (d, D) terminal filtered state mean, one column per output
    P: jax.Array  # (d, d) terminal filtered state covariance
    n: jax.Array  # scalar: datapoints absorbed so far

    @property
    def d(self) -> int:
        return self.P.shape[-1]

    @property
    def D(self) -> int:
        return self.m.shape[-1]

    @property
    def nbytes(self) -> int:
        """Resident bytes of the state pytree — what `GPServer`'s LRU
        charges. Constant per registration: forecasting state never grows
        with the data absorbed."""
        return int(sum(x.nbytes for x in jax.tree_util.tree_leaves(self)))


def _require_sde(kernel: Kernel) -> None:
    if not kernel.supports_sde():
        raise ValueError(
            f"kernel {kernel!r} has no state-space (SDE) form: temporal "
            f"models need kernel.supports_sde() — matern12/matern32/"
            f"matern52 on input_dim=1, or Sum/Product of those. For other "
            f"kernels use SparseGPRegression (the collapsed bound).")


def forecast_closure(kernel: Kernel):
    """The (unjitted) marginal forecast epilogue closed over a kernel —
    the temporal analogue of `repro.serve.state._predict_closure`. Each
    row of Xt is an independent forecast from the stored terminal state
    (mean = H A(dt) m, var = H (A P A^T + Q) H^T), so batches need no
    ordering and `GPServer` padding/coalescing is safe; dt clamps at 0
    (see module docstring)."""

    def fn(state: TemporalState, Xt: jax.Array):
        model = kernel.to_sde(state.kern)
        dt = jnp.maximum(Xt[:, 0] - state.t_last, 0.0)
        A, Q = sde.discretize(model, dt)
        mean = jnp.einsum("i,bij,jd->bd", model.H, A, state.m)
        P = jnp.einsum("bij,jk,blk->bil", A, state.P, A) + Q
        var = jnp.einsum("i,bij,j->b", model.H, P, model.H)
        return mean, var

    return fn


@functools.lru_cache(maxsize=None)
def _forecast_fn(kernel: Kernel):
    return jax.jit(forecast_closure(kernel))


def forecast(kernel: Kernel, state: TemporalState,
             Xt: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Latent marginal forecast at Xt (B, 1) timestamps: mean (B, D) and
    variance (B,). O(B d^3); jitted per kernel."""
    return _forecast_fn(kernel)(state, jnp.asarray(Xt))


@functools.lru_cache(maxsize=None)
def _update_fn(kernel: Kernel):
    def core(state: TemporalState, t_new: jax.Array,
             Y_new: jax.Array) -> TemporalState:
        model = kernel.to_sde(state.kern)
        dt = jnp.concatenate([t_new[:1] - state.t_last, jnp.diff(t_new)])
        A, Q = sde.discretize(model, dt)
        res = pskf.kalman_filter(A, Q, model.H, jnp.exp(-state.log_beta),
                                 Y_new, state.m, state.P, parallel=False)
        return TemporalState(kern=state.kern, log_beta=state.log_beta,
                             t_last=t_new[-1], m=res.means[-1],
                             P=res.covs[-1],
                             n=state.n + t_new.shape[0])

    return jax.jit(core)


def update_state(kernel: Kernel, state: TemporalState, X_new,
                 Y_new) -> TemporalState:
    """Fold new observations into a served state by filtering forward from
    the stored terminal (m, P): O(B d^3), no access to past data, and the
    result is EXACTLY the state a one-shot fit over the concatenated
    series would produce (the Kalman recursion is the same arithmetic).
    New timestamps must be sorted and strictly after `state.t_last`."""
    t_new = _as_times(X_new)
    _validate_times(t_new, what="X_new")
    if float(np.asarray(t_new[0])) <= float(np.asarray(state.t_last)):
        raise ValueError(
            f"X_new must start strictly after the state's forecast origin "
            f"t_last = {float(np.asarray(state.t_last))!r}, got first new "
            f"timestamp {float(np.asarray(t_new[0]))!r}; a temporal state "
            f"only filters FORWARD (re-fit to revise the past)")
    Y_new = _as_2d(jnp.asarray(Y_new))
    if Y_new.shape[1] != state.D:
        raise ValueError(
            f"Y_new has {Y_new.shape[1]} output column(s), state carries "
            f"D={state.D}")
    return _update_fn(kernel)(state, t_new, Y_new)


class TemporalGPRegression:
    """Exact GP regression on 1-D (temporal) inputs via the state-space
    path: kernel -> LTI SDE -> Kalman filter/smoother, O(N) in the number
    of datapoints with no (N, N) — or even (N, M) — intermediate.

    Args:
      kernel: a kernel with `supports_sde()` (matern12/32/52 on 1-D input,
        or Sum/Product of those); default Matern32(1).
      parallel: True (default) runs filter and smoother as
        `jax.lax.associative_scan` associative operators (O(log N) depth —
        the paper's parallelization story applied along time); False runs
        the sequential `lax.scan` twin (same arithmetic, O(N) depth).

    Surface parity with `SparseGPRegression`: fit / elbo / predict /
    posterior / export_state (+ lml, the honest name here: the state-space
    likelihood is exact, so elbo() == lml()).
    """

    def __init__(self, kernel: Optional[Kernel] = None, *,
                 parallel: bool = True):
        self.kernel = kernel if kernel is not None else Matern32(1)
        _require_sde(self.kernel)
        self.parallel = bool(parallel)
        self.params: Optional[Params] = None
        self.history: list = []
        self._data: Optional[Tuple[jax.Array, jax.Array]] = None
        self._loss_cache = None  # (kernel, parallel, built loss)
        self._smooth_cache = None  # (kernel, parallel, built smoother core)

    # -- loss / smoother builders (jit-cached per kernel) --------------------

    def _build_loss(self):
        kernel, parallel = self.kernel, self.parallel

        def loss(params: Params, t: jax.Array, Y: jax.Array) -> jax.Array:
            model = kernel.to_sde(params["kern"])
            dt = jnp.concatenate([jnp.zeros_like(t[:1]), jnp.diff(t)])
            A, Q = sde.discretize(model, dt)
            m0 = jnp.zeros((model.d, Y.shape[1]), dtype=A.dtype)
            res = pskf.kalman_filter(A, Q, model.H,
                                     jnp.exp(-params["log_beta"]), Y, m0,
                                     model.Pinf, parallel=parallel)
            return -res.lml / t.shape[0]

        return loss

    def _loss_fn(self):
        key = (self.kernel, self.parallel)
        if self._loss_cache is None or self._loss_cache[0] != key:
            self._loss_cache = (key, self._build_loss())
        return self._loss_cache[1]

    def _build_smooth(self):
        """Smoothed latent marginals over a merged (train + query) timeline:
        (params, t_all, Y_all, mask) -> (mean (N_all, D), var (N_all,)).
        Masked steps carry no observation — that is how query timestamps
        interpolate exactly."""
        kernel, parallel = self.kernel, self.parallel

        def smooth(params: Params, t_all, Y_all, mask):
            model = kernel.to_sde(params["kern"])
            dt = jnp.concatenate([jnp.zeros_like(t_all[:1]), jnp.diff(t_all)])
            A, Q = sde.discretize(model, dt)
            m0 = jnp.zeros((model.d, Y_all.shape[1]), dtype=A.dtype)
            res = pskf.kalman_filter(A, Q, model.H,
                                     jnp.exp(-params["log_beta"]), Y_all, m0,
                                     model.Pinf, mask=mask, parallel=parallel)
            ms, Ps = pskf.rts_smoother(A, Q, res.means, res.covs,
                                       parallel=parallel)
            mean = jnp.einsum("i,nid->nd", model.H, ms)
            var = jnp.einsum("i,nij,j->n", model.H, Ps, model.H)
            return mean, var

        return smooth

    def _smooth_fn(self):
        key = (self.kernel, self.parallel)
        if self._smooth_cache is None or self._smooth_cache[0] != key:
            self._smooth_cache = (key, jax.jit(self._build_smooth()))
        return self._smooth_cache[1]

    # -- SparseGPRegression-parity surface -----------------------------------

    def init_params(self, X, Y, *, log_beta: float = 2.0) -> Params:
        t = _as_times(X)
        return {
            "kern": self.kernel.init(),
            "log_beta": jnp.asarray(log_beta, t.dtype),
        }

    def _require_fitted(self):
        if self.params is None:
            raise RuntimeError(
                f"{type(self).__name__} is not fitted yet — call .fit() first")

    def fit(self, X, Y, *, optimizer: str = "adam", steps: int = 300,
            lr: float = 3e-2, log_every: int = 0,
            params: Optional[Params] = None) -> "TemporalGPRegression":
        """Maximize the EXACT log marginal likelihood over kernel
        hyperparameters + noise with the shared optimizer drivers
        (`repro.core.inference.fit_adam` / `fit_lbfgs`). X must be sorted,
        duplicate-free timestamps ((N,) or (N, 1)); Y is (N,) or (N, D)."""
        t = _as_times(X)
        _validate_times(t)
        Y = _as_2d(jnp.asarray(Y))
        if Y.shape[0] != t.shape[0]:
            raise ValueError(f"X has {t.shape[0]} rows, Y has {Y.shape[0]}")
        if params is None:
            params = self.init_params(t, Y)
        self._data = (t, Y)
        loss = self._loss_fn()
        if optimizer == "adam":
            self.params, self.history = inference.fit_adam(
                loss, params, (t, Y), steps=steps, lr=lr, log_every=log_every)
        elif optimizer == "lbfgs":
            self.params, final = inference.fit_lbfgs(loss, params, (t, Y),
                                                     maxiter=steps)
            self.history = [final]
        else:
            raise ValueError(
                f"optimizer must be one of {_OPTIMIZERS}, got {optimizer!r}")
        return self

    def lml(self) -> float:
        """Exact log marginal likelihood (total) on the training data."""
        self._require_fitted()
        t, Y = self._data
        return float(-self._loss_fn()(self.params, t, Y) * t.shape[0])

    def elbo(self) -> float:
        """Surface parity with SparseGPRegression; the state-space
        likelihood is exact, so the 'bound' is tight: elbo() == lml()."""
        return self.lml()

    def predict(self, Xt, *, parallel: Optional[bool] = None):
        """Exact posterior latent marginals at Xt: mean (B, D), var (B,).

        Query timestamps may be in any order and may coincide with training
        timestamps: they are merged into the training timeline as MASKED
        (observation-free) steps, filtered + smoothed, and mapped back —
        interpolation and extrapolation are both exact, matching the dense
        O(N^3) GP posterior (tests pin <= 1e-6 at N=512)."""
        self._require_fitted()
        if parallel is not None and bool(parallel) != self.parallel:
            # rebuild on a different scan path without clobbering the cache
            clone = TemporalGPRegression(self.kernel, parallel=parallel)
            clone.params, clone._data = self.params, self._data
            return clone.predict(Xt)
        t_test = _as_times(Xt)
        t, Y = self._data
        # merge: stable argsort keeps train entries ahead of coincident
        # queries, so a query AT a training time smooths (dt = 0 step)
        t_all = jnp.concatenate([t, t_test])
        order = jnp.argsort(t_all, stable=True)
        mask = jnp.concatenate([
            jnp.ones(t.shape[0], dtype=bool),
            jnp.zeros(t_test.shape[0], dtype=bool)])[order]
        Y_all = jnp.concatenate(
            [Y, jnp.zeros((t_test.shape[0], Y.shape[1]), Y.dtype)])[order]
        mean_all, var_all = self._smooth_fn()(self.params, t_all[order],
                                              Y_all, mask)
        # scatter back: positions of the query rows in the merged timeline
        inv = jnp.argsort(order, stable=True)[t.shape[0]:]
        return mean_all[inv], var_all[inv]

    def posterior(self) -> Tuple[jax.Array, jax.Array]:
        """Smoothed latent marginals AT the training timestamps:
        (mean (N, D), var (N,)). The temporal analogue of
        `SparseGPRegression.posterior()` — here the posterior is exact."""
        self._require_fitted()
        t, Y = self._data
        mask = jnp.ones(t.shape[0], dtype=bool)
        return self._smooth_fn()(self.params, t, Y, mask)

    def export_state(self) -> TemporalState:
        """Freeze the fitted model into the O(d^2) `TemporalState` the
        serving tier ships: terminal filtered moments + hyperparameters.
        `repro.serve` predicts (forecasts) from it and folds new
        observations in via `update_state` without the training data."""
        self._require_fitted()
        t, Y = self._data
        loss_params = self.params
        model = self.kernel.to_sde(loss_params["kern"])
        dt = jnp.concatenate([jnp.zeros_like(t[:1]), jnp.diff(t)])
        A, Q = sde.discretize(model, dt)
        m0 = jnp.zeros((model.d, Y.shape[1]), dtype=A.dtype)
        res = pskf.kalman_filter(A, Q, model.H,
                                 jnp.exp(-loss_params["log_beta"]), Y, m0,
                                 model.Pinf, parallel=self.parallel)
        return TemporalState(kern=loss_params["kern"],
                             log_beta=loss_params["log_beta"], t_last=t[-1],
                             m=res.means[-1], P=res.covs[-1],
                             n=jnp.asarray(t.shape[0], t.dtype))
