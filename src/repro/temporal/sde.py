"""Kernel -> LTI SDE conversion for the state-space (temporal) GP backend.

A stationary 1-D GP prior f(t) ~ GP(0, k(t - t')) with a rational spectral
density is EXACTLY the stationary distribution of a linear time-invariant
stochastic differential equation

    dx(t) = F x(t) dt + L dW(t),     f(t) = H x(t),          (Sarkka & al.)

with state dimension d (1 for Matern-1/2, 2 for 3/2, 3 for 5/2). The
stationary covariance P_inf solves the Lyapunov equation

    F P_inf + P_inf F^T + L q L^T = 0,

and the kernel is recovered as k(tau) = H expm(F tau) P_inf H^T for
tau >= 0 (tested against `Kernel.K` in tests/test_temporal.py). Between
observation times the SDE discretizes exactly:

    A_k = expm(F dt_k),     Q_k = P_inf - A_k P_inf A_k^T,

where the stationary shortcut for Q_k (instead of the integral of
e^{F s} L q L^T e^{F^T s}) is an identity of the Lyapunov equation — it is
what lets Sum/Product compositions discretize without a closed-form
continuous-time noise integral.

Compositions mirror `repro.gp.kernels.Sum` / `Product`:

    sum:     F, Qc, P_inf block-diagonal; H concatenated      (f = f1 + f2)
    product: F = F1 (+) F2 (Kronecker sum), H = H1 (x) H2,
             P_inf = P1 (x) P2, Qc = Qc1 (x) P2 + P1 (x) Qc2

since expm((F1 (+) F2) tau) = expm(F1 tau) (x) expm(F2 tau) makes
H expm(F tau) P_inf H^T factor into k1(tau) * k2(tau).

This module is deliberately kernel-class-free (plain array builders), so
`repro.gp.kernels` can lazily import it from the `to_sde()` hooks without
an import cycle.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class LTISDE(NamedTuple):
    """The LTI SDE behind a stationary kernel (see module docstring).

    `L` is the (d, w) noise loading of leaf/sum models; Kronecker products
    mix the white-noise channels, so composite `product` models carry
    `L=None` and only the full diffusion matrix `Qc = L q L^T` — the
    discretization (and everything downstream) needs only `Qc`.
    """

    F: jax.Array  # (d, d) drift
    H: jax.Array  # (d,)   observation row: f(t) = H x(t)
    Pinf: jax.Array  # (d, d) stationary covariance
    Qc: jax.Array  # (d, d) diffusion L q L^T
    L: Optional[jax.Array] = None  # (d, w) noise loading, when meaningful

    @property
    def d(self) -> int:
        return self.F.shape[-1]


def _scalar(x: jax.Array) -> jax.Array:
    """ARD-shaped (1,) lengthscales and scalars both become 0-d."""
    return jnp.reshape(jnp.asarray(x), ())


def matern12_sde(variance: jax.Array, lengthscale: jax.Array) -> LTISDE:
    """Matern nu=1/2 (Ornstein-Uhlenbeck): lam = 1/l, q = 2 sigma^2 lam."""
    var, lam = _scalar(variance), 1.0 / _scalar(lengthscale)
    one = jnp.ones_like(var)
    F = (-lam * one)[None, None]
    q = 2.0 * var * lam
    return LTISDE(F=F, H=jnp.stack([one]), Pinf=var[None, None],
                  Qc=q[None, None], L=one[None, None])


def matern32_sde(variance: jax.Array, lengthscale: jax.Array) -> LTISDE:
    """Matern nu=3/2: lam = sqrt(3)/l, q = 4 sigma^2 lam^3."""
    var, ls = _scalar(variance), _scalar(lengthscale)
    lam = jnp.sqrt(3.0) / ls
    zero, one = jnp.zeros_like(var), jnp.ones_like(var)
    F = jnp.stack([jnp.stack([zero, one]),
                   jnp.stack([-(lam**2), -2.0 * lam])])
    q = 4.0 * var * lam**3
    Qc = jnp.stack([jnp.stack([zero, zero]), jnp.stack([zero, q])])
    Pinf = jnp.stack([jnp.stack([var, zero]),
                      jnp.stack([zero, var * lam**2])])
    return LTISDE(F=F, H=jnp.stack([one, zero]), Pinf=Pinf, Qc=Qc,
                  L=jnp.stack([zero, one])[:, None])


def matern52_sde(variance: jax.Array, lengthscale: jax.Array) -> LTISDE:
    """Matern nu=5/2: lam = sqrt(5)/l, q = 16/3 sigma^2 lam^5."""
    var, ls = _scalar(variance), _scalar(lengthscale)
    lam = jnp.sqrt(5.0) / ls
    zero, one = jnp.zeros_like(var), jnp.ones_like(var)
    F = jnp.stack([
        jnp.stack([zero, one, zero]),
        jnp.stack([zero, zero, one]),
        jnp.stack([-(lam**3), -3.0 * lam**2, -3.0 * lam]),
    ])
    q = var * lam**5 * (16.0 / 3.0)
    Qc = jnp.zeros_like(F).at[2, 2].set(q)
    kappa = var * lam**2 / 3.0  # -E[f(t) f''(t)], the (0,2) cross moment
    Pinf = jnp.stack([
        jnp.stack([var, zero, -kappa]),
        jnp.stack([zero, kappa, zero]),
        jnp.stack([-kappa, zero, var * lam**4]),
    ])
    return LTISDE(F=F, H=jnp.stack([one, zero, zero]), Pinf=Pinf, Qc=Qc,
                  L=jnp.stack([zero, zero, one])[:, None])


def _block_diag(blocks: Tuple[jax.Array, ...]) -> jax.Array:
    return jax.scipy.linalg.block_diag(*blocks)


def sum_sde(*parts: LTISDE) -> LTISDE:
    """f = sum_i f_i with independent part states: everything block-diagonal,
    H concatenated. k_sum(tau) = sum_i k_i(tau) follows directly."""
    L = None
    if all(p.L is not None for p in parts):
        L = _block_diag(tuple(p.L for p in parts))
    return LTISDE(
        F=_block_diag(tuple(p.F for p in parts)),
        H=jnp.concatenate([p.H for p in parts]),
        Pinf=_block_diag(tuple(p.Pinf for p in parts)),
        Qc=_block_diag(tuple(p.Qc for p in parts)),
        L=L,
    )


def _product_pair(a: LTISDE, b: LTISDE) -> LTISDE:
    """Kronecker composition: expm((F1 (+) F2) t) = expm(F1 t) (x) expm(F2 t)
    makes H expm(F tau) Pinf H^T = k1(tau) k2(tau). Qc follows from the
    Lyapunov identity Qc = -(F Pinf + Pinf F^T) applied to the composite."""
    Ia = jnp.eye(a.d, dtype=a.F.dtype)
    Ib = jnp.eye(b.d, dtype=b.F.dtype)
    return LTISDE(
        F=jnp.kron(a.F, Ib) + jnp.kron(Ia, b.F),
        H=jnp.kron(a.H, b.H),
        Pinf=jnp.kron(a.Pinf, b.Pinf),
        Qc=jnp.kron(a.Qc, b.Pinf) + jnp.kron(a.Pinf, b.Qc),
        L=None,
    )


def product_sde(*parts: LTISDE) -> LTISDE:
    out = parts[0]
    for p in parts[1:]:
        out = _product_pair(out, p)
    return out


def discretize(sde: LTISDE, dt: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Exact discretization over gaps `dt` (N,): A (N, d, d), Q (N, d, d).

    A_k = expm(F dt_k); Q_k = Pinf - A_k Pinf A_k^T uses the stationary
    shortcut (exact — see module docstring), which also makes Q_k PSD by
    construction and gives dt = 0 -> (A, Q) = (I, 0) so repeated/padded
    timestamps cost nothing. Differentiable and vmap/jit-safe (jax's expm
    is Pade + scaling-squaring in lax ops).
    """
    dt = jnp.asarray(dt)
    # promote BEFORE the arithmetic: a mixed f32 Pinf / f64 A einsum is not
    # bit-stable across jit vs eager, which would break streamed == one-shot
    # parity (f32 hyperparameters with f64 timestamps is the default setup)
    dtype = jnp.result_type(sde.F.dtype, dt.dtype)
    F, Pinf = sde.F.astype(dtype), sde.Pinf.astype(dtype)
    A = jax.vmap(jax.scipy.linalg.expm)(F[None] * dt[:, None, None])
    Q = Pinf[None] - jnp.einsum("nij,jk,nlk->nil", A, Pinf, A)
    return A, 0.5 * (Q + jnp.swapaxes(Q, -1, -2))
