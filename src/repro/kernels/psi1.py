"""Pallas TPU kernel: Psi1 statistic of the Bayesian GP-LVM (paper §3).

    Psi1[n,m] = sigma^2 prod_q (1 + S_nq/l_q^2)^(-1/2)
                exp(-0.5 (mu_nq - z_mq)^2 / (l_q^2 + S_nq))

TPU adaptation — the CUDA version (paper Table 1) loops a thread over
(n, m, q). Here the n-dependent denominator d_nq = l_q^2 + S_nq is factored
so the whole exponent becomes MXU matmuls over the Q contraction:

    (mu-z)^2 / d  =  mu^2/d  -  2 (mu/d) z  +  (1/d) z^2
    expo[n,m]     =  c_n  -  2 (mu*b)[n,:] @ Z^T[:,m]  +  b[n,:] @ (Z^2)^T[:,m]

with b = 1/d, c_n = sum_q mu^2 b. No (TILE_N, TILE_M, Q) broadcast tensor
ever exists — the kernel is two (TILE_N, Q) x (Q, TILE_M) MXU contractions
plus VPU row terms, which is also what makes large-Q GP heads viable.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.suffstats import _psi1_tile

TILE_N = 256
TILE_M = 128


def _psi1_kernel(mu_ref, s_ref, z_ref, l2_ref, o_ref, *, ct=jnp.float32):
    mu = mu_ref[...].astype(ct)  # (TILE_N, Q)
    S = s_ref[...].astype(ct)  # (TILE_N, Q)
    Z = z_ref[...].astype(ct)  # (TILE_M, Q)
    l2 = l2_ref[...].astype(ct)  # (1, Q)

    # the shared tile helper of the fused forward/reverse kernels — the
    # single-statistic op evaluates the identical expression, so the psi1
    # formula exists in exactly one place
    _, blk = _psi1_tile(mu, S, Z, l2, ct=ct)
    o_ref[...] = blk.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "block"))
def psi1_pallas(
    mu: jax.Array,
    S: jax.Array,
    Z: jax.Array,
    variance: jax.Array,
    lengthscale: jax.Array,
    *,
    interpret: bool = False,
    block: tuple | None = None,
) -> jax.Array:
    # `block=(tile_n, tile_m)` overrides the module-constant tiles (the
    # repro.tune knob); the wrapper pads to the block's multiple, so every
    # candidate is numerically identical to the defaults.
    tile_n, tile_m = block if block is not None else (TILE_N, TILE_M)
    N, Q = mu.shape
    M = Z.shape[0]
    dtype = mu.dtype
    # compiled TPU execution computes in float32; interpret mode computes in
    # the input dtype promoted to at least f32 (same policy as the fused
    # suffstats kernel) so f64 parity tests exercise the kernel body itself
    ct = jnp.promote_types(dtype, jnp.float32) if interpret else jnp.float32
    pad_n = (-N) % tile_n
    pad_m = (-M) % tile_m
    mu_p = jnp.pad(mu.astype(ct), ((0, pad_n), (0, 0)))
    # pad S with 1.0: any positive value keeps log1p/division well-defined
    S_p = jnp.pad(S.astype(ct), ((0, pad_n), (0, 0)), constant_values=1.0)
    Z_p = jnp.pad(Z.astype(ct), ((0, pad_m), (0, 0)))
    l2 = (lengthscale.astype(ct) ** 2)[None, :]  # (1, Q)

    grid = (mu_p.shape[0] // tile_n, Z_p.shape[0] // tile_m)
    out = pl.pallas_call(
        functools.partial(_psi1_kernel, ct=ct),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_n, Q), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_n, Q), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_m, Q), lambda i, j: (j, 0)),
            pl.BlockSpec((1, Q), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile_n, tile_m), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mu_p.shape[0], Z_p.shape[0]), ct),
        interpret=interpret,
    )(mu_p, S_p, Z_p, l2)
    return (variance * out[:N, :M]).astype(dtype)
