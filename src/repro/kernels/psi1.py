"""Pallas TPU kernel: Psi1 statistic of the Bayesian GP-LVM (paper §3).

    Psi1[n,m] = sigma^2 prod_q (1 + S_nq/l_q^2)^(-1/2)
                exp(-0.5 (mu_nq - z_mq)^2 / (l_q^2 + S_nq))

TPU adaptation — the CUDA version (paper Table 1) loops a thread over
(n, m, q). Here the n-dependent denominator d_nq = l_q^2 + S_nq is factored
so the whole exponent becomes MXU matmuls over the Q contraction:

    (mu-z)^2 / d  =  mu^2/d  -  2 (mu/d) z  +  (1/d) z^2
    expo[n,m]     =  c_n  -  2 (mu*b)[n,:] @ Z^T[:,m]  +  b[n,:] @ (Z^2)^T[:,m]

with b = 1/d, c_n = sum_q mu^2 b. No (TILE_N, TILE_M, Q) broadcast tensor
ever exists — the kernel is two (TILE_N, Q) x (Q, TILE_M) MXU contractions
plus VPU row terms, which is also what makes large-Q GP heads viable.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_N = 256
TILE_M = 128


def _psi1_kernel(mu_ref, s_ref, z_ref, l2_ref, o_ref):
    mu = mu_ref[...].astype(jnp.float32)  # (TILE_N, Q)
    S = s_ref[...].astype(jnp.float32)  # (TILE_N, Q)
    Z = z_ref[...].astype(jnp.float32)  # (TILE_M, Q)
    l2 = l2_ref[...].astype(jnp.float32)  # (1, Q)

    b = 1.0 / (l2 + S)  # (TILE_N, Q)
    lognorm = -0.5 * jnp.sum(jnp.log1p(S / l2), axis=-1, keepdims=True)  # (TILE_N, 1)
    c = jnp.sum(mu * mu * b, axis=-1, keepdims=True)  # (TILE_N, 1)
    mub_zt = jax.lax.dot_general(
        mu * b, Z, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (TILE_N, TILE_M)  MXU
    b_z2t = jax.lax.dot_general(
        b, Z * Z, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (TILE_N, TILE_M)  MXU
    expo = -0.5 * (c - 2.0 * mub_zt + b_z2t)
    o_ref[...] = jnp.exp(lognorm + expo).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def psi1_pallas(
    mu: jax.Array,
    S: jax.Array,
    Z: jax.Array,
    variance: jax.Array,
    lengthscale: jax.Array,
    *,
    interpret: bool = False,
) -> jax.Array:
    N, Q = mu.shape
    M = Z.shape[0]
    dtype = mu.dtype
    pad_n = (-N) % TILE_N
    pad_m = (-M) % TILE_M
    mu_p = jnp.pad(mu.astype(jnp.float32), ((0, pad_n), (0, 0)))
    # pad S with 1.0: any positive value keeps log1p/division well-defined
    S_p = jnp.pad(S.astype(jnp.float32), ((0, pad_n), (0, 0)), constant_values=1.0)
    Z_p = jnp.pad(Z.astype(jnp.float32), ((0, pad_m), (0, 0)))
    l2 = (lengthscale.astype(jnp.float32) ** 2)[None, :]  # (1, Q)

    grid = (mu_p.shape[0] // TILE_N, Z_p.shape[0] // TILE_M)
    out = pl.pallas_call(
        _psi1_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_N, Q), lambda i, j: (i, 0)),
            pl.BlockSpec((TILE_N, Q), lambda i, j: (i, 0)),
            pl.BlockSpec((TILE_M, Q), lambda i, j: (j, 0)),
            pl.BlockSpec((1, Q), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((TILE_N, TILE_M), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mu_p.shape[0], Z_p.shape[0]), jnp.float32),
        interpret=interpret,
    )(mu_p, S_p, Z_p, l2)
    return (variance * out[:N, :M]).astype(dtype)
