"""Differentiable jit'd wrappers around the Pallas psi-statistic kernels.

Forward = Pallas kernel (interpret-mode on CPU, compiled on TPU).
Backward of the single-statistic kernels = memory-lean jnp (jax.vjp of the
ref formulas, chunked where needed). Backward of the fused `suffstats` op =
the HAND-DERIVED reverse pass (kernels/suffstats.py, the paper's Table-2
gradient loops expressed as closed-form reverse rules), dispatched by a
`bwd_backend` knob:

  * ``"auto"``   (default) — mirror the forward's three-way dispatch: the
    Pallas reverse kernel compiled on TPU, the same kernel body in interpret
    mode off-TPU for small N, and the streaming-jnp reverse scan off-TPU for
    large N. This is the only knob value callers normally need.
  * ``"pallas"`` — force the Pallas reverse kernel (interpret off-TPU even
    at large N: slow, for validation).
  * ``"jnp"``    — force the streaming-jnp reverse scan everywhere.

`INTERPRET` flips automatically: True off-TPU so the whole test/bench suite
exercises the real kernel bodies on CPU. Because interpret mode pays a
Python-level cost per grid point, the fused `suffstats` op only runs the
kernel bodies off-TPU up to `FUSED_INTERPRET_MAX_N` datapoints; beyond that
it switches to the numerically-matching streaming-jnp twins.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.kfu import kfu_pallas
from repro.kernels.psi1 import psi1_pallas
from repro.kernels.psi2 import psi2_pallas
from repro.kernels.suffstats import (
    suffstats_bwd_pallas,
    suffstats_fused_jnp,
    suffstats_pallas,
    suffstats_vjp_jnp,
)

INTERPRET = jax.default_backend() != "tpu"

# off-TPU, run the real fused kernel body (interpret mode) only for problems
# small enough that per-grid-point interpretation stays cheap
FUSED_INTERPRET_MAX_N = 1024


# ---------------------------------------------------------------------------
# kfu
# ---------------------------------------------------------------------------

@jax.custom_vjp
def kfu(X, Z, variance, lengthscale):
    return kfu_pallas(X, Z, variance, lengthscale, interpret=INTERPRET)


def _kfu_fwd(X, Z, variance, lengthscale):
    return kfu(X, Z, variance, lengthscale), (X, Z, variance, lengthscale)


def _kfu_bwd(res, g):
    _, vjp = jax.vjp(ref.kfu_rbf, *res)
    return vjp(g)


kfu.defvjp(_kfu_fwd, _kfu_bwd)


# ---------------------------------------------------------------------------
# psi1
# ---------------------------------------------------------------------------

@jax.custom_vjp
def psi1(mu, S, Z, variance, lengthscale):
    return psi1_pallas(mu, S, Z, variance, lengthscale, interpret=INTERPRET)


def _psi1_fwd(mu, S, Z, variance, lengthscale):
    return psi1(mu, S, Z, variance, lengthscale), (mu, S, Z, variance, lengthscale)


def _psi1_bwd(res, g):
    _, vjp = jax.vjp(ref.psi1_rbf, *res)
    return vjp(g)


psi1.defvjp(_psi1_fwd, _psi1_bwd)


# ---------------------------------------------------------------------------
# psi2
# ---------------------------------------------------------------------------

def _psi2_ref_chunked(mu, S, Z, variance, lengthscale):
    # import here to avoid a core<->kernels import cycle at module load
    from repro.core.psi_stats import _psi2_rbf_chunked

    return _psi2_rbf_chunked(mu, S, Z, variance, lengthscale)


@jax.custom_vjp
def psi2(mu, S, Z, variance, lengthscale):
    return psi2_pallas(mu, S, Z, variance, lengthscale, interpret=INTERPRET)


def _psi2_fwd(mu, S, Z, variance, lengthscale):
    return psi2(mu, S, Z, variance, lengthscale), (mu, S, Z, variance, lengthscale)


def _psi2_bwd(res, g):
    # chunked reverse pass: O(chunk * M^2) live memory, like the forward
    _, vjp = jax.vjp(_psi2_ref_chunked, *res)
    return vjp(g)


psi2.defvjp(_psi2_fwd, _psi2_bwd)


# ---------------------------------------------------------------------------
# fused suffstats (psi2 + psiY in one pass over N)
# ---------------------------------------------------------------------------

BWD_BACKENDS = ("auto", "pallas", "jnp")


def _suffstats_impl(mu, S, Y, Z, variance, lengthscale):
    if not INTERPRET:
        return suffstats_pallas(mu, S, Y, Z, variance, lengthscale,
                                interpret=False)
    if mu.shape[0] <= FUSED_INTERPRET_MAX_N:
        return suffstats_pallas(mu, S, Y, Z, variance, lengthscale,
                                interpret=True)
    return suffstats_fused_jnp(mu, S, Y, Z, variance, lengthscale)


def _suffstats_bwd_dispatch(bwd_backend, res, g2, gY):
    """Reverse-pass dispatch, mirroring the forward's three-way split."""
    if bwd_backend == "jnp":
        return suffstats_vjp_jnp(*res, g2, gY)
    if bwd_backend == "pallas":
        return suffstats_bwd_pallas(*res, g2, gY, interpret=INTERPRET)
    if not INTERPRET:
        return suffstats_bwd_pallas(*res, g2, gY, interpret=False)
    if res[0].shape[0] <= FUSED_INTERPRET_MAX_N:
        return suffstats_bwd_pallas(*res, g2, gY, interpret=True)
    return suffstats_vjp_jnp(*res, g2, gY)


@functools.lru_cache(maxsize=None)
def _make_suffstats_op(bwd_backend: str):
    """One custom_vjp op per bwd_backend value (the knob must be static at
    trace time, so it selects among cached op instances rather than riding
    the traced arguments)."""

    @jax.custom_vjp
    def op(mu, S, Y, Z, variance, lengthscale):
        return _suffstats_impl(mu, S, Y, Z, variance, lengthscale)

    def fwd(mu, S, Y, Z, variance, lengthscale):
        out = op(mu, S, Y, Z, variance, lengthscale)
        return out, (mu, S, Y, Z, variance, lengthscale)

    def bwd(res, g):
        g2, gY = g
        return _suffstats_bwd_dispatch(bwd_backend, res, g2, gY)

    op.defvjp(fwd, bwd)
    return op


def suffstats(mu, S, Y, Z, variance, lengthscale, *, bwd_backend: str = "auto"):
    """Fused (psi2 (M, M), psiY (M, D)) with a hand-derived O(chunk * M^2)
    reverse pass — usable under jax.grad inside training steps.

    `bwd_backend` selects the reverse-pass implementation ("auto" | "pallas"
    | "jnp", see module docstring); the forward dispatch is unaffected.
    """
    if bwd_backend not in BWD_BACKENDS:
        raise ValueError(
            f"bwd_backend must be one of {BWD_BACKENDS}, got {bwd_backend!r}")
    return _make_suffstats_op(bwd_backend)(mu, S, Y, Z, variance, lengthscale)
