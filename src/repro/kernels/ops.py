"""Differentiable jit'd wrappers around the Pallas psi-statistic kernels.

Forward = Pallas kernel (interpret-mode on CPU, compiled on TPU). Backward =
the HAND-DERIVED reverse passes (kernels/suffstats.py, the paper's Table-2
gradient loops expressed as closed-form reverse rules) for the fused
`suffstats` op AND the single-statistic ops (`kfu`/`psi1`/`psi2` specialize
the fused rules — see docs/derivations/suffstats_vjp.md). Every op's
reverse-pass implementation is selected by a static `bwd_backend` knob:

  * ``"auto"``   (default) — mirror the forward's three-way dispatch: the
    Pallas reverse kernel compiled on TPU, the same kernel body in interpret
    mode off-TPU for small N, and the streaming-jnp reverse scan off-TPU for
    large N. This is the only knob value callers normally need.
  * ``"pallas"`` — force the Pallas reverse kernel (interpret off-TPU even
    at large N: slow, for validation).
  * ``"jnp"``    — force the streaming-jnp reverse scan everywhere.

Tile selection: every entry point resolves its forward and reverse block
configuration through the `repro.tune` autotuner (`tune.best_blocks`) unless
the caller pins `block=`/`bwd_block=` explicitly. With tuning disabled and a
cold cache that resolution returns None — the kernels' module-constant tiles
— at dict-lookup cost; with a tuned cache the measured winner is baked into
the (bounded, per-knob) cached custom_vjp op.

`interpret_mode()` flips automatically: True off-TPU so the whole test/bench
suite exercises the real kernel bodies on CPU. It reads the backend at call
time (import-time freezing would mis-dispatch after a test fixture or
`jax.config` forces a platform post-import); `_INTERPRET_OVERRIDE` is the
test-visible override. Because interpret mode pays a Python-level cost per
grid point, the reverse dispatch only runs the kernel bodies off-TPU up to
`fused_interpret_max_n()` datapoints; beyond that it switches to the
numerically-matching streaming-jnp twins.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax

from repro.kernels.kfu import kfu_pallas
from repro.kernels.psi1 import psi1_pallas
from repro.kernels.psi2 import psi2_pallas
from repro.kernels.suffstats import (
    kfu_bwd_pallas,
    kfu_vjp_jnp,
    psi1_bwd_pallas,
    psi1_vjp_jnp,
    psi2_bwd_pallas,
    psi2_vjp_jnp,
    suffstats_bwd_pallas,
    suffstats_fused_jnp,
    suffstats_pallas,
    suffstats_vjp_jnp,
)

# Test-visible override for `interpret_mode()`: None = detect from the
# backend at call time; True/False force a path (restore to None after).
_INTERPRET_OVERRIDE: bool | None = None


def interpret_mode() -> bool:
    """Whether the Pallas kernel bodies should run in interpret mode.

    Read at CALL time, not import time: `jax.default_backend()` is itself
    cached by jax and invalidated when the platform config changes, so a
    test fixture (or `jax.config.update("jax_platform_name", ...)`) that
    forces a backend after this module imports still dispatches the right
    kernel path.
    """
    if _INTERPRET_OVERRIDE is not None:
        return bool(_INTERPRET_OVERRIDE)
    return jax.default_backend() != "tpu"


# off-TPU, run the real kernel bodies (interpret mode) only for problems
# small enough that per-grid-point interpretation stays cheap. The shipped
# default; a per-host measured value can override it through the tune cache
# (key ``interpret_max_n|<backend>``), and `_INTERPRET_MAX_N_OVERRIDE` is
# the test hook that wins over both.
DEFAULT_FUSED_INTERPRET_MAX_N = 1024

_INTERPRET_MAX_N_OVERRIDE: int | None = None


def fused_interpret_max_n() -> int:
    """The off-accelerator interpret-vs-streaming dispatch threshold, read
    at CALL time: test override > tune-cache entry > shipped default."""
    if _INTERPRET_MAX_N_OVERRIDE is not None:
        return int(_INTERPRET_MAX_N_OVERRIDE)
    from repro import tune

    cached = tune.cached_interpret_max_n()
    if cached is not None:
        return int(cached)
    return DEFAULT_FUSED_INTERPRET_MAX_N


def __getattr__(name: str):
    # back-compat: both used to be import-time module constants; keep the
    # attributes readable but always call-time fresh
    if name == "INTERPRET":
        return interpret_mode()
    if name == "FUSED_INTERPRET_MAX_N":
        return fused_interpret_max_n()
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


BWD_BACKENDS = ("auto", "pallas", "jnp")


def _check_bwd_backend(bwd_backend: str) -> None:
    if bwd_backend not in BWD_BACKENDS:
        raise ValueError(
            f"bwd_backend must be one of {BWD_BACKENDS}, got {bwd_backend!r}")


def _bwd_dispatch(bwd_backend, n, pallas_fn, jnp_fn):
    """The shared three-way reverse dispatch (mirrors the forward's split):
    `pallas_fn(interpret)` runs a Pallas reverse kernel, `jnp_fn()` the
    streaming-jnp twin. Every op's custom_vjp backward routes through here.
    """
    if bwd_backend == "jnp":
        return jnp_fn()
    if bwd_backend == "pallas":
        return pallas_fn(interpret_mode())
    if not interpret_mode():
        return pallas_fn(False)
    if n <= fused_interpret_max_n():
        return pallas_fn(True)
    return jnp_fn()


# ---------------------------------------------------------------------------
# tuned-block resolution + op-factory cache policy
# ---------------------------------------------------------------------------

# Each (bwd_backend, block, bwd_block) knob combination owns one cached
# custom_vjp op (the knobs must be static at trace time). Bounded: an
# autotuner exploring many block candidates through these entry points must
# not grow an unbounded op population — LRU keeps the working set.
_OP_CACHE_SIZE = 32


def _tuned_block(kernel_name: str, dtype, m: int, q: int,
                 ) -> Optional[Tuple[int, int]]:
    """`tune.best_blocks` for one direction of one op; None = module
    defaults. Lazy import: `repro.tune` imports the kernel wrappers (and,
    transitively, this module) for measurement."""
    from repro import tune

    return tune.best_blocks(kernel_name, dtype=dtype, m=int(m), q=int(q))


def cache_info():
    """Debug hook: lru_cache statistics of every op factory, keyed by op
    name — how many knob combinations are live vs evicted."""
    return {
        "kfu": _make_kfu_op.cache_info(),
        "psi1": _make_psi1_op.cache_info(),
        "psi2": _make_psi2_op.cache_info(),
        "suffstats": _make_suffstats_op.cache_info(),
    }


# ---------------------------------------------------------------------------
# kfu
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=_OP_CACHE_SIZE)
def _make_kfu_op(bwd_backend: str, block, bwd_block):
    @jax.custom_vjp
    def op(X, Z, variance, lengthscale):
        return kfu_pallas(X, Z, variance, lengthscale,
                          interpret=interpret_mode(), block=block)

    def fwd(X, Z, variance, lengthscale):
        return op(X, Z, variance, lengthscale), (X, Z, variance, lengthscale)

    def bwd(res, g):
        X, Z, variance, lengthscale = res
        return _bwd_dispatch(
            bwd_backend, X.shape[0],
            lambda interp: kfu_bwd_pallas(X, Z, variance, lengthscale, g,
                                          interpret=interp, block=bwd_block),
            lambda: kfu_vjp_jnp(X, Z, variance, lengthscale, g))

    op.defvjp(fwd, bwd)
    return op


def kfu(X, Z, variance, lengthscale, *, bwd_backend: str = "auto",
        block: Optional[Tuple[int, int]] = None,
        bwd_block: Optional[Tuple[int, int]] = None):
    """RBF cross-covariance K_fu (N, M) with a hand-derived, kernelized
    reverse pass (the S -> 0 specialization of the psi1 rules). `block` /
    `bwd_block` pin the forward/reverse tiles; None consults the autotuner
    (the reverse delegates to the psi1 reverse kernel, so its tune key is
    `psi1_bwd_pallas`)."""
    _check_bwd_backend(bwd_backend)
    if block is None:
        block = _tuned_block("kfu_pallas", X.dtype, Z.shape[0], X.shape[1])
    if bwd_block is None:
        bwd_block = _tuned_block("psi1_bwd_pallas", X.dtype, Z.shape[0],
                                 X.shape[1])
    return _make_kfu_op(bwd_backend, block, bwd_block)(
        X, Z, variance, lengthscale)


# ---------------------------------------------------------------------------
# psi1
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=_OP_CACHE_SIZE)
def _make_psi1_op(bwd_backend: str, block, bwd_block):
    @jax.custom_vjp
    def op(mu, S, Z, variance, lengthscale):
        return psi1_pallas(mu, S, Z, variance, lengthscale,
                           interpret=interpret_mode(), block=block)

    def fwd(mu, S, Z, variance, lengthscale):
        return op(mu, S, Z, variance, lengthscale), \
            (mu, S, Z, variance, lengthscale)

    def bwd(res, g):
        return _bwd_dispatch(
            bwd_backend, res[0].shape[0],
            lambda interp: psi1_bwd_pallas(*res, g, interpret=interp,
                                           block=bwd_block),
            lambda: psi1_vjp_jnp(*res, g))

    op.defvjp(fwd, bwd)
    return op


def psi1(mu, S, Z, variance, lengthscale, *, bwd_backend: str = "auto",
         block: Optional[Tuple[int, int]] = None,
         bwd_block: Optional[Tuple[int, int]] = None):
    """Psi1 statistic (N, M) with a hand-derived, kernelized reverse pass
    (eq. (10)-(14) of the derivation, branch weight W1 = g . psi1).
    `block`/`bwd_block` pin the tiles; None consults the autotuner."""
    _check_bwd_backend(bwd_backend)
    if block is None:
        block = _tuned_block("psi1_pallas", mu.dtype, Z.shape[0], mu.shape[1])
    if bwd_block is None:
        bwd_block = _tuned_block("psi1_bwd_pallas", mu.dtype, Z.shape[0],
                                 mu.shape[1])
    return _make_psi1_op(bwd_backend, block, bwd_block)(
        mu, S, Z, variance, lengthscale)


# ---------------------------------------------------------------------------
# psi2
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=_OP_CACHE_SIZE)
def _make_psi2_op(bwd_backend: str, block, bwd_block):
    @jax.custom_vjp
    def op(mu, S, Z, variance, lengthscale):
        return psi2_pallas(mu, S, Z, variance, lengthscale,
                           interpret=interpret_mode(), block=block)

    def fwd(mu, S, Z, variance, lengthscale):
        return op(mu, S, Z, variance, lengthscale), \
            (mu, S, Z, variance, lengthscale)

    def bwd(res, g2):
        return _bwd_dispatch(
            bwd_backend, res[0].shape[0],
            lambda interp: psi2_bwd_pallas(*res, g2, interpret=interp,
                                           block=bwd_block),
            lambda: psi2_vjp_jnp(*res, g2))

    op.defvjp(fwd, bwd)
    return op


def psi2(mu, S, Z, variance, lengthscale, *, bwd_backend: str = "auto",
         block: Optional[Tuple[int, int]] = None,
         bwd_block: Optional[Tuple[int, int]] = None):
    """Psi2 statistic (M, M) with a hand-derived, kernelized reverse pass
    (the fused op's psi2 branch alone: eq. (9), (15)-(20)).
    `block`/`bwd_block` pin the tiles; None consults the autotuner."""
    _check_bwd_backend(bwd_backend)
    if block is None:
        block = _tuned_block("psi2_pallas", mu.dtype, Z.shape[0], mu.shape[1])
    if bwd_block is None:
        bwd_block = _tuned_block("psi2_bwd_pallas", mu.dtype, Z.shape[0],
                                 mu.shape[1])
    return _make_psi2_op(bwd_backend, block, bwd_block)(
        mu, S, Z, variance, lengthscale)


# ---------------------------------------------------------------------------
# fused suffstats (psi2 + psiY in one pass over N)
# ---------------------------------------------------------------------------

def _suffstats_impl(mu, S, Y, Z, variance, lengthscale, block=None):
    if not interpret_mode():
        return suffstats_pallas(mu, S, Y, Z, variance, lengthscale,
                                interpret=False, block=block)
    if mu.shape[0] <= fused_interpret_max_n():
        return suffstats_pallas(mu, S, Y, Z, variance, lengthscale,
                                interpret=True, block=block)
    return suffstats_fused_jnp(mu, S, Y, Z, variance, lengthscale)


@functools.lru_cache(maxsize=_OP_CACHE_SIZE)
def _make_suffstats_op(bwd_backend: str, block, bwd_block):
    """One custom_vjp op per knob combination (the knobs must be static at
    trace time, so they select among cached op instances rather than riding
    the traced arguments)."""

    @jax.custom_vjp
    def op(mu, S, Y, Z, variance, lengthscale):
        return _suffstats_impl(mu, S, Y, Z, variance, lengthscale,
                               block=block)

    def fwd(mu, S, Y, Z, variance, lengthscale):
        out = op(mu, S, Y, Z, variance, lengthscale)
        return out, (mu, S, Y, Z, variance, lengthscale)

    def bwd(res, g):
        g2, gY = g
        return _bwd_dispatch(
            bwd_backend, res[0].shape[0],
            lambda interp: suffstats_bwd_pallas(*res, g2, gY,
                                                interpret=interp,
                                                block=bwd_block),
            lambda: suffstats_vjp_jnp(*res, g2, gY))

    op.defvjp(fwd, bwd)
    return op


def suffstats(mu, S, Y, Z, variance, lengthscale, *,
              bwd_backend: str = "auto",
              block: Optional[Tuple[int, int]] = None,
              bwd_block: Optional[Tuple[int, int]] = None):
    """Fused (psi2 (M, M), psiY (M, D)) with a hand-derived O(chunk * M^2)
    reverse pass — usable under jax.grad inside training steps.

    `bwd_backend` selects the reverse-pass implementation ("auto" | "pallas"
    | "jnp", see module docstring); the forward dispatch is unaffected.
    `block`/`bwd_block` pin the forward/reverse Pallas tiles; None consults
    the autotuner.
    """
    _check_bwd_backend(bwd_backend)
    if block is None:
        block = _tuned_block("suffstats_pallas", mu.dtype, Z.shape[0],
                             mu.shape[1])
    if bwd_block is None:
        bwd_block = _tuned_block("suffstats_bwd_pallas", mu.dtype,
                                 Z.shape[0], mu.shape[1])
    return _make_suffstats_op(bwd_backend, block, bwd_block)(
        mu, S, Y, Z, variance, lengthscale)
