"""Differentiable jit'd wrappers around the Pallas psi-statistic kernels.

Forward = Pallas kernel (interpret-mode on CPU, compiled on TPU). Backward =
the HAND-DERIVED reverse passes (kernels/suffstats.py, the paper's Table-2
gradient loops expressed as closed-form reverse rules) for the fused
`suffstats` op AND the single-statistic ops (`kfu`/`psi1`/`psi2` specialize
the fused rules — see docs/derivations/suffstats_vjp.md). Every op's
reverse-pass implementation is selected by a static `bwd_backend` knob:

  * ``"auto"``   (default) — mirror the forward's three-way dispatch: the
    Pallas reverse kernel compiled on TPU, the same kernel body in interpret
    mode off-TPU for small N, and the streaming-jnp reverse scan off-TPU for
    large N. This is the only knob value callers normally need.
  * ``"pallas"`` — force the Pallas reverse kernel (interpret off-TPU even
    at large N: slow, for validation).
  * ``"jnp"``    — force the streaming-jnp reverse scan everywhere.

`interpret_mode()` flips automatically: True off-TPU so the whole test/bench
suite exercises the real kernel bodies on CPU. It reads the backend at call
time (import-time freezing would mis-dispatch after a test fixture or
`jax.config` forces a platform post-import); `_INTERPRET_OVERRIDE` is the
test-visible override. Because interpret mode pays a Python-level cost per
grid point, the reverse dispatch only runs the kernel bodies off-TPU up to
`FUSED_INTERPRET_MAX_N` datapoints; beyond that it switches to the
numerically-matching streaming-jnp twins.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels.kfu import kfu_pallas
from repro.kernels.psi1 import psi1_pallas
from repro.kernels.psi2 import psi2_pallas
from repro.kernels.suffstats import (
    kfu_bwd_pallas,
    kfu_vjp_jnp,
    psi1_bwd_pallas,
    psi1_vjp_jnp,
    psi2_bwd_pallas,
    psi2_vjp_jnp,
    suffstats_bwd_pallas,
    suffstats_fused_jnp,
    suffstats_pallas,
    suffstats_vjp_jnp,
)

# Test-visible override for `interpret_mode()`: None = detect from the
# backend at call time; True/False force a path (restore to None after).
_INTERPRET_OVERRIDE: bool | None = None


def interpret_mode() -> bool:
    """Whether the Pallas kernel bodies should run in interpret mode.

    Read at CALL time, not import time: `jax.default_backend()` is itself
    cached by jax and invalidated when the platform config changes, so a
    test fixture (or `jax.config.update("jax_platform_name", ...)`) that
    forces a backend after this module imports still dispatches the right
    kernel path.
    """
    if _INTERPRET_OVERRIDE is not None:
        return bool(_INTERPRET_OVERRIDE)
    return jax.default_backend() != "tpu"


def __getattr__(name: str):
    # back-compat: `ops.INTERPRET` used to be an import-time constant; keep
    # the attribute readable but always call-time fresh
    if name == "INTERPRET":
        return interpret_mode()
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


# off-TPU, run the real kernel bodies (interpret mode) only for problems
# small enough that per-grid-point interpretation stays cheap
FUSED_INTERPRET_MAX_N = 1024

BWD_BACKENDS = ("auto", "pallas", "jnp")


def _check_bwd_backend(bwd_backend: str) -> None:
    if bwd_backend not in BWD_BACKENDS:
        raise ValueError(
            f"bwd_backend must be one of {BWD_BACKENDS}, got {bwd_backend!r}")


def _bwd_dispatch(bwd_backend, n, pallas_fn, jnp_fn):
    """The shared three-way reverse dispatch (mirrors the forward's split):
    `pallas_fn(interpret)` runs a Pallas reverse kernel, `jnp_fn()` the
    streaming-jnp twin. Every op's custom_vjp backward routes through here.
    """
    if bwd_backend == "jnp":
        return jnp_fn()
    if bwd_backend == "pallas":
        return pallas_fn(interpret_mode())
    if not interpret_mode():
        return pallas_fn(False)
    if n <= FUSED_INTERPRET_MAX_N:
        return pallas_fn(True)
    return jnp_fn()


# ---------------------------------------------------------------------------
# kfu
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _make_kfu_op(bwd_backend: str):
    @jax.custom_vjp
    def op(X, Z, variance, lengthscale):
        return kfu_pallas(X, Z, variance, lengthscale,
                          interpret=interpret_mode())

    def fwd(X, Z, variance, lengthscale):
        return op(X, Z, variance, lengthscale), (X, Z, variance, lengthscale)

    def bwd(res, g):
        X, Z, variance, lengthscale = res
        return _bwd_dispatch(
            bwd_backend, X.shape[0],
            lambda interp: kfu_bwd_pallas(X, Z, variance, lengthscale, g,
                                          interpret=interp),
            lambda: kfu_vjp_jnp(X, Z, variance, lengthscale, g))

    op.defvjp(fwd, bwd)
    return op


def kfu(X, Z, variance, lengthscale, *, bwd_backend: str = "auto"):
    """RBF cross-covariance K_fu (N, M) with a hand-derived, kernelized
    reverse pass (the S -> 0 specialization of the psi1 rules)."""
    _check_bwd_backend(bwd_backend)
    return _make_kfu_op(bwd_backend)(X, Z, variance, lengthscale)


# ---------------------------------------------------------------------------
# psi1
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _make_psi1_op(bwd_backend: str):
    @jax.custom_vjp
    def op(mu, S, Z, variance, lengthscale):
        return psi1_pallas(mu, S, Z, variance, lengthscale,
                           interpret=interpret_mode())

    def fwd(mu, S, Z, variance, lengthscale):
        return op(mu, S, Z, variance, lengthscale), \
            (mu, S, Z, variance, lengthscale)

    def bwd(res, g):
        return _bwd_dispatch(
            bwd_backend, res[0].shape[0],
            lambda interp: psi1_bwd_pallas(*res, g, interpret=interp),
            lambda: psi1_vjp_jnp(*res, g))

    op.defvjp(fwd, bwd)
    return op


def psi1(mu, S, Z, variance, lengthscale, *, bwd_backend: str = "auto"):
    """Psi1 statistic (N, M) with a hand-derived, kernelized reverse pass
    (eq. (10)-(14) of the derivation, branch weight W1 = g . psi1)."""
    _check_bwd_backend(bwd_backend)
    return _make_psi1_op(bwd_backend)(mu, S, Z, variance, lengthscale)


# ---------------------------------------------------------------------------
# psi2
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _make_psi2_op(bwd_backend: str):
    @jax.custom_vjp
    def op(mu, S, Z, variance, lengthscale):
        return psi2_pallas(mu, S, Z, variance, lengthscale,
                           interpret=interpret_mode())

    def fwd(mu, S, Z, variance, lengthscale):
        return op(mu, S, Z, variance, lengthscale), \
            (mu, S, Z, variance, lengthscale)

    def bwd(res, g2):
        return _bwd_dispatch(
            bwd_backend, res[0].shape[0],
            lambda interp: psi2_bwd_pallas(*res, g2, interpret=interp),
            lambda: psi2_vjp_jnp(*res, g2))

    op.defvjp(fwd, bwd)
    return op


def psi2(mu, S, Z, variance, lengthscale, *, bwd_backend: str = "auto"):
    """Psi2 statistic (M, M) with a hand-derived, kernelized reverse pass
    (the fused op's psi2 branch alone: eq. (9), (15)-(20))."""
    _check_bwd_backend(bwd_backend)
    return _make_psi2_op(bwd_backend)(mu, S, Z, variance, lengthscale)


# ---------------------------------------------------------------------------
# fused suffstats (psi2 + psiY in one pass over N)
# ---------------------------------------------------------------------------

def _suffstats_impl(mu, S, Y, Z, variance, lengthscale):
    if not interpret_mode():
        return suffstats_pallas(mu, S, Y, Z, variance, lengthscale,
                                interpret=False)
    if mu.shape[0] <= FUSED_INTERPRET_MAX_N:
        return suffstats_pallas(mu, S, Y, Z, variance, lengthscale,
                                interpret=True)
    return suffstats_fused_jnp(mu, S, Y, Z, variance, lengthscale)


@functools.lru_cache(maxsize=None)
def _make_suffstats_op(bwd_backend: str):
    """One custom_vjp op per bwd_backend value (the knob must be static at
    trace time, so it selects among cached op instances rather than riding
    the traced arguments)."""

    @jax.custom_vjp
    def op(mu, S, Y, Z, variance, lengthscale):
        return _suffstats_impl(mu, S, Y, Z, variance, lengthscale)

    def fwd(mu, S, Y, Z, variance, lengthscale):
        out = op(mu, S, Y, Z, variance, lengthscale)
        return out, (mu, S, Y, Z, variance, lengthscale)

    def bwd(res, g):
        g2, gY = g
        return _bwd_dispatch(
            bwd_backend, res[0].shape[0],
            lambda interp: suffstats_bwd_pallas(*res, g2, gY,
                                                interpret=interp),
            lambda: suffstats_vjp_jnp(*res, g2, gY))

    op.defvjp(fwd, bwd)
    return op


def suffstats(mu, S, Y, Z, variance, lengthscale, *, bwd_backend: str = "auto"):
    """Fused (psi2 (M, M), psiY (M, D)) with a hand-derived O(chunk * M^2)
    reverse pass — usable under jax.grad inside training steps.

    `bwd_backend` selects the reverse-pass implementation ("auto" | "pallas"
    | "jnp", see module docstring); the forward dispatch is unaffected.
    """
    _check_bwd_backend(bwd_backend)
    return _make_suffstats_op(bwd_backend)(mu, S, Y, Z, variance, lengthscale)
