"""Differentiable jit'd wrappers around the Pallas psi-statistic kernels.

Forward = Pallas kernel (interpret-mode on CPU, compiled on TPU).
Backward = memory-lean jnp (chunked where needed) via jax.vjp of the ref
formulas — the paper's Table-2 gradient loops expressed as closed-form
reverse rules. A Pallas backward for psi2 is a recorded perf-iteration item
(EXPERIMENTS.md §Perf).

`INTERPRET` flips automatically: True off-TPU so the whole test/bench suite
exercises the real kernel bodies on CPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.kfu import kfu_pallas
from repro.kernels.psi1 import psi1_pallas
from repro.kernels.psi2 import psi2_pallas

INTERPRET = jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# kfu
# ---------------------------------------------------------------------------

@jax.custom_vjp
def kfu(X, Z, variance, lengthscale):
    return kfu_pallas(X, Z, variance, lengthscale, interpret=INTERPRET)


def _kfu_fwd(X, Z, variance, lengthscale):
    return kfu(X, Z, variance, lengthscale), (X, Z, variance, lengthscale)


def _kfu_bwd(res, g):
    _, vjp = jax.vjp(ref.kfu_rbf, *res)
    return vjp(g)


kfu.defvjp(_kfu_fwd, _kfu_bwd)


# ---------------------------------------------------------------------------
# psi1
# ---------------------------------------------------------------------------

@jax.custom_vjp
def psi1(mu, S, Z, variance, lengthscale):
    return psi1_pallas(mu, S, Z, variance, lengthscale, interpret=INTERPRET)


def _psi1_fwd(mu, S, Z, variance, lengthscale):
    return psi1(mu, S, Z, variance, lengthscale), (mu, S, Z, variance, lengthscale)


def _psi1_bwd(res, g):
    _, vjp = jax.vjp(ref.psi1_rbf, *res)
    return vjp(g)


psi1.defvjp(_psi1_fwd, _psi1_bwd)


# ---------------------------------------------------------------------------
# psi2
# ---------------------------------------------------------------------------

def _psi2_ref_chunked(mu, S, Z, variance, lengthscale):
    # import here to avoid a core<->kernels import cycle at module load
    from repro.core.psi_stats import _psi2_rbf_chunked

    return _psi2_rbf_chunked(mu, S, Z, variance, lengthscale)


@jax.custom_vjp
def psi2(mu, S, Z, variance, lengthscale):
    return psi2_pallas(mu, S, Z, variance, lengthscale, interpret=INTERPRET)


def _psi2_fwd(mu, S, Z, variance, lengthscale):
    return psi2(mu, S, Z, variance, lengthscale), (mu, S, Z, variance, lengthscale)


def _psi2_bwd(res, g):
    # chunked reverse pass: O(chunk * M^2) live memory, like the forward
    _, vjp = jax.vjp(_psi2_ref_chunked, *res)
    return vjp(g)


psi2.defvjp(_psi2_fwd, _psi2_bwd)
