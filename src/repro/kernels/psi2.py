"""Pallas TPU kernel: Psi2 statistic (paper §3 Table 1, "Phi" accumulation).

    Psi2[m,m'] = sum_n sigma^4 prod_q (1+2 S_nq/l_q^2)^(-1/2)
        exp(-(z_mq - z_m'q)^2/(4 l_q^2) - (mu_nq - zbar_q)^2/(l_q^2 + 2 S_nq))

TPU adaptation of the CUDA design (block per (m1,m2) pair, threads over n,
shared-memory reduction):

  * grid = (M/TM, M/TM, N/TN); the N axis is the *innermost* grid dimension,
    so for a fixed (m1, m2) tile the kernel revisits the same VMEM output
    block sequentially and accumulates in place — a race-free replacement for
    CUDA's shared-memory tree reduction (TPU grid steps are sequential per
    core, so no synchronization exists or is needed).
  * the (mu - zbar)^2 / d_nq exponent is expanded so the n<->m coupling
    becomes two MXU matmuls (A1, A2) plus a rank-Q cross term accumulated
    per-q on the VPU; the final weighted reduction over the datapoint tile is
    itself an MXU contraction  w(1,TN) @ E(TN, TM*TM).
  * padded datapoints carry weight 0 (exact masking — they contribute nothing
    to the sum, matching the paper's "sum over exactly N points").

The n-independent factor sigma^4 exp(-(z-z')^2/(4 l^2)) is applied outside
the kernel (O(M^2), negligible) — keeping the kernel a pure streaming
reduction over datapoints.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.suffstats import _psi2_tile

TILE_N = 32
TILE_M = 128


def _psi2_kernel(mu_ref, s_ref, w_ref, z1_ref, z2_ref, l2_ref, o_ref, *,
                 ct=jnp.float32):
    k = pl.program_id(2)

    mu = mu_ref[...].astype(ct)  # (TN, Q)
    S = s_ref[...].astype(ct)  # (TN, Q)
    w = w_ref[...].astype(ct)  # (TN, 1)
    z1 = z1_ref[...].astype(ct)  # (TM, Q)
    z2 = z2_ref[...].astype(ct)  # (TM, Q)
    l2 = l2_ref[...].astype(ct)  # (1, Q)

    tn = mu.shape[0]
    tm = z1.shape[0]

    # the shared tile helper of the fused forward/reverse kernels: the
    # per-point factor E (MXU halfterms + rank-Q cross term) is evaluated in
    # exactly one place, so the single-statistic and fused formulas can't drift
    _, E = _psi2_tile(mu, S, z1, z2, l2, ct=ct)  # (TN, TM, TM)

    # weighted datapoint reduction on the MXU: (1,TN) @ (TN, TM*TM)
    contrib = jax.lax.dot_general(
        w.T, E.reshape(tn, tm * tm), (((1,), (0,)), ((), ())),
        preferred_element_type=ct,
    ).reshape(tm, tm)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = contrib

    @pl.when(k > 0)
    def _acc():
        o_ref[...] += contrib


@functools.partial(jax.jit, static_argnames=("interpret", "block"))
def psi2_pallas(
    mu: jax.Array,
    S: jax.Array,
    Z: jax.Array,
    variance: jax.Array,
    lengthscale: jax.Array,
    *,
    interpret: bool = False,
    block: tuple | None = None,
) -> jax.Array:
    # `block=(tile_n, tile_m)` overrides the module-constant tiles (the
    # repro.tune knob); the wrapper pads to the block's multiple, so every
    # candidate is numerically identical to the defaults.
    tile_n, tile_m = block if block is not None else (TILE_N, TILE_M)
    N, Q = mu.shape
    M = Z.shape[0]
    dtype = mu.dtype
    # compiled TPU execution computes in float32; interpret mode computes in
    # the input dtype promoted to at least f32 (same policy as the fused
    # suffstats kernel) so f64 parity tests exercise the kernel body itself
    ct = jnp.promote_types(dtype, jnp.float32) if interpret else jnp.float32
    pad_n = (-N) % tile_n
    pad_m = (-M) % tile_m
    mu_p = jnp.pad(mu.astype(ct), ((0, pad_n), (0, 0)))
    S_p = jnp.pad(S.astype(ct), ((0, pad_n), (0, 0)), constant_values=1.0)
    w = jnp.pad(jnp.ones((N, 1), ct), ((0, pad_n), (0, 0)))
    Z_p = jnp.pad(Z.astype(ct), ((0, pad_m), (0, 0)))
    l2 = (lengthscale.astype(ct) ** 2)[None, :]

    Mp = Z_p.shape[0]
    grid = (Mp // tile_m, Mp // tile_m, mu_p.shape[0] // tile_n)
    acc = pl.pallas_call(
        functools.partial(_psi2_kernel, ct=ct),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_n, Q), lambda i, j, k: (k, 0)),
            pl.BlockSpec((tile_n, Q), lambda i, j, k: (k, 0)),
            pl.BlockSpec((tile_n, 1), lambda i, j, k: (k, 0)),
            pl.BlockSpec((tile_m, Q), lambda i, j, k: (i, 0)),
            pl.BlockSpec((tile_m, Q), lambda i, j, k: (j, 0)),
            pl.BlockSpec((1, Q), lambda i, j, k: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile_m, tile_m), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Mp), ct),
        interpret=interpret,
    )(mu_p, S_p, w, Z_p, Z_p, l2)

    # n-independent prefactor: sigma^4 exp(-(z - z')^2 / (4 l^2))
    zs = Z.astype(ct) / lengthscale.astype(ct)
    zn = jnp.sum(zs * zs, -1)
    d2 = jnp.maximum(zn[:, None] + zn[None, :] - 2.0 * zs @ zs.T, 0.0)
    pref = variance.astype(ct) ** 2 * jnp.exp(-0.25 * d2)
    return (pref * acc[:M, :M]).astype(dtype)
