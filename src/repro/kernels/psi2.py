"""Pallas TPU kernel: Psi2 statistic (paper §3 Table 1, "Phi" accumulation).

    Psi2[m,m'] = sum_n sigma^4 prod_q (1+2 S_nq/l_q^2)^(-1/2)
        exp(-(z_mq - z_m'q)^2/(4 l_q^2) - (mu_nq - zbar_q)^2/(l_q^2 + 2 S_nq))

TPU adaptation of the CUDA design (block per (m1,m2) pair, threads over n,
shared-memory reduction):

  * grid = (M/TM, M/TM, N/TN); the N axis is the *innermost* grid dimension,
    so for a fixed (m1, m2) tile the kernel revisits the same VMEM output
    block sequentially and accumulates in place — a race-free replacement for
    CUDA's shared-memory tree reduction (TPU grid steps are sequential per
    core, so no synchronization exists or is needed).
  * the (mu - zbar)^2 / d_nq exponent is expanded so the n<->m coupling
    becomes two MXU matmuls (A1, A2) plus a rank-Q cross term accumulated
    per-q on the VPU; the final weighted reduction over the datapoint tile is
    itself an MXU contraction  w(1,TN) @ E(TN, TM*TM).
  * padded datapoints carry weight 0 (exact masking — they contribute nothing
    to the sum, matching the paper's "sum over exactly N points").

The n-independent factor sigma^4 exp(-(z-z')^2/(4 l^2)) is applied outside
the kernel (O(M^2), negligible) — keeping the kernel a pure streaming
reduction over datapoints.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_N = 32
TILE_M = 128


def _psi2_kernel(mu_ref, s_ref, w_ref, z1_ref, z2_ref, l2_ref, o_ref):
    k = pl.program_id(2)

    mu = mu_ref[...].astype(jnp.float32)  # (TN, Q)
    S = s_ref[...].astype(jnp.float32)  # (TN, Q)
    w = w_ref[...].astype(jnp.float32)  # (TN, 1)
    z1 = z1_ref[...].astype(jnp.float32)  # (TM, Q)
    z2 = z2_ref[...].astype(jnp.float32)  # (TM, Q)
    l2 = l2_ref[...].astype(jnp.float32)  # (1, Q)

    tn, q_dim = mu.shape
    tm = z1.shape[0]

    r = 1.0 / (l2 + 2.0 * S)  # (TN, Q)
    lognorm = -0.5 * jnp.sum(jnp.log1p(2.0 * S / l2), axis=-1, keepdims=True)  # (TN,1)
    c2 = jnp.sum(mu * mu * r, axis=-1, keepdims=True)  # (TN,1)
    mur = mu * r

    def halfterm(z):  # (TN, TM): (mu r) @ z^T - 0.25 r @ (z^2)^T
        a = jax.lax.dot_general(mur, z, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        b = jax.lax.dot_general(r, z * z, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        return a - 0.25 * b

    A1 = halfterm(z1)  # (TN, TM)
    A2 = halfterm(z2)  # (TN, TM)

    # cross[n, m1, m2] = 0.5 sum_q r_nq z1_m1q z2_m2q  — accumulated per q
    cross = jnp.zeros((tn, tm, tm), jnp.float32)
    for q in range(q_dim):  # Q is a compile-time constant (latent dim, small)
        cross = cross + (
            r[:, q][:, None, None] * z1[:, q][None, :, None] * z2[:, q][None, None, :]
        )

    expo = (
        (lognorm - c2)[:, :, None]  # (TN,1,1)
        + A1[:, :, None]
        + A2[:, None, :]
        - 0.5 * cross
    )
    E = jnp.exp(expo)  # (TN, TM, TM)

    # weighted datapoint reduction on the MXU: (1,TN) @ (TN, TM*TM)
    contrib = jax.lax.dot_general(
        w.T, E.reshape(tn, tm * tm), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).reshape(tm, tm)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = contrib

    @pl.when(k > 0)
    def _acc():
        o_ref[...] += contrib


@functools.partial(jax.jit, static_argnames=("interpret",))
def psi2_pallas(
    mu: jax.Array,
    S: jax.Array,
    Z: jax.Array,
    variance: jax.Array,
    lengthscale: jax.Array,
    *,
    interpret: bool = False,
) -> jax.Array:
    N, Q = mu.shape
    M = Z.shape[0]
    dtype = mu.dtype
    pad_n = (-N) % TILE_N
    pad_m = (-M) % TILE_M
    mu_p = jnp.pad(mu.astype(jnp.float32), ((0, pad_n), (0, 0)))
    S_p = jnp.pad(S.astype(jnp.float32), ((0, pad_n), (0, 0)), constant_values=1.0)
    w = jnp.pad(jnp.ones((N, 1), jnp.float32), ((0, pad_n), (0, 0)))
    Z_p = jnp.pad(Z.astype(jnp.float32), ((0, pad_m), (0, 0)))
    l2 = (lengthscale.astype(jnp.float32) ** 2)[None, :]

    Mp = Z_p.shape[0]
    grid = (Mp // TILE_M, Mp // TILE_M, mu_p.shape[0] // TILE_N)
    acc = pl.pallas_call(
        _psi2_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_N, Q), lambda i, j, k: (k, 0)),
            pl.BlockSpec((TILE_N, Q), lambda i, j, k: (k, 0)),
            pl.BlockSpec((TILE_N, 1), lambda i, j, k: (k, 0)),
            pl.BlockSpec((TILE_M, Q), lambda i, j, k: (i, 0)),
            pl.BlockSpec((TILE_M, Q), lambda i, j, k: (j, 0)),
            pl.BlockSpec((1, Q), lambda i, j, k: (0, 0)),
        ],
        out_specs=pl.BlockSpec((TILE_M, TILE_M), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Mp), jnp.float32),
        interpret=interpret,
    )(mu_p, S_p, w, Z_p, Z_p, l2)

    # n-independent prefactor: sigma^4 exp(-(z - z')^2 / (4 l^2))
    zs = Z.astype(jnp.float32) / lengthscale.astype(jnp.float32)
    zn = jnp.sum(zs * zs, -1)
    d2 = jnp.maximum(zn[:, None] + zn[None, :] - 2.0 * zs @ zs.T, 0.0)
    pref = variance.astype(jnp.float32) ** 2 * jnp.exp(-0.25 * d2)
    return (pref * acc[:M, :M]).astype(dtype)
