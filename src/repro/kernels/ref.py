"""Pure-jnp oracles for the psi-statistic kernels (paper §3, Tables 1-2).

These are the reference implementations of the quantities the paper computes
on GPU:

  - ``kfu``  : cross covariance K_fu (N x M)        [sparse GP, deterministic X]
  - ``phi_exact`` : Phi = K_fu^T K_fu (M x M)
  - ``psi0`` : sum_n <k(x_n, x_n)>_{q(x_n)}          (scalar)
  - ``psi1`` : Psi1[n,m] = <k(x_n, z_m)>_{q(x_n)}    (N x M)
  - ``psi2`` : Psi2 = sum_n <k_fu(x_n)^T k_fu(x_n)>  (M x M)

Closed forms for the RBF-ARD kernel under diagonal Gaussian
q(x_n) = N(mu_n, diag(S_n)) follow Titsias & Lawrence (2010).

Every Pallas kernel in this package is validated against these with
``assert_allclose`` over shape/dtype sweeps (tests/test_kernels_psi.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Deterministic-input statistics (supervised sparse GP, paper eq. (2)-(3))
# ---------------------------------------------------------------------------

def kfu_rbf(X: jax.Array, Z: jax.Array, variance: jax.Array, lengthscale: jax.Array) -> jax.Array:
    """K_fu[n, m] = sigma^2 exp(-0.5 sum_q (x_nq - z_mq)^2 / l_q^2)."""
    Xs = X / lengthscale
    Zs = Z / lengthscale
    d2 = (
        jnp.sum(Xs**2, -1)[:, None]
        + jnp.sum(Zs**2, -1)[None, :]
        - 2.0 * Xs @ Zs.T
    )
    return variance * jnp.exp(-0.5 * jnp.maximum(d2, 0.0))


def phi_exact_rbf(X: jax.Array, Z: jax.Array, variance: jax.Array, lengthscale: jax.Array) -> jax.Array:
    """Phi = K_fu^T K_fu, the paper's per-datapoint outer-product sum."""
    Kfu = kfu_rbf(X, Z, variance, lengthscale)
    return Kfu.T @ Kfu


# ---------------------------------------------------------------------------
# Expected statistics under q(X) (Bayesian GP-LVM, paper eq. (4))
# ---------------------------------------------------------------------------

def psi0_rbf(mu: jax.Array, S: jax.Array, variance: jax.Array, lengthscale: jax.Array) -> jax.Array:
    """psi0 = sum_n <k(x_n,x_n)> = N * sigma^2 for the RBF kernel."""
    del S, lengthscale
    return mu.shape[0] * variance


def psi1_rbf(
    mu: jax.Array, S: jax.Array, Z: jax.Array, variance: jax.Array, lengthscale: jax.Array
) -> jax.Array:
    """Psi1[n,m] = sigma^2 prod_q (1+S_nq/l_q^2)^(-1/2)
    exp(-0.5 (mu_nq - z_mq)^2 / (l_q^2 + S_nq))."""
    l2 = lengthscale**2  # (Q,)
    denom = l2[None, :] + S  # (N, Q)
    # log-normalizer: -0.5 sum_q log(1 + S/l^2)
    lognorm = -0.5 * jnp.sum(jnp.log1p(S / l2[None, :]), axis=-1)  # (N,)
    # exponent: -0.5 sum_q (mu - z)^2 / denom
    diff = mu[:, None, :] - Z[None, :, :]  # (N, M, Q)
    expo = -0.5 * jnp.sum(diff**2 / denom[:, None, :], axis=-1)  # (N, M)
    return variance * jnp.exp(lognorm[:, None] + expo)


def psi2_n_rbf(
    mu: jax.Array, S: jax.Array, Z: jax.Array, variance: jax.Array, lengthscale: jax.Array
) -> jax.Array:
    """Per-datapoint psi2: (N, M, M) tensor before the sum over n.

    psi2[n,m,m'] = sigma^4 prod_q (1 + 2 S_nq/l_q^2)^(-1/2)
        * exp(-(z_mq - z_m'q)^2 / (4 l_q^2) - (mu_nq - zbar_q)^2 / (l_q^2 + 2 S_nq))
    with zbar = (z_m + z_m') / 2.
    """
    l2 = lengthscale**2
    denom = l2[None, :] + 2.0 * S  # (N, Q)
    lognorm = -0.5 * jnp.sum(jnp.log1p(2.0 * S / l2[None, :]), axis=-1)  # (N,)
    zdiff = Z[:, None, :] - Z[None, :, :]  # (M, M, Q)
    zterm = -jnp.sum(zdiff**2 / (4.0 * l2[None, None, :]), axis=-1)  # (M, M)
    zbar = 0.5 * (Z[:, None, :] + Z[None, :, :])  # (M, M, Q)
    mudiff = mu[:, None, None, :] - zbar[None, :, :, :]  # (N, M, M, Q)
    muterm = -jnp.sum(mudiff**2 / denom[:, None, None, :], axis=-1)  # (N, M, M)
    return variance**2 * jnp.exp(lognorm[:, None, None] + zterm[None, :, :] + muterm)


def psi2_rbf(
    mu: jax.Array, S: jax.Array, Z: jax.Array, variance: jax.Array, lengthscale: jax.Array
) -> jax.Array:
    """Psi2 = sum_n psi2^{(n)}  (M x M). O(N M^2 Q) memory-naive oracle.

    The memory-lean factorized form used in production is in psi_stats.py /
    the Pallas kernel; this oracle keeps the textbook (N,M,M,Q) broadcast so
    there is an independent implementation to validate against.
    """
    return jnp.sum(psi2_n_rbf(mu, S, Z, variance, lengthscale), axis=0)


# -- Linear kernel (used to keep the statistics layer kernel-generic) -------

def psi0_linear(mu: jax.Array, S: jax.Array, ard: jax.Array) -> jax.Array:
    return jnp.sum(ard[None, :] * (mu**2 + S))


def psi1_linear(mu: jax.Array, S: jax.Array, Z: jax.Array, ard: jax.Array) -> jax.Array:
    del S
    return (mu * ard) @ Z.T


def psi2_linear(mu: jax.Array, S: jax.Array, Z: jax.Array, ard: jax.Array) -> jax.Array:
    Za = Z * ard  # (M, Q)
    # sum_n (mu_n mu_n^T + diag(S_n)) contracted with Za on both sides
    moment = (mu.T @ mu) + jnp.diag(jnp.sum(S, axis=0))  # (Q, Q)
    return Za @ moment @ Za.T
