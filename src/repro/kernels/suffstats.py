"""Fused suffstats kernel: ALL sufficient statistics in one pass over N
(beyond-paper optimization C3, EXPERIMENTS.md §Perf) — forward AND reverse
Pallas TPU kernels, streaming jnp twins of both, and the hand-derived
reverse-pass algebra they all implement.

The paper computes Psi1 and Psi2 in separate GPU kernels (Table 1); the
bound only ever consumes psiY = Psi1^T Y and Psi2, so this kernel streams
each datapoint once and accumulates BOTH:

    psiY[m, :]   += psi1[n, m] * y[n, :]
    acc2[m, m']  += exp(lognorm2_n + muterm_n,m,m')

Removing the second pass halves HBM reads of (mu, S) and never materializes
the (N, M) Psi1 matrix.

The REVERSE pass has the same structure (paper Table 2 generalized to the
fused outputs): given cotangents (g2, gY) of (psi2, psiY), every input
cotangent is a weighted streaming reduction over the same per-point factors
the forward computes — so the backward reuses the forward's tile scheme.
The full algebra, with the equation numbers cited throughout this file,
lives in docs/derivations/suffstats_vjp.md.

Main entry points (wired into differentiable ops by `repro.kernels.ops`):

  * `suffstats_pallas`      — forward Pallas kernel (compiled on TPU,
                              interpret elsewhere). Grid (i, j, kn) with the
                              N axis innermost: each (M-tile, M-tile) output
                              block accumulates datapoint tiles in place.
  * `suffstats_bwd_pallas`  — reverse Pallas kernel. Grid (kn, i, j) with
                              the N axis OUTERMOST: the per-datapoint
                              cotangent blocks (dmu, dS, dY) accumulate the
                              (i, j) inducing tiles in place, while the
                              global cotangents (dZ, dvariance,
                              dlengthscale) live in whole-array output
                              blocks whose index never changes (they stay
                              resident in VMEM for the entire grid).
  * `suffstats_fused_jnp`   — numerically-matching streaming `lax.scan`
                              over N chunks; the off-TPU large-N forward.
  * `suffstats_vjp_jnp`     — the same reverse algebra as a streaming jnp
                              scan; the off-TPU large-N backward.

The single-statistic ops' reverse passes live here too — `kfu_bwd_pallas` /
`psi1_bwd_pallas` / `psi2_bwd_pallas` and their streaming jnp twins
(`kfu_vjp_jnp` / `psi1_vjp_jnp` / `psi2_vjp_jnp`) — as specializations of
the fused rules on the same tile scheme.

The Pallas forward and reverse kernels share the `_psi1_tile` / `_psi2_tile`
block helpers below, and every reverse pass (fused or single-statistic,
Pallas or jnp) shares the `_psi1_bwd_tile` / `_psi2_bwd_tile` cotangent
helpers, so the exponential a reverse pass differentiates is the
exponential the forward evaluates and the cotangent algebra exists in
exactly one place — forward and reverse formulas cannot drift. The jnp
forward pair shares `_psi1_weighted` / `_psi2_weighted` the same way (and
`_psi1_weighted` is itself a wrapper over `_psi1_tile`).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_N = 32
TILE_M = 128


# ---------------------------------------------------------------------------
# shared tile helpers (used by BOTH the forward and reverse Pallas kernels)
# ---------------------------------------------------------------------------

def _dot(a, b, dims, ct):
    return jax.lax.dot_general(a, b, (dims, ((), ())),
                               preferred_element_type=ct)


def _psi1_tile(mu, S, z, l2, *, ct):
    """psi1 block / (v * w) for one (TN, TM) tile via the MXU factorization
    (suffstats_vjp.md eq. (1)-(2)): returns (b (TN, Q), blk (TN, TM)).

    Shared by the forward kernel, the reverse kernel, and (through
    `_psi1_weighted`) the streaming jnp twin + hand-derived VJP — every
    consumer evaluates the identical expression.
    """
    b = 1.0 / (l2 + S)
    lognorm1 = -0.5 * jnp.sum(jnp.log1p(S / l2), axis=-1, keepdims=True)
    c1 = jnp.sum(mu * mu * b, axis=-1, keepdims=True)
    mub_zt = _dot(mu * b, z, ((1,), (1,)), ct)
    b_z2t = _dot(b, z * z, ((1,), (1,)), ct)
    return b, jnp.exp(lognorm1 - 0.5 * (c1 - 2.0 * mub_zt + b_z2t))


def _psi2_tile(mu, S, z1, z2, l2, *, ct):
    """Per-point psi2 factor E (without the v^2 exp(zterm) prefactor or pad
    weight) for one (TN, TM, TM) tile (suffstats_vjp.md eq. (4)-(6)):
    returns (r (TN, Q), E (TN, TM, TM)).

    The (mu - zbar)^2 exponent is expanded so the n<->m coupling becomes two
    MXU matmuls (A1, A2) plus a rank-Q cross term accumulated per q on the
    VPU — same math as kernels/psi2.py. Shared by the forward and reverse
    kernels (see `_psi1_tile`).
    """
    tn, q_dim = mu.shape
    tm = z1.shape[0]
    r = 1.0 / (l2 + 2.0 * S)
    lognorm2 = -0.5 * jnp.sum(jnp.log1p(2.0 * S / l2), axis=-1, keepdims=True)
    c2 = jnp.sum(mu * mu * r, axis=-1, keepdims=True)
    mur = mu * r

    def halfterm(z):
        a = _dot(mur, z, ((1,), (1,)), ct)
        b = _dot(r, z * z, ((1,), (1,)), ct)
        return a - 0.25 * b

    A1 = halfterm(z1)
    A2 = halfterm(z2)
    cross = jnp.zeros((tn, tm, tm), ct)
    for q in range(q_dim):
        cross = cross + (r[:, q][:, None, None] * z1[:, q][None, :, None]
                         * z2[:, q][None, None, :])
    E = jnp.exp((lognorm2 - c2)[:, :, None] + A1[:, :, None] + A2[:, None, :]
                - 0.5 * cross)
    return r, E


# ---------------------------------------------------------------------------
# shared reverse-pass tile helpers
# ---------------------------------------------------------------------------
#
# Every input cotangent of every psi-statistic op is linear in a per-point
# branch weight — W1 (eq. (8), the psi1/psiY branch) or T (eq. (9), the psi2
# branch) — so the whole reverse pass factors into the two tile helpers
# below. The fused reverse kernel, the single-statistic reverse kernels
# (kfu/psi1/psi2), and the streaming jnp twins all call these, the same way
# every forward shares `_psi1_tile`/`_psi2_tile`: the ops differ only in how
# they build their branch weight, never in the cotangent algebra.

def _psi1_bwd_tile(mu, S, z1, l2, W1, *, ct):
    """Cotangent contributions of one (TN, TM) psi1-branch tile given branch
    weight W1 (eq. (8)): returns (dmu (TN, Q), dS (TN, Q), dz (TM, Q),
    dvraw scalar, dl (1, Q)) per eq. (10)-(14).

    `dvraw` is the raw weight total sum W1 — the caller divides by v
    (eq. (13)), which keeps v out of the tile entirely.
    """
    b = 1.0 / (l2 + S)
    ls = jnp.sqrt(l2)
    s1 = jnp.sum(W1, axis=1, keepdims=True)  # (TN, 1)
    W1Z = _dot(W1, z1, ((1,), (0,)), ct)  # (TN, Q)
    sq1 = mu * mu * s1 - 2.0 * mu * W1Z + _dot(W1, z1 * z1, ((1,), (0,)), ct)
    dmu = -b * (mu * s1 - W1Z)  # eq. (10)
    dS = -0.5 * b * s1 + 0.5 * b * b * sq1  # eq. (11)
    dz = (_dot(W1, mu * b, ((0,), (0,)), ct)
          - z1 * _dot(W1, b, ((0,), (0,)), ct))  # eq. (12)
    dvraw = jnp.sum(s1)  # eq. (13); the 1/v rides outside
    dl = jnp.sum((S * b / ls) * s1 + ls * b * b * sq1,
                 axis=0, keepdims=True)  # eq. (14)
    return dmu, dS, dz, dvraw, dl


def _psi2_bwd_tile(mu, S, z1, z2, l2, T, *, ct):
    """Cotangent contributions of one (TN, TM, TM) psi2-branch tile given
    branch weight T (eq. (9)): returns (dmu (TN, Q), dS (TN, Q),
    dz_i (TM, Q) — slot-a rows, dz_j (TM, Q) — slot-b rows, dvraw scalar,
    dl (1, Q)) per eq. (15)-(20).

    All T moments reduce to MXU contractions against z / z^2; nothing larger
    than T itself is ever live. `dvraw` is the raw weight total 2 sum T
    (eq. (19) without the 1/v, divided out by the caller).
    """
    tn, q_dim = mu.shape
    tm = z1.shape[0]
    ls = jnp.sqrt(l2)
    z1sq = z1 * z1
    z2sq = z2 * z2
    r = 1.0 / (l2 + 2.0 * S)
    row = jnp.sum(T, axis=2)  # (TN, TM)  sum over m' (slot b)
    col = jnp.sum(T, axis=1)  # (TN, TM)  sum over m  (slot a)
    t = jnp.sum(row, axis=1, keepdims=True)  # (TN, 1)
    # zbar moments (eq. (15)): u = sum_ab T zbar, w2 = sum_ab T zbar^2
    TZ2 = _dot(T.reshape(tn * tm, tm), z2, ((1,), (0,)), ct
               ).reshape(tn, tm, q_dim)
    TtZ1 = _dot(jnp.swapaxes(T, 1, 2).reshape(tn * tm, tm), z1,
                ((1,), (0,)), ct).reshape(tn, tm, q_dim)
    u = 0.5 * (_dot(row, z1, ((1,), (0,)), ct) + _dot(col, z2, ((1,), (0,)), ct))
    B = jnp.sum(z1[None, :, :] * TZ2, axis=1)  # (TN, Q) bilinear z^T T z
    w2 = 0.25 * (_dot(row, z1sq, ((1,), (0,)), ct)
                 + _dot(col, z2sq, ((1,), (0,)), ct)) + 0.5 * B
    V = mu * mu * t - 2.0 * mu * u + w2  # sum_ab T (mu - zbar)^2
    dmu = -2.0 * r * (mu * t - u)  # eq. (16)
    dS = -r * t + 2.0 * r * r * V  # eq. (17)
    dvraw = 2.0 * jnp.sum(t)  # eq. (19); the 1/v rides outside
    # eq. (20): dlengthscale — lognorm2 + exponent-r terms + the zterm term
    P = jnp.sum(T, axis=0)  # (TM, TM)
    Pr = jnp.sum(P, axis=1, keepdims=True)  # (TM, 1) row sums
    Pc = jnp.sum(P, axis=0, keepdims=True).T  # (TM, 1) column sums
    PZ2 = _dot(P, z2, ((1,), (0,)), ct)  # (TM, Q)
    PtZ1 = _dot(P, z1, ((0,), (0,)), ct)  # (TM, Q)
    # sum_ab P (z1_a - z2_b)^2 per q, factored through the P moments
    zd2 = (jnp.sum(Pr * z1sq, axis=0, keepdims=True)
           + jnp.sum(Pc * z2sq, axis=0, keepdims=True)
           - 2.0 * jnp.sum(z1 * PZ2, axis=0, keepdims=True))  # (1, Q)
    dl = ((2.0 / ls) * jnp.sum(S * r * t, axis=0, keepdims=True)
          + 2.0 * ls * jnp.sum(r * r * V, axis=0, keepdims=True)
          + zd2 / (2.0 * ls * l2))
    # eq. (18): dZ — slot-a rows (tile i) and slot-b rows (tile j)
    r_mu = r * mu
    dz_i = (_dot(row, r_mu, ((0,), (0,)), ct)
            - 0.5 * z1 * _dot(row, r, ((0,), (0,)), ct)
            - 0.5 * jnp.sum(r[:, None, :] * TZ2, axis=0)
            + (PZ2 - z1 * Pr) / (2.0 * l2))
    dz_j = (_dot(col, r_mu, ((0,), (0,)), ct)
            - 0.5 * z2 * _dot(col, r, ((0,), (0,)), ct)
            - 0.5 * jnp.sum(r[:, None, :] * TtZ1, axis=0)
            + (PtZ1 - z2 * Pc) / (2.0 * l2))
    return dmu, dS, dz_i, dz_j, dvraw, dl


# ---------------------------------------------------------------------------
# forward kernel
# ---------------------------------------------------------------------------

def _suffstats_kernel(mu_ref, s_ref, y_ref, w_ref, z1_ref, z2_ref, l2_ref,
                      psi2_ref, psiy_ref, *, ct=jnp.float32):
    j = pl.program_id(1)
    kn = pl.program_id(2)

    mu = mu_ref[...].astype(ct)  # (TN, Q)
    S = s_ref[...].astype(ct)
    y = y_ref[...].astype(ct)  # (TN, D)
    w = w_ref[...].astype(ct)  # (TN, 1)
    z1 = z1_ref[...].astype(ct)  # (TM, Q)
    z2 = z2_ref[...].astype(ct)
    l2 = l2_ref[...].astype(ct)  # (1, Q)

    tn = mu.shape[0]
    tm = z1.shape[0]

    # ---------------- psi2 tile (shared helper; eq. (6)-(7)) -------------
    _, E = _psi2_tile(mu, S, z1, z2, l2, ct=ct)
    # weighted datapoint reduction on the MXU: (1, TN) @ (TN, TM*TM)
    contrib2 = _dot(w.T, E.reshape(tn, tm * tm), ((1,), (0,)), ct
                    ).reshape(tm, tm)

    @pl.when(kn == 0)
    def _():
        psi2_ref[...] = contrib2

    @pl.when(kn > 0)
    def _():
        psi2_ref[...] += contrib2

    # ---------------- psiY tile (shared helper; eq. (2)-(3)) -------------
    @pl.when(j == 0)
    def _():
        _, blk = _psi1_tile(mu, S, z1, l2, ct=ct)
        psi1_blk = blk * w  # (TN, TM)
        contribY = _dot(psi1_blk, y, ((0,), (0,)), ct)  # (TM, D)

        @pl.when(kn == 0)
        def _():
            psiy_ref[...] = contribY

        @pl.when(kn > 0)
        def _():
            psiy_ref[...] += contribY


@functools.partial(jax.jit, static_argnames=("interpret", "block"))
def suffstats_pallas(mu, S, Y, Z, variance, lengthscale, *,
                     interpret: bool = False, block: tuple | None = None):
    """Returns (psi2 (M, M), psiY (M, D)) accumulated over all N.

    Compiled (TPU) execution computes in float32 — the hardware dtype the
    tile sizes are chosen for. Interpret mode keeps the input dtype instead:
    it exists to validate the kernel body, and under x64 that makes parity
    checks meaningful rather than epilogue-conditioning-limited.

    `block=(tile_n, tile_m)` overrides the module-constant tiles (the
    repro.tune knob); the wrapper pads to the block's multiple, so every
    candidate is numerically identical to the defaults.
    """
    tile_n, tile_m = block if block is not None else (TILE_N, TILE_M)
    N, Q = mu.shape
    M = Z.shape[0]
    D = Y.shape[1]
    ct = mu.dtype if interpret else jnp.float32
    pad_n = (-N) % tile_n
    pad_m = (-M) % tile_m
    mu_p = jnp.pad(mu.astype(ct), ((0, pad_n), (0, 0)))
    S_p = jnp.pad(S.astype(ct), ((0, pad_n), (0, 0)), constant_values=1.0)
    Y_p = jnp.pad(Y.astype(ct), ((0, pad_n), (0, 0)))
    w = jnp.pad(jnp.ones((N, 1), ct), ((0, pad_n), (0, 0)))
    Z_p = jnp.pad(Z.astype(ct), ((0, pad_m), (0, 0)))
    l2 = (lengthscale.astype(ct) ** 2)[None, :]
    Mp = Z_p.shape[0]

    grid = (Mp // tile_m, Mp // tile_m, mu_p.shape[0] // tile_n)
    acc2, accY = pl.pallas_call(
        functools.partial(_suffstats_kernel, ct=ct),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_n, Q), lambda i, j, kn: (kn, 0)),
            pl.BlockSpec((tile_n, Q), lambda i, j, kn: (kn, 0)),
            pl.BlockSpec((tile_n, D), lambda i, j, kn: (kn, 0)),
            pl.BlockSpec((tile_n, 1), lambda i, j, kn: (kn, 0)),
            pl.BlockSpec((tile_m, Q), lambda i, j, kn: (i, 0)),
            pl.BlockSpec((tile_m, Q), lambda i, j, kn: (j, 0)),
            pl.BlockSpec((1, Q), lambda i, j, kn: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tile_m, tile_m), lambda i, j, kn: (i, j)),
            pl.BlockSpec((tile_m, D), lambda i, j, kn: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Mp, Mp), ct),
            jax.ShapeDtypeStruct((Mp, D), ct),
        ],
        interpret=interpret,
    )(mu_p, S_p, Y_p, w, Z_p, Z_p, l2)

    zs = Z.astype(ct) / lengthscale.astype(ct)
    zn = jnp.sum(zs * zs, -1)
    d2 = jnp.maximum(zn[:, None] + zn[None, :] - 2.0 * zs @ zs.T, 0.0)
    pref2 = variance.astype(ct) ** 2 * jnp.exp(-0.25 * d2)
    psi2 = pref2 * acc2[:M, :M]
    psiY = variance.astype(ct) * accY[:M]
    return psi2, psiY


# ---------------------------------------------------------------------------
# reverse kernel: same tile structure, N axis outermost
# ---------------------------------------------------------------------------
#
# Grid (kn, i, j). For a fixed datapoint tile kn, the kernel sweeps every
# (i, j) pair of inducing tiles and accumulates the per-datapoint cotangent
# blocks (dmu, dS, dY — out index kn) in place; the global cotangents
# (dZ, dvariance, dlengthscale) are single whole-array output blocks (index
# constant across the grid) updated every iteration — the grid is sequential
# per core, so no synchronization exists or is needed (same argument as the
# forward's in-place psi2 accumulation).
#
# Equation numbers reference docs/derivations/suffstats_vjp.md. The branch
# weights are W1 (eq. (8), psi1/psiY branch) and T (eq. (9), psi2 branch);
# every cotangent is linear in them, so per-tile contributions simply add.

def _suffstats_bwd_kernel(mu_ref, s_ref, y_ref, w_ref, z1_ref, z2_ref,
                          l2_ref, g2p_ref, gyv_ref,
                          dmu_ref, ds_ref, dy_ref, dz_ref, dvraw_ref, dl_ref,
                          *, tile_m, ct=jnp.float32):
    kn = pl.program_id(0)
    i = pl.program_id(1)
    j = pl.program_id(2)
    first_mm = jnp.logical_and(i == 0, j == 0)

    mu = mu_ref[...].astype(ct)  # (TN, Q)
    S = s_ref[...].astype(ct)
    w = w_ref[...].astype(ct)  # (TN, 1)
    z1 = z1_ref[...].astype(ct)  # (TM, Q)
    z2 = z2_ref[...].astype(ct)
    l2 = l2_ref[...].astype(ct)  # (1, Q)
    g2p = g2p_ref[...].astype(ct)  # (TM, TM) = g2 * v^2 exp(zterm), padded 0

    # ---------------- psi2 branch: T = g2p . E . w  (eq. (9)) ------------
    _, E = _psi2_tile(mu, S, z1, z2, l2, ct=ct)
    T = g2p[None, :, :] * E * w[:, :, None]  # (TN, TM, TM)
    dmu_c, ds_c, dz_i, dz_j, dvraw_c, dl_c = _psi2_bwd_tile(
        mu, S, z1, z2, l2, T, ct=ct)

    # ---------------- accumulate: per-datapoint blocks -------------------
    @pl.when(first_mm)
    def _():
        dmu_ref[...] = dmu_c
        ds_ref[...] = ds_c

    @pl.when(jnp.logical_not(first_mm))
    def _():
        dmu_ref[...] += dmu_c
        ds_ref[...] += ds_c

    # ---------------- accumulate: global blocks --------------------------
    @pl.when(jnp.logical_and(kn == 0, first_mm))
    def _():
        dz_ref[...] = jnp.zeros(dz_ref.shape, ct)
        dvraw_ref[...] = jnp.zeros(dvraw_ref.shape, ct)
        dl_ref[...] = jnp.zeros(dl_ref.shape, ct)

    dz_ref[pl.dslice(i * tile_m, tile_m), :] += dz_i
    dz_ref[pl.dslice(j * tile_m, tile_m), :] += dz_j
    dvraw_ref[...] += dvraw_c
    dl_ref[...] += dl_c

    # ---------------- psi1/psiY branch (once per (kn, i); eq. (10)-(14)) -
    @pl.when(j == 0)
    def _():
        y = y_ref[...].astype(ct)  # (TN, D)
        gyv = gyv_ref[...].astype(ct)  # (TM, D) = v * gY, padded 0
        _, blk = _psi1_tile(mu, S, z1, l2, ct=ct)
        blk = blk * w  # psi1 / v, pad-masked
        W1 = _dot(y, gyv, ((1,), (1,)), ct) * blk  # (TN, TM)  eq. (8)
        dmu1, ds1, dz1, dvraw1, dl1 = _psi1_bwd_tile(mu, S, z1, l2, W1, ct=ct)
        dmu_ref[...] += dmu1
        ds_ref[...] += ds1
        dvraw_ref[...] += dvraw1
        dl_ref[...] += dl1
        dz_ref[pl.dslice(i * tile_m, tile_m), :] += dz1
        dy_c = _dot(blk, gyv, ((1,), (0,)), ct)  # (TN, D)

        @pl.when(i == 0)
        def _():
            dy_ref[...] = dy_c

        @pl.when(i > 0)
        def _():
            dy_ref[...] += dy_c


@functools.partial(jax.jit, static_argnames=("interpret", "block"))
def suffstats_bwd_pallas(mu, S, Y, Z, variance, lengthscale, g2, gY, *,
                         interpret: bool = False, block: tuple | None = None):
    """Pallas reverse pass of ``(psi2, psiY) = suffstats(...)``.

    Returns cotangents ``(dmu, dS, dY, dZ, dvariance, dlengthscale)`` given
    output cotangents ``g2 (M, M)`` and ``gY (M, D)``. Same dtype policy as
    the forward: compiled TPU execution computes in float32, interpret mode
    keeps the input dtype so f64 parity tests check the kernel body itself.

    The (m, m')-only psi2 prefactor v^2 exp(zterm) is folded into the
    cotangent outside the kernel (eq. (9)): the kernel sees
    G2p = g2 * v^2 exp(zterm), padded with zeros so padded inducing rows
    contribute nothing; gY is pre-scaled by v the same way. The variance
    cotangent leaves the kernel as the raw branch weight total
    sum W1 + 2 sum T (eq. (13)+(19)) and is divided by v here.

    `block=(tile_n, tile_m)` overrides the module-constant tiles (the
    repro.tune knob); padding makes any block choice numerically identical.
    """
    tile_n, tile_m = block if block is not None else (TILE_N, TILE_M)
    N, Q = mu.shape
    M = Z.shape[0]
    D = Y.shape[1]
    ct = mu.dtype if interpret else jnp.float32
    pad_n = (-N) % tile_n
    pad_m = (-M) % tile_m
    mu_p = jnp.pad(mu.astype(ct), ((0, pad_n), (0, 0)))
    S_p = jnp.pad(S.astype(ct), ((0, pad_n), (0, 0)), constant_values=1.0)
    Y_p = jnp.pad(Y.astype(ct), ((0, pad_n), (0, 0)))
    w = jnp.pad(jnp.ones((N, 1), ct), ((0, pad_n), (0, 0)))
    Z_p = jnp.pad(Z.astype(ct), ((0, pad_m), (0, 0)))
    l2 = (lengthscale.astype(ct) ** 2)[None, :]
    v = variance.astype(ct)

    zs = Z.astype(ct) / lengthscale.astype(ct)
    zn = jnp.sum(zs * zs, -1)
    d2 = jnp.maximum(zn[:, None] + zn[None, :] - 2.0 * zs @ zs.T, 0.0)
    g2p = jnp.pad(g2.astype(ct) * v**2 * jnp.exp(-0.25 * d2),
                  ((0, pad_m), (0, pad_m)))
    gyv = jnp.pad(v * gY.astype(ct), ((0, pad_m), (0, 0)))

    Np = mu_p.shape[0]
    Mp = Z_p.shape[0]
    grid = (Np // tile_n, Mp // tile_m, Mp // tile_m)
    dmu, dS, dY, dZ, dvraw, dl = pl.pallas_call(
        functools.partial(_suffstats_bwd_kernel, tile_m=tile_m, ct=ct),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_n, Q), lambda kn, i, j: (kn, 0)),  # mu
            pl.BlockSpec((tile_n, Q), lambda kn, i, j: (kn, 0)),  # S
            pl.BlockSpec((tile_n, D), lambda kn, i, j: (kn, 0)),  # Y
            pl.BlockSpec((tile_n, 1), lambda kn, i, j: (kn, 0)),  # w
            pl.BlockSpec((tile_m, Q), lambda kn, i, j: (i, 0)),  # Z (slot a)
            pl.BlockSpec((tile_m, Q), lambda kn, i, j: (j, 0)),  # Z (slot b)
            pl.BlockSpec((1, Q), lambda kn, i, j: (0, 0)),  # l^2
            pl.BlockSpec((tile_m, tile_m), lambda kn, i, j: (i, j)),  # G2p
            pl.BlockSpec((tile_m, D), lambda kn, i, j: (i, 0)),  # v * gY
        ],
        out_specs=[
            pl.BlockSpec((tile_n, Q), lambda kn, i, j: (kn, 0)),  # dmu
            pl.BlockSpec((tile_n, Q), lambda kn, i, j: (kn, 0)),  # dS
            pl.BlockSpec((tile_n, D), lambda kn, i, j: (kn, 0)),  # dY
            pl.BlockSpec((Mp, Q), lambda kn, i, j: (0, 0)),  # dZ (resident)
            pl.BlockSpec((1, 1), lambda kn, i, j: (0, 0)),  # dv_raw
            pl.BlockSpec((1, Q), lambda kn, i, j: (0, 0)),  # dl
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Np, Q), ct),
            jax.ShapeDtypeStruct((Np, Q), ct),
            jax.ShapeDtypeStruct((Np, D), ct),
            jax.ShapeDtypeStruct((Mp, Q), ct),
            jax.ShapeDtypeStruct((1, 1), ct),
            jax.ShapeDtypeStruct((1, Q), ct),
        ],
        interpret=interpret,
    )(mu_p, S_p, Y_p, w, Z_p, Z_p, l2, g2p, gyv)
    return (dmu[:N].astype(mu.dtype), dS[:N].astype(S.dtype),
            dY[:N].astype(Y.dtype), dZ[:M].astype(Z.dtype),
            (dvraw[0, 0] / v).astype(variance.dtype),
            dl[0].astype(lengthscale.dtype))


# ---------------------------------------------------------------------------
# single-statistic reverse kernels (kfu / psi1 / psi2)
# ---------------------------------------------------------------------------
#
# The single-statistic ops' reverse passes are specializations of the fused
# rules — the cotangent algebra is identical, only the branch weight changes
# (docs/derivations/suffstats_vjp.md §"Single-statistic specializations"):
#
#   psi1 op:  W1[n,m] = g1[n,m] · psi1[n,m]   (the output cotangent itself
#             weights psi1, where the fused op weights by gY·Y)
#   kfu op:   psi1 at S = 0 (psi1 IS the S-smoothed K_fu), dS discarded
#   psi2 op:  T exactly as the fused psi2 branch (eq. (9))
#
# so the kernels below are the fused reverse kernel with one branch removed,
# on the same tile helpers and the same grid/accumulation scheme.

def _psi1_bwd_kernel(mu_ref, s_ref, z_ref, l2_ref, gv_ref,
                     dmu_ref, ds_ref, dz_ref, dvraw_ref, dl_ref,
                     *, tile_m, ct=jnp.float32):
    kn = pl.program_id(0)
    i = pl.program_id(1)

    mu = mu_ref[...].astype(ct)  # (TN, Q)
    S = s_ref[...].astype(ct)
    z = z_ref[...].astype(ct)  # (TM, Q)
    l2 = l2_ref[...].astype(ct)  # (1, Q)
    gv = gv_ref[...].astype(ct)  # (TN, TM) = v * g, zero-padded both axes

    # shared forward tile: blk = psi1 / v; zero-padded gv rows/cols kill
    # every padded contribution, so no separate pad-weight input is needed
    _, blk = _psi1_tile(mu, S, z, l2, ct=ct)
    W1 = gv * blk  # eq. (8) specialized: W1 = g1 . psi1
    dmu_c, ds_c, dz_c, dvraw_c, dl_c = _psi1_bwd_tile(mu, S, z, l2, W1, ct=ct)

    @pl.when(i == 0)
    def _():
        dmu_ref[...] = dmu_c
        ds_ref[...] = ds_c

    @pl.when(i > 0)
    def _():
        dmu_ref[...] += dmu_c
        ds_ref[...] += ds_c

    @pl.when(jnp.logical_and(kn == 0, i == 0))
    def _():
        dz_ref[...] = jnp.zeros(dz_ref.shape, ct)
        dvraw_ref[...] = jnp.zeros(dvraw_ref.shape, ct)
        dl_ref[...] = jnp.zeros(dl_ref.shape, ct)

    dz_ref[pl.dslice(i * tile_m, tile_m), :] += dz_c
    dvraw_ref[...] += dvraw_c
    dl_ref[...] += dl_c


@functools.partial(jax.jit, static_argnames=("interpret", "block"))
def psi1_bwd_pallas(mu, S, Z, variance, lengthscale, g, *,
                    interpret: bool = False, block: tuple | None = None):
    """Pallas reverse pass of ``psi1 = psi1_pallas(...)``.

    Returns cotangents ``(dmu, dS, dZ, dvariance, dlengthscale)`` given the
    output cotangent ``g (N, M)``. Grid (kn, i): per-datapoint blocks
    (dmu, dS) accumulate the inducing tiles in place, the global cotangents
    (dZ, dvariance, dlengthscale) live in constant-index VMEM-resident
    blocks — the fused reverse kernel's scheme with the psi2 branch removed.
    v is folded into the cotangent (gv = v * g) so it never enters the
    kernel; the raw variance weight sum W1 is divided by v here (eq. (13)).
    Interpret-mode dtype policy matches the single-statistic forwards:
    computes in the input dtype promoted to at least f32.

    `block=(tile_n, tile_m)` overrides the module-constant tiles (the
    repro.tune knob); padding makes any block choice numerically identical.
    """
    tile_n, tile_m = block if block is not None else (TILE_N, TILE_M)
    N, Q = mu.shape
    M = Z.shape[0]
    ct = jnp.promote_types(mu.dtype, jnp.float32) if interpret else jnp.float32
    pad_n = (-N) % tile_n
    pad_m = (-M) % tile_m
    mu_p = jnp.pad(mu.astype(ct), ((0, pad_n), (0, 0)))
    S_p = jnp.pad(S.astype(ct), ((0, pad_n), (0, 0)), constant_values=1.0)
    Z_p = jnp.pad(Z.astype(ct), ((0, pad_m), (0, 0)))
    l2 = (lengthscale.astype(ct) ** 2)[None, :]
    v = variance.astype(ct)
    gv = jnp.pad(v * g.astype(ct), ((0, pad_n), (0, pad_m)))

    Np = mu_p.shape[0]
    Mp = Z_p.shape[0]
    grid = (Np // tile_n, Mp // tile_m)
    dmu, dS, dZ, dvraw, dl = pl.pallas_call(
        functools.partial(_psi1_bwd_kernel, tile_m=tile_m, ct=ct),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_n, Q), lambda kn, i: (kn, 0)),  # mu
            pl.BlockSpec((tile_n, Q), lambda kn, i: (kn, 0)),  # S
            pl.BlockSpec((tile_m, Q), lambda kn, i: (i, 0)),  # Z
            pl.BlockSpec((1, Q), lambda kn, i: (0, 0)),  # l^2
            pl.BlockSpec((tile_n, tile_m), lambda kn, i: (kn, i)),  # v * g
        ],
        out_specs=[
            pl.BlockSpec((tile_n, Q), lambda kn, i: (kn, 0)),  # dmu
            pl.BlockSpec((tile_n, Q), lambda kn, i: (kn, 0)),  # dS
            pl.BlockSpec((Mp, Q), lambda kn, i: (0, 0)),  # dZ (resident)
            pl.BlockSpec((1, 1), lambda kn, i: (0, 0)),  # dv_raw
            pl.BlockSpec((1, Q), lambda kn, i: (0, 0)),  # dl
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Np, Q), ct),
            jax.ShapeDtypeStruct((Np, Q), ct),
            jax.ShapeDtypeStruct((Mp, Q), ct),
            jax.ShapeDtypeStruct((1, 1), ct),
            jax.ShapeDtypeStruct((1, Q), ct),
        ],
        interpret=interpret,
    )(mu_p, S_p, Z_p, l2, gv)
    return (dmu[:N].astype(mu.dtype), dS[:N].astype(S.dtype),
            dZ[:M].astype(Z.dtype), (dvraw[0, 0] / v).astype(variance.dtype),
            dl[0].astype(lengthscale.dtype))


def kfu_bwd_pallas(X, Z, variance, lengthscale, g, *, interpret: bool = False,
                   block: tuple | None = None):
    """Pallas reverse pass of ``Kfu = kfu_pallas(...)``: the S -> 0
    specialization of the psi1 reverse kernel (K_fu is psi1 with zero
    latent variance; suffstats_vjp.md §"Exact statistics"). Returns
    ``(dX, dZ, dvariance, dlengthscale)``."""
    dX, _, dZ, dv, dl = psi1_bwd_pallas(X, jnp.zeros_like(X), Z, variance,
                                        lengthscale, g, interpret=interpret,
                                        block=block)
    return dX, dZ, dv, dl


def _psi2_bwd_kernel(mu_ref, s_ref, w_ref, z1_ref, z2_ref, l2_ref, g2p_ref,
                     dmu_ref, ds_ref, dz_ref, dvraw_ref, dl_ref,
                     *, tile_m, ct=jnp.float32):
    kn = pl.program_id(0)
    i = pl.program_id(1)
    j = pl.program_id(2)
    first_mm = jnp.logical_and(i == 0, j == 0)

    mu = mu_ref[...].astype(ct)  # (TN, Q)
    S = s_ref[...].astype(ct)
    w = w_ref[...].astype(ct)  # (TN, 1)
    z1 = z1_ref[...].astype(ct)  # (TM, Q)
    z2 = z2_ref[...].astype(ct)
    l2 = l2_ref[...].astype(ct)  # (1, Q)
    g2p = g2p_ref[...].astype(ct)  # (TM, TM) = g2 * v^2 exp(zterm), padded 0

    # the fused kernel's psi2 branch, verbatim: same shared helpers
    _, E = _psi2_tile(mu, S, z1, z2, l2, ct=ct)
    T = g2p[None, :, :] * E * w[:, :, None]  # (TN, TM, TM)  eq. (9)
    dmu_c, ds_c, dz_i, dz_j, dvraw_c, dl_c = _psi2_bwd_tile(
        mu, S, z1, z2, l2, T, ct=ct)

    @pl.when(first_mm)
    def _():
        dmu_ref[...] = dmu_c
        ds_ref[...] = ds_c

    @pl.when(jnp.logical_not(first_mm))
    def _():
        dmu_ref[...] += dmu_c
        ds_ref[...] += ds_c

    @pl.when(jnp.logical_and(kn == 0, first_mm))
    def _():
        dz_ref[...] = jnp.zeros(dz_ref.shape, ct)
        dvraw_ref[...] = jnp.zeros(dvraw_ref.shape, ct)
        dl_ref[...] = jnp.zeros(dl_ref.shape, ct)

    dz_ref[pl.dslice(i * tile_m, tile_m), :] += dz_i
    dz_ref[pl.dslice(j * tile_m, tile_m), :] += dz_j
    dvraw_ref[...] += dvraw_c
    dl_ref[...] += dl_c


@functools.partial(jax.jit, static_argnames=("interpret", "block"))
def psi2_bwd_pallas(mu, S, Z, variance, lengthscale, g2, *,
                    interpret: bool = False, block: tuple | None = None):
    """Pallas reverse pass of ``psi2 = psi2_pallas(...)``.

    Returns cotangents ``(dmu, dS, dZ, dvariance, dlengthscale)`` given the
    output cotangent ``g2 (M, M)``. This is `suffstats_bwd_pallas` with the
    psi1/psiY branch removed: same grid (kn, i, j), same per-datapoint /
    VMEM-resident output split, same folded prefactor
    G2p = g2 * v^2 exp(zterm) (eq. (9)) padded with zeros. Interpret-mode
    dtype policy matches the single-statistic forwards.

    `block=(tile_n, tile_m)` overrides the module-constant tiles (the
    repro.tune knob); padding makes any block choice numerically identical.
    """
    tile_n, tile_m = block if block is not None else (TILE_N, TILE_M)
    N, Q = mu.shape
    M = Z.shape[0]
    ct = jnp.promote_types(mu.dtype, jnp.float32) if interpret else jnp.float32
    pad_n = (-N) % tile_n
    pad_m = (-M) % tile_m
    mu_p = jnp.pad(mu.astype(ct), ((0, pad_n), (0, 0)))
    S_p = jnp.pad(S.astype(ct), ((0, pad_n), (0, 0)), constant_values=1.0)
    w = jnp.pad(jnp.ones((N, 1), ct), ((0, pad_n), (0, 0)))
    Z_p = jnp.pad(Z.astype(ct), ((0, pad_m), (0, 0)))
    l2 = (lengthscale.astype(ct) ** 2)[None, :]
    v = variance.astype(ct)

    zs = Z.astype(ct) / lengthscale.astype(ct)
    zn = jnp.sum(zs * zs, -1)
    d2 = jnp.maximum(zn[:, None] + zn[None, :] - 2.0 * zs @ zs.T, 0.0)
    g2p = jnp.pad(g2.astype(ct) * v**2 * jnp.exp(-0.25 * d2),
                  ((0, pad_m), (0, pad_m)))

    Np = mu_p.shape[0]
    Mp = Z_p.shape[0]
    grid = (Np // tile_n, Mp // tile_m, Mp // tile_m)
    dmu, dS, dZ, dvraw, dl = pl.pallas_call(
        functools.partial(_psi2_bwd_kernel, tile_m=tile_m, ct=ct),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_n, Q), lambda kn, i, j: (kn, 0)),  # mu
            pl.BlockSpec((tile_n, Q), lambda kn, i, j: (kn, 0)),  # S
            pl.BlockSpec((tile_n, 1), lambda kn, i, j: (kn, 0)),  # w
            pl.BlockSpec((tile_m, Q), lambda kn, i, j: (i, 0)),  # Z (slot a)
            pl.BlockSpec((tile_m, Q), lambda kn, i, j: (j, 0)),  # Z (slot b)
            pl.BlockSpec((1, Q), lambda kn, i, j: (0, 0)),  # l^2
            pl.BlockSpec((tile_m, tile_m), lambda kn, i, j: (i, j)),  # G2p
        ],
        out_specs=[
            pl.BlockSpec((tile_n, Q), lambda kn, i, j: (kn, 0)),  # dmu
            pl.BlockSpec((tile_n, Q), lambda kn, i, j: (kn, 0)),  # dS
            pl.BlockSpec((Mp, Q), lambda kn, i, j: (0, 0)),  # dZ (resident)
            pl.BlockSpec((1, 1), lambda kn, i, j: (0, 0)),  # dv_raw
            pl.BlockSpec((1, Q), lambda kn, i, j: (0, 0)),  # dl
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Np, Q), ct),
            jax.ShapeDtypeStruct((Np, Q), ct),
            jax.ShapeDtypeStruct((Mp, Q), ct),
            jax.ShapeDtypeStruct((1, 1), ct),
            jax.ShapeDtypeStruct((1, Q), ct),
        ],
        interpret=interpret,
    )(mu_p, S_p, w, Z_p, Z_p, l2, g2p)
    return (dmu[:N].astype(mu.dtype), dS[:N].astype(S.dtype),
            dZ[:M].astype(Z.dtype), (dvraw[0, 0] / v).astype(variance.dtype),
            dl[0].astype(lengthscale.dtype))


# ---------------------------------------------------------------------------
# streaming jnp twin of the forward kernel (off-TPU large-N path)
# ---------------------------------------------------------------------------

def _pad_stream(mu, S, Y, chunk):
    """Pad the N axis to a chunk multiple; returns per-chunk xs + weights."""
    N, Q = mu.shape
    D = Y.shape[1]
    pad = (-N) % chunk
    mu_p = jnp.pad(mu, ((0, pad), (0, 0)))
    # pad S with ones (any positive value) and mask via weight w
    S_p = jnp.pad(S, ((0, pad), (0, 0)), constant_values=1.0)
    Y_p = jnp.pad(Y, ((0, pad), (0, 0)))
    w = jnp.pad(jnp.ones((N,), mu.dtype), ((0, pad),))
    k = (N + pad) // chunk
    return (mu_p.reshape(k, chunk, Q), S_p.reshape(k, chunk, Q),
            Y_p.reshape(k, chunk, D), w.reshape(k, chunk))


def _psi1_weighted(mu_i, S_i, w_i, Z, l2):
    """psi1 block / variance with pad weights folded in: returns
    (b (chunk, Q), blk (chunk, M)).

    A wrapper over the shared `_psi1_tile` — the streaming forward, the
    hand-derived VJP, and the Pallas kernels all evaluate the identical
    expression, or the registered gradient would be wrong.
    """
    b, blk = _psi1_tile(mu_i, S_i, Z, l2[None, :], ct=mu_i.dtype)
    return b, blk * w_i[:, None]


def _psi2_weighted(mu_i, S_i, w_i, zbar, l2):
    """Per-point psi2 factor exp(lognorm2 + e2) (without the v^2 exp(zterm)
    prefactor), pad weights folded in: returns (r (chunk, Q), E (chunk, M, M)).
    Shared by the streaming forward and the hand-derived VJP (see above)."""
    Q = mu_i.shape[1]
    M = zbar.shape[0]
    r = 1.0 / (l2[None, :] + 2.0 * S_i)
    lognorm2 = -0.5 * jnp.sum(jnp.log1p(2.0 * S_i / l2[None, :]), axis=-1)
    expo = jnp.zeros((mu_i.shape[0], M, M), mu_i.dtype)
    for q in range(Q):  # Q is small (latent dim); unrolled
        dq = mu_i[:, None, None, q] - zbar[None, :, :, q]
        expo = expo - dq * dq * r[:, None, None, q]
    return r, jnp.exp(lognorm2[:, None, None] + expo) * w_i[:, None, None]


def suffstats_fused_jnp(mu, S, Y, Z, variance, lengthscale, *, chunk: int = 1024):
    """(psi2 (M, M), psiY (M, D)) by one streaming jnp pass over N — the same
    math and accumulation order as `suffstats_pallas`, O(chunk * M^2) live."""
    N, Q = mu.shape
    M = Z.shape[0]
    D = Y.shape[1]
    l2 = lengthscale**2
    zdiff = Z[:, None, :] - Z[None, :, :]
    zterm = -jnp.sum(zdiff**2 / (4.0 * l2), axis=-1)  # (M, M)
    zbar = 0.5 * (Z[:, None, :] + Z[None, :, :])

    xs = _pad_stream(mu, S, Y, chunk)

    def body(acc, x):
        mu_i, S_i, Y_i, w_i = x
        acc2, accY = acc
        _, psi1_blk = _psi1_weighted(mu_i, S_i, w_i, Z, l2)  # (chunk, M)
        accY = accY + variance * psi1_blk.T @ Y_i
        _, E = _psi2_weighted(mu_i, S_i, w_i, zbar, l2)  # (chunk, M, M)
        acc2 = acc2 + jnp.sum(E, axis=0)
        return (acc2, accY), None

    # `+ 0 * mu[0, 0]` inherits mu's varying-manual-axes type so the scan
    # carry is well-typed when this runs inside shard_map (see shard_map-vma).
    vma = 0.0 * mu[0, 0]
    acc0 = (jnp.zeros((M, M), mu.dtype) + vma, jnp.zeros((M, D), mu.dtype) + vma)
    (acc2, accY), _ = jax.lax.scan(body, acc0, xs)
    return variance**2 * jnp.exp(zterm) * acc2, accY


# ---------------------------------------------------------------------------
# hand-derived reverse pass as a streaming jnp scan over N
# ---------------------------------------------------------------------------
#
# Same algebra as the Pallas reverse kernel above (equation numbers from
# docs/derivations/suffstats_vjp.md), expressed as a second streaming kernel
# over N: per-datapoint cotangents (dmu, dS, dY) leave chunk by chunk,
# global cotangents (dZ, dvariance, dlengthscale) ride the scan carry. Peak
# live memory is O(chunk * M^2), matching the forward. Since z1 == z2 == Z
# here, the two dZ slot contributions of eq. (18) are evaluated in their
# symmetrized form (T + T^T).

def suffstats_vjp_jnp(mu, S, Y, Z, variance, lengthscale, g2, gY, *,
                      chunk: int = 512):
    """Hand-derived VJP of ``(psi2, psiY) = suffstats(...)``.

    Returns cotangents ``(dmu, dS, dY, dZ, dvariance, dlengthscale)``.
    Validated against jax.grad of the jnp reference formulas in
    tests/test_streaming.py and tests/test_suffstats_bwd.py.
    """
    N, Q = mu.shape
    M = Z.shape[0]
    dt = mu.dtype
    v = variance.astype(dt)
    ls = lengthscale.astype(dt)
    l2 = ls**2
    g2 = g2.astype(dt)
    gY = gY.astype(dt)
    zdiff = Z[:, None, :] - Z[None, :, :]  # (M, M, Q)
    zterm = -jnp.sum(zdiff**2 / (4.0 * l2), axis=-1)
    zbar = 0.5 * (Z[:, None, :] + Z[None, :, :])
    # fold the (m, m')-only psi2 prefactor v^2 exp(zterm) into the cotangent
    G2p = g2 * v**2 * jnp.exp(zterm)  # (M, M)  — eq. (9)
    Z2 = Z * Z

    xs = _pad_stream(mu, S, Y, chunk)

    def body(carry, x):
        dZ_a, dv_a, dl_a = carry
        mu_i, S_i, Y_i, w_i = x
        # ---------------- psi1 branch (eq. (8), (10)-(14)) ----------------
        b, blk = _psi1_weighted(mu_i, S_i, w_i, Z, l2)  # (c, Q), (c, M)
        psi1w = v * blk  # (c, M)
        W1 = (Y_i @ gY.T) * psi1w  # (c, M)  — eq. (8)
        dY_i = psi1w @ gY  # (c, D)
        s1 = jnp.sum(W1, axis=1)  # (c,)
        W1Z = W1 @ Z  # (c, Q)
        # sum_m W1 (mu - z_m)^2, factored through Z moments
        sq1 = mu_i**2 * s1[:, None] - 2.0 * mu_i * W1Z + W1 @ Z2
        dmu_i = -b * (mu_i * s1[:, None] - W1Z)  # eq. (10)
        dS_i = -0.5 * b * s1[:, None] + 0.5 * b * b * sq1  # eq. (11)
        dZ_c = W1.T @ (mu_i * b) - Z * (W1.T @ b)  # (M, Q)  — eq. (12)
        dv_c = jnp.sum(s1) / v  # eq. (13)
        dl_c = jnp.sum((S_i * b / ls) * s1[:, None] + ls * b * b * sq1,
                       axis=0)  # eq. (14)
        # ---------------- psi2 branch (eq. (9), (15)-(20)) ----------------
        r, E = _psi2_weighted(mu_i, S_i, w_i, zbar, l2)  # (c, Q), (c, M, M)
        T = G2p[None, :, :] * E  # (c, M, M)  — eq. (9)
        t = jnp.sum(T, axis=(1, 2))  # (c,)
        rc = jnp.sum(T, axis=2) + jnp.sum(T, axis=1)  # (c, M) row + col sums
        u = 0.5 * rc @ Z  # (c, Q): sum_mm' T zbar        — eq. (15)
        B = jnp.einsum("nab,aq,bq->nq", T, Z, Z)  # (c, Q) bilinear z^T T z
        w2 = 0.25 * (rc @ Z2) + 0.5 * B  # sum_mm' T zbar^2
        V = mu_i**2 * t[:, None] - 2.0 * mu_i * u + w2  # sum_mm' T (mu-zbar)^2
        dmu_i = dmu_i - 2.0 * r * (mu_i * t[:, None] - u)  # eq. (16)
        dS_i = dS_i - r * t[:, None] + 2.0 * r * r * V  # eq. (17)
        # eq. (18), symmetrized: zbar appears in both slots — symmetrize T
        # once, then the two slot sums collapse to a single contraction
        # (psi2_n is m<->m' even).
        Ts = T + jnp.swapaxes(T, 1, 2)
        Ps = jnp.sum(Ts, axis=0)  # (M, M)
        dZ_c = dZ_c - (Z * jnp.sum(Ps, axis=1)[:, None] - Ps @ Z) / (2.0 * l2)
        dZ_c = dZ_c + jnp.einsum("nk,nq->kq", rc, r * mu_i) \
            - 0.5 * Z * jnp.einsum("nk,nq->kq", rc, r) \
            - 0.5 * jnp.einsum("nkm,mq,nq->kq", Ts, Z, r)
        dv_c = dv_c + 2.0 * jnp.sum(t) / v  # eq. (19)
        dl_c = dl_c + (2.0 / ls) * jnp.sum((S_i * r) * t[:, None], axis=0) \
            + 2.0 * ls * jnp.sum(r * r * V, axis=0) \
            + jnp.einsum("ab,abq->q", jnp.sum(T, axis=0), zdiff**2) \
            / (2.0 * ls**3)  # eq. (20)
        return (dZ_a + dZ_c, dv_a + dv_c, dl_a + dl_c), (dmu_i, dS_i, dY_i)

    vma = 0.0 * mu[0, 0]
    # dvariance rides the carry as (1,): rank-0 scan carries trip this jax
    # version's shard_map transpose spec check (see gp/stats.py)
    carry0 = (jnp.zeros((M, Q), dt) + vma, jnp.zeros((1,), dt) + vma,
              jnp.zeros((Q,), dt) + vma)
    (dZ, dv, dl), (dmu_s, dS_s, dY_s) = jax.lax.scan(body, carry0, xs)
    dmu = dmu_s.reshape(-1, Q)[:N]
    dS = dS_s.reshape(-1, Q)[:N]
    dY = dY_s.reshape(-1, Y.shape[1])[:N]
    return (dmu.astype(mu.dtype), dS.astype(S.dtype), dY.astype(Y.dtype),
            dZ.astype(Z.dtype), dv[0].astype(variance.dtype),
            dl.astype(lengthscale.dtype))


# ---------------------------------------------------------------------------
# streaming jnp twins of the single-statistic reverse passes
# ---------------------------------------------------------------------------
#
# The off-TPU large-N backward of the kfu/psi1/psi2 ops: the same tile
# helpers the Pallas reverse kernels call, driven by a lax.scan over N
# chunks instead of a grid. Per-datapoint cotangents (dmu, dS) leave chunk
# by chunk; global cotangents (dZ, dvariance, dlengthscale) ride the carry.
# Peak live memory is O(chunk * M) for psi1/kfu and O(chunk * M^2) for
# psi2 — never an (N, M, Q) reference-formula residual.

def psi1_vjp_jnp(mu, S, Z, variance, lengthscale, g, *, chunk: int = 512):
    """Hand-derived VJP of ``psi1 = psi1_rbf(...)`` as a streaming scan.

    Returns cotangents ``(dmu, dS, dZ, dvariance, dlengthscale)`` given the
    output cotangent ``g (N, M)``.
    """
    N, Q = mu.shape
    M = Z.shape[0]
    dt = jnp.promote_types(mu.dtype, jnp.float32)
    v = variance.astype(dt)
    ls = lengthscale.astype(dt)
    l2 = (ls**2)[None, :]
    Zc = Z.astype(dt)
    pad = (-N) % chunk
    mu_p = jnp.pad(mu.astype(dt), ((0, pad), (0, 0)))
    S_p = jnp.pad(S.astype(dt), ((0, pad), (0, 0)), constant_values=1.0)
    # zero-padded cotangent rows kill every padded contribution (eq. (8))
    gv_p = jnp.pad(v * g.astype(dt), ((0, pad), (0, 0)))
    k = (N + pad) // chunk
    xs = (mu_p.reshape(k, chunk, Q), S_p.reshape(k, chunk, Q),
          gv_p.reshape(k, chunk, M))

    def body(carry, x):
        dZ_a, dv_a, dl_a = carry
        mu_i, S_i, gv_i = x
        _, blk = _psi1_tile(mu_i, S_i, Zc, l2, ct=dt)  # psi1 / v
        W1 = gv_i * blk  # eq. (8) specialized: W1 = g1 . psi1
        dmu_i, dS_i, dz_c, dvraw_c, dl_c = _psi1_bwd_tile(
            mu_i, S_i, Zc, l2, W1, ct=dt)
        return (dZ_a + dz_c, dv_a + dvraw_c[None], dl_a + dl_c[0]), \
            (dmu_i, dS_i)

    vma = 0.0 * mu_p[0, 0]
    # dvariance rides the carry as (1,) — see suffstats_vjp_jnp
    carry0 = (jnp.zeros((M, Q), dt) + vma, jnp.zeros((1,), dt) + vma,
              jnp.zeros((Q,), dt) + vma)
    (dZ, dvraw, dl), (dmu_s, dS_s) = jax.lax.scan(body, carry0, xs)
    return (dmu_s.reshape(-1, Q)[:N].astype(mu.dtype),
            dS_s.reshape(-1, Q)[:N].astype(S.dtype),
            dZ.astype(Z.dtype), (dvraw[0] / v).astype(variance.dtype),
            dl.astype(lengthscale.dtype))


def kfu_vjp_jnp(X, Z, variance, lengthscale, g, *, chunk: int = 512):
    """Hand-derived VJP of ``Kfu = kfu_rbf(...)``: the S -> 0 specialization
    of the psi1 twin. Returns ``(dX, dZ, dvariance, dlengthscale)``."""
    dX, _, dZ, dv, dl = psi1_vjp_jnp(X, jnp.zeros_like(X), Z, variance,
                                     lengthscale, g, chunk=chunk)
    return dX, dZ, dv, dl


def psi2_vjp_jnp(mu, S, Z, variance, lengthscale, g2, *, chunk: int = 512):
    """Hand-derived VJP of ``psi2 = psi2_rbf(...)`` as a streaming scan.

    Returns cotangents ``(dmu, dS, dZ, dvariance, dlengthscale)`` given the
    output cotangent ``g2 (M, M)``. Since z1 == z2 == Z, the two dZ slot
    contributions of eq. (18) are summed.
    """
    N, Q = mu.shape
    M = Z.shape[0]
    dt = jnp.promote_types(mu.dtype, jnp.float32)
    v = variance.astype(dt)
    ls = lengthscale.astype(dt)
    l2 = (ls**2)[None, :]
    Zc = Z.astype(dt)
    zs = Zc / ls
    zn = jnp.sum(zs * zs, -1)
    d2 = jnp.maximum(zn[:, None] + zn[None, :] - 2.0 * zs @ zs.T, 0.0)
    # fold the (m, m')-only prefactor v^2 exp(zterm) into the cotangent
    G2p = g2.astype(dt) * v**2 * jnp.exp(-0.25 * d2)  # (M, M)  — eq. (9)

    pad = (-N) % chunk
    mu_p = jnp.pad(mu.astype(dt), ((0, pad), (0, 0)))
    S_p = jnp.pad(S.astype(dt), ((0, pad), (0, 0)), constant_values=1.0)
    w = jnp.pad(jnp.ones((N,), dt), ((0, pad),))
    k = (N + pad) // chunk
    xs = (mu_p.reshape(k, chunk, Q), S_p.reshape(k, chunk, Q),
          w.reshape(k, chunk))

    def body(carry, x):
        dZ_a, dv_a, dl_a = carry
        mu_i, S_i, w_i = x
        _, E = _psi2_tile(mu_i, S_i, Zc, Zc, l2, ct=dt)  # (c, M, M)
        T = G2p[None, :, :] * E * w_i[:, None, None]  # eq. (9)
        dmu_i, dS_i, dz_i, dz_j, dvraw_c, dl_c = _psi2_bwd_tile(
            mu_i, S_i, Zc, Zc, l2, T, ct=dt)
        return (dZ_a + dz_i + dz_j, dv_a + dvraw_c[None], dl_a + dl_c[0]), \
            (dmu_i, dS_i)

    vma = 0.0 * mu_p[0, 0]
    carry0 = (jnp.zeros((M, Q), dt) + vma, jnp.zeros((1,), dt) + vma,
              jnp.zeros((Q,), dt) + vma)
    (dZ, dvraw, dl), (dmu_s, dS_s) = jax.lax.scan(body, carry0, xs)
    return (dmu_s.reshape(-1, Q)[:N].astype(mu.dtype),
            dS_s.reshape(-1, Q)[:N].astype(S.dtype),
            dZ.astype(Z.dtype), (dvraw[0] / v).astype(variance.dtype),
            dl.astype(lengthscale.dtype))
