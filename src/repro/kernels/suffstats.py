"""Fused Pallas TPU kernel: ALL sufficient statistics in one pass over N
(beyond-paper optimization C3, EXPERIMENTS.md §Perf).

The paper computes Psi1 and Psi2 in separate GPU kernels (Table 1); the
bound only ever consumes psiY = Psi1^T Y and Psi2, so this kernel streams
each datapoint once and accumulates BOTH:

    psiY[m, :]   += psi1[n, m] * y[n, :]
    acc2[m, m']  += exp(lognorm2_n + muterm_n,m,m')

Removing the second pass halves HBM reads of (mu, S) and never materializes
the (N, M) Psi1 matrix. Grid = (M/TM, M/TM, N/TN) with the N axis innermost
(sequential accumulation); psiY accumulates only on the j == 0 column of the
grid so it is added exactly once per (m-tile, n-tile).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_N = 32
TILE_M = 128


def _suffstats_kernel(mu_ref, s_ref, y_ref, w_ref, z1_ref, z2_ref, l2_ref,
                      psi2_ref, psiy_ref):
    j = pl.program_id(1)
    kn = pl.program_id(2)

    mu = mu_ref[...].astype(jnp.float32)  # (TN, Q)
    S = s_ref[...].astype(jnp.float32)
    y = y_ref[...].astype(jnp.float32)  # (TN, D)
    w = w_ref[...].astype(jnp.float32)  # (TN, 1)
    z1 = z1_ref[...].astype(jnp.float32)  # (TM, Q)
    z2 = z2_ref[...].astype(jnp.float32)
    l2 = l2_ref[...].astype(jnp.float32)  # (1, Q)

    tn, q_dim = mu.shape
    tm = z1.shape[0]

    # ---------------- psi2 tile (same math as kernels/psi2.py) ----------
    r = 1.0 / (l2 + 2.0 * S)
    lognorm2 = -0.5 * jnp.sum(jnp.log1p(2.0 * S / l2), axis=-1, keepdims=True)
    c2 = jnp.sum(mu * mu * r, axis=-1, keepdims=True)
    mur = mu * r

    def halfterm(z):
        a = jax.lax.dot_general(mur, z, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        b = jax.lax.dot_general(r, z * z, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        return a - 0.25 * b

    A1 = halfterm(z1)
    A2 = halfterm(z2)
    cross = jnp.zeros((tn, tm, tm), jnp.float32)
    for q in range(q_dim):
        cross = cross + (r[:, q][:, None, None] * z1[:, q][None, :, None]
                         * z2[:, q][None, None, :])
    E = jnp.exp((lognorm2 - c2)[:, :, None] + A1[:, :, None] + A2[:, None, :]
                - 0.5 * cross)
    contrib2 = jax.lax.dot_general(
        w.T, E.reshape(tn, tm * tm), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).reshape(tm, tm)

    @pl.when(kn == 0)
    def _():
        psi2_ref[...] = contrib2

    @pl.when(kn > 0)
    def _():
        psi2_ref[...] += contrib2

    # ---------------- psiY tile (psi1 MXU factorization) ----------------
    @pl.when(j == 0)
    def _():
        b = 1.0 / (l2 + S)
        lognorm1 = -0.5 * jnp.sum(jnp.log1p(S / l2), axis=-1, keepdims=True)
        c1 = jnp.sum(mu * mu * b, axis=-1, keepdims=True)
        mub_zt = jax.lax.dot_general(mu * b, z1, (((1,), (1,)), ((), ())),
                                     preferred_element_type=jnp.float32)
        b_z2t = jax.lax.dot_general(b, z1 * z1, (((1,), (1,)), ((), ())),
                                    preferred_element_type=jnp.float32)
        psi1_blk = jnp.exp(lognorm1 - 0.5 * (c1 - 2.0 * mub_zt + b_z2t)) * w  # (TN, TM)
        contribY = jax.lax.dot_general(psi1_blk, y, (((0,), (0,)), ((), ())),
                                       preferred_element_type=jnp.float32)  # (TM, D)

        @pl.when(kn == 0)
        def _():
            psiy_ref[...] = contribY

        @pl.when(kn > 0)
        def _():
            psiy_ref[...] += contribY


@functools.partial(jax.jit, static_argnames=("interpret",))
def suffstats_pallas(mu, S, Y, Z, variance, lengthscale, *, interpret: bool = False):
    """Returns (psi2 (M, M), psiY (M, D)) accumulated over all N."""
    N, Q = mu.shape
    M = Z.shape[0]
    D = Y.shape[1]
    pad_n = (-N) % TILE_N
    pad_m = (-M) % TILE_M
    mu_p = jnp.pad(mu.astype(jnp.float32), ((0, pad_n), (0, 0)))
    S_p = jnp.pad(S.astype(jnp.float32), ((0, pad_n), (0, 0)), constant_values=1.0)
    Y_p = jnp.pad(Y.astype(jnp.float32), ((0, pad_n), (0, 0)))
    w = jnp.pad(jnp.ones((N, 1), jnp.float32), ((0, pad_n), (0, 0)))
    Z_p = jnp.pad(Z.astype(jnp.float32), ((0, pad_m), (0, 0)))
    l2 = (lengthscale.astype(jnp.float32) ** 2)[None, :]
    Mp = Z_p.shape[0]

    grid = (Mp // TILE_M, Mp // TILE_M, mu_p.shape[0] // TILE_N)
    acc2, accY = pl.pallas_call(
        _suffstats_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_N, Q), lambda i, j, kn: (kn, 0)),
            pl.BlockSpec((TILE_N, Q), lambda i, j, kn: (kn, 0)),
            pl.BlockSpec((TILE_N, D), lambda i, j, kn: (kn, 0)),
            pl.BlockSpec((TILE_N, 1), lambda i, j, kn: (kn, 0)),
            pl.BlockSpec((TILE_M, Q), lambda i, j, kn: (i, 0)),
            pl.BlockSpec((TILE_M, Q), lambda i, j, kn: (j, 0)),
            pl.BlockSpec((1, Q), lambda i, j, kn: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((TILE_M, TILE_M), lambda i, j, kn: (i, j)),
            pl.BlockSpec((TILE_M, D), lambda i, j, kn: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Mp, Mp), jnp.float32),
            jax.ShapeDtypeStruct((Mp, D), jnp.float32),
        ],
        interpret=interpret,
    )(mu_p, S_p, Y_p, w, Z_p, Z_p, l2)

    zs = Z.astype(jnp.float32) / lengthscale.astype(jnp.float32)
    zn = jnp.sum(zs * zs, -1)
    d2 = jnp.maximum(zn[:, None] + zn[None, :] - 2.0 * zs @ zs.T, 0.0)
    pref2 = variance.astype(jnp.float32) ** 2 * jnp.exp(-0.25 * d2)
    psi2 = pref2 * acc2[:M, :M]
    psiY = variance.astype(jnp.float32) * accY[:M]
    return psi2, psiY
