"""Fused suffstats kernel: ALL sufficient statistics in one pass over N
(beyond-paper optimization C3, EXPERIMENTS.md §Perf) — forward Pallas TPU
kernel, streaming jnp twin, and the hand-derived streaming reverse pass.

The paper computes Psi1 and Psi2 in separate GPU kernels (Table 1); the
bound only ever consumes psiY = Psi1^T Y and Psi2, so this kernel streams
each datapoint once and accumulates BOTH:

    psiY[m, :]   += psi1[n, m] * y[n, :]
    acc2[m, m']  += exp(lognorm2_n + muterm_n,m,m')

Removing the second pass halves HBM reads of (mu, S) and never materializes
the (N, M) Psi1 matrix. Grid = (M/TM, M/TM, N/TN) with the N axis innermost
(sequential accumulation); psiY accumulates only on the j == 0 column of the
grid so it is added exactly once per (m-tile, n-tile).

Three entry points (wired into a differentiable op by `repro.kernels.ops`):

  * `suffstats_pallas`     — the Pallas kernel (compiled on TPU, interpret
                             elsewhere).
  * `suffstats_fused_jnp`  — numerically-identical streaming `lax.scan` over
                             N chunks; the off-TPU large-N forward.
  * `suffstats_vjp_jnp`    — HAND-DERIVED reverse pass (paper Table 2
                             generalized to the fused outputs), itself a
                             second streaming kernel over N: per-datapoint
                             cotangents (dmu, dS, dY) leave chunk by chunk,
                             global cotangents (dZ, dvariance, dlengthscale)
                             ride the scan carry. Peak live memory is
                             O(chunk * M^2), matching the forward.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_N = 32
TILE_M = 128


def _suffstats_kernel(mu_ref, s_ref, y_ref, w_ref, z1_ref, z2_ref, l2_ref,
                      psi2_ref, psiy_ref, *, ct=jnp.float32):
    j = pl.program_id(1)
    kn = pl.program_id(2)

    mu = mu_ref[...].astype(ct)  # (TN, Q)
    S = s_ref[...].astype(ct)
    y = y_ref[...].astype(ct)  # (TN, D)
    w = w_ref[...].astype(ct)  # (TN, 1)
    z1 = z1_ref[...].astype(ct)  # (TM, Q)
    z2 = z2_ref[...].astype(ct)
    l2 = l2_ref[...].astype(ct)  # (1, Q)

    tn, q_dim = mu.shape
    tm = z1.shape[0]

    # ---------------- psi2 tile (same math as kernels/psi2.py) ----------
    r = 1.0 / (l2 + 2.0 * S)
    lognorm2 = -0.5 * jnp.sum(jnp.log1p(2.0 * S / l2), axis=-1, keepdims=True)
    c2 = jnp.sum(mu * mu * r, axis=-1, keepdims=True)
    mur = mu * r

    def halfterm(z):
        a = jax.lax.dot_general(mur, z, (((1,), (1,)), ((), ())),
                                preferred_element_type=ct)
        b = jax.lax.dot_general(r, z * z, (((1,), (1,)), ((), ())),
                                preferred_element_type=ct)
        return a - 0.25 * b

    A1 = halfterm(z1)
    A2 = halfterm(z2)
    cross = jnp.zeros((tn, tm, tm), ct)
    for q in range(q_dim):
        cross = cross + (r[:, q][:, None, None] * z1[:, q][None, :, None]
                         * z2[:, q][None, None, :])
    E = jnp.exp((lognorm2 - c2)[:, :, None] + A1[:, :, None] + A2[:, None, :]
                - 0.5 * cross)
    contrib2 = jax.lax.dot_general(
        w.T, E.reshape(tn, tm * tm), (((1,), (0,)), ((), ())),
        preferred_element_type=ct).reshape(tm, tm)

    @pl.when(kn == 0)
    def _():
        psi2_ref[...] = contrib2

    @pl.when(kn > 0)
    def _():
        psi2_ref[...] += contrib2

    # ---------------- psiY tile (psi1 MXU factorization) ----------------
    @pl.when(j == 0)
    def _():
        b = 1.0 / (l2 + S)
        lognorm1 = -0.5 * jnp.sum(jnp.log1p(S / l2), axis=-1, keepdims=True)
        c1 = jnp.sum(mu * mu * b, axis=-1, keepdims=True)
        mub_zt = jax.lax.dot_general(mu * b, z1, (((1,), (1,)), ((), ())),
                                     preferred_element_type=ct)
        b_z2t = jax.lax.dot_general(b, z1 * z1, (((1,), (1,)), ((), ())),
                                    preferred_element_type=ct)
        psi1_blk = jnp.exp(lognorm1 - 0.5 * (c1 - 2.0 * mub_zt + b_z2t)) * w  # (TN, TM)
        contribY = jax.lax.dot_general(psi1_blk, y, (((0,), (0,)), ((), ())),
                                       preferred_element_type=ct)  # (TM, D)

        @pl.when(kn == 0)
        def _():
            psiy_ref[...] = contribY

        @pl.when(kn > 0)
        def _():
            psiy_ref[...] += contribY


@functools.partial(jax.jit, static_argnames=("interpret",))
def suffstats_pallas(mu, S, Y, Z, variance, lengthscale, *, interpret: bool = False):
    """Returns (psi2 (M, M), psiY (M, D)) accumulated over all N.

    Compiled (TPU) execution computes in float32 — the hardware dtype the
    tile sizes are chosen for. Interpret mode keeps the input dtype instead:
    it exists to validate the kernel body, and under x64 that makes parity
    checks meaningful rather than epilogue-conditioning-limited.
    """
    N, Q = mu.shape
    M = Z.shape[0]
    D = Y.shape[1]
    ct = mu.dtype if interpret else jnp.float32
    pad_n = (-N) % TILE_N
    pad_m = (-M) % TILE_M
    mu_p = jnp.pad(mu.astype(ct), ((0, pad_n), (0, 0)))
    S_p = jnp.pad(S.astype(ct), ((0, pad_n), (0, 0)), constant_values=1.0)
    Y_p = jnp.pad(Y.astype(ct), ((0, pad_n), (0, 0)))
    w = jnp.pad(jnp.ones((N, 1), ct), ((0, pad_n), (0, 0)))
    Z_p = jnp.pad(Z.astype(ct), ((0, pad_m), (0, 0)))
    l2 = (lengthscale.astype(ct) ** 2)[None, :]
    Mp = Z_p.shape[0]

    grid = (Mp // TILE_M, Mp // TILE_M, mu_p.shape[0] // TILE_N)
    acc2, accY = pl.pallas_call(
        functools.partial(_suffstats_kernel, ct=ct),
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_N, Q), lambda i, j, kn: (kn, 0)),
            pl.BlockSpec((TILE_N, Q), lambda i, j, kn: (kn, 0)),
            pl.BlockSpec((TILE_N, D), lambda i, j, kn: (kn, 0)),
            pl.BlockSpec((TILE_N, 1), lambda i, j, kn: (kn, 0)),
            pl.BlockSpec((TILE_M, Q), lambda i, j, kn: (i, 0)),
            pl.BlockSpec((TILE_M, Q), lambda i, j, kn: (j, 0)),
            pl.BlockSpec((1, Q), lambda i, j, kn: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((TILE_M, TILE_M), lambda i, j, kn: (i, j)),
            pl.BlockSpec((TILE_M, D), lambda i, j, kn: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Mp, Mp), ct),
            jax.ShapeDtypeStruct((Mp, D), ct),
        ],
        interpret=interpret,
    )(mu_p, S_p, Y_p, w, Z_p, Z_p, l2)

    zs = Z.astype(ct) / lengthscale.astype(ct)
    zn = jnp.sum(zs * zs, -1)
    d2 = jnp.maximum(zn[:, None] + zn[None, :] - 2.0 * zs @ zs.T, 0.0)
    pref2 = variance.astype(ct) ** 2 * jnp.exp(-0.25 * d2)
    psi2 = pref2 * acc2[:M, :M]
    psiY = variance.astype(ct) * accY[:M]
    return psi2, psiY


# ---------------------------------------------------------------------------
# streaming jnp twin of the forward kernel (off-TPU large-N path)
# ---------------------------------------------------------------------------

def _pad_stream(mu, S, Y, chunk):
    """Pad the N axis to a chunk multiple; returns per-chunk xs + weights."""
    N, Q = mu.shape
    D = Y.shape[1]
    pad = (-N) % chunk
    mu_p = jnp.pad(mu, ((0, pad), (0, 0)))
    # pad S with ones (any positive value) and mask via weight w
    S_p = jnp.pad(S, ((0, pad), (0, 0)), constant_values=1.0)
    Y_p = jnp.pad(Y, ((0, pad), (0, 0)))
    w = jnp.pad(jnp.ones((N,), mu.dtype), ((0, pad),))
    k = (N + pad) // chunk
    return (mu_p.reshape(k, chunk, Q), S_p.reshape(k, chunk, Q),
            Y_p.reshape(k, chunk, D), w.reshape(k, chunk))


def _psi1_weighted(mu_i, S_i, w_i, Z, l2):
    """psi1 block / variance via the MXU factorization (see kernels/psi1.py),
    pad weights folded in: returns (b (chunk, Q), blk (chunk, M)).

    Shared by the streaming forward and the hand-derived VJP — the two MUST
    evaluate the identical expression or the registered gradient is wrong.
    """
    b = 1.0 / (l2[None, :] + S_i)
    lognorm1 = -0.5 * jnp.sum(jnp.log1p(S_i / l2[None, :]), axis=-1)
    c1 = jnp.sum(mu_i * mu_i * b, axis=-1)
    expo1 = -0.5 * (c1[:, None] - 2.0 * (mu_i * b) @ Z.T + b @ (Z * Z).T)
    return b, jnp.exp(lognorm1[:, None] + expo1) * w_i[:, None]


def _psi2_weighted(mu_i, S_i, w_i, zbar, l2):
    """Per-point psi2 factor exp(lognorm2 + e2) (without the v^2 exp(zterm)
    prefactor), pad weights folded in: returns (r (chunk, Q), E (chunk, M, M)).
    Shared by the streaming forward and the hand-derived VJP (see above)."""
    Q = mu_i.shape[1]
    M = zbar.shape[0]
    r = 1.0 / (l2[None, :] + 2.0 * S_i)
    lognorm2 = -0.5 * jnp.sum(jnp.log1p(2.0 * S_i / l2[None, :]), axis=-1)
    expo = jnp.zeros((mu_i.shape[0], M, M), mu_i.dtype)
    for q in range(Q):  # Q is small (latent dim); unrolled
        dq = mu_i[:, None, None, q] - zbar[None, :, :, q]
        expo = expo - dq * dq * r[:, None, None, q]
    return r, jnp.exp(lognorm2[:, None, None] + expo) * w_i[:, None, None]


def suffstats_fused_jnp(mu, S, Y, Z, variance, lengthscale, *, chunk: int = 1024):
    """(psi2 (M, M), psiY (M, D)) by one streaming jnp pass over N — the same
    math and accumulation order as `suffstats_pallas`, O(chunk * M^2) live."""
    N, Q = mu.shape
    M = Z.shape[0]
    D = Y.shape[1]
    l2 = lengthscale**2
    zdiff = Z[:, None, :] - Z[None, :, :]
    zterm = -jnp.sum(zdiff**2 / (4.0 * l2), axis=-1)  # (M, M)
    zbar = 0.5 * (Z[:, None, :] + Z[None, :, :])

    xs = _pad_stream(mu, S, Y, chunk)

    def body(acc, x):
        mu_i, S_i, Y_i, w_i = x
        acc2, accY = acc
        _, psi1_blk = _psi1_weighted(mu_i, S_i, w_i, Z, l2)  # (chunk, M)
        accY = accY + variance * psi1_blk.T @ Y_i
        _, E = _psi2_weighted(mu_i, S_i, w_i, zbar, l2)  # (chunk, M, M)
        acc2 = acc2 + jnp.sum(E, axis=0)
        return (acc2, accY), None

    # `+ 0 * mu[0, 0]` inherits mu's varying-manual-axes type so the scan
    # carry is well-typed when this runs inside shard_map (see shard_map-vma).
    vma = 0.0 * mu[0, 0]
    acc0 = (jnp.zeros((M, M), mu.dtype) + vma, jnp.zeros((M, D), mu.dtype) + vma)
    (acc2, accY), _ = jax.lax.scan(body, acc0, xs)
    return variance**2 * jnp.exp(zterm) * acc2, accY


# ---------------------------------------------------------------------------
# hand-derived reverse pass: a second streaming kernel over N
# ---------------------------------------------------------------------------
#
# Notation (everything per latent dim q unless noted; v = variance, l2 = l^2):
#
#   psi1[n,m]    = v * exp(-0.5 sum_q log(1+S/l2) - 0.5 sum_q (mu-z_m)^2 b),
#                  b = 1/(l2+S)
#   psiY[m,d]    = sum_n psi1[n,m] Y[n,d]
#   psi2_n[m,m'] = v^2 * exp(-0.5 sum_q log(1+2S/l2) + zterm_mm'
#                            - sum_q (mu - zbar)^2 r),
#                  r = 1/(l2+2S), zbar = (z_m+z_m')/2,
#                  zterm = -sum_q (z_m-z_m')^2/(4 l2)
#
# Given output cotangents g2 (M,M) and gY (M,D), define per chunk
#   W1[n,m]    = (Y gY^T)[n,m] * psi1[n,m]          (psi1 branch weights)
#   T[n,m,m']  = g2[m,m'] * psi2_n[m,m']            (psi2 branch weights)
# and contract the analytic derivative of each exponent against W1 / T.
# All (n,*) contractions reduce to chunk-local matmuls/einsums against Z, so
# nothing larger than (chunk, M, M) is ever live — the reverse pass streams
# exactly like the forward.

def suffstats_vjp_jnp(mu, S, Y, Z, variance, lengthscale, g2, gY, *,
                      chunk: int = 512):
    """Hand-derived VJP of ``(psi2, psiY) = suffstats(...)``.

    Returns cotangents ``(dmu, dS, dY, dZ, dvariance, dlengthscale)``.
    Validated against jax.grad of the jnp reference formulas in
    tests/test_streaming.py.
    """
    N, Q = mu.shape
    M = Z.shape[0]
    dt = mu.dtype
    v = variance.astype(dt)
    ls = lengthscale.astype(dt)
    l2 = ls**2
    g2 = g2.astype(dt)
    gY = gY.astype(dt)
    zdiff = Z[:, None, :] - Z[None, :, :]  # (M, M, Q)
    zterm = -jnp.sum(zdiff**2 / (4.0 * l2), axis=-1)
    zbar = 0.5 * (Z[:, None, :] + Z[None, :, :])
    # fold the (m, m')-only psi2 prefactor v^2 exp(zterm) into the cotangent
    G2p = g2 * v**2 * jnp.exp(zterm)  # (M, M)
    Z2 = Z * Z

    xs = _pad_stream(mu, S, Y, chunk)

    def body(carry, x):
        dZ_a, dv_a, dl_a = carry
        mu_i, S_i, Y_i, w_i = x
        # ---------------- psi1 branch ----------------
        b, blk = _psi1_weighted(mu_i, S_i, w_i, Z, l2)  # (c, Q), (c, M)
        psi1w = v * blk  # (c, M)
        W1 = (Y_i @ gY.T) * psi1w  # (c, M)
        dY_i = psi1w @ gY  # (c, D)
        s1 = jnp.sum(W1, axis=1)  # (c,)
        W1Z = W1 @ Z  # (c, Q)
        # sum_m W1 (mu - z_m)^2, factored through Z moments
        sq1 = mu_i**2 * s1[:, None] - 2.0 * mu_i * W1Z + W1 @ Z2
        dmu_i = -b * (mu_i * s1[:, None] - W1Z)
        dS_i = -0.5 * b * s1[:, None] + 0.5 * b * b * sq1
        dZ_c = W1.T @ (mu_i * b) - Z * (W1.T @ b)  # (M, Q)
        dv_c = jnp.sum(s1) / v
        dl_c = jnp.sum((S_i * b / ls) * s1[:, None] + ls * b * b * sq1, axis=0)
        # ---------------- psi2 branch ----------------
        r, E = _psi2_weighted(mu_i, S_i, w_i, zbar, l2)  # (c, Q), (c, M, M)
        T = G2p[None, :, :] * E  # (c, M, M)
        t = jnp.sum(T, axis=(1, 2))  # (c,)
        rc = jnp.sum(T, axis=2) + jnp.sum(T, axis=1)  # (c, M) row + col sums
        u = 0.5 * rc @ Z  # (c, Q): sum_mm' T zbar
        B = jnp.einsum("nab,aq,bq->nq", T, Z, Z)  # (c, Q) bilinear z^T T z
        w2 = 0.25 * (rc @ Z2) + 0.5 * B  # sum_mm' T zbar^2
        V = mu_i**2 * t[:, None] - 2.0 * mu_i * u + w2  # sum_mm' T (mu-zbar)^2
        dmu_i = dmu_i - 2.0 * r * (mu_i * t[:, None] - u)
        dS_i = dS_i - r * t[:, None] + 2.0 * r * r * V
        # dZ: zbar appears in both slots — symmetrize T once, then the two
        # slot sums collapse to a single contraction (psi2_n is m<->m' even).
        Ts = T + jnp.swapaxes(T, 1, 2)
        Ps = jnp.sum(Ts, axis=0)  # (M, M)
        dZ_c = dZ_c - (Z * jnp.sum(Ps, axis=1)[:, None] - Ps @ Z) / (2.0 * l2)
        dZ_c = dZ_c + jnp.einsum("nk,nq->kq", rc, r * mu_i) \
            - 0.5 * Z * jnp.einsum("nk,nq->kq", rc, r) \
            - 0.5 * jnp.einsum("nkm,mq,nq->kq", Ts, Z, r)
        dv_c = dv_c + 2.0 * jnp.sum(t) / v
        dl_c = dl_c + (2.0 / ls) * jnp.sum((S_i * r) * t[:, None], axis=0) \
            + 2.0 * ls * jnp.sum(r * r * V, axis=0) \
            + jnp.einsum("ab,abq->q", jnp.sum(T, axis=0), zdiff**2) / (2.0 * ls**3)
        return (dZ_a + dZ_c, dv_a + dv_c, dl_a + dl_c), (dmu_i, dS_i, dY_i)

    vma = 0.0 * mu[0, 0]
    # dvariance rides the carry as (1,): rank-0 scan carries trip this jax
    # version's shard_map transpose spec check (see gp/stats.py)
    carry0 = (jnp.zeros((M, Q), dt) + vma, jnp.zeros((1,), dt) + vma,
              jnp.zeros((Q,), dt) + vma)
    (dZ, dv, dl), (dmu_s, dS_s, dY_s) = jax.lax.scan(body, carry0, xs)
    dmu = dmu_s.reshape(-1, Q)[:N]
    dS = dS_s.reshape(-1, Q)[:N]
    dY = dY_s.reshape(-1, Y.shape[1])[:N]
    return (dmu.astype(mu.dtype), dS.astype(S.dtype), dY.astype(Y.dtype),
            dZ.astype(Z.dtype), dv[0].astype(variance.dtype),
            dl.astype(lengthscale.dtype))
