"""Pallas TPU kernel: RBF cross-covariance K_fu (paper §3, the sparse-GP /
GP-head hot loop).

TPU adaptation (vs the paper's CUDA Table 1): instead of a thread per
datapoint, the squared distance is rewritten as

    d2[n,m] = |x_n/l|^2 + |z_m/l|^2 - 2 (x/l) @ (z/l)^T

so the O(N M Q) inner product runs on the 128x128 MXU, and the row/col norms
are VPU row reductions. Each grid step owns one (TILE_N, TILE_M) output tile
in VMEM; BlockSpec index maps make every output tile written exactly once
(no global-memory write contention to manage, unlike CUDA cc-2.0).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_N = 256
TILE_M = 128


def _kfu_kernel(xs_ref, zs_ref, o_ref, *, ct=jnp.float32):
    """xs/zs are pre-scaled by 1/lengthscale in the wrapper (one pass,
    instead of once per tile)."""
    xs = xs_ref[...].astype(ct)  # (TILE_N, Q)
    zs = zs_ref[...].astype(ct)  # (TILE_M, Q)
    xn = jnp.sum(xs * xs, axis=-1, keepdims=True)  # (TILE_N, 1)
    zn = jnp.sum(zs * zs, axis=-1)[None, :]  # (1, TILE_M)
    cross = jax.lax.dot_general(
        xs, zs, (((1,), (1,)), ((), ())), preferred_element_type=ct
    )  # MXU: (TILE_N, TILE_M)
    d2 = jnp.maximum(xn + zn - 2.0 * cross, 0.0)
    o_ref[...] = jnp.exp(-0.5 * d2).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "block"))
def kfu_pallas(
    X: jax.Array,
    Z: jax.Array,
    variance: jax.Array,
    lengthscale: jax.Array,
    *,
    interpret: bool = False,
    block: tuple | None = None,
) -> jax.Array:
    """K_fu = variance * exp(-0.5 ||(x-z)/l||^2), tiled (tile_n, tile_m).

    Compiled (TPU) execution computes in float32 — the hardware dtype the
    tiles are chosen for. Interpret mode computes in the input dtype promoted
    to at least f32 (same policy as the fused suffstats kernel): it exists to
    validate the kernel body, and under x64 that makes f64 parity checks
    meaningful.

    `block=(tile_n, tile_m)` overrides the module-constant tiles — the knob
    the `repro.tune` autotuner turns; None keeps (TILE_N, TILE_M). The
    wrapper pads to whatever multiple the block demands, so any measured
    winner is numerically identical to the defaults.
    """
    tile_n, tile_m = block if block is not None else (TILE_N, TILE_M)
    N, Q = X.shape
    M = Z.shape[0]
    dtype = X.dtype
    ct = jnp.promote_types(dtype, jnp.float32) if interpret else jnp.float32
    pad_n = (-N) % tile_n
    pad_m = (-M) % tile_m
    Xs = jnp.pad((X / lengthscale).astype(ct), ((0, pad_n), (0, 0)))
    Zs = jnp.pad((Z / lengthscale).astype(ct), ((0, pad_m), (0, 0)))

    grid = (Xs.shape[0] // tile_n, Zs.shape[0] // tile_m)
    out = pl.pallas_call(
        functools.partial(_kfu_kernel, ct=ct),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_n, Q), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_m, Q), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tile_n, tile_m), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Xs.shape[0], Zs.shape[0]), ct),
        interpret=interpret,
    )(Xs, Zs)
    return (variance * out[:N, :M]).astype(dtype)
