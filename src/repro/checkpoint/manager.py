"""Sharded checkpointing with retention, atomicity, async save, and elastic
restore.

Layout per step:  <dir>/step_<N>/
    manifest.json   — step, leaf paths, shapes, dtypes, extra state (data
                      iterator, RNG), save timestamp
    arrays.npz      — one entry per pytree leaf (path-keyed)

Guarantees:
  * atomic: written to step_<N>.tmp then os.rename'd — a crash mid-save never
    corrupts the latest checkpoint;
  * retention: keep the newest `keep` checkpoints (+ every `keep_every`-th);
  * async: `save(..., blocking=False)` hands the host copy to a worker
    thread; `wait()` joins (the train loop overlaps save with compute);
  * elastic restore: arrays are saved unsharded (gathered); `restore`
    device_puts onto WHATEVER mesh/sharding the restoring job provides, so a
    job restarted on a different pod count resumes bit-exactly. (At real
    multi-pod scale the same manifest format fronts per-host shard files;
    the reshard path is identical.)
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import numpy as np

PyTree = Any


class CheckpointCorruptError(RuntimeError):
    """A checkpoint directory that exists but cannot be trusted: manifest
    that does not parse, arrays file missing or truncated, or arrays that
    disagree with the manifest's declared shapes/dtypes. Raised instead of
    handing back garbage leaves — a torn restore must fail loudly."""


def leaf_key(path) -> str:
    """The manifest/npz key for one pytree leaf path — shared by save and
    every restore path so the two can never drift."""
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)


def _np_dtype(name: str) -> np.dtype:
    """Resolve a manifest dtype name, including the ml_dtypes ones numpy
    does not know natively (bfloat16, float8_*)."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes

        return np.dtype(getattr(ml_dtypes, name))


def _flatten(tree: PyTree) -> Dict[str, np.ndarray]:
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = leaf_key(path)
        arr = np.asarray(leaf)
        if arr.dtype.name in ("bfloat16", "float8_e4m3fn", "float8_e5m2"):
            # npz can't round-trip ml_dtypes: store widened; manifest keeps
            # the true dtype and restore() casts back (f32 ⊃ bf16: lossless)
            arr = arr.astype(np.float32)
        out[key] = arr
    return out


class CheckpointManager:
    def __init__(self, directory: str | Path, *, keep: int = 3, keep_every: int = 0):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.keep_every = keep_every
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------
    def save(self, step: int, tree: PyTree, extra: Optional[Dict] = None,
             blocking: bool = True) -> None:
        flat = _flatten(tree)  # host copy happens here, synchronously
        treedef = jax.tree.structure(tree)
        manifest = {
            "step": step,
            "time": time.time(),
            "extra": extra or {},
            "treedef": str(treedef),
            "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)} for k, v in flat.items()},
        }

        def write():
            tmp = self.dir / f"step_{step}.tmp"
            final = self.dir / f"step_{step}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            np.savez(tmp / "arrays.npz", **flat)
            (tmp / "manifest.json").write_text(json.dumps(manifest))
            if final.exists():
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._retain()

        self.wait()
        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # ------------------------------------------------------------------
    def steps(self) -> list[int]:
        return sorted(
            int(p.name.split("_")[1]) for p in self.dir.glob("step_*") if not p.suffix
        )

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def _retain(self) -> None:
        steps = self.steps()
        doomed = steps[: -self.keep] if self.keep else []
        for s in doomed:
            if self.keep_every and s % self.keep_every == 0:
                continue
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)

    # ------------------------------------------------------------------
    def load_manifest(self, step: Optional[int] = None) -> Dict:
        """The validated manifest of a step: must exist, parse as JSON, and
        carry a leaves table. Raises CheckpointCorruptError otherwise —
        cheap enough to call for metadata alone (no array I/O)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        d = self.dir / f"step_{step}"
        try:
            manifest = json.loads((d / "manifest.json").read_text())
        except FileNotFoundError:
            raise CheckpointCorruptError(f"{d}: manifest.json missing") from None
        except (json.JSONDecodeError, UnicodeDecodeError) as e:
            raise CheckpointCorruptError(
                f"{d}/manifest.json does not parse as JSON ({e})") from None
        if not isinstance(manifest.get("leaves"), dict):
            raise CheckpointCorruptError(
                f"{d}/manifest.json carries no leaves table")
        return manifest

    def load_arrays(self, step: Optional[int] = None
                    ) -> tuple[Dict[str, np.ndarray], Dict]:
        """Validated raw read: (path-keyed numpy leaves, manifest).

        Every failure mode of a torn or corrupt checkpoint — unparseable
        manifest, missing/truncated arrays.npz, leaves absent from the
        archive, shapes disagreeing with the manifest — raises
        CheckpointCorruptError naming the offending piece; callers never
        see garbage arrays. Leaves saved widened (ml_dtypes) are cast back
        to their manifest dtype, so the dict carries the true dtypes."""
        step = step if step is not None else self.latest_step()
        manifest = self.load_manifest(step)
        d = self.dir / f"step_{step}"
        try:
            with np.load(d / "arrays.npz") as npz:
                arrays = {k: npz[k] for k in npz.files}
        except FileNotFoundError:
            raise CheckpointCorruptError(f"{d}: arrays.npz missing") from None
        except Exception as e:
            raise CheckpointCorruptError(
                f"{d}/arrays.npz unreadable — truncated or corrupt ({e})"
            ) from None
        out = {}
        for key, meta in manifest["leaves"].items():
            if key not in arrays:
                raise CheckpointCorruptError(
                    f"{d}: leaf {key!r} missing from arrays.npz")
            arr = arrays[key]
            if list(arr.shape) != list(meta["shape"]):
                raise CheckpointCorruptError(
                    f"{d}: leaf {key!r} has shape {list(arr.shape)}, manifest "
                    f"declares {meta['shape']}")
            dtype = _np_dtype(meta["dtype"])
            if arr.dtype != dtype:
                arr = arr.astype(dtype)
            out[key] = arr
        return out, manifest

    def restore(self, target: PyTree, step: Optional[int] = None,
                shardings: Optional[PyTree] = None) -> tuple[PyTree, Dict]:
        """Restore into the structure of `target`; `shardings` (same structure)
        places each leaf — pass the CURRENT mesh's shardings for elastic
        resume onto a different topology."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        arrays, manifest = self.load_arrays(step)

        flat_target, treedef = jax.tree_util.tree_flatten_with_path(target)
        shard_leaves = None
        if shardings is not None:
            shard_leaves = jax.tree.flatten(
                shardings, is_leaf=lambda x: hasattr(x, "spec") or x is None)[0]
        leaves = []
        for i, (path, leaf) in enumerate(flat_target):
            key = leaf_key(path)
            if key not in arrays:
                raise KeyError(f"checkpoint step {step} missing leaf {key}")
            arr = arrays[key]
            if list(arr.shape) != list(leaf.shape):
                raise ValueError(f"{key}: checkpoint {arr.shape} != target {leaf.shape}")
            arr = arr.astype(leaf.dtype)
            if shard_leaves is not None and shard_leaves[i] is not None:
                leaves.append(jax.device_put(arr, shard_leaves[i]))
            else:
                leaves.append(jax.device_put(arr))
        tree = jax.tree_util.tree_unflatten(jax.tree.structure(target), leaves)
        return tree, manifest["extra"]
