"""Version-drift shims for the JAX APIs this repo relies on.

The codebase targets the jax.shard_map / jax.make_mesh(axis_types=...) API
surface; older installs (e.g. jax 0.4.x) spell these differently or lack
them. Every mesh/shard_map/cost-analysis call site goes through this module
so the drift is handled exactly once.
"""
from __future__ import annotations

from typing import Any, Dict, Sequence

import jax

# jax.shard_map (new) vs jax.experimental.shard_map.shard_map (0.4.x).
# The old entry point also spells check_vma as check_rep.
if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:  # pragma: no cover - branch depends on installed jax
    from jax.experimental.shard_map import shard_map as _shard_map_legacy

    def shard_map(f, *, check_vma: bool | None = None, **kwargs):
        if check_vma is not None:
            kwargs["check_rep"] = check_vma
        # legacy shard_map has no replication rule for pallas_call (the
        # Pallas statistics backends run inside these bodies) — the
        # documented workaround is check_rep=False; correctness is
        # unaffected (the losses psum explicitly).
        kwargs.setdefault("check_rep", False)
        return _shard_map_legacy(f, **kwargs)

# Explicit-sharding axis types only exist on newer jax; Auto is the default
# behaviour everywhere, so dropping the kwarg is semantics-preserving.
HAS_AXIS_TYPES = hasattr(jax.sharding, "AxisType")


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str], *,
              devices=None) -> jax.sharding.Mesh:
    """jax.make_mesh with Auto axis types where the kwarg exists."""
    kwargs: Dict[str, Any] = {}
    if devices is not None:
        kwargs["devices"] = devices
    if HAS_AXIS_TYPES:
        kwargs["axis_types"] = (jax.sharding.AxisType.Auto,) * len(axis_names)
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)


# jax 0.4.x has no differentiation rule for optimization_barrier; newer jax
# differentiates it as "barrier the tangents/cotangents too". Reproduce that
# with a custom_vjp so remat'd scans (models/transformer.py) stay trainable.
def _barrier_is_differentiable() -> bool:
    try:
        jax.eval_shape(
            jax.grad(lambda x: jax.lax.optimization_barrier(x)), 1.0
        )
        return True
    except NotImplementedError:
        return False


if _barrier_is_differentiable():
    optimization_barrier = jax.lax.optimization_barrier
else:  # pragma: no cover - branch depends on installed jax

    @jax.custom_vjp
    def optimization_barrier(x):
        return jax.lax.optimization_barrier(x)

    def _barrier_fwd(x):
        return optimization_barrier(x), None

    def _barrier_bwd(_, g):
        return (jax.lax.optimization_barrier(g),)

    optimization_barrier.defvjp(_barrier_fwd, _barrier_bwd)


def xla_cost_analysis(compiled) -> Dict[str, float]:
    """compiled.cost_analysis() returns a dict on new jax, [dict] on 0.4.x."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    return cost
