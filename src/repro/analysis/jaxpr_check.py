"""Jaxpr invariant checker: classify every intermediate's scaling class.

The streaming engine's whole contract — "no grad path materializes an
O(N * M) intermediate" — was enforced by `launch.memory.peak_intermediate_bytes`
plus a hand-computed byte threshold copy-pasted into four test files. The
threshold form has two failure modes: the constant silently encodes N, M and
itemsize (change any and the bound means something else), and a buffer that
scales badly but starts small sails under it.

This module states the invariant the way the code means it: trace the
function at TWO problem sizes (N and factor * N, traces only — nothing
executes), pair the jaxprs equation by equation (same program, same trace,
so the structure is identical and only shapes differ), and read each
intermediate's growth exponent off the size ratio. An (N, M) buffer is then
not "more than 52428800 bytes" but "scaling class O(N * M)" — independent of
the sizes the test happened to pick.

Entry points:

  * `scaling_report(fn, *args, axis="N", sizes=...)` — every intermediate
    with its scaling class, largest class first.
  * `assert_no_scaling(fn, *args, axis="N", worse_than="N*M", sizes=...)` —
    raise `ScalingViolation` (with the offending primitive and source line)
    if any intermediate reaches the named class within `margin`.
  * `trace_intermediates(fn, *args)` — the single-trace walk
    `launch.memory` now wraps for backward compatibility.

The walk recurses into every sub-jaxpr held by an equation's params —
list/tuple-valued AND dict-valued (scan/cond/pjit/remat/custom_vjp bodies),
closing the analyzer blind spot the old `launch.memory` walker had.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Iterable, List, Optional, Sequence, Tuple

__all__ = [
    "AnalysisError",
    "ScalingViolation",
    "Intermediate",
    "ScalingReport",
    "trace_intermediates",
    "scaling_report",
    "scaling_class",
    "assert_no_scaling",
    "sub_jaxprs",
]


class AnalysisError(RuntimeError):
    """The analyzer itself cannot proceed (e.g. the traced program changed
    structure between the two problem sizes — a size-dependent dispatch
    branch sits between them; pick sizes on the same side of it)."""


class ScalingViolation(AssertionError):
    """An intermediate reached a forbidden scaling class."""

    def __init__(self, message: str, violations: Sequence["Intermediate"]):
        super().__init__(message)
        self.violations = list(violations)


# ---------------------------------------------------------------------------
# jaxpr walking
# ---------------------------------------------------------------------------

def sub_jaxprs(val: Any) -> Iterable[Any]:
    """Yield every (raw) jaxpr reachable from one eqn param value.

    Handles ClosedJaxpr, raw Jaxpr, and list/tuple/dict containers of
    either — dict-valued params (e.g. custom_vjp's bwd mapping) were the
    blind spot of the pre-analysis walker.
    """
    if hasattr(val, "jaxpr"):  # ClosedJaxpr
        yield val.jaxpr
    elif hasattr(val, "eqns"):  # raw Jaxpr
        yield val
    elif isinstance(val, (list, tuple)):
        for item in val:
            yield from sub_jaxprs(item)
    elif isinstance(val, dict):
        for item in val.values():
            yield from sub_jaxprs(item)


def _source_line(eqn) -> str:
    try:
        from jax._src import source_info_util

        return source_info_util.summarize(eqn.source_info)
    except Exception:  # pragma: no cover - jax-internal API drift
        return "<unknown>"


def _collect(jaxpr, out: List[Tuple[Any, Any]]) -> None:
    """Append (aval, eqn) for every equation output, depth-first in trace
    order — the order is what lets two traces of the same program at
    different sizes be paired index by index."""
    for eqn in jaxpr.eqns:
        for v in eqn.outvars:
            aval = getattr(v, "aval", None)
            if aval is not None and hasattr(aval, "shape") and hasattr(aval, "dtype"):
                out.append((aval, eqn))
        for val in eqn.params.values():
            for sub in sub_jaxprs(val):
                _collect(sub, out)


def trace_intermediates(fn: Callable, *args, **kwargs) -> List[Tuple[Tuple[int, ...], str, int, str, str]]:
    """One-trace walk: [(shape, dtype, nbytes, primitive, source)] for every
    equation output of ``fn(*args, **kwargs)``. Traces only — never executes."""
    import jax

    closed = jax.make_jaxpr(lambda *a: fn(*a, **kwargs))(*args)
    pairs: List[Tuple[Any, Any]] = []
    _collect(closed.jaxpr, pairs)
    return [(tuple(a.shape), str(a.dtype), int(a.size) * a.dtype.itemsize,
             eqn.primitive.name, _source_line(eqn)) for a, eqn in pairs]


# ---------------------------------------------------------------------------
# two-size scaling classification
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Intermediate:
    """One equation output with its scaling class along the grown axis."""

    shape: Tuple[int, ...]
    dtype: str
    nbytes: int
    primitive: str
    source: str
    growth_exp: int  # p in elements ~ coeff * axis^p
    coeff: float     # elements / axis^p at the base size
    label: str       # human class label, e.g. "O(N*M)"

    def describe(self) -> str:
        return (f"{self.label:<12} {self.shape!s:<20} {self.dtype:<8} "
                f"{self.nbytes / 1e6:>10.2f} MB  {self.primitive}  "
                f"[{self.source}]")


@dataclasses.dataclass(frozen=True)
class ScalingReport:
    """Deduplicated intermediates of one traced function, worst class first."""

    axis: str
    axis_size: int
    sizes: Dict[str, int]
    entries: Tuple[Intermediate, ...]

    @property
    def worst(self) -> Optional[Intermediate]:
        return self.entries[0] if self.entries else None

    @property
    def worst_class(self) -> str:
        return self.entries[0].label if self.entries else "O(1)"

    def format(self, top: int = 10) -> str:
        head = (f"scaling report along axis {self.axis!r} "
                f"({self.axis} = {self.axis_size}, "
                f"{', '.join(f'{k} = {v}' for k, v in self.sizes.items() if k != self.axis)})")
        lines = [e.describe() for e in self.entries[:top]]
        return "\n".join([head] + lines)


def _class_label(axis: str, exp: int, coeff: float,
                 sizes: Dict[str, int]) -> str:
    """Express the per-axis coefficient through the named sizes: coeff ~ M
    becomes "O(N*M)", coeff ~ M*Q becomes "O(N*M*Q)". Falls back to the
    numeric coefficient when no product of named sizes is within 2x."""
    axis_part = [] if exp == 0 else [axis if exp == 1 else f"{axis}^{exp}"]
    if exp == 0 and coeff <= 2.0:
        return "O(1)"
    # candidate products of the non-axis named sizes, powers 0..2 each
    names = [(k, v) for k, v in sizes.items() if k != axis and v > 1]
    best: Tuple[float, List[str]] = (abs(math.log(max(coeff, 1.0))), [])
    for mask in range(3 ** len(names)):
        prod, parts, m = 1.0, [], mask
        for name, value in names:
            power, m = m % 3, m // 3
            if power:
                prod *= value ** power
                parts.append(name if power == 1 else f"{name}^{power}")
        err = abs(math.log(max(coeff, 1.0) / prod))
        if err < best[0] - 1e-9:
            best = (err, parts)
    if best[0] <= math.log(2.0):
        parts = axis_part + best[1]
        return "O(" + ("*".join(parts) or "1") + ")"
    if exp == 0:
        return f"O({coeff:.0f})"
    return "O(" + "*".join(axis_part + [f"{coeff:.0f}"]) + ")"


def _grow_args(args, axis_size: int, factor: int):
    """Abstract copies of `args` (any pytree of arrays / ShapeDtypeStructs)
    with every dimension equal to `axis_size` multiplied by `factor`."""
    import jax

    def grow(leaf):
        shape = tuple(d * factor if d == axis_size else d for d in leaf.shape)
        return jax.ShapeDtypeStruct(shape, leaf.dtype)

    return jax.tree_util.tree_map(grow, args)


def _abstract_args(args):
    import jax

    return jax.tree_util.tree_map(
        lambda leaf: jax.ShapeDtypeStruct(tuple(leaf.shape), leaf.dtype), args)


def scaling_report(fn: Callable, *args, axis: str = "N",
                   sizes: Optional[Dict[str, int]] = None,
                   factor: int = 2) -> ScalingReport:
    """Classify every intermediate of ``fn(*args)`` by how it scales along
    `axis`.

    `sizes` names the problem dimensions, e.g. ``{"N": 1_000_000, "M": 128,
    "Q": 4}``; it must contain `axis`. The function is traced (never
    executed) at the given sizes and again with every dimension equal to
    ``sizes[axis]`` grown by `factor`; each intermediate's growth exponent is
    read off the per-equation size ratio. Dimensions that coincidentally
    equal ``sizes[axis]`` would be grown too — use sizes where the streaming
    axis is unambiguous (it always is at the million-point scales this
    guards).
    """
    import jax

    if sizes is None or axis not in sizes:
        raise ValueError(
            f"sizes= must name the grown axis, e.g. sizes={{{axis!r}: <N>, 'M': <M>}}")
    axis_size = int(sizes[axis])
    if factor < 2:
        raise ValueError(f"factor must be >= 2, got {factor}")

    base = _abstract_args(args)
    grown = _grow_args(args, axis_size, factor)
    pairs1: List[Tuple[Any, Any]] = []
    pairs2: List[Tuple[Any, Any]] = []
    _collect(jax.make_jaxpr(fn)(*base).jaxpr, pairs1)
    _collect(jax.make_jaxpr(fn)(*grown).jaxpr, pairs2)

    if len(pairs1) != len(pairs2):
        raise AnalysisError(
            f"program structure changed between {axis} = {axis_size} and "
            f"{axis} = {factor * axis_size} ({len(pairs1)} vs {len(pairs2)} "
            f"intermediates) — a size-dependent dispatch branch sits between "
            f"the two sizes; pick sizes on the same side of it")

    log_factor = math.log(factor)
    best: Dict[Tuple[Tuple[int, ...], str, int, float], Intermediate] = {}
    for (a1, e1), (a2, e2) in zip(pairs1, pairs2):
        if e1.primitive.name != e2.primitive.name:
            raise AnalysisError(
                f"program structure changed between the two sizes: "
                f"{e1.primitive.name} vs {e2.primitive.name} at the same "
                f"trace position")
        s1 = max(int(a1.size), 1)
        s2 = max(int(a2.size), 1)
        exp = max(int(round(math.log(s2 / s1) / log_factor)), 0)
        coeff = s1 / float(axis_size ** exp)
        key = (tuple(a1.shape), str(a1.dtype), exp, coeff)
        if key not in best:
            best[key] = Intermediate(
                shape=tuple(a1.shape), dtype=str(a1.dtype),
                nbytes=int(a1.size) * a1.dtype.itemsize,
                primitive=e1.primitive.name, source=_source_line(e1),
                growth_exp=exp, coeff=coeff,
                label=_class_label(axis, exp, coeff, sizes))
    entries = sorted(best.values(),
                     key=lambda e: (e.growth_exp, e.coeff, e.nbytes),
                     reverse=True)
    return ScalingReport(axis=axis, axis_size=axis_size, sizes=dict(sizes),
                         entries=tuple(entries))


def scaling_class(fn: Callable, *args, axis: str = "N",
                  sizes: Optional[Dict[str, int]] = None,
                  factor: int = 2) -> str:
    """The worst scaling-class label of ``fn(*args)`` along `axis` — what the
    benchmark rows report as their headline memory signal."""
    return scaling_report(fn, *args, axis=axis, sizes=sizes,
                         factor=factor).worst_class


# ---------------------------------------------------------------------------
# the named-bound assertion the tests state their guarantee through
# ---------------------------------------------------------------------------

def _parse_bound(worse_than: str, axis: str,
                 sizes: Dict[str, int]) -> Tuple[int, float]:
    """Parse "N*M" / "N" / "N^2" / "N*M*Q" into (axis exponent, coefficient
    in elements). Every non-axis token must be a named size or an integer."""
    exp, coeff = 0, 1.0
    for token in worse_than.replace(" ", "").split("*"):
        if not token:
            continue
        name, _, power = token.partition("^")
        p = int(power) if power else 1
        if name == axis:
            exp += p
        elif name in sizes:
            coeff *= float(sizes[name]) ** p
        elif name.isdigit():
            coeff *= float(name) ** p
        else:
            raise ValueError(
                f"worse_than={worse_than!r} names {name!r}, which is neither "
                f"the axis {axis!r} nor in sizes={sorted(sizes)}")
    if exp == 0:
        raise ValueError(
            f"worse_than={worse_than!r} must involve the grown axis {axis!r}")
    return exp, coeff


def assert_no_scaling(fn: Callable, *args, axis: str = "N",
                      worse_than: str = "N*M",
                      sizes: Optional[Dict[str, int]] = None,
                      margin: float = 4.0, factor: int = 2,
                      budget_bytes: Optional[int] = None) -> ScalingReport:
    """Assert no intermediate of ``fn(*args)`` reaches the scaling class
    `worse_than` along `axis`.

    An intermediate violates the bound when its growth exponent along `axis`
    exceeds the bound's, or when it matches the bound's exponent and its
    per-``axis^p`` coefficient comes within `margin` of the bound's — the
    default ``margin=4.0`` with ``worse_than="N*M"`` reads "nothing within
    4x of an (N, M) array", the contract the streaming tests always meant.
    ``margin < 1`` loosens the bound instead: ``margin=0.5`` allows up to a
    2x-the-bound buffer (for ops whose OUTPUT cotangent is itself (N, M)).

    `budget_bytes`, when given, additionally caps every intermediate's
    absolute size regardless of class. Returns the full `ScalingReport` on
    success so callers can log it.
    """
    rep = scaling_report(fn, *args, axis=axis, sizes=sizes, factor=factor)
    bound_exp, bound_coeff = _parse_bound(worse_than, axis, rep.sizes)
    violations = [
        e for e in rep.entries
        if e.growth_exp > bound_exp
        or (e.growth_exp == bound_exp and e.coeff * margin >= bound_coeff)
        or (budget_bytes is not None and e.nbytes > budget_bytes)
    ]
    if violations:
        listing = "\n".join("  " + v.describe() for v in violations[:8])
        raise ScalingViolation(
            f"{len(violations)} intermediate(s) reach scaling class "
            f"O({worse_than}) along {axis} (margin {margin:g}"
            + (f", budget {budget_bytes / 1e6:.0f} MB" if budget_bytes else "")
            + f"):\n{listing}",
            violations)
    return rep
