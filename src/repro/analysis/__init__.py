"""repro.analysis — static analysis for the scaling claims the tests assert.

Four passes plus a runtime verifier, runnable as a library, as a CLI
(``python -m repro.analysis``), and as the "static analysis" lane in
``scripts/ci.sh``:

* :mod:`repro.analysis.jaxpr_check` — traces a function at two problem
  sizes and classifies every intermediate's scaling class along an axis
  (O(1), O(N), O(N*M), ...). `assert_no_scaling` is the single statement of
  the paper's memory guarantee ("no grad-path intermediate grows like
  N*M") that the per-test byte thresholds used to approximate.
* :mod:`repro.analysis.pallas_audit` — per-kernel VMEM residency, tile
  divisibility, index-map bounds and dtype-promotion-rule checks computed
  from the BlockSpecs without lowering anything; feeds BENCH_vmem.json.
* :mod:`repro.analysis.lint` — AST rules ANL001-ANL004 for the invariants
  earlier PRs fixed by hand (call-time platform dispatch, locked registry
  access, bwd_backend-only VJP registration, no literal kernel dtypes).
* :mod:`repro.analysis.concurrency` — whole-repo lock model of the
  serving tier: acquisition graph, lock-order cycles / declared-hierarchy
  inversions (ANL005), guard-inferred race candidates (ANL006, the
  generalized ANL002), blocking calls under locks (ANL007).
* :mod:`repro.analysis.lockdep` — runtime lock-order verifier
  (``watch()`` / ``named_lock``) that turns the serve test battery into a
  deadlock detector; raises ``LockOrderViolation`` on the first inversion.

Submodules load lazily: ``concurrency`` and ``lockdep`` are stdlib-only
and are imported at runtime by `repro.tune.cache`, so touching them must
not drag in jax via the heavier passes.
"""
from typing import Dict

_EXPORTS: Dict[str, str] = {
    # jaxpr_check
    "AnalysisError": "jaxpr_check",
    "Intermediate": "jaxpr_check",
    "ScalingReport": "jaxpr_check",
    "ScalingViolation": "jaxpr_check",
    "assert_no_scaling": "jaxpr_check",
    "scaling_class": "jaxpr_check",
    "scaling_report": "jaxpr_check",
    "trace_intermediates": "jaxpr_check",
    # lint
    "LintFinding": "lint",
    "RULES": "lint",
    "lint_paths": "lint",
    "lint_source": "lint",
    # pallas_audit
    "AuditFinding": "pallas_audit",
    "KernelAudit": "pallas_audit",
    "Problem": "pallas_audit",
    "VMEM_BUDGET_BYTES": "pallas_audit",
    "audit_callable": "pallas_audit",
    "audit_kernels": "pallas_audit",
    "vmem_table": "pallas_audit",
    # concurrency
    "BLOCKING_OK": "concurrency",
    "ConcurrencyFinding": "concurrency",
    "ConcurrencyModel": "concurrency",
    "LOCK_HIERARCHY": "concurrency",
    "analyze_paths": "concurrency",
    "analyze_sources": "concurrency",
    # lockdep
    "LockOrderViolation": "lockdep",
    "named_lock": "lockdep",
    "watch": "lockdep",
}

__all__ = sorted(_EXPORTS) + ["concurrency", "lockdep", "jaxpr_check",
                              "lint", "pallas_audit"]


def __getattr__(name: str):
    if name in ("concurrency", "lockdep", "jaxpr_check", "lint",
                "pallas_audit"):
        import importlib
        return importlib.import_module(f"repro.analysis.{name}")
    mod = _EXPORTS.get(name)
    if mod is None:
        raise AttributeError(f"module 'repro.analysis' has no attribute "
                             f"{name!r}")
    import importlib
    return getattr(importlib.import_module(f"repro.analysis.{mod}"), name)


def __dir__():
    return __all__
