"""repro.analysis — static analysis for the scaling claims the tests assert.

Three passes, runnable as a library, as a CLI (``python -m repro.analysis``),
and as the "static analysis" lane in ``scripts/ci.sh``:

* :mod:`repro.analysis.jaxpr_check` — traces a function at two problem
  sizes and classifies every intermediate's scaling class along an axis
  (O(1), O(N), O(N*M), ...). `assert_no_scaling` is the single statement of
  the paper's memory guarantee ("no grad-path intermediate grows like
  N*M") that the per-test byte thresholds used to approximate.
* :mod:`repro.analysis.pallas_audit` — per-kernel VMEM residency, tile
  divisibility, index-map bounds and dtype-promotion-rule checks computed
  from the BlockSpecs without lowering anything; feeds BENCH_vmem.json.
* :mod:`repro.analysis.lint` — AST rules ANL001-ANL004 for the invariants
  earlier PRs fixed by hand (call-time platform dispatch, locked registry
  access, bwd_backend-only VJP registration, no literal kernel dtypes).
"""
from repro.analysis.jaxpr_check import (
    AnalysisError,
    Intermediate,
    ScalingReport,
    ScalingViolation,
    assert_no_scaling,
    scaling_class,
    scaling_report,
    trace_intermediates,
)
from repro.analysis.lint import LintFinding, RULES, lint_paths, lint_source
from repro.analysis.pallas_audit import (
    AuditFinding,
    KernelAudit,
    Problem,
    VMEM_BUDGET_BYTES,
    audit_callable,
    audit_kernels,
    vmem_table,
)

__all__ = [
    "AnalysisError",
    "Intermediate",
    "ScalingReport",
    "ScalingViolation",
    "assert_no_scaling",
    "scaling_class",
    "scaling_report",
    "trace_intermediates",
    "LintFinding",
    "RULES",
    "lint_paths",
    "lint_source",
    "AuditFinding",
    "KernelAudit",
    "Problem",
    "VMEM_BUDGET_BYTES",
    "audit_callable",
    "audit_kernels",
    "vmem_table",
]
