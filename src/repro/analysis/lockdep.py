"""Runtime lock-order verification (lockdep) for the serving tier.

The static pass (`repro.analysis.concurrency`) sees every acquisition the
AST shows; it cannot see orders that only materialize through dynamic
dispatch, callbacks, or cross-object calls. This module closes that gap
the way the kernel's lockdep does: instrument the lock primitives, record
the acquisition-order digraph each thread actually performs, and fail the
FIRST time an edge inverts either the declared hierarchy
(`concurrency.LOCK_HIERARCHY`) or an order some thread already observed
(the AB/BA pattern) — instead of waiting for the scheduler to interleave
two threads into the real deadlock.

Two entry points:

* ``watch()`` — opt-in context manager that monkeypatches
  ``threading.Lock/RLock/Condition`` so every lock **created under the
  repo root while watching** is wrapped. Locks created by stdlib/jax
  internals (Future conditions, Thread events) are left untouched — the
  creation frame's file decides. The serve test battery runs entirely
  under ``watch()`` via an autouse conftest fixture, so every
  fault-injection and load test doubles as a deadlock check.
* ``named_lock(name, kind=...)`` — replacement for module-level
  ``threading.Lock()``s created at import time (before any ``watch()``
  could patch the factory). The wrapper carries its canonical
  hierarchy name permanently and participates in whichever ``watch()``
  is active when it is acquired. `repro.tune.cache` / `repro.tune.autotune`
  route their process locks through this.

Checks are performed BEFORE the underlying acquire, so a genuine ABBA
interleaving raises :class:`LockOrderViolation` instead of hanging the
test run. Violations are also appended to the recorder — worker threads
that funnel exceptions into Futures (GPServer's serve loop) cannot
swallow the evidence; the conftest fixture asserts the recorder is clean
at teardown.

Overhead when no ``watch()`` is active is one attribute read per
acquisition on wrapped locks, and zero on unwrapped ones.
"""
from __future__ import annotations

import linecache
import pathlib
import re
import sys
import threading
from contextlib import contextmanager
from typing import Dict, List, Optional, Tuple

from repro.analysis.concurrency import LOCK_HIERARCHY

__all__ = [
    "LockOrderViolation",
    "Recorder",
    "watch",
    "named_lock",
    "current_recorder",
]

_REPO_ROOT = pathlib.Path(__file__).resolve().parents[3]
_SRC_ROOT = _REPO_ROOT / "src"
_THIS_FILE = str(pathlib.Path(__file__).resolve())

_RANK: Dict[str, int] = {name: i for i, name in enumerate(LOCK_HIERARCHY)}

# genuine primitives, captured before any watch() can patch the module
_RawLock = threading.Lock
_RawRLock = threading.RLock
_RawCondition = threading.Condition


class LockOrderViolation(RuntimeError):
    """A lock acquisition inverted the declared hierarchy or an
    already-observed acquisition order."""

    def __init__(self, message: str, *, lock: str, held: Tuple[str, ...]):
        super().__init__(message)
        self.lock = lock
        self.held = held


class Recorder:
    """Observed acquisition-order digraph + violations for one watch()."""

    def __init__(self, raise_on_violation: bool = True):
        self.raise_on_violation = raise_on_violation
        # (held_name, acquired_name) -> first site "thread @ file:line"
        self.edges: Dict[Tuple[str, str], str] = {}
        self.violations: List[LockOrderViolation] = []
        self.acquisitions: int = 0
        self._mu = _RawLock()

    def assert_clean(self) -> None:
        if self.violations:
            lines = "\n  ".join(str(v) for v in self.violations)
            raise AssertionError(
                f"lockdep recorded {len(self.violations)} lock-order "
                f"violation(s):\n  {lines}")

    # -- internal ----------------------------------------------------------

    def _site(self) -> str:
        f = sys._getframe(3)
        while f is not None and f.f_code.co_filename == _THIS_FILE:
            f = f.f_back
        where = (f"{pathlib.Path(f.f_code.co_filename).name}:{f.f_lineno}"
                 if f is not None else "?")
        return f"{threading.current_thread().name} @ {where}"

    def _fail(self, message: str, lock: str,
              held: Tuple[str, ...]) -> None:
        exc = LockOrderViolation(message, lock=lock, held=held)
        with self._mu:
            self.violations.append(exc)
        if self.raise_on_violation:
            raise exc

    def note_acquire(self, wrapper: "_Instrumented",
                     held: List["_Instrumented"]) -> None:
        """Check-then-record for one acquisition. Called with the
        thread's current held stack, BEFORE the underlying acquire."""
        site = self._site()
        with self._mu:
            self.acquisitions += 1
        name = wrapper.name

        # self-deadlock: non-reentrant lock already held by this thread
        if wrapper.kind == "lock" and any(w is wrapper for w in held):
            self._fail(
                f"`{name}` acquired while already held by this thread "
                f"({site}): non-reentrant lock, guaranteed self-deadlock",
                name, tuple(w.name for w in held))
            return
        if wrapper.kind != "lock" and any(w is wrapper for w in held):
            return  # re-entrant re-acquire: no new ordering information

        held_names = tuple(w.name for w in held)
        rank = _RANK.get(name)
        for h in held_names:
            if h == name:
                continue  # same-name sibling (two _Entry.locks): allowed
            # declared hierarchy
            hrank = _RANK.get(h)
            if rank is not None and hrank is not None and rank < hrank:
                self._fail(
                    f"`{name}` acquired while holding `{h}` ({site}) "
                    f"inverts the declared hierarchy "
                    f"(LOCK_HIERARCHY ranks {name} before {h})",
                    name, held_names)
                return
            # observed order (AB/BA)
            with self._mu:
                prior = self.edges.get((name, h))
            if prior is not None:
                self._fail(
                    f"`{name}` acquired while holding `{h}` ({site}), "
                    f"but the opposite order was observed earlier "
                    f"({prior}): AB/BA deadlock candidate",
                    name, held_names)
                return
        with self._mu:
            for h in held_names:
                if h != name:
                    self.edges.setdefault((h, name), site)


# the active recorder; read lock-free on the acquire fast path
_active: Optional[Recorder] = None
_watch_mu = _RawLock()

_held_local = threading.local()


def current_recorder() -> Optional[Recorder]:
    return _active


def _held_stack() -> List["_Instrumented"]:
    try:
        return _held_local.stack
    except AttributeError:
        _held_local.stack = []
        return _held_local.stack


class _Instrumented:
    """Proxy around a real Lock/RLock/Condition that reports to the
    active recorder. Transparent when no watch() is active."""

    __slots__ = ("name", "kind", "_raw")

    def __init__(self, name: str, kind: str, raw):
        self.name = name
        self.kind = kind
        self._raw = raw

    def __repr__(self) -> str:
        return f"<lockdep {self.kind} {self.name!r} {self._raw!r}>"

    # -- core protocol -----------------------------------------------------

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        rec = _active
        held = _held_stack()
        if rec is not None and blocking:
            rec.note_acquire(self, held)
        got = self._raw.acquire(blocking, timeout)
        if got:
            held.append(self)
        return got

    def release(self) -> None:
        held = _held_stack()
        for i in range(len(held) - 1, -1, -1):
            if held[i] is self:
                del held[i]
                break
        self._raw.release()

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()

    def locked(self) -> bool:
        return self._raw.locked()

    # -- condition protocol (delegates; wait releases the lock) -----------

    def wait(self, timeout: Optional[float] = None):
        held = _held_stack()
        idx = next((i for i in range(len(held) - 1, -1, -1)
                    if held[i] is self), None)
        if idx is not None:
            del held[idx]
        try:
            return self._raw.wait(timeout)
        finally:
            if idx is not None:
                held.append(self)  # wait() re-acquired before returning

    def wait_for(self, predicate, timeout: Optional[float] = None):
        held = _held_stack()
        idx = next((i for i in range(len(held) - 1, -1, -1)
                    if held[i] is self), None)
        if idx is not None:
            del held[idx]
        try:
            return self._raw.wait_for(predicate, timeout)
        finally:
            if idx is not None:
                held.append(self)

    def notify(self, n: int = 1) -> None:
        self._raw.notify(n)

    def notify_all(self) -> None:
        self._raw.notify_all()

    def __getattr__(self, item):
        return getattr(self._raw, item)


def named_lock(name: str, kind: str = "lock") -> _Instrumented:
    """A permanently-instrumented lock with an explicit canonical
    hierarchy name. Use for module-level locks, which are created at
    import time — before any ``watch()`` could patch the factories."""
    if kind == "lock":
        raw = _RawLock()
    elif kind == "rlock":
        raw = _RawRLock()
    elif kind == "condition":
        raw = _RawCondition()
    else:
        raise ValueError(f"unknown lock kind {kind!r}")
    return _Instrumented(name, kind, raw)


# ---------------------------------------------------------------------------
# creation-site naming for watch()-patched factories
# ---------------------------------------------------------------------------

_ASSIGN_RE = re.compile(
    r"(?:self\.(?P<attr>\w+)|(?P<global>[A-Za-z_]\w*))\s*=\s*threading\.")


def _infer_name(frame) -> Optional[str]:
    """Canonical name for a lock created at `frame`, or None when the
    creation site is outside the repo (leave the lock raw)."""
    filename = frame.f_code.co_filename
    try:
        resolved = pathlib.Path(filename).resolve()
        resolved.relative_to(_REPO_ROOT)
    except (ValueError, OSError):
        return None
    if str(resolved) == _THIS_FILE:
        return None
    line = linecache.getline(filename, frame.f_lineno).strip()
    m = _ASSIGN_RE.search(line)
    if m and m.group("attr") and "self" in frame.f_locals:
        cls = type(frame.f_locals["self"]).__name__
        return f"{cls}.{m.group('attr')}"
    if m and m.group("global"):
        try:
            mod = resolved.relative_to(_SRC_ROOT)
            qual = str(mod.with_suffix("")).replace("/", ".")
        except ValueError:
            qual = resolved.stem
        return f"{qual}.{m.group('global')}"
    try:
        rel = resolved.relative_to(_REPO_ROOT)
    except ValueError:
        rel = resolved
    return f"{rel}:{frame.f_lineno}"


def _factory(kind: str, raw_factory):
    def make(*args, **kwargs):
        if args or kwargs:  # Condition(lock=...) etc: don't second-guess
            return raw_factory(*args, **kwargs)
        name = _infer_name(sys._getframe(1))
        if name is None:
            return raw_factory()
        return _Instrumented(name, kind, raw_factory())
    return make


@contextmanager
def watch(raise_on_violation: bool = True):
    """Instrument every repo-created lock for the duration of the block.

    Yields the :class:`Recorder`; check ``recorder.violations`` (or call
    ``recorder.assert_clean()``) at exit — a violation raised inside a
    worker thread may have been routed into a Future, but it is always
    recorded.
    """
    global _active
    with _watch_mu:
        if _active is not None:
            raise RuntimeError("lockdep.watch() is already active "
                               "(nesting is not supported)")
        rec = Recorder(raise_on_violation=raise_on_violation)
        _active = rec
    patched = {
        "Lock": _factory("lock", _RawLock),
        "RLock": _factory("rlock", _RawRLock),
        "Condition": _factory("condition", _RawCondition),
    }
    saved = {k: getattr(threading, k) for k in patched}
    for k, v in patched.items():
        setattr(threading, k, v)
    try:
        yield rec
    finally:
        for k, v in saved.items():
            setattr(threading, k, v)
        with _watch_mu:
            _active = None
