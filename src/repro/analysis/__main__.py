"""CLI for the static-analysis passes: ``python -m repro.analysis``.

    python -m repro.analysis --all            # every pass (CI lane)
    python -m repro.analysis --lint           # AST rules only
    python -m repro.analysis --pallas-audit   # kernel VMEM/tiling/dtype
    python -m repro.analysis --jaxpr-check    # scaling smoke on the
                                              # quickstart SGPR loss

Exit status is the number of failing passes (0 on a clean tree). Findings
print with file:line so editors can jump to them. Suppress a lint finding
inline with ``# noqa: ANL00x``; there is deliberately no suppression for
the pallas audit or the jaxpr check — fix the kernel or widen the stated
bound instead.
"""
from __future__ import annotations

import argparse
import sys


def _run_lint(paths=None) -> int:
    from repro.analysis.lint import lint_paths

    findings = lint_paths(paths or None)
    for f in findings:
        print(f.describe())
    print(f"[lint] {len(findings)} finding(s) across rules ANL001-ANL004")
    return 1 if findings else 0


def _run_pallas_audit(vmem_budget_bytes: int) -> int:
    from repro.analysis.pallas_audit import audit_kernels

    audits = audit_kernels(vmem_budget_bytes=vmem_budget_bytes)
    bad = 0
    for a in audits:
        status = "ok" if (a.fits and not a.findings) else "FAIL"
        print(f"[pallas] {a.name:24s} grid={a.grid!s:14s} ct={a.ct} "
              f"vmem={a.vmem_estimate_bytes / 2**20:6.2f} MiB "
              f"(budget {a.vmem_budget_bytes / 2**20:.0f} MiB)  {status}")
        for f in a.findings:
            print(f"         {f.describe()}")
            bad += 1
    print(f"[pallas] {len(audits)} kernel(s) audited, {bad} finding(s)")
    return 1 if bad else 0


def _run_jaxpr_check() -> int:
    """Scaling smoke on the quickstart model: value_and_grad of the chunked
    SGPR loss must keep every intermediate strictly below O(N*M)."""
    import jax
    import jax.numpy as jnp

    from repro.analysis.jaxpr_check import ScalingViolation, assert_no_scaling
    from repro.gp import SparseGPRegression, get

    N, M, chunk = 4096, 32, 512
    key = jax.random.PRNGKey(0)
    X = jax.random.uniform(key, (N, 1), jnp.float32, -3.0, 3.0)
    Y = jnp.sin(2.0 * X)
    gp = SparseGPRegression(kernel=get("rbf")(1), M=M, chunk=chunk)
    p = gp.init_params(X, Y)
    try:
        report = assert_no_scaling(
            jax.value_and_grad(gp._loss_fn()), p, X, Y,
            axis="N", worse_than="N*M", sizes={"N": N, "M": M})
    except ScalingViolation as exc:
        print(f"[jaxpr] FAIL: {exc}")
        return 1
    print(f"[jaxpr] quickstart SGPR value_and_grad: worst intermediate "
          f"{report.worst_class} — below the O(N*M) bound")

    # the temporal backend's sequential training loss must stay O(N): no
    # (N, N) Gram matrix may appear anywhere in value_and_grad. (The
    # parallel path can't be traced at two sizes — associative_scan's tree
    # changes structure with N — so the scan lanes in tests/test_temporal.py
    # cover it via single-trace intermediates instead.)
    from repro.gp import regression

    n = 2048
    gaps = jax.random.uniform(jax.random.fold_in(key, 2), (n,),
                              minval=0.5e-3, maxval=1.5e-3)
    t = jnp.cumsum(gaps)  # the loss core takes flat (N,) times
    y = jnp.sin(4.0 * t)[:, None]
    tgp = regression(get("matern32")(1), backend="temporal", parallel=False)
    tp = tgp.init_params(t[:, None], y)
    loss = tgp._loss_fn()
    try:
        report = assert_no_scaling(
            jax.value_and_grad(loss), tp, t, y,
            axis="N", worse_than="N^2", sizes={"N": n})
    except ScalingViolation as exc:
        print(f"[jaxpr] FAIL: {exc}")
        return 1
    print(f"[jaxpr] temporal sequential value_and_grad: worst intermediate "
          f"{report.worst_class} — below the O(N^2) bound")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static-analysis passes over the repro tree")
    ap.add_argument("--all", action="store_true",
                    help="run every pass (default when no pass is selected)")
    ap.add_argument("--lint", action="store_true", help="AST lint rules")
    ap.add_argument("--pallas-audit", action="store_true",
                    help="Pallas kernel VMEM/tiling/dtype audit")
    ap.add_argument("--jaxpr-check", action="store_true",
                    help="scaling-class smoke on the quickstart SGPR loss")
    ap.add_argument("--vmem-budget", type=int, default=None, metavar="BYTES",
                    help="override the per-core VMEM budget for the audit")
    ap.add_argument("paths", nargs="*", metavar="PATH",
                    help="restrict the lint pass to these files "
                         "(default: every .py under src/repro)")
    args = ap.parse_args(argv)

    from repro.analysis.pallas_audit import VMEM_BUDGET_BYTES

    budget = args.vmem_budget or VMEM_BUDGET_BYTES
    chosen = args.lint or args.pallas_audit or args.jaxpr_check
    run_all = args.all or not chosen

    failures = 0
    if run_all or args.lint:
        failures += _run_lint(args.paths)
    if run_all or args.pallas_audit:
        failures += _run_pallas_audit(budget)
    if run_all or args.jaxpr_check:
        failures += _run_jaxpr_check()
    if failures:
        print(f"static analysis: {failures} pass(es) failed")
    else:
        print("static analysis: all passes clean")
    return failures


if __name__ == "__main__":
    sys.exit(main())
