"""CLI for the static-analysis passes: ``python -m repro.analysis``.

    python -m repro.analysis --all            # every pass (CI lane)
    python -m repro.analysis --lint           # AST rules only
    python -m repro.analysis --concurrency    # lock graph / races / blocking
    python -m repro.analysis --pallas-audit   # kernel VMEM/tiling/dtype
    python -m repro.analysis --jaxpr-check    # scaling smoke on the
                                              # quickstart SGPR loss
    python -m repro.analysis --all --format json   # machine-readable

Exit status is the number of failing passes (0 on a clean tree). Findings
print with file:line so editors can jump to them; ``--format json`` emits
one JSON document (findings, lock graph, audit rows) for tooling.
Suppress a lint/concurrency finding inline with ``# noqa: ANL00x``; there
is deliberately no suppression for the pallas audit or the jaxpr check —
fix the kernel or widen the stated bound instead.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys


def _run_lint(paths, emit) -> tuple:
    from repro.analysis.lint import lint_paths

    findings = lint_paths(paths or None)
    for f in findings:
        emit(f.describe())
    emit(f"[lint] {len(findings)} finding(s) across rules ANL001-ANL004 "
         f"(+ inferred ANL006)")
    payload = {"findings": [dataclasses.asdict(f) for f in findings]}
    return (1 if findings else 0), payload


def _run_concurrency(paths, emit) -> tuple:
    from repro.analysis.concurrency import (BLOCKING_OK, LOCK_HIERARCHY,
                                            analyze_paths)

    model = analyze_paths(paths or None)
    for f in model.findings:
        emit(f.describe())
    emit(f"[concurrency] {len(model.defs)} lock(s), "
         f"{len(model.acquisitions)} acquisition site(s), "
         f"{len(model.edges)} order edge(s), "
         f"{len(model.findings)} finding(s) across rules ANL005-ANL007")
    payload = {
        "hierarchy": list(LOCK_HIERARCHY),
        "blocking_ok": sorted(BLOCKING_OK),
        "locks": [dataclasses.asdict(d) for d in model.defs.values()],
        "edges": [
            {"held": a, "acquired": b,
             "sites": [f"{p}:{ln}" for p, ln in sorted(sites)]}
            for (a, b), sites in sorted(model.edges.items())
        ],
        "findings": [f.as_dict() for f in model.findings],
    }
    return (1 if model.findings else 0), payload


def _run_pallas_audit(vmem_budget_bytes: int, emit) -> tuple:
    from repro.analysis.pallas_audit import audit_kernels

    audits = audit_kernels(vmem_budget_bytes=vmem_budget_bytes)
    bad = 0
    rows = []
    for a in audits:
        status = "ok" if (a.fits and not a.findings) else "FAIL"
        emit(f"[pallas] {a.name:24s} grid={a.grid!s:14s} ct={a.ct} "
             f"vmem={a.vmem_estimate_bytes / 2**20:6.2f} MiB "
             f"(budget {a.vmem_budget_bytes / 2**20:.0f} MiB)  {status}")
        for f in a.findings:
            emit(f"         {f.describe()}")
            bad += 1
        rows.append({
            "name": a.name, "grid": list(a.grid), "ct": str(a.ct),
            "vmem_estimate_bytes": int(a.vmem_estimate_bytes),
            "vmem_budget_bytes": int(a.vmem_budget_bytes),
            "fits": bool(a.fits),
            "findings": [f.describe() for f in a.findings],
        })
    emit(f"[pallas] {len(audits)} kernel(s) audited, {bad} finding(s)")
    return (1 if bad else 0), {"kernels": rows}


def _run_jaxpr_check(emit) -> tuple:
    """Scaling smoke on the quickstart model: value_and_grad of the chunked
    SGPR loss must keep every intermediate strictly below O(N*M)."""
    import jax
    import jax.numpy as jnp

    from repro.analysis.jaxpr_check import ScalingViolation, assert_no_scaling
    from repro.gp import SparseGPRegression, get

    checks = []

    N, M, chunk = 4096, 32, 512
    key = jax.random.PRNGKey(0)
    X = jax.random.uniform(key, (N, 1), jnp.float32, -3.0, 3.0)
    Y = jnp.sin(2.0 * X)
    gp = SparseGPRegression(kernel=get("rbf")(1), M=M, chunk=chunk)
    p = gp.init_params(X, Y)
    try:
        report = assert_no_scaling(
            jax.value_and_grad(gp._loss_fn()), p, X, Y,
            axis="N", worse_than="N*M", sizes={"N": N, "M": M})
    except ScalingViolation as exc:
        emit(f"[jaxpr] FAIL: {exc}")
        return 1, {"checks": checks, "error": str(exc)}
    emit(f"[jaxpr] quickstart SGPR value_and_grad: worst intermediate "
         f"{report.worst_class} — below the O(N*M) bound")
    checks.append({"name": "sgpr_value_and_grad", "bound": "N*M",
                   "worst_class": report.worst_class})

    # the temporal backend's sequential training loss must stay O(N): no
    # (N, N) Gram matrix may appear anywhere in value_and_grad. (The
    # parallel path can't be traced at two sizes — associative_scan's tree
    # changes structure with N — so the scan lanes in tests/test_temporal.py
    # cover it via single-trace intermediates instead.)
    from repro.gp import regression

    n = 2048
    gaps = jax.random.uniform(jax.random.fold_in(key, 2), (n,),
                              minval=0.5e-3, maxval=1.5e-3)
    t = jnp.cumsum(gaps)  # the loss core takes flat (N,) times
    y = jnp.sin(4.0 * t)[:, None]
    tgp = regression(get("matern32")(1), backend="temporal", parallel=False)
    tp = tgp.init_params(t[:, None], y)
    loss = tgp._loss_fn()
    try:
        report = assert_no_scaling(
            jax.value_and_grad(loss), tp, t, y,
            axis="N", worse_than="N^2", sizes={"N": n})
    except ScalingViolation as exc:
        emit(f"[jaxpr] FAIL: {exc}")
        return 1, {"checks": checks, "error": str(exc)}
    emit(f"[jaxpr] temporal sequential value_and_grad: worst intermediate "
         f"{report.worst_class} — below the O(N^2) bound")
    checks.append({"name": "temporal_sequential_value_and_grad",
                   "bound": "N^2", "worst_class": report.worst_class})
    return 0, {"checks": checks}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static-analysis passes over the repro tree")
    ap.add_argument("--all", action="store_true",
                    help="run every pass (default when no pass is selected)")
    ap.add_argument("--lint", action="store_true", help="AST lint rules")
    ap.add_argument("--concurrency", action="store_true",
                    help="lock-acquisition graph: order cycles (ANL005), "
                         "guard-inferred races (ANL006), blocking under "
                         "locks (ANL007)")
    ap.add_argument("--pallas-audit", action="store_true",
                    help="Pallas kernel VMEM/tiling/dtype audit")
    ap.add_argument("--jaxpr-check", action="store_true",
                    help="scaling-class smoke on the quickstart SGPR loss")
    ap.add_argument("--vmem-budget", type=int, default=None, metavar="BYTES",
                    help="override the per-core VMEM budget for the audit")
    ap.add_argument("--format", choices=("text", "json"), default="text",
                    help="text (default) prints findings with file:line; "
                         "json emits one machine-readable document")
    ap.add_argument("paths", nargs="*", metavar="PATH",
                    help="restrict the lint/concurrency passes to these "
                         "files (default: every .py under src/repro)")
    args = ap.parse_args(argv)

    from repro.analysis.pallas_audit import VMEM_BUDGET_BYTES

    budget = args.vmem_budget or VMEM_BUDGET_BYTES
    chosen = (args.lint or args.concurrency or args.pallas_audit
              or args.jaxpr_check)
    run_all = args.all or not chosen
    text = args.format == "text"
    emit = print if text else (lambda *_a, **_k: None)

    failures = 0
    passes = {}
    if run_all or args.lint:
        rc, passes["lint"] = _run_lint(args.paths, emit)
        failures += rc
    if run_all or args.concurrency:
        rc, passes["concurrency"] = _run_concurrency(args.paths, emit)
        failures += rc
    if run_all or args.pallas_audit:
        rc, passes["pallas_audit"] = _run_pallas_audit(budget, emit)
        failures += rc
    if run_all or args.jaxpr_check:
        rc, passes["jaxpr_check"] = _run_jaxpr_check(emit)
        failures += rc

    if text:
        if failures:
            print(f"static analysis: {failures} pass(es) failed")
        else:
            print("static analysis: all passes clean")
    else:
        print(json.dumps({"passes": passes, "failures": failures,
                          "ok": failures == 0}, indent=2, sort_keys=True))
    return failures


if __name__ == "__main__":
    sys.exit(main())
