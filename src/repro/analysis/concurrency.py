"""Static concurrency analyzer: the lock discipline of the serving tier,
machine-checked.

The multithreaded runtime packages (`repro.serve`, `repro.tune`,
`repro.checkpoint`) stay deadlock- and race-free by a hand-reasoned lock
protocol (the `_budget_lock -> entry.lock -> _registry_lock` order, the
"registry access only under its lock" rule, "eviction persists under the
entry lock"). This pass turns that protocol into rules the CI lane
enforces, the way ANL001-ANL004 froze earlier hand-fixed bug classes:

  ANL005  lock-order cycle. The whole-repo lock-acquisition graph (edge
          A -> B whenever B is acquired while A is held) must be acyclic,
          and every edge between locks named in `LOCK_HIERARCHY` must
          respect the declared order. An AB/BA pair is a deadlock waiting
          for the right interleaving.
  ANL006  guarded attribute touched without a lock. Generalizes the old
          hardcoded `_models`/`_registry_lock` rule (ANL002, kept as a
          `# noqa` alias): any attribute that is *written under a lock*
          somewhere outside `__init__` is shared mutable state, and every
          lock-free read or write of it elsewhere is a race candidate.
          PR 5's registry-iteration race was exactly such a lock-free read.
  ANL007  blocking call while holding a lock. `Future.result`, queue
          `get`s, waits, file I/O and device calls under a lock stall every
          thread behind that lock (and invert lock-vs-IO ordering under
          load). Locks whose documented JOB is serializing I/O — the
          checkpoint-store and tune-cache locks — are declared in
          `BLOCKING_OK`; `cond.wait()` on the condition you hold is the
          intended CV pattern and is exempt.

Everything here is stdlib-only AST work: nothing imports jax, so the
runtime verifier (`repro.analysis.lockdep`) and `repro.tune.cache` can
import the lock-hierarchy declaration without dragging in the compiler.

Scope and honesty notes (what "static" means here):

* Analysis is intraprocedural: a lock held by the *caller* is invisible
  inside the callee. Functions whose name ends in ``_locked`` are the
  declared "caller holds the lock" convention — their bodies are exempt
  from ANL006 and do not feed guard inference.
* A write under a *different* lock than usual (the mixed-guard pattern,
  e.g. counters bumped under `_cv` and snapshot under `_registry_lock`)
  is left to the runtime verifier; the static rule only flags accesses
  holding no lock at all.
* `self`-attribute inference is per-class; attributes reached through
  other objects (`entry.state`) are covered by the lock-graph + lockdep,
  not by ANL006.

Suppress a finding inline with ``# noqa: ANL00x``; ``# noqa: ANL002``
still suppresses the generalized rule (alias).
"""
from __future__ import annotations

import ast
import dataclasses
import pathlib
import re
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

__all__ = [
    "RULES",
    "LOCK_HIERARCHY",
    "BLOCKING_OK",
    "ALIASES",
    "ConcurrencyFinding",
    "LockDef",
    "Acquisition",
    "ConcurrencyModel",
    "analyze_sources",
    "analyze_paths",
    "guard_findings",
    "noqa_codes",
    "suppressed",
]

RULES: Dict[str, str] = {
    "ANL005": "lock-order cycle / declared-hierarchy inversion",
    "ANL006": "lock-guarded attribute accessed without a lock "
              "(generalizes ANL002)",
    "ANL007": "blocking call while holding a lock",
}

# Old rule IDs accepted in `# noqa:` comments for the rule that replaced
# them. ANL002 ("_models outside _registry_lock") is now derived from guard
# inference and reported as ANL006.
ALIASES: Dict[str, str] = {"ANL002": "ANL006"}

# ---------------------------------------------------------------------------
# the declared global lock hierarchy
# ---------------------------------------------------------------------------

# Total acquisition order over every named lock in the runtime packages:
# a thread holding a lock may only acquire locks FURTHER DOWN this list.
# This is the single statement of the ordering docs/serving.md used to
# carry in prose; the static pass checks every visible edge against it and
# `repro.analysis.lockdep` enforces it at runtime. Constraints encoded:
#   _cv           never wraps another acquisition (queue ops only);
#   _budget_lock  serializes residency transitions and wraps entry locks
#                 (`_insert`, `_resident_state`, `_make_room`/`_evict`);
#   _Entry.lock   wraps store I/O (evict-persists-dirty, lazy reload) and
#                 the leaf registry lock, and may reach the tune locks via
#                 `online.update` -> `kernels.ops` -> `repro.tune`;
#   tune locks    autotune's resolve-measure-store cycle wraps the cache
#                 file lock;
#   StateStore    wraps nothing but the checkpoint manager (lock-free);
#   _registry_lock is a leaf: nothing is ever acquired under it.
LOCK_HIERARCHY: Tuple[str, ...] = (
    "GPServer._cv",
    "GPServer._budget_lock",
    "_Entry.lock",
    "repro.tune.autotune._LOCK",
    "repro.tune.cache._LOCK",
    "StateStore._lock",
    "GPServer._registry_lock",
)

# Locks whose declared purpose is serializing blocking work (checkpoint
# file I/O, the tune-cache read-merge-write cycle). ANL007 does not fire
# while ONLY these are held — for anything else, blocking under the lock
# is a finding.
BLOCKING_OK = frozenset({
    "StateStore._lock",
    "repro.tune.cache._LOCK",
})

_RANK: Dict[str, int] = {name: i for i, name in enumerate(LOCK_HIERARCHY)}

# ---------------------------------------------------------------------------
# noqa handling (shared with repro.analysis.lint)
# ---------------------------------------------------------------------------

NOQA_RE = re.compile(r"#\s*noqa:\s*(ANL\d{3}(?:\s*,\s*ANL\d{3})*)")


def noqa_codes(source_lines: Sequence[str], line: int) -> Set[str]:
    """The ANL codes suppressed on `line` (1-indexed) of the source."""
    if 1 <= line <= len(source_lines):
        m = NOQA_RE.search(source_lines[line - 1])
        if m:
            return {c.strip() for c in m.group(1).split(",")}
    return set()


def suppressed(code: str, codes: Set[str]) -> bool:
    """Is a finding with `code` suppressed by the noqa set `codes`?
    Honors `ALIASES` in both directions (`# noqa: ANL002` mutes ANL006)."""
    if code in codes:
        return True
    return any(ALIASES.get(c) == code for c in codes)


# ---------------------------------------------------------------------------
# model dataclasses
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ConcurrencyFinding:
    path: str
    line: int
    code: str
    message: str

    def describe(self) -> str:
        return f"{self.path}:{self.line}: {self.code} {self.message}"

    def as_dict(self) -> Dict[str, object]:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class LockDef:
    """One lock object the repo creates: canonical name, primitive kind,
    and the definition site."""
    name: str
    kind: str  # "lock" | "rlock" | "condition"
    path: str
    line: int


@dataclasses.dataclass(frozen=True)
class Acquisition:
    """One acquisition site: the lock taken, where, and what was held."""
    lock: str
    path: str
    line: int
    held: Tuple[str, ...]


@dataclasses.dataclass
class ConcurrencyModel:
    """The whole-repo lock model the findings are derived from."""
    defs: Dict[str, LockDef] = dataclasses.field(default_factory=dict)
    acquisitions: List[Acquisition] = dataclasses.field(default_factory=list)
    # edge (held -> acquired) -> every site that witnesses it
    edges: Dict[Tuple[str, str], List[Tuple[str, int]]] = dataclasses.field(
        default_factory=dict)
    findings: List[ConcurrencyFinding] = dataclasses.field(
        default_factory=list)


# ---------------------------------------------------------------------------
# pass 1: lock definitions
# ---------------------------------------------------------------------------

_FACTORY_KINDS = {
    "Lock": "lock",
    "RLock": "rlock",
    "Condition": "condition",
    "BoundedSemaphore": "lock",
    "Semaphore": "lock",
}

_GUARD_EXEMPT_FUNCS = {"__init__", "__new__", "__post_init__"}

# attribute names treated as locks even without a visible definition
_LOCKISH = re.compile(r"lock|mutex|_cv$|cond|sem", re.IGNORECASE)

_MUTATING_METHODS = {
    "append", "appendleft", "extend", "insert", "add", "remove", "discard",
    "pop", "popleft", "popitem", "clear", "update", "setdefault",
    "move_to_end", "sort", "reverse",
}


def _dotted(node: ast.AST) -> Optional[str]:
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _module_qual(relpath: str) -> str:
    """'repro/tune/cache.py' -> 'repro.tune.cache' (best effort)."""
    p = relpath.replace("\\", "/")
    if p.endswith(".py"):
        p = p[:-3]
    return p.strip("/").replace("/", ".")


def _lock_factory_kind(value: ast.AST) -> Optional[Tuple[str, Optional[str]]]:
    """(kind, explicit_name) if `value` constructs a lock.

    Recognizes `threading.Lock()` / `Lock()` / `RLock()` / `Condition()`
    and `lockdep.named_lock("canonical.name", kind=...)` (whose first
    argument IS the canonical name)."""
    if not isinstance(value, ast.Call):
        return None
    dotted = _dotted(value.func) or ""
    leaf = dotted.rsplit(".", 1)[-1]
    if leaf == "named_lock":
        name = None
        if value.args and isinstance(value.args[0], ast.Constant) \
                and isinstance(value.args[0].value, str):
            name = value.args[0].value
        kind = "lock"
        for kw in value.keywords:
            if kw.arg == "kind" and isinstance(kw.value, ast.Constant):
                kind = str(kw.value.value)
        return kind, name
    if leaf in _FACTORY_KINDS and (dotted == leaf
                                   or dotted == f"threading.{leaf}"):
        return _FACTORY_KINDS[leaf], None
    return None


class _DefCollector(ast.NodeVisitor):
    """Finds every lock definition in one module: `self.X = Lock()` inside
    a class, `NAME = Lock()` at module scope, and `named_lock(...)`
    wrappers (which carry their canonical name explicitly)."""

    def __init__(self, relpath: str):
        self.relpath = relpath
        self.modqual = _module_qual(relpath)
        self._class_stack: List[str] = []
        self._func_depth = 0
        # (class, attr) -> LockDef ; (modqual, NAME) -> LockDef
        self.class_defs: Dict[Tuple[str, str], LockDef] = {}
        self.module_defs: Dict[Tuple[str, str], LockDef] = {}

    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        self._class_stack.append(node.name)
        self.generic_visit(node)
        self._class_stack.pop()

    def _visit_func(self, node) -> None:
        self._func_depth += 1
        self.generic_visit(node)
        self._func_depth -= 1

    visit_FunctionDef = _visit_func
    visit_AsyncFunctionDef = _visit_func
    visit_Lambda = _visit_func

    def _record(self, target: ast.AST, value: ast.AST, line: int) -> None:
        got = _lock_factory_kind(value)
        if got is None:
            return
        kind, explicit = got
        if (isinstance(target, ast.Attribute)
                and isinstance(target.value, ast.Name)
                and target.value.id == "self" and self._class_stack):
            cls = self._class_stack[-1]
            name = explicit or f"{cls}.{target.attr}"
            self.class_defs[(cls, target.attr)] = LockDef(
                name, kind, self.relpath, line)
        elif isinstance(target, ast.Name) and self._func_depth == 0:
            name = explicit or f"{self.modqual}.{target.id}"
            self.module_defs[(self.modqual, target.id)] = LockDef(
                name, kind, self.relpath, line)

    def visit_Assign(self, node: ast.Assign) -> None:
        for t in node.targets:
            self._record(t, node.value, node.lineno)
        self.generic_visit(node)

    def visit_AnnAssign(self, node: ast.AnnAssign) -> None:
        if node.value is not None:
            self._record(node.target, node.value, node.lineno)
        self.generic_visit(node)


# ---------------------------------------------------------------------------
# pass 2: per-function lock walking
# ---------------------------------------------------------------------------

# call leaves that block regardless of receiver
_BLOCKING_LEAVES = {
    "result",             # concurrent.futures.Future.result
    "block_until_ready",  # device sync
    "read_text", "write_text", "read_bytes", "write_bytes",  # pathlib I/O
    "urlopen",
}
# dotted names that block
_BLOCKING_DOTTED = {
    "time.sleep",
    "jax.block_until_ready", "jax.device_put", "jax.device_get",
    "json.dump", "json.load",
    "np.savez", "numpy.savez", "np.load", "numpy.load",
    "pickle.dump", "pickle.load",
    "os.replace", "os.rename", "os.fdopen", "os.makedirs",
    "shutil.rmtree", "shutil.copy", "shutil.copytree", "shutil.move",
}
# bare callables that block
_BLOCKING_BARE = {"open", "input"}


@dataclasses.dataclass
class _Access:
    kind: str  # "read" | "write"
    line: int
    held: Tuple[str, ...]
    func: Optional[str]
    exempt: bool


def _module_global_names(tree: ast.Module) -> Set[str]:
    """Names bound by assignment at module top level — the only names the
    guard inference may treat as shared module globals."""
    out: Set[str] = set()

    def targets(node: ast.AST) -> None:
        if isinstance(node, ast.Name):
            out.add(node.id)
        elif isinstance(node, (ast.Tuple, ast.List)):
            for e in node.elts:
                targets(e)

    for stmt in tree.body:
        if isinstance(stmt, ast.Assign):
            for t in stmt.targets:
                targets(t)
        elif isinstance(stmt, (ast.AnnAssign, ast.AugAssign)):
            targets(stmt.target)
    return out


def _scope_locals(node) -> Set[str]:
    """Names local to a function scope: parameters plus every name bound
    anywhere in its immediate body (Python's whole-function local rule).
    Nested defs/lambdas are separate scopes and are not descended into."""
    locs: Set[str] = set()
    args = getattr(node, "args", None)
    if args is not None:
        for a in (list(args.posonlyargs) + list(args.args)
                  + list(args.kwonlyargs)):
            locs.add(a.arg)
        if args.vararg:
            locs.add(args.vararg.arg)
        if args.kwarg:
            locs.add(args.kwarg.arg)

    def scan(n: ast.AST) -> None:
        for child in ast.iter_child_nodes(n):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.Lambda, ast.ClassDef)):
                if isinstance(child, (ast.FunctionDef,
                                      ast.AsyncFunctionDef, ast.ClassDef)):
                    locs.add(child.name)
                continue
            if isinstance(child, ast.Name) and isinstance(
                    child.ctx, (ast.Store, ast.Del)):
                locs.add(child.id)
            elif isinstance(child, ast.ExceptHandler) and child.name:
                locs.add(child.name)
            elif isinstance(child, (ast.Import, ast.ImportFrom)):
                for alias in child.names:
                    locs.add((alias.asname or alias.name).split(".")[0])
            scan(child)

    body = getattr(node, "body", None)
    if isinstance(body, list):
        for stmt in body:
            scan(stmt)
    return locs


class _FileWalker(ast.NodeVisitor):
    """Walks one module with a held-lock stack, collecting acquisitions,
    ANL007 findings, and the attribute accesses guard inference consumes."""

    def __init__(self, relpath: str,
                 class_defs: Dict[Tuple[str, str], LockDef],
                 module_defs: Dict[Tuple[str, str], LockDef],
                 attr_owners: Dict[str, Set[str]],
                 module_globals: Set[str]):
        self.relpath = relpath
        self.modqual = _module_qual(relpath)
        self.class_defs = class_defs
        self.module_defs = module_defs
        self.attr_owners = attr_owners
        self.module_globals = module_globals
        self._class_stack: List[str] = []
        self._func_stack: List[str] = []
        # per-function (locals, names declared `global`)
        self._scope_stack: List[Tuple[Set[str], Set[str]]] = []
        self._held: List[str] = []
        self.acquisitions: List[Acquisition] = []
        self.edges: Dict[Tuple[str, str], List[Tuple[str, int]]] = {}
        self.blocking: List[ConcurrencyFinding] = []
        # ("class", C) or ("module", modqual) -> attr -> [_Access]
        self.accesses: Dict[Tuple[str, str], Dict[str, List[_Access]]] = {}

    # -- helpers -----------------------------------------------------------

    def _func(self) -> Optional[str]:
        return self._func_stack[-1] if self._func_stack else None

    def _exempt_func(self) -> bool:
        f = self._func()
        if f is None:  # module scope: definitions, not shared mutation
            return True
        return f in _GUARD_EXEMPT_FUNCS or f.endswith("_locked")

    def _resolve_lock(self, node: ast.AST) -> Optional[str]:
        """Canonical lock name for an acquisition expression, or None if
        the expression is not a known lock."""
        if isinstance(node, ast.Name):
            d = self.module_defs.get((self.modqual, node.id))
            return d.name if d else None
        if isinstance(node, ast.Attribute) and isinstance(node.value, ast.Name):
            attr = node.attr
            if node.value.id == "self" and self._class_stack:
                cls = self._class_stack[-1]
                d = self.class_defs.get((cls, attr))
                if d:
                    return d.name
                owners = self.attr_owners.get(attr, set())
                if len(owners) == 1:
                    return f"{next(iter(owners))}.{attr}"
                if _LOCKISH.search(attr):
                    # no visible definition (partial source, lock injected
                    # by a factory) but the name says lock: still model the
                    # acquisition so guard inference works on snippets
                    return f"{cls}.{attr}"
                return None
            owners = self.attr_owners.get(attr, set())
            if len(owners) == 1:
                return f"{next(iter(owners))}.{attr}"
            if len(owners) > 1:
                return f"*.{attr}"  # merged lock class (conservative)
        return None

    def _lock_kind(self, name: str) -> Optional[str]:
        for d in self.class_defs.values():
            if d.name == name:
                return d.kind
        for d in self.module_defs.values():
            if d.name == name:
                return d.kind
        return None

    def _note_acquire(self, name: str, node: ast.AST) -> None:
        site = (self.relpath, node.lineno)
        self.acquisitions.append(
            Acquisition(name, self.relpath, node.lineno, tuple(self._held)))
        for held in self._held:
            if held == name and self._lock_kind(name) == "rlock":
                continue  # re-entrant by construction
            self.edges.setdefault((held, name), []).append(site)

    # -- scope tracking ----------------------------------------------------

    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        self._class_stack.append(node.name)
        self.generic_visit(node)
        self._class_stack.pop()

    def _visit_func(self, node) -> None:
        # a nested def's body does not run under the enclosing with
        saved, self._held = self._held, []
        self._func_stack.append(getattr(node, "name", "<lambda>"))
        self._scope_stack.append((_scope_locals(node), set()))
        self.generic_visit(node)
        self._scope_stack.pop()
        self._func_stack.pop()
        self._held = saved

    visit_FunctionDef = _visit_func
    visit_AsyncFunctionDef = _visit_func
    visit_Lambda = _visit_func

    def visit_Global(self, node: ast.Global) -> None:
        if self._scope_stack:
            self._scope_stack[-1][1].update(node.names)

    def visit_With(self, node: ast.With) -> None:
        pushed = 0
        for item in node.items:
            self.visit(item.context_expr)  # attr reads inside the expr
            if item.optional_vars is not None:
                self.visit(item.optional_vars)
            name = self._resolve_lock(item.context_expr)
            if name is not None:
                self._note_acquire(name, item.context_expr)
                self._held.append(name)
                pushed += 1
        for stmt in node.body:
            self.visit(stmt)
        if pushed:
            del self._held[-pushed:]

    # -- rules -------------------------------------------------------------

    def _blocking_finding(self, node: ast.Call, what: str) -> None:
        self.blocking.append(ConcurrencyFinding(
            self.relpath, node.lineno, "ANL007",
            f"blocking call `{what}` while holding "
            f"{' -> '.join(self._held)}: every thread behind the lock "
            f"stalls on this operation (move it outside the critical "
            f"section, or declare the lock in BLOCKING_OK if serializing "
            f"this is its documented job)"))

    def visit_Call(self, node: ast.Call) -> None:
        func = node.func
        dotted = _dotted(func) or ""
        leaf = dotted.rsplit(".", 1)[-1] if dotted else ""

        # acquire()/release() outside a with-statement
        if isinstance(func, ast.Attribute) and func.attr in ("acquire",
                                                             "release"):
            name = self._resolve_lock(func.value)
            if name is not None:
                if func.attr == "acquire":
                    self._note_acquire(name, node)
                    self._held.append(name)
                else:
                    for i in range(len(self._held) - 1, -1, -1):
                        if self._held[i] == name:
                            del self._held[i]
                            break
                self.generic_visit(node)
                return

        # ANL007: blocking work under a lock
        if self._held and not all(h in BLOCKING_OK for h in self._held):
            receiver = (self._resolve_lock(func.value)
                        if isinstance(func, ast.Attribute) else None)
            if leaf == "wait" and receiver is not None \
                    and receiver in self._held:
                pass  # cond.wait() on the held condition: the CV pattern
            elif dotted in _BLOCKING_DOTTED:
                self._blocking_finding(node, dotted)
            elif dotted in _BLOCKING_BARE:
                self._blocking_finding(node, dotted)
            elif leaf in _BLOCKING_LEAVES and isinstance(func, ast.Attribute):
                self._blocking_finding(node, dotted or leaf)
            elif leaf == "wait" and isinstance(func, ast.Attribute):
                self._blocking_finding(node, dotted or leaf)
            elif (leaf == "get" and isinstance(func, ast.Attribute)
                  and "queue" in (_dotted(func.value) or "").lower()):
                self._blocking_finding(node, dotted or leaf)

        # attribute-mutating method calls count as writes for inference
        if isinstance(func, ast.Attribute) and func.attr in _MUTATING_METHODS:
            self._note_attr(func.value, "write")

        self.generic_visit(node)

    # -- attribute accesses (guard inference input) ------------------------

    def _owner_key(self, node: ast.AST) -> Optional[Tuple[Tuple[str, str], str]]:
        """((scope-kind, scope-name), attr) for self.X; module-global NAME.

        A bare name only counts as a module global if it is bound at
        module top level AND (per Python's scoping rules) not shadowed by
        a local of the enclosing function — unless declared ``global``."""
        if (isinstance(node, ast.Attribute)
                and isinstance(node.value, ast.Name)
                and node.value.id == "self" and self._class_stack):
            return ("class", self._class_stack[-1]), node.attr
        if isinstance(node, ast.Name) and node.id in self.module_globals:
            for locs, gdecls in self._scope_stack:
                if node.id in gdecls:
                    continue
                if node.id in locs:
                    return None  # a function local shadows the global
            return ("module", self.modqual), node.id
        return None

    def _note_attr(self, node: ast.AST, kind: str,
                   line: Optional[int] = None) -> None:
        got = self._owner_key(node)
        if got is None:
            return
        owner, attr = got
        self.accesses.setdefault(owner, {}).setdefault(attr, []).append(
            _Access(kind, line or node.lineno, tuple(self._held),
                    self._func(), self._exempt_func()))

    def visit_Attribute(self, node: ast.Attribute) -> None:
        kind = "write" if isinstance(node.ctx, (ast.Store, ast.Del)) else "read"
        self._note_attr(node, kind)
        self.generic_visit(node)

    def visit_Subscript(self, node: ast.Subscript) -> None:
        # self._models[k] = v  /  _MEMO[key] = v  are writes to the mapping
        if isinstance(node.ctx, (ast.Store, ast.Del)):
            self._note_attr(node.value, "write", line=node.lineno)
        self.generic_visit(node)

    def visit_Name(self, node: ast.Name) -> None:
        if not self._func_stack:
            return  # module scope: definitions, not shared mutation
        if isinstance(node.ctx, (ast.Store, ast.Del)):
            self._note_attr(node, "write")
        elif isinstance(node.ctx, ast.Load):
            self._note_attr(node, "read")


# ---------------------------------------------------------------------------
# guard inference (ANL006) — shared with repro.analysis.lint
# ---------------------------------------------------------------------------

def _infer_findings(relpath: str,
                    accesses: Dict[Tuple[str, str], Dict[str, List[_Access]]],
                    ) -> List[ConcurrencyFinding]:
    findings: List[ConcurrencyFinding] = []
    for (scope_kind, scope_name), attrs in accesses.items():
        for attr, acc in attrs.items():
            guarded_writes = [a for a in acc
                              if a.kind == "write" and a.held and not a.exempt]
            if not guarded_writes:
                continue  # not shared mutable state under a lock: untracked
            guards = sorted({h for a in guarded_writes for h in a.held})
            gsite = guarded_writes[0]
            what = f"self.{attr}" if scope_kind == "class" else attr
            flagged_lines: Set[int] = set()
            for a in acc:
                if a.held or a.exempt:
                    continue
                # one finding per line: a mutating-method call records both
                # the write and the receiver read at the same site
                if a.line in flagged_lines:
                    continue
                flagged_lines.add(a.line)
                findings.append(ConcurrencyFinding(
                    relpath, a.line, "ANL006",
                    f"`{what}` {a.kind} without a lock, but it is written "
                    f"under {' / '.join(f'`{g}`' for g in guards)} "
                    f"(e.g. line {gsite.line}) — lock-free access races "
                    f"the guarded writers"))
    findings.sort(key=lambda f: f.line)
    return findings


def guard_findings(source: str, relpath: str) -> List[ConcurrencyFinding]:
    """ANL006 findings for one module (noqa already applied). This is the
    generalized ANL002: guards are INFERRED from where attributes are
    written under locks, not hardcoded per attribute."""
    model = analyze_sources([(relpath, source)])
    return [f for f in model.findings if f.code == "ANL006"]


# ---------------------------------------------------------------------------
# cycles + hierarchy (ANL005)
# ---------------------------------------------------------------------------

def _sccs(nodes: Sequence[str],
          adj: Dict[str, Set[str]]) -> List[List[str]]:
    """Tarjan SCCs, iterative (analysis code must not recurse on repo
    size)."""
    index: Dict[str, int] = {}
    low: Dict[str, int] = {}
    on_stack: Set[str] = set()
    stack: List[str] = []
    out: List[List[str]] = []
    counter = [0]

    for root in nodes:
        if root in index:
            continue
        work: List[Tuple[str, Iterable[str]]] = [(root, iter(adj.get(root, ())))]
        index[root] = low[root] = counter[0]
        counter[0] += 1
        stack.append(root)
        on_stack.add(root)
        while work:
            v, it = work[-1]
            advanced = False
            for w in it:
                if w not in index:
                    index[w] = low[w] = counter[0]
                    counter[0] += 1
                    stack.append(w)
                    on_stack.add(w)
                    work.append((w, iter(adj.get(w, ()))))
                    advanced = True
                    break
                if w in on_stack:
                    low[v] = min(low[v], index[w])
            if advanced:
                continue
            work.pop()
            if work:
                pv = work[-1][0]
                low[pv] = min(low[pv], low[v])
            if low[v] == index[v]:
                comp = []
                while True:
                    w = stack.pop()
                    on_stack.discard(w)
                    comp.append(w)
                    if w == v:
                        break
                out.append(comp)
    return out


def _cycle_findings(edges: Dict[Tuple[str, str], List[Tuple[str, int]]],
                    ) -> List[ConcurrencyFinding]:
    findings: List[ConcurrencyFinding] = []
    adj: Dict[str, Set[str]] = {}
    nodes: List[str] = []
    for (a, b) in edges:
        if a not in adj:
            adj[a] = set()
            nodes.append(a)
        if b not in adj:
            adj[b] = set()
            nodes.append(b)
        adj[a].add(b)

    def _fmt(a: str, b: str) -> str:
        path, line = sorted(edges[(a, b)])[0]
        return f"{a} -> {b} ({path}:{line})"

    # self-deadlock: non-reentrant lock re-acquired while held
    for (a, b), sites in sorted(edges.items()):
        if a == b:
            path, line = sorted(sites)[0]
            findings.append(ConcurrencyFinding(
                path, line, "ANL005",
                f"`{a}` acquired while already held by the same thread "
                f"(non-reentrant lock: guaranteed self-deadlock)"))

    # cycles across locks
    for comp in _sccs(nodes, adj):
        if len(comp) < 2:
            continue
        comp_set = set(comp)
        cyc_edges = sorted((a, b) for (a, b) in edges
                           if a in comp_set and b in comp_set and a != b)
        detail = "; ".join(_fmt(a, b) for a, b in cyc_edges)
        path, line = sorted(edges[cyc_edges[0]])[0]
        findings.append(ConcurrencyFinding(
            path, line, "ANL005",
            f"lock-order cycle between {', '.join(sorted(comp_set))}: "
            f"{detail} — two threads interleaving these acquisitions "
            f"deadlock"))

    # declared-hierarchy inversions (no cycle needed: the declared order
    # is the contract even before the reverse edge ships)
    for (a, b), sites in sorted(edges.items()):
        ra, rb = _RANK.get(a), _RANK.get(b)
        if ra is not None and rb is not None and rb < ra:
            path, line = sorted(sites)[0]
            findings.append(ConcurrencyFinding(
                path, line, "ANL005",
                f"`{b}` acquired while holding `{a}` inverts the declared "
                f"lock hierarchy (LOCK_HIERARCHY ranks {b} before {a})"))
    return findings


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def analyze_sources(sources: Sequence[Tuple[str, str]]) -> ConcurrencyModel:
    """Build the lock model and findings for (relpath, source) pairs.
    Definitions are collected across ALL files first, so `entry.lock` in
    one module resolves against `_Entry.__init__` in another."""
    model = ConcurrencyModel()
    parsed: List[Tuple[str, str, ast.AST]] = []
    class_defs: Dict[Tuple[str, str], LockDef] = {}
    module_defs: Dict[Tuple[str, str], LockDef] = {}
    for relpath, source in sources:
        relpath = relpath.replace("\\", "/")
        try:
            tree = ast.parse(source, filename=relpath)
        except SyntaxError as exc:
            model.findings.append(ConcurrencyFinding(
                relpath, exc.lineno or 0, "ANL000",
                f"syntax error: {exc.msg}"))
            continue
        parsed.append((relpath, source, tree))
        coll = _DefCollector(relpath)
        coll.visit(tree)
        class_defs.update(coll.class_defs)
        module_defs.update(coll.module_defs)

    attr_owners: Dict[str, Set[str]] = {}
    for (cls, attr) in class_defs:
        attr_owners.setdefault(attr, set()).add(cls)
    for d in list(class_defs.values()) + list(module_defs.values()):
        model.defs[d.name] = d

    raw: List[ConcurrencyFinding] = []
    for relpath, source, tree in parsed:
        walker = _FileWalker(relpath, class_defs, module_defs, attr_owners,
                             _module_global_names(tree))
        walker.visit(tree)
        model.acquisitions.extend(walker.acquisitions)
        for edge, sites in walker.edges.items():
            model.edges.setdefault(edge, []).extend(sites)
        raw.extend(walker.blocking)
        raw.extend(_infer_findings(relpath, walker.accesses))

    raw.extend(_cycle_findings(model.edges))

    # noqa filtering, per file
    lines_by_path = {relpath: source.splitlines()
                     for relpath, source, _ in parsed}
    for f in raw:
        codes = noqa_codes(lines_by_path.get(f.path, ()), f.line)
        if not suppressed(f.code, codes):
            model.findings.append(f)
    model.findings.sort(key=lambda f: (f.path, f.line, f.code))
    return model


def analyze_paths(paths: Optional[Iterable[pathlib.Path]] = None,
                  root: Optional[pathlib.Path] = None) -> ConcurrencyModel:
    """Analyze a set of files (default: every .py under src/repro — the
    same walk as `repro.analysis.lint.lint_paths`)."""
    if root is None:
        root = pathlib.Path(__file__).resolve().parents[2]
    if paths is None:
        paths = sorted((root / "repro").rglob("*.py"))
    sources: List[Tuple[str, str]] = []
    for path in paths:
        resolved = pathlib.Path(path).resolve()
        try:
            rel = str(resolved.relative_to(root))
        except ValueError:  # outside src/ (e.g. a fixture): report as given
            rel = str(path)
        sources.append((rel, resolved.read_text(encoding="utf-8")))
    return analyze_sources(sources)
