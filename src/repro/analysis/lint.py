"""AST-based repo lint: the invariants PRs 3-5 fixed by hand, as rules.

Each rule encodes a bug class that actually shipped (and was reverted) in
this repo's history:

  ANL001  import-time platform dispatch. `jax.devices()` /
          `jax.default_backend()` at module scope bakes the backend present
          at import into module state; under `jax.distributed` or test
          reordering that snapshot is stale. Platform reads must happen at
          call time (the `interpret_mode()` pattern in `kernels/ops.py`).
  ANL002  unguarded shared-state access — now an alias. The original rule
          hardcoded one attribute/lock pair (`_models`/`_registry_lock`);
          it is generalized by `repro.analysis.concurrency`'s guard
          inference (ANL006): ANY attribute written under a lock is
          tracked, and every lock-free access of it is flagged. `lint`
          reports those findings inline, and `# noqa: ANL002` comments
          keep working (the alias suppresses ANL006).
  ANL003  backward-pass registration outside the dispatcher. Kernel modules
          must not call `jax.vjp` or register `.defvjp` themselves — the
          lru-cached op factories in `kernels/ops.py` own custom-VJP wiring
          so `bwd_backend` dispatch ("pallas" | "reference") stays the only
          switch. A stray `defvjp` in a kernel file silently shadows it.
  ANL004  hard-coded compute dtypes in kernel files. Kernel bodies take
          their dtype from the promotion helpers (`ct = ...`); a literal
          `dtype=jnp.float32` / `.astype(jnp.float32)` in a kernel file
          breaks the f64 interpret-mode parity path.

Suppress a finding inline with `# noqa: ANL00x` on the offending line.
`lint_source` lints a string (used by the seeded-violation fixtures);
`lint_paths` walks the tree.
"""
from __future__ import annotations

import ast
import dataclasses
import pathlib
import re
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

__all__ = ["LintFinding", "RULES", "lint_source", "lint_paths"]

RULES: Dict[str, str] = {
    "ANL001": "import-time platform dispatch (use interpret_mode() / "
              "call-time jax.devices())",
    "ANL002": "alias of ANL006: lock-guarded attribute accessed without "
              "a lock (guard inference in repro.analysis.concurrency)",
    "ANL003": "backward registration outside the bwd_backend dispatcher",
    "ANL004": "hard-coded dtype literal in a kernel file",
}

# platform-reading callables that must not run at import time
_PLATFORM_CALLS = {"devices", "default_backend", "local_devices",
                   "process_index", "get_backend"}

# files whose ANL003/ANL004 rules apply (path match, forward slashes)
_KERNEL_DIR = "repro/kernels/"
_DISPATCH_OWNER = "repro/kernels/ops.py"

# dtype-literal names a kernel file must not hard-code (ANL004)
_DTYPE_LITERALS = {"float16", "bfloat16", "float32", "float64",
                   "int8", "int16", "int32", "int64"}

_NOQA = re.compile(r"#\s*noqa:\s*(ANL\d{3}(?:\s*,\s*ANL\d{3})*)")


@dataclasses.dataclass(frozen=True)
class LintFinding:
    path: str
    line: int
    code: str
    message: str

    def describe(self) -> str:
        return f"{self.path}:{self.line}: {self.code} {self.message}"


def _noqa_codes(source_lines: Sequence[str], line: int) -> Set[str]:
    if 1 <= line <= len(source_lines):
        m = _NOQA.search(source_lines[line - 1])
        if m:
            return {c.strip() for c in m.group(1).split(",")}
    return set()


def _dotted(node: ast.AST) -> Optional[str]:
    """'jax.devices' for Attribute/Name chains, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _is_dtype_literal(node: ast.AST) -> bool:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value in _DTYPE_LITERALS
    dotted = _dotted(node)
    return bool(dotted) and dotted.rsplit(".", 1)[-1] in _DTYPE_LITERALS


class _Visitor(ast.NodeVisitor):
    def __init__(self, relpath: str):
        self.relpath = relpath
        self.findings: List[LintFinding] = []
        self._func_depth = 0
        self._func_names: List[str] = []
        self._in_kernel_file = (
            _KERNEL_DIR in relpath and not relpath.endswith("ops.py"))
        self._in_promotion_helper = 0

    def _add(self, node: ast.AST, code: str, message: str) -> None:
        self.findings.append(LintFinding(
            self.relpath, getattr(node, "lineno", 0), code, message))

    # -- scope tracking ----------------------------------------------------
    def _visit_func(self, node) -> None:
        self._func_depth += 1
        self._func_names.append(node.name)
        promo = "promote" in node.name or node.name == "_compute_dtype"
        self._in_promotion_helper += promo
        self.generic_visit(node)
        self._in_promotion_helper -= promo
        self._func_names.pop()
        self._func_depth -= 1

    visit_FunctionDef = _visit_func
    visit_AsyncFunctionDef = _visit_func

    def visit_Lambda(self, node: ast.Lambda) -> None:
        self._func_depth += 1
        self.generic_visit(node)
        self._func_depth -= 1

    # -- rules -------------------------------------------------------------
    def visit_Call(self, node: ast.Call) -> None:
        dotted = _dotted(node.func) or ""
        leaf = dotted.rsplit(".", 1)[-1]

        # ANL001: platform read at module scope
        if (self._func_depth == 0 and dotted.startswith("jax")
                and leaf in _PLATFORM_CALLS):
            self._add(node, "ANL001",
                      f"`{dotted}()` runs at import time; platform dispatch "
                      f"must be read at call time (see interpret_mode())")

        # ANL003: backward registration outside kernels/ops.py
        if self._in_kernel_file:
            if leaf == "defvjp":
                self._add(node, "ANL003",
                          "custom-VJP registration belongs to the op "
                          "factories in kernels/ops.py (bwd_backend "
                          "dispatch), not individual kernel files")
            elif dotted == "jax.vjp":
                self._add(node, "ANL003",
                          "direct jax.vjp of a reference implementation "
                          "bypasses bwd_backend dispatch; register the "
                          "backward through kernels/ops.py")

        # ANL004: literal dtype= kwarg / .astype(literal) in kernel files
        if self._in_kernel_file and not self._in_promotion_helper:
            for kw in node.keywords:
                if kw.arg == "dtype" and _is_dtype_literal(kw.value):
                    self._add(node, "ANL004",
                              "hard-coded dtype= literal; take the compute "
                              "dtype from the promotion helper (ct)")
            if leaf == "astype" and node.args and _is_dtype_literal(
                    node.args[0]):
                self._add(node, "ANL004",
                          "hard-coded .astype(<literal>); take the compute "
                          "dtype from the promotion helper (ct)")

        self.generic_visit(node)

def lint_source(source: str, relpath: str) -> List[LintFinding]:
    """Lint one module's source text. `relpath` selects which rules apply
    (kernel-file rules key off the path) and is reported in findings.

    Unguarded-shared-state findings (the generalized ANL002) come from
    `repro.analysis.concurrency.guard_findings` and are reported here as
    ANL006, so a plain `--lint` run still catches the registry-race bug
    class without the full lock-graph pass."""
    from repro.analysis import concurrency

    relpath = relpath.replace("\\", "/")
    try:
        tree = ast.parse(source, filename=relpath)
    except SyntaxError as exc:
        return [LintFinding(relpath, exc.lineno or 0, "ANL000",
                            f"syntax error: {exc.msg}")]
    visitor = _Visitor(relpath)
    visitor.visit(tree)
    lines = source.splitlines()
    findings = [f for f in visitor.findings
                if f.code not in _noqa_codes(lines, f.line)]
    findings.extend(
        LintFinding(f.path, f.line, f.code, f.message)
        for f in concurrency.guard_findings(source, relpath))
    findings.sort(key=lambda f: (f.line, f.code))
    return findings


def lint_paths(paths: Optional[Iterable[pathlib.Path]] = None,
               root: Optional[pathlib.Path] = None) -> List[LintFinding]:
    """Lint a set of files (default: every .py under src/repro)."""
    if root is None:
        root = pathlib.Path(__file__).resolve().parents[2]
    if paths is None:
        paths = sorted((root / "repro").rglob("*.py"))
    findings: List[LintFinding] = []
    for path in paths:
        resolved = pathlib.Path(path).resolve()
        try:
            rel = str(resolved.relative_to(root))
        except ValueError:  # outside src/ (e.g. a fixture): report as given
            rel = str(path)
        findings.extend(lint_source(
            resolved.read_text(encoding="utf-8"), rel))
    return findings
