"""Pallas kernel auditor: static VMEM / tiling / dtype checks per kernel.

Every Pallas kernel in `repro.kernels` makes three promises it can only
keep structurally:

  * its per-grid-step VMEM working set — streamed blocks (double-buffered
    by the pipeline), constant-index blocks that stay RESIDENT across the
    whole grid (the reverse kernels' dZ/dvariance/dlengthscale
    accumulators), and the kernel-body workspace — fits the ~16 MB/core
    VMEM budget;
  * every operand it receives is padded to a tile multiple and every
    BlockSpec index map stays inside the padded array;
  * its compute dtype follows the documented promotion rule — float32 when
    compiled, max(input dtype, float32) in interpret mode — and never
    silently downcasts an f64 parity path.

This module checks all three WITHOUT running (or even lowering) a kernel:
`pl.pallas_call` is temporarily swapped for a recorder and each wrapper is
traced with `jax.eval_shape`, which hands us the real grid, BlockSpecs,
padded operand shapes and the kernel body's bound compute dtype. The body
workspace is estimated from the kernel jaxpr that a (separate, unmocked)
interpret-mode trace embeds in the `pallas_call` equation, walked with the
same machinery as `repro.analysis.jaxpr_check`.

The per-kernel budget rows (`vmem_table`) are written to BENCH_vmem.json by
`benchmarks/run.py --only analysis` — the table the tile autotuner
(ROADMAP item 2) will consume when block sizes stop being hand-picked
constants.
"""
from __future__ import annotations

import dataclasses
import functools
import itertools
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.analysis.jaxpr_check import sub_jaxprs

__all__ = [
    "Problem",
    "AuditFinding",
    "BlockInfo",
    "KernelAudit",
    "KERNELS",
    "VMEM_BUDGET_BYTES",
    "capture_pallas_calls",
    "vmem_estimate",
    "audit_callable",
    "audit_candidate",
    "audit_kernels",
    "kernel_registry",
    "vmem_table",
]

# ~16 MB of VMEM per TPU core (see the Pallas TPU guide's memory hierarchy).
VMEM_BUDGET_BYTES = 16 * 2 ** 20

# Cap on exhaustive grid enumeration for the index-map checks; beyond it
# only the corner points are evaluated.
_MAX_GRID_POINTS = 8192


def vmem_estimate(streamed_bytes: int, resident_bytes: int,
                  body_workspace_bytes: int) -> int:
    """THE per-grid-step VMEM residency model, in one place: streamed blocks
    are double-buffered by the Pallas pipeline, constant-index blocks keep a
    single resident copy, and the kernel body's largest intermediate rides on
    top. `KernelAudit`, the audit findings, and the `repro.tune` candidate
    filter all price a block configuration through this function — the
    auditor and the autotuner cannot disagree about what fits."""
    return 2 * streamed_bytes + resident_bytes + body_workspace_bytes


@dataclasses.dataclass(frozen=True)
class Problem:
    """Representative problem sizes the kernels are audited at. Multi-tile
    in both N and M so index maps and accumulator residency are exercised."""

    N: int = 4096
    M: int = 256
    Q: int = 4
    D: int = 2


@dataclasses.dataclass(frozen=True)
class AuditFinding:
    kernel: str
    code: str  # VMEM001 | TILE001 | IDX001 | DTYPE001
    message: str

    def describe(self) -> str:
        return f"{self.kernel}: {self.code} {self.message}"


@dataclasses.dataclass(frozen=True)
class BlockInfo:
    kind: str  # "in" | "out"
    pos: int
    block_shape: Tuple[int, ...]
    dtype: str
    nbytes: int
    resident: bool  # constant index map: lives in VMEM for the whole grid


@dataclasses.dataclass(frozen=True)
class KernelAudit:
    """The compiled-path (float32) VMEM/tiling view of one kernel, plus the
    dtype-rule findings gathered across every audited input dtype."""

    name: str
    grid: Tuple[int, ...]
    ct: str
    blocks: Tuple[BlockInfo, ...]
    streamed_bytes: int
    resident_bytes: int
    body_workspace_bytes: int
    vmem_budget_bytes: int
    findings: Tuple[AuditFinding, ...]

    @property
    def vmem_estimate_bytes(self) -> int:
        return vmem_estimate(self.streamed_bytes, self.resident_bytes,
                             self.body_workspace_bytes)

    @property
    def fits(self) -> bool:
        return self.vmem_estimate_bytes <= self.vmem_budget_bytes


# ---------------------------------------------------------------------------
# capture: swap pl.pallas_call for a recorder, trace the wrapper abstractly
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _Capture:
    kernel_fn: Any
    grid: Tuple[int, ...]
    in_specs: List[Any]
    out_specs: List[Any]
    out_shape: List[Any]
    operands: List[Any]  # abstract avals actually passed to pallas_call
    interpret: bool

    @property
    def ct(self):
        return getattr(self.kernel_fn, "keywords", {}).get("ct")


def capture_pallas_calls(fn: Callable, *args) -> List[_Capture]:
    """Trace ``fn(*args)`` (abstractly — nothing executes, nothing lowers)
    with `pl.pallas_call` replaced by a recorder; returns one `_Capture` per
    pallas_call site, with the padded operand shapes the wrapper built."""
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    captures: List[_Capture] = []

    def recorder(kernel, out_shape=None, *, grid=None, in_specs=None,
                 out_specs=None, interpret=False, **kw):
        if out_shape is None:
            out_shape = kw.pop("out_shape", None)
        multi = isinstance(out_shape, (list, tuple))
        shapes = list(out_shape) if multi else [out_shape]
        specs = out_specs if isinstance(out_specs, (list, tuple)) else [out_specs]

        def runner(*operands):
            captures.append(_Capture(
                kernel_fn=kernel, grid=tuple(grid),
                in_specs=list(in_specs), out_specs=list(specs),
                out_shape=shapes,
                operands=[jax.ShapeDtypeStruct(tuple(o.shape), o.dtype)
                          for o in operands],
                interpret=bool(interpret)))
            outs = [jnp.zeros(s.shape, s.dtype) for s in shapes]
            return outs if multi else outs[0]

        return runner

    # the wrappers are @jax.jit functions; trace the wrapped python function
    # so the recorder is hit even when a compiled cache entry exists. The
    # fresh lambda defeats eval_shape's (fn identity, avals) trace cache —
    # a cached trace would skip the recorder entirely on repeat audits.
    plain = getattr(fn, "__wrapped__", fn)
    original = pl.pallas_call
    pl.pallas_call = recorder
    try:
        jax.eval_shape(lambda *a: plain(*a), *args)
    finally:
        pl.pallas_call = original
    return captures


def _body_workspace_bytes(fn: Callable, *args) -> int:
    """Largest intermediate inside the kernel body, from the kernel jaxpr an
    interpret-mode trace embeds in the pallas_call equation."""
    import jax

    plain = getattr(fn, "__wrapped__", fn)
    closed = jax.make_jaxpr(plain)(*args)

    def find(jaxpr):
        for eqn in jaxpr.eqns:
            if eqn.primitive.name == "pallas_call":
                return eqn.params.get("jaxpr")
            for val in eqn.params.values():
                for sub in sub_jaxprs(val):
                    hit = find(sub)
                    if hit is not None:
                        return hit
        return None

    body = find(closed.jaxpr)
    if body is None:
        return 0
    worst = 0
    stack = [getattr(body, "jaxpr", body)]
    while stack:
        j = stack.pop()
        for eqn in j.eqns:
            for v in eqn.outvars:
                aval = getattr(v, "aval", None)
                if aval is not None and hasattr(aval, "shape"):
                    worst = max(worst, int(aval.size) * aval.dtype.itemsize)
            for val in eqn.params.values():
                stack.extend(sub_jaxprs(val))
    return worst


# ---------------------------------------------------------------------------
# the checks
# ---------------------------------------------------------------------------

def _grid_points(grid: Tuple[int, ...]):
    total = 1
    for g in grid:
        total *= max(int(g), 1)
    if total <= _MAX_GRID_POINTS:
        return itertools.product(*(range(int(g)) for g in grid))
    return itertools.product(*((0, int(g) - 1) for g in grid))


def _index_profile(spec, grid: Tuple[int, ...]):
    """(is_constant, max_block_index per dim) of one BlockSpec over the grid."""
    first = None
    lo = hi = None
    for point in _grid_points(grid):
        idx = tuple(int(i) for i in spec.index_map(*point))
        if first is None:
            first, lo, hi = idx, list(idx), list(idx)
        else:
            lo = [min(a, b) for a, b in zip(lo, idx)]
            hi = [max(a, b) for a, b in zip(hi, idx)]
    constant = first is not None and tuple(lo) == tuple(hi)
    return constant, tuple(lo or ()), tuple(hi or ())


def _block_bytes(block_shape: Tuple[int, ...], dtype) -> int:
    import numpy as np

    size = 1
    for d in block_shape:
        size *= int(d)
    return size * np.dtype(dtype).itemsize


def _check_spec(name: str, kind: str, pos: int, spec, aval, grid,
                findings: List[AuditFinding]) -> BlockInfo:
    block = tuple(int(b) for b in spec.block_shape)
    shape = tuple(int(d) for d in aval.shape)
    if len(block) != len(shape):
        findings.append(AuditFinding(name, "TILE001",
                        f"{kind}[{pos}] block rank {len(block)} != operand "
                        f"rank {len(shape)} (shape {shape})"))
    else:
        for d, (b, s) in enumerate(zip(block, shape)):
            if s % b != 0:
                findings.append(AuditFinding(name, "TILE001",
                                f"{kind}[{pos}] dim {d}: operand extent {s} "
                                f"not divisible by block extent {b} — the "
                                f"wrapper must pad to a tile multiple"))
    constant, lo, hi = _index_profile(spec, grid)
    if len(block) == len(shape):
        for d, (b, s, h, l) in enumerate(zip(block, shape, hi, lo)):
            if l < 0 or (h + 1) * b > s:
                findings.append(AuditFinding(name, "IDX001",
                                f"{kind}[{pos}] dim {d}: index map reaches "
                                f"block {h} of extent {b} beyond the operand "
                                f"extent {s}"))
    return BlockInfo(kind=kind, pos=pos, block_shape=block,
                     dtype=str(aval.dtype),
                     nbytes=_block_bytes(block, aval.dtype),
                     resident=constant)


def _expected_ct(input_dtype, interpret: bool):
    import jax.numpy as jnp

    if interpret:
        return jnp.promote_types(jnp.dtype(input_dtype), jnp.float32)
    return jnp.dtype(jnp.float32)


def _check_dtype_rule(name: str, cap: _Capture, input_dtype,
                      findings: List[AuditFinding]) -> None:
    """The documented promotion rule: compiled kernels compute in float32;
    interpret mode computes in max(input dtype, float32) so f64 parity tests
    exercise the body itself. A divergence (e.g. a body bound to the raw
    input dtype under compilation, or a silent f64 -> f32 downcast in
    interpret mode) is exactly the class of bug this flags."""
    import numpy as np

    expected = _expected_ct(input_dtype, cap.interpret)
    mode = "interpret" if cap.interpret else "compiled"
    ct = cap.ct
    if ct is not None and np.dtype(ct) != expected:
        findings.append(AuditFinding(name, "DTYPE001",
                        f"kernel body compute dtype is {np.dtype(ct).name} "
                        f"({mode}, input {np.dtype(input_dtype).name}); the "
                        f"promotion rule requires {expected.name}"))
    for kind, avals in (("operand", cap.operands), ("output", cap.out_shape)):
        for pos, aval in enumerate(avals):
            if np.dtype(aval.dtype) != expected:
                findings.append(AuditFinding(name, "DTYPE001",
                                f"{kind}[{pos}] enters/leaves the kernel as "
                                f"{np.dtype(aval.dtype).name} ({mode}, input "
                                f"{np.dtype(input_dtype).name}); expected "
                                f"{expected.name}"))


def audit_callable(fn: Callable, *args, name: Optional[str] = None,
                   vmem_budget_bytes: int = VMEM_BUDGET_BYTES,
                   input_dtype=None, check_dtype_rule: bool = True,
                   interpret: bool = False,
                   body_workspace_args: Optional[Sequence[Any]] = None,
                   ) -> List[KernelAudit]:
    """Audit every pallas_call inside one wrapper invocation. `args` are
    abstract (`jax.ShapeDtypeStruct`) or concrete arrays; nothing executes.
    Returns one `KernelAudit` per pallas_call site."""
    import jax.numpy as jnp

    name = name or getattr(fn, "__name__", repr(fn))
    if input_dtype is None:
        leaves = [a for a in args if hasattr(a, "dtype")]
        input_dtype = leaves[0].dtype if leaves else jnp.float32
    captures = capture_pallas_calls(
        fn, *args) if not interpret else capture_pallas_calls(
        functools.partial(fn, interpret=True), *args)
    workspace = 0
    if body_workspace_args is not None:
        workspace = _body_workspace_bytes(
            functools.partial(fn, interpret=True), *body_workspace_args)
    audits = []
    for cap in captures:
        findings: List[AuditFinding] = []
        blocks = [
            _check_spec(name, "in", i, spec, aval, cap.grid, findings)
            for i, (spec, aval) in enumerate(zip(cap.in_specs, cap.operands))
        ] + [
            _check_spec(name, "out", i, spec, aval, cap.grid, findings)
            for i, (spec, aval) in enumerate(zip(cap.out_specs, cap.out_shape))
        ]
        if check_dtype_rule:
            _check_dtype_rule(name, cap, input_dtype, findings)
        streamed = sum(b.nbytes for b in blocks if not b.resident)
        resident = sum(b.nbytes for b in blocks if b.resident)
        estimate = vmem_estimate(streamed, resident, workspace)
        if estimate > vmem_budget_bytes:
            findings.append(AuditFinding(name, "VMEM001",
                            f"per-grid-step VMEM estimate "
                            f"{estimate / 2**20:.2f} MiB (2x{streamed} "
                            f"streamed + {resident} resident + {workspace} "
                            f"body workspace) exceeds the "
                            f"{vmem_budget_bytes / 2**20:.2f} MiB budget"))
        audits.append(KernelAudit(
            name=name, grid=cap.grid,
            ct=str(jnp.dtype(cap.ct)) if cap.ct is not None else "?",
            blocks=tuple(blocks), streamed_bytes=streamed,
            resident_bytes=resident, body_workspace_bytes=workspace,
            vmem_budget_bytes=vmem_budget_bytes,
            findings=tuple(findings)))
    return audits


# ---------------------------------------------------------------------------
# the kernel registry: every Pallas kernel in repro.kernels
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    import jax

    return jax.ShapeDtypeStruct(shape, dtype)


def _args_kfu(p: Problem, dt):
    return (_sds((p.N, p.Q), dt), _sds((p.M, p.Q), dt), _sds((), dt),
            _sds((p.Q,), dt))


def _args_psi1(p: Problem, dt):
    return (_sds((p.N, p.Q), dt), _sds((p.N, p.Q), dt), _sds((p.M, p.Q), dt),
            _sds((), dt), _sds((p.Q,), dt))


_args_psi2 = _args_psi1


def _args_suffstats(p: Problem, dt):
    return (_sds((p.N, p.Q), dt), _sds((p.N, p.Q), dt), _sds((p.N, p.D), dt),
            _sds((p.M, p.Q), dt), _sds((), dt), _sds((p.Q,), dt))


def _args_suffstats_bwd(p: Problem, dt):
    return _args_suffstats(p, dt) + (_sds((p.M, p.M), dt),
                                     _sds((p.M, p.D), dt))


def _args_psi1_bwd(p: Problem, dt):
    return _args_psi1(p, dt) + (_sds((p.N, p.M), dt),)


def _args_psi2_bwd(p: Problem, dt):
    return _args_psi2(p, dt) + (_sds((p.M, p.M), dt),)


def kernel_registry() -> List[Tuple[str, Callable, Callable]]:
    """(name, wrapper fn, args builder) for every Pallas kernel in
    `repro.kernels`. `kfu_bwd_pallas` is the S -> 0 wrapper over
    `psi1_bwd_pallas` and owns no pallas_call of its own. Shared by the
    auditor (this module) and the tile autotuner (`repro.tune`) — one list
    of kernels, one set of representative argument builders."""
    from repro.kernels import kfu, psi1, psi2, suffstats

    return [
        ("kfu_pallas", kfu.kfu_pallas, _args_kfu),
        ("psi1_pallas", psi1.psi1_pallas, _args_psi1),
        ("psi2_pallas", psi2.psi2_pallas, _args_psi2),
        ("suffstats_pallas", suffstats.suffstats_pallas, _args_suffstats),
        ("suffstats_bwd_pallas", suffstats.suffstats_bwd_pallas,
         _args_suffstats_bwd),
        ("psi1_bwd_pallas", suffstats.psi1_bwd_pallas, _args_psi1_bwd),
        ("psi2_bwd_pallas", suffstats.psi2_bwd_pallas, _args_psi2_bwd),
    ]


_kernel_registry = kernel_registry  # pre-tune name, kept for callers

KERNELS = tuple(name for name, _, _ in kernel_registry())


def registry_entry(kernel_name: str) -> Tuple[Callable, Callable]:
    """(wrapper fn, args builder) for one registered kernel, or KeyError."""
    for name, fn, build in kernel_registry():
        if name == kernel_name:
            return fn, build
    raise KeyError(
        f"unknown kernel {kernel_name!r}; registered: {list(KERNELS)}")


def audit_candidate(kernel_name: str, block: Tuple[int, int], *,
                    problem: Problem = Problem(), dtype=None,
                    vmem_budget_bytes: int = VMEM_BUDGET_BYTES,
                    ) -> KernelAudit:
    """Audit one registered kernel at a CANDIDATE block configuration
    ``block = (tile_n, tile_m)`` instead of its module-constant tiles.

    This is the search-space gate of the `repro.tune` autotuner: a candidate
    is admissible only if the returned audit `fits` the VMEM budget and
    carries no TILE001/IDX001 finding — the same recorder trace, block
    accounting, and `vmem_estimate` model the `--pallas-audit` CLI applies
    to the shipped constants (nothing executes or lowers here either).
    """
    import jax.numpy as jnp

    dtype = jnp.float32 if dtype is None else jnp.dtype(dtype)
    fn, build = registry_entry(kernel_name)
    # partial the UNWRAPPED python function: a partial of the jitted wrapper
    # could hit jit's trace cache on repeat audits and skip the recorder
    plain = getattr(fn, "__wrapped__", fn)
    fn_b = functools.partial(plain, block=(int(block[0]), int(block[1])))
    args = build(problem, dtype)
    return audit_callable(
        fn_b, *args, name=kernel_name, vmem_budget_bytes=vmem_budget_bytes,
        input_dtype=dtype, check_dtype_rule=False,
        body_workspace_args=args)[0]


def audit_kernels(problem: Problem = Problem(),
                  vmem_budget_bytes: int = VMEM_BUDGET_BYTES,
                  dtypes: Sequence[str] = ("float32", "float64"),
                  ) -> List[KernelAudit]:
    """Audit every registered kernel. The returned audits carry the
    compiled-path (float32) VMEM/tiling view; the dtype-promotion rule is
    additionally checked at every dtype in `dtypes`, in both compiled and
    interpret mode, with any divergence attached to the kernel's findings."""
    import jax.numpy as jnp

    audits: List[KernelAudit] = []
    for name, fn, build in _kernel_registry():
        f32_args = build(problem, jnp.float32)
        main = audit_callable(
            fn, *f32_args, name=name, vmem_budget_bytes=vmem_budget_bytes,
            input_dtype=jnp.float32, check_dtype_rule=True,
            body_workspace_args=f32_args)
        extra: List[AuditFinding] = []
        for dt in dtypes:
            for interpret in (False, True):
                if str(jnp.dtype(dt)) == "float32" and not interpret:
                    continue  # already covered by the main audit
                for a in audit_callable(
                        fn, *build(problem, jnp.dtype(dt)), name=name,
                        vmem_budget_bytes=vmem_budget_bytes,
                        input_dtype=jnp.dtype(dt), interpret=interpret,
                        check_dtype_rule=True):
                    extra.extend(f for f in a.findings
                                 if f.code == "DTYPE001")
        for a in main:
            merged = tuple(dict.fromkeys(a.findings + tuple(extra)))
            audits.append(dataclasses.replace(a, findings=merged))
    return audits


def vmem_table(audits: Sequence[KernelAudit]) -> List[Dict[str, Any]]:
    """The budget table (one row per kernel) BENCH_vmem.json carries — the
    input the tile autotuner will consume."""
    rows = []
    for a in audits:
        rows.append({
            "section": "vmem",
            "kernel": a.name,
            "grid": list(a.grid),
            "ct": a.ct,
            "blocks": [
                {"kind": b.kind, "pos": b.pos,
                 "block_shape": list(b.block_shape), "dtype": b.dtype,
                 "bytes": b.nbytes, "resident": b.resident}
                for b in a.blocks
            ],
            "streamed_bytes": a.streamed_bytes,
            "resident_bytes": a.resident_bytes,
            "body_workspace_bytes": a.body_workspace_bytes,
            "vmem_estimate_bytes": a.vmem_estimate_bytes,
            "vmem_budget_bytes": a.vmem_budget_bytes,
            "fits": a.fits,
            "findings": [f.describe() for f in a.findings],
        })
    return rows
