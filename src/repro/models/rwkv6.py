"""RWKV-6 "Finch" time-mix and channel-mix (arXiv:2404.05892).

Attention-free temporal mixer with *data-dependent* per-channel decay
(the defining Finch feature):

    w_t = exp(-exp(w0 + lora_w(x_t)))                 in (0,1), per channel
    S_t = diag(w_t) S_{t-1} + k_t v_t^T               per head, (K, V) state
    o_t = S_{t-1}^T r_t + (r_t . (u ⊙ k_t)) v_t       current token uses bonus u

Training runs a *chunked* parallel form: sequence chunks of size CHUNK are
processed with an exact intra-chunk pairwise matrix (c, c, K) — all decay
exponentials are differences cum_{t-1} - cum_i <= 0 so exp() never overflows
— while the (B, H, K, V) state carries across chunks through a lax.scan.
Cost is O(T * c * K) time and O(c^2 K) live memory: sub-quadratic in T, which
is what qualifies rwkv6 for the long_500k cell. Decode is the plain O(1)
recurrence. (On real TPUs this chunk body is the natural Pallas kernel; the
jnp form keeps HLO-level roofline analysis exact.)
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, dt, rmsnorm, rmsnorm_init

CHUNK = 64
LORA_RANK = 64


def timemix_init(key, cfg: ModelConfig):
    d = cfg.d_model
    ks = jax.random.split(key, 9)
    return {
        "mu": jnp.full((4, d), 0.5, jnp.float32),  # shift-mix for r,k,v,g
        "mu_w": jnp.full((d,), 0.5, jnp.float32),
        "w0": jnp.full((d,), -6.0, jnp.float32),  # decay bias (slow default)
        "lora_wA": dense_init(ks[0], d, LORA_RANK, cfg),
        "lora_wB": (jnp.zeros((LORA_RANK, d))).astype(dt(cfg)),
        "wr": dense_init(ks[1], d, d, cfg),
        "wk": dense_init(ks[2], d, d, cfg),
        "wv": dense_init(ks[3], d, d, cfg),
        "wg": dense_init(ks[4], d, d, cfg),
        "wo": dense_init(ks[5], d, d, cfg),
        "u": jnp.zeros((d,), jnp.float32),  # per-channel bonus
        "gn_scale": jnp.ones((d,), jnp.float32),  # per-head groupnorm
    }


class TimeMixState(NamedTuple):
    S: jax.Array  # (B, H, K, V) wkv state
    x_prev: jax.Array  # (B, d) last token (for token shift)


def timemix_state_init(cfg: ModelConfig, B: int, dtype) -> TimeMixState:
    K = cfg.rwkv_head_dim
    H = cfg.d_model // K
    return TimeMixState(
        S=jnp.zeros((B, H, K, K), jnp.float32),
        x_prev=jnp.zeros((B, cfg.d_model), dtype),
    )


def _shift_mix(x, x_shift, mu):
    return x + (x_shift - x) * mu


def _decays(params, xw, cfg: ModelConfig):
    cdt = dt(cfg, "compute")
    lora = jnp.tanh(xw.astype(cdt) @ params["lora_wA"].astype(cdt)) @ params["lora_wB"].astype(cdt)
    logw = -jnp.exp(jnp.clip(params["w0"] + lora.astype(jnp.float32), -8.0, 2.0))
    return logw  # (..., d), log of decay in (-inf, 0)


def _groupnorm(params, o, H):
    B, T, d = o.shape
    oh = o.reshape(B, T, H, d // H).astype(jnp.float32)
    mean = jnp.mean(oh, axis=-1, keepdims=True)
    var = jnp.var(oh, axis=-1, keepdims=True)
    oh = (oh - mean) * jax.lax.rsqrt(var + 1e-5)
    return (oh.reshape(B, T, d) * params["gn_scale"]).astype(o.dtype)


def timemix_apply_chunked(params, x: jax.Array, state: TimeMixState, cfg: ModelConfig,
                          constrain=lambda t, s: t):
    """x: (B, T, d) with T % CHUNK == 0. Returns (out, new_state)."""
    cdt = dt(cfg, "compute")
    B, T, d = x.shape
    K = cfg.rwkv_head_dim
    H = d // K
    c = min(CHUNK, T)
    pad = (-T) % c  # trailing pad steps are exact no-ops: k=0, decay=1
    n = (T + pad) // c

    # token shift over the full sequence (cheap), chunk the projections
    x_shift = jnp.concatenate([state.x_prev[:, None, :].astype(x.dtype), x[:, :-1]], axis=1)
    mu = params["mu"]
    xr = _shift_mix(x, x_shift, mu[0]).astype(cdt)
    xk = _shift_mix(x, x_shift, mu[1]).astype(cdt)
    xv = _shift_mix(x, x_shift, mu[2]).astype(cdt)
    xg = _shift_mix(x, x_shift, mu[3]).astype(cdt)
    xw = _shift_mix(x, x_shift, params["mu_w"])

    r = (xr @ params["wr"].astype(cdt)).reshape(B, T, H, K)
    k = (xk @ params["wk"].astype(cdt)).reshape(B, T, H, K)
    v = (xv @ params["wv"].astype(cdt)).reshape(B, T, H, K)
    g = jax.nn.silu(xg @ params["wg"].astype(cdt))  # (B, T, d)
    logw = _decays(params, xw, cfg).reshape(B, T, H, K)  # fp32
    u = params["u"].reshape(H, K)

    if pad:
        r = jnp.pad(r, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        logw = jnp.pad(logw, ((0, 0), (0, pad), (0, 0), (0, 0)))  # log 1 = 0

    # chunk: (n, B, c, H, K) fp32 for the state math
    def chunked(t):
        return t.reshape(B, n, c, H, K).transpose(1, 0, 2, 3, 4).astype(jnp.float32)

    rc, kc, vc, wc = (constrain(chunked(t), "rwkv_chunks") for t in (r, k, v, logw))
    S0 = constrain(state.S, "rwkv_state")

    @jax.checkpoint  # backward recomputes the (c, c) pairwise block, never stores it
    def body(S, inp):
        ri, ki, vi, lwi = inp  # (B, c, H, K)
        cum = jnp.cumsum(lwi, axis=1)  # inclusive (B, c, H, K)
        cum_prev = cum - lwi  # exclusive: sum_{j<t}
        # intra-chunk pairwise: A[t,i] = sum_a r_t k_i exp(cum_prev_t - cum_i), i < t
        diff = cum_prev[:, :, None] - cum[:, None, :]  # (B, c, c, H, K)
        tri = (jnp.arange(c)[:, None] > jnp.arange(c)[None, :])[None, :, :, None, None]
        Aij = jnp.sum(ri[:, :, None] * ki[:, None, :] * jnp.exp(diff) * tri, axis=-1)
        # diagonal: bonus term
        Adiag = jnp.sum(ri * u[None, None] * ki, axis=-1)  # (B, c, H)
        A = Aij + Adiag[:, :, None] * jnp.eye(c)[None, :, :, None]  # (B, c, c, H)
        o_intra = jnp.einsum("btih,bihv->bthv", A, vi)
        # cross-chunk: o_cross[t] = (r_t * exp(cum_prev_t)) @ S_in
        o_cross = jnp.einsum("bthk,bhkv->bthv", ri * jnp.exp(cum_prev), S)
        # state update: S' = exp(cum_last) * S + sum_i exp(cum_last - cum_i) k_i v_i^T
        cum_last = cum[:, -1]  # (B, H, K)
        S_decay = jnp.exp(cum_last)[:, :, :, None] * S
        kd = ki * jnp.exp(cum_last[:, None] - cum)  # (B, c, H, K)
        S_new = S_decay + jnp.einsum("bthk,bthv->bhkv", kd, vi)
        return S_new, o_intra + o_cross

    S_new, o = jax.lax.scan(body, S0, (rc, kc, vc, wc))
    o = o.transpose(1, 0, 2, 3, 4).reshape(B, T + pad, d)[:, :T]  # (B, T, d)
    o = _groupnorm(params, o, H) * g
    out = o.astype(cdt) @ params["wo"].astype(cdt)
    return out, TimeMixState(S_new, x[:, -1, :])


def timemix_apply_decode(params, x: jax.Array, state: TimeMixState, cfg: ModelConfig,
                         constrain=lambda t, s: t):
    """x: (B, 1, d) single-token recurrence."""
    cdt = dt(cfg, "compute")
    B, _, d = x.shape
    K = cfg.rwkv_head_dim
    H = d // K
    xt = x[:, 0]
    xs = state.x_prev.astype(xt.dtype)
    mu = params["mu"]
    proj = lambda name, m: (_shift_mix(xt, xs, m).astype(cdt) @ params[name].astype(cdt))
    r = proj("wr", mu[0]).reshape(B, H, K).astype(jnp.float32)
    k = proj("wk", mu[1]).reshape(B, H, K).astype(jnp.float32)
    v = proj("wv", mu[2]).reshape(B, H, K).astype(jnp.float32)
    g = jax.nn.silu(_shift_mix(xt, xs, mu[3]).astype(cdt) @ params["wg"].astype(cdt))
    logw = _decays(params, _shift_mix(xt, xs, params["mu_w"]), cfg).reshape(B, H, K)
    u = params["u"].reshape(H, K)

    # o = S^T r + (r . (u*k)) v ; S' = diag(w) S + k v^T
    o = jnp.einsum("bhk,bhkv->bhv", r, state.S) + jnp.sum(r * u * k, -1, keepdims=True) * v
    S_new = jnp.exp(logw)[..., None] * state.S + k[..., None] * v[:, :, None, :]
    o = o.reshape(B, 1, d)
    o = _groupnorm(params, o.astype(cdt), H) * g[:, None, :]
    out = o.astype(cdt) @ params["wo"].astype(cdt)
    return out, TimeMixState(S_new, xt)


# ---------------------------------------------------------------------------
# channel mix
# ---------------------------------------------------------------------------

def chanmix_init(key, cfg: ModelConfig):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "mu_k": jnp.full((d,), 0.5, jnp.float32),
        "mu_r": jnp.full((d,), 0.5, jnp.float32),
        "wk": dense_init(ks[0], d, f, cfg),
        "wv": dense_init(ks[1], f, d, cfg),
        "wr": dense_init(ks[2], d, d, cfg),
    }


def chanmix_apply(params, x: jax.Array, x_prev: jax.Array, cfg: ModelConfig):
    """x: (B, T, d); x_prev: (B, d) last token of the previous call.
    Returns (out, new_x_prev)."""
    cdt = dt(cfg, "compute")
    x_shift = jnp.concatenate([x_prev[:, None, :].astype(x.dtype), x[:, :-1]], axis=1)
    xk = _shift_mix(x, x_shift, params["mu_k"]).astype(cdt)
    xr = _shift_mix(x, x_shift, params["mu_r"]).astype(cdt)
    kk = jnp.square(jax.nn.relu(xk @ params["wk"].astype(cdt)))
    out = jax.nn.sigmoid(xr @ params["wr"].astype(cdt)) * (kk @ params["wv"].astype(cdt))
    return out, x[:, -1, :]
