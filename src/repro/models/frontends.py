"""Modality-frontend STUBS (per the assignment: [audio]/[vlm] entries specify
the transformer backbone only; input_specs() provides precomputed frame/patch
embeddings).

These stand in for whisper's mel+conv stack and InternViT: smoke tests and
examples draw synthetic embeddings with the right shapes/statistics; the
dry-run only ever sees ShapeDtypeStructs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

WHISPER_FRAMES = 1500  # 30 s audio -> conv-downsampled frame count
INTERNVIT_TOKENS = 256  # 448px / patch14 -> 1024, pixel-shuffled 4x -> 256


def audio_frames_stub(key, B: int, cfg: ModelConfig, dtype=jnp.float32) -> jax.Array:
    """Precomputed post-conv mel-frame embeddings (B, F, d)."""
    return jax.random.normal(key, (B, cfg.encoder_frames, cfg.d_model), dtype)


def patch_embeds_stub(key, B: int, cfg: ModelConfig, dtype=jnp.float32) -> jax.Array:
    """Precomputed InternViT patch embeddings projected to LM width (B, P, d)."""
    return jax.random.normal(key, (B, cfg.frontend_tokens, cfg.d_model), dtype)
