"""Encoder-decoder transformer (whisper-small family).

The mel-spectrogram conv frontend is a STUB per the assignment: input_specs
provides precomputed post-conv frame embeddings (B, frames, d) directly; the
encoder is the standard bidirectional transformer over those frames, the
decoder adds cross-attention. RoPE replaces whisper's learned positions
(hardware-adaptation note in DESIGN.md — positionals are roofline-neutral).

Layers are homogeneous, so the encoder and decoder are each one scan.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models.layers import (
    chunked_softmax_xent,
    dt,
    embed_init,
    embed_lookup,
    logits_from,
    mlp_apply,
    mlp_init,
    rmsnorm,
    rmsnorm_init,
)

PyTree = Any


def _enc_layer_init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 2)
    return {
        "ln1": rmsnorm_init(cfg.d_model, cfg),
        "attn": attn.attn_init(ks[0], cfg),
        "ln2": rmsnorm_init(cfg.d_model, cfg),
        "mlp": mlp_init(ks[1], cfg),
    }


def _dec_layer_init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 3)
    return {
        "ln1": rmsnorm_init(cfg.d_model, cfg),
        "attn": attn.attn_init(ks[0], cfg),
        "lnx": rmsnorm_init(cfg.d_model, cfg),
        "xattn": attn.attn_init(ks[1], cfg),
        "ln2": rmsnorm_init(cfg.d_model, cfg),
        "mlp": mlp_init(ks[2], cfg),
    }


def _stack_layers(keys, init_fn, cfg):
    layers = [init_fn(k, cfg) for k in keys]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *layers)


def init_params(key, cfg: ModelConfig) -> PyTree:
    k_emb, k_enc, k_dec = jax.random.split(key, 3)
    return {
        "embed": embed_init(k_emb, cfg),
        "enc_layers": _stack_layers(jax.random.split(k_enc, cfg.encoder_layers), _enc_layer_init, cfg),
        "enc_norm": rmsnorm_init(cfg.d_model, cfg),
        "dec_layers": _stack_layers(jax.random.split(k_dec, cfg.num_layers), _dec_layer_init, cfg),
        "dec_norm": rmsnorm_init(cfg.d_model, cfg),
    }


def encode(params, frames: jax.Array, cfg: ModelConfig, constrain=lambda t, s: t,
           mode: str = "train") -> jax.Array:
    """frames: (B, F, d) stub-frontend embeddings -> (B, F, d) encodings."""
    B, F, _ = frames.shape
    x = frames.astype(dt(cfg, "compute"))
    positions = jnp.broadcast_to(jnp.arange(F, dtype=jnp.int32)[None], (B, F))

    tp = getattr(constrain, "tp", 1)

    def body(xc, layer):
        h = rmsnorm(layer["ln1"], xc, cfg.norm_eps)
        q, k, v = attn._qkv(layer["attn"], h, positions, cfg, tp, constrain)
        out = attn.blockwise_attention(q, k, v, positions, positions, window=-1,
                                       causal=False, constrain=constrain, mode=mode,
                                       kv_map=attn.head_to_kv_map(cfg, tp))
        out = attn._unpad_heads(out, cfg, tp) @ layer["attn"]["wo"].astype(out.dtype)
        xc = constrain(xc + out.astype(xc.dtype), "act_embed")
        h = rmsnorm(layer["ln2"], xc, cfg.norm_eps)
        xc = xc + mlp_apply(layer["mlp"], h, cfg, constrain=constrain).astype(xc.dtype)
        return constrain(xc, "act_embed"), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, params["enc_layers"])
    return rmsnorm(params["enc_norm"], x, cfg.norm_eps)


class DecState(NamedTuple):
    self_kv: attn.KVCache  # stacked (L, ...)
    cross_k: jax.Array  # (L, B, F, Kv, hd) — precomputed at prefill
    cross_v: jax.Array
    enc_pos: jax.Array  # (B, F)


def _cross_kv(layer, enc_out, enc_pos, cfg):
    cdt = dt(cfg, "compute")
    B, F, _ = enc_out.shape
    hd = cfg.resolved_head_dim()
    k = (enc_out.astype(cdt) @ layer["xattn"]["wk"].astype(cdt)).reshape(B, F, cfg.num_kv_heads, hd)
    v = (enc_out.astype(cdt) @ layer["xattn"]["wv"].astype(cdt)).reshape(B, F, cfg.num_kv_heads, hd)
    k = attn.apply_rope(k, enc_pos, cfg.rope_theta)
    return k, v


def _decoder(params, x, positions, enc_out, enc_pos, cfg, *, states: DecState | None,
             cur_pos, mode: str, constrain=lambda t, s: t):
    cdt = dt(cfg, "compute")
    hd = cfg.resolved_head_dim()
    H, Kv = cfg.num_heads, cfg.num_kv_heads
    tp = getattr(constrain, "tp", 1)
    Hp = cfg.padded_heads(tp)

    def body(carry, xs):
        xc = carry
        if states is None:
            layer = xs
            st = None
        else:
            layer, st = xs
        # self attention
        h = rmsnorm(layer["ln1"], xc, cfg.norm_eps)
        if mode == "train":
            if st is not None:
                out, (k, v) = attn.attn_apply_train(
                    layer["attn"], h, positions, cfg, constrain=constrain, return_kv=True)
                new_kv = attn.cache_from_prefill(st[0], k, v, positions, -1)
            else:
                out = attn.attn_apply_train(layer["attn"], h, positions, cfg, constrain=constrain)
                new_kv = None
        else:
            out, new_kv = attn.attn_apply_decode(layer["attn"], h, cur_pos, st[0], cfg,
                                                 constrain=constrain)
        xc = constrain(xc + out.astype(xc.dtype), "act_embed")

        # cross attention
        h = rmsnorm(layer["lnx"], xc, cfg.norm_eps)
        B, S, _ = h.shape
        q = (h.astype(cdt) @ layer["xattn"]["wq"].astype(cdt)).reshape(B, S, H, hd)
        if Hp != H:
            q = jnp.pad(q, ((0, 0), (0, 0), (0, Hp - H), (0, 0)))
        q = attn.apply_rope(q, positions, cfg.rope_theta)
        q = constrain(q, "act_heads")
        if st is None:
            kx, vx = _cross_kv(layer, enc_out, enc_pos, cfg)
        else:
            kx, vx = (st[1], st[2]) if mode != "train" else _cross_kv(layer, enc_out, enc_pos, cfg)
        out = attn.blockwise_attention(q, kx, vx, positions, enc_pos, window=-1,
                                       causal=False, constrain=constrain,
                                       mode="train" if (mode == "train" and st is None) else "infer",
                                       kv_map=attn.head_to_kv_map(cfg, tp))
        out = attn._unpad_heads(out, cfg, tp) @ layer["xattn"]["wo"].astype(cdt)
        xc = constrain(xc + out.astype(xc.dtype), "act_embed")

        # mlp
        h = rmsnorm(layer["ln2"], xc, cfg.norm_eps)
        xc = xc + mlp_apply(layer["mlp"], h, cfg, constrain=constrain).astype(xc.dtype)
        xc = constrain(xc, "act_embed")
        if st is not None:
            if mode == "train":
                kx_c, vx_c = _cross_kv(layer, enc_out, enc_pos, cfg)
                return xc, (new_kv, kx_c, vx_c)
            return xc, (new_kv, kx, vx)
        return xc, None

    body_fn = jax.checkpoint(body) if (cfg.remat and mode == "train" and states is None) else body
    if states is None:
        x, _ = jax.lax.scan(body_fn, x, params["dec_layers"])
        new_states = None
    else:
        x, ys = jax.lax.scan(body_fn, x, (params["dec_layers"],
                                          (states.self_kv, states.cross_k, states.cross_v)))
        new_states = DecState(ys[0], ys[1], ys[2], enc_pos)
    return rmsnorm(params["dec_norm"], x, cfg.norm_eps), new_states


def train_loss(params, batch: Dict[str, jax.Array], cfg: ModelConfig,
               constrain=lambda t, s: t):
    enc_out = encode(params, batch["encoder_frames"], cfg, constrain=constrain, mode="train")
    B, F, _ = enc_out.shape
    enc_pos = jnp.broadcast_to(jnp.arange(F, dtype=jnp.int32)[None], (B, F))
    tokens = batch["tokens"]
    S = tokens.shape[1]
    x = embed_lookup(params["embed"], tokens, cfg)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    x, _ = _decoder(params, x, positions, enc_out, enc_pos, cfg, states=None,
                    cur_pos=None, mode="train", constrain=constrain)
    labels = jnp.pad(tokens[:, 1:], ((0, 0), (0, 1)))
    mask = batch.get("loss_mask", jnp.ones_like(tokens, jnp.float32))
    mask = mask.astype(jnp.float32).at[:, -1].set(0.0)
    ce = chunked_softmax_xent(x, labels, mask, params["embed"], None, cfg, constrain=constrain)
    return ce, {"ce": ce, "aux": jnp.zeros((), jnp.float32)}


def init_decode_state(cfg: ModelConfig, B: int, S_ctx: int) -> DecState:
    cdt = dt(cfg, "compute")
    hd = cfg.resolved_head_dim()
    L, F, Kv = cfg.num_layers, cfg.encoder_frames, cfg.num_kv_heads
    one = attn.init_cache(cfg, B, S_ctx, -1, cdt)
    return DecState(
        self_kv=jax.tree.map(lambda x: jnp.stack([x] * L), one),
        cross_k=jnp.zeros((L, B, F, Kv, hd), cdt),
        cross_v=jnp.zeros((L, B, F, Kv, hd), cdt),
        enc_pos=jnp.zeros((B, F), jnp.int32),
    )


def prefill(params, batch: Dict[str, jax.Array], cfg: ModelConfig,
            constrain=lambda t, s: t, total_slots: int | None = None):
    enc_out = encode(params, batch["encoder_frames"], cfg, constrain=constrain, mode="infer")
    B, F, _ = enc_out.shape
    enc_pos = jnp.broadcast_to(jnp.arange(F, dtype=jnp.int32)[None], (B, F))
    tokens = batch["tokens"]
    S = tokens.shape[1]
    x = embed_lookup(params["embed"], tokens, cfg)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    states = init_decode_state(cfg, B, total_slots or S + 1)._replace(enc_pos=enc_pos)
    x, states = _decoder(params, x, positions, enc_out, enc_pos, cfg, states=states,
                         cur_pos=None, mode="train", constrain=constrain)
    logits = logits_from(params["embed"], None, x[:, -1:, :], cfg)
    return logits[:, 0], states


def decode_step(params, tokens: jax.Array, cur_pos: jax.Array, states: DecState,
                cfg: ModelConfig, constrain=lambda t, s: t):
    B = tokens.shape[0]
    x = embed_lookup(params["embed"], tokens, cfg)
    positions = jnp.broadcast_to(cur_pos[None, None], (B, 1)).astype(jnp.int32)
    x, states = _decoder(params, x, positions, None, states.enc_pos, cfg, states=states,
                         cur_pos=cur_pos, mode="decode", constrain=constrain)
    logits = logits_from(params["embed"], None, x, cfg)
    return constrain(logits[:, 0].astype(jnp.float32), "logits"), states
