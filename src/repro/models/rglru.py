"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Real-Gated Linear Recurrent Unit:

    r_t = sigmoid(W_a u_t)                      recurrence gate
    i_t = sigmoid(W_i u_t)                      input gate
    log a_t = c * r_t * log sigmoid(Lambda)     per-channel, c = 8
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t^2) ⊙ (i_t ⊙ u_t)

The recurrence is a first-order per-channel linear scan, so training uses
jax.lax.associative_scan over time — O(T) work, O(log T) depth, and it
parallelizes over the sequence (this is the TPU-native answer to "the RNN is
sequential": no kernel needed, XLA fuses the combine). Decode is the O(1)
step. A width-4 causal depthwise conv precedes the LRU, as in Griffin.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, dt

LRU_C = 8.0
CONV_W = 4


def rglru_init(key, cfg: ModelConfig):
    d = cfg.d_model
    ks = jax.random.split(key, 6)
    return {
        "w_gate": dense_init(ks[0], d, d, cfg),  # gelu branch
        "w_x": dense_init(ks[1], d, d, cfg),  # recurrent branch input
        "conv_w": (jax.random.normal(ks[2], (CONV_W, d), jnp.float32) * 0.1).astype(dt(cfg)),
        "conv_b": jnp.zeros((d,), jnp.float32),
        "w_a": dense_init(ks[3], d, d, cfg),
        "w_i": dense_init(ks[4], d, d, cfg),
        "lam": jnp.full((d,), 2.0, jnp.float32),  # sigmoid(2) ~ .88 slow decay
        "w_out": dense_init(ks[5], d, d, cfg),
    }


class RGLRUState(NamedTuple):
    h: jax.Array  # (B, d) fp32 recurrent state
    conv: jax.Array  # (B, CONV_W-1, d) conv tail


def rglru_state_init(cfg: ModelConfig, B: int, dtype) -> RGLRUState:
    return RGLRUState(
        h=jnp.zeros((B, cfg.d_model), jnp.float32),
        conv=jnp.zeros((B, CONV_W - 1, cfg.d_model), dtype),
    )


def _conv1d_causal(params, u: jax.Array, tail: jax.Array):
    """Depthwise causal conv, width CONV_W. u: (B,T,d); tail: (B,CONV_W-1,d).
    Returns (out (B,T,d), new_tail)."""
    w = params["conv_w"].astype(u.dtype)
    ext = jnp.concatenate([tail.astype(u.dtype), u], axis=1)  # (B, T+3, d)
    out = sum(ext[:, i : i + u.shape[1]] * w[i] for i in range(CONV_W))
    return out + params["conv_b"].astype(u.dtype), ext[:, -(CONV_W - 1) :]


def _lru_gates(params, u, cfg: ModelConfig):
    cdt = dt(cfg, "compute")
    r = jax.nn.sigmoid((u @ params["w_a"].astype(cdt)).astype(jnp.float32))
    i = jax.nn.sigmoid((u @ params["w_i"].astype(cdt)).astype(jnp.float32))
    log_a = LRU_C * r * jax.nn.log_sigmoid(params["lam"])  # (..., d) < 0
    b = jnp.sqrt(-jnp.expm1(2.0 * log_a)) * i * u.astype(jnp.float32)  # sqrt(1-a^2)
    return log_a, b


def rglru_apply_train(params, x: jax.Array, state: RGLRUState, cfg: ModelConfig,
                      constrain=lambda t, s: t):
    """x: (B, T, d); returns (out, new_state)."""
    cdt = dt(cfg, "compute")
    gate = constrain(jax.nn.gelu(x.astype(cdt) @ params["w_gate"].astype(cdt)), "act_chan")
    u = constrain(x.astype(cdt) @ params["w_x"].astype(cdt), "act_chan")
    u, conv_tail = _conv1d_causal(params, u, state.conv)
    log_a, b = _lru_gates(params, u, cfg)
    log_a = constrain(log_a, "act_chan")
    b = constrain(b, "act_chan")

    # prepend carried state as a pseudo-step: h_0 carries in via b-slot
    log_a_ext = jnp.concatenate([jnp.zeros_like(log_a[:, :1]), log_a], axis=1)
    b_ext = jnp.concatenate([state.h[:, None, :], b], axis=1)

    def combine(left, right):
        la1, b1 = left
        la2, b2 = right
        return la1 + la2, jnp.exp(la2) * b1 + b2

    _, h = jax.lax.associative_scan(combine, (log_a_ext, b_ext), axis=1)
    h = h[:, 1:]  # drop the carry pseudo-step
    out = (gate * h.astype(cdt)) @ params["w_out"].astype(cdt)
    return out, RGLRUState(h[:, -1, :], conv_tail)


def rglru_apply_decode(params, x: jax.Array, state: RGLRUState, cfg: ModelConfig,
                       constrain=lambda t, s: t):
    """x: (B, 1, d) single step."""
    cdt = dt(cfg, "compute")
    xt = x.astype(cdt)
    gate = jax.nn.gelu(xt @ params["w_gate"].astype(cdt))[:, 0]
    u = (xt @ params["w_x"].astype(cdt))[:, 0]  # (B, d)
    ext = jnp.concatenate([state.conv.astype(u.dtype), u[:, None]], axis=1)  # (B,4,d)
    w = params["conv_w"].astype(u.dtype)
    u = sum(ext[:, i] * w[i] for i in range(CONV_W)) + params["conv_b"].astype(u.dtype)
    log_a, b = _lru_gates(params, u, cfg)
    h = jnp.exp(log_a) * state.h + b
    out = ((gate * h.astype(cdt)) @ params["w_out"].astype(cdt))[:, None, :]
    return out, RGLRUState(h, ext[:, 1:])
