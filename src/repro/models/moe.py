"""Mixture-of-Experts FFN with permutation-gather token dispatch and expert
parallelism.

Memory discipline (hard-won, see EXPERIMENTS.md §Dry-run):
  * the classic GShard (T, E, C) one-hot dispatch tensor is O(T*E*C) —
    hopeless at arctic scale (1M tokens, 128 experts);
  * a row-scatter `zeros(E*C, d).at[slot].set(x)` is O(T*d) in theory, but
    XLA's scatter partitioning materializes u32 index masks of the operand
    size (70 GiB/chip on arctic train_4k);
  * therefore: dispatch/combine are row GATHERS through a precomputed
    slot<->token permutation (1-D u32 scatters only), wrapped in a
    custom_vjp whose backward is a gather by the inverse permutation —
    the mapping is injective, so scatter-add never appears in either pass.

Slot assignment is sort-based (argsort over expert ids + segment starts), so
no (T, E) cumsum tensor exists either. Experts shard over the model axis
(EP); the router runs in fp32 and returns a Switch-style load-balance aux.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro import compat

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, dt


def moe_init(key, cfg: ModelConfig):
    E, d, f = cfg.num_experts, cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 4)
    scale = d**-0.5

    def expert_mats(k, din, dout):
        return (jax.random.normal(k, (E, din, dout), jnp.float32) * din**-0.5).astype(dt(cfg))

    return {
        "router": (jax.random.normal(ks[0], (d, E), jnp.float32) * scale).astype(jnp.float32),
        "w_gate": expert_mats(ks[1], d, f),
        "w_up": expert_mats(ks[2], d, f),
        "w_down": expert_mats(ks[3], f, d),
    }


# ---------------------------------------------------------------------------
# permutation gather with gather-based VJP
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def permute_rows(x, fwd_idx, inv_idx, n_out: int):
    """out[j] = x[fwd_idx[j]] (rows); out-of-range index -> zero row.

    fwd_idx: (n_out,) indices into x's rows (sentinel = x.shape[0]).
    inv_idx: (x.shape[0],) inverse mapping (sentinel = n_out) — used only by
    the backward pass. The mapping must be injective on valid entries.
    """
    del inv_idx
    return jnp.take(x, fwd_idx, axis=0, mode="fill", fill_value=0)


def _permute_fwd(x, fwd_idx, inv_idx, n_out):
    return permute_rows(x, fwd_idx, inv_idx, n_out), (inv_idx, x.shape[0])


def _permute_bwd(n_out, res, g):
    inv_idx, n_in = res
    dx = jnp.take(g, inv_idx, axis=0, mode="fill", fill_value=0)
    return dx, None, None


permute_rows.defvjp(_permute_fwd, _permute_bwd)


class MoEOut(NamedTuple):
    y: jax.Array
    aux_loss: jax.Array  # load-balance loss (Switch LB: E * sum_e f_e * p_e)


def _route(params, xt, E: int, k: int):
    """fp32 routing: (top_p, top_e, aux)."""
    T = xt.shape[0]
    logits = xt.astype(jnp.float32) @ params["router"]  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)  # (T, k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
    me = jnp.mean(probs, axis=0)
    ce = jnp.zeros((E,), jnp.float32).at[top_e[:, 0]].add(1.0) / T
    aux = E * jnp.sum(me * ce)
    return top_p, top_e, aux


def _expert_ffn(xe, wg, wu, wd, constrain):
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, wg)) * jnp.einsum("ecd,edf->ecf", xe, wu)
    h = constrain(h, "moe_ffn")
    return jnp.einsum("ecf,efd->ecd", h, wd)


def moe_apply_ep(params, x: jax.Array, cfg: ModelConfig, constrain) -> MoEOut:
    """Expert-parallel MoE via shard_map: the paper's local-compute + one-psum
    pattern. Tokens stay on their (pod, data) shard, every model shard holds
    E/tp experts and a full replica of the local tokens; each chip slots its
    local tokens for its local experts (1-D sort/gather work only), runs the
    expert FFN, combines locally, and a single psum over "model" produces the
    output. No all-to-all, no cross-shard row gathers.

    Capacity is per-(data-shard, expert): C_loc = cf * T_loc * k / E.
    """
    mesh = constrain.mesh
    cdt = dt(cfg, "compute")
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.num_experts_per_tok
    tp = mesh.shape.get("model", 1)
    E_loc = E // tp
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp = 1
    for a in batch_axes:
        dp *= mesh.shape[a]
    T_loc = (B // dp) * S  # tokens per data shard
    C = max(8, int(cfg.capacity_factor * T_loc * k / E))
    C = -(-C // 8) * 8

    from jax.sharding import PartitionSpec as P  # local import: keep module light

    bspec = batch_axes if len(batch_axes) > 1 else (batch_axes[0] if batch_axes else None)

    def local_fn(x_loc, router_w, wg, wu, wd):
        # x_loc: (B_loc, S, d) local tokens (full S per model rank by design);
        # reshape to (T_loc, d) locally — see moe_apply_ep_a2a for why
        xt = x_loc.reshape(T_loc, d)
        top_p, top_e, aux = _route({"router": router_w}, xt, E, k)
        my_first = jax.lax.axis_index("model").astype(jnp.int32) * E_loc
        flat_e = top_e.reshape(T_loc * k).astype(jnp.int32) - my_first  # local ids
        mine = (flat_e >= 0) & (flat_e < E_loc)
        key = jnp.where(mine, flat_e, E_loc)  # foreign pairs sort to the end
        order = jnp.argsort(key, stable=True).astype(jnp.int32)
        sorted_e = key[order]
        seg_start = jnp.searchsorted(sorted_e, jnp.arange(E_loc, dtype=jnp.int32)).astype(jnp.int32)
        pos_sorted = jnp.arange(T_loc * k, dtype=jnp.int32) - seg_start[sorted_e]
        keep = (sorted_e < E_loc) & (pos_sorted < C)
        slot_sorted = jnp.where(keep, sorted_e * C + pos_sorted, E_loc * C)
        slot_of_pair = jnp.full((T_loc * k,), E_loc * C, jnp.int32).at[order].set(slot_sorted)
        pair_of_slot = jnp.full((E_loc * C,), T_loc * k, jnp.int32).at[
            slot_sorted
        ].set(order, mode="drop")

        xp = jnp.repeat(xt.astype(cdt), k, axis=0)  # (T_loc*k, d)
        xe = permute_rows(xp, pair_of_slot, slot_of_pair, E_loc * C)
        ye = _expert_ffn(xe.reshape(E_loc, C, d), wg.astype(cdt), wu.astype(cdt),
                         wd.astype(cdt), lambda t, s: t)
        ye_pairs = permute_rows(ye.reshape(E_loc * C, d), slot_of_pair, pair_of_slot,
                                T_loc * k)
        w = (top_p.reshape(T_loc * k) * (slot_of_pair < E_loc * C)).astype(cdt)
        y = jnp.sum((ye_pairs * w[:, None]).reshape(T_loc, k, d), axis=1)
        y = jax.lax.psum(y.astype(cdt), "model")  # the one collective, in bf16 (B2)
        if batch_axes:  # aux is per-data-shard: average over the data axes
            aux = jax.lax.psum(aux, batch_axes) / dp
        return y.reshape(x_loc.shape), aux

    fn = compat.shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(P(bspec, None, None), P(), P("model", None, None),
                  P("model", None, None), P("model", None, None)),
        out_specs=(P(bspec, None, None), P()),
        check_vma=False,
    )
    y, aux = fn(x, params["router"], params["w_gate"], params["w_up"], params["w_down"])
    return MoEOut(y, aux.astype(jnp.float32))


def moe_apply_ep_a2a(params, x: jax.Array, cfg: ModelConfig, constrain) -> MoEOut:
    """All-to-all expert parallelism (perf iteration B4, §Perf; GLaM-style).

    Tokens shard over (pod, data, model) — each chip routes only T_chip =
    T/(dp*tp) tokens. Pairs sort by destination model-rank into fixed
    (tp, C_send, d) buffers; one all_to_all delivers them to the expert
    owner, which re-sorts into per-expert queues, runs the FFN, and a
    reverse all_to_all returns the results to the token owners. Both
    directions are pure gathers + a2a (differentiable: a2a^T = a2a), so no
    scatter pathology and the per-chip MoE activation footprint drops 16x
    vs the dispatch-free path. Two capacity stages (send-side C_send per
    destination rank, expert-side C_recv per expert) bound the buffers.
    """
    mesh = constrain.mesh
    cdt = dt(cfg, "compute")
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.num_experts_per_tok
    tp = mesh.shape.get("model", 1)
    E_loc = E // tp
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp = 1
    for a in batch_axes:
        dp *= mesh.shape[a]
    T_chip = (B // dp) * S // tp
    cf = cfg.capacity_factor
    C_send = -(-max(8, int(cf * T_chip * k / tp)) // 8) * 8
    C_recv = -(-max(8, int(cf * tp * C_send / E_loc)) // 8) * 8

    from jax.sharding import PartitionSpec as P

    all_axes = batch_axes + ("model",)
    bspec = all_axes if len(all_axes) > 1 else all_axes[0]

    def _slot(ids, n_buckets: int, cap: int, n_items: int):
        """Sort-based slotting: ids (n_items,) in [0, n_buckets) or >= for
        'drop'. Returns (slot_of_item, item_of_slot) with sentinels."""
        key = jnp.where(ids < n_buckets, ids, n_buckets)
        order = jnp.argsort(key, stable=True).astype(jnp.int32)
        sorted_b = key[order]
        seg = jnp.searchsorted(sorted_b, jnp.arange(n_buckets, dtype=jnp.int32)).astype(jnp.int32)
        pos = jnp.arange(n_items, dtype=jnp.int32) - seg[sorted_b]
        keep = (sorted_b < n_buckets) & (pos < cap)
        slot_sorted = jnp.where(keep, sorted_b * cap + pos, n_buckets * cap)
        slot_of_item = jnp.full((n_items,), n_buckets * cap, jnp.int32).at[order].set(slot_sorted)
        item_of_slot = jnp.full((n_buckets * cap,), n_items, jnp.int32).at[
            slot_sorted
        ].set(order, mode="drop")
        return slot_of_item, item_of_slot

    def local_fn(x_loc, router_w, wg, wu, wd):
        # x_loc: (B_loc, S/tp, d) — reshape to tokens LOCALLY (a global
        # (B,S,d)->(B*S,d) merge across differently-sharded dims triggers
        # GSPMD involuntary full rematerialization: 28 GiB/chip on arctic)
        xt = x_loc.reshape(T_chip, d)
        top_p, top_e, aux = _route({"router": router_w}, xt, E, k)
        flat_e = top_e.reshape(T_chip * k).astype(jnp.int32)
        dest = flat_e // E_loc  # destination model rank per pair

        # ---- send side: pairs -> (tp, C_send) buffers -------------------
        s_of_pair, pair_of_s = _slot(dest, tp, C_send, T_chip * k)
        xp = jnp.repeat(xt.astype(cdt), k, axis=0)
        send = permute_rows(xp, pair_of_s, s_of_pair, tp * C_send)  # (tp*C_send, d)
        # expert-local id rides along (sentinel E_loc for empty slots)
        e_send = jnp.full((tp * C_send,), E_loc, jnp.int32).at[
            jnp.where(s_of_pair < tp * C_send, s_of_pair, tp * C_send)
        ].set(flat_e % E_loc, mode="drop")

        recv = jax.lax.all_to_all(send.reshape(tp, C_send, d), "model", 0, 0, tiled=False)
        e_recv = jax.lax.all_to_all(e_send.reshape(tp, C_send), "model", 0, 0,
                                    tiled=False).reshape(tp * C_send)

        # ---- expert side: recv slots -> per-expert queues ---------------
        r_of_slotq, slotq_of_r = _slot(e_recv, E_loc, C_recv, tp * C_send)
        xe = permute_rows(recv.reshape(tp * C_send, d), slotq_of_r, r_of_slotq,
                          E_loc * C_recv)
        ye = _expert_ffn(xe.reshape(E_loc, C_recv, d), wg.astype(cdt), wu.astype(cdt),
                         wd.astype(cdt), lambda t, s: t)
        back = permute_rows(ye.reshape(E_loc * C_recv, d), r_of_slotq, slotq_of_r,
                            tp * C_send)

        # ---- reverse a2a + combine --------------------------------------
        ret = jax.lax.all_to_all(back.reshape(tp, C_send, d), "model", 0, 0,
                                 tiled=False).reshape(tp * C_send, d)
        y_pairs = permute_rows(ret, s_of_pair, pair_of_s, T_chip * k)
        w = (top_p.reshape(T_chip * k) * (s_of_pair < tp * C_send)).astype(cdt)
        y = jnp.sum((y_pairs * w[:, None]).reshape(T_chip, k, d), axis=1)
        aux = jax.lax.psum(aux, all_axes) / (dp * tp)
        return y.reshape(x_loc.shape), aux

    bonly = batch_axes if len(batch_axes) > 1 else (batch_axes[0] if batch_axes else None)
    fn = compat.shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(P(bonly, "model", None), P(), P("model", None, None),
                  P("model", None, None), P("model", None, None)),
        out_specs=(P(bonly, "model", None), P()),
        check_vma=False,
    )
    x = constrain(x, "act_embed")  # (B, S, d): batch x seq(model) sharded
    y, aux = fn(x, params["router"], params["w_gate"], params["w_up"], params["w_down"])
    return MoEOut(y, aux.astype(jnp.float32))


def moe_apply(params, x: jax.Array, cfg: ModelConfig, constrain=lambda t, s: t) -> MoEOut:
    """x: (B, S, d) -> (B, S, d). Dispatch: a2a EP when tokens divide over
    (batch x model) (training/prefill), dispatch-free EP otherwise (decode /
    tiny batches), dense gather path off-mesh."""
    mesh = getattr(constrain, "mesh", None)
    if mesh is not None and mesh.shape.get("model", 1) > 1 and cfg.num_experts % mesh.shape["model"] == 0:
        tp = mesh.shape["model"]
        batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        dp = 1
        for a in batch_axes:
            dp *= mesh.shape[a]
        B, S, _ = x.shape
        T_loc = (B // dp) * S if B % dp == 0 else 0
        if T_loc and T_loc % tp == 0 and T_loc // tp >= 64:
            return moe_apply_ep_a2a(params, x, cfg, constrain)
        return moe_apply_ep(params, x, cfg, constrain)
    return moe_apply_dense(params, x, cfg, constrain)


def moe_apply_dense(params, x: jax.Array, cfg: ModelConfig, constrain=lambda t, s: t) -> MoEOut:
    """Single-device / no-EP path: global-capacity slotting, same math."""
    cdt = dt(cfg, "compute")
    B, S, d = x.shape
    T = B * S
    E, k = cfg.num_experts, cfg.num_experts_per_tok
    C = max(8, int(cfg.capacity_factor * T * k / E))
    C = -(-C // 8) * 8
    xt = x.reshape(T, d)

    # --- routing (fp32) ---
    logits = xt.astype(jnp.float32) @ params["router"]  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)  # (T, k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    me = jnp.mean(probs, axis=0)  # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[top_e[:, 0]].add(1.0) / T
    aux = E * jnp.sum(me * ce)

    # --- sort-based slot assignment: all 1-D integer work ---
    flat_e = top_e.reshape(T * k).astype(jnp.int32)
    order = jnp.argsort(flat_e, stable=True).astype(jnp.int32)  # (T*k,)
    sorted_e = flat_e[order]
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(E, dtype=jnp.int32)).astype(jnp.int32)  # (E,)
    pos_sorted = jnp.arange(T * k, dtype=jnp.int32) - seg_start[sorted_e]
    keep_sorted = pos_sorted < C
    slot_sorted = jnp.where(keep_sorted, sorted_e * C + pos_sorted, E * C)
    # slot per (token, choice) pair, original order
    slot_of_pair = jnp.zeros((T * k,), jnp.int32).at[order].set(slot_sorted)  # (T*k,)
    # inverse: which pair fills each slot (sentinel T*k = empty)
    pair_of_slot = jnp.full((E * C,), T * k, jnp.int32).at[
        jnp.where(keep_sorted, slot_sorted, E * C)
    ].set(order, mode="drop")

    # --- dispatch: gather pair rows into (E, C, d) slots ---
    # pair view (token repeated k times) keeps the slot<->pair map injective,
    # so both directions of permute_rows are gathers; repeat's own backward
    # is a cheap reshape-sum over k.
    xp = jnp.repeat(xt.astype(cdt), k, axis=0)  # (T*k, d)
    xe = permute_rows(xp, pair_of_slot, slot_of_pair, E * C)  # (E*C, d)
    xe = constrain(xe.reshape(E, C, d), "moe_tokens")

    # --- expert FFN: batched over E (sharded over model axis) ---
    wg = params["w_gate"].astype(cdt)
    wu = params["w_up"].astype(cdt)
    wd = params["w_down"].astype(cdt)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, wg)) * jnp.einsum("ecd,edf->ecf", xe, wu)
    h = constrain(h, "moe_ffn")
    ye = jnp.einsum("ecf,efd->ecd", h, wd)  # (E, C, d)
    ye = constrain(ye, "moe_tokens").reshape(E * C, d)

    # --- combine: gather each pair's slot row; dropped pairs -> zero row ---
    ye_pairs = permute_rows(ye, slot_of_pair, pair_of_slot, T * k)  # (T*k, d)
    w = (top_p.reshape(T * k) * (slot_of_pair < E * C)).astype(cdt)
    y = jnp.sum((ye_pairs * w[:, None]).reshape(T, k, d), axis=1)
    return MoEOut(y.reshape(B, S, d), aux.astype(jnp.float32))
