"""Common transformer layers — pure-pytree functional modules (no flax).

Convention: every module is an (init, apply) pair. `init(key, cfg, ...)`
returns a params dict; `apply(params, x, ...)` is shape-polymorphic and
dtype-disciplined: matmuls run in cfg.compute_dtype, normalizations and
softmax statistics in float32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def dt(cfg: ModelConfig, kind: str = "param"):
    return jnp.dtype(cfg.param_dtype if kind == "param" else cfg.compute_dtype)


def dense_init(key, d_in: int, d_out: int, cfg: ModelConfig, scale: float | None = None):
    scale = scale if scale is not None else d_in**-0.5
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dt(cfg))


def rmsnorm_init(d: int, cfg: ModelConfig):
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(params, x: jax.Array, eps: float) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps) * params["scale"]
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs  # (..., S, 1, hd/2)
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GELU)
# ---------------------------------------------------------------------------

def mlp_init(key, cfg: ModelConfig, d: int | None = None, f: int | None = None):
    d = d or cfg.d_model
    f = f or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.act == "swiglu":
        return {
            "w_gate": dense_init(ks[0], d, f, cfg),
            "w_up": dense_init(ks[1], d, f, cfg),
            "w_down": dense_init(ks[2], f, d, cfg),
        }
    return {"w_up": dense_init(ks[0], d, f, cfg), "w_down": dense_init(ks[1], f, d, cfg)}


def mlp_apply(params, x: jax.Array, cfg: ModelConfig, constrain=lambda t, s: t) -> jax.Array:
    cdt = dt(cfg, "compute")
    x = x.astype(cdt)
    if cfg.act == "swiglu":
        h = jax.nn.silu(x @ params["w_gate"].astype(cdt)) * (x @ params["w_up"].astype(cdt))
    else:
        h = jax.nn.gelu(x @ params["w_up"].astype(cdt))
    h = constrain(h, "ffn")
    return h @ params["w_down"].astype(cdt)


# ---------------------------------------------------------------------------
# Embedding + sequence-chunked cross-entropy
# ---------------------------------------------------------------------------

def embed_init(key, cfg: ModelConfig):
    # table std d^-1/2: lookups are rescaled by sqrt(d) below, and tied
    # logits x @ table^T come out unit-variance without a separate scale.
    # Rows beyond vocab_size are TP padding (cfg.padded_vocab) — never
    # indexed, and masked out of logits/CE.
    table = (jax.random.normal(key, (cfg.padded_vocab(), cfg.d_model), jnp.float32)
             * cfg.d_model**-0.5).astype(dt(cfg))
    return {"table": table}


def embed_lookup(params, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    return params["table"].astype(dt(cfg, "compute"))[tokens] * (cfg.d_model**0.5)


def unembed_init(key, cfg: ModelConfig):
    return {"w": dense_init(key, cfg.d_model, cfg.padded_vocab(), cfg)}


def logits_from(params_embed, params_unembed, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Logits over the PADDED vocab (pad ids masked to -inf)."""
    cdt = dt(cfg, "compute")
    if cfg.tie_embeddings:
        logits = x.astype(cdt) @ params_embed["table"].astype(cdt).T
    else:
        logits = x.astype(cdt) @ params_unembed["w"].astype(cdt)
    if cfg.padded_vocab() != cfg.vocab_size:
        pad_mask = jnp.arange(cfg.padded_vocab()) < cfg.vocab_size
        logits = jnp.where(pad_mask, logits, -1e30)
    return logits


def chunked_softmax_xent(
    x: jax.Array,
    labels: jax.Array,
    loss_mask: jax.Array,
    params_embed,
    params_unembed,
    cfg: ModelConfig,
    constrain=lambda t, s: t,
) -> jax.Array:
    """Mean CE over masked positions without materializing (B, S, V).

    Scans over sequence chunks; per chunk the (B, c, V) logits live briefly
    (sharded over the model axis via `constrain`) and reduce to fp32 scalars.
    """
    B, S, _ = x.shape
    c = min(cfg.logits_chunk, S)
    pad = (-S) % c
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        loss_mask = jnp.pad(loss_mask, ((0, 0), (0, pad)))
    n_chunks = x.shape[1] // c
    xs = x.reshape(B, n_chunks, c, -1).swapaxes(0, 1)  # (n, B, c, d)
    ls = labels.reshape(B, n_chunks, c).swapaxes(0, 1)
    ms = loss_mask.reshape(B, n_chunks, c).swapaxes(0, 1)

    def body(carry, inp):
        xc, lc, mc = inp
        logits = logits_from(params_embed, params_unembed, xc, cfg)  # (B, c, V)
        logits = constrain(logits.astype(jnp.float32), "logits")
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * mc
        return (carry[0] + jnp.sum(nll), carry[1] + jnp.sum(mc)), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32),) * 2, (xs, ls, ms))
    return tot / jnp.maximum(cnt, 1.0)
