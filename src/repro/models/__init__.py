from repro.models import attention, encdec, frontends, layers, model_zoo, moe, rglru, rwkv6, transformer

__all__ = ["attention", "encdec", "frontends", "layers", "model_zoo", "moe", "rglru", "rwkv6", "transformer"]
