"""Grouped-query attention with blockwise (flash-style) softmax, sliding
windows, and ring-buffer KV caches.

Design notes (TPU-oriented):

  * Train/prefill attention is *blockwise*: an online-softmax scan over
    (q-block, kv-block) pairs. The pair list is built statically as the lower
    block-triangle (causal) or a clipped band (sliding window), so compute is
    ~causal-optimal — the naive "scan all kv for all q, mask half away" costs
    2x the FLOPs and shows up directly in the roofline's compute term (this
    was perf iteration #1, see EXPERIMENTS.md §Perf).
  * GQA never materializes repeated KV heads: scores are grouped einsums
    (B, kv, group, bq, bk) in fp32.
  * Decode uses a KV cache with absolute positions stored per slot; windowed
    layers get a ring buffer of exactly `window` slots, so a 32k-window-1024
    hybrid decodes against O(window) state, not O(seq).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.layers import apply_rope, dense_init, dt

DEFAULT_BLOCK_Q = 512
DEFAULT_BLOCK_KV = 512
NEG_INF = -1e30


def attn_init(key, cfg: ModelConfig, d: int | None = None, *, cross: bool = False):
    d = d or cfg.d_model
    hd = cfg.resolved_head_dim()
    H, Kv = cfg.num_heads, cfg.num_kv_heads
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], d, H * hd, cfg),
        "wk": dense_init(ks[1], d, Kv * hd, cfg),
        "wv": dense_init(ks[2], d, Kv * hd, cfg),
        "wo": dense_init(ks[3], H * hd, d, cfg, scale=(H * hd) ** -0.5),
    }


class KVCache(NamedTuple):
    k: jax.Array  # (B, S_slots, Kv, hd) — roped keys
    v: jax.Array  # (B, S_slots, Kv, hd)
    pos: jax.Array  # (B, S_slots) absolute position per slot; -1 = empty


def _qkv(params, x, positions, cfg: ModelConfig, tp: int = 1,
         constrain=lambda t, s: t):
    """Projections + RoPE. Query heads are FLAT-padded with zero heads to
    cfg.padded_heads(tp) so the head axis shards evenly over the model axis;
    `head_to_kv_map` routes each (possibly padded) query head to its kv head
    inside blockwise_attention, and the pads are sliced off before w_o.
    q/k are constrained to the head-sharded layout BEFORE RoPE so the fp32
    rotation chain runs on 1/tp of the heads (§Perf B3)."""
    cdt = dt(cfg, "compute")
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim()
    H, Kv = cfg.num_heads, cfg.num_kv_heads
    Hp = cfg.padded_heads(tp)
    x = x.astype(cdt)
    q = (x @ params["wq"].astype(cdt)).reshape(B, S, H, hd)
    if Hp != H:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, Hp - H), (0, 0)))
    k = (x @ params["wk"].astype(cdt)).reshape(B, S, Kv, hd)
    v = (x @ params["wv"].astype(cdt)).reshape(B, S, Kv, hd)
    q = apply_rope(constrain(q, "act_heads"), positions, cfg.rope_theta)
    k = apply_rope(constrain(k, "act_kv_heads"), positions, cfg.rope_theta)
    return q, k, v


def head_to_kv_map(cfg: ModelConfig, tp: int) -> np.ndarray:
    """Static (Hp,) map: query head -> kv head (pads point at kv head 0)."""
    H, Kv = cfg.num_heads, cfg.num_kv_heads
    G = H // Kv
    Hp = cfg.padded_heads(tp)
    return np.asarray([h // G if h < H else 0 for h in range(Hp)], np.int32)


def _unpad_heads(out_flat: jax.Array, cfg: ModelConfig, tp: int) -> jax.Array:
    """(.., Hp*hd) -> (.., H*hd): drop flat-padded query heads before w_o."""
    H, hd = cfg.num_heads, cfg.resolved_head_dim()
    Hp = cfg.padded_heads(tp)
    if Hp == H:
        return out_flat
    lead = out_flat.shape[:-1]
    return out_flat.reshape(*lead, Hp, hd)[..., :H, :].reshape(*lead, H * hd)


def _pair_list(n_q: int, n_kv: int, n_kv_per_q: Optional[int], causal: bool) -> np.ndarray:
    """Static (iq, ikv) block pairs: full grid (bidirectional/cross), lower
    triangle (causal), or a clipped band ending at the diagonal (windowed)."""
    pairs = []
    for iq in range(n_q):
        if not causal:
            lo, hi = 0, n_kv - 1
        else:
            lo = 0 if n_kv_per_q is None else max(0, iq - n_kv_per_q + 1)
            hi = iq
        for ikv in range(lo, hi + 1):
            pairs.append((iq, ikv))
    return np.asarray(pairs, np.int32)


def blockwise_attention(
    q: jax.Array,  # (B, S, H, hd) — H already TP-padded by _qkv
    k: jax.Array,  # (B, S_kv, Kv, hd)
    v: jax.Array,
    q_positions: jax.Array,  # (B, S)
    kv_positions: jax.Array,  # (B, S_kv)
    *,
    window: int,  # -1 = full causal
    causal: bool = True,  # False: bidirectional/cross attention
    block_q: int = DEFAULT_BLOCK_Q,
    block_kv: int = DEFAULT_BLOCK_KV,
    constrain=lambda t, s: t,
    mode: str = "train",  # "train": remat-friendly backward; "infer": pair-scan
    kv_map: Optional[np.ndarray] = None,  # (H,) query-head -> kv-head
) -> jax.Array:
    """KV heads are gathered up to the (padded) query-head axis before the
    block loop so every block tensor has a single head axis that shards
    cleanly over the model axis (grouped (Kv, G) layouts defeat GSPMD's
    while-loop propagation and the scores replicate — 192 GiB/chip on smollm
    before this change). The scan carries are explicitly constrained for the
    same reason."""
    B, S, H, hd = q.shape
    S_kv, Kv = k.shape[1], k.shape[2]
    if kv_map is None:
        kv_map = np.repeat(np.arange(Kv, dtype=np.int32), H // Kv)
    assert len(kv_map) == H, (len(kv_map), H)
    if Kv != H or not np.array_equal(kv_map, np.arange(H)):
        k = k[:, :, jnp.asarray(kv_map), :]
        v = v[:, :, jnp.asarray(kv_map), :]
    bq = min(block_q, S)
    bk = min(block_kv, S_kv)
    pad_q = (-S) % bq  # uneven q (whisper's 1500 frames): pad + slice off
    S_orig = S
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        q_positions = jnp.pad(q_positions, ((0, 0), (0, pad_q)), constant_values=-1)
        S += pad_q
    pad_kv = (-S_kv) % bk  # uneven kv: pad + mask (padded slots carry pos -1)
    if pad_kv:
        k = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        kv_positions = jnp.pad(kv_positions, ((0, 0), (0, pad_kv)), constant_values=-1)
        S_kv += pad_kv
    assert S % bq == 0 and S_kv % bk == 0, (S, bq, S_kv, bk)
    n_q, n_kv = S // bq, S_kv // bk
    # fold the softmax scale into q: saves one full pass over every
    # (bq, bk) score block (perf iteration A2, EXPERIMENTS.md §Perf)
    q = q * jnp.asarray(hd**-0.5, q.dtype)

    qb = constrain(q.reshape(B, n_q, bq, H, hd).transpose(1, 0, 3, 2, 4), "attn_blocks")
    kb = constrain(k.reshape(B, n_kv, bk, H, hd).transpose(1, 0, 3, 2, 4), "attn_blocks")
    vb = constrain(v.reshape(B, n_kv, bk, H, hd).transpose(1, 0, 3, 2, 4), "attn_blocks")
    qpb = q_positions.reshape(B, n_q, bq).transpose(1, 0, 2)  # (n_q, B, bq)
    kpb = kv_positions.reshape(B, n_kv, bk).transpose(1, 0, 2)

    n_kv_per_q = None if window < 0 else (window + bq - 1) // bk + 1

    def block_scores(qi, ki, qp, kp):
        s = jnp.einsum("bhqd,bhsd->bhqs", qi, ki, preferred_element_type=jnp.float32)
        ok = kp[:, None, :] >= 0  # kv-slot validity (padded slots carry -1)
        if causal:
            ok = ok & (qp[:, :, None] >= kp[:, None, :])
        if window > 0:
            ok = ok & (qp[:, :, None] - kp[:, None, :] < window)
        return jnp.where(ok[:, None, :, :], s, NEG_INF)

    def online_update(carry, qi, ki, vi, qp, kp):
        mi, li, ai = carry
        s = block_scores(qi, ki, qp, kp)
        m_new = jnp.maximum(mi, jnp.max(s, axis=-1))
        # A4 (refuted, §Perf): materializing p in bf16 ADDED a convert pass
        # at the fusion boundary (+4% memory term) — fp32 p with an inline
        # cast at the dot is what XLA fuses best.
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(mi - m_new)
        l_new = li * corr + jnp.sum(p, axis=-1)
        a_new = ai * corr[..., None] + jnp.einsum(
            "bhqs,bhsd->bhqd", p.astype(vi.dtype), vi, preferred_element_type=jnp.float32
        )
        return m_new, l_new, a_new

    if mode == "train":
        # Differentiable layout: one (checkpointed) kv-scan per q block. The
        # backward then recomputes the (bq, bk) probability block instead of
        # saving it — the pair-scan layout stacks every p block as a scan
        # residual (4.8 GiB/layer/chip at smollm train_4k; EXPERIMENTS §Perf).
        outs = []
        for iq in range(n_q):
            if not causal:
                kv_idx = list(range(n_kv))
            else:
                lo = 0 if n_kv_per_q is None else max(0, iq - n_kv_per_q + 1)
                kv_idx = list(range(lo, iq + 1))
            qi = qb[iq]
            qp = qpb[iq]
            m0 = jnp.full((B, H, bq), NEG_INF, jnp.float32)
            l0 = jnp.zeros((B, H, bq), jnp.float32)
            a0 = jnp.zeros((B, H, bq, hd), jnp.float32)
            m0, l0, a0 = (constrain(m0, "attn_carry_q"), constrain(l0, "attn_carry_q"),
                          constrain(a0, "attn_carry_qa"))

            @jax.checkpoint
            def body(carry, ikv, _qi=qi, _qp=qp):
                ki = jax.lax.dynamic_index_in_dim(kb, ikv, 0, keepdims=False)
                vi = jax.lax.dynamic_index_in_dim(vb, ikv, 0, keepdims=False)
                kp = jax.lax.dynamic_index_in_dim(kpb, ikv, 0, keepdims=False)
                return online_update(carry, _qi, ki, vi, _qp, kp), None

            (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), jnp.asarray(kv_idx, jnp.int32))
            outs.append(acc / jnp.maximum(l[..., None], 1e-30))
        out = jnp.stack(outs)  # (n_q, B, H, bq, hd)
    else:
        # Inference layout: single scan over the static (iq, ikv) pair list —
        # lowest HLO footprint, no transpose pass exists to pay for.
        pairs = jnp.asarray(_pair_list(n_q, n_kv, n_kv_per_q, causal))  # (P, 2)
        m0 = constrain(jnp.full((n_q, B, H, bq), NEG_INF, jnp.float32), "attn_carry")
        l0 = constrain(jnp.zeros((n_q, B, H, bq), jnp.float32), "attn_carry")
        a0 = constrain(jnp.zeros((n_q, B, H, bq, hd), jnp.float32), "attn_blocks")

        def body(carry, pair):
            m, l, acc = carry
            iq, ikv = pair[0], pair[1]
            qi = jax.lax.dynamic_index_in_dim(qb, iq, 0, keepdims=False)  # (B,H,bq,hd)
            ki = jax.lax.dynamic_index_in_dim(kb, ikv, 0, keepdims=False)
            vi = jax.lax.dynamic_index_in_dim(vb, ikv, 0, keepdims=False)
            qp = jax.lax.dynamic_index_in_dim(qpb, iq, 0, keepdims=False)  # (B, bq)
            kp = jax.lax.dynamic_index_in_dim(kpb, ikv, 0, keepdims=False)  # (B, bk)
            mi = jax.lax.dynamic_index_in_dim(m, iq, 0, keepdims=False)
            li = jax.lax.dynamic_index_in_dim(l, iq, 0, keepdims=False)
            ai = jax.lax.dynamic_index_in_dim(acc, iq, 0, keepdims=False)
            m_new, l_new, a_new = online_update((mi, li, ai), qi, ki, vi, qp, kp)
            m = jax.lax.dynamic_update_index_in_dim(m, m_new, iq, 0)
            l = jax.lax.dynamic_update_index_in_dim(l, l_new, iq, 0)
            acc = jax.lax.dynamic_update_index_in_dim(acc, a_new, iq, 0)
            return (m, l, acc), None

        (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), pairs)
        out = acc / jnp.maximum(l[..., None], 1e-30)

    out = out.transpose(1, 0, 3, 2, 4).reshape(B, S, H * hd)  # (B,S,H*hd)
    return out[:, :S_orig].astype(q.dtype)


def attn_apply_train(
    params,
    x: jax.Array,
    positions: jax.Array,
    cfg: ModelConfig,
    *,
    window: int = -1,
    constrain=lambda t, s: t,
    return_kv: bool = False,
):
    """Full-sequence attention (training / prefill)."""
    tp = getattr(constrain, "tp", 1)
    q, k, v = _qkv(params, x, positions, cfg, tp, constrain)
    v = constrain(v, "act_kv_heads")
    # prefill (return_kv) is forward-only: the pair-scan layout is cheaper
    out = blockwise_attention(q, k, v, positions, positions, window=window,
                              constrain=constrain,
                              mode="infer" if return_kv else "train",
                              kv_map=head_to_kv_map(cfg, tp))
    out = _unpad_heads(out, cfg, tp) @ params["wo"].astype(dt(cfg, "compute"))
    if return_kv:
        return out, (k, v)
    return out


def init_cache(cfg: ModelConfig, B: int, S_ctx: int, window: int, dtype) -> KVCache:
    """Cache for one layer. Windowed layers allocate only `window` slots."""
    slots = S_ctx if window < 0 else min(window, S_ctx)
    hd = cfg.resolved_head_dim()
    return KVCache(
        k=jnp.zeros((B, slots, cfg.num_kv_heads, hd), dtype),
        v=jnp.zeros((B, slots, cfg.num_kv_heads, hd), dtype),
        pos=jnp.full((B, slots), -1, jnp.int32),
    )


def attn_apply_decode(
    params,
    x: jax.Array,  # (B, 1, d)
    cur_pos: jax.Array,  # scalar int32: absolute position of the new token
    cache: KVCache,
    cfg: ModelConfig,
    *,
    window: int = -1,
    constrain=lambda t, s: t,
):
    """One-token decode against the cache; returns (out, new_cache)."""
    cdt = dt(cfg, "compute")
    B = x.shape[0]
    hd = cfg.resolved_head_dim()
    H, Kv = cfg.num_heads, cfg.num_kv_heads
    G = H // Kv  # decode: heads are unsharded, no padding needed
    positions = jnp.broadcast_to(cur_pos[None], (B, 1))
    q, k_new, v_new = _qkv(params, x, positions, cfg, tp=1)

    slots = cache.k.shape[1]
    slot = (cur_pos % slots).astype(jnp.int32)  # identity when slots covers ctx
    z = jnp.zeros((), jnp.int32)  # index dtypes must match under x64 mode
    k_cache = jax.lax.dynamic_update_slice(cache.k, k_new.astype(cache.k.dtype), (z, slot, z, z))
    v_cache = jax.lax.dynamic_update_slice(cache.v, v_new.astype(cache.v.dtype), (z, slot, z, z))
    pos_cache = jax.lax.dynamic_update_slice(
        cache.pos, jnp.broadcast_to(cur_pos[None, None], (B, 1)).astype(jnp.int32), (z, slot)
    )

    qg = q.reshape(B, Kv, G, hd)  # (B,Kv,G,hd)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache.astype(cdt),
                   preferred_element_type=jnp.float32) * hd**-0.5
    valid = (pos_cache >= 0) & (pos_cache <= cur_pos)
    if window > 0:
        valid = valid & (cur_pos - pos_cache < window)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p.astype(cdt), v_cache.astype(cdt),
                     preferred_element_type=jnp.float32)
    out = out.reshape(B, 1, H * hd).astype(cdt) @ params["wo"].astype(cdt)
    return out, KVCache(k_cache, v_cache, pos_cache)


def cache_from_prefill(cache: KVCache, k: jax.Array, v: jax.Array,
                       positions: jax.Array, window: int) -> KVCache:
    """Fill a pre-allocated decode cache from prefill KV.

    Windowed layers keep only the last `slots` positions, ring-indexed by
    absolute position (so subsequent decode steps write consistently)."""
    B, S = positions.shape
    slots = cache.k.shape[1]
    if S <= slots:
        return KVCache(
            jax.lax.dynamic_update_slice(cache.k, k.astype(cache.k.dtype), (0, 0, 0, 0)),
            jax.lax.dynamic_update_slice(cache.v, v.astype(cache.v.dtype), (0, 0, 0, 0)),
            jax.lax.dynamic_update_slice(cache.pos, positions.astype(jnp.int32), (0, 0)),
        )
    k_tail, v_tail, p_tail = k[:, -slots:], v[:, -slots:], positions[:, -slots:]
    idx = p_tail % slots  # (B, slots)
    bidx = jnp.arange(B)[:, None]
    return KVCache(
        cache.k.at[bidx, idx].set(k_tail.astype(cache.k.dtype)),
        cache.v.at[bidx, idx].set(v_tail.astype(cache.v.dtype)),
        cache.pos.at[bidx, idx].set(p_tail.astype(jnp.int32)),
    )
