"""Unified model API: build(cfg) dispatches to the right assembly and exposes
(init, train_loss, prefill, decode_step, init_decode_state, input_specs).

`input_specs(cfg, shape)` returns jax.ShapeDtypeStruct stand-ins for every
model input of a given (arch x shape) cell — weak-type-correct, shardable, no
device allocation — consumed by launch/dryrun.py. `make_batch` materializes
the same structure with synthetic data for smoke tests and examples.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeCell
from repro.models import encdec, transformer

PyTree = Any


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable[[jax.Array], PyTree]
    train_loss: Callable[..., tuple[jax.Array, Dict[str, jax.Array]]]
    prefill: Callable[..., tuple[jax.Array, PyTree]]
    decode_step: Callable[..., tuple[jax.Array, PyTree]]
    init_decode_state: Callable[[int, int], PyTree]


def build(cfg: ModelConfig) -> Model:
    if cfg.family == "audio":
        return Model(
            cfg=cfg,
            init=lambda key: encdec.init_params(key, cfg),
            train_loss=lambda p, b, constrain=lambda t, s: t: encdec.train_loss(
                p, b, cfg, constrain=constrain),
            prefill=lambda p, b, constrain=lambda t, s: t, total_slots=None: encdec.prefill(
                p, b, cfg, constrain=constrain, total_slots=total_slots),
            decode_step=lambda p, t, pos, st, constrain=lambda t_, s: t_: encdec.decode_step(
                p, t, pos, st, cfg, constrain=constrain),
            init_decode_state=lambda B, S: encdec.init_decode_state(cfg, B, S),
        )
    return Model(
        cfg=cfg,
        init=lambda key: transformer.init_params(key, cfg),
        train_loss=lambda p, b, constrain=lambda t, s: t: transformer.train_loss(
            p, b, cfg, constrain=constrain),
        prefill=lambda p, b, constrain=lambda t, s: t, total_slots=None: transformer.prefill(
            p, b, cfg, constrain=constrain, total_slots=total_slots),
        decode_step=lambda p, t, pos, st, constrain=lambda t_, s: t_: transformer.decode_step(
            p, t, pos, st, cfg, constrain=constrain),
        init_decode_state=lambda B, S: transformer.init_decode_state(cfg, B, S),
    )


# ---------------------------------------------------------------------------
# input specs / synthetic batches per (arch x shape) cell
# ---------------------------------------------------------------------------

def _text_len(cfg: ModelConfig, seq_len: int) -> int:
    """Text tokens in a cell; multimodal prefixes count toward seq_len."""
    if cfg.frontend_tokens:
        return seq_len - cfg.frontend_tokens
    return seq_len


def batch_shapes(cfg: ModelConfig, shape: ShapeCell, batch: int | None = None) -> Dict[str, Any]:
    """Shapes+dtypes of the data batch for train/prefill cells."""
    B = batch if batch is not None else shape.global_batch
    S = _text_len(cfg, shape.seq_len)
    spec: Dict[str, Any] = {"tokens": ((B, S), jnp.int32)}
    if cfg.family == "audio":
        spec["encoder_frames"] = ((B, cfg.encoder_frames, cfg.d_model), jnp.bfloat16)
    if cfg.frontend_tokens:
        spec["frontend_embeds"] = ((B, cfg.frontend_tokens, cfg.d_model), jnp.bfloat16)
    return spec


def input_specs(cfg: ModelConfig, shape: ShapeCell, batch: int | None = None) -> Dict[str, jax.ShapeDtypeStruct]:
    return {
        k: jax.ShapeDtypeStruct(shp, dt) for k, (shp, dt) in batch_shapes(cfg, shape, batch).items()
    }


def make_batch(key: jax.Array, cfg: ModelConfig, shape: ShapeCell, batch: int | None = None) -> Dict[str, jax.Array]:
    """Synthetic batch matching input_specs (smoke tests / examples)."""
    out = {}
    for name, (shp, dt) in batch_shapes(cfg, shape, batch).items():
        key, sub = jax.random.split(key)
        if dt == jnp.int32:
            out[name] = jax.random.randint(sub, shp, 0, cfg.vocab_size, dt)
        else:
            out[name] = jax.random.normal(sub, shp, jnp.float32).astype(dt)
    return out
