"""Decoder-only LM assembly with pattern-period layer scanning.

Heterogeneous layer patterns (gemma3's 5 local : 1 global, rwkv/hybrid
mixes) conflict with a naive scan-over-layers: a scan body must be static,
but window sizes / mixer types vary per layer. The resolution here: tile the
pattern across num_layers and split the stack into *segments* of repeated
periods —

    gemma3-4b (34L, pattern LLLLLG):  [5 x (L L L L L G)] + [1 x (L L L L)]

Each segment is one lax.scan over its repeat count; the body statically
unrolls the (short) period, so every layer keeps its compile-time window and
the HLO contains no masked-away wasted attention FLOPs and no dual-branch
conditionals. Homogeneous models degenerate to the classic scan (period 1).
Parameters are stacked (repeat, *param) per segment — FSDP-sharded leading
dims all-gather per scan step, which is what the XLA latency-hiding
scheduler overlaps with compute.

The same segment structure drives train, prefill, and decode (caches are
stacked per segment), plus rwkv6 (ssm) and recurrentgemma (hybrid) mixers.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, NamedTuple, Tuple

import jax
import jax.ad_checkpoint
import jax.numpy as jnp

from repro import compat
from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import rwkv6 as rwkv_mod
from repro.models.layers import (
    chunked_softmax_xent,
    dt,
    embed_init,
    embed_lookup,
    logits_from,
    mlp_apply,
    mlp_init,
    rmsnorm,
    rmsnorm_init,
    unembed_init,
)

PyTree = Any
AUX_LOSS_WEIGHT = 0.01


class Segment(NamedTuple):
    repeat: int
    windows: Tuple[int, ...]  # per position in the period
    mixers: Tuple[str, ...]  # "attn" | "rglru" | "rwkv"


def segments(cfg: ModelConfig) -> List[Segment]:
    windows = cfg.layer_windows()
    mixers = cfg.layer_mixers()
    L = cfg.num_layers
    if not cfg.scan_layers:  # fully unrolled: one repeat-1 segment per layer
        return [Segment(1, (windows[i],), (mixers[i],)) for i in range(L)]
    p = max(len(cfg.window_pattern), len(cfg.mixer_pattern))
    k, r = divmod(L, p)
    segs = []
    if k:
        segs.append(Segment(k, windows[:p], mixers[:p]))
    if r:
        segs.append(Segment(1, windows[L - r :], mixers[L - r :]))
    return segs


# ---------------------------------------------------------------------------
# per-layer init/apply
# ---------------------------------------------------------------------------

def _layer_init(key, cfg: ModelConfig, mixer: str) -> PyTree:
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    p: Dict[str, PyTree] = {"ln1": rmsnorm_init(d, cfg), "ln2": rmsnorm_init(d, cfg)}
    if mixer == "attn":
        p["attn"] = attn.attn_init(ks[0], cfg)
    elif mixer == "rglru":
        p["rglru"] = rglru_mod.rglru_init(ks[0], cfg)
    elif mixer == "rwkv":
        p["rwkv"] = rwkv_mod.timemix_init(ks[0], cfg)
    else:
        raise ValueError(mixer)
    if mixer == "rwkv":
        p["cmix"] = rwkv_mod.chanmix_init(ks[1], cfg)
    elif cfg.num_experts:
        p["moe"] = moe_mod.moe_init(ks[1], cfg)
        if cfg.moe_dense_residual:
            p["mlp"] = mlp_init(ks[2], cfg)
    else:
        p["mlp"] = mlp_init(ks[1], cfg)
    return p


class LayerState(NamedTuple):
    """Decode-time state for one layer (exactly one field is 'active')."""

    kv: attn.KVCache | None
    rglru: rglru_mod.RGLRUState | None
    rwkv_tm: rwkv_mod.TimeMixState | None
    cmix_prev: jax.Array | None


def _layer_state_init(cfg: ModelConfig, mixer: str, window: int, B: int, S_ctx: int) -> LayerState:
    cdt = dt(cfg, "compute")
    if mixer == "attn":
        return LayerState(attn.init_cache(cfg, B, S_ctx, window, cdt), None, None, None)
    if mixer == "rglru":
        return LayerState(None, rglru_mod.rglru_state_init(cfg, B, cdt), None, None)
    return LayerState(
        None, None, rwkv_mod.timemix_state_init(cfg, B, cdt), jnp.zeros((B, cfg.d_model), cdt)
    )


def _layer_apply(
    params: PyTree,
    x: jax.Array,
    positions: jax.Array,
    cfg: ModelConfig,
    *,
    mixer: str,
    window: int,
    mode: str,  # "train" | "decode"
    state: LayerState | None,
    cur_pos: jax.Array | None,
    constrain=lambda t, s: t,
) -> tuple[jax.Array, LayerState | None, jax.Array]:
    """Returns (x_out, new_state, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    # B3 (§Perf): pin the norm output to the sequence-sharded layout — else
    # GSPMD hoists the S all-gather above the fp32 norm chain and the norm
    # math runs on full-S replicated-over-model tensors (16x traffic).
    h = constrain(rmsnorm(params["ln1"], x, cfg.norm_eps), "act_embed")
    new_state = state
    if mixer == "attn":
        if mode == "train":
            if state is not None:  # prefill: also build the cache
                out, (k, v) = attn.attn_apply_train(
                    params["attn"], h, positions, cfg, window=window,
                    constrain=constrain, return_kv=True,
                )
                cache = attn.cache_from_prefill(state.kv, k, v, positions, window)
                new_state = state._replace(kv=cache)
            else:
                out = attn.attn_apply_train(
                    params["attn"], h, positions, cfg, window=window, constrain=constrain
                )
        else:
            out, kv = attn.attn_apply_decode(
                params["attn"], h, cur_pos, state.kv, cfg, window=window, constrain=constrain
            )
            new_state = state._replace(kv=kv)
    elif mixer == "rglru":
        st = state.rglru if state is not None else rglru_mod.rglru_state_init(cfg, x.shape[0], x.dtype)
        fn = rglru_mod.rglru_apply_train if mode == "train" else rglru_mod.rglru_apply_decode
        out, st = fn(params["rglru"], h, st, cfg, constrain=constrain)
        new_state = state._replace(rglru=st) if state is not None else None
    else:  # rwkv
        st = state.rwkv_tm if state is not None else rwkv_mod.timemix_state_init(cfg, x.shape[0], x.dtype)
        fn = rwkv_mod.timemix_apply_chunked if mode == "train" else rwkv_mod.timemix_apply_decode
        out, st = fn(params["rwkv"], h, st, cfg, constrain=constrain)
        new_state = state._replace(rwkv_tm=st) if state is not None else None
    # remat policy anchor: saving the mixer output means the backward never
    # re-runs the attention/wkv forward (perf iteration A3, §Perf)
    out = jax.ad_checkpoint.checkpoint_name(out, "mixer_out")
    x = x + out.astype(x.dtype)
    x = constrain(x, "act_embed")

    h = constrain(rmsnorm(params["ln2"], x, cfg.norm_eps), "act_embed")
    if mixer == "rwkv":
        prev = state.cmix_prev if state is not None else jnp.zeros_like(h[:, -1])
        out, prev = rwkv_mod.chanmix_apply(params["cmix"], h, prev, cfg)
        if state is not None:
            new_state = new_state._replace(cmix_prev=prev)
    elif cfg.num_experts:
        moe_out = moe_mod.moe_apply(params["moe"], h, cfg, constrain=constrain)
        out, aux = moe_out.y, moe_out.aux_loss
        if cfg.moe_dense_residual:
            out = out + mlp_apply(params["mlp"], h, cfg, constrain=constrain)
    else:
        out = mlp_apply(params["mlp"], h, cfg, constrain=constrain)
    x = x + out.astype(x.dtype)
    return constrain(x, "act_embed"), new_state, aux


# ---------------------------------------------------------------------------
# whole-model init / apply
# ---------------------------------------------------------------------------

def init_params(key, cfg: ModelConfig) -> PyTree:
    segs = segments(cfg)
    keys = jax.random.split(key, len(segs) + 2)
    params: Dict[str, PyTree] = {"embed": embed_init(keys[0], cfg)}
    if not cfg.tie_embeddings:
        params["unembed"] = unembed_init(keys[1], cfg)
    params["final_norm"] = rmsnorm_init(cfg.d_model, cfg)
    for si, seg in enumerate(segs):
        lkeys = jax.random.split(keys[2 + si], seg.repeat * len(seg.windows)).reshape(
            seg.repeat, len(seg.windows), 2
        )
        rows = []
        for rep in range(seg.repeat):
            row = [
                _layer_init(lkeys[rep, j], cfg, seg.mixers[j]) for j in range(len(seg.windows))
            ]
            # stack period positions into leading axis only if homogeneous;
            # period positions may have different mixers => keep as tuple
            rows.append(tuple(row))
        # stack over repeats: map over period positions
        stacked = tuple(
            jax.tree.map(lambda *xs: jnp.stack(xs), *(rows[r][j] for r in range(seg.repeat)))
            for j in range(len(seg.windows))
        )
        params[f"seg{si}"] = stacked
    return params


def _backbone(
    params: PyTree,
    x: jax.Array,
    positions: jax.Array,
    cfg: ModelConfig,
    *,
    mode: str,
    states: PyTree | None,
    cur_pos: jax.Array | None,
    constrain=lambda t, s: t,
):
    """Runs all segments. states (if given) mirrors the segment structure:
    states[f"seg{si}"] = tuple over period positions of stacked LayerStates."""
    segs = segments(cfg)
    aux_total = jnp.zeros((), jnp.float32)
    new_states: Dict[str, PyTree] = {}

    for si, seg in enumerate(segs):
        seg_params = params[f"seg{si}"]
        seg_state = states[f"seg{si}"] if states is not None else None

        def body(carry, xs, _seg=seg):
            xc, aux_c = carry
            # keep the saved residual stack in the carry's own dtype: without
            # the barrier XLA hoists the rmsnorm f32-convert into the saved
            # buffer, doubling the remat stack (32 GiB on rwkv6 train_4k).
            xc = compat.optimization_barrier(xc)
            layer_params, layer_state = xs
            out_states = []
            for j in range(len(_seg.windows)):
                st_j = layer_state[j] if layer_state is not None else None
                xc, st_j, aux = _layer_apply(
                    layer_params[j],
                    xc,
                    positions,
                    cfg,
                    mixer=_seg.mixers[j],
                    window=_seg.windows[j],
                    mode=mode,
                    state=st_j,
                    cur_pos=cur_pos,
                    constrain=constrain,
                )
                out_states.append(st_j)
            return (xc, aux_c + aux), tuple(out_states) if layer_state is not None else None

        # perf iteration A3 (refuted, §Perf): saving mixer outputs via
        # save_only_these_names cost +0.44 GiB and no traffic win — the
        # backward's own d(attention) passes dominate, not the recompute.
        body_fn = jax.checkpoint(body) if (cfg.remat and mode == "train") else body
        (x, aux_total), seg_new_state = jax.lax.scan(
            body_fn, (x, aux_total), (seg_params, seg_state)
        )
        new_states[f"seg{si}"] = seg_new_state

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return x, (new_states if states is not None else None), aux_total


def init_decode_state(cfg: ModelConfig, B: int, S_ctx: int) -> PyTree:
    """Stacked per-segment decode states (KV caches / recurrent states)."""
    segs = segments(cfg)
    states: Dict[str, PyTree] = {}
    for si, seg in enumerate(segs):
        per_pos = []
        for j in range(len(seg.windows)):
            one = _layer_state_init(cfg, seg.mixers[j], seg.windows[j], B, S_ctx)
            per_pos.append(jax.tree.map(lambda x: jnp.stack([x] * seg.repeat), one))
        states[f"seg{si}"] = tuple(per_pos)
    return states


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------

def _input_embeddings(params, batch: Dict[str, jax.Array], cfg: ModelConfig):
    """Token embeddings, with optional multimodal prefix (stub frontends)."""
    x = embed_lookup(params["embed"], batch["tokens"], cfg)
    if "frontend_embeds" in batch:
        fe = batch["frontend_embeds"].astype(x.dtype) * (cfg.d_model**0.5)
        x = jnp.concatenate([fe, x], axis=1)
    return x


def train_loss(params, batch: Dict[str, jax.Array], cfg: ModelConfig,
               constrain=lambda t, s: t) -> tuple[jax.Array, Dict[str, jax.Array]]:
    """Next-token CE (+ MoE aux). batch: tokens (B,S[,frontend])."""
    x = _input_embeddings(params, batch, cfg)
    x = constrain(x, "act_embed")
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    x, _, aux = _backbone(params, x, positions, cfg, mode="train", states=None,
                          cur_pos=None, constrain=constrain)

    P = x.shape[1] - batch["tokens"].shape[1]  # frontend prefix length
    x_text = x[:, P:, :]
    labels = jnp.pad(batch["tokens"][:, 1:], ((0, 0), (0, 1)))
    mask = batch.get("loss_mask", jnp.ones_like(batch["tokens"], jnp.float32))
    mask = mask.astype(jnp.float32).at[:, -1].set(0.0)
    ce = chunked_softmax_xent(x_text, labels, mask, params["embed"],
                              params.get("unembed"), cfg, constrain=constrain)
    loss = ce + AUX_LOSS_WEIGHT * aux
    return loss, {"ce": ce, "aux": aux}


def prefill(params, batch: Dict[str, jax.Array], cfg: ModelConfig,
            constrain=lambda t, s: t, total_slots: int | None = None):
    """Full-context forward building decode caches; returns (last_logits, states).

    total_slots: KV-cache capacity (>= prefill length + planned decode steps);
    defaults to prefill length + 1.
    """
    x = _input_embeddings(params, batch, cfg)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    states = init_decode_state(cfg, B, total_slots or S + 1)
    x, states, _ = _backbone(params, x, positions, cfg, mode="train", states=states,
                             cur_pos=None, constrain=constrain)
    logits = logits_from(params["embed"], params.get("unembed"), x[:, -1:, :], cfg)
    return logits[:, 0], states


def decode_step(params, tokens: jax.Array, cur_pos: jax.Array, states: PyTree,
                cfg: ModelConfig, constrain=lambda t, s: t):
    """One-token serve step. tokens: (B, 1); cur_pos: scalar absolute position.
    Returns (logits (B, V), new_states)."""
    x = embed_lookup(params["embed"], tokens, cfg)
    B = x.shape[0]
    positions = jnp.broadcast_to(cur_pos[None, None], (B, 1)).astype(jnp.int32)
    x, states, _ = _backbone(params, x, positions, cfg, mode="decode", states=states,
                             cur_pos=cur_pos, constrain=constrain)
    logits = logits_from(params["embed"], params.get("unembed"), x, cfg)
    return constrain(logits[:, 0].astype(jnp.float32), "logits"), states
