"""Fault-tolerant training loop.

Production concerns handled here (scaled down to run anywhere, including the
CPU CI box — the logic is topology-independent):

  * resume: on start, restore the latest checkpoint (params, optimizer state,
    data-iterator state, step counter) if one exists;
  * periodic + final checkpoints, async save overlapping the next step;
  * transient-failure retry: a step that raises is retried after re-syncing
    from the last checkpoint (this is the single-controller analogue of a
    coordinator restarting a failed pod slice);
  * straggler watchdog: per-step wall times feed a running median; a step
    slower than `straggler_factor` x median is logged with the mitigation a
    real deployment takes (flag the slow host for the scheduler; with sync
    SPMD the whole step IS the straggler, so detection is global for free);
  * preemption hook: SIGTERM triggers a final checkpoint before exit.
"""
from __future__ import annotations

import dataclasses
import signal
import statistics
import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager

PyTree = Any


@dataclasses.dataclass
class LoopConfig:
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    keep: int = 3
    max_retries: int = 2
    straggler_factor: float = 3.0
    log_every: int = 10
    async_save: bool = True


class TrainLoop:
    def __init__(self, step_fn: Callable, params: PyTree, opt_state: PyTree,
                 data_iter, loop_cfg: LoopConfig, *,
                 shardings: Optional[tuple] = None):
        self.step_fn = step_fn
        self.params = params
        self.opt_state = opt_state
        self.data = data_iter
        self.cfg = loop_cfg
        self.ckpt = CheckpointManager(loop_cfg.ckpt_dir, keep=loop_cfg.keep)
        self.shardings = shardings  # (param_shardings, opt_shardings) or None
        self.step = 0
        self.step_times: list[float] = []
        self._preempted = False
        try:
            signal.signal(signal.SIGTERM, self._on_sigterm)
        except ValueError:
            pass  # not on the main thread (tests)

    def _on_sigterm(self, *_):
        self._preempted = True

    # ------------------------------------------------------------------
    def try_resume(self) -> bool:
        latest = self.ckpt.latest_step()
        if latest is None:
            return False
        tree = {"params": self.params, "opt": self.opt_state}
        shardings = None
        if self.shardings is not None:
            shardings = {"params": self.shardings[0], "opt": self.shardings[1]}
        restored, extra = self.ckpt.restore(tree, shardings=shardings)
        self.params, self.opt_state = restored["params"], restored["opt"]
        self.step = extra["step"]
        if hasattr(self.data, "restore_state") and "data" in extra:
            self.data.restore_state(extra["data"])
        print(f"[resume] restored step {self.step} from {self.cfg.ckpt_dir}")
        return True

    def _save(self, blocking: bool) -> None:
        extra = {"step": self.step}
        if hasattr(self.data, "checkpoint_state"):
            extra["data"] = self.data.checkpoint_state()
        self.ckpt.save(self.step, {"params": self.params, "opt": self.opt_state},
                       extra=extra, blocking=blocking)

    # ------------------------------------------------------------------
    def run(self, num_steps: int) -> Dict[str, float]:
        self.try_resume()
        metrics: Dict[str, float] = {}
        while self.step < num_steps and not self._preempted:
            batch = self.data.next()
            t0 = time.perf_counter()
            for attempt in range(self.cfg.max_retries + 1):
                try:
                    self.params, self.opt_state, m = self.step_fn(
                        self.params, self.opt_state, batch)
                    jax.block_until_ready(m["loss"])
                    break
                except Exception as e:  # noqa: BLE001 — transient-failure path
                    if attempt == self.cfg.max_retries:
                        self._save(blocking=True)
                        raise
                    print(f"[retry] step {self.step} failed ({type(e).__name__}: {e}); "
                          f"re-syncing from checkpoint (attempt {attempt + 1})")
                    if self.ckpt.latest_step() is not None:
                        self.try_resume()
            dt = time.perf_counter() - t0
            self._watch_stragglers(dt)
            self.step += 1
            metrics = {k: float(np.asarray(v)) for k, v in m.items()}
            if self.cfg.log_every and self.step % self.cfg.log_every == 0:
                print(f"step {self.step:6d} loss {metrics['loss']:.4f} "
                      f"gnorm {metrics.get('grad_norm', float('nan')):.3f} {dt*1e3:.0f} ms")
            if self.cfg.ckpt_every and self.step % self.cfg.ckpt_every == 0:
                self._save(blocking=not self.cfg.async_save)
        self.ckpt.wait()
        self._save(blocking=True)
        return metrics

    def _watch_stragglers(self, dt: float) -> None:
        self.step_times.append(dt)
        window = self.step_times[-50:]
        if len(window) >= 10:
            med = statistics.median(window[:-1])
            if dt > self.cfg.straggler_factor * med:
                print(f"[straggler] step {self.step} took {dt*1e3:.0f} ms "
                      f"(median {med*1e3:.0f} ms) — flagging host for reschedule; "
                      "sync SPMD makes the slowest chip the step time")
