"""Elastic scaling: resume any checkpoint on any mesh.

Checkpoints store full (gathered) arrays; resuming on a different topology is
re-placement, not resharding of shard files: build the step on the NEW mesh,
compute its shardings from the same rules table, and restore with them.
`reshard_for_mesh` is the one-call utility; tests/test_checkpoint.py proves a
2-device-mesh checkpoint resumes bit-exactly on a 4-device mesh and back.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh

from repro.checkpoint.manager import CheckpointManager
from repro.parallel import sharding as shd

PyTree = Any


def reshard_for_mesh(ckpt_dir: str, abstract_params: PyTree, mesh: Mesh,
                     step: int | None = None) -> tuple[PyTree, dict]:
    """Load `ckpt_dir` and place parameters for `mesh` (any device count)."""
    mgr = CheckpointManager(ckpt_dir)
    specs = shd.param_specs(abstract_params, mesh)
    shardings = shd.to_shardings(specs, mesh)
    tree = {"params": abstract_params}
    restored, extra = mgr.restore(tree, step=step, shardings={"params": shardings})
    return restored["params"], extra
