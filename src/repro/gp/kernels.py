"""Kernel protocol + string registry for the `repro.gp` API (GPy-style).

Every kernel is a lightweight stateless object; parameters live in a plain
dict of (log-transformed) arrays so the whole model state stays a pytree the
optimizers and shard_map understand. The protocol every kernel implements:

    init(...)                 -> Params             unconstrained init
    K(params, X, X2=None)     -> (N, N2)            dense covariance
    Kdiag(params, X)          -> (N,)               diagonal of K(X, X)
    exact_suff_stats(...)     -> SuffStats          deterministic-X statistics
    expected_suff_stats(...)  -> SuffStats          statistics under q(X)

Expected (psi) statistics additionally factor through `psi0/psi1/psi2`, which
is what lets `Sum` compose them: psi2 of a sum kernel needs the closed-form
*cross* statistics sum_n <kA(x_n, z_m) kB(x_n, z_m')> between every pair of
parts (GPy's "psicomp" cross terms; implemented here for RBF x Linear and
Linear x Linear). Kernels without closed-form psi statistics (the Materns)
support the exact path and raise `NotImplementedError` from the expected one.

Registry: `get("rbf")(input_dim)` — a string -> class mapping so models,
configs, and serving endpoints can name kernels without importing classes.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Tuple, Type

import jax
import jax.numpy as jnp

from repro.core import psi_stats
from repro.core.psi_stats import SuffStats
from repro.kernels import ref

Params = Dict[str, jax.Array]

# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Type["Kernel"]] = {}


def register(name: str) -> Callable[[Type["Kernel"]], Type["Kernel"]]:
    def deco(cls: Type["Kernel"]) -> Type["Kernel"]:
        cls.name = name
        _REGISTRY[name] = cls
        return cls

    return deco


def get(name: str) -> Type["Kernel"]:
    """Resolve a kernel class by registry name, e.g. get("rbf")(1)."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown kernel {name!r}; available: {sorted(_REGISTRY)}"
        ) from None


def available() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def default_rbf(kernel: "Kernel | None", input_dim: int) -> "Kernel":
    """The shared defaulting rule: no kernel given -> the paper's RBF."""
    return kernel if kernel is not None else RBF(input_dim)


# ---------------------------------------------------------------------------
# protocol / base class
# ---------------------------------------------------------------------------


class Kernel:
    """Base kernel: generic exact statistics via K_fu, psi-statistics abstract.

    `exact_suff_stats` works for ANY kernel that can evaluate K — the paper's
    supervised sparse-GP path only needs K_fu matmuls. The expected path
    needs the kernel-specific closed forms (psi0/psi1/psi2).
    """

    name: str = "kernel"
    input_dim: int

    def init(self, **kwargs) -> Params:
        raise NotImplementedError

    def K(self, params: Params, X: jax.Array, X2: jax.Array | None = None) -> jax.Array:
        raise NotImplementedError

    def Kdiag(self, params: Params, X: jax.Array) -> jax.Array:
        raise NotImplementedError

    def _check_backend(self, backend: str) -> None:
        # loud rather than a silent jnp fallback: only the RBF hot path (and
        # delegating composites like all-RBF Product) have Pallas/fused kernels
        if backend != "jnp":
            raise ValueError(
                f"{type(self).__name__} implements backend='jnp' statistics "
                f"only (got {backend!r}); the Pallas/fused backends exist for "
                f"the RBF kernel"
            )

    # -- exact statistics (deterministic X) ---------------------------------
    def exact_suff_stats(
        self, params: Params, X: jax.Array, Y: jax.Array, Z: jax.Array,
        *, backend: str = "jnp", bwd_backend: str = "auto",
    ) -> SuffStats:
        self._check_backend(backend)
        del bwd_backend  # only the RBF kernel backends have kernelized
        # reverse passes; the generic jnp path differentiates through XLA
        Kfu = self.K(params, X, Z)
        return SuffStats(
            psi0=jnp.sum(self.Kdiag(params, X)),
            psi2=Kfu.T @ Kfu,
            psiY=Kfu.T @ Y,
            yy=jnp.sum(Y * Y),
            n=jnp.asarray(X.shape[0], Kfu.dtype),
        )

    # -- expected statistics under q(X) = prod_n N(mu_n, diag(S_n)) ---------
    def psi0(self, params: Params, mu: jax.Array, S: jax.Array) -> jax.Array:
        raise NotImplementedError(self._no_psi())

    def psi1(self, params: Params, mu: jax.Array, S: jax.Array, Z: jax.Array) -> jax.Array:
        raise NotImplementedError(self._no_psi())

    def psi2(self, params: Params, mu: jax.Array, S: jax.Array, Z: jax.Array) -> jax.Array:
        raise NotImplementedError(self._no_psi())

    def expected_suff_stats(
        self, params: Params, mu: jax.Array, S: jax.Array, Y: jax.Array,
        Z: jax.Array, *, backend: str = "jnp", bwd_backend: str = "auto",
    ) -> SuffStats:
        self._check_backend(backend)
        del bwd_backend  # see exact_suff_stats: jnp path = XLA autodiff
        psi1 = self.psi1(params, mu, S, Z)
        return SuffStats(
            psi0=self.psi0(params, mu, S),
            psi2=self.psi2(params, mu, S, Z),
            psiY=psi1.T @ Y,
            yy=jnp.sum(Y * Y),
            n=jnp.asarray(mu.shape[0], mu.dtype),
        )

    def _no_psi(self) -> str:
        return (
            f"closed-form psi statistics under Gaussian q(X) do not exist for "
            f"the {type(self).__name__!r} kernel; it supports the exact "
            f"(deterministic-X) path only. Use an 'rbf'/'linear' kernel (or a "
            f"Sum/Product of them) for Bayesian GP-LVM models."
        )

    # -- capability queries (what facades dispatch on) -----------------------
    def supports_psi(self) -> bool:
        """True when the closed-form expected (psi) statistics path exists."""
        return type(self).psi0 is not Kernel.psi0

    def supports_sde(self) -> bool:
        """True when `to_sde()` works: the kernel has an exact state-space
        (LTI SDE) form, i.e. the temporal backend can train/serve it."""
        return False

    def to_sde(self, params: Params):
        """The kernel's exact LTI SDE (`repro.temporal.sde.LTISDE`) at the
        given hyperparameters — the hook the temporal backend dispatches
        through, so the string registry keeps working for both backends."""
        raise NotImplementedError(
            f"kernel {type(self).__name__!r} has no state-space (SDE) form; "
            f"backend='temporal' supports 'matern12'/'matern32'/'matern52' "
            f"on 1-D inputs, and Sum/Product compositions of those"
        )


# ---------------------------------------------------------------------------
# leaf kernels
# ---------------------------------------------------------------------------


@register("rbf")
@dataclasses.dataclass(frozen=True)
class RBF(Kernel):
    """RBF (squared exponential) kernel with ARD lengthscales.

    The paper (and GPy) parameterize it as

        k(x, x') = sigma_f^2 * exp(-0.5 * sum_q (x_q - x'_q)^2 / l_q^2)

    stored as unconstrained log-values so gradient-based optimizers (Adam
    here, L-BFGS-B in the paper) work on R^n. Closed-form psi statistics
    under Gaussian q(X) exist, which is why the paper's GP-LVM experiments
    use it; its statistics also have Pallas TPU kernels (backend="pallas":
    kfu/psi1/psi2, each kernelized in BOTH directions — their reverse
    passes specialize the fused op's hand-derived rules) and the fused
    suffstats op (backend="fused": psi2 + psiY in one pass — expected
    statistics, and exact ones via S -> 0). Both kernel backends dispatch
    their reverse-pass implementation on the `bwd_backend` knob (Pallas
    reverse kernel or streaming jnp twin).
    """

    input_dim: int

    def init(self, variance: float = 1.0, lengthscale: float = 1.0) -> Params:
        return {
            "log_variance": jnp.asarray(jnp.log(variance), jnp.float32),
            "log_lengthscale": jnp.full((self.input_dim,), jnp.log(lengthscale), jnp.float32),
        }

    @staticmethod
    def variance(params: Params) -> jax.Array:
        return jnp.exp(params["log_variance"])

    @staticmethod
    def lengthscale(params: Params) -> jax.Array:
        return jnp.exp(params["log_lengthscale"])

    def K(self, params: Params, X: jax.Array, X2: jax.Array | None = None) -> jax.Array:
        ls = self.lengthscale(params)
        Xs = X / ls
        X2s = Xs if X2 is None else X2 / ls
        # squared euclidean distances via the stable (a-b)^2 expansion
        d2 = (
            jnp.sum(Xs**2, -1)[:, None]
            + jnp.sum(X2s**2, -1)[None, :]
            - 2.0 * Xs @ X2s.T
        )
        d2 = jnp.maximum(d2, 0.0)
        return self.variance(params) * jnp.exp(-0.5 * d2)

    def Kdiag(self, params: Params, X: jax.Array) -> jax.Array:
        return jnp.full((X.shape[0],), self.variance(params))

    def exact_suff_stats(self, params, X, Y, Z, *, backend: str = "jnp",
                         bwd_backend: str = "auto") -> SuffStats:
        if backend not in ("jnp", "pallas", "fused"):
            raise ValueError(
                f"RBF exact statistics support backend='jnp'|'pallas'|'fused', "
                f"got {backend!r}"
            )
        return psi_stats.exact_stats_rbf(params, X, Y, Z, backend=backend,
                                         bwd_backend=bwd_backend)

    def psi0(self, params, mu, S) -> jax.Array:
        return ref.psi0_rbf(mu, S, self.variance(params), self.lengthscale(params))

    def psi1(self, params, mu, S, Z) -> jax.Array:
        return ref.psi1_rbf(mu, S, Z, self.variance(params), self.lengthscale(params))

    def psi2(self, params, mu, S, Z) -> jax.Array:
        return psi_stats._psi2_rbf_chunked(
            mu, S, Z, self.variance(params), self.lengthscale(params)
        )

    def expected_suff_stats(self, params, mu, S, Y, Z, *, backend: str = "jnp",
                            bwd_backend: str = "auto") -> SuffStats:
        if backend not in ("jnp", "pallas", "fused"):
            raise ValueError(
                f"RBF expected statistics support backend='jnp'|'pallas'|'fused', "
                f"got {backend!r}"
            )
        return psi_stats.expected_stats_rbf(params, mu, S, Y, Z, backend=backend,
                                            bwd_backend=bwd_backend)


@register("linear")
@dataclasses.dataclass(frozen=True)
class Linear(Kernel):
    """Linear kernel k(x,x') = sum_q a_q x_q x'_q (ARD variances).

    Also admits closed-form psi statistics; used in tests to make sure the
    psi-statistics layer is kernel-generic.
    """

    input_dim: int

    def init(self, variance: float = 1.0) -> Params:
        return {"log_ard": jnp.full((self.input_dim,), jnp.log(variance), jnp.float32)}

    @staticmethod
    def ard(params: Params) -> jax.Array:
        return jnp.exp(params["log_ard"])

    def K(self, params: Params, X: jax.Array, X2: jax.Array | None = None) -> jax.Array:
        a = self.ard(params)
        X2 = X if X2 is None else X2
        return (X * a) @ X2.T

    def Kdiag(self, params: Params, X: jax.Array) -> jax.Array:
        return jnp.sum(self.ard(params) * X * X, -1)

    def psi0(self, params, mu, S) -> jax.Array:
        return ref.psi0_linear(mu, S, self.ard(params))

    def psi1(self, params, mu, S, Z) -> jax.Array:
        return ref.psi1_linear(mu, S, Z, self.ard(params))

    def psi2(self, params, mu, S, Z) -> jax.Array:
        return ref.psi2_linear(mu, S, Z, self.ard(params))


@dataclasses.dataclass(frozen=True)
class _Matern(Kernel):
    """Shared machinery of the Matern family: K is a function of the scaled
    distance r = sqrt(sum_q (x_q - x'_q)^2 / l_q^2). No closed-form psi
    statistics under Gaussian q(X) exist (the expectation of exp(-r) has no
    elementary form), so only the exact path is supported — the base-class
    expected_suff_stats raises cleanly.
    """

    input_dim: int

    def init(self, variance: float = 1.0, lengthscale: float = 1.0) -> Params:
        return {
            "log_variance": jnp.asarray(jnp.log(variance), jnp.float32),
            "log_lengthscale": jnp.full((self.input_dim,), jnp.log(lengthscale), jnp.float32),
        }

    @staticmethod
    def variance(params: Params) -> jax.Array:
        return jnp.exp(params["log_variance"])

    @staticmethod
    def lengthscale(params: Params) -> jax.Array:
        return jnp.exp(params["log_lengthscale"])

    def _r(self, params: Params, X: jax.Array, X2: jax.Array | None) -> jax.Array:
        ls = self.lengthscale(params)
        Xs = X / ls
        X2s = Xs if X2 is None else X2 / ls
        d2 = (
            jnp.sum(Xs**2, -1)[:, None]
            + jnp.sum(X2s**2, -1)[None, :]
            - 2.0 * Xs @ X2s.T
        )
        # sqrt has an infinite derivative at 0: clamp from below (the value
        # error is ~1e-9, far under kernel noise floors)
        return jnp.sqrt(jnp.maximum(d2, 1e-18))

    def _shape_fn(self, r: jax.Array) -> jax.Array:
        raise NotImplementedError

    def K(self, params: Params, X: jax.Array, X2: jax.Array | None = None) -> jax.Array:
        return self.variance(params) * self._shape_fn(self._r(params, X, X2))

    def Kdiag(self, params: Params, X: jax.Array) -> jax.Array:
        return jnp.full((X.shape[0],), self.variance(params))

    def _no_psi(self) -> str:
        return (
            f"closed-form psi statistics under Gaussian q(X) do not exist for "
            f"the {type(self).__name__!r} kernel (the expectation of exp(-r) "
            f"has no elementary form), so the collapsed-bound expected path "
            f"cannot use it. On 1-D inputs the Matern family has an exact "
            f"O(N) state-space path instead: use backend='temporal' "
            f"(repro.gp.regression(kernel, backend='temporal') / "
            f"repro.gp.TemporalGPRegression)."
        )

    def supports_sde(self) -> bool:
        # the kernel -> SDE duality is a property of STATIONARY 1-D priors
        return self.input_dim == 1

    def to_sde(self, params: Params):
        if self.input_dim != 1:
            raise NotImplementedError(
                f"{type(self).__name__} with input_dim={self.input_dim} has "
                f"no state-space form; the kernel -> LTI SDE duality is 1-D "
                f"(temporal). Use input_dim=1 for backend='temporal'."
            )
        from repro.temporal import sde as _sde  # lazy: avoid import cycle

        builder = getattr(_sde, f"{self.name}_sde")
        return builder(self.variance(params), self.lengthscale(params))


@register("matern12")
@dataclasses.dataclass(frozen=True)
class Matern12(_Matern):
    """Matern nu=1/2 (exponential / Ornstein-Uhlenbeck) kernel."""

    def _shape_fn(self, r: jax.Array) -> jax.Array:
        return jnp.exp(-r)


@register("matern32")
@dataclasses.dataclass(frozen=True)
class Matern32(_Matern):
    """Matern nu=3/2 kernel."""

    def _shape_fn(self, r: jax.Array) -> jax.Array:
        s = jnp.sqrt(3.0) * r
        return (1.0 + s) * jnp.exp(-s)


@register("matern52")
@dataclasses.dataclass(frozen=True)
class Matern52(_Matern):
    """Matern nu=5/2 kernel."""

    def _shape_fn(self, r: jax.Array) -> jax.Array:
        s = jnp.sqrt(5.0) * r
        return (1.0 + s + s**2 / 3.0) * jnp.exp(-s)


# ---------------------------------------------------------------------------
# cross psi-2 statistics between heterogeneous parts (for Sum)
# ---------------------------------------------------------------------------


def _cross_psi2_rbf_linear(
    rbf: RBF, p_rbf: Params, lin: Linear, p_lin: Params,
    mu: jax.Array, S: jax.Array, Z: jax.Array,
) -> jax.Array:
    """C[m, m'] = sum_n <k_rbf(x_n, z_m) k_lin(x_n, z_m')>_{q(x_n)}.

    Writing k_rbf(x, z) prop N(x | z, diag(l^2)), the product q(x_n) k_rbf
    is an unnormalized Gaussian with mass Psi1[n, m] and mean

        c[n, m, q] = (mu_nq l_q^2 + z_mq S_nq) / (l_q^2 + S_nq),

    so <k_rbf(x, z_m) sum_q a_q x_q z'_q> = Psi1[n, m] * (a * c[n, m]) . z'.
    (GPy's RBF x Linear psicomp cross term.)
    """
    l2 = rbf.lengthscale(p_rbf) ** 2  # (Q,)
    a = lin.ard(p_lin)  # (Q,)
    psi1 = ref.psi1_rbf(mu, S, Z, rbf.variance(p_rbf), rbf.lengthscale(p_rbf))  # (N, M)
    # tilted-Gaussian mean per (n, m, q)
    c = (mu[:, None, :] * l2[None, None, :] + Z[None, :, :] * S[:, None, :]) / (
        l2[None, None, :] + S[:, None, :]
    )
    return jnp.einsum("nm,nmq,kq->mk", psi1, c, Z * a)


def _cross_psi2_linear_linear(
    ka: Linear, pa: Params, kb: Linear, pb: Params,
    mu: jax.Array, S: jax.Array, Z: jax.Array,
) -> jax.Array:
    """C[m, m'] = (z_m * a1)^T [sum_n (mu_n mu_n^T + diag(S_n))] (z_m' * a2)."""
    moment = (mu.T @ mu) + jnp.diag(jnp.sum(S, axis=0))  # (Q, Q)
    return (Z * ka.ard(pa)) @ moment @ (Z * kb.ard(pb)).T


def _cross_psi2(ka: Kernel, pa: Params, kb: Kernel, pb: Params, mu, S, Z) -> jax.Array:
    """Dispatch the closed-form cross term; transpose handles argument order."""
    if isinstance(ka, RBF) and isinstance(kb, Linear):
        return _cross_psi2_rbf_linear(ka, pa, kb, pb, mu, S, Z)
    if isinstance(ka, Linear) and isinstance(kb, RBF):
        return _cross_psi2_rbf_linear(kb, pb, ka, pa, mu, S, Z).T
    if isinstance(ka, Linear) and isinstance(kb, Linear):
        return _cross_psi2_linear_linear(ka, pa, kb, pb, mu, S, Z)
    raise NotImplementedError(
        f"no closed-form cross psi2 statistics between "
        f"{type(ka).__name__} and {type(kb).__name__} (GPy implements "
        f"RBF x Linear; use the exact path or those part types)"
    )


def _has_cross_psi2(ka: Kernel, kb: Kernel) -> bool:
    """Mirror of `_cross_psi2`'s dispatch table, for capability queries."""
    return (isinstance(ka, RBF) and isinstance(kb, Linear)) or (
        isinstance(ka, Linear) and isinstance(kb, (RBF, Linear)))


# ---------------------------------------------------------------------------
# composite kernels
# ---------------------------------------------------------------------------


class _Composite(Kernel):
    """Shared plumbing: parts act on the same inputs, params nest as k0/k1/..."""

    def __init__(self, *parts: Kernel):
        if len(parts) < 2:
            raise ValueError(f"{type(self).__name__} needs >= 2 parts")
        dims = {p.input_dim for p in parts}
        if len(dims) != 1:
            raise ValueError(f"parts disagree on input_dim: {sorted(dims)}")
        self.parts: Tuple[Kernel, ...] = tuple(parts)
        self.input_dim = parts[0].input_dim

    def init(self, **kwargs) -> Params:
        """Per-part init kwargs, addressed by slot: ``init(k0={"variance": 2.0})``
        forwards to ``parts[0].init(variance=2.0)``. Unknown slots raise
        instead of being silently dropped (leaf kernels honor their kwargs,
        so composites must not eat them)."""
        slots = [f"k{i}" for i in range(len(self.parts))]
        unknown = sorted(set(kwargs) - set(slots))
        if unknown:
            raise TypeError(
                f"{type(self).__name__}.init() takes per-part kwargs keyed by "
                f"slot ({', '.join(slots)}), each a dict of that part's init "
                f"kwargs; got unknown key(s) {unknown}"
            )
        out = {}
        for slot, part in zip(slots, self.parts):
            part_kwargs = kwargs.get(slot, {})
            if not isinstance(part_kwargs, dict):
                raise TypeError(
                    f"{type(self).__name__}.init({slot}=...) must be a dict of "
                    f"{type(part).__name__}.init kwargs, got "
                    f"{type(part_kwargs).__name__}"
                )
            out[slot] = part.init(**part_kwargs)
        return out

    def _split(self, params: Params):
        return [(p, params[f"k{i}"]) for i, p in enumerate(self.parts)]

    def __repr__(self) -> str:
        return f"{type(self).__name__}({', '.join(map(repr, self.parts))})"


@register("sum")
class Sum(_Composite):
    """k = sum_i k_i. Exact statistics come generically from K; expected
    statistics compose part psi stats plus pairwise closed-form cross terms.
    """

    def K(self, params: Params, X: jax.Array, X2: jax.Array | None = None) -> jax.Array:
        return sum(p.K(pp, X, X2) for p, pp in self._split(params))

    def Kdiag(self, params: Params, X: jax.Array) -> jax.Array:
        return sum(p.Kdiag(pp, X) for p, pp in self._split(params))

    def psi0(self, params, mu, S) -> jax.Array:
        return sum(p.psi0(pp, mu, S) for p, pp in self._split(params))

    def psi1(self, params, mu, S, Z) -> jax.Array:
        return sum(p.psi1(pp, mu, S, Z) for p, pp in self._split(params))

    def psi2(self, params, mu, S, Z) -> jax.Array:
        pairs = self._split(params)
        total = sum(p.psi2(pp, mu, S, Z) for p, pp in pairs)
        for i, (pa, ppa) in enumerate(pairs):
            for pb, ppb in pairs[i + 1 :]:
                cross = _cross_psi2(pa, ppa, pb, ppb, mu, S, Z)
                total = total + cross + cross.T
        return total

    def supports_psi(self) -> bool:
        # a sum needs every part's psi stats AND every pairwise cross term
        return all(p.supports_psi() for p in self.parts) and all(
            _has_cross_psi2(pa, pb)
            for i, pa in enumerate(self.parts) for pb in self.parts[i + 1:])

    def supports_sde(self) -> bool:
        return all(p.supports_sde() for p in self.parts)

    def to_sde(self, params: Params):
        from repro.temporal import sde as _sde  # lazy: avoid import cycle

        return _sde.sum_sde(*[p.to_sde(pp) for p, pp in self._split(params)])


@register("product")
class Product(_Composite):
    """k = prod_i k_i. Exact statistics are generic (K_fu is an elementwise
    product). Expected statistics exist in closed form only when every part
    is an RBF: a product of RBFs is itself an RBF with variance prod sigma_i^2
    and lengthscales (sum_i l_i^-2)^(-1/2) — delegate to that kernel.
    """

    def K(self, params: Params, X: jax.Array, X2: jax.Array | None = None) -> jax.Array:
        out = None
        for p, pp in self._split(params):
            k = p.K(pp, X, X2)
            out = k if out is None else out * k
        return out

    def Kdiag(self, params: Params, X: jax.Array) -> jax.Array:
        out = None
        for p, pp in self._split(params):
            k = p.Kdiag(pp, X)
            out = k if out is None else out * k
        return out

    def _equivalent_rbf(self, params: Params) -> tuple[RBF, Params]:
        pairs = self._split(params)
        if not all(isinstance(p, RBF) for p, _ in pairs):
            raise NotImplementedError(
                "Product psi statistics exist in closed form only for "
                "all-RBF parts (the product is then itself an RBF); "
                f"got {[type(p).__name__ for p, _ in pairs]}"
            )
        log_var = sum(pp["log_variance"] for _, pp in pairs)
        inv_l2 = sum(jnp.exp(-2.0 * pp["log_lengthscale"]) for _, pp in pairs)
        eq_params = {"log_variance": log_var, "log_lengthscale": -0.5 * jnp.log(inv_l2)}
        return RBF(self.input_dim), eq_params

    def psi0(self, params, mu, S) -> jax.Array:
        k, p = self._equivalent_rbf(params)
        return k.psi0(p, mu, S)

    def psi1(self, params, mu, S, Z) -> jax.Array:
        k, p = self._equivalent_rbf(params)
        return k.psi1(p, mu, S, Z)

    def psi2(self, params, mu, S, Z) -> jax.Array:
        k, p = self._equivalent_rbf(params)
        return k.psi2(p, mu, S, Z)

    def expected_suff_stats(self, params, mu, S, Y, Z, *, backend: str = "jnp",
                            bwd_backend: str = "auto") -> SuffStats:
        k, p = self._equivalent_rbf(params)
        return k.expected_suff_stats(p, mu, S, Y, Z, backend=backend,
                                     bwd_backend=bwd_backend)

    def supports_psi(self) -> bool:
        # closed form only when the product is itself an RBF (all-RBF parts)
        return all(isinstance(p, RBF) for p in self.parts)

    def supports_sde(self) -> bool:
        return all(p.supports_sde() for p in self.parts)

    def to_sde(self, params: Params):
        from repro.temporal import sde as _sde  # lazy: avoid import cycle

        return _sde.product_sde(
            *[p.to_sde(pp) for p, pp in self._split(params)])


# ---------------------------------------------------------------------------
# registry-level capability query
# ---------------------------------------------------------------------------


def capabilities(kernel: "Kernel | str", input_dim: int = 1) -> Dict[str, bool]:
    """What inference paths a kernel supports, for fail-fast facade dispatch.

    Accepts a kernel instance or a registry name (instantiated at
    `input_dim`, which matters: e.g. Materns are SDE-capable only in 1-D).
    Keys: "exact" (collapsed bound, deterministic X — always true), "psi"
    (collapsed bound under Gaussian q(X)), "sde" (backend="temporal").
    """
    if isinstance(kernel, str):
        kernel = get(kernel)(input_dim)
    return {
        "exact": True,
        "psi": kernel.supports_psi(),
        "sde": kernel.supports_sde(),
    }
