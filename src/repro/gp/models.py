"""GPy-style model facades over the distributed collapsed bound.

    gp = SparseGPRegression(kernel=get("rbf")(1), M=32, mesh=make_gp_mesh())
    gp.fit(X, Y, optimizer="adam", steps=300)
    mean, var = gp.predict(Xt)

The facades own exactly the wiring `examples/quickstart.py` used to hand-roll:
parameter init, the (optionally distributed) loss, the optimizer driver, and
the posterior/prediction epilogue. The math stays where it was — svgp.py for
the bound, the kernel objects for statistics, core.distributed for the
shard_map+psum decomposition — so the facade path and the hand-wired path
produce bit-identical losses.

`mesh=` selects the paper's data-parallel path (shard_map over the data axes,
one psum of the sufficient statistics); `backend=` routes the statistics
through Pallas TPU kernels ("pallas") or the fused suffstats op ("fused" —
expected statistics for the GP-LVM, exact ones for regression via S -> 0);
`bwd_backend=` picks the reverse-pass implementation of the kernelized
backends — the fused op and the single-statistic pallas ops all backward
through hand-derived Pallas reverse kernels or their streaming jnp twins
("auto" dispatches like the forward); `chunk=` streams the statistics over
N in chunks of that size (or `chunk="auto"`, sized by the `repro.tune`
autotuner) so
training AND prediction peak at O(chunk * M + M^2) memory regardless of N.
All of these come from the constructor so serving/config code can pick them
by string/int without touching model internals. See docs/api.md for the
full public surface and docs/architecture.md for how the layers fit.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core import distributed, gplvm, inference, svgp
from repro.gp.kernels import Kernel, RBF, default_rbf
from repro.gp.stats import ExactBatch, suff_stats

Params = Dict[str, jax.Array]

_OPTIMIZERS = ("adam", "lbfgs")


def _as_2d(Y: jax.Array) -> jax.Array:
    return Y[:, None] if Y.ndim == 1 else Y


def _pick_inducing(X: jax.Array, M: int) -> jax.Array:
    """Every (N // M)-th datapoint — the quickstart's deterministic subset."""
    N = X.shape[0]
    if M >= N:
        return X
    return X[:: max(N // M, 1)][:M]


class _CollapsedGPModel:
    """Shared facade plumbing: kernel/mesh/backend/chunk state + optimizer
    driver + the (possibly distributed, possibly streaming) posterior
    statistics pass."""

    def __init__(self, kernel: Optional[Kernel], M: int, *,
                 mesh: Optional[Mesh] = None, backend: str = "jnp",
                 chunk: Optional[Union[int, str]] = None,
                 bwd_backend: str = "auto"):
        self.kernel = kernel
        self.M = int(M)
        self.mesh = mesh
        self.backend = backend
        self.bwd_backend = bwd_backend
        # chunk: None (one shot), a positive int, or "auto" (resolved by the
        # repro.tune autotuner inside gp.stats.streaming_suff_stats)
        if chunk is None or chunk == "auto":
            self.chunk = chunk
        elif isinstance(chunk, str):
            raise ValueError(
                f'chunk must be None, a positive int or "auto", got {chunk!r}')
        else:
            self.chunk = int(chunk)
        self.params: Optional[Params] = None
        self.history: list = []
        self._loss_cache = None  # (kernel, built_loss): rebuilt if kernel changes
        self._stats_cache = None  # (kernel, built_stats_fn)
        self._posterior_cache: Optional[svgp.Posterior] = None  # cleared by fit
        self._stats_value_cache = None  # fitted-data SuffStats, cleared by fit

    # -- subclass hooks ----------------------------------------------------
    def _build_loss(self):
        raise NotImplementedError

    def _build_stats(self):
        raise NotImplementedError

    def _loss_fn(self):
        """Build the (possibly shard_map'd) loss once per kernel — repeated
        elbo()/fit() calls reuse the same closure so jit caching holds."""
        if self._loss_cache is None or self._loss_cache[0] is not self.kernel:
            self._loss_cache = (self.kernel, self._build_loss())
        return self._loss_cache[1]

    def _stats_fn(self):
        """The posterior/predict-time statistics pass, built once per kernel.
        With `mesh=` it shard_maps + psums like the training losses (the
        ROADMAP's distributed-prediction item); with `chunk=` it streams."""
        if self._stats_cache is None or self._stats_cache[0] is not self.kernel:
            self._stats_cache = (self.kernel, jax.jit(self._build_stats()))
        return self._stats_cache[1]

    def _require_fitted(self):
        if self.params is None:
            raise RuntimeError(f"{type(self).__name__} is not fitted yet — call .fit() first")

    def _optimize(self, loss_fn, params: Params, data: tuple, *, optimizer: str,
                  steps: int, lr: float, log_every: int) -> Params:
        self._posterior_cache = None
        self._stats_value_cache = None
        if optimizer == "adam":
            params, self.history = inference.fit_adam(
                loss_fn, params, data, steps=steps, lr=lr, log_every=log_every)
        elif optimizer == "lbfgs":
            params, final = inference.fit_lbfgs(loss_fn, params, data, maxiter=steps)
            self.history = [final]
        else:
            raise ValueError(f"optimizer must be one of {_OPTIMIZERS}, got {optimizer!r}")
        return params

    def _fitted_stats(self):
        """SuffStats of the fitted data at the fitted params, computed once
        per fit (the O(N M^2) pass) and shared by `posterior()` and
        `export_state()`. Invalidated by `fit()`."""
        self._require_fitted()
        if self._stats_value_cache is None:
            self._stats_value_cache = self._stats_fn()(self.params, *self._data)
        return self._stats_value_cache

    def posterior(self) -> svgp.Posterior:
        """Optimal q(u) implied by the collapsed bound at the fitted params.
        Cached: the O(N M^2) statistics pass and the O(M^3) factorization
        run once per fit, not per predict call — sharded over the mesh
        and/or streamed by `chunk=`, exactly like the training losses."""
        self._require_fitted()
        if self._posterior_cache is not None:
            return self._posterior_cache
        p = self.params
        beta = jnp.exp(p["log_beta"])
        factors = svgp.posterior_factors(self.kernel.K(p["kern"], p["Z"]),
                                         self._fitted_stats(), beta)
        self._posterior_cache = svgp.optimal_qu(factors, beta)
        return self._posterior_cache

    def export_state(self):
        """Freeze the fitted model into a `repro.serve.PosteriorState`: the
        Cholesky factors, woodbury vector, hyperparameters, and the raw
        `SuffStats` monoid — everything `repro.serve` needs to predict in
        O(M B + M^2 B) and to absorb new data without the training set."""
        from repro.serve.state import build_state

        self._require_fitted()
        return build_state(self.kernel, self.params, self._fitted_stats())

    def elbo(self) -> float:
        """Evidence lower bound (total, not per-datapoint) on the training data."""
        self._require_fitted()
        loss = self._loss_fn()
        n = self._data[0].shape[0]
        return float(-loss(self.params, *self._data) * n)


class SparseGPRegression(_CollapsedGPModel):
    """Sparse GP regression on the collapsed (Titsias) bound, paper eq. (2)-(3).

    Args:
      kernel: any `repro.gp.kernels.Kernel`; default RBF (inferred input dim).
      M: number of inducing points (initialized as a subset of X).
      mesh: optional jax Mesh — statistics shard over its data axes and merge
        with one psum (the paper's MPI scheme); None = single-device math.
      backend: "jnp" | "pallas" | "fused" statistics path ("fused" rides the
        fused suffstats kernel with S -> 0, so the supervised hot path is
        one kernelized pass over N in both directions of differentiation).
      chunk: stream the O(N) statistics in chunks of this size (training and
        prediction both peak at O(chunk * M + M^2) memory); None = one shot.
      bwd_backend: "auto" | "pallas" | "jnp" — reverse-pass implementation
        of the kernelized backends ("pallas" and "fused"; ignored by "jnp").
    """

    def __init__(self, kernel: Optional[Kernel] = None, M: int = 32, *,
                 mesh: Optional[Mesh] = None, backend: str = "jnp",
                 chunk: Optional[Union[int, str]] = None,
                 bwd_backend: str = "auto"):
        super().__init__(kernel, M, mesh=mesh, backend=backend, chunk=chunk,
                         bwd_backend=bwd_backend)
        self._data: Optional[Tuple[jax.Array, jax.Array]] = None

    def _build_loss(self):
        if self.mesh is not None:
            return distributed.sgpr_loss_dist(self.mesh, kernel=self.kernel,
                                              backend=self.backend,
                                              chunk=self.chunk,
                                              bwd_backend=self.bwd_backend)
        kernel, backend, chunk = self.kernel, self.backend, self.chunk
        bwd_backend = self.bwd_backend

        def loss(params: Params, X: jax.Array, Y: jax.Array) -> jax.Array:
            kern = default_rbf(kernel, params["Z"].shape[1])
            stats = suff_stats(kern, params["kern"],
                               ExactBatch(X, Y, params["Z"]), backend=backend,
                               chunk=chunk, bwd_backend=bwd_backend)
            Kuu = kern.K(params["kern"], params["Z"])
            terms = svgp.collapsed_bound(Kuu, stats, jnp.exp(params["log_beta"]),
                                         Y.shape[1])
            return -terms.bound / stats.n

        return loss

    def _build_stats(self):
        if self.mesh is not None:
            return distributed.sgpr_stats_dist(self.mesh, kernel=self.kernel,
                                               backend=self.backend,
                                               chunk=self.chunk,
                                               bwd_backend=self.bwd_backend)
        kernel, backend, chunk = self.kernel, self.backend, self.chunk
        bwd_backend = self.bwd_backend

        def stats_fn(params: Params, X: jax.Array, Y: jax.Array):
            kern = default_rbf(kernel, params["Z"].shape[1])
            return suff_stats(kern, params["kern"],
                              ExactBatch(X, Y, params["Z"]), backend=backend,
                              chunk=chunk, bwd_backend=bwd_backend)

        return stats_fn

    def init_params(self, X: jax.Array, Y: jax.Array, *,
                    log_beta: float = 2.0) -> Params:
        if self.kernel is None:
            self.kernel = RBF(X.shape[1])
        return {
            "kern": self.kernel.init(),
            "Z": _pick_inducing(X, self.M),
            "log_beta": jnp.asarray(log_beta, X.dtype),
        }

    def fit(self, X: jax.Array, Y: jax.Array, *, optimizer: str = "adam",
            steps: int = 300, lr: float = 3e-2, log_every: int = 0,
            params: Optional[Params] = None) -> "SparseGPRegression":
        Y = _as_2d(Y)
        if params is None:
            params = self.init_params(X, Y)
        elif self.kernel is None:
            self.kernel = RBF(params["Z"].shape[1])
        self._data = (X, Y)
        self.params = self._optimize(self._loss_fn(), params, (X, Y),
                                     optimizer=optimizer, steps=steps, lr=lr,
                                     log_every=log_every)
        return self

    def predict(self, Xt: jax.Array) -> Tuple[jax.Array, jax.Array]:
        """Posterior mean (N*, D) and marginal variance (N*,) of f at Xt."""
        self._require_fitted()
        p = self.params
        post = self.posterior()
        return svgp.predict_f(post, self.kernel.K(p["kern"], Xt, p["Z"]),
                              self.kernel.Kdiag(p["kern"], Xt))


class BayesianGPLVM(_CollapsedGPModel):
    """Bayesian GP-LVM (paper eq. (4)): latent X with factorized Gaussian q(X).

    Args:
      kernel: kernel with closed-form psi statistics (RBF/Linear or their
        Sum/Product composites); default RBF(Q).
      Q: latent dimensionality.
      M: number of inducing points.
      mesh / backend / chunk / bwd_backend: as for SparseGPRegression;
        backend="fused" is the fused suffstats op (one pass over N producing
        psi2/psiY together), backend="pallas" the single-statistic
        psi1/psi2 kernels — both differentiable via the hand-derived
        reverse passes, kernelized when bwd_backend is "auto"/"pallas".
    """

    def __init__(self, kernel: Optional[Kernel] = None, M: int = 100,
                 Q: Optional[int] = None, *,
                 mesh: Optional[Mesh] = None, backend: str = "jnp",
                 chunk: Optional[Union[int, str]] = None,
                 bwd_backend: str = "auto"):
        super().__init__(kernel, M, mesh=mesh, backend=backend, chunk=chunk,
                         bwd_backend=bwd_backend)
        if kernel is not None and Q is not None and Q != kernel.input_dim:
            raise ValueError(
                f"Q={Q} conflicts with kernel.input_dim={kernel.input_dim}; "
                f"pass one or make them agree"
            )
        self.Q = kernel.input_dim if kernel is not None else (Q if Q is not None else 1)
        self._data: Optional[Tuple[jax.Array]] = None

    def _build_loss(self):
        if self.mesh is not None:
            return distributed.gplvm_loss_dist(self.mesh, kernel=self.kernel,
                                               backend=self.backend,
                                               chunk=self.chunk,
                                               bwd_backend=self.bwd_backend)
        return functools.partial(gplvm.loss, kernel=self.kernel,
                                 backend=self.backend, chunk=self.chunk,
                                 bwd_backend=self.bwd_backend)

    def _build_stats(self):
        if self.mesh is not None:
            return distributed.gplvm_stats_dist(self.mesh, kernel=self.kernel,
                                                backend=self.backend,
                                                chunk=self.chunk,
                                                bwd_backend=self.bwd_backend)
        return functools.partial(gplvm.local_stats, kernel=self.kernel,
                                 backend=self.backend, chunk=self.chunk,
                                 bwd_backend=self.bwd_backend)

    def fit(self, Y: jax.Array, *, optimizer: str = "adam", steps: int = 400,
            lr: float = 2e-2, log_every: int = 0,
            init_X: Optional[jax.Array] = None,
            key: Optional[jax.Array] = None,
            params: Optional[Params] = None) -> "BayesianGPLVM":
        Y = _as_2d(Y)
        if self.kernel is None:
            self.kernel = RBF(self.Q)
        if params is None:
            params = gplvm.init_params(key if key is not None else jax.random.PRNGKey(0),
                                       np.asarray(Y), self.Q, self.M,
                                       init_X=init_X, kernel=self.kernel)
        if self.mesh is not None:
            params = distributed.shard_gp_params(params, self.mesh)
        self._data = (Y,)
        self.params = self._optimize(self._loss_fn(), params, (Y,),
                                     optimizer=optimizer, steps=steps, lr=lr,
                                     log_every=log_every)
        return self

    def latent(self) -> Tuple[jax.Array, jax.Array]:
        """Variational posterior over the latents: (q_mu, q_S)."""
        self._require_fitted()
        return self.params["q_mu"], jnp.exp(self.params["q_logS"])

    def predict(self, Xstar: jax.Array) -> Tuple[jax.Array, jax.Array]:
        """Decode latent coordinates Xstar to data space: mean (N*, D), var (N*,)."""
        self._require_fitted()
        p = self.params
        post = self.posterior()
        return svgp.predict_f(post, self.kernel.K(p["kern"], Xstar, p["Z"]),
                              self.kernel.Kdiag(p["kern"], Xstar))


# ---------------------------------------------------------------------------
# backend dispatch
# ---------------------------------------------------------------------------

_BACKENDS = ("collapsed", "temporal")


def regression(kernel: Optional[Kernel] = None, *, backend: str = "collapsed",
               **kwargs):
    """GP regression facade picked by compute backend.

    backend="collapsed" (default) -> `SparseGPRegression`: the paper's
    distributed collapsed bound, any kernel/input_dim, O(N M^2) via
    inducing points; kwargs = (M, mesh, backend, chunk, bwd_backend) —
    note the statistics-path knob is the SparseGPRegression constructor's
    own `backend=`, spelled `stats_backend=` here to avoid clashing.

    backend="temporal" -> `repro.temporal.TemporalGPRegression`: exact
    state-space inference for 1-D stationary kernels (Matern family and
    Sum/Product of it — `kernel.supports_sde()`), O(N) with a parallel
    associative-scan path; kwargs = (parallel,).

    Fails fast with the capability error of the chosen backend (e.g. an
    RBF kernel under backend="temporal", or psi-less Materns in a GP-LVM).
    """
    if backend == "collapsed":
        if "stats_backend" in kwargs:
            kwargs["backend"] = kwargs.pop("stats_backend")
        return SparseGPRegression(kernel, **kwargs)
    if backend == "temporal":
        from repro.temporal import TemporalGPRegression

        return TemporalGPRegression(kernel, **kwargs)
    raise ValueError(f"backend must be one of {_BACKENDS}, got {backend!r}")
