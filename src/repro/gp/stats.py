"""Kernel-dispatched sufficient statistics for the collapsed bound.

One entry point, `suff_stats(kernel, params, batch, backend=...)`, replaces
the RBF-only free functions (`psi_stats.exact_stats_rbf` / `expected_stats_rbf`)
at every call site: the batch type selects exact (deterministic X) vs
expected (Gaussian q(X)) statistics, the kernel object supplies the math,
and `backend` routes the hot path through Pallas kernels ("pallas"), the
fused streaming-jnp pass ("fused", RBF expected only) or plain jnp.

The returned `SuffStats` is the same commutative monoid as before — callers
psum/combine it identically regardless of kernel or backend.
"""
from __future__ import annotations

from typing import NamedTuple, Union

import jax

from repro.core.psi_stats import SuffStats
from repro.gp.kernels import Kernel, Params


class ExactBatch(NamedTuple):
    """Supervised sparse-GP data: deterministic inputs X."""

    X: jax.Array  # (N, Q)
    Y: jax.Array  # (N, D)
    Z: jax.Array  # (M, Q)


class ExpectedBatch(NamedTuple):
    """Bayesian GP-LVM data: Gaussian q(X) = prod_n N(mu_n, diag(S_n))."""

    mu: jax.Array  # (N, Q)
    S: jax.Array  # (N, Q)
    Y: jax.Array  # (N, D)
    Z: jax.Array  # (M, Q)


Batch = Union[ExactBatch, ExpectedBatch]


def suff_stats(kernel: Kernel, params: Params, batch: Batch, *,
               backend: str = "jnp") -> SuffStats:
    """Sufficient statistics of `batch` under `kernel`, kernel-dispatched."""
    if isinstance(batch, ExactBatch):
        return kernel.exact_suff_stats(params, batch.X, batch.Y, batch.Z, backend=backend)
    if isinstance(batch, ExpectedBatch):
        return kernel.expected_suff_stats(
            params, batch.mu, batch.S, batch.Y, batch.Z, backend=backend
        )
    raise TypeError(f"expected ExactBatch or ExpectedBatch, got {type(batch).__name__}")
