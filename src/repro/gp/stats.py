"""Kernel-dispatched sufficient statistics for the collapsed bound.

One entry point, `suff_stats(kernel, params, batch, backend=..., chunk=...)`,
replaces the RBF-only free functions (`psi_stats.exact_stats_rbf` /
`expected_stats_rbf`) at every call site: the batch type selects exact
(deterministic X) vs expected (Gaussian q(X)) statistics, the kernel object
supplies the math, and `backend` routes the hot path through the
single-statistic Pallas kernels ("pallas"), the fused suffstats op ("fused")
or plain jnp — both kernel backends are differentiable through hand-derived
reverse kernels selected by `bwd_backend`.

`chunk=` turns every path into a streaming reduction: the N datapoints are
scanned in chunks of that size and the per-chunk `SuffStats` are combined
through the monoid, so peak live memory is O(chunk * M + M^2) regardless of
N — training included, because the scan body is rematerialized
(`jax.checkpoint`) and the accumulator is linear in the carry, which lets
reverse-mode recompute each chunk instead of stacking residuals. This is
what makes the paper's "millions of datapoints" literal on one host; it
composes with the mesh path (per-shard scan, then one psum).

The returned `SuffStats` is the same commutative monoid as before — callers
psum/combine it identically regardless of kernel, backend or chunking.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Union

import jax
import jax.numpy as jnp

from repro.core.psi_stats import SuffStats
from repro.gp.kernels import Kernel, Params


class ExactBatch(NamedTuple):
    """Supervised sparse-GP data: deterministic inputs X."""

    X: jax.Array  # (N, Q)
    Y: jax.Array  # (N, D)
    Z: jax.Array  # (M, Q)


class ExpectedBatch(NamedTuple):
    """Bayesian GP-LVM data: Gaussian q(X) = prod_n N(mu_n, diag(S_n))."""

    mu: jax.Array  # (N, Q)
    S: jax.Array  # (N, Q)
    Y: jax.Array  # (N, D)
    Z: jax.Array  # (M, Q)


Batch = Union[ExactBatch, ExpectedBatch]


def _dispatch(kernel: Kernel, params: Params, batch: Batch, backend: str,
              bwd_backend: str = "auto") -> SuffStats:
    if isinstance(batch, ExactBatch):
        return kernel.exact_suff_stats(params, batch.X, batch.Y, batch.Z,
                                       backend=backend, bwd_backend=bwd_backend)
    if isinstance(batch, ExpectedBatch):
        return kernel.expected_suff_stats(
            params, batch.mu, batch.S, batch.Y, batch.Z, backend=backend,
            bwd_backend=bwd_backend
        )
    raise TypeError(f"expected ExactBatch or ExpectedBatch, got {type(batch).__name__}")


def streaming_suff_stats(kernel: Kernel, params: Params, batch: Batch, *,
                         backend: str = "jnp", chunk: Union[int, str] = 4096,
                         bwd_backend: str = "auto") -> SuffStats:
    """`suff_stats` as a chunked lax.scan over N: O(chunk * M + M^2) live.

    Works for any kernel and either batch type — the per-chunk statistics go
    through the normal kernel dispatch, the chunks combine through the
    `SuffStats` monoid. A non-dividing N is handled by an explicit tail
    chunk outside the scan (no padding/masking, so kernels need no weight
    plumbing). The scan body is rematerialized so the backward pass
    recomputes chunks instead of saving per-chunk intermediates.

    ``chunk="auto"`` resolves the size through the `repro.tune` autotuner
    (measured winner when tuned/cached, the historical default otherwise).
    Every chunked caller — the facades, `serve.online`, the mesh path —
    routes through here, so this is the single resolution point.
    """
    if not isinstance(batch, (ExactBatch, ExpectedBatch)):
        raise TypeError(f"expected ExactBatch or ExpectedBatch, got {type(batch).__name__}")
    if isinstance(chunk, str):
        if chunk != "auto":
            raise ValueError(f'chunk must be a positive int or "auto", got {chunk!r}')
        from repro import tune

        first = batch[0]
        chunk = tune.best_chunk(
            n=first.shape[0], m=batch.Z.shape[0], q=batch.Z.shape[1],
            d=batch.Y.shape[1], dtype=first.dtype, backend=backend,
            bwd_backend=bwd_backend)
    if chunk <= 0:
        raise ValueError(f"chunk must be positive, got {chunk}")
    per_point = [a for name, a in zip(batch._fields, batch) if name != "Z"]
    N = per_point[0].shape[0]
    rebuild = type(batch)

    def one(*parts) -> SuffStats:
        return _dispatch(kernel, params, rebuild(*parts, batch.Z), backend,
                         bwd_backend)

    n_full, rem = divmod(N, chunk)
    stats: Optional[SuffStats] = None
    if n_full:
        stacked = tuple(
            a[: n_full * chunk].reshape(n_full, chunk, *a.shape[1:])
            for a in per_point
        )
        shapes = jax.eval_shape(one, *(a[0] for a in stacked))

        # rank-0 scan carries break this jax version's shard_map transpose
        # (its spec check rejects scalar cotangents), so scalar statistics
        # ride the carry as (1,) and drop back to () after the scan
        def lift(s: SuffStats) -> SuffStats:
            return jax.tree.map(lambda x: x[None] if x.ndim == 0 else x, s)

        # `+ 0 * x[0...]` inherits the data's varying-manual-axes type so the
        # carry is well-typed when this runs inside shard_map.
        vma = 0.0 * per_point[0][(0,) * per_point[0].ndim]
        init = jax.tree.map(
            lambda s: (jnp.zeros((1, *s.shape) if s.ndim == 0 else s.shape,
                                 s.dtype) + vma).astype(s.dtype),
            shapes,
        )

        @jax.checkpoint
        def body(acc, xs):
            return SuffStats.combine(acc, lift(one(*xs))), None

        lifted, _ = jax.lax.scan(body, init, stacked)
        stats = SuffStats(*(
            x[0] if ref.ndim == 0 else x for x, ref in zip(lifted, shapes)
        ))
    if rem:
        tail = one(*(a[n_full * chunk:] for a in per_point))
        stats = tail if stats is None else SuffStats.combine(stats, tail)
    if stats is None:  # N == 0: defer to the one-shot path's zero statistics
        return one(*per_point)
    return stats


def suff_stats(kernel: Kernel, params: Params, batch: Batch, *,
               backend: str = "jnp", chunk: Optional[Union[int, str]] = None,
               bwd_backend: str = "auto") -> SuffStats:
    """Sufficient statistics of `batch` under `kernel`, kernel-dispatched.

    `chunk=None` evaluates the statistics in one shot (full-batch
    workspaces); an integer streams the datapoints in chunks of that size,
    and ``"auto"`` streams with the `repro.tune`-resolved size.
    The "fused" backend is exempt: its op already streams internally (jnp
    twin / Pallas grid over N) with a streaming hand-derived VJP.
    `bwd_backend` selects the reverse-pass implementation of the kernelized
    backends — the fused op and the single-statistic "pallas" ops both
    dispatch on it (Pallas reverse kernel vs streaming jnp scan; ignored by
    the "jnp" backend).
    """
    if chunk is not None and backend != "fused":
        return streaming_suff_stats(kernel, params, batch, backend=backend,
                                    chunk=chunk, bwd_backend=bwd_backend)
    return _dispatch(kernel, params, batch, backend, bwd_backend)
