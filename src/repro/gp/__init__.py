"""Public GP API: GPy-style model facades over the distributed collapsed bound.

    from repro.gp import SparseGPRegression, kernels

    gp = SparseGPRegression(kernel=kernels.get("rbf")(1), M=32).fit(X, Y)
    mean, var = gp.predict(Xt)

Kernels resolve by name through `repro.gp.kernels.get` (rbf, linear,
matern12/32/52, sum, product); models accept `mesh=` for the paper's
shard_map+psum data parallelism and `backend=` for the Pallas/fused
statistics paths.

Model classes load lazily (PEP 562) so importing `repro.gp.kernels` from the
core layers never drags in the model/optimizer stack.
"""
from repro.gp import kernels
from repro.gp.kernels import (Kernel, available, capabilities, get, register)
from repro.gp.stats import ExactBatch, ExpectedBatch, suff_stats

__all__ = [
    "Kernel", "available", "capabilities", "get", "register", "kernels",
    "ExactBatch", "ExpectedBatch", "suff_stats",
    "SparseGPRegression", "BayesianGPLVM", "TemporalGPRegression",
    "regression", "models",
]

_LAZY = ("SparseGPRegression", "BayesianGPLVM", "regression", "models")


def __getattr__(name):
    if name in _LAZY:
        import importlib

        models = importlib.import_module("repro.gp.models")
        return models if name == "models" else getattr(models, name)
    if name == "TemporalGPRegression":
        import importlib

        return importlib.import_module("repro.temporal").TemporalGPRegression
    raise AttributeError(f"module 'repro.gp' has no attribute {name!r}")
