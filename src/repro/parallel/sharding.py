"""Logical-axis sharding rules: one table maps every parameter, activation
tag, optimizer slot, and decode-state leaf to a PartitionSpec.

Scheme (MaxText-style FSDP + TP, DP over the pod axis by default):

  batch axes       = ("pod", "data")  — all data parallelism
  "model" axis     = tensor parallel (attention heads / ffn hidden / vocab /
                     MoE experts) — 16-way intra-pod (one ICI torus axis)
  FSDP             = params additionally sharded over "data" on a non-TP dim;
                     XLA all-gathers them per scan step (overlapped by the
                     latency-hiding scheduler)

Param rules are keyed on the flattened pytree path (trailing dims only, so
scan-stacked leading layer axes are transparent).
"""
from __future__ import annotations

import re
from typing import Any, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any

BATCH = ("pod", "data")  # collapses to ("data",) on single-pod meshes


def _batch_axes(mesh: Mesh):
    axes = tuple(a for a in BATCH if a in mesh.axis_names)
    return axes if len(axes) > 1 else axes[0] if axes else None


# (path regex, spec for the TRAILING dims; leading dims padded with None)
PARAM_RULES: Sequence[Tuple[str, Tuple]] = (
    (r"embed/table$", ("model", "data")),  # (V, d): vocab-TP + FSDP
    (r"unembed/w$", ("data", "model")),  # (d, V)
    (r"(attn|xattn)/w[qkv]$", ("data", "model")),  # (d, H*hd)
    (r"(attn|xattn)/wo$", ("model", "data")),  # (H*hd, d)
    (r"moe/router$", (None, None)),  # (d, E) replicated: loss-bearing fp32
    (r"moe/w_(gate|up)$", ("model", "data", None)),  # (E, d, f): EP + FSDP
    (r"moe/w_down$", ("model", None, "data")),  # (E, f, d)
    (r"mlp/w_(gate|up)$", ("data", "model")),  # (d, f)
    (r"mlp/w_down$", ("model", "data")),  # (f, d)
    (r"rwkv/(wr|wk|wv|wg)$", ("data", "model")),  # (d, d): channels TP
    (r"rwkv/wo$", ("model", "data")),
    (r"rwkv/lora_wA$", ("data", None)),
    (r"rwkv/lora_wB$", (None, "model")),
    (r"cmix/(wk|wr)$", ("data", "model")),
    (r"cmix/wv$", ("model", "data")),
    (r"rglru/(w_gate|w_x|w_a|w_i)$", ("data", "model")),  # (d, d): channels TP
    (r"rglru/w_out$", ("model", "data")),
    (r"rglru/conv_w$", (None, "model")),  # (4, d) depthwise
    # GP core (data-parallel local params live on the batch axes)
    (r"q_(mu|logS)$", (BATCH, None)),
    (r"^Z$", (None, None)),
)

# decode-state rules (path, trailing spec). KV caches shard batch + SLOTS
# (sequence) over the model axis — flash-decode style: scores/softmax over a
# sharded kv-length psum partial max/sum, and the (tiny) attention output
# all-reduces. This is what fits a 32k x 128-batch arctic cache in HBM
# (kv-head sharding can't: Kv=8 < tp=16).
STATE_RULES: Sequence[Tuple[str, Tuple]] = (
    (r"kv/[kv]$", (BATCH, "model", None, None)),  # (B, slots, Kv, hd)
    (r"kv/pos$", (BATCH, "model")),  # (B, slots)
    (r"cross_[kv]$", (BATCH, "model", None, None)),  # (B, F, Kv, hd); F=1500 -> replicated
    (r"enc_pos$", (BATCH, None)),
    (r"rwkv_tm/S$", (BATCH, "model", None, None)),  # (B, H, K, V)
    (r"rwkv_tm/x_prev$", (BATCH, "model")),
    (r"rglru/h$", (BATCH, "model")),  # (B, d)
    (r"rglru/conv$", (BATCH, None, "model")),  # (B, 3, d)
    (r"cmix_prev$", (BATCH, "model")),
)

# activation tags used by models' `constrain` callbacks
ACT_RULES = {
    # residual stream: sequence-parallel over the model axis (Megatron SP) —
    # norms/residual adds are pointwise over S, and it divides the remat
    # carry stack by tp. Attention/FFN internals reshard to head/ffn layouts.
    "act_embed": (BATCH, "model", None),  # (B, S, d)
    "act_heads": (BATCH, None, "model", None),  # (B, S, H, hd)
    "act_kv_heads": (BATCH, None, "model", None),
    "ffn": (BATCH, None, "model"),  # (B, S, f)
    "logits": (BATCH, None, "model"),  # (B, c, V); rank-2 handled below
    "moe_tokens": ("model", None, None),  # (E, C, d)
    "moe_ffn": ("model", None, None),  # (E, C, f)
    # blockwise-attention internals: blocked q/k/v/acc and softmax stats
    "attn_blocks": (None, BATCH, "model", None, None),  # (n, B, H, blk, hd)
    "attn_carry": (None, BATCH, "model", None),  # (n_q, B, H, bq)
    "attn_carry_q": (BATCH, "model", None),  # (B, H, bq) per-q-block stats
    "attn_carry_qa": (BATCH, "model", None, None),  # (B, H, bq, hd)
    # rwkv wkv internals: heads over model
    "rwkv_chunks": (None, BATCH, None, "model", None),  # (n, B, c, H, K)
    "rwkv_state": (BATCH, "model", None, None),  # (B, H, K, V)
    # per-channel activations (rglru branch tensors): (B, S, d) channels-TP
    "act_chan": (BATCH, None, "model"),
    # MoE entry: (T, d) tokens on the batch axes, replicated over model
    "moe_input": (BATCH, None),
    # a2a-EP entry: tokens sharded over batch AND model axes
    "moe_input_a2a": (BATCH + ("model",), None),
}


def _resolve(entry, mesh: Mesh) -> Optional[Any]:
    """Map a rule entry (axis name / axis tuple / None) to mesh axes,
    dropping axes the mesh doesn't have (e.g. "pod" on single-pod)."""
    if entry is None:
        return None
    if isinstance(entry, str):
        return entry if entry in mesh.axis_names else None
    axes = tuple(a for a in entry if a in mesh.axis_names)
    if not axes:
        return None
    return axes if len(axes) > 1 else axes[0]


def _axes_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _spec_from_trailing(trailing: Tuple, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Resolve a trailing-dims rule against a concrete shape; any axis whose
    size does not evenly divide the dim is dropped (jit arguments must shard
    evenly — padding decisions are made explicitly in the models instead)."""
    rank = len(shape)
    resolved = list(_resolve(e, mesh) for e in trailing)
    if rank < len(resolved):  # tag reused on a lower-rank tensor: keep tail
        resolved = resolved[len(resolved) - rank :]
    resolved = [None] * (rank - len(resolved)) + resolved
    for i, (dim, ax) in enumerate(zip(shape, resolved)):
        if ax is not None and dim % _axes_size(mesh, ax) != 0:
            resolved[i] = None
    return P(*resolved)


def _path_str(path) -> str:
    parts = []
    for k in path:
        parts.append(str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k)))))
    return "/".join(parts)


def _rules_spec(rules, path: str, shape: Tuple[int, ...], mesh: Mesh) -> P:
    for pat, trailing in rules:
        if re.search(pat, path):
            return _spec_from_trailing(trailing, shape, mesh)
    return P()  # replicate (norm scales, gates, scalars, biases)


def param_specs(params: PyTree, mesh: Mesh) -> PyTree:
    def leaf(path, x):
        return _rules_spec(PARAM_RULES, _path_str(path), tuple(getattr(x, "shape", ())), mesh)

    return jax.tree_util.tree_map_with_path(leaf, params)


def state_specs(states: PyTree, mesh: Mesh) -> PyTree:
    def leaf(path, x):
        return _rules_spec(STATE_RULES, _path_str(path), tuple(getattr(x, "shape", ())), mesh)

    return jax.tree_util.tree_map_with_path(leaf, states)


def batch_specs(batch: PyTree, mesh: Mesh) -> PyTree:
    def leaf(x):
        shape = tuple(getattr(x, "shape", ()))
        if not shape:
            return P()
        return _spec_from_trailing((BATCH,) + (None,) * (len(shape) - 1), shape, mesh)

    return jax.tree.map(leaf, batch)


def to_shardings(specs: PyTree, mesh: Mesh) -> PyTree:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), specs, is_leaf=lambda x: isinstance(x, P)
    )


def make_constrain(mesh: Mesh):
    """The `constrain(tensor, tag)` callback threaded through the models.
    Carries `tp` (model-axis size) so attention can pad query heads to an
    evenly-shardable count."""

    def constrain(t, tag: str):
        trailing = ACT_RULES.get(tag)
        if trailing is None:
            return t
        spec = _spec_from_trailing(trailing, tuple(t.shape), mesh)
        return jax.lax.with_sharding_constraint(t, NamedSharding(mesh, spec))

    constrain.tp = mesh.shape.get("model", 1)
    constrain.mesh = mesh
    return constrain
