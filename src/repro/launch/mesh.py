"""Production mesh definitions.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before any jax init; tests and
benches see the real single CPU device).

Production target: TPU v5e pods, 256 chips each, mesh (data=16, model=16)
per pod; multi-pod adds a leading "pod" axis over the (slow) DCN links —
used for data parallelism (optionally pipeline stages, parallel/pipeline.py).
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh

from repro import compat

POD_SHAPE = (16, 16)
N_PODS = 2


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (N_PODS, *POD_SHAPE) if multi_pod else POD_SHAPE
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devices)} — the dry-run "
            "must set XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "any jax import (see launch/dryrun.py)"
        )
    return compat.make_mesh(shape, axes, devices=devices)


def make_host_mesh() -> Mesh:
    """Whatever devices exist (1 CPU here): for tests/examples; same code path."""
    n = len(jax.devices())
    return compat.make_mesh((1, n), ("data", "model"))
