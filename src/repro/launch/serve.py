"""Serving launcher: batched prefill + decode loop.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --preset smoke \
        --batch 4 --prompt-len 64 --new-tokens 32

Implements the production serve loop shape: one prefill step builds the
sharded KV/recurrent caches, then a jitted single-token decode step runs
autoregressively (greedy here; the logits interface takes any sampler).
Reports tokens/s.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import ShapeCell, get_config, get_smoke_config
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models.model_zoo import build, make_batch
from repro.parallel import sharding as shd


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--preset", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--mesh", default="host", choices=["host", "pod", "multipod"])
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.preset == "smoke" else get_config(args.arch)
    mesh = (make_host_mesh() if args.mesh == "host"
            else make_production_mesh(multi_pod=args.mesh == "multipod"))
    model = build(cfg)
    constrain = shd.make_constrain(mesh)

    with mesh:
        key = jax.random.PRNGKey(0)
        params = model.init(key)
        shape = ShapeCell("cli", args.prompt_len, args.batch, "prefill")
        batch = make_batch(key, cfg, shape, batch=args.batch)
        total = args.prompt_len + args.new_tokens + 1

        t0 = time.perf_counter()
        prefill = jax.jit(lambda p, b: model.prefill(p, b, constrain, total_slots=total))
        logits, states = prefill(params, batch)
        jax.block_until_ready(logits)
        t_prefill = time.perf_counter() - t0

        decode = jax.jit(lambda p, t, pos, st: model.decode_step(p, t, pos, st, constrain))
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32) % cfg.vocab_size
        prefix = cfg.frontend_tokens or 0
        pos0 = batch["tokens"].shape[1] + prefix
        outs = []
        t0 = time.perf_counter()
        for i in range(args.new_tokens):
            logits, states = decode(params, tok, jnp.asarray(pos0 + i, jnp.int32), states)
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32) % cfg.vocab_size
            outs.append(tok)
        jax.block_until_ready(tok)
        t_decode = time.perf_counter() - t0

    n_tok = args.batch * args.new_tokens
    print(f"prefill: {args.batch}x{args.prompt_len} in {t_prefill*1e3:.1f} ms "
          f"({args.batch*args.prompt_len/t_prefill:.0f} tok/s)")
    print(f"decode: {n_tok} tokens in {t_decode*1e3:.1f} ms ({n_tok/t_decode:.0f} tok/s)")
    print("sample:", jnp.concatenate(outs, 1)[0, :16].tolist())


if __name__ == "__main__":
    main()
