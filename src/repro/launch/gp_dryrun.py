import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)

"""Dry-run for the PAPER'S OWN workload at production scale: one distributed
Bayesian GP-LVM Adam step, N datapoints sharded over the pod (the paper's §4
experiment x256 chips). This is perf-hillclimb cell C (EXPERIMENTS.md §Perf).

    PYTHONPATH=src python -m repro.launch.gp_dryrun --n 16777216 --m 128 \
        --backend fused --mesh pod
"""

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
from pathlib import Path  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.core import distributed  # noqa: E402
from repro.launch import hlo_cost, roofline  # noqa: E402
from repro.optim import AdamConfig, AdamState, adam_init, adam_update  # noqa: E402

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=16_777_216)  # 65536 per chip (pod)
    ap.add_argument("--m", type=int, default=128)
    ap.add_argument("--q", type=int, default=1)
    ap.add_argument("--d", type=int, default=3)
    ap.add_argument("--backend", default="jnp", choices=["jnp", "fused"])
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod"])
    args = ap.parse_args()

    n_chips = 256 if args.mesh == "pod" else 512
    from repro import compat

    mesh = compat.make_mesh((n_chips,), ("data",), devices=jax.devices()[:n_chips])
    N, M, Q, D = args.n, args.m, args.q, args.d

    params_a = {
        "kern": {"log_variance": jax.ShapeDtypeStruct((), jnp.float32),
                 "log_lengthscale": jax.ShapeDtypeStruct((Q,), jnp.float32)},
        "Z": jax.ShapeDtypeStruct((M, Q), jnp.float32),
        "log_beta": jax.ShapeDtypeStruct((), jnp.float32),
        "q_mu": jax.ShapeDtypeStruct((N, Q), jnp.float32),
        "q_logS": jax.ShapeDtypeStruct((N, Q), jnp.float32),
    }
    Y_a = jax.ShapeDtypeStruct((N, D), jnp.float32)
    adam = AdamConfig(lr=1e-2, clip_norm=None, weight_decay=0.0)
    opt_a = jax.eval_shape(lambda p: adam_init(p, adam), params_a)

    loss_fn = distributed.gplvm_loss_dist(mesh, backend=args.backend)

    def train_step(params, opt, Y):
        loss, grads = jax.value_and_grad(loss_fn)(params, Y)
        params, opt, gnorm = adam_update(grads, opt, params, adam)
        return params, opt, {"loss": loss, "gnorm": gnorm}

    local = P("data", None)
    pspec = {"kern": {"log_variance": P(), "log_lengthscale": P()}, "Z": P(),
             "log_beta": P(), "q_mu": local, "q_logS": local}
    shard = lambda spec: jax.tree.map(lambda s: NamedSharding(mesh, s), spec,
                                      is_leaf=lambda x: isinstance(x, P))
    pshard = shard(pspec)
    oshard = AdamState(NamedSharding(mesh, P()), pshard, pshard)
    mshard = {"loss": NamedSharding(mesh, P()), "gnorm": NamedSharding(mesh, P())}

    t0 = time.time()
    with mesh:
        lowered = jax.jit(
            train_step,
            in_shardings=(pshard, oshard, shard(local)),
            out_shardings=(pshard, oshard, mshard),
            donate_argnums=(0, 1),
        ).lower(params_a, opt_a, Y_a)
        compiled = lowered.compile()
    t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    cost = hlo_cost.analyze(compiled.as_text())
    terms = roofline.roofline_terms(cost.flops, cost.bytes, cost.coll_traffic)
    rec = {
        "arch": f"gplvm-N{N}-M{M}", "shape": "train_gp", "mesh": args.mesh,
        "kind": "train", "seq_len": 1, "global_batch": N, "status": "ok",
        "backend": args.backend, "n_chips": n_chips,
        "compile_s": round(t_compile, 2),
        "memory": {"peak_hbm_bytes_est": ma.argument_size_in_bytes
                   + ma.output_size_in_bytes + ma.temp_size_in_bytes
                   - ma.alias_size_in_bytes,
                   "argument_bytes": ma.argument_size_in_bytes,
                   "temp_bytes": ma.temp_size_in_bytes},
        "flops_per_chip": cost.flops,
        "bytes_per_chip": cost.bytes,
        "collectives": {"counts": cost.coll_counts,
                        "traffic_bytes_per_chip": cost.coll_traffic},
        "roofline": terms,
    }
    out = OUT_DIR / f"gplvm_{args.backend}_{args.mesh}.json"
    out.write_text(json.dumps(rec, indent=2))
    print(json.dumps({k: rec[k] for k in ("backend", "compile_s", "flops_per_chip",
                                          "bytes_per_chip")}, indent=1))
    r = terms
    print(f"terms: compute {r['t_compute_s']*1e6:.1f} us | memory "
          f"{r['t_memory_s']*1e6:.1f} us | collective {r['t_collective_s']*1e6:.1f} us "
          f"| dominant {r['dominant']} | HBM {rec['memory']['peak_hbm_bytes_est']/2**30:.2f} GiB")


if __name__ == "__main__":
    main()
