"""Trip-count-aware cost analysis over compiled HLO text.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE — under
scan-over-layers (and blockwise-attention / chunked-CE scans) that
undercounts FLOPs, bytes, and collective traffic by the trip count (~30x for
a 30-layer model). This module re-derives the three roofline inputs exactly
from the scheduled HLO:

  * builds the computation table (name -> instructions, result shapes);
  * walks the call graph (fusion/call/while/conditional), multiplying while
    bodies by their trip count (parsed from the loop-condition comparison
    against the s32 constant — which is exactly how lax.scan lowers);
  * FLOPs: dot/convolution = 2 * prod(result) * contraction size; elementwise
    arithmetic/transcendentals = 1 flop per output element (XLA convention);
  * bytes: operands + result at fusion boundaries and standalone ops
    (intra-fusion temporaries are register/VMEM-resident and not counted);
  * collectives: ring-model traffic per op (see launch/roofline.py),
    multiplied through enclosing loops.

Validated against XLA's own numbers on scan-free programs
(tests/test_hlo_cost.py) and against analytic matmul counts.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "u2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

ELEMENTWISE_1FLOP = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "exponential", "exponential-minus-one", "log", "log-plus-one",
    "tanh", "sqrt", "rsqrt", "power", "compare", "select", "and", "or",
    "xor", "not", "sign", "floor", "ceil", "round-nearest-afz", "cosine",
    "sine", "clamp", "atan2", "erf", "logistic", "cbrt",
}

COLLECTIVES = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute"}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([\d,]*)\]")
_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*")
_OPCODE_RE = re.compile(r"\s*([a-z][\w\-]*)\(")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
# computation header: `%name (params...) -> rettype {` — params may nest parens
_COMP_START_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")


def _parse_instr(line: str) -> Optional[Tuple[str, str, str, str]]:
    """Parse `%name = TYPE opcode(rest...`. TYPE may be a tuple containing
    nested parens/braces and /*index=N*/ comments — scanned with a balanced
    parenthesis walk, not a regex."""
    m = _NAME_RE.match(line)
    if not m:
        return None
    name = m.group(1)
    i = m.end()
    n = len(line)
    if i < n and line[i] == "(":  # tuple type
        depth = 0
        j = i
        while j < n:
            if line[j] == "(":
                depth += 1
            elif line[j] == ")":
                depth -= 1
                if depth == 0:
                    break
            j += 1
        ty = line[i : j + 1]
        i = j + 1
    else:  # simple type like bf16[1,2]{1,0}
        j = i
        while j < n and line[j] not in " ":
            j += 1
        ty = line[i:j]
        i = j
    mo = _OPCODE_RE.match(line, i)
    if not mo:
        return None
    return name, ty, mo.group(1), line[mo.end():]


def _parse_shape(tystr: str) -> Tuple[int, int]:
    """Return (elements_bytes, element_count) for a type string (tuple: sum/max)."""
    total_bytes = 0
    total_elems = 0
    for m in _SHAPE_RE.finditer(tystr):
        dt, dims = m.group(1), m.group(2)
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total_bytes += n * _DTYPE_BYTES.get(dt, 4)
        total_elems += n
    return total_bytes, total_elems


@dataclasses.dataclass
class Instr:
    name: str
    ty: str
    opcode: str
    rest: str  # everything after the opening paren (operands + attrs)


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_traffic: float = 0.0
    coll_raw: float = 0.0
    coll_counts: Optional[Dict[str, float]] = None

    def __post_init__(self):
        if self.coll_counts is None:
            self.coll_counts = {}

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.coll_traffic += other.coll_traffic * mult
        self.coll_raw += other.coll_raw * mult
        for k, v in other.coll_counts.items():
            self.coll_counts[k] = self.coll_counts.get(k, 0) + v * mult


_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _group_size(rest: str) -> int:
    m = _GROUPS_RE.search(rest)
    if m:
        return max(1, len([x for x in m.group(1).split(",") if x.strip() != ""]))
    m = _GROUPS_IOTA_RE.search(rest)
    if m:
        return max(1, int(m.group(2)))
    return 1


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.comps: Dict[str, List[Instr]] = {}
        self.entry: Optional[str] = None
        self._parse(hlo_text)
        self._memo: Dict[str, Cost] = {}
        self._params_memo: Dict[str, Dict[int, str]] = {}

    # ------------------------------------------------------------------
    def _parse(self, text: str) -> None:
        cur: Optional[str] = None
        for line in text.splitlines():
            if cur is None:
                m = _COMP_START_RE.match(line)
                if m and line.rstrip().endswith("{"):
                    cur = m.group(1)
                    self.comps[cur] = []
                    if line.startswith("ENTRY"):
                        self.entry = cur
                continue
            if line.startswith("}"):
                cur = None
                continue
            parsed = _parse_instr(line)
            if parsed:
                self.comps[cur].append(Instr(*parsed))

    # ------------------------------------------------------------------
    def _symtab(self, comp: str) -> Dict[str, str]:
        return {i.name: i.ty for i in self.comps[comp]}

    _CALLS_LIST_RE = re.compile(r"(?:calls|branch_computations)=\{([^}]*)\}")
    _CALLS_ONE_RE = re.compile(r"(?:calls|body|condition|to_apply)=%([\w.\-]+)")

    def _called(self, instr: Instr) -> List[str]:
        out = []
        for m in self._CALLS_LIST_RE.finditer(instr.rest):
            out += [n.strip().lstrip("%") for n in m.group(1).split(",") if n.strip()]
        for m in self._CALLS_ONE_RE.finditer(instr.rest):
            out.append(m.group(1))
        return [n for n in out if n in self.comps]

    def _trip_count(self, cond_comp: str) -> int:
        """Parse the scan trip count from the loop condition: the s32
        constant compared against the induction variable."""
        consts = []
        for i in self.comps.get(cond_comp, []):
            if i.opcode == "constant" and i.ty.startswith("s32"):
                m = re.match(r"(-?\d+)", i.rest.rstrip(") ,"))
                if m:
                    consts.append(int(m.group(1)))
            # fused compare: constant may live in the called computation
            for callee in self._called(i):
                for j in self.comps.get(callee, []):
                    if j.opcode == "constant" and j.ty.startswith("s32"):
                        m = re.match(r"(-?\d+)", j.rest.rstrip(") ,"))
                        if m:
                            consts.append(int(m.group(1)))
        return max(consts) if consts else 1

    # ------------------------------------------------------------------
    def _operand_names(self, instr: Instr) -> List[str]:
        # operands are the leading %names in rest, before attribute k=v pairs
        depth = 0
        head = []
        for ch in instr.rest:
            if ch == "(":
                depth += 1
            elif ch == ")":
                if depth == 0:
                    break
                depth -= 1
            head.append(ch)
        return re.findall(r"%([\w.\-]+)", "".join(head))

    def _dot_flops(self, instr: Instr, symtab: Dict[str, str]) -> float:
        out_bytes, out_elems = _parse_shape(instr.ty)
        ops = self._operand_names(instr)
        contract = 1.0
        m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.rest)
        if m and ops:
            lhs_ty = symtab.get(ops[0], "")
            sm = _SHAPE_RE.search(lhs_ty)
            if sm:
                dims = [int(d) for d in sm.group(2).split(",") if d]
                for ci in m.group(1).split(","):
                    if ci and int(ci) < len(dims):
                        contract *= dims[int(ci)]
        return 2.0 * out_elems * contract

    def _conv_flops(self, instr: Instr, symtab: Dict[str, str]) -> float:
        _, out_elems = _parse_shape(instr.ty)
        ops = self._operand_names(instr)
        k_elems = 1.0
        if len(ops) > 1:
            _, k_elems = _parse_shape(symtab.get(ops[1], ""))
        return 2.0 * out_elems * k_elems  # upper bound: full kernel per output

    # ------------------------------------------------------------------
    _SLICING_OPS = {"dynamic-slice", "gather", "slice"}

    def _comp_params(self, comp: str) -> Dict[int, str]:
        cached = self._params_memo.get(comp)
        if cached is not None:
            return cached
        params: Dict[int, str] = {}
        for i in self.comps.get(comp, []):
            if i.opcode == "parameter":
                m = re.match(r"(\d+)", i.rest)
                if m:
                    params[int(m.group(1))] = i.name
        self._params_memo[comp] = params
        return params

    def _param_traffic(self, callee: str, idx: int, *, depth: int = 0) -> Optional[float]:
        """Bytes `callee` actually reads from its idx-th parameter, or None
        when any use touches the full array (caller then charges full size).
        Recurses through nested fusion/call wrappers — newer XLA wraps the
        loop-body slice fusion in a parallel `call` computation."""
        if depth > 4:
            return None
        pname = self._comp_params(callee).get(idx)
        if pname is None:
            return None
        instrs = self.comps.get(callee, [])
        uses = [i for i in instrs if pname in self._operand_names(i)]
        if not uses:
            return None
        total = 0.0
        for u in uses:
            if u.opcode in self._SLICING_OPS:
                total += _parse_shape(u.ty)[0]
                continue
            if u.opcode == "dynamic-update-slice":
                uops = self._operand_names(u)
                if uops and uops[0] == pname and len(uops) > 1:
                    # in-place update target: traffic = the update slice
                    sym = self._symtab(callee)
                    total += _parse_shape(sym.get(uops[1], ""))[0]
                    continue
                return None
            if u.opcode in ("fusion", "call"):
                sub = self._called(u)
                if not sub:
                    return None
                # the same array may feed several operand slots: charge each
                for sub_idx, o in enumerate(self._operand_names(u)):
                    if o != pname:
                        continue
                    b = self._param_traffic(sub[0], sub_idx, depth=depth + 1)
                    if b is None:
                        return None
                    total += b
                continue
            return None
        return total

    def _fusion_operand_bytes(self, callee: str, operands: List[str],
                              symtab: Dict[str, str]) -> float:
        """Bytes read by a fusion, counting a parameter consumed ONLY by
        slicing ops at its slice size, not its full size. This is what makes
        scan-over-layers accounting honest: the stacked (L, ...) parameter
        array enters the loop-body fusion, but each iteration only touches
        one layer's slice."""
        if self.comps.get(callee) is None:
            return sum(_parse_shape(symtab.get(o, ""))[0] for o in operands)
        total = 0.0
        for idx, opname in enumerate(operands):
            full = _parse_shape(symtab.get(opname, ""))[0]
            sliced = self._param_traffic(callee, idx)
            total += full if sliced is None else sliced
        return total

    def comp_cost(self, comp: str) -> Cost:
        if comp in self._memo:
            return self._memo[comp]
        total = Cost()
        self._memo[comp] = total  # break cycles defensively
        symtab = self._symtab(comp)
        for instr in self.comps.get(comp, []):
            op = instr.opcode
            base = op.replace("-start", "")
            if op in ("parameter", "constant", "get-tuple-element", "tuple",
                      "bitcast", "after-all") or op.endswith("-done"):
                continue
            if op == "while":
                mb = re.search(r"body=%?([\w.\-]+)", instr.rest)
                mc = re.search(r"condition=%?([\w.\-]+)", instr.rest)
                body = mb.group(1) if mb else None
                cond = mc.group(1) if mc else None
                mt = _TRIP_RE.search(instr.rest)  # XLA's own analysis, if present
                if mt:
                    trips = int(mt.group(1))
                else:
                    trips = self._trip_count(cond) if cond else 1
                if body:
                    total.add(self.comp_cost(body), trips)
                if cond:
                    total.add(self.comp_cost(cond), trips)
                continue
            if op == "conditional":
                branches = self._called(instr)
                if branches:
                    costs = [self.comp_cost(b) for b in branches]
                    worst = max(costs, key=lambda c: c.flops + c.bytes)
                    total.add(worst)
                continue
            if base in COLLECTIVES:
                out_bytes, _ = _parse_shape(instr.ty)
                if op.endswith("-start") and base == "all-gather":
                    # result tuple = (operand, gathered): take the larger half
                    out_bytes = out_bytes  # tuple sum; gathered dominates
                P = _group_size(instr.rest)
                if P > 1:
                    frac = (P - 1) / P
                    if base == "all-gather":
                        t = out_bytes * frac
                    elif base == "reduce-scatter":
                        t = out_bytes * (P - 1)
                    elif base == "all-reduce":
                        t = 2 * out_bytes * frac
                    elif base == "all-to-all":
                        t = out_bytes * frac
                    else:
                        t = out_bytes
                    total.coll_traffic += t
                    total.coll_raw += out_bytes
                    total.coll_counts[base] = total.coll_counts.get(base, 0) + 1
                total.bytes += out_bytes * 2
                continue
            if op == "fusion" or op == "call":
                callees = self._called(instr)
                for callee in callees:
                    sub = self.comp_cost(callee)
                    total.flops += sub.flops
                    total.coll_traffic += sub.coll_traffic
                    total.coll_raw += sub.coll_raw
                    for k, v in sub.coll_counts.items():
                        total.coll_counts[k] = total.coll_counts.get(k, 0) + v
                # bytes at the fusion boundary; sliced params count slice size
                out_b, _ = _parse_shape(instr.ty)
                ops = self._operand_names(instr)
                if callees:
                    in_b = self._fusion_operand_bytes(callees[0], ops, symtab)
                    # in-place update root: writes the update slice, not the
                    # array — also when the root is a bitcast/reshape of the
                    # DUS (XLA's "bitcast_dynamic-update-slice" fusions)
                    body = self.comps.get(callees[0], [])
                    dus = [j for j in body if j.opcode == "dynamic-update-slice"]
                    root = body[-1] if body else None
                    root_is_dus_like = root is not None and (
                        root.opcode == "dynamic-update-slice"
                        or (len(dus) == 1 and root.opcode in ("bitcast", "reshape", "copy"))
                    )
                    if root_is_dus_like and dus:
                        rsym = self._symtab(callees[0])
                        upd = 0.0
                        for j in dus:
                            rops = self._operand_names(j)
                            if len(rops) > 1:
                                upd += _parse_shape(rsym.get(rops[1], ""))[0]
                        out_b = upd
                else:
                    in_b = sum(_parse_shape(symtab.get(o, ""))[0] for o in ops)
                total.bytes += out_b + in_b
                continue
            if op in ("dynamic-slice", "gather"):
                out_b, _ = _parse_shape(instr.ty)
                total.bytes += out_b * 2  # slice read + write; not the operand
                continue
            if op == "dynamic-update-slice":
                ops = self._operand_names(instr)
                upd = _parse_shape(symtab.get(ops[1], ""))[0] if len(ops) > 1 else 0
                total.bytes += upd * 2  # in-place: read update, write slice
                continue
            if op == "dot":
                total.flops += self._dot_flops(instr, symtab)
                out_b, _ = _parse_shape(instr.ty)
                in_b = sum(_parse_shape(symtab.get(o, ""))[0] for o in self._operand_names(instr))
                total.bytes += out_b + in_b
                continue
            if op == "convolution":
                total.flops += self._conv_flops(instr, symtab)
                out_b, _ = _parse_shape(instr.ty)
                total.bytes += out_b * 3
                continue
            if op in ("reduce", "reduce-window", "sort", "scatter", "gather",
                      "dynamic-slice", "dynamic-update-slice", "copy", "reshape",
                      "transpose", "broadcast", "iota", "concatenate", "slice",
                      "pad", "convert", "select-and-scatter", "rng", "reverse",
                      "dot-general", "cholesky", "triangular-solve", "custom-call"):
                out_b, out_e = _parse_shape(instr.ty)
                in_b = sum(_parse_shape(symtab.get(o, ""))[0] for o in self._operand_names(instr))
                total.bytes += out_b + in_b
                if op in ("reduce", "reduce-window"):
                    total.flops += max(in_b / 4.0, out_e)  # ~1 flop per input elem
                continue
            if op in ELEMENTWISE_1FLOP:
                out_b, out_e = _parse_shape(instr.ty)
                total.flops += out_e
                total.bytes += out_b * 3  # two reads + one write, standalone
                continue
            # default: count bytes only
            out_b, _ = _parse_shape(instr.ty)
            total.bytes += out_b
        self._memo[comp] = total
        return total

    def entry_cost(self) -> Cost:
        if not self.entry:
            raise ValueError("no ENTRY computation found")
        return self.comp_cost(self.entry)


def analyze(hlo_text: str) -> Cost:
    return HloCostModel(hlo_text).entry_cost()
