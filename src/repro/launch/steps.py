"""pjit-able step functions (train / prefill / decode) with full sharding
trees. Used identically by the real trainer/server (launch/train.py,
launch/serve.py) and the multi-pod dry-run (launch/dryrun.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeCell
from repro.models import model_zoo
from repro.optim import AdamConfig, AdamState, adam_init, adam_update
from repro.parallel import sharding as shd

PyTree = Any


@dataclasses.dataclass(frozen=True)
class StepBundle:
    """A lowered-able step function + abstract args + in/out shardings."""

    fn: Any
    abstract_args: tuple
    in_shardings: tuple
    out_shardings: Any
    donate_argnums: tuple = ()

    def jitted(self):
        return jax.jit(
            self.fn,
            in_shardings=self.in_shardings,
            out_shardings=self.out_shardings,
            donate_argnums=self.donate_argnums,
        )

    def lower(self):
        return self.jitted().lower(*self.abstract_args)


def _abstract_params(model) -> PyTree:
    return jax.eval_shape(model.init, jax.random.PRNGKey(0))


def _logits_spec(B: int, cfg: ModelConfig, mesh: Mesh):
    """(B, padded_vocab) decode/prefill logits: batch-DP + vocab-TP."""
    sds = jax.ShapeDtypeStruct((B, cfg.padded_vocab()), jnp.float32)
    return shd._spec_from_trailing((shd.BATCH, "model"), sds.shape, mesh)


def default_adam(cfg: ModelConfig) -> AdamConfig:
    return AdamConfig(lr=3e-4, weight_decay=0.1, clip_norm=1.0,
                      state_dtype=cfg.optimizer_state_dtype)


def make_train_step(cfg: ModelConfig, shape: ShapeCell, mesh: Mesh,
                    adam: AdamConfig | None = None, batch: int | None = None) -> StepBundle:
    model = model_zoo.build(cfg)
    adam = adam or default_adam(cfg)
    constrain = shd.make_constrain(mesh)

    n_mb = max(1, cfg.microbatches)
    acc_dt = jnp.dtype(cfg.grad_accum_dtype)

    def grads_of(params, data):
        def loss_fn(p):
            return model.train_loss(p, data, constrain)

        return jax.value_and_grad(loss_fn, has_aux=True)(params)

    def train_step(params, opt_state: AdamState, data: Dict[str, jax.Array]):
        if n_mb == 1:
            (loss, metrics), grads = grads_of(params, data)
        else:
            # gradient accumulation over sequential microbatches
            def split(x):
                B = x.shape[0]
                return x.reshape(n_mb, B // n_mb, *x.shape[1:])

            mbs = jax.tree.map(split, data)

            def body(acc, mb):
                (l, m), g = grads_of(params, mb)
                acc = jax.tree.map(
                    lambda a, gi: a + gi.astype(a.dtype) / n_mb, acc, g)
                return acc, (l, m)

            acc0 = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt), params)
            grads, (losses, ms) = jax.lax.scan(body, acc0, mbs)
            loss = jnp.mean(losses)
            metrics = jax.tree.map(lambda x: jnp.mean(x), ms)
        params, opt_state, gnorm = adam_update(grads, opt_state, params, adam)
        metrics = dict(metrics, loss=loss, grad_norm=gnorm)
        return params, opt_state, metrics

    params_a = _abstract_params(model)
    opt_a = jax.eval_shape(lambda p: adam_init(p, adam), params_a)
    data_a = model_zoo.input_specs(cfg, shape, batch)

    pspec = shd.param_specs(params_a, mesh)
    ospec = AdamState(P(), pspec, pspec)
    dspec = shd.batch_specs(data_a, mesh)
    mspec = jax.tree.map(lambda _: P(), {"ce": 0, "aux": 0, "loss": 0, "grad_norm": 0})

    tos = lambda t: shd.to_shardings(t, mesh)
    return StepBundle(
        fn=train_step,
        abstract_args=(params_a, opt_a, data_a),
        in_shardings=(tos(pspec), tos(ospec), tos(dspec)),
        out_shardings=(tos(pspec), tos(ospec), tos(mspec)),
        donate_argnums=(0, 1),
    )


def make_prefill_step(cfg: ModelConfig, shape: ShapeCell, mesh: Mesh,
                      batch: int | None = None) -> StepBundle:
    model = model_zoo.build(cfg)
    constrain = shd.make_constrain(mesh)

    def prefill_step(params, data):
        return model.prefill(params, data, constrain)

    params_a = _abstract_params(model)
    data_a = model_zoo.input_specs(cfg, shape, batch)
    _, states_a = jax.eval_shape(prefill_step, params_a, data_a)

    pspec = shd.param_specs(params_a, mesh)
    dspec = shd.batch_specs(data_a, mesh)
    sspec = shd.state_specs(states_a, mesh)
    B = batch or shape.global_batch
    lspec = _logits_spec(B, cfg, mesh)

    tos = lambda t: shd.to_shardings(t, mesh)
    return StepBundle(
        fn=prefill_step,
        abstract_args=(params_a, data_a),
        in_shardings=(tos(pspec), tos(dspec)),
        out_shardings=(tos(lspec), tos(sspec)),
    )


def make_decode_step(cfg: ModelConfig, shape: ShapeCell, mesh: Mesh,
                     batch: int | None = None) -> StepBundle:
    """One-token serve step against a KV/recurrent cache of shape.seq_len."""
    model = model_zoo.build(cfg)
    constrain = shd.make_constrain(mesh)
    B = batch or shape.global_batch

    def decode_step(params, states, tokens, pos):
        logits, states = model.decode_step(params, tokens, pos, states, constrain)
        return logits, states

    params_a = _abstract_params(model)
    states_a = jax.eval_shape(lambda: model.init_decode_state(B, shape.seq_len))
    tokens_a = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    pos_a = jax.ShapeDtypeStruct((), jnp.int32)

    pspec = shd.param_specs(params_a, mesh)
    sspec = shd.state_specs(states_a, mesh)
    tspec = shd.batch_specs(tokens_a, mesh)
    lspec = _logits_spec(B, cfg, mesh)

    tos = lambda t: shd.to_shardings(t, mesh)
    return StepBundle(
        fn=decode_step,
        abstract_args=(params_a, states_a, tokens_a, pos_a),
        in_shardings=(tos(pspec), tos(sspec), tos(tspec), NamedSharding(mesh, P())),
        out_shardings=(tos(lspec), tos(sspec)),
        donate_argnums=(1,),
    )


def make_step(kind: str, cfg: ModelConfig, shape: ShapeCell, mesh: Mesh,
              batch: int | None = None) -> StepBundle:
    if kind == "train":
        return make_train_step(cfg, shape, mesh, batch=batch)
    if kind == "prefill":
        return make_prefill_step(cfg, shape, mesh, batch=batch)
    if kind == "decode":
        return make_decode_step(cfg, shape, mesh, batch=batch)
    raise ValueError(kind)
