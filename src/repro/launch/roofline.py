"""Roofline-term extraction from compiled dry-run artifacts.

Per (arch x shape x mesh) cell:

    compute term    = HLO_FLOPs_per_chip / peak_FLOP/s
    memory term     = HLO_bytes_per_chip / HBM_bw
    collective term = collective_traffic_bytes_per_chip / collective_bw

``compiled.cost_analysis()`` on an SPMD-partitioned module reports *per-chip*
flops/bytes (the partitioner has already divided the program), so no further
/chips is applied — this is algebraically identical to the assignment's
total/(chips * bw) form.

Collective traffic is parsed from the compiled HLO text: for every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute we
apply the standard ring-transfer model over the parsed replica-group size P:

    all-gather:         out_bytes * (P-1)/P
    reduce-scatter:     in_bytes  * (P-1)/P      (= out_bytes * (P-1))
    all-reduce:         2 * bytes * (P-1)/P
    all-to-all:         bytes * (P-1)/P
    collective-permute: bytes

Hardware model (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link ICI;
ring collectives drive both directions of one torus link => 100 GB/s/chip
effective collective bandwidth (documented assumption).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s
ICI_LINK_BW = 50e9  # B/s per link per direction
COLLECTIVE_BW = 2 * ICI_LINK_BW  # bidirectional ring on one torus axis

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"(\w[\w.-]*)\s*=\s*([a-z0-9]+)\[([\d,]*)\][^=]*?"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", re.I)
_TUPLE_COLL_RE = re.compile(
    r"=\s*\(([^)]*)\)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", re.I)
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


@dataclasses.dataclass
class CollectiveStats:
    counts: Dict[str, int]
    raw_bytes: Dict[str, int]  # sum of result bytes by op kind
    traffic_bytes: float  # ring-model per-chip traffic

    def total_raw(self) -> int:
        return sum(self.raw_bytes.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    counts: Dict[str, int] = {}
    raw: Dict[str, int] = {}
    traffic = 0.0
    for line in hlo_text.splitlines():
        if "all-reduce" not in line and "all-gather" not in line \
                and "reduce-scatter" not in line and "all-to-all" not in line \
                and "collective-permute" not in line:
            continue
        if "-done" in line or "async" in line.split("=")[0]:
            continue
        m = _COLL_RE.search(line)
        shapes: List[int] = []
        kind = None
        if m:
            kind = m.group(4).lower()
            shapes = [_shape_bytes(m.group(2), m.group(3))]
        else:
            mt = _TUPLE_COLL_RE.search(line)
            if not mt:
                continue
            kind = mt.group(2).lower()
            shapes = [
                _shape_bytes(sm.group(1), sm.group(2))
                for sm in re.finditer(r"([a-z0-9]+)\[([\d,]*)\]", mt.group(1))
            ]
        out_bytes = sum(shapes)
        # replica group size
        P = 1
        mg = _GROUPS_RE.search(line)
        if mg:
            P = len([x for x in mg.group(1).split(",") if x.strip() != ""])
        else:
            mi = _GROUPS_IOTA_RE.search(line)
            if mi:
                P = int(mi.group(2))  # [groups, group_size]
        if P <= 1:
            continue
        frac = (P - 1) / P
        if kind == "all-gather":
            t = out_bytes * frac
        elif kind == "reduce-scatter":
            t = out_bytes * (P - 1)  # input = out * P
        elif kind == "all-reduce":
            t = 2 * out_bytes * frac
        elif kind == "all-to-all":
            t = out_bytes * frac
        else:  # collective-permute
            t = out_bytes
        counts[kind] = counts.get(kind, 0) + 1
        raw[kind] = raw.get(kind, 0) + out_bytes
        traffic += t
    return CollectiveStats(counts, raw, traffic)


def roofline_terms(flops_per_chip: float, bytes_per_chip: float,
                   collective_traffic: float) -> Dict[str, float]:
    t_compute = flops_per_chip / PEAK_FLOPS
    t_memory = bytes_per_chip / HBM_BW
    t_coll = collective_traffic / COLLECTIVE_BW
    dominant = max(
        ("compute", t_compute), ("memory", t_memory), ("collective", t_coll),
        key=lambda kv: kv[1],
    )[0]
    bound = max(t_compute, t_memory, t_coll)
    return {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "step_lower_bound_s": bound,
        # fraction of the roofline-optimal step that compute occupies:
        # 1.0 => perfectly compute-bound
        "compute_fraction_of_bound": t_compute / bound if bound > 0 else 0.0,
    }


def count_params(tree) -> int:
    import jax
    import numpy as np

    return int(sum(np.prod(x.shape) for x in jax.tree.leaves(tree)))


def active_param_fraction_scaling(path: str) -> float | None:
    """Weight for 'active' parameter counting; see model_flops."""
    return None


def model_flops(cfg, params_tree, n_tokens: int) -> Dict[str, float]:
    """MODEL_FLOPS = 6 * N * D with N = non-embedding params (active experts
    only for MoE), D = tokens processed. Exact, from the param pytree."""
    import jax
    import numpy as np

    total = 0.0
    active = 0.0
    moe_scale = (cfg.num_experts_per_tok / cfg.num_experts) if cfg.num_experts else 1.0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params_tree)[0]:
        p = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        n = float(np.prod(leaf.shape))
        if "embed/table" in p or "unembed" in p:
            continue  # embedding lookups are not matmul FLOPs
        total += n
        if re.search(r"moe/w_(gate|up|down)", p):
            active += n * moe_scale
        else:
            active += n
    return {
        "n_params_nonembed": total,
        "n_params_active": active,
        "model_flops": 6.0 * active * n_tokens,
    }
