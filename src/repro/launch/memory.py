"""Trace-level peak-memory estimation for the streaming statistics engine.

XLA's per-backend memory analysis is unavailable on CPU, but the question
the streaming engine has to answer — "does any intermediate scale with N?"
— is visible in the jaxpr: every equation output is an intermediate buffer
the program materializes at some point. `peak_intermediate_bytes` walks the
(closed) jaxpr of a function, recursing into sub-jaxprs (scan/cond/pjit/
remat bodies), and returns the size of the single largest intermediate.

This is what the chunked-training tests assert on (a chunked million-point
loss must have no intermediate anywhere near N * M) and what the benchmark
harness reports as its peak-memory estimate. It is an estimate of the
dominating buffer, not a liveness analysis — good for catching O(N * M)
materialization, not for byte-exact accounting.
"""
from __future__ import annotations

from typing import Any, Callable, List, Tuple


def _walk_jaxpr(jaxpr, seen: List[Tuple[Tuple[int, ...], str, int]]) -> None:
    for eqn in jaxpr.eqns:
        for v in eqn.outvars:
            aval = getattr(v, "aval", None)
            if aval is not None and hasattr(aval, "shape") and hasattr(aval, "dtype"):
                nbytes = int(aval.size) * aval.dtype.itemsize
                seen.append((tuple(aval.shape), str(aval.dtype), nbytes))
        for val in eqn.params.values():
            for sub in _sub_jaxprs(val):
                _walk_jaxpr(sub, seen)


def _sub_jaxprs(val: Any):
    if hasattr(val, "jaxpr"):  # ClosedJaxpr
        yield val.jaxpr
    elif hasattr(val, "eqns"):  # raw Jaxpr
        yield val
    elif isinstance(val, (list, tuple)):
        for item in val:
            yield from _sub_jaxprs(item)


def intermediate_report(fn: Callable, *args, top: int = 8, **kwargs):
    """The `top` largest intermediates of `fn(*args)` as
    [(shape, dtype, bytes)], largest first. Traces only — never executes."""
    import jax

    closed = jax.make_jaxpr(lambda *a: fn(*a, **kwargs))(*args)
    seen: List[Tuple[Tuple[int, ...], str, int]] = []
    _walk_jaxpr(closed.jaxpr, seen)
    best = {}
    for shape, dtype, nbytes in seen:
        best[(shape, dtype)] = nbytes
    rows = sorted(((s, d, b) for (s, d), b in best.items()), key=lambda r: -r[2])
    return rows[:top]


def peak_intermediate_bytes(fn: Callable, *args, **kwargs) -> int:
    """Size in bytes of the largest single intermediate `fn(*args)` creates."""
    rows = intermediate_report(fn, *args, top=1, **kwargs)
    return rows[0][2] if rows else 0
