"""Trace-level peak-memory estimation for the streaming statistics engine.

XLA's per-backend memory analysis is unavailable on CPU, but the question
the streaming engine has to answer — "does any intermediate scale with N?"
— is visible in the jaxpr: every equation output is an intermediate buffer
the program materializes at some point.

The walk itself now lives in `repro.analysis.jaxpr_check`, which also
classifies each intermediate's scaling class by tracing at two problem
sizes (`assert_no_scaling` is what the tests state their guarantee
through). This module keeps the original byte-level entry points as thin
wrappers for the benchmark harness and for callers that want a number, not
a class. The old walker here also had a real blind spot — it recursed into
list/tuple-valued eqn params only, silently skipping jaxprs nested under
dict-valued params (custom_vjp bodies) — which the shared analyzer walk
fixes.
"""
from __future__ import annotations

from typing import Callable, List, Tuple

from repro.analysis.jaxpr_check import sub_jaxprs, trace_intermediates

# backward-compatible alias: the fixed walker (handles dict-valued params)
_sub_jaxprs = sub_jaxprs


def intermediate_report(fn: Callable, *args, top: int = 8, **kwargs):
    """The `top` largest intermediates of `fn(*args)` as
    [(shape, dtype, bytes)], largest first. Traces only — never executes."""
    best = {}
    for shape, dtype, nbytes, _, _ in trace_intermediates(fn, *args, **kwargs):
        best[(shape, dtype)] = nbytes
    rows: List[Tuple[Tuple[int, ...], str, int]] = sorted(
        ((s, d, b) for (s, d), b in best.items()), key=lambda r: -r[2])
    return rows[:top]


def peak_intermediate_bytes(fn: Callable, *args, **kwargs) -> int:
    """Size in bytes of the largest single intermediate `fn(*args)` creates."""
    rows = intermediate_report(fn, *args, top=1, **kwargs)
    return rows[0][2] if rows else 0
