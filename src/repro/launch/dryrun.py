import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell with
ShapeDtypeStruct inputs (no allocation), print memory/cost analysis, parse
collective traffic, and persist a JSON report per cell under
experiments/dryrun/.

    PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all --mesh both

The two XLA_FLAGS lines above MUST run before any other import (jax locks the
device count at first init); this module is the only place in the repo that
requests 512 host devices.
"""

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from pathlib import Path  # noqa: E402

import jax  # noqa: E402

from repro.configs.base import ARCH_IDS, SHAPES, cell_applicable, get_config  # noqa: E402
from repro.launch import hlo_cost, roofline  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.steps import make_step  # noqa: E402

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: Path,
             force: bool = False) -> dict:
    mesh_name = "multipod" if multi_pod else "pod"
    out_path = out_dir / f"{arch}_{shape_name}_{mesh_name}.json"
    if out_path.exists() and not force:
        rec = json.loads(out_path.read_text())
        print(f"[cached] {arch} x {shape_name} x {mesh_name}: {rec.get('status')}")
        return rec

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "kind": shape.kind, "seq_len": shape.seq_len, "global_batch": shape.global_batch,
    }
    ok, why = cell_applicable(cfg, shape)
    if not ok:
        rec.update(status="skipped", reason=why)
        out_path.write_text(json.dumps(rec, indent=2))
        print(f"[skip]   {arch} x {shape_name}: {why}")
        return rec

    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        n_chips = mesh.size
        t0 = time.time()
        with mesh:
            bundle = make_step(shape.kind, cfg, shape, mesh)
            lowered = bundle.lower()
            t_lower = time.time() - t0
            t0 = time.time()
            compiled = lowered.compile()
            t_compile = time.time() - t0

        ma = compiled.memory_analysis()
        from repro import compat

        ca = compat.xla_cost_analysis(compiled) or {}
        hlo_text = compiled.as_text()
        # trip-count-aware accounting (XLA's cost_analysis counts scan bodies
        # once — see launch/hlo_cost.py); XLA's raw numbers kept for reference
        cost = hlo_cost.analyze(hlo_text)

        flops = float(cost.flops)
        bytes_accessed = float(cost.bytes)
        terms = roofline.roofline_terms(flops, bytes_accessed, cost.coll_traffic)

        params_a = bundle.abstract_args[0]
        n_tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
        mf = roofline.model_flops(cfg, params_a, n_tokens)
        if shape.kind != "train":
            # 6ND counts fwd+bwd; prefill/decode are forward-only => 2ND
            mf["model_flops"] /= 3.0
        useful = mf["model_flops"] / (flops * n_chips) if flops else 0.0

        rec.update(
            status="ok",
            n_chips=n_chips,
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            memory={
                "argument_bytes": ma.argument_size_in_bytes,
                "output_bytes": ma.output_size_in_bytes,
                "temp_bytes": ma.temp_size_in_bytes,
                "alias_bytes": ma.alias_size_in_bytes,
                "peak_hbm_bytes_est": ma.argument_size_in_bytes
                + ma.output_size_in_bytes + ma.temp_size_in_bytes
                - ma.alias_size_in_bytes,
            },
            flops_per_chip=flops,
            bytes_per_chip=bytes_accessed,
            xla_flops_scan_once=float(ca.get("flops", 0.0)),
            xla_bytes_scan_once=float(ca.get("bytes accessed", 0.0)),
            collectives={
                "counts": cost.coll_counts,
                "raw_bytes_per_chip": cost.coll_raw,
                "traffic_bytes_per_chip": cost.coll_traffic,
            },
            roofline=terms,
            model_flops=mf,
            useful_compute_fraction=useful,
            n_params_total=roofline.count_params(params_a),
        )
        hbm_gb = rec["memory"]["peak_hbm_bytes_est"] / 2**30
        print(
            f"[ok]     {arch} x {shape_name} x {mesh_name}: "
            f"compile {t_compile:.1f}s, {hbm_gb:.2f} GiB/chip, "
            f"dominant={terms['dominant']} bound={terms['step_lower_bound_s']*1e3:.2f} ms "
            f"useful={useful:.2f}"
        )
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
        print(f"[FAIL]   {arch} x {shape_name} x {mesh_name}: {type(e).__name__}: {e}")
    out_path.write_text(json.dumps(rec, indent=2))
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all", help="arch id or 'all'")
    ap.add_argument("--shape", default="all", help="shape cell or 'all'")
    ap.add_argument("--mesh", default="both", choices=["pod", "multipod", "both"])
    ap.add_argument("--out", default=str(OUT_DIR))
    ap.add_argument("--force", action="store_true", help="recompute cached cells")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"pod": [False], "multipod": [True], "both": [False, True]}[args.mesh]
    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                rec = run_cell(arch, shape, multi, out_dir, force=args.force)
                n_fail += rec.get("status") == "error"
    print(f"done; {n_fail} failures")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
