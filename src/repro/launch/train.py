"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --preset smoke \
        --steps 20 --batch 8 --seq 128

On this box it runs on the host mesh (1 CPU device — same code path as a
pod: the mesh is the only difference). `--preset full` uses the assigned
architecture config unchanged (for real hardware); `--preset smoke` uses the
reduced same-family config. The loop checkpoints/resumes via runtime.
"""
from __future__ import annotations

import argparse

import jax

from repro.configs.base import ShapeCell, get_config, get_smoke_config
from repro.data.synthetic import TokenStream
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.launch.steps import make_train_step
from repro.optim import adam_init
from repro.runtime.train_loop import LoopConfig, TrainLoop


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--preset", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh", default="host", choices=["host", "pod", "multipod"])
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.preset == "smoke" else get_config(args.arch)
    shape = ShapeCell("cli", args.seq, args.batch, "train")
    mesh = (make_host_mesh() if args.mesh == "host"
            else make_production_mesh(multi_pod=args.mesh == "multipod"))

    with mesh:
        bundle = make_train_step(cfg, shape, mesh, batch=args.batch)
        step_fn = bundle.jitted()
        model_init = bundle.abstract_args[0]
        key = jax.random.PRNGKey(0)
        from repro.models.model_zoo import build

        params = jax.device_put(build(cfg).init(key), bundle.in_shardings[0])
        from repro.launch.steps import default_adam

        opt = jax.device_put(adam_init(params, default_adam(cfg)), bundle.in_shardings[1])
        data = TokenStream(cfg, shape, batch=args.batch)

        loop = TrainLoop(step_fn, params, opt, data,
                         LoopConfig(ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                                    log_every=1),
                         shardings=(bundle.in_shardings[0], bundle.in_shardings[1]))
        final = loop.run(args.steps)
        print("final metrics:", final)


if __name__ == "__main__":
    main()
