"""MiniCPM-2B: llama-like dense LM trained with the WSD schedule
(the optim substrate implements wsd_schedule for it). [arXiv:2404.06395]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b",
    family="dense",
    num_layers=40,
    d_model=2304,
    num_heads=36,
    num_kv_heads=36,
    head_dim=64,
    d_ff=5760,
    vocab_size=122753,
    tie_embeddings=True,
)

SMOKE_CONFIG = ModelConfig(
    name="minicpm-2b-smoke",
    family="dense",
    num_layers=3,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=160,
    vocab_size=512,
    tie_embeddings=True,
    param_dtype="float32",
    compute_dtype="float32",
    logits_chunk=64,
    remat=False,
)
