"""Whisper-small: 12L enc + 12L dec, conv/mel frontend stubbed (input_specs
provides the 1500 post-conv frame embeddings). [arXiv:2212.04356]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="audio",
    num_layers=12,
    encoder_layers=12,
    encoder_frames=1500,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    d_ff=3072,
    vocab_size=51865,
    act="gelu",
    tie_embeddings=True,
)

SMOKE_CONFIG = ModelConfig(
    name="whisper-small-smoke",
    family="audio",
    num_layers=2,
    encoder_layers=2,
    encoder_frames=24,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab_size=512,
    act="gelu",
    tie_embeddings=True,
    param_dtype="float32",
    compute_dtype="float32",
    logits_chunk=64,
    remat=False,
)
