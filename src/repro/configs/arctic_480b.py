"""Snowflake Arctic-style 480B MoE: 128 experts top-2 + dense residual FFN.
[hf:Snowflake/snowflake-arctic-base; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=4864,
    vocab_size=32000,
    num_experts=128,
    num_experts_per_tok=2,
    moe_dense_residual=True,
    # bf16 Adam moments: 480B params would not fit fp32 m/v on one v5e pod
    optimizer_state_dtype="bfloat16",
    # Perf iteration B1 (§Perf): microbatches 4 -> 1. Each microbatch
    # re-gathers the FSDP-sharded 27 GB/layer expert weights, so mb=4 made
    # the step collective-bound (34.6 s); mb=1 is faster on BOTH the
    # collective and memory terms (22.4 s). The mb/grad_accum knobs remain
    # the documented memory<->traffic trade for tighter-HBM deployments.
    microbatches=1,
    grad_accum_dtype="bfloat16",
)

SMOKE_CONFIG = ModelConfig(
    name="arctic-480b-smoke",
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=96,
    vocab_size=512,
    num_experts=8,
    num_experts_per_tok=2,
    moe_dense_residual=True,
    param_dtype="float32",
    compute_dtype="float32",
    logits_chunk=64,
    remat=False,
)
