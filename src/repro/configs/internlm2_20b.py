"""InternLM2-20B: dense GQA LM. [arXiv:2403.17297]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internlm2-20b",
    family="dense",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=92544,
)

SMOKE_CONFIG = ModelConfig(
    name="internlm2-20b-smoke",
    family="dense",
    num_layers=3,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=192,
    vocab_size=512,
    param_dtype="float32",
    compute_dtype="float32",
    logits_chunk=64,
    remat=False,
)
