"""Config system: architecture configs, input-shape cells, registries.

Every assigned architecture is a `ModelConfig` in its own module
(src/repro/configs/<id>.py) registered here, selectable via ``--arch <id>``
in the launchers. Input-shape cells (train_4k / prefill_32k / decode_32k /
long_500k) are global and pair with every arch per the assignment.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Callable, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # defaults to d_model // num_heads

    # --- MoE ---
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_dense_residual: bool = False  # arctic: dense FFN residual alongside MoE
    capacity_factor: float = 1.25

    # --- attention pattern ---
    # per-layer window sizes; -1 = full/global attention. Length must divide
    # num_layers (the pattern tiles). E.g. gemma3: (1024,)*5 + (-1,)
    window_pattern: Tuple[int, ...] = (-1,)
    # per-layer temporal-mixer types for hybrid archs; tiles like windows.
    # "attn" | "rglru" | "rwkv"
    mixer_pattern: Tuple[str, ...] = ("attn",)

    rope_theta: float = 10000.0

    # --- enc-dec / multimodal stubs ---
    encoder_layers: int = 0
    encoder_frames: int = 0  # whisper: post-conv frame count (stub frontend)
    frontend_tokens: int = 0  # internvl: ViT patch tokens (stub frontend)

    # --- numerics / structure ---
    norm_eps: float = 1e-6
    act: str = "swiglu"  # swiglu | gelu
    tie_embeddings: bool = False
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    optimizer_state_dtype: str = "float32"
    remat: bool = True
    scan_layers: bool = True
    logits_chunk: int = 512  # sequence-chunked CE (never materialize B,S,V)

    # rwkv6
    rwkv_head_dim: int = 64

    # gradient accumulation: split the global batch into `microbatches`
    # sequential steps (activation memory / microbatches); accumulate in
    # `grad_accum_dtype` (bf16 for arctic: a fp32 accumulator alone is
    # 7.5 GB/chip at 480B params on one pod)
    microbatches: int = 1
    grad_accum_dtype: str = "float32"

    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    def padded_vocab(self) -> int:
        """Megatron-style vocab padding: embedding/logit tables are allocated
        at a multiple of 256 so the vocab dim shards evenly at any TP <= 256
        (whisper 51865, minicpm 122753, internvl 92553 are odd). Padded ids
        are masked to -inf in the CE/logits paths."""
        return -(-self.vocab_size // 256) * 256

    def padded_heads(self, tp: int) -> int:
        """Query heads FLAT-padded to the next multiple of tp so the head dim
        shards evenly over the model axis. Train/prefill attention repeats kv
        heads to the (padded) query-head axis through an explicit head->kv
        gather map, so no group structure is required of the pad — smollm
        pads 15 -> 16 (6.7% waste) instead of the group-preserving 15 -> 80
        (433%); perf iteration A1 in EXPERIMENTS.md §Perf. Decode uses the
        grouped-unpadded path (heads are not sharded at decode)."""
        return -(-self.num_heads // tp) * tp

    def layer_windows(self) -> Tuple[int, ...]:
        reps = -(-self.num_layers // len(self.window_pattern))
        return (self.window_pattern * reps)[: self.num_layers]

    def layer_mixers(self) -> Tuple[str, ...]:
        reps = -(-self.num_layers // len(self.mixer_pattern))
        return (self.mixer_pattern * reps)[: self.num_layers]

    # Exact parameter counts are computed from the (eval_shape'd) param
    # pytree in launch/roofline.py — no analytic approximation here.


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}

ARCH_IDS = [
    "arctic-480b",
    "moonshot-v1-16b-a3b",
    "whisper-small",
    "gemma3-4b",
    "smollm-360m",
    "minicpm-2b",
    "internlm2-20b",
    "recurrentgemma-2b",
    "rwkv6-7b",
    "internvl2-2b",
]

_MODULE_FOR = {a: "repro.configs." + a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULE_FOR:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULE_FOR)}")
    mod = importlib.import_module(_MODULE_FOR[arch])
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    mod = importlib.import_module(_MODULE_FOR[arch])
    return mod.SMOKE_CONFIG


def sub_quadratic(cfg: ModelConfig) -> bool:
    """True if the arch can run long_500k (no full-attention layer)."""
    if cfg.family in ("ssm",):
        return True
    if cfg.family == "hybrid":
        # hybrid qualifies if every attention layer is windowed
        mixers, windows = cfg.layer_mixers(), cfg.layer_windows()
        return all(m != "attn" or w > 0 for m, w in zip(mixers, windows))
    return False


def cell_applicable(cfg: ModelConfig, shape: ShapeCell) -> tuple[bool, str]:
    """(runnable, reason-if-not) for an (arch x shape) cell."""
    if shape.name == "long_500k" and not sub_quadratic(cfg):
        return False, "full-attention arch: 500k decode needs sub-quadratic attention (DESIGN.md)"
    return True, ""
