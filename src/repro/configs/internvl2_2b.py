"""InternVL2-2B: InternViT frontend (STUB: 256 precomputed patch embeddings
via input_specs) + InternLM2-1.8B-style decoder. [arXiv:2404.16821]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=92553,
    frontend_tokens=256,
)

SMOKE_CONFIG = ModelConfig(
    name="internvl2-2b-smoke",
    family="vlm",
    num_layers=3,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    frontend_tokens=16,
    param_dtype="float32",
    compute_dtype="float32",
    logits_chunk=64,
    remat=False,
)
