"""Moonshot/Moonlight 16B-A3B MoE: 64 experts top-6.
[hf:moonshotai/Moonlight-16B-A3B; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=163840,
    num_experts=64,
    num_experts_per_tok=6,
)

SMOKE_CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b-smoke",
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=48,
    vocab_size=512,
    num_experts=8,
    num_experts_per_tok=3,
    param_dtype="float32",
    compute_dtype="float32",
    logits_chunk=64,
    remat=False,
)
