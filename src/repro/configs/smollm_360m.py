"""SmolLM-360M: llama-arch small dense LM. [hf:HuggingFaceTB/SmolLM-360M]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m",
    family="dense",
    num_layers=32,
    d_model=960,
    num_heads=15,
    num_kv_heads=5,
    head_dim=64,
    d_ff=2560,
    vocab_size=49152,
    tie_embeddings=True,
)

SMOKE_CONFIG = ModelConfig(
    name="smollm-360m-smoke",
    family="dense",
    num_layers=3,
    d_model=60,
    num_heads=3,
    num_kv_heads=1,
    head_dim=20,
    d_ff=160,
    vocab_size=512,
    tie_embeddings=True,
    param_dtype="float32",
    compute_dtype="float32",
    logits_chunk=64,
    remat=False,
)
