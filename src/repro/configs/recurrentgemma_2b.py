"""RecurrentGemma-2B (Griffin): RG-LRU + local attention, 2:1 pattern
(two recurrent blocks then one window-2048 MQA layer). [arXiv:2402.19427]

Every attention layer is windowed => sub-quadratic => runs long_500k."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    mixer_pattern=("rglru", "rglru", "attn"),
    window_pattern=(2048, 2048, 2048),  # applies to the attn positions
    tie_embeddings=True,
)

SMOKE_CONFIG = ModelConfig(
    name="recurrentgemma-2b-smoke",
    family="hybrid",
    num_layers=8,  # 2 periods + remainder (rglru, rglru): both segments
    d_model=64,
    num_heads=4,
    num_kv_heads=1,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    mixer_pattern=("rglru", "rglru", "attn"),
    window_pattern=(16, 16, 16),
    tie_embeddings=True,
    param_dtype="float32",
    compute_dtype="float32",
    logits_chunk=64,
    remat=False,
)
