from repro.configs.base import ARCH_IDS, SHAPES, ModelConfig, ShapeCell, cell_applicable, get_config, get_smoke_config, sub_quadratic

__all__ = ["ARCH_IDS", "SHAPES", "ModelConfig", "ShapeCell", "cell_applicable", "get_config", "get_smoke_config", "sub_quadratic"]
