"""Gemma3-4B-style dense LM: 5 local (sliding 1024) : 1 global layer pattern,
huge 262k vocab, 128k context. [hf:google/gemma-3-*-pt]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-4b",
    family="dense",
    num_layers=34,
    d_model=2560,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=10240,
    vocab_size=262144,
    window_pattern=(1024, 1024, 1024, 1024, 1024, -1),  # 5 local : 1 global
    rope_theta=1_000_000.0,
    tie_embeddings=True,
)

SMOKE_CONFIG = ModelConfig(
    name="gemma3-4b-smoke",
    family="dense",
    num_layers=8,  # 1 full period (6) + remainder (2): exercises both segments
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    window_pattern=(16, 16, 16, 16, 16, -1),
    tie_embeddings=True,
    param_dtype="float32",
    compute_dtype="float32",
    logits_chunk=64,
    remat=False,
)
