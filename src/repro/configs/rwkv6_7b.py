"""RWKV-6 "Finch" 7B: attention-free, data-dependent decay, rwkv
channel-mix FFN. Sub-quadratic => runs long_500k. [arXiv:2404.05892]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    num_layers=32,
    d_model=4096,
    num_heads=64,  # wkv heads = d_model / rwkv_head_dim
    num_kv_heads=64,
    d_ff=14336,
    vocab_size=65536,
    mixer_pattern=("rwkv",),
    rwkv_head_dim=64,
)

SMOKE_CONFIG = ModelConfig(
    name="rwkv6-7b-smoke",
    family="ssm",
    num_layers=3,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab_size=512,
    mixer_pattern=("rwkv",),
    rwkv_head_dim=16,
    param_dtype="float32",
    compute_dtype="float32",
    logits_chunk=64,
    remat=False,
)
