"""Paper Fig 1a: average time per inference iteration vs dataset size N.

One iteration = value+grad of the Bayesian GP-LVM bound (the paper's
optimizer step cost is dominated by it). Setup mirrors §4: synthetic data,
Q=1, D=3, M=100 inducing points. We report jnp-backend times on this CPU
(the Pallas TPU kernels run in interpret mode here — their perf story is the
roofline, not CPU wall-time) and verify the paper's linearity-in-N claim.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, time_call, validate_psi_kernel
from repro.core import gplvm
from repro.data.synthetic import gplvm_synthetic
from repro.gp import get

SIZES = (1024, 2048, 4096, 8192, 16384)
M = 100


def run(sizes=SIZES, kernel_name: str = "rbf") -> list[str]:
    validate_psi_kernel(kernel_name)
    out = []
    key = jax.random.PRNGKey(0)
    kern = get(kernel_name)(1)
    times = {}
    for N in sizes:
        _, Y = gplvm_synthetic(key, N=N, D=3, Q=1)
        Y = Y.astype(jnp.float32)
        params = gplvm.init_params(key, np.asarray(Y), Q=1, M=M, kernel=kern)
        vg = jax.jit(jax.value_and_grad(lambda p: gplvm.loss(p, Y, kernel=kern)))
        t = time_call(vg, params, warmup=1, iters=3)
        times[N] = t
        out.append(row(f"gp_scaling_N{N}", t, f"per_point_us={t/N*1e6:.3f}"))
    # linearity check (paper: cost scales linearly with N)
    r = times[sizes[-1]] / times[sizes[0]]
    ideal = sizes[-1] / sizes[0]
    out.append(row("gp_scaling_linearity", 0.0,
                   f"t(N_max)/t(N_min)={r:.2f}_vs_ideal={ideal:.1f}"))
    return out


if __name__ == "__main__":
    print("\n".join(run()))
