"""CPU wall-time sanity bench: one train step per reduced-config architecture
(catches order-of-magnitude regressions in the model stack; the full-scale
perf story lives in the roofline table)."""
from __future__ import annotations

import jax

from benchmarks.common import row
from repro.configs.base import ARCH_IDS, ShapeCell, get_smoke_config
from repro.data.synthetic import TokenStream
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import default_adam, make_train_step
from repro.models.model_zoo import build
from repro.optim import adam_init

CELL = ShapeCell("bench", 128, 4, "train")


def run(archs=ARCH_IDS) -> list[str]:
    out = []
    mesh = make_host_mesh()
    with mesh:
        for arch in archs:
            cfg = get_smoke_config(arch)
            bundle = make_train_step(cfg, CELL, mesh, batch=CELL.global_batch)
            step = bundle.jitted()
            params = build(cfg).init(jax.random.PRNGKey(0))
            opt = adam_init(params, default_adam(cfg))
            batch = TokenStream(cfg, CELL).next()

            # donated buffers: thread state through timed steps
            import time as _time

            params, opt, m = step(params, opt, batch)  # compile + warmup
            jax.block_until_ready(m["loss"])
            times = []
            for _ in range(3):
                t0 = _time.perf_counter()
                params, opt, m = step(params, opt, batch)
                jax.block_until_ready(m["loss"])
                times.append(_time.perf_counter() - t0)
            t = sorted(times)[1]
            tok_s = CELL.global_batch * CELL.seq_len / t
            out.append(row(f"lm_step_{arch}", t, f"tok_per_s={tok_s:.0f}"))
    return out


if __name__ == "__main__":
    print("\n".join(run()))
