"""Paper Fig 1b: fraction of iteration time spent in the INDISTRIBUTABLE
computation — the O(M^3) bound epilogue that runs replicated after the psum —
versus the distributable per-datapoint statistics.

The paper's claim: this fraction is small and shrinks with N, so more
machines keep helping. We time the two phases separately (both jitted).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, time_call, validate_psi_kernel
from repro.core import gplvm
from repro.data.synthetic import gplvm_synthetic
from repro.gp import get

SIZES = (1024, 4096, 16384)
M = 100


def run(sizes=SIZES, kernel_name: str = "rbf") -> list[str]:
    validate_psi_kernel(kernel_name)
    out = []
    key = jax.random.PRNGKey(0)
    kern = get(kernel_name)(1)
    for N in sizes:
        _, Y = gplvm_synthetic(key, N=N, D=3, Q=1)
        Y = Y.astype(jnp.float32)
        params = gplvm.init_params(key, np.asarray(Y), Q=1, M=M, kernel=kern)

        stats_fn = jax.jit(lambda p: gplvm.local_stats(p, Y, kernel=kern))
        stats = stats_fn(params)
        epilogue = jax.jit(
            lambda p, s: gplvm.bound_from_stats(
                p, s, gplvm.kl_qp(p["q_mu"], p["q_logS"]), Y.shape[1], kernel=kern))

        t_stats = time_call(stats_fn, params, warmup=1, iters=3)
        t_epi = time_call(epilogue, params, stats, warmup=1, iters=3)
        frac = t_epi / (t_epi + t_stats)
        out.append(row(f"indistributable_N{N}", t_epi,
                       f"stats_us={t_stats*1e6:.0f},fraction={frac*100:.1f}%"))
    return out


if __name__ == "__main__":
    print("\n".join(run()))
