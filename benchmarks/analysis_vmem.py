"""Static VMEM budget table per Pallas kernel (BENCH_vmem.json).

Not a timing benchmark: the rows come from `repro.analysis.pallas_audit`,
which computes each kernel's per-grid-step VMEM residency (double-buffered
streamed blocks + constant-index resident accumulators + kernel-body
workspace) straight from the BlockSpecs, without lowering or running
anything. The table is the input the tile autotuner (ROADMAP item 2) will
consume when TILE_N/TILE_M stop being hand-picked constants — and the
committed trajectory future kernel PRs diff their working sets against.
"""
from __future__ import annotations

from benchmarks.common import SCHEMA_VERSION, row


def run(*, smoke: bool = False):
    """Returns (csv_rows, json_doc). `smoke` audits at smaller sizes."""
    from repro.analysis.pallas_audit import (Problem, VMEM_BUDGET_BYTES,
                                             audit_kernels, vmem_table)

    problem = Problem(N=1024, M=256, Q=2, D=2) if smoke else Problem()
    audits = audit_kernels(problem=problem)
    rows = vmem_table(audits)
    csv = [
        row(f"vmem_{r['kernel']}", 0.0,
            f"vmem_mb={r['vmem_estimate_bytes'] / 2**20:.2f},"
            f"resident_kb={r['resident_bytes'] / 1024:.1f},"
            f"fits={int(r['fits'])}")
        for r in rows
    ]
    doc = {
        "meta": {
            "bench": "vmem",
            "schema_version": SCHEMA_VERSION,
            "smoke": bool(smoke),
            "problem": {"N": problem.N, "M": problem.M,
                        "Q": problem.Q, "D": problem.D},
            "vmem_budget_bytes": VMEM_BUDGET_BYTES,
        },
        "rows": rows,
    }
    return csv, doc


if __name__ == "__main__":
    csv, _ = run(smoke=True)
    print("\n".join(csv))
