"""Streaming sufficient-statistics engine benchmark (BENCH_gp.json).

Time-per-point and a trace-level peak-memory estimate versus N for the
streaming (`chunk=`) engine, on the jnp and fused backends, up to a million
datapoints on whatever this host is — the harness future perf PRs measure
against. A "pallas-interpret" row exercises the fused Pallas kernel body
(off-TPU it only runs for small N; see repro.kernels.ops).

Rows time the jitted GP-LVM negative-ELBO (pass="loss", the predict-time
statistics cost) and its value_and_grad (pass="step", the training step
cost, timed at the smaller sizes so the full sweep stays minutes-scale),
plus the exact-path SGPR loss — all chunked, so nothing materializes an
(N, M) workspace. Each row's headline memory signal is its `scaling_class`
from repro.analysis (the worst intermediate's growth class along N, e.g.
"O(N)"); the raw `peak_intermediate_bytes` column stays for trajectory
continuity. Rows whose traced program changes structure between N and 2N
(the fused op's interpret/jnp dispatch at FUSED_INTERPRET_MAX_N) report
"n/a(dispatch-boundary)" instead of a class.

Fused "step" rows carry a `bwd_backend` field: the reverse pass of the
fused op is itself dispatched (Pallas reverse kernel vs streaming jnp scan,
see repro.kernels.ops), and the pallas-interpret rows time BOTH kernel
bodies end-to-end through jax.value_and_grad.

"singlestat-*" rows time the single-statistic ops (backend="pallas":
kfu/psi1/psi2), whose reverse passes are now kernelized on the same tile
scheme — their "step" rows drive jax.value_and_grad through the
single-statistic forward AND reverse kernel bodies.
"""
from __future__ import annotations

import functools

import jax
import numpy as np

from benchmarks.common import row, time_call, validate_psi_kernel
from repro.analysis import AnalysisError, scaling_class
from repro.core import gplvm
from repro.data.synthetic import gplvm_synthetic
from repro.gp import get
from repro.launch.memory import peak_intermediate_bytes

SIZES = (16384, 65536, 262144, 1048576)
SMOKE_SIZES = (1024, 4096)
GRAD_MAX_N = 65536  # value_and_grad rows are timed up to this size
M, Q, D = 32, 1, 2
CHUNK = 4096
BACKENDS = ("jnp", "fused")


def _json_row(model, backend, pass_, N, seconds, peak_bytes, cls,
              bwd_backend=None):
    # the engine chunk only steers the jnp path; the fused/pallas ops stream
    # at their own internal granularity, so their rows must not claim it.
    # bwd_backend is only meaningful for "step" rows of the kernelized
    # backends (fused and singlestat-* alike: the grad dispatch knob).
    return {
        "section": "gp_stream", "model": model, "backend": backend,
        "pass": pass_, "N": int(N), "M": M,
        "chunk": CHUNK if backend == "jnp" else None,
        "bwd_backend": bwd_backend if pass_ == "step" else None,
        "seconds": float(seconds),
        "us_per_point": float(seconds / N * 1e6),
        "scaling_class": cls,
        "peak_intermediate_bytes": int(peak_bytes),
    }


def _bench(fn, *args, N):
    jfn = jax.jit(fn)
    t = time_call(jfn, *args, warmup=1, iters=1 if N > GRAD_MAX_N else 2)
    peak = peak_intermediate_bytes(fn, *args)
    try:
        cls = scaling_class(fn, *args, axis="N", sizes={"N": N, "M": M})
    except AnalysisError:
        # the trace at 2N crosses a size-dependent dispatch branch (e.g.
        # FUSED_INTERPRET_MAX_N): no single class describes the row
        cls = "n/a(dispatch-boundary)"
    return t, peak, cls


def run(sizes=SIZES, kernel_name: str = "rbf", *, smoke: bool = False):
    """Returns (csv_rows, json_rows)."""
    validate_psi_kernel(kernel_name)
    if smoke:
        sizes = SMOKE_SIZES
    # the fused/pallas ops are RBF-only; other psi-capable kernels sweep jnp
    backends = BACKENDS if kernel_name == "rbf" else ("jnp",)
    csv, rows = [], []
    key = jax.random.PRNGKey(0)
    kern = get(kernel_name)(Q)

    for N in sizes:
        _, Y = gplvm_synthetic(key, N=N, D=D, Q=Q)
        params = gplvm.init_params(key, np.asarray(Y), Q=Q, M=M, kernel=kern)
        for backend in backends:
            loss = functools.partial(gplvm.loss, kernel=kern, backend=backend,
                                     chunk=CHUNK)
            t, peak, cls = _bench(loss, params, Y, N=N)
            rows.append(_json_row("gplvm", backend, "loss", N, t, peak, cls))
            csv.append(row(f"gp_stream_gplvm_{backend}_loss_N{N}", t,
                           f"per_point_us={t/N*1e6:.3f},peak_mb={peak/1e6:.1f}"))
            if N <= GRAD_MAX_N:
                vg = jax.value_and_grad(loss)
                t, peak, cls = _bench(vg, params, Y, N=N)
                bwd = "auto" if backend == "fused" else None
                rows.append(_json_row("gplvm", backend, "step", N, t, peak, cls,
                                      bwd_backend=bwd))
                csv.append(row(f"gp_stream_gplvm_{backend}_step_N{N}", t,
                               f"per_point_us={t/N*1e6:.3f},peak_mb={peak/1e6:.1f}"))

    # exact-path (SGPR) streaming: matmul-bound, cheap even at 1M
    from repro.gp import SparseGPRegression

    for N in sizes:
        kx, kn = jax.random.split(jax.random.fold_in(key, N))
        X = jax.random.uniform(kx, (N, 1), jax.numpy.float32, -3.0, 3.0)
        Ys = jax.numpy.sin(2.0 * X) + 0.1 * jax.random.normal(kn, (N, 1))
        gp = SparseGPRegression(kernel=get(kernel_name)(1), M=M, chunk=CHUNK)
        p = gp.init_params(X, Ys)
        loss = gp._loss_fn()
        t, peak, cls = _bench(loss, p, X, Ys, N=N)
        rows.append(_json_row("sgpr", "jnp", "loss", N, t, peak, cls))
        csv.append(row(f"gp_stream_sgpr_jnp_loss_N{N}", t,
                       f"per_point_us={t/N*1e6:.3f},peak_mb={peak/1e6:.1f}"))

    # fused Pallas kernel bodies in interpret mode (small-N: per-grid-point
    # interpretation is Python-priced; the TPU perf story is the roofline).
    # The "step" row drives value_and_grad through BOTH kernels — forward
    # grid (i, j, kn) and the reverse kernel's grid (kn, i, j).
    from repro.kernels import ops

    n_int = min(1024, ops.FUSED_INTERPRET_MAX_N)
    if not smoke and kernel_name == "rbf":  # smoke's fused N=1024 row is interpret already
        _, Y = gplvm_synthetic(key, N=n_int, D=D, Q=Q)
        params = gplvm.init_params(key, np.asarray(Y), Q=Q, M=M, kernel=kern)
        label = "pallas-interpret" if ops.interpret_mode() else "pallas"
        loss = functools.partial(gplvm.loss, kernel=kern, backend="fused")
        t, peak, cls = _bench(loss, params, Y, N=n_int)
        rows.append(_json_row("gplvm", label, "loss", n_int, t, peak, cls))
        csv.append(row(f"gp_stream_gplvm_{label}_loss_N{n_int}", t,
                       f"per_point_us={t/n_int*1e6:.3f},peak_mb={peak/1e6:.1f}"))
        step = jax.value_and_grad(functools.partial(
            gplvm.loss, kernel=kern, backend="fused", bwd_backend="pallas"))
        t, peak, cls = _bench(step, params, Y, N=n_int)
        rows.append(_json_row("gplvm", label, "step", n_int, t, peak, cls,
                              bwd_backend="pallas"))
        csv.append(row(f"gp_stream_gplvm_{label}_step_N{n_int}", t,
                       f"per_point_us={t/n_int*1e6:.3f},peak_mb={peak/1e6:.1f}"))

    # single-statistic ops (backend="pallas"): kfu/psi1/psi2 now backward
    # through their own Pallas reverse kernels (bwd_backend dispatch in
    # repro.kernels.ops) instead of jax.vjp of the reference formulas. The
    # "step" rows time value_and_grad through both kernel bodies; runs in
    # smoke mode too so CI asserts the rows exist.
    if kernel_name == "rbf":
        label = ("singlestat-pallas-interpret" if ops.interpret_mode()
                 else "singlestat-pallas")
        _, Y = gplvm_synthetic(key, N=n_int, D=D, Q=Q)
        params = gplvm.init_params(key, np.asarray(Y), Q=Q, M=M, kernel=kern)
        loss = functools.partial(gplvm.loss, kernel=kern, backend="pallas")
        t, peak, cls = _bench(loss, params, Y, N=n_int)
        rows.append(_json_row("gplvm", label, "loss", n_int, t, peak, cls))
        csv.append(row(f"gp_stream_gplvm_{label}_loss_N{n_int}", t,
                       f"per_point_us={t/n_int*1e6:.3f},peak_mb={peak/1e6:.1f}"))
        step = jax.value_and_grad(functools.partial(
            gplvm.loss, kernel=kern, backend="pallas", bwd_backend="pallas"))
        t, peak, cls = _bench(step, params, Y, N=n_int)
        rows.append(_json_row("gplvm", label, "step", n_int, t, peak, cls,
                              bwd_backend="pallas"))
        csv.append(row(f"gp_stream_gplvm_{label}_step_N{n_int}", t,
                       f"per_point_us={t/n_int*1e6:.3f},peak_mb={peak/1e6:.1f}"))
        # exact path: the SGPR training step through the kfu reverse kernel
        kx, kn_ = jax.random.split(jax.random.fold_in(key, n_int))
        X = jax.random.uniform(kx, (n_int, 1), jax.numpy.float32, -3.0, 3.0)
        Ys = jax.numpy.sin(2.0 * X) + 0.1 * jax.random.normal(kn_, (n_int, 1))
        gp = SparseGPRegression(kernel=get(kernel_name)(1), M=M,
                                backend="pallas", bwd_backend="pallas")
        p = gp.init_params(X, Ys)
        step = jax.value_and_grad(gp._loss_fn())
        t, peak, cls = _bench(step, p, X, Ys, N=n_int)
        rows.append(_json_row("sgpr", label, "step", n_int, t, peak, cls,
                              bwd_backend="pallas"))
        csv.append(row(f"gp_stream_sgpr_{label}_step_N{n_int}", t,
                       f"per_point_us={t/n_int*1e6:.3f},peak_mb={peak/1e6:.1f}"))
    return csv, rows


if __name__ == "__main__":
    csv, _ = run(smoke=True)
    print("\n".join(csv))
