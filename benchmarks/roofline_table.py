"""§Roofline: render the per-(arch x shape x mesh) roofline table from the
dry-run JSONs (launch/dryrun.py must have populated experiments/dryrun)."""
from __future__ import annotations

import json
from pathlib import Path

from repro.configs.base import ARCH_IDS, SHAPES

DRYRUN_DIR = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def load_cells(mesh: str = "pod") -> list[dict]:
    cells = []
    for arch in ARCH_IDS:
        for shape in SHAPES:
            p = DRYRUN_DIR / f"{arch}_{shape}_{mesh}.json"
            if p.exists():
                cells.append(json.loads(p.read_text()))
    return cells


def useful_fraction(rec: dict) -> float:
    """MODEL_FLOPS / (HLO_FLOPs x chips), with the fwd-only 2ND convention
    for prefill/decode (recomputed here so older records are consistent)."""
    mf = rec.get("model_flops", {})
    n_active = mf.get("n_params_active", 0.0)
    n_tokens = rec["global_batch"] * (rec["seq_len"] if rec["kind"] != "decode" else 1)
    model = (6.0 if rec["kind"] == "train" else 2.0) * n_active * n_tokens
    denom = rec["flops_per_chip"] * rec["n_chips"]
    return model / denom if denom else 0.0


def table(mesh: str = "pod") -> str:
    lines = [
        "| arch | shape | t_comp (s) | t_mem (s) | t_coll (s) | dominant | "
        "bound (s) | HBM GiB | useful |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for rec in load_cells(mesh):
        if rec["status"] == "skipped":
            lines.append(f"| {rec['arch']} | {rec['shape']} | — | — | — | "
                         f"skipped: {rec['reason'][:40]}… | — | — | — |")
            continue
        if rec["status"] != "ok":
            lines.append(f"| {rec['arch']} | {rec['shape']} | — | — | — | ERROR | — | — | — |")
            continue
        r = rec["roofline"]
        lines.append(
            f"| {rec['arch']} | {rec['shape']} | {r['t_compute_s']:.3f} | "
            f"{r['t_memory_s']:.3f} | {r['t_collective_s']:.3f} | {r['dominant']} | "
            f"{r['step_lower_bound_s']:.3f} | "
            f"{rec['memory']['peak_hbm_bytes_est']/2**30:.1f} | "
            f"{useful_fraction(rec):.2f} |")
    return "\n".join(lines)


def run() -> list[str]:
    out = []
    for rec in load_cells("pod"):
        if rec["status"] != "ok":
            continue
        r = rec["roofline"]
        out.append(
            f"roofline_{rec['arch']}_{rec['shape']},{r['step_lower_bound_s']*1e6:.0f},"
            f"dom={r['dominant']},useful={useful_fraction(rec):.2f}")
    return out


if __name__ == "__main__":
    print(table("pod"))
