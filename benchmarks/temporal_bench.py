"""Temporal (state-space GP) backend benchmark (BENCH_temporal.json).

Wall-clock of the two scan paths over the SAME per-step model arrays, at
N in {16k, 64k, 256k, 1M} (Matern-3/2, d = 2):

  * lml      — `kalman_filter(...).lml`: the training objective
               (what every optimizer step evaluates);
  * predict  — filter + RTS smoother: the posterior-marginals pass behind
               `TemporalGPRegression.predict` / `.posterior`.

`path=parallel` is the `jax.lax.associative_scan` formulation (O(N) work,
O(log N) depth); `path=sequential` is the `lax.scan` textbook recursion
(O(N) work AND depth). Each parallel row carries `speedup_vs_sequential` —
the paper's parallelization story measured along time. On a serial backend
(CPU) the parallel path's ~2x work overhead can outweigh the depth win, so
speedups below 1 are expected there and recorded honestly; the depth win
needs parallel hardware (GPU/TPU), same as the paper's.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import SCHEMA_VERSION, row, time_call

SIZES = (16_384, 65_536, 262_144, 1_048_576)
SMOKE_SIZES = (4_096, 16_384)
D_STATE = 2  # Matern32


def _model_arrays(n: int):
    """Per-step (A, Q, H, R, y, m0, P0) for a Matern-3/2 over n
    non-uniformly spaced timestamps (the session default dtype)."""
    from repro.gp import kernels as gpk
    from repro.temporal import discretize

    kernel = gpk.Matern32(1)
    params = {
        "log_variance": jnp.asarray(0.0),
        "log_lengthscale": jnp.full((1,), -1.0),
    }
    key = jax.random.PRNGKey(0)
    gaps = jax.random.uniform(key, (n,), minval=0.5e-4, maxval=1.5e-4)
    t = jnp.cumsum(gaps)
    y = jnp.sin(40.0 * t)[:, None] + 0.1 * jax.random.normal(
        jax.random.fold_in(key, 1), (n, 1))
    model = kernel.to_sde(params)
    dt = jnp.concatenate([jnp.zeros_like(t[:1]), jnp.diff(t)])
    A, Q = discretize(model, dt)
    m0 = jnp.zeros((model.d, 1), A.dtype)
    return A, Q, model.H, jnp.asarray(0.01), y, m0, model.Pinf


def run(smoke: bool = False):
    """Returns (csv_rows, doc) — doc is the BENCH_temporal.json payload."""
    from repro.temporal import kalman_filter, rts_smoother

    sizes = SMOKE_SIZES if smoke else SIZES
    iters = 3 if smoke else 5
    csv, json_rows = [], []
    for n in sizes:
        args = _model_arrays(n)

        def lml_fn(parallel):
            def fn(A, Q, H, R, y, m0, P0):
                return kalman_filter(A, Q, H, R, y, m0, P0,
                                     parallel=parallel).lml
            return jax.jit(fn)

        def predict_fn(parallel):
            def fn(A, Q, H, R, y, m0, P0):
                res = kalman_filter(A, Q, H, R, y, m0, P0, parallel=parallel)
                ms, Ps = rts_smoother(A, Q, res.means, res.covs,
                                      parallel=parallel)
                return jnp.einsum("i,nid->nd", H, ms), \
                    jnp.einsum("i,nij,j->n", H, Ps, H)
            return jax.jit(fn)

        for op, make in (("lml", lml_fn), ("predict", predict_fn)):
            secs = {}
            for parallel in (False, True):
                path = "parallel" if parallel else "sequential"
                s = time_call(make(parallel), *args, warmup=1, iters=iters)
                secs[path] = s
                r = {"section": "temporal", "op": op, "path": path,
                     "N": int(n), "d": D_STATE,
                     "us_per_call": float(s * 1e6),
                     "ns_per_point": float(s / n * 1e9), "iters": iters}
                if parallel:
                    r["speedup_vs_sequential"] = float(
                        secs["sequential"] / s)
                json_rows.append(r)
                derived = (f"speedup={r['speedup_vs_sequential']:.2f}x"
                           if parallel else f"{r['ns_per_point']:.0f}ns/pt")
                csv.append(row(f"temporal_{op}_{path}_n{n}", s, derived))
    doc = {
        "meta": {
            "bench": "temporal",
            "schema_version": SCHEMA_VERSION,
            "jax_backend": jax.default_backend(),
            "device_count": jax.device_count(),
            "smoke": bool(smoke),
            "kernel": "matern32",
            "d_state": D_STATE,
        },
        "rows": json_rows,
    }
    return csv, doc
