"""Benchmark utilities: robust timing of jitted callables + input validation."""
from __future__ import annotations

import time

import jax

# Version stamp every committed BENCH_*.json carries in meta.schema_version.
# `benchmarks.run.validate_bench_files` rejects files that miss or mismatch
# it, so a row-format change forces regenerating the committed trajectories
# instead of silently mixing incompatible rows. Bump when row/meta fields
# change meaning.
SCHEMA_VERSION = 1

# The GP-LVM benchmarks evaluate the *expected* (psi) statistics, which only
# exist in closed form for these registry names. The registry also holds
# Materns (exact path only) and composites (need part kernels, not a bare
# name) — both would fail deep inside the bound with an opaque error, so the
# benchmarks validate up front.
PSI_STAT_KERNELS = ("linear", "rbf")


def validate_psi_kernel(kernel_name: str) -> None:
    """Fail fast (and helpfully) on kernels the psi-statistics benches can't run."""
    if kernel_name not in PSI_STAT_KERNELS:
        from repro.gp import available

        raise ValueError(
            f"kernel_name={kernel_name!r} is not usable here: this benchmark "
            f"needs closed-form psi statistics under Gaussian q(X), which "
            f"exist for {list(PSI_STAT_KERNELS)} (registry also has "
            f"{sorted(set(available()) - set(PSI_STAT_KERNELS))}, which are "
            f"exact-path-only or composite)"
        )


def time_call(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall seconds per call (post-compilation)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def row(name: str, seconds: float, derived: str = "") -> str:
    return f"{name},{seconds * 1e6:.1f},{derived}"


def latency_percentiles(fn, *args, warmup: int = 3, iters: int = 100):
    """(p50, p95) wall seconds per call — per-REQUEST latency, not the
    median-of-medians `time_call` reports for throughput benches."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2], times[min(int(len(times) * 0.95), len(times) - 1)]
