"""Benchmark utilities: robust timing of jitted callables."""
from __future__ import annotations

import time

import jax


def time_call(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall seconds per call (post-compilation)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def row(name: str, seconds: float, derived: str = "") -> str:
    return f"{name},{seconds * 1e6:.1f},{derived}"
