"""Sustained-load serving benchmark (the BENCH_serve.json `serve_load` rows).

The "millions of users" scenario reduced to a measurable harness: MODELS
registered models served by one `GPServer` under a byte budget that only
fits about half of them, driven by CLIENTS concurrent `submit()` streams
(each hammering its own model mix) plus one concurrent `update()` stream,
for DURATION seconds, with the states spilling to a scratch `StateStore`.

Two configurations of the same traffic:

  * budgeted   — `budget_bytes` ~ half the total state bytes: the LRU
                 evicts cold states to the checkpoint store and lazily
                 reloads them on access. The acceptance bar:
                 `peak_resident_bytes <= budget_bytes` for the whole run
                 (the server makes room BEFORE loading, so the budget is a
                 true ceiling, not a soft target).
  * unbounded  — same traffic with no budget: the QPS/latency baseline that
                 prices what eviction+reload costs.

Each row carries QPS, p50/p99 request latency, eviction / lazy-reload
counts, update throughput, and the peak resident state bytes — all from
`GPServer.metrics()`. Regenerate with
`python -m benchmarks.run --only serve_load`.
"""
from __future__ import annotations

import tempfile
import threading
import time

import jax
import jax.numpy as jnp

MODELS = 6
N_FIT, M, STEPS = 1024, 24, 30
BATCH = 16
CLIENTS, SMOKE_CLIENTS = 8, 4
DURATION_S, SMOKE_DURATION_S = 8.0, 2.0
# budget sized to hold about half the registered states resident
BUDGET_FRACTION = 0.5


def _fit_states(smoke: bool):
    """MODELS distinct fitted states over shifted copies of one dataset —
    cheap to build, genuinely different posteriors (distinct predictions,
    so cross-model cache bugs would show as wrong answers)."""
    from repro.gp import SparseGPRegression, get

    key = jax.random.PRNGKey(0)
    X = jnp.sort(jax.random.uniform(key, (N_FIT, 1), minval=-3.0, maxval=3.0),
                 axis=0)
    states = []
    kernel = get("rbf")(1)
    for i in range(MODELS):
        Y = jnp.sin(2.0 * X + 0.37 * i) + 0.1 * jax.random.normal(
            jax.random.fold_in(key, i + 1), X.shape)
        gp = SparseGPRegression(kernel=kernel, M=M).fit(
            X, Y, steps=5 if smoke else STEPS)
        states.append(gp.export_state())
    return kernel, states, X


def _drive(srv, names, X, *, clients: int, duration: float):
    """Concurrent submit() streams + one update() stream for `duration`
    seconds; returns (latencies_s, requests, updates, errors)."""
    latencies, errors = [], []
    lock = threading.Lock()
    stop = time.monotonic() + duration
    updates = [0]

    def client(cid: int):
        # each client walks the model list from its own offset, so every
        # model stays warm-ish but the working set exceeds the budget
        i = cid
        while time.monotonic() < stop:
            name = names[i % len(names)]
            i += 1
            t0 = time.perf_counter()
            try:
                srv.submit(name, X[:BATCH], timeout=30.0).result(timeout=60)
            except Exception as e:  # pragma: no cover - surfaced in the row
                with lock:
                    errors.append(repr(e))
                continue
            dt = time.perf_counter() - t0
            with lock:
                latencies.append(dt)

    def updater():
        key = jax.random.PRNGKey(99)
        j = 0
        while time.monotonic() < stop:
            name = names[j % len(names)]
            j += 1
            Xu = jax.random.uniform(jax.random.fold_in(key, j), (64, 1),
                                    minval=-3.0, maxval=3.0)
            try:
                srv.update(name, Xu, jnp.sin(2.0 * Xu))
            except Exception as e:  # pragma: no cover
                with lock:
                    errors.append(repr(e))
                continue
            updates[0] += 1

    threads = [threading.Thread(target=client, args=(c,)) for c in range(clients)]
    threads.append(threading.Thread(target=updater))
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    return latencies, len(latencies), updates[0], errors


def _percentile(sorted_xs, q):
    return sorted_xs[min(int(len(sorted_xs) * q), len(sorted_xs) - 1)]


def run(*, smoke: bool = False):
    """Returns (csv_rows, json_rows). Rows land in BENCH_serve.json with
    section="serve_load" (benchmarks.run merges them with the latency
    section's rows)."""
    from repro.serve import GPServer, StateStore

    clients = SMOKE_CLIENTS if smoke else CLIENTS
    duration = SMOKE_DURATION_S if smoke else DURATION_S
    kernel, states, X = _fit_states(smoke)
    state_bytes = states[0].nbytes
    budget = int(MODELS * state_bytes * BUDGET_FRACTION)
    names = [f"m{i}" for i in range(MODELS)]

    csv, rows = [], []
    for path, budget_bytes in (("budgeted", budget), ("unbounded", None)):
        with tempfile.TemporaryDirectory(prefix="serve_load_") as scratch:
            srv = GPServer(store=StateStore(scratch), budget_bytes=budget_bytes)
            for name, st in zip(names, states):
                srv.register(name, kernel=kernel, state=st)
            # warm the compile caches outside the measured window
            for name in names:
                srv.submit(name, X[:BATCH]).result(timeout=60)
            lat, requests, updates, errors = _drive(
                srv, names, X, clients=clients, duration=duration)
            metrics = srv.metrics()
            srv.close()
        lat.sort()
        row = {
            "section": "serve_load", "op": "load", "path": path,
            "models": MODELS, "M": M, "B": BATCH, "clients": clients,
            "duration_s": float(duration),
            "state_bytes": int(state_bytes),
            "budget_bytes": budget_bytes,
            "requests": int(requests),
            "qps": float(requests / duration),
            "p50_us": float(_percentile(lat, 0.50) * 1e6) if lat else None,
            "p99_us": float(_percentile(lat, 0.99) * 1e6) if lat else None,
            "updates": int(updates),
            "errors": len(errors),
            "evictions": int(metrics["evictions"]),
            "lazy_loads": int(metrics["lazy_loads"]),
            "peak_resident_bytes": int(metrics["peak_resident_bytes"]),
            "under_budget": bool(
                budget_bytes is None
                or metrics["peak_resident_bytes"] <= budget_bytes),
        }
        rows.append(row)
        csv.append(
            f"serve_load_{path},{row['p50_us'] or 0:.1f},"
            f"qps={row['qps']:.0f} p99_us={row['p99_us'] or 0:.0f} "
            f"evictions={row['evictions']} "
            f"peak_resident={row['peak_resident_bytes']}")
        if errors:  # pragma: no cover - debugging aid, not the happy path
            csv.append(f"serve_load_{path}_errors,{len(errors)},{errors[0]}")
    return csv, rows


if __name__ == "__main__":
    out, _ = run(smoke=True)
    print("\n".join(out))
