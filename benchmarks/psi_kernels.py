"""Paper §3 (Tables 1-2): the Psi/Phi statistic kernels.

On this CPU box the Pallas kernels execute in interpret mode (Python-level —
meaningless wall time), so the benchmark reports (a) the jnp reference times
that the CPU actually runs, and (b) the ANALYTIC kernel-level roofline for
the TPU target: flops/bytes of each kernel at the paper's shapes, vs v5e
peaks — this is the number the §Perf iterations move.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import row, time_call
from repro.core.psi_stats import _psi2_rbf_chunked
from repro.kernels import ref

PEAK_FLOPS = 197e12
HBM_BW = 819e9


def run() -> list[str]:
    out = []
    key = jax.random.PRNGKey(0)
    for (N, M, Q) in [(16384, 100, 1), (65536, 100, 1), (16384, 512, 8)]:
        ks = jax.random.split(key, 3)
        mu = jax.random.normal(ks[0], (N, Q), jnp.float32)
        S = 0.1 + jax.random.uniform(ks[1], (N, Q), jnp.float32)
        Z = jax.random.normal(ks[2], (M, Q), jnp.float32)
        var = jnp.asarray(1.0, jnp.float32)
        ls = jnp.ones((Q,), jnp.float32)

        f1 = jax.jit(lambda m, s, z: ref.psi1_rbf(m, s, z, var, ls))
        t1 = time_call(f1, mu, S, Z, warmup=1, iters=3)
        f2 = jax.jit(lambda m, s, z: _psi2_rbf_chunked(m, s, z, var, ls))
        t2 = time_call(f2, mu, S, Z, warmup=1, iters=3)

        # analytic TPU roofline for the fused psi2 kernel (dominant cost):
        # flops ~ N*M^2*(Q*3+8); bytes ~ N*Q*3*4 (stream mu,S,w) + M^2*4
        flops = N * M * M * (3 * Q + 8)
        bytes_ = N * Q * 3 * 4 + M * M * 4
        t_c = flops / PEAK_FLOPS
        t_m = bytes_ / HBM_BW
        bound = "compute" if t_c > t_m else "memory"
        out.append(row(f"psi1_jnp_N{N}_M{M}_Q{Q}", t1, ""))
        out.append(row(
            f"psi2_jnp_N{N}_M{M}_Q{Q}", t2,
            f"tpu_pred_us={max(t_c,t_m)*1e6:.1f},bound={bound}"))
    return out


if __name__ == "__main__":
    print("\n".join(run()))
