"""Benchmark driver: one section per paper table/figure + the roofline table.

    PYTHONPATH=src python -m benchmarks.run [--fast]

Prints ``name,us_per_call,derived`` CSV rows.
"""
from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="smaller sweeps")
    args = ap.parse_args()

    from benchmarks import gp_scaling, indistributable, lm_step, psi_kernels, roofline_table
    from repro.configs.base import ARCH_IDS

    rows = ["name,us_per_call,derived"]
    print("# paper Fig 1a - GP-LVM iteration time vs N", file=sys.stderr)
    rows += gp_scaling.run(sizes=(1024, 4096) if args.fast else gp_scaling.SIZES)
    print("# paper Fig 1b - indistributable fraction", file=sys.stderr)
    rows += indistributable.run(sizes=(1024, 4096) if args.fast else indistributable.SIZES)
    print("# paper S3 - psi-statistic kernels", file=sys.stderr)
    rows += psi_kernels.run()
    print("# LM smoke step bench", file=sys.stderr)
    rows += lm_step.run(archs=["smollm-360m", "rwkv6-7b"] if args.fast else ARCH_IDS)
    print("# roofline table (from dry-run artifacts)", file=sys.stderr)
    rows += roofline_table.run()
    print("\n".join(rows))


if __name__ == "__main__":
    main()
