"""Benchmark driver: one section per paper table/figure + the roofline table
+ the streaming-engine sweep (BENCH_gp.json).

    PYTHONPATH=src python -m benchmarks.run [--fast|--smoke] [--only SECTION] \
        [--out BENCH_gp.json]

Prints ``name,us_per_call,derived`` CSV rows to stdout. Whenever the
gp_stream section runs (the default; excluded only by ``--only`` with
another section), the machine-readable streaming-engine results (time/point
+ peak-memory estimate vs N for the jnp and fused backends) are written to
``--out`` so perf PRs have a trajectory to diff against.
"""
from __future__ import annotations

import argparse
import json
import sys

SECTIONS = ("gp_scaling", "indistributable", "psi_kernels", "gp_stream",
            "lm_step", "roofline")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", "--smoke", dest="fast", action="store_true",
                    help="smaller sweeps (CI smoke mode)")
    ap.add_argument("--only", choices=SECTIONS, default=None,
                    help="run a single section")
    ap.add_argument("--out", default=None,
                    help="where to write the streaming-engine JSON "
                         "(default: BENCH_gp.json, or BENCH_gp.smoke.json "
                         "under --smoke so the committed full-sweep "
                         "trajectory is never clobbered by a smoke run)")
    args = ap.parse_args()
    if args.out is None:
        args.out = "BENCH_gp.smoke.json" if args.fast else "BENCH_gp.json"

    def wanted(name: str) -> bool:
        return args.only is None or args.only == name

    from benchmarks import (gp_scaling, gp_stream, indistributable, lm_step,
                            psi_kernels, roofline_table)
    from repro.configs.base import ARCH_IDS

    rows = ["name,us_per_call,derived"]
    json_rows = []
    if wanted("gp_scaling"):
        print("# paper Fig 1a - GP-LVM iteration time vs N", file=sys.stderr)
        rows += gp_scaling.run(sizes=(1024, 4096) if args.fast else gp_scaling.SIZES)
    if wanted("indistributable"):
        print("# paper Fig 1b - indistributable fraction", file=sys.stderr)
        rows += indistributable.run(sizes=(1024, 4096) if args.fast else indistributable.SIZES)
    if wanted("psi_kernels"):
        print("# paper S3 - psi-statistic kernels", file=sys.stderr)
        rows += psi_kernels.run()
    if wanted("gp_stream"):
        print("# streaming suffstats engine - time/point + peak memory vs N",
              file=sys.stderr)
        csv, json_rows = gp_stream.run(smoke=args.fast)
        rows += csv
    if wanted("lm_step"):
        print("# LM smoke step bench", file=sys.stderr)
        rows += lm_step.run(archs=["smollm-360m", "rwkv6-7b"] if args.fast else ARCH_IDS)
    if wanted("roofline"):
        print("# roofline table (from dry-run artifacts)", file=sys.stderr)
        rows += roofline_table.run()
    print("\n".join(rows))

    if wanted("gp_stream"):
        import jax

        doc = {
            "meta": {
                "bench": "gp_stream",
                "jax_backend": jax.default_backend(),
                "device_count": jax.device_count(),
                "smoke": bool(args.fast),
                "chunk": gp_stream.CHUNK,
                "M": gp_stream.M,
            },
            "rows": json_rows,
        }
        with open(args.out, "w") as f:
            json.dump(doc, f, indent=1)
        print(f"# wrote {args.out} ({len(json_rows)} rows)", file=sys.stderr)


if __name__ == "__main__":
    main()
