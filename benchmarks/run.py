"""Benchmark driver: one section per paper table/figure + the roofline table
+ the streaming-engine sweep (BENCH_gp.json) + the serving-latency sweep
(BENCH_serve.json) + the static per-kernel VMEM budget table
(BENCH_vmem.json, from repro.analysis.pallas_audit).

    PYTHONPATH=src python -m benchmarks.run [--fast|--smoke] [--only SECTION] \
        [--out BENCH_gp.json] [--serve-out BENCH_serve.json] \
        [--vmem-out BENCH_vmem.json]

Prints ``name,us_per_call,derived`` CSV rows to stdout. Whenever the
gp_stream / serve sections run (both default; excluded only by ``--only``
with another section), the machine-readable results are written to
``--out`` / ``--serve-out`` so perf PRs have a trajectory to diff against.

Before running anything, every committed BENCH_*.json at the repo root is
validated: it must parse and its meta.schema_version must match
`benchmarks.common.SCHEMA_VERSION` — a row-format change therefore forces
regenerating the committed trajectories.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

SECTIONS = ("gp_scaling", "indistributable", "psi_kernels", "gp_stream",
            "serve", "serve_load", "temporal", "lm_step", "roofline",
            "analysis", "tune")

# every serve_load row must carry these keys (validate_bench_files checks the
# committed BENCH_serve.json against this, so the sustained-load trajectory
# can't silently lose its acceptance columns)
SERVE_LOAD_ROW_KEYS = frozenset({
    "section", "op", "path", "models", "clients", "duration_s",
    "budget_bytes", "requests", "qps", "p50_us", "p99_us", "updates",
    "evictions", "lazy_loads", "peak_resident_bytes", "under_budget",
})


def validate_bench_files(root=None, *, exclude=()) -> list:
    """Check every BENCH_*.json under `root` (default: the repo root)
    parses and carries the current schema version; returns the file names.
    Raises ValueError with the offending file on any mismatch. `exclude`
    names files to skip — the driver passes the outputs the current run is
    about to overwrite, so bumping SCHEMA_VERSION never deadlocks the
    regeneration command on its own stale outputs."""
    from benchmarks.common import SCHEMA_VERSION

    root = pathlib.Path(root) if root is not None else \
        pathlib.Path(__file__).resolve().parents[1]
    names = []
    for path in sorted(root.glob("BENCH_*.json")):
        if path.name in exclude:
            continue
        try:
            with open(path) as f:
                doc = json.load(f)
        except Exception as e:
            raise ValueError(f"{path.name}: does not parse as JSON ({e})") from None
        version = (doc.get("meta") or {}).get("schema_version")
        if version != SCHEMA_VERSION:
            raise ValueError(
                f"{path.name}: meta.schema_version is {version!r}, current is "
                f"{SCHEMA_VERSION} — regenerate with `python -m benchmarks.run`")
        if not isinstance(doc.get("rows"), list) or not doc["rows"]:
            raise ValueError(f"{path.name}: missing or empty rows list")
        if path.name == "BENCH_serve.json":
            load_rows = [r for r in doc["rows"]
                         if isinstance(r, dict) and r.get("section") == "serve_load"]
            if not load_rows:
                raise ValueError(
                    f"{path.name}: no serve_load rows — regenerate with "
                    "`python -m benchmarks.run --only serve_load`")
            for r in load_rows:
                missing = SERVE_LOAD_ROW_KEYS - r.keys()
                if missing:
                    raise ValueError(
                        f"{path.name}: serve_load row missing keys "
                        f"{sorted(missing)}")
                if r.get("budget_bytes") is not None and not r.get("under_budget"):
                    raise ValueError(
                        f"{path.name}: budgeted serve_load row exceeded its "
                        f"budget (peak {r.get('peak_resident_bytes')} > "
                        f"{r.get('budget_bytes')})")
        names.append(path.name)
    return names


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", "--smoke", dest="fast", action="store_true",
                    help="smaller sweeps (CI smoke mode)")
    ap.add_argument("--only", choices=SECTIONS, default=None,
                    help="run a single section")
    ap.add_argument("--out", default=None,
                    help="where to write the streaming-engine JSON "
                         "(default: BENCH_gp.json, or BENCH_gp.smoke.json "
                         "under --smoke so the committed full-sweep "
                         "trajectory is never clobbered by a smoke run)")
    ap.add_argument("--serve-out", default=None,
                    help="where to write the serving-latency JSON (default: "
                         "BENCH_serve.json, or BENCH_serve.smoke.json under "
                         "--smoke)")
    ap.add_argument("--vmem-out", default=None,
                    help="where to write the static VMEM budget table "
                         "(default: BENCH_vmem.json, or BENCH_vmem.smoke.json "
                         "under --smoke)")
    ap.add_argument("--tune-out", default=None,
                    help="where to write the autotuner tuned-vs-default "
                         "table (default: BENCH_tune.json, or "
                         "BENCH_tune.smoke.json under --smoke)")
    ap.add_argument("--temporal-out", default=None,
                    help="where to write the temporal-backend parallel-vs-"
                         "sequential scan table (default: "
                         "BENCH_temporal.json, or BENCH_temporal.smoke.json "
                         "under --smoke)")
    args = ap.parse_args()
    if args.out is None:
        args.out = "BENCH_gp.smoke.json" if args.fast else "BENCH_gp.json"
    if args.serve_out is None:
        args.serve_out = "BENCH_serve.smoke.json" if args.fast else "BENCH_serve.json"
    if args.vmem_out is None:
        args.vmem_out = "BENCH_vmem.smoke.json" if args.fast else "BENCH_vmem.json"
    if args.tune_out is None:
        args.tune_out = "BENCH_tune.smoke.json" if args.fast else "BENCH_tune.json"
    if args.temporal_out is None:
        args.temporal_out = ("BENCH_temporal.smoke.json" if args.fast
                             else "BENCH_temporal.json")

    overwriting = {pathlib.Path(args.out).name, pathlib.Path(args.serve_out).name,
                   pathlib.Path(args.vmem_out).name,
                   pathlib.Path(args.tune_out).name,
                   pathlib.Path(args.temporal_out).name}
    committed = validate_bench_files(exclude=overwriting)
    print(f"# committed bench files OK: {', '.join(committed) or '(none)'}",
          file=sys.stderr)

    def wanted(name: str) -> bool:
        return args.only is None or args.only == name

    from benchmarks import (gp_scaling, gp_stream, indistributable, lm_step,
                            psi_kernels, roofline_table)
    from repro.configs.base import ARCH_IDS

    rows = ["name,us_per_call,derived"]
    json_rows = []
    if wanted("gp_scaling"):
        print("# paper Fig 1a - GP-LVM iteration time vs N", file=sys.stderr)
        rows += gp_scaling.run(sizes=(1024, 4096) if args.fast else gp_scaling.SIZES)
    if wanted("indistributable"):
        print("# paper Fig 1b - indistributable fraction", file=sys.stderr)
        rows += indistributable.run(sizes=(1024, 4096) if args.fast else indistributable.SIZES)
    if wanted("psi_kernels"):
        print("# paper S3 - psi-statistic kernels", file=sys.stderr)
        rows += psi_kernels.run()
    if wanted("gp_stream"):
        print("# streaming suffstats engine - time/point + peak memory vs N",
              file=sys.stderr)
        csv, json_rows = gp_stream.run(smoke=args.fast)
        rows += csv
    serve_doc = None
    if wanted("serve"):
        from benchmarks import serve_latency

        print("# serving path - predict latency p50/p95 + update throughput",
              file=sys.stderr)
        csv, serve_doc = serve_latency.run(smoke=args.fast)
        rows += csv
    temporal_doc = None
    if wanted("temporal"):
        from benchmarks import temporal_bench

        print("# temporal backend - parallel associative scan vs sequential "
              "lax.scan (lml + predict)", file=sys.stderr)
        csv, temporal_doc = temporal_bench.run(smoke=args.fast)
        rows += csv
    load_rows = None
    if wanted("serve_load"):
        from benchmarks import serve_load

        print("# serving path - sustained load: QPS, tail latency, eviction "
              "traffic under a byte budget", file=sys.stderr)
        csv, load_rows = serve_load.run(smoke=args.fast)
        rows += csv
    if wanted("lm_step"):
        print("# LM smoke step bench", file=sys.stderr)
        rows += lm_step.run(archs=["smollm-360m", "rwkv6-7b"] if args.fast else ARCH_IDS)
    if wanted("roofline"):
        print("# roofline table (from dry-run artifacts)", file=sys.stderr)
        rows += roofline_table.run()
    vmem_doc = None
    if wanted("analysis"):
        from benchmarks import analysis_vmem

        print("# static analysis - per-kernel VMEM budget table",
              file=sys.stderr)
        csv, vmem_doc = analysis_vmem.run(smoke=args.fast)
        rows += csv
    tune_doc = None
    if wanted("tune"):
        from benchmarks import tune_bench

        print("# autotuner - tuned-vs-default blocks + roofline check",
              file=sys.stderr)
        csv, tune_doc = tune_bench.run(smoke=args.fast)
        rows += csv
    print("\n".join(rows))

    if wanted("gp_stream"):
        import jax

        from benchmarks.common import SCHEMA_VERSION

        doc = {
            "meta": {
                "bench": "gp_stream",
                "schema_version": SCHEMA_VERSION,
                "jax_backend": jax.default_backend(),
                "device_count": jax.device_count(),
                "smoke": bool(args.fast),
                "chunk": gp_stream.CHUNK,
                "M": gp_stream.M,
            },
            "rows": json_rows,
        }
        with open(args.out, "w") as f:
            json.dump(doc, f, indent=1)
        print(f"# wrote {args.out} ({len(json_rows)} rows)", file=sys.stderr)
    if serve_doc is not None or load_rows is not None:
        # BENCH_serve.json holds both the latency sweep and the sustained-load
        # rows; whichever section didn't run this invocation keeps its rows
        # from the existing file, so `--only serve_load` never clobbers the
        # latency trajectory (and vice versa).
        from benchmarks.common import SCHEMA_VERSION

        existing = {}
        if serve_doc is None or load_rows is None:
            try:
                with open(args.serve_out) as f:
                    existing = json.load(f)
            except (OSError, ValueError):
                existing = {}
        ex_rows = existing.get("rows") or []
        if serve_doc is not None:
            meta, latency_rows = serve_doc["meta"], serve_doc["rows"]
        else:
            meta = existing.get("meta") or {
                "bench": "serve_latency", "schema_version": SCHEMA_VERSION,
                "smoke": bool(args.fast)}
            latency_rows = [r for r in ex_rows
                            if r.get("section") != "serve_load"]
        if load_rows is None:
            load_rows = [r for r in ex_rows
                         if r.get("section") == "serve_load"]
        merged = {"meta": meta, "rows": latency_rows + load_rows}
        with open(args.serve_out, "w") as f:
            json.dump(merged, f, indent=1)
        print(f"# wrote {args.serve_out} ({len(merged['rows'])} rows)",
              file=sys.stderr)
    if vmem_doc is not None:
        with open(args.vmem_out, "w") as f:
            json.dump(vmem_doc, f, indent=1)
        print(f"# wrote {args.vmem_out} ({len(vmem_doc['rows'])} rows)",
              file=sys.stderr)
    if tune_doc is not None:
        with open(args.tune_out, "w") as f:
            json.dump(tune_doc, f, indent=1)
        print(f"# wrote {args.tune_out} ({len(tune_doc['rows'])} rows)",
              file=sys.stderr)
    if temporal_doc is not None:
        with open(args.temporal_out, "w") as f:
            json.dump(temporal_doc, f, indent=1)
        print(f"# wrote {args.temporal_out} ({len(temporal_doc['rows'])} rows)",
              file=sys.stderr)


if __name__ == "__main__":
    main()
