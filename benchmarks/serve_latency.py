"""Serving-path latency benchmark (BENCH_serve.json at the repo root).

Per-REQUEST p50/p95 predict latency at several batch sizes, for three paths
over the same fitted model:

  * facade           — `SparseGPRegression.predict()` as users call it
                       (cached posterior, eager O(M B) epilogue per call);
  * server_bucketed  — `GPServer.predict()`: cached `PosteriorState`, the
                       request padded to a bucket shape so one jitted
                       executable serves every batch size;
  * server_nobucket  — same server with `use_buckets=False` (every shape
                       compiles + dispatches its own executable) — isolates
                       what the bucket cache buys.

Plus `submit()` round-trip latency under thread concurrency (the
micro-batching queue), and `update()` throughput versus batch size (points
folded per second through the SuffStats monoid + O(M^3) refold).

The headline row is `speedup_vs_facade` at B=16 — the acceptance bar is
>= 10x for the bucketed cached-state path.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import SCHEMA_VERSION, latency_percentiles, row

N_FIT, M, STEPS = 4096, 32, 30
BATCHES = (1, 16, 64, 256)
SMOKE_BATCHES = (1, 16)
UPDATE_BATCHES = (256, 4096, 32768)
SMOKE_UPDATE_BATCHES = (256, 1024)
ITERS, SMOKE_ITERS = 300, 30
SUBMIT_THREADS = 8


def _fit_model(smoke: bool):
    from repro.gp import SparseGPRegression, get

    key = jax.random.PRNGKey(0)
    X = jnp.sort(jax.random.uniform(key, (N_FIT, 1), minval=-3.0, maxval=3.0),
                 axis=0)
    Y = jnp.sin(2.0 * X) + 0.1 * jax.random.normal(
        jax.random.fold_in(key, 1), (N_FIT, 1))
    gp = SparseGPRegression(kernel=get("rbf")(1), M=M).fit(
        X, Y, steps=5 if smoke else STEPS)
    return gp, X, Y


def _predict_row(path, B, p50, p95, iters):
    return {
        "section": "serve", "op": "predict", "path": path, "B": int(B),
        "M": M, "p50_us": float(p50 * 1e6), "p95_us": float(p95 * 1e6),
        "iters": int(iters),
    }


def _submit_latency(srv, name, Xt, iters):
    """p50/p95 of the full submit()->result() round trip with
    SUBMIT_THREADS concurrent clients per wave (the worker coalesces each
    wave into shared device calls)."""
    import threading

    times = []
    lock = threading.Lock()

    def client():
        t0 = time.perf_counter()
        srv.submit(name, Xt).result(timeout=60)
        dt = time.perf_counter() - t0
        with lock:
            times.append(dt)

    def wave():
        threads = [threading.Thread(target=client) for _ in range(SUBMIT_THREADS)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()

    # warmup waves: the worker coalesces a VARIABLE number of requests per
    # device call (1..SUBMIT_THREADS, depending on thread timing), and each
    # distinct coalesced arity/bucket compiles once — run enough waves to
    # see them all before measuring
    for _ in range(12):
        wave()
    times.clear()
    for _ in range(iters):
        wave()
    times.sort()
    return times[len(times) // 2], times[min(int(len(times) * 0.95), len(times) - 1)]


def run(*, smoke: bool = False):
    """Returns (csv_rows, json_doc). The doc goes to BENCH_serve.json."""
    from repro.serve import GPServer

    batches = SMOKE_BATCHES if smoke else BATCHES
    update_batches = SMOKE_UPDATE_BATCHES if smoke else UPDATE_BATCHES
    iters = SMOKE_ITERS if smoke else ITERS

    gp, X, Y = _fit_model(smoke)
    srv = GPServer()
    srv.register("gp", gp)
    srv_nb = GPServer(use_buckets=False)
    srv_nb.register("gp", kernel=gp.kernel, state=srv.state("gp"))

    csv, rows = [], []
    p50_by_path = {}
    for B in batches:
        Xt = X[:B]
        paths = (
            ("facade", lambda: gp.predict(Xt)),
            ("server_bucketed", lambda: srv.predict("gp", Xt)),
            ("server_nobucket", lambda: srv_nb.predict("gp", Xt)),
        )
        for path, fn in paths:
            p50, p95 = latency_percentiles(fn, iters=iters)
            p50_by_path[(path, B)] = p50
            rows.append(_predict_row(path, B, p50, p95, iters))
            csv.append(row(f"serve_predict_{path}_B{B}", p50,
                           f"p95_us={p95 * 1e6:.1f}"))

    # the acceptance headline: bucketed cached-state vs the facade path
    B_ref = 16
    speedup = p50_by_path[("facade", B_ref)] / p50_by_path[("server_bucketed", B_ref)]
    rows.append({"section": "serve", "op": "derived",
                 "name": "speedup_vs_facade", "B": B_ref, "M": M,
                 "value": float(speedup)})
    csv.append(row(f"serve_speedup_vs_facade_B{B_ref}",
                   p50_by_path[("server_bucketed", B_ref)],
                   f"speedup={speedup:.1f}x"))

    # micro-batched submit round trip under concurrency
    p50, p95 = _submit_latency(srv, "gp", X[:B_ref], max(iters // 10, 5))
    rows.append({"section": "serve", "op": "submit", "path": "server_bucketed",
                 "B": B_ref, "M": M, "threads": SUBMIT_THREADS,
                 "p50_us": float(p50 * 1e6), "p95_us": float(p95 * 1e6),
                 "iters": max(iters // 10, 5)})
    csv.append(row(f"serve_submit_B{B_ref}_threads{SUBMIT_THREADS}", p50,
                   f"p95_us={p95 * 1e6:.1f}"))

    # online update throughput vs batch size (fold + O(M^3) refold)
    key = jax.random.PRNGKey(1)
    for Bu in update_batches:
        Xu = jax.random.uniform(key, (Bu, 1), minval=-3.0, maxval=3.0)
        Yu = jnp.sin(2.0 * Xu)
        p50, p95 = latency_percentiles(
            lambda: srv.update("gp", Xu, Yu), warmup=1,
            iters=max(iters // 30, 3))
        rows.append({"section": "serve", "op": "update", "B": int(Bu), "M": M,
                     "p50_us": float(p50 * 1e6), "p95_us": float(p95 * 1e6),
                     "points_per_sec": float(Bu / p50),
                     "iters": max(iters // 30, 3)})
        csv.append(row(f"serve_update_B{Bu}", p50,
                       f"points_per_sec={Bu / p50:.0f}"))
    srv.close()
    srv_nb.close()

    doc = {
        "meta": {
            "bench": "serve_latency",
            "schema_version": SCHEMA_VERSION,
            "jax_backend": jax.default_backend(),
            "device_count": jax.device_count(),
            "smoke": bool(smoke),
            "N_fit": N_FIT,
            "M": M,
        },
        "rows": rows,
    }
    return csv, doc


if __name__ == "__main__":
    csv, _ = run(smoke=True)
    print("\n".join(csv))
