"""Autotuner benchmark: tuned-vs-default blocks per kernel + roofline check.

For every registered Pallas kernel the section measures the auditor-
admissible candidate blocks (`repro.tune.measure_blocks` — the same
stopwatch `best_blocks` uses on a cold key), then reports the default
block's time, the measured winner, the speedup, and the winner's achieved
FLOP/s against `repro.launch.roofline.PEAK_FLOPS`. A final row does the
same for the streaming-scan chunk ladder.

The FLOP counts are the analytic models of the kernels' dominant
contractions (MXU matmuls; the reverse passes re-walk the forward's tiles
roughly three times). On a CPU host the kernels run in interpret mode, so
achieved/roofline numbers are only meaningful on an accelerator — the rows
still exercise the full tuned-vs-default machinery, which is what the CI
smoke lane asserts on.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

from benchmarks.common import SCHEMA_VERSION, row

# candidate caps: keep the full lane bounded and the smoke lane 2-wide
FULL_CANDIDATES = 4
SMOKE_CANDIDATES = 2


def _problem(smoke: bool):
    from repro.analysis.pallas_audit import Problem

    return Problem(N=128, M=128, Q=3, D=2) if smoke else \
        Problem(N=512, M=256, Q=4, D=2)


def _flops(kernel: str, N: int, M: int, Q: int, D: int) -> float:
    """Dominant-term FLOP models of the kernels' MXU contractions."""
    kfu = 2.0 * N * M * Q
    psi1 = 4.0 * N * M * Q
    psi2 = 2.0 * N * M * M * Q
    fused = psi2 + psi1 + 2.0 * N * M * D
    table = {
        "kfu_pallas": kfu,
        "psi1_pallas": psi1,
        "psi2_pallas": psi2,
        "suffstats_pallas": fused,
        # reverse passes re-evaluate the forward tiles + two cotangent
        # contractions: ~3x the forward's dominant term
        "suffstats_bwd_pallas": 3.0 * fused,
        "psi1_bwd_pallas": 3.0 * psi1,
        "psi2_bwd_pallas": 3.0 * psi2,
    }
    return table[kernel]


def run(smoke: bool = False) -> Tuple[List[str], Dict]:
    import jax

    from repro import tune
    from repro.analysis.pallas_audit import KERNELS
    from repro.launch.roofline import PEAK_FLOPS

    prob = _problem(smoke)
    limit = SMOKE_CANDIDATES if smoke else FULL_CANDIDATES
    csv: List[str] = []
    json_rows: List[Dict] = []

    for kernel in KERNELS:
        default = tune.default_blocks(kernel)
        cands = tune.candidate_blocks(kernel, problem=prob, limit=limit)
        if default not in cands:
            cands = [default] + cands
        timings = tune.measure_blocks(kernel, cands, problem=prob)
        best = min(timings, key=timings.get)
        t_default = timings[default]
        t_best = timings[best]
        flops = _flops(kernel, prob.N, prob.M, prob.Q, prob.D)
        achieved = flops / t_best if t_best > 0 else 0.0
        csv.append(row(
            f"tune/{kernel}", t_best,
            f"default={default[0]}x{default[1]} best={best[0]}x{best[1]} "
            f"speedup={t_default / t_best:.2f}x"))
        json_rows.append({
            "section": "tune",
            "kernel": kernel,
            "problem": {"N": prob.N, "M": prob.M, "Q": prob.Q, "D": prob.D},
            "dtype": "float32",
            "candidates": len(cands),
            "default_block": list(default),
            "best_block": list(best),
            "t_default_s": t_default,
            "t_best_s": t_best,
            "speedup_vs_default": t_default / t_best,
            "flops": flops,
            "achieved_flops": achieved,
            "roofline_peak_flops": PEAK_FLOPS,
            "roofline_frac": achieved / PEAK_FLOPS,
        })

    # streaming chunk ladder through the real lax.scan path
    n_stream = 2048 if smoke else 16384
    cands = tune.candidate_chunks(n_stream, limit=limit)
    if tune.DEFAULT_CHUNK not in cands:
        cands = [tune.DEFAULT_CHUNK] + cands
    timings = tune.measure_chunks(cands, n=n_stream, m=prob.M, q=prob.Q,
                                  d=prob.D, backend="jnp")
    best_c = min(timings, key=timings.get)
    t_default = timings[tune.DEFAULT_CHUNK]
    t_best = timings[best_c]
    flops = _flops("suffstats_pallas", n_stream, prob.M, prob.Q, prob.D)
    achieved = flops / t_best if t_best > 0 else 0.0
    csv.append(row(
        "tune/streaming_chunk", t_best,
        f"default={tune.DEFAULT_CHUNK} best={best_c} "
        f"speedup={t_default / t_best:.2f}x"))
    json_rows.append({
        "section": "tune",
        "kernel": "streaming_suff_stats",
        "problem": {"N": n_stream, "M": prob.M, "Q": prob.Q, "D": prob.D},
        "dtype": "float32",
        "candidates": len(cands),
        "default_chunk": tune.DEFAULT_CHUNK,
        "best_chunk": int(best_c),
        "t_default_s": t_default,
        "t_best_s": t_best,
        "speedup_vs_default": t_default / t_best,
        "flops": flops,
        "achieved_flops": achieved,
        "roofline_peak_flops": PEAK_FLOPS,
        "roofline_frac": achieved / PEAK_FLOPS,
    })

    doc = {
        "meta": {
            "bench": "tune",
            "schema_version": SCHEMA_VERSION,
            "jax_backend": jax.default_backend(),
            "device_count": jax.device_count(),
            "smoke": bool(smoke),
            "interpret_note": "off-accelerator rows time interpret-mode "
                              "kernels; roofline fractions are only "
                              "meaningful on TPU/GPU",
        },
        "rows": json_rows,
    }
    return csv, doc
