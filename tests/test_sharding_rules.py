"""Sharding-rule regression tests: every (arch x step-input) leaf must shard
evenly on the production mesh — checked abstractly (no 512-device compile)."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ARCH_IDS, SHAPES, get_config
from repro.models import model_zoo
from repro.parallel import sharding as shd


class FakeMesh:
    """Just enough of a Mesh for the rules table (axis names + sizes)."""

    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


POD = FakeMesh({"data": 16, "model": 16})
MULTI = FakeMesh({"pod": 2, "data": 16, "model": 16})


def _check(specs, tree, mesh):
    for (path, leaf), (_, spec) in zip(
        jax.tree_util.tree_flatten_with_path(tree)[0],
        jax.tree_util.tree_flatten_with_path(
            specs, is_leaf=lambda x: isinstance(x, P))[0],
    ):
        for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * (len(leaf.shape) - len(spec))):
            if ax is None:
                continue
            size = shd._axes_size(mesh, ax)
            assert dim % size == 0, (path, leaf.shape, spec)


@pytest.mark.parametrize("mesh", [POD, MULTI], ids=["pod", "multipod"])
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_specs_divide_evenly(arch, mesh):
    cfg = get_config(arch)
    model = model_zoo.build(cfg)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    _check(shd.param_specs(params, mesh), params, mesh)


@pytest.mark.parametrize("arch", ["arctic-480b", "rwkv6-7b", "recurrentgemma-2b",
                                  "whisper-small"])
def test_state_specs_divide_evenly(arch):
    cfg = get_config(arch)
    model = model_zoo.build(cfg)
    states = jax.eval_shape(lambda: model.init_decode_state(128, 32768))
    _check(shd.state_specs(states, POD), states, POD)


def test_batch_b1_not_sharded():
    specs = shd.batch_specs({"tokens": jax.ShapeDtypeStruct((1, 64), jnp.int32)}, POD)
    assert specs["tokens"] == P(None, None)


def test_sharded_param_fraction_is_high():
    """Catch silent replication: most parameter BYTES must be sharded over
    both axes on the pod mesh."""
    for arch in ("internlm2-20b", "arctic-480b", "rwkv6-7b"):
        cfg = get_config(arch)
        model = model_zoo.build(cfg)
        params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        specs = shd.param_specs(params, POD)
        total = both = 0
        for (path, leaf), (_, spec) in zip(
            jax.tree_util.tree_flatten_with_path(params)[0],
            jax.tree_util.tree_flatten_with_path(
                specs, is_leaf=lambda x: isinstance(x, P))[0],
        ):
            n = 1
            for d in leaf.shape:
                n *= d
            total += n
            axes = {a for a in jax.tree.leaves(tuple(spec)) if a is not None}
            if {"data", "model"} <= set(map(str, axes)):
                both += n
        assert both / total > 0.95, (arch, both / total)


def test_vocab_padding_multiple_and_head_padding():
    from repro.models.attention import head_to_kv_map

    for arch in ARCH_IDS:
        cfg = get_config(arch)
        assert cfg.padded_vocab() % 256 == 0
        assert cfg.padded_vocab() >= cfg.vocab_size
        hp = cfg.padded_heads(16)
        assert hp % 16 == 0 and hp >= cfg.num_heads
        # flat padding (perf iteration A1): the head->kv gather map carries
        # the grouping, so hp need NOT divide by num_kv_heads
        kv_map = head_to_kv_map(cfg, 16)
        assert len(kv_map) == hp
        assert all(0 <= int(k) < cfg.num_kv_heads for k in kv_map)
        G = cfg.num_heads // cfg.num_kv_heads
        assert all(int(kv_map[h]) == h // G for h in range(cfg.num_heads))
    assert get_config("arctic-480b").padded_heads(16) == 64  # 56 -> 64
    assert get_config("smollm-360m").padded_heads(16) == 16  # 15 -> 16, not 80
