"""Blockwise attention vs a dense reference: causal, windowed, bidirectional,
GQA grouping, ragged lengths, both train and infer layouts; decode ring cache."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import attention as attn


def dense_reference(q, k, v, qp, kp, window, causal):
    B, S, H, hd = q.shape
    Kv = k.shape[2]
    G = H // Kv
    k = jnp.repeat(k, G, axis=2)
    v = jnp.repeat(v, G, axis=2)
    s = jnp.einsum("bqhd,bshd->bhqs", q, k).astype(jnp.float64) * hd**-0.5
    ok = kp[:, None, :] >= 0
    if causal:
        ok = ok & (qp[:, :, None] >= kp[:, None, :])
    if window > 0:
        ok = ok & (qp[:, :, None] - kp[:, None, :] < window)
    s = jnp.where(ok[:, None, :, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqs,bshd->bqhd", p, v.astype(jnp.float64))
    return out.reshape(B, S, H * hd)


@pytest.mark.parametrize("mode", ["train", "infer"])
@pytest.mark.parametrize("window,causal", [(-1, True), (7, True), (-1, False)])
@pytest.mark.parametrize("S,Skv,H,Kv", [(32, 32, 4, 2), (24, 24, 6, 6), (32, 17, 4, 1)])
def test_blockwise_matches_dense(mode, window, causal, S, Skv, H, Kv):
    if Skv != S and causal:
        pytest.skip("ragged kv only used for cross attention")
    key = jax.random.PRNGKey(0)
    B, hd = 2, 8
    q = jax.random.normal(key, (B, S, H, hd), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, Skv, Kv, hd), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, Skv, Kv, hd), jnp.float32)
    qp = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    kp = jnp.broadcast_to(jnp.arange(Skv, dtype=jnp.int32)[None], (B, Skv))
    got = attn.blockwise_attention(q, k, v, qp, kp, window=window, causal=causal,
                                   block_q=8, block_kv=8, mode=mode)
    want = dense_reference(q, k, v, qp, kp, window, causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_blockwise_gradients_match_dense():
    key = jax.random.PRNGKey(3)
    B, S, H, Kv, hd = 2, 32, 4, 2, 8
    q = jax.random.normal(key, (B, S, H, hd), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, Kv, hd), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, Kv, hd), jnp.float32)
    qp = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    w = jnp.cos(jnp.arange(B * S * H * hd, dtype=jnp.float32).reshape(B, S, H * hd) * 0.01)

    def f_block(q, k, v):
        return jnp.sum(attn.blockwise_attention(q, k, v, qp, qp, window=-1,
                                                block_q=8, block_kv=8, mode="train") * w)

    def f_dense(q, k, v):
        return jnp.sum(dense_reference(q, k, v, qp, qp, -1, True).astype(jnp.float32) * w)

    ga = jax.grad(f_block, argnums=(0, 1, 2))(q, k, v)
    gb = jax.grad(f_dense, argnums=(0, 1, 2))(q, k, v)
    for a, b, name in zip(ga, gb, "qkv"):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4,
                                   err_msg=name)


def _mini_cfg(window=-1):
    return ModelConfig(
        name="t", family="dense", num_layers=1, d_model=32, num_heads=4,
        num_kv_heads=2, head_dim=8, d_ff=64, vocab_size=128,
        window_pattern=(window,), param_dtype="float32", compute_dtype="float32",
    )


@pytest.mark.parametrize("window", [-1, 6])
def test_decode_ring_cache_matches_full_recompute(window):
    """Sequential decode through the (ring) cache == attention over the full
    prefix recomputed each step."""
    cfg = _mini_cfg(window)
    key = jax.random.PRNGKey(0)
    params = attn.attn_init(key, cfg)
    B, T = 2, 12
    xs = jax.random.normal(jax.random.fold_in(key, 1), (B, T, cfg.d_model), jnp.float32)

    cache = attn.init_cache(cfg, B, T, window, jnp.float32)
    outs_dec = []
    for t in range(T):
        out, cache = attn.attn_apply_decode(
            params, xs[:, t : t + 1], jnp.asarray(t, jnp.int32), cache, cfg, window=window)
        outs_dec.append(out)
    got = jnp.concatenate(outs_dec, axis=1)

    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
    want = attn.attn_apply_train(params, xs, positions, cfg, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_head_padding_is_exact():
    """padded_heads > H must not change the result (padded groups are sliced
    off before w_o)."""
    cfg = _mini_cfg()
    key = jax.random.PRNGKey(0)
    params = attn.attn_init(key, cfg)
    B, T = 2, 16
    xs = jax.random.normal(key, (B, T, cfg.d_model), jnp.float32)
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))

    base = attn.attn_apply_train(params, xs, positions, cfg)

    padded = lambda t, s: t
    padded.tp = 8  # forces padded_heads: H=4, Kv=2 -> G'=4 -> Hp=8
    assert cfg.padded_heads(8) == 8
    got = attn.attn_apply_train(params, xs, positions, cfg, constrain=padded)
    np.testing.assert_allclose(np.asarray(got), np.asarray(base), rtol=2e-5, atol=2e-5)
