"""Pallas kernel validation: interpret-mode kernel bodies vs the pure-jnp
oracles, swept over shapes and dtypes (the per-kernel allclose requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.kfu import kfu_pallas
from repro.kernels.psi1 import psi1_pallas
from repro.kernels.psi2 import psi2_pallas

SHAPES = [
    (64, 32, 1),  # paper's Q=1 setting
    (200, 100, 2),  # paper's M=100
    (513, 128, 3),  # n not a tile multiple
    (128, 257, 5),  # m not a tile multiple
    (31, 7, 4),  # everything small / ragged
]
DTYPES = [jnp.float32, jnp.bfloat16]


def _inputs(N, M, Q, dtype, seed=0):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 4)
    mu = jax.random.normal(ks[0], (N, Q), jnp.float32).astype(dtype)
    S = (0.05 + jax.random.uniform(ks[1], (N, Q), jnp.float32)).astype(dtype)
    Z = jax.random.normal(ks[2], (M, Q), jnp.float32).astype(dtype)
    var = jnp.asarray(1.7, jnp.float32)
    ls = 0.5 + jax.random.uniform(ks[3], (Q,), jnp.float32)
    return mu, S, Z, var, ls


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("shape", SHAPES)
def test_kfu_matches_ref(shape, dtype):
    N, M, Q = shape
    X, _, Z, var, ls = _inputs(N, M, Q, dtype)
    got = kfu_pallas(X, Z, var, ls, interpret=True)
    want = ref.kfu_rbf(X.astype(jnp.float32), Z.astype(jnp.float32), var, ls)
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want), **_tol(dtype))


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("shape", SHAPES)
def test_psi1_matches_ref(shape, dtype):
    N, M, Q = shape
    mu, S, Z, var, ls = _inputs(N, M, Q, dtype)
    got = psi1_pallas(mu, S, Z, var, ls, interpret=True)
    want = ref.psi1_rbf(mu.astype(jnp.float32), S.astype(jnp.float32),
                        Z.astype(jnp.float32), var, ls)
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want), **_tol(dtype))


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("shape", SHAPES)
def test_psi2_matches_ref(shape, dtype):
    N, M, Q = shape
    mu, S, Z, var, ls = _inputs(N, M, Q, dtype)
    got = psi2_pallas(mu, S, Z, var, ls, interpret=True)
    want = ref.psi2_rbf(mu.astype(jnp.float32), S.astype(jnp.float32),
                        Z.astype(jnp.float32), var, ls)
    scale = float(jnp.max(jnp.abs(want)))
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(got, np.float32) / scale,
                               np.asarray(want) / scale, rtol=tol, atol=tol)


def test_ops_gradients_match_ref():
    """custom_vjp wrappers: gradients through the Pallas forward equal
    gradients of the oracle (paper Table 2's quantities)."""
    N, M, Q = 48, 24, 2
    mu, S, Z, var, ls = _inputs(N, M, Q, jnp.float32)
    w = jnp.cos(jnp.arange(M * M, dtype=jnp.float32).reshape(M, M) * 0.01)

    def f_ops(mu, S, Z, var, ls):
        return jnp.sum(ops.psi2(mu, S, Z, var, ls) * w) + jnp.sum(
            ops.psi1(mu, S, Z, var, ls)) + jnp.sum(ops.kfu(mu, Z, var, ls))

    def f_ref(mu, S, Z, var, ls):
        return jnp.sum(ref.psi2_rbf(mu, S, Z, var, ls) * w) + jnp.sum(
            ref.psi1_rbf(mu, S, Z, var, ls)) + jnp.sum(ref.kfu_rbf(mu, Z, var, ls))

    g_ops = jax.grad(f_ops, argnums=(0, 1, 2, 3, 4))(mu, S, Z, var, ls)
    g_ref = jax.grad(f_ref, argnums=(0, 1, 2, 3, 4))(mu, S, Z, var, ls)
    for a, b, name in zip(g_ops, g_ref, "mu S Z var ls".split()):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=5e-4, atol=5e-4,
                                   err_msg=name)


def test_pallas_stats_equal_jnp_stats():
    """End-to-end: the sufficient statistics feeding the GP-LVM bound are
    identical between backend='pallas' and backend='jnp' (f32). The bound
    epilogue is deterministic given equal stats (test_gp_bound covers it)."""
    from repro.core import gplvm

    key = jax.random.PRNGKey(0)
    Y = jax.random.normal(key, (96, 3), jnp.float32)
    params = gplvm.init_params(key, np.asarray(Y), Q=1, M=16)
    s_jnp = gplvm.local_stats(params, Y, backend="jnp")
    s_pal = gplvm.local_stats(params, Y, backend="pallas")
    for a, b, name in zip(s_jnp, s_pal, s_jnp._fields):
        np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32),
                                   rtol=5e-4, atol=5e-5, err_msg=name)


@pytest.mark.parametrize("shape", [(200, 100, 1, 3), (513, 128, 3, 2), (64, 130, 2, 5)])
def test_fused_suffstats_kernel_matches_ref(shape):
    """The beyond-paper fused kernel (psi2 + psiY in one pass, §Perf C3)."""
    from repro.kernels.suffstats import suffstats_pallas

    N, M, Q, D = shape
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 4)
    mu = jax.random.normal(ks[0], (N, Q), jnp.float32)
    S = 0.05 + jax.random.uniform(ks[1], (N, Q), jnp.float32)
    Y = jax.random.normal(ks[2], (N, D), jnp.float32)
    Z = jax.random.normal(ks[3], (M, Q), jnp.float32)
    var = jnp.asarray(1.3, jnp.float32)
    ls = 0.6 + jax.random.uniform(ks[1], (Q,), jnp.float32)
    p2, pY = suffstats_pallas(mu, S, Y, Z, var, ls, interpret=True)
    p2r = ref.psi2_rbf(mu, S, Z, var, ls)
    pYr = ref.psi1_rbf(mu, S, Z, var, ls).T @ Y
    np.testing.assert_allclose(np.asarray(p2) / np.abs(p2r).max(),
                               np.asarray(p2r) / np.abs(p2r).max(), atol=2e-6)
    np.testing.assert_allclose(np.asarray(pY) / np.abs(pYr).max(),
                               np.asarray(pYr) / np.abs(pYr).max(), atol=2e-6)


def test_fused_jnp_backend_matches_separate():
    from repro.core import psi_stats

    key = jax.random.PRNGKey(1)
    ks = jax.random.split(key, 4)
    N, M, Q, D = 300, 64, 2, 3
    mu = jax.random.normal(ks[0], (N, Q), jnp.float32)
    S = 0.05 + jax.random.uniform(ks[1], (N, Q), jnp.float32)
    Y = jax.random.normal(ks[2], (N, D), jnp.float32)
    Z = jax.random.normal(ks[3], (M, Q), jnp.float32)
    kp = {"log_variance": jnp.asarray(0.3, jnp.float32),
          "log_lengthscale": jnp.zeros((Q,), jnp.float32)}
    a = psi_stats.expected_stats_rbf(kp, mu, S, Y, Z, backend="jnp")
    b = psi_stats.expected_stats_rbf(kp, mu, S, Y, Z, backend="fused")
    for x, y, name in zip(a, b, a._fields):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=2e-5,
                                   atol=2e-5, err_msg=name)
