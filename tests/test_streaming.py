"""Streaming sufficient-statistics engine: chunked == unchunked (values and
grads), the fused-suffstats hand-derived VJP vs jax.grad of the jnp
reference, the million-point no-(N, M)-materialization guarantee, the
donation-honoring Adam driver, composite init kwargs, and benchmark input
validation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import distributed, gplvm, inference, psi_stats
from repro.gp import BayesianGPLVM, SparseGPRegression, get, suff_stats
from repro.gp.stats import ExactBatch, ExpectedBatch
from repro.kernels import ops, ref
from repro.analysis import assert_no_scaling


def _f64(tree):
    return jax.tree.map(lambda x: x.astype(jnp.float64), tree)


def _qx(key, N, Q):
    k1, k2 = jax.random.split(key)
    mu = jax.random.normal(k1, (N, Q), jnp.float64)
    S = 0.05 + 0.2 * jax.random.uniform(k2, (N, Q), jnp.float64)
    return mu, S


def _data(key, N=137, Q=2, D=3, M=9):
    X = jax.random.normal(key, (N, Q), jnp.float64)
    Y = jax.random.normal(jax.random.fold_in(key, 1), (N, D), jnp.float64)
    Z = jax.random.normal(jax.random.fold_in(key, 2), (M, Q), jnp.float64)
    return X, Y, Z


# chunk sizes: non-dividing, dividing prefix, == N, > N
CHUNKS = (32, 50, 137, 200)


def _assert_stats_close(a, b, rtol=1e-9):
    for x, y, name in zip(a, b, a._fields):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=rtol,
                                   atol=1e-12, err_msg=name)


# ---------------------------------------------------------------------------
# chunked == unchunked: values
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ("rbf", "matern32", "sum"))
@pytest.mark.parametrize("chunk", CHUNKS)
def test_streaming_exact_stats_match_full(name, chunk):
    key = jax.random.PRNGKey(0)
    X, Y, Z = _data(key)
    kern = get(name)(2) if name != "sum" else get(name)(get("rbf")(2), get("linear")(2))
    p = _f64(kern.init())
    full = suff_stats(kern, p, ExactBatch(X, Y, Z))
    chunked = suff_stats(kern, p, ExactBatch(X, Y, Z), chunk=chunk)
    _assert_stats_close(full, chunked)


@pytest.mark.parametrize("name", ("rbf", "linear"))
@pytest.mark.parametrize("chunk", CHUNKS)
def test_streaming_expected_stats_match_full(name, chunk):
    key = jax.random.PRNGKey(1)
    _, Y, Z = _data(key)
    mu, S = _qx(key, 137, 2)
    kern = get(name)(2)
    p = _f64(kern.init())
    full = suff_stats(kern, p, ExpectedBatch(mu, S, Y, Z))
    chunked = suff_stats(kern, p, ExpectedBatch(mu, S, Y, Z), chunk=chunk)
    _assert_stats_close(full, chunked)


# ---------------------------------------------------------------------------
# chunked == unchunked: grads
# ---------------------------------------------------------------------------

def _weighted_scalar(stats):
    """A generic non-trivial functional of the statistics (fixed weights)."""
    M = stats.psi2.shape[0]
    w2 = jnp.cos(0.1 * jnp.arange(M * M, dtype=stats.psi2.dtype)).reshape(M, M)
    wY = jnp.sin(0.1 * jnp.arange(stats.psiY.size, dtype=stats.psiY.dtype)
                 ).reshape(stats.psiY.shape)
    return (stats.psi0 + jnp.sum(stats.psi2 * w2) + jnp.sum(stats.psiY * wY)
            + stats.yy)


@pytest.mark.parametrize("chunk", (32, 137))
def test_streaming_exact_grads_match_full(chunk):
    key = jax.random.PRNGKey(2)
    X, Y, Z = _data(key)
    kern = get("rbf")(2)
    p = _f64(kern.init(1.3, 0.8))

    def scalar(p, Z, c):
        return _weighted_scalar(suff_stats(kern, p, ExactBatch(X, Y, Z), chunk=c))

    ga = jax.grad(scalar, argnums=(0, 1))(p, Z, None)
    gb = jax.grad(scalar, argnums=(0, 1))(p, Z, chunk)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=1e-9, atol=1e-12), ga, gb)


@pytest.mark.parametrize("chunk", (32, 137))
def test_streaming_expected_grads_match_full(chunk):
    key = jax.random.PRNGKey(3)
    _, Y, Z = _data(key)
    mu, S = _qx(key, 137, 2)
    kern = get("rbf")(2)
    p = _f64(kern.init())

    def scalar(p, mu, S, Z, c):
        return _weighted_scalar(
            suff_stats(kern, p, ExpectedBatch(mu, S, Y, Z), chunk=c))

    ga = jax.grad(scalar, argnums=(0, 1, 2, 3))(p, mu, S, Z, None)
    gb = jax.grad(scalar, argnums=(0, 1, 2, 3))(p, mu, S, Z, chunk)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=1e-9, atol=1e-12), ga, gb)


def test_streaming_under_mesh_matches_unchunked():
    """chunk= composes with shard_map: same distributed loss and grads."""
    key = jax.random.PRNGKey(4)
    N = 256
    X = jax.random.uniform(key, (N, 1), jnp.float64, -3.0, 3.0)
    Y = jnp.sin(2.0 * X)
    mesh = distributed.make_gp_mesh()
    params = {"kern": _f64(get("rbf")(1).init()), "Z": X[:16],
              "log_beta": jnp.asarray(2.0, jnp.float64)}
    base = distributed.sgpr_loss_dist(mesh, kernel=get("rbf")(1))
    chunked = distributed.sgpr_loss_dist(mesh, kernel=get("rbf")(1), chunk=100)
    va, ga = jax.value_and_grad(base)(params, X, Y)
    vb, gb = jax.value_and_grad(chunked)(params, X, Y)
    # summation order differs; the bound epilogue amplifies f64 roundoff
    np.testing.assert_allclose(float(va), float(vb), rtol=1e-6)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=1e-6,
        atol=1e-6 * max(1e-2, float(np.max(np.abs(np.asarray(a)))))), ga, gb)


# ---------------------------------------------------------------------------
# fused suffstats op: hand-derived VJP vs jax.grad of the jnp reference
# ---------------------------------------------------------------------------

def _fused_case(key, N, M=11, Q=2, D=3):
    ks = jax.random.split(key, 6)
    mu = jax.random.normal(ks[0], (N, Q), jnp.float64)
    S = 0.05 + jax.random.uniform(ks[1], (N, Q), jnp.float64)
    Y = jax.random.normal(ks[2], (N, D), jnp.float64)
    Z = jax.random.normal(ks[3], (M, Q), jnp.float64)
    var = jnp.asarray(1.3, jnp.float64)
    ls = 0.6 + jax.random.uniform(ks[4], (Q,), jnp.float64)
    g2 = jax.random.normal(ks[5], (M, M), jnp.float64)
    gY = jax.random.normal(jax.random.fold_in(key, 7), (M, D), jnp.float64)
    return mu, S, Y, Z, var, ls, g2, gY


@pytest.mark.parametrize("N", (200, 1500))
def test_fused_suffstats_vjp_matches_reference_grad(N):
    """N=200 exercises the Pallas forward (interpret mode); N=1500 the
    streaming jnp twin. Both use the hand-derived streaming VJP, compared
    against jax.grad of the dense jnp reference formulas."""
    mu, S, Y, Z, var, ls, g2, gY = _fused_case(jax.random.PRNGKey(5), N)

    def via_op(mu, S, Y, Z, var, ls):
        p2, pY = ops.suffstats(mu, S, Y, Z, var, ls)
        return jnp.sum(g2 * p2) + jnp.sum(gY * pY)

    def via_ref(mu, S, Y, Z, var, ls):
        p2 = ref.psi2_rbf(mu, S, Z, var, ls)
        pY = ref.psi1_rbf(mu, S, Z, var, ls).T @ Y
        return jnp.sum(g2 * p2) + jnp.sum(gY * pY)

    args = (mu, S, Y, Z, var, ls)
    np.testing.assert_allclose(float(via_op(*args)), float(via_ref(*args)),
                               rtol=1e-10)
    g_op = jax.grad(via_op, argnums=tuple(range(6)))(*args)
    g_ref = jax.grad(via_ref, argnums=tuple(range(6)))(*args)
    for a, b, name in zip(g_op, g_ref, ("mu", "S", "Y", "Z", "var", "ls")):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-8,
                                   atol=1e-10, err_msg=name)


def test_gplvm_fused_grad_matches_jnp_reference():
    """Acceptance bar: jax.grad of the GP-LVM loss with backend="fused"
    (Pallas forward in interpret mode) matches the jnp reference to <= 1e-4
    relative error, per parameter leaf."""
    key = jax.random.PRNGKey(6)
    Y = jax.random.normal(jax.random.fold_in(key, 1), (300, 3), jnp.float64)
    params = _f64(gplvm.init_params(key, np.asarray(Y), Q=1, M=12))
    assert 300 <= ops.FUSED_INTERPRET_MAX_N  # really the interpret path
    g_ref = jax.grad(gplvm.loss)(params, Y, backend="jnp")
    g_fused = jax.grad(gplvm.loss)(params, Y, backend="fused")
    ref_leaves, _ = jax.tree_util.tree_flatten_with_path(g_ref)
    fused_leaves, _ = jax.tree_util.tree_flatten_with_path(g_fused)
    for (path, a), (_, b) in zip(ref_leaves, fused_leaves):
        a, b = np.asarray(a, np.float64), np.asarray(b, np.float64)
        rel = np.max(np.abs(a - b)) / (np.max(np.abs(a)) + 1e-12)
        assert rel <= 1e-4, (jax.tree_util.keystr(path), rel)


def test_fused_backend_trains_under_fit():
    """backend="fused" is no longer inference-only: fit() runs jax.grad
    through the fused op and the bound improves."""
    key = jax.random.PRNGKey(7)
    from repro.data.synthetic import gplvm_synthetic

    _, Y = gplvm_synthetic(key, N=192, D=3, Q=1)
    Y = Y.astype(jnp.float64)
    lvm = BayesianGPLVM(kernel=get("rbf")(1), M=12, backend="fused")
    l0 = None
    for steps in (1, 40):
        lvm.fit(Y, steps=steps, lr=5e-2, key=key)
        if l0 is None:
            l0 = lvm.history[-1]
    assert lvm.history[-1] < l0 - 0.1, (l0, lvm.history[-1])


# ---------------------------------------------------------------------------
# million-point scale: nothing materializes an (N, M) array
# ---------------------------------------------------------------------------

def _no_nm_intermediate(fn, *args, N, M):
    """The guarantee stated once, via the analyzer: no intermediate anywhere
    in the trace scales like O(N*M) (default margin 4 reads "nothing within
    4x of an (N, M) array" — streaming would be broken)."""
    assert_no_scaling(fn, *args, axis="N", worse_than="N*M",
                      sizes={"N": N, "M": M})


def test_million_point_chunked_training_has_no_nm_workspace():
    """Trace-level guarantee at N=1e6, M=100: the largest intermediate
    anywhere in value_and_grad of both chunked losses stays chunk-sized."""
    N, M, chunk = 1_000_000, 100, 8192
    key = jax.random.PRNGKey(8)
    X = jax.random.uniform(key, (N, 1), jnp.float32, -3.0, 3.0)
    Y = jnp.sin(2.0 * X)
    gp = SparseGPRegression(kernel=get("rbf")(1), M=M, chunk=chunk)
    p = gp.init_params(X, Y)
    _no_nm_intermediate(jax.value_and_grad(gp._loss_fn()), p, X, Y, N=N, M=M)
    # posterior/predict statistics pass too
    _no_nm_intermediate(gp._build_stats(), p, X, Y, N=N, M=M)

    # GP-LVM: same engine, expected statistics
    params = {
        "kern": get("rbf")(1).init(),
        "Z": jax.random.normal(key, (M, 1), jnp.float32),
        "log_beta": jnp.asarray(2.0, jnp.float32),
        "q_mu": jax.random.normal(key, (N, 1), jnp.float32),
        "q_logS": jnp.full((N, 1), -2.0, jnp.float32),
    }
    Yl = jnp.ones((N, 2), jnp.float32)

    def lvm_loss(params, Y):
        return gplvm.loss(params, Y, kernel=get("rbf")(1), chunk=chunk)

    _no_nm_intermediate(jax.value_and_grad(lvm_loss), params, Yl, N=N, M=M)


@pytest.mark.slow
def test_million_point_sgpr_fit_and_predict_executes():
    """The acceptance scenario, actually executed on this box: fit and
    predict at N = 1,000,000 (M = 100) through the streaming engine."""
    N, M = 1_000_000, 100
    key = jax.random.PRNGKey(9)
    X = jax.random.uniform(key, (N, 1), jnp.float32, -3.0, 3.0)
    f = jnp.sin(2.0 * X[:, 0])
    Y = (f + 0.1 * jax.random.normal(jax.random.fold_in(key, 1), (N,)))[:, None]
    gp = SparseGPRegression(kernel=get("rbf")(1), M=M, chunk=8192)
    gp.fit(X, Y, steps=2, lr=3e-2)
    mean, var = gp.predict(X[:512])
    rmse = float(jnp.sqrt(jnp.mean((mean[:, 0] - f[:512]) ** 2)))
    assert np.isfinite(gp.history[-1])
    assert np.all(np.asarray(var) > 0)
    assert rmse < 0.5, rmse  # 2 steps: sanity, not convergence


# ---------------------------------------------------------------------------
# distributed posterior (ROADMAP item)
# ---------------------------------------------------------------------------

def test_posterior_statistics_distribute_over_mesh():
    key = jax.random.PRNGKey(10)
    N = 400
    X = jnp.sort(jax.random.uniform(key, (N, 1), jnp.float64, -3.0, 3.0), axis=0)
    Y = jnp.sin(2.0 * X)
    mesh = distributed.make_gp_mesh()
    gp_mesh = SparseGPRegression(kernel=get("rbf")(1), M=16, mesh=mesh,
                                 chunk=128).fit(X, Y, steps=40)
    gp_local = SparseGPRegression(kernel=get("rbf")(1), M=16)
    gp_local.fit(X, Y, steps=0, params=gp_mesh.params)
    gp_local.params = gp_mesh.params
    a, b = gp_mesh.posterior(), gp_local.posterior()
    np.testing.assert_allclose(np.asarray(a.mean_u), np.asarray(b.mean_u),
                               rtol=1e-8, atol=1e-10)
    np.testing.assert_allclose(np.asarray(a.cov_u), np.asarray(b.cov_u),
                               rtol=1e-8, atol=1e-10)

    from repro.data.synthetic import gplvm_synthetic

    _, Yl = gplvm_synthetic(key, N=128, D=3, Q=1)
    Yl = Yl.astype(jnp.float64)
    lvm_mesh = BayesianGPLVM(kernel=get("rbf")(1), M=12, mesh=mesh, chunk=48)
    lvm_mesh.fit(Yl, steps=30, lr=5e-2, key=key)
    lvm_local = BayesianGPLVM(kernel=get("rbf")(1), M=12)
    lvm_local.fit(Yl, steps=0, params=lvm_mesh.params, key=key)
    lvm_local.params = lvm_mesh.params
    a, b = lvm_mesh.posterior(), lvm_local.posterior()
    np.testing.assert_allclose(np.asarray(a.mean_u), np.asarray(b.mean_u),
                               rtol=1e-8, atol=1e-10)


# ---------------------------------------------------------------------------
# fit_adam: donation honored, no wasted final statistics pass
# ---------------------------------------------------------------------------

def test_fit_adam_history_and_donate_paths_agree():
    key = jax.random.PRNGKey(11)
    X = jax.random.normal(key, (64, 2), jnp.float64)
    w0 = {"w": jnp.zeros((2,), jnp.float64)}
    target = jnp.asarray([1.0, -2.0], jnp.float64)

    def loss(p, X):
        return jnp.mean((X @ (p["w"] - target)) ** 2)

    pa, ha = inference.fit_adam(loss, w0, (X,), steps=50, lr=0.1, donate=True)
    pb, hb = inference.fit_adam(loss, w0, (X,), steps=50, lr=0.1, donate=False)
    np.testing.assert_allclose(np.asarray(pa["w"]), np.asarray(pb["w"]), rtol=1e-12)
    # history ends with the final step's loss; no extra evaluation happens
    assert ha and hb and np.isfinite(ha[-1]) and ha[-1] == hb[-1]
    # zero steps -> empty history, params untouched
    p0, h0 = inference.fit_adam(loss, w0, (X,), steps=0)
    assert h0 == [] and np.all(np.asarray(p0["w"]) == 0)
    # when log_every already captured the final step, it is not re-appended
    _, h = inference.fit_adam(loss, w0, (X,), steps=3, log_every=1)
    assert len(h) == 3 and h[0] > h[-1]
    _, h = inference.fit_adam(loss, w0, (X,), steps=4, log_every=2)
    assert len(h) == 3  # logged at i=0, 2; final step (i=3) appended once


# ---------------------------------------------------------------------------
# composite kernel init kwargs (ROADMAP item)
# ---------------------------------------------------------------------------

def test_composite_init_forwards_per_part_kwargs():
    from repro.gp.kernels import Linear, Product, RBF, Sum

    kern = Sum(RBF(2), Linear(2))
    p = kern.init(k0={"variance": 2.0, "lengthscale": 0.5}, k1={"variance": 3.0})
    np.testing.assert_allclose(float(p["k0"]["log_variance"]), np.log(2.0),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(p["k0"]["log_lengthscale"]),
                               np.log(0.5), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(p["k1"]["log_ard"]), np.log(3.0),
                               rtol=1e-6)
    prod = Product(RBF(2), RBF(2))
    p = prod.init(k1={"lengthscale": 2.0})
    np.testing.assert_allclose(np.asarray(p["k1"]["log_lengthscale"]),
                               np.log(2.0), rtol=1e-6)

    with pytest.raises(TypeError, match="k0, k1"):
        kern.init(variance=2.0)
    with pytest.raises(TypeError, match="dict"):
        kern.init(k0=2.0)


# ---------------------------------------------------------------------------
# benchmark kernel-name validation (ROADMAP item)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bad", ("matern32", "sum", "product"))
def test_benchmarks_validate_kernel_names(bad):
    from benchmarks import gp_scaling, gp_stream, indistributable

    for mod in (gp_scaling, indistributable, gp_stream):
        with pytest.raises(ValueError, match="closed-form psi"):
            mod.run(kernel_name=bad)
    # the supported names pass validation (probe without running the bench)
    from benchmarks.common import validate_psi_kernel

    validate_psi_kernel("rbf")
    validate_psi_kernel("linear")
