"""Optimizer substrate: Adam vs a numpy reference, schedules, clipping,
top-k compression with error feedback."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.optim import (AdamConfig, adam_init, adam_update, constant_schedule,
                         cosine_schedule, topk_compress_decompress, wsd_schedule)
from repro.optim.compression import compression_init


def numpy_adam(params, grads, steps, lr=0.1, b1=0.9, b2=0.999, eps=1e-8):
    m = np.zeros_like(params)
    v = np.zeros_like(params)
    p = params.copy()
    for t in range(1, steps + 1):
        g = grads[t - 1]
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / (1 - b1**t)
        vh = v / (1 - b2**t)
        p = p - lr * mh / (np.sqrt(vh) + eps)
    return p


def test_adam_matches_numpy_reference():
    rng = np.random.RandomState(0)
    p0 = rng.randn(17).astype(np.float32)
    gs = [rng.randn(17).astype(np.float32) for _ in range(5)]
    cfg = AdamConfig(lr=0.1, clip_norm=None, weight_decay=0.0)
    params = {"w": jnp.asarray(p0)}
    state = adam_init(params, cfg)
    for g in gs:
        params, state, _ = adam_update({"w": jnp.asarray(g)}, state, params, cfg)
    # reference uses mh/(sqrt(vh)+eps); ours folds the bias correction into
    # alpha: identical up to the eps placement — loose tolerance
    want = numpy_adam(p0, gs, 5)
    np.testing.assert_allclose(np.asarray(params["w"]), want, rtol=1e-3, atol=1e-4)


def test_adam_minimizes_quadratic():
    cfg = AdamConfig(lr=0.05, clip_norm=1.0, weight_decay=0.0)
    params = {"w": jnp.asarray([3.0, -2.0, 1.0])}
    state = adam_init(params, cfg)
    loss = lambda p: jnp.sum((p["w"] - jnp.asarray([1.0, 1.0, 1.0])) ** 2)
    for _ in range(300):
        g = jax.grad(loss)(params)
        params, state, _ = adam_update(g, state, params, cfg)
    assert float(loss(params)) < 1e-3


def test_clipping_bounds_update():
    cfg = AdamConfig(lr=1.0, clip_norm=0.5)
    params = {"w": jnp.zeros((4,))}
    state = adam_init(params, cfg)
    _, _, gnorm = adam_update({"w": jnp.full((4,), 100.0)}, state, params, cfg)
    assert float(gnorm) == 200.0  # pre-clip norm reported


def test_bf16_state_dtype():
    cfg = AdamConfig(lr=0.1, state_dtype="bfloat16")
    params = {"w": jnp.zeros((8,), jnp.bfloat16)}
    state = adam_init(params, cfg)
    assert state.m["w"].dtype == jnp.bfloat16
    params2, state2, _ = adam_update({"w": jnp.ones((8,), jnp.bfloat16)}, state, params, cfg)
    assert state2.v["w"].dtype == jnp.bfloat16
    assert params2["w"].dtype == jnp.bfloat16


def test_schedules_shape():
    wsd = wsd_schedule(1.0, 10, 20, 10)
    assert float(wsd(jnp.asarray(0))) == 0.0
    assert abs(float(wsd(jnp.asarray(10))) - 1.0) < 1e-6
    assert abs(float(wsd(jnp.asarray(25))) - 1.0) < 1e-6
    assert float(wsd(jnp.asarray(40))) < 0.02
    cos = cosine_schedule(1.0, 5, 50)
    assert float(cos(jnp.asarray(5))) >= float(cos(jnp.asarray(50)))
    assert float(constant_schedule(0.3)(jnp.asarray(7))) == np.float32(0.3)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**16), ratio=st.sampled_from([0.05, 0.2, 0.5]))
def test_topk_compression_error_feedback_conserves_signal(seed, ratio):
    """Sum over steps of compressed grads + final residual == sum of raw
    grads (error feedback loses nothing)."""
    rng = np.random.RandomState(seed)
    grads = [{"w": jnp.asarray(rng.randn(64).astype(np.float32))} for _ in range(6)]
    state = compression_init(grads[0])
    sent_total = np.zeros(64, np.float32)
    for g in grads:
        sent, state = topk_compress_decompress(g, state, ratio=ratio)
        sent_total += np.asarray(sent["w"])
        nnz = int(np.sum(np.asarray(sent["w"]) != 0))
        assert nnz <= max(1, int(ratio * 64)) + 1
    raw_total = sum(np.asarray(g["w"]) for g in grads)
    np.testing.assert_allclose(sent_total + np.asarray(state.residual["w"]),
                               raw_total, rtol=1e-4, atol=1e-5)
