"""MoE dispatch invariants: the permutation-gather path equals a naive
per-token loop when capacity is unconstrained; drops behave; EP shard_map
path matches (subprocess, 8 fake devices)."""
import dataclasses
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs.base import get_smoke_config
from repro.models import moe as moe_mod


def _cfg(cf=8.0, arch="arctic-480b"):
    return dataclasses.replace(get_smoke_config(arch), capacity_factor=cf)


def naive_reference(params, x, cfg):
    """Per-token loop over top-k experts (no capacity)."""
    B, S, d = x.shape
    xt = x.reshape(-1, d)
    logits = xt @ params["router"]
    probs = jax.nn.softmax(logits, -1)
    top_p, top_e = jax.lax.top_k(probs, cfg.num_experts_per_tok)
    top_p = top_p / jnp.sum(top_p, -1, keepdims=True)
    wg, wu, wd = params["w_gate"], params["w_up"], params["w_down"]
    out = jnp.zeros_like(xt)
    for t in range(xt.shape[0]):
        acc = jnp.zeros((d,))
        for j in range(cfg.num_experts_per_tok):
            e = top_e[t, j]
            h = jax.nn.silu(xt[t] @ wg[e]) * (xt[t] @ wu[e])
            acc = acc + top_p[t, j] * (h @ wd[e])
        out = out.at[t].set(acc)
    return out.reshape(B, S, d)


def test_dense_path_matches_naive_loop():
    cfg = _cfg()
    key = jax.random.PRNGKey(0)
    params = jax.tree.map(lambda x: x.astype(jnp.float64),
                          moe_mod.moe_init(key, cfg))
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 8, cfg.d_model), jnp.float64)
    got = moe_mod.moe_apply_dense(params, x, cfg).y
    want = naive_reference(params, x, cfg)
    # moe_apply computes the expert FFN in cfg.compute_dtype (f32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5)


def test_capacity_drops_reduce_output_norm_only():
    """With tight capacity, outputs are a masked version of the uncapped ones
    (dropped pairs contribute zero), never garbage."""
    key = jax.random.PRNGKey(1)
    cfg_lo = _cfg(cf=0.25)
    params = moe_mod.moe_init(key, cfg_lo)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 32, cfg_lo.d_model), jnp.float32)
    y_lo = moe_mod.moe_apply_dense(params, x, cfg_lo).y
    y_hi = moe_mod.moe_apply_dense(params, x, _cfg(cf=8.0)).y
    assert np.all(np.isfinite(np.asarray(y_lo)))
    assert float(jnp.linalg.norm(y_lo)) <= float(jnp.linalg.norm(y_hi)) * 1.25 + 1e-3


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**16), T=st.integers(2, 16))
def test_aux_loss_bounds(seed, T):
    """Switch LB loss: >= 1 at perfect balance... >= its theoretical min of 1
    is not guaranteed per-batch, but it is >= 0 and <= E."""
    cfg = _cfg()
    key = jax.random.PRNGKey(seed)
    params = moe_mod.moe_init(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1), (1, T, cfg.d_model), jnp.float32)
    aux = float(moe_mod.moe_apply_dense(params, x, cfg).aux_loss)
    assert 0.0 <= aux <= cfg.num_experts


def test_permute_rows_vjp_is_gather_exact():
    key = jax.random.PRNGKey(2)
    n_in, n_out, d = 10, 7, 4
    x = jax.random.normal(key, (n_in, d), jnp.float64)
    fwd = jnp.asarray([3, 9, 0, n_in, 5, 1, n_in], jnp.int32)  # sentinels = n_in
    inv = jnp.full((n_in,), n_out, jnp.int32)
    for j, i in enumerate(fwd):
        if int(i) < n_in:
            inv = inv.at[int(i)].set(j)
    w = jnp.arange(n_out * d, dtype=jnp.float64).reshape(n_out, d)

    f = lambda x: jnp.sum(moe_mod.permute_rows(x, fwd, inv, n_out) * w)
    g = jax.grad(f)(x)
    # reference via dense one-hot
    onehot = (fwd[:, None] == jnp.arange(n_in)[None, :]).astype(jnp.float64)
    g_ref = (onehot * 1.0).T @ w
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref), rtol=1e-12)


EP_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "{src}")
import jax, jax.numpy as jnp, numpy as np, dataclasses
from repro.configs.base import get_smoke_config
from repro.models import moe as moe_mod
from repro.parallel import sharding as shd

cfg = dataclasses.replace(get_smoke_config("arctic-480b"), capacity_factor=8.0)
from repro import compat
mesh = compat.make_mesh((2, 4), ("data", "model"))
params = moe_mod.moe_init(jax.random.PRNGKey(0), cfg)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model), jnp.float32)
constrain = shd.make_constrain(mesh)
def ld(p, x): return jnp.sum(moe_mod.moe_apply_dense(p, x, cfg).y ** 2)
def le(p, x): return jnp.sum(moe_mod.moe_apply_ep(p, x, cfg, constrain).y ** 2)
with mesh:
    vd, gd = jax.value_and_grad(ld)(params, x)
    ve, ge = jax.jit(jax.value_and_grad(le))(params, x)
assert abs(float(vd) - float(ve)) < 1e-2 * abs(float(vd)), (float(vd), float(ve))
for k in ("w_gate", "w_up", "w_down"):
    err = float(jnp.max(jnp.abs(gd[k] - ge[k])))
    ref = float(jnp.max(jnp.abs(gd[k]))) + 1e-9
    assert err / ref < 1e-3, (k, err, ref)
print("EP-OK")
"""


@pytest.mark.slow
def test_ep_shard_map_matches_dense_subprocess():
    import repro

    src = repro.__file__.rsplit("/repro/", 1)[0]
    out = subprocess.run([sys.executable, "-c", EP_SCRIPT.format(src=src)],
                         capture_output=True, text=True, timeout=600)
    assert "EP-OK" in out.stdout, out.stdout + out.stderr
