"""Correctness of the collapsed variational bound (paper eq. (2)-(4))."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gplvm, inference, psi_stats, svgp
from repro.core.gp_kernels import RBF


def _problem(N=200, M=30, Q=2, D=3, seed=0):
    key = jax.random.PRNGKey(seed)
    X = jax.random.normal(key, (N, Q), jnp.float64)
    kern = RBF(Q)
    kp = {k: v.astype(jnp.float64) for k, v in kern.init(1.5, 0.8).items()}
    W = jax.random.normal(jax.random.PRNGKey(1), (Q, D), jnp.float64)
    Y = jnp.sin(X @ W * 2.0) + 0.1 * jax.random.normal(jax.random.PRNGKey(2), (N, D), jnp.float64)
    return kern, kp, X, Y


def test_bound_below_exact_marginal():
    kern, kp, X, Y = _problem()
    beta = jnp.asarray(100.0, jnp.float64)
    exact = svgp.exact_gp_log_marginal(kern.K(kp, X), Y, beta)
    stats = psi_stats.exact_stats_rbf(kp, X, Y, X[:30], )
    terms = svgp.collapsed_bound(kern.K(kp, X[:30]), stats, beta, Y.shape[1])
    assert float(terms.bound) <= float(exact)


def test_bound_tight_when_Z_is_X():
    kern, kp, X, Y = _problem()
    beta = jnp.asarray(100.0, jnp.float64)
    exact = svgp.exact_gp_log_marginal(kern.K(kp, X), Y, beta)
    stats = psi_stats.exact_stats_rbf(kp, X, Y, X)
    terms = svgp.collapsed_bound(kern.K(kp, X), stats, beta, Y.shape[1])
    # jitter-level slack only
    assert abs(float(exact - terms.bound)) < 0.05 * abs(float(exact)) + 0.5


def test_bound_monotone_in_M():
    kern, kp, X, Y = _problem()
    beta = jnp.asarray(100.0, jnp.float64)
    vals = []
    for M in (5, 15, 60, 200):
        stats = psi_stats.exact_stats_rbf(kp, X, Y, X[:M])
        vals.append(float(svgp.collapsed_bound(kern.K(kp, X[:M]), stats, beta, Y.shape[1]).bound))
    assert vals == sorted(vals), vals


def test_prediction_recovers_function():
    kern, kp, X, Y = _problem(N=300, M=60)
    beta = jnp.asarray(100.0, jnp.float64)
    Z = X[:60]
    stats = psi_stats.exact_stats_rbf(kp, X, Y, Z)
    terms = svgp.collapsed_bound(kern.K(kp, Z), stats, beta, Y.shape[1])
    post = svgp.optimal_qu(terms, beta)
    mean, var = svgp.predict_f(post, kern.K(kp, X[:50], Z), kern.Kdiag(kp, X[:50]))
    rmse = float(jnp.sqrt(jnp.mean((mean - Y[:50]) ** 2)))
    assert rmse < 0.3, rmse
    assert np.all(np.asarray(var) > 0)


def test_gplvm_bound_improves_under_adam():
    key = jax.random.PRNGKey(0)
    from repro.data.synthetic import gplvm_synthetic

    _, Y = gplvm_synthetic(key, N=128, D=3, Q=1)
    Y = Y.astype(jnp.float64)
    params = gplvm.init_params(key, np.asarray(Y), Q=1, M=16)
    params = jax.tree.map(lambda x: x.astype(jnp.float64), params)
    l0 = float(gplvm.loss(params, Y))
    params, hist = inference.fit_adam(gplvm.loss, params, (Y,), steps=60, lr=5e-2)
    assert hist[-1] < l0 - 0.1, (l0, hist[-1])


def test_lbfgs_driver_matches_paper_setup():
    """The paper optimizes with (scipy) L-BFGS-B; a few iterations must
    decrease the negative bound."""
    key = jax.random.PRNGKey(1)
    from repro.data.synthetic import gplvm_synthetic

    _, Y = gplvm_synthetic(key, N=96, D=3, Q=1)
    Y = Y.astype(jnp.float64)
    params = gplvm.init_params(key, np.asarray(Y), Q=1, M=12)
    params = jax.tree.map(lambda x: x.astype(jnp.float64), params)
    l0 = float(gplvm.loss(params, Y))
    _, lf = inference.fit_lbfgs(gplvm.loss, params, (Y,), maxiter=25)
    assert lf < l0
