"""Data pipeline: determinism, exact restart, GP synthetic data statistics."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ShapeCell, get_smoke_config
from repro.data.synthetic import TokenStream, gplvm_synthetic


def test_token_stream_deterministic_restart():
    cfg = get_smoke_config("smollm-360m")
    shape = ShapeCell("t", 32, 2, "train")
    a = TokenStream(cfg, shape, seed=5)
    batches = [a.next() for _ in range(4)]
    state = a.checkpoint_state()
    after = [a.next() for _ in range(3)]

    b = TokenStream(cfg, shape, seed=5)
    b.restore_state(state)
    replay = [b.next() for _ in range(3)]
    for x, y in zip(after, replay):
        np.testing.assert_array_equal(np.asarray(x["tokens"]), np.asarray(y["tokens"]))
    # and different steps differ
    assert not np.array_equal(np.asarray(batches[0]["tokens"]),
                              np.asarray(batches[1]["tokens"]))


def test_token_stream_matches_model_inputs():
    cfg = get_smoke_config("internvl2-2b")
    shape = ShapeCell("t", 64, 2, "train")
    s = TokenStream(cfg, shape)
    batch = s.next()
    assert batch["tokens"].shape == (2, 64 - cfg.frontend_tokens)
    assert batch["frontend_embeds"].shape == (2, cfg.frontend_tokens, cfg.d_model)
    assert int(batch["tokens"].max()) < cfg.vocab_size


def test_gplvm_synthetic_statistics():
    key = jax.random.PRNGKey(0)
    X, Y = gplvm_synthetic(key, N=512, D=3, Q=1)
    assert X.shape == (512, 1) and Y.shape == (512, 3)
    # smooth function of X: nearby X => nearby Y (continuity proxy)
    order = jnp.argsort(X[:, 0])
    Ys = Y[order]
    d_near = float(jnp.mean(jnp.sum((Ys[1:] - Ys[:-1]) ** 2, -1)))
    d_far = float(jnp.mean(jnp.sum((Ys - Ys[::-1]) ** 2, -1)))
    assert d_near < d_far / 3


def test_gplvm_synthetic_rff_path():
    key = jax.random.PRNGKey(1)
    X, Y = gplvm_synthetic(key, N=8192, D=3, Q=1)  # > 4096: RFF branch
    assert Y.shape == (8192, 3)
    assert np.all(np.isfinite(np.asarray(Y)))
