"""Parity battery for `repro.temporal`: the state-space GP backend.

Three oracles pin the subsystem down:

* the KERNEL: k(tau) = H expm(F tau) P_inf H^T must reproduce `Kernel.K`
  for every SDE-capable kernel (leaf Materns, Sum, Product);
* the DENSE GP: log marginal likelihood and posterior from an O(N^3)
  Cholesky (`svgp.exact_gp_log_marginal`, jitter=0) must match the O(N)
  filter/smoother to float64 roundoff;
* ITSELF: the parallel `associative_scan` path must match the sequential
  `lax.scan` twin to <= 1e-10, and `update`-streamed serving state must
  equal the one-shot fit's terminal state.

Plus the scaling contract (no (N, N) intermediate — `analysis`
trace assertions) and the serving/persistence integration.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import gp
from repro.analysis import assert_no_scaling, trace_intermediates
from repro.core.svgp import exact_gp_log_marginal
from repro.gp import kernels as gpk
from repro.serve.persist import PERSIST_SCHEMA
from repro.temporal import (TemporalGPRegression, TemporalState, discretize,
                            forecast, kalman_filter, rts_smoother,
                            update_state)


def _f64_matern(var=1.3, ls=0.7):
    return {"log_variance": jnp.log(jnp.asarray(var, jnp.float64)),
            "log_lengthscale": jnp.full((1,), np.log(ls), jnp.float64)}


def _series(n, d_out=1, seed=0, lo=0.0, hi=10.0):
    """Non-uniformly spaced timestamps + smooth noisy outputs."""
    rng = np.random.default_rng(seed)
    t = np.sort(rng.uniform(lo, hi, n))
    f = np.stack([np.sin((k + 1) * t) for k in range(d_out)], axis=1)
    y = f + 0.1 * rng.standard_normal((n, d_out))
    return jnp.asarray(t), jnp.asarray(y)


def _discretized(kernel, params, t):
    model = kernel.to_sde(params)
    dt = jnp.concatenate([jnp.zeros_like(t[:1]), jnp.diff(t)])
    return model, discretize(model, dt)


SDE_CASES = [
    (gpk.Matern12(1), _f64_matern(1.3, 0.7)),
    (gpk.Matern32(1), _f64_matern(0.8, 1.4)),
    (gpk.Matern52(1), _f64_matern(2.1, 0.5)),
    (gpk.Sum(gpk.Matern32(1), gpk.Matern12(1)),
     {"k0": _f64_matern(0.9, 1.1), "k1": _f64_matern(0.4, 2.3)}),
    (gpk.Product(gpk.Matern32(1), gpk.Matern52(1)),
     {"k0": _f64_matern(1.2, 0.9), "k1": _f64_matern(0.7, 1.6)}),
]


# ---------------------------------------------------------------------------
# kernel <-> SDE duality
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kernel,params", SDE_CASES,
                         ids=[repr(k) for k, _ in SDE_CASES])
def test_sde_reproduces_kernel(kernel, params):
    model = kernel.to_sde(params)
    taus = jnp.asarray([0.0, 0.05, 0.3, 1.0, 2.7, 6.0])
    k_sde = jnp.stack([
        model.H @ jax.scipy.linalg.expm(model.F * tau) @ model.Pinf @ model.H
        for tau in taus])
    X = jnp.zeros((1, 1))
    k_ref = jnp.stack([kernel.K(params, X, X + tau)[0, 0] for tau in taus])
    np.testing.assert_allclose(k_sde, k_ref, rtol=1e-9, atol=1e-12)


@pytest.mark.parametrize("kernel,params", SDE_CASES,
                         ids=[repr(k) for k, _ in SDE_CASES])
def test_sde_lyapunov_and_discretization(kernel, params):
    model = kernel.to_sde(params)
    # stationarity: F Pinf + Pinf F^T + Qc = 0
    resid = model.F @ model.Pinf + model.Pinf @ model.F.T + model.Qc
    np.testing.assert_allclose(resid, 0.0, atol=1e-10)
    dt = jnp.asarray([0.0, 0.02, 0.5, 3.0])
    A, Q = discretize(model, dt)
    np.testing.assert_allclose(A[0], jnp.eye(model.d), atol=1e-14)
    np.testing.assert_allclose(Q[0], 0.0, atol=1e-14)
    for k in range(dt.shape[0]):  # Q_k = Pinf - A Pinf A^T is PSD
        eig = np.linalg.eigvalsh(np.asarray(Q[k]))
        assert eig.min() > -1e-10


def test_matern_to_sde_needs_1d():
    k = gpk.Matern32(3)
    assert not k.supports_sde()
    with pytest.raises(NotImplementedError, match="1-D"):
        k.to_sde(k.init())


def test_capability_queries():
    assert gp.capabilities("matern32") == {"exact": True, "psi": False,
                                           "sde": True}
    assert gp.capabilities("rbf") == {"exact": True, "psi": True,
                                      "sde": False}
    assert gp.capabilities("matern52", input_dim=2)["sde"] is False
    mixed = gpk.Sum(gpk.Matern32(1), gpk.RBF(1))
    assert gp.capabilities(mixed) == {"exact": True, "psi": False,
                                      "sde": False}
    assert gp.capabilities(gpk.Product(gpk.RBF(1), gpk.RBF(1)))["psi"] is True


def test_matern_no_psi_names_temporal():
    k = gpk.Matern32(1)
    with pytest.raises(NotImplementedError, match="temporal"):
        k.psi0(k.init(), jnp.zeros((4, 1)), jnp.ones((4, 1)))


def test_rbf_has_no_sde():
    k = gpk.RBF(1)
    with pytest.raises(NotImplementedError, match="matern"):
        k.to_sde(k.init())


# ---------------------------------------------------------------------------
# parallel associative scan == sequential lax.scan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("d_out", [1, 3])
@pytest.mark.parametrize("masked", [False, True])
def test_parallel_matches_sequential(d_out, masked):
    t, y = _series(257, d_out=d_out, seed=3)
    kernel, params = gpk.Matern52(1), _f64_matern()
    model, (A, Q) = _discretized(kernel, params, t)
    R = jnp.asarray(0.01)
    m0 = jnp.zeros((model.d, d_out))
    mask = None
    if masked:
        mask = jnp.asarray(np.random.default_rng(0).uniform(size=257) < 0.7)
    par = kalman_filter(A, Q, model.H, R, y, m0, model.Pinf, mask=mask,
                        parallel=True)
    seq = kalman_filter(A, Q, model.H, R, y, m0, model.Pinf, mask=mask,
                        parallel=False)
    np.testing.assert_allclose(par.means, seq.means, atol=1e-10)
    np.testing.assert_allclose(par.covs, seq.covs, atol=1e-10)
    np.testing.assert_allclose(par.lml, seq.lml, atol=1e-10)
    sp = rts_smoother(A, Q, par.means, par.covs, parallel=True)
    ss = rts_smoother(A, Q, seq.means, seq.covs, parallel=False)
    np.testing.assert_allclose(sp[0], ss[0], atol=1e-10)
    np.testing.assert_allclose(sp[1], ss[1], atol=1e-10)


# ---------------------------------------------------------------------------
# dense-GP oracle: lml + posterior
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kernel,params", SDE_CASES,
                         ids=[repr(k) for k, _ in SDE_CASES])
@pytest.mark.parametrize("parallel", [True, False])
def test_lml_matches_dense_cholesky(kernel, params, parallel):
    t, y = _series(129, seed=5)
    beta = jnp.asarray(25.0)
    model, (A, Q) = _discretized(kernel, params, t)
    res = kalman_filter(A, Q, model.H, 1.0 / beta, y,
                        jnp.zeros((model.d, 1)), model.Pinf,
                        parallel=parallel)
    Kff = kernel.K(params, t[:, None])
    lml_dense = exact_gp_log_marginal(Kff, y, beta, jitter=0.0)
    # rtol floor: _Matern._r clamps d2 at 1e-18, so the DENSE Kff diagonal
    # is var * exp(-1e-9) — a ~1e-9 relative perturbation the exact SDE
    # path does not share, visible for Matern12 (whose shape function has
    # nonzero slope at r = 0) at ~1e-8 in the lml
    np.testing.assert_allclose(res.lml, lml_dense, rtol=1e-7)


def test_fit_predict_matches_dense_gp_n512():
    """ISSUE acceptance: Matern-3/2 fit + predict vs dense exact GP at
    N=512, <= 1e-6 in f64 — including unsorted, interleaved test points."""
    t, y = _series(512, seed=7)
    X, Y = t[:, None], y[:, 0]
    m = TemporalGPRegression(gpk.Matern32(1)).fit(X, Y, steps=60, lr=5e-2)
    p = m.params
    beta = jnp.exp(p["log_beta"])
    Kff = m.kernel.K(p["kern"], X)
    lml_dense = exact_gp_log_marginal(Kff, Y[:, None], beta, jitter=0.0)
    np.testing.assert_allclose(m.lml(), lml_dense, rtol=1e-7)
    assert m.elbo() == m.lml()

    rng = np.random.default_rng(8)
    Xt = jnp.asarray(rng.uniform(-0.5, 10.5, 64))[:, None]  # unsorted
    mean, var = m.predict(Xt)
    Kxt = m.kernel.K(p["kern"], X, Xt)
    Afac = Kff + jnp.eye(512) / beta
    mean_d = Kxt.T @ jnp.linalg.solve(Afac, Y[:, None])
    var_d = m.kernel.Kdiag(p["kern"], Xt) - jnp.einsum(
        "nt,nt->t", Kxt, jnp.linalg.solve(Afac, Kxt))
    np.testing.assert_allclose(mean, mean_d, atol=1e-6)
    np.testing.assert_allclose(var, var_d, atol=1e-6)

    # posterior() = smoothed marginals at the training timestamps
    pm, pv = m.posterior()
    mean_tr = Kff @ jnp.linalg.solve(Afac, Y[:, None])
    var_tr = jnp.diag(Kff) - jnp.einsum(
        "nt,nt->t", Kff, jnp.linalg.solve(Afac, Kff))
    np.testing.assert_allclose(pm, mean_tr, atol=1e-6)
    np.testing.assert_allclose(pv, var_tr, atol=1e-6)

    # predict(parallel=False) agrees through the sequential path
    mean_s, var_s = m.predict(Xt, parallel=False)
    np.testing.assert_allclose(mean, mean_s, atol=1e-10)
    np.testing.assert_allclose(var, var_s, atol=1e-10)


def test_backend_dispatch_and_validation():
    t, y = _series(64)
    X, Y = t[:, None], y[:, 0]
    m = gp.regression(gpk.Matern32(1), backend="temporal")
    assert isinstance(m, TemporalGPRegression)
    assert isinstance(gp.regression(gpk.RBF(1), backend="collapsed", M=8),
                      gp.SparseGPRegression)
    with pytest.raises(ValueError, match="backend"):
        gp.regression(gpk.RBF(1), backend="nope")
    with pytest.raises(ValueError, match="supports_sde"):
        gp.regression(gpk.RBF(1), backend="temporal")

    with pytest.raises(ValueError, match="sorted ascending"):
        m.fit(X[::-1], Y)
    with pytest.raises(ValueError, match="duplicate timestamp"):
        m.fit(jnp.concatenate([X[:1], X]), jnp.concatenate([Y[:1], Y]))
    with pytest.raises(ValueError, match="1-D inputs"):
        m.fit(jnp.zeros((8, 2)), Y[:8])
    with pytest.raises(ValueError, match="rows"):
        m.fit(X, Y[:-3])
    with pytest.raises(RuntimeError, match="not fitted"):
        m.predict(X)
    with pytest.raises(ValueError, match="optimizer"):
        m.fit(X, Y, optimizer="sgd")
    m.fit(X, Y, steps=2)
    assert m.predict(X[:4])[0].shape == (4, 1)
    m.fit(X, Y, optimizer="lbfgs", steps=3)  # lbfgs path also drives


def test_streamed_update_equals_one_shot():
    t, y = _series(300, seed=11)
    X, Y = t[:, None], y[:, 0]
    kernel = gpk.Matern52(1)
    m = TemporalGPRegression(kernel).fit(X, Y, steps=25, lr=5e-2)
    full = m.export_state()

    half = TemporalGPRegression(kernel)
    half.fit(X[:100], Y[:100], steps=0, params=m.params)
    st = half.export_state()
    # stream the rest in two uneven chunks through the serving-layer entry
    from repro.serve import online
    st = online.update(kernel, st, X[100:230], Y[100:230])
    st = update_state(kernel, st, X[230:], Y[230:])
    np.testing.assert_allclose(st.m, full.m, atol=1e-10)
    np.testing.assert_allclose(st.P, full.P, atol=1e-10)
    assert float(st.t_last) == float(full.t_last)
    assert float(st.n) == float(full.n)

    with pytest.raises(ValueError, match="strictly after"):
        update_state(kernel, st, X[:5], Y[:5])
    with pytest.raises(ValueError, match="output column"):
        update_state(kernel, st, X[-1:] + 1.0, jnp.zeros((1, 3)))


# ---------------------------------------------------------------------------
# serving tier
# ---------------------------------------------------------------------------


def _fitted(n=200, seed=13, steps=20):
    t, y = _series(n, seed=seed)
    m = TemporalGPRegression(gpk.Matern32(1))
    m.fit(t[:, None], y[:, 0], steps=steps, lr=5e-2)
    return m


def test_server_serves_and_streams_temporal(tmp_path):
    from repro import serve

    m = _fitted()
    with serve.GPServer(store=serve.StateStore(tmp_path)) as srv:
        srv.register("ts", m)
        Xf = jnp.linspace(10.2, 12.0, 9)[:, None]
        mean, var = srv.predict("ts", Xf)
        fm, fv = forecast(m.kernel, m.export_state(), Xf)
        np.testing.assert_allclose(mean, fm, atol=0)
        np.testing.assert_allclose(var, fv, atol=0)
        # functional serve.predict dispatches on the state type
        fn_mean, fn_var = serve.predict(m.kernel, m.export_state(), Xf)
        np.testing.assert_allclose(fn_mean, fm, atol=0)
        # coalesced submit path
        futs = [srv.submit("ts", Xf[i:i + 3]) for i in range(0, 9, 3)]
        got = jnp.concatenate([f.result(timeout=30)[0] for f in futs])
        np.testing.assert_allclose(got, fm, atol=0)
        # marginals only: full covariance is a training-data question
        with pytest.raises(ValueError, match="diag=False"):
            srv.predict("ts", Xf, diag=False)
        with pytest.raises(ValueError, match="diag=False"):
            serve.predict(m.kernel, m.export_state(), Xf, diag=False)
        # streaming update through the server facade
        Xn = jnp.linspace(12.1, 13.0, 16)[:, None]
        srv.update("ts", Xn, jnp.sin(Xn[:, 0]))
        assert float(srv.state("ts").t_last) == pytest.approx(13.0)
        # monoid-only operations refuse the temporal state
        with pytest.raises(TypeError, match="forward"):
            srv.downdate("ts", Xn, jnp.sin(Xn[:, 0]))
        with pytest.raises(TypeError, match="statistics"):
            srv.refit("ts")


def test_temporal_state_persistence_round_trip(tmp_path):
    from repro import serve

    m = _fitted(seed=17)
    st = m.export_state()
    store = serve.StateStore(tmp_path)
    store.save("ts", m.kernel, st)
    assert serve.state_kind(st) == "temporal"
    kernel2, st2 = store.load("ts")
    assert isinstance(st2, TemporalState)
    assert repr(kernel2) == repr(m.kernel)
    for a, b in zip(jax.tree_util.tree_leaves(st),
                    jax.tree_util.tree_leaves(st2)):
        assert bool(jnp.all(a == b))  # bit-exact
    # cold restart serves identically
    srv = serve.GPServer.load(store)
    Xf = jnp.linspace(10.5, 11.5, 4)[:, None]
    np.testing.assert_allclose(srv.predict("ts", Xf)[0],
                               forecast(m.kernel, st, Xf)[0], atol=0)
    srv.close()


def test_schema1_manifest_still_loads_as_posterior(tmp_path):
    """Back-compat: pre-temporal (schema 1) manifests carry no state_kind
    and must keep loading as PosteriorState."""
    from repro import serve

    t, y = _series(64, seed=19)
    mp = gp.SparseGPRegression(gpk.RBF(1), M=8).fit(t[:, None], y[:, 0],
                                                    steps=5)
    store = serve.StateStore(tmp_path)
    store.save("old", mp.kernel, mp.export_state())
    manifest = next((tmp_path / "old").glob("step_*/manifest.json"))
    doc = json.loads(manifest.read_text())
    assert doc["extra"]["persist_schema"] == PERSIST_SCHEMA == 2
    doc["extra"]["persist_schema"] = 1
    del doc["extra"]["state_kind"]
    manifest.write_text(json.dumps(doc))
    kernel, state = store.load("old")
    assert isinstance(state, serve.PosteriorState)

    # but an unknown state_kind is refused, loudly
    doc["extra"]["persist_schema"] = 2
    doc["extra"]["state_kind"] = "mystery"
    manifest.write_text(json.dumps(doc))
    from repro.checkpoint.manager import CheckpointCorruptError
    with pytest.raises(CheckpointCorruptError, match="state_kind"):
        store.load("old")


# ---------------------------------------------------------------------------
# scaling contract: O(N d^2), no (N, N)
# ---------------------------------------------------------------------------


def _loss(kernel, parallel):
    def loss(params, t, Y):
        model = kernel.to_sde(params["kern"])
        dt = jnp.concatenate([jnp.zeros_like(t[:1]), jnp.diff(t)])
        A, Q = discretize(model, dt)
        res = kalman_filter(A, Q, model.H, jnp.exp(-params["log_beta"]), Y,
                            jnp.zeros((model.d, Y.shape[1])), model.Pinf,
                            parallel=parallel)
        return -res.lml / t.shape[0]

    return loss


def test_sequential_loss_scales_linearly():
    """value_and_grad of the sequential-scan loss keeps every intermediate
    under O(N^2) along N — i.e. the filter is O(N d^2) end to end."""
    n = 4096
    t, y = _series(n, seed=23)
    params = {"kern": _f64_matern(), "log_beta": jnp.asarray(3.0)}
    fn = jax.value_and_grad(_loss(gpk.Matern32(1), parallel=False))
    report = assert_no_scaling(fn, params, t, y, axis="N",
                               worse_than="N^2", sizes={"N": n})
    assert report.worst.growth_exp <= 1


@pytest.mark.parametrize("parallel", [True, False])
def test_no_dense_nxn_intermediate(parallel):
    """Single-trace check (works for the parallel path too, whose
    associative-scan structure is N-dependent): no intermediate carries
    two axes of size N — nothing (N, N) is ever materialized."""
    n = 2048
    t, y = _series(n, seed=29)
    params = {"kern": _f64_matern(), "log_beta": jnp.asarray(3.0)}
    inter = trace_intermediates(_loss(gpk.Matern32(1), parallel), params, t, y)
    assert len(inter) > 0
    for shape, _, nbytes, prim, src in inter:
        big = [s for s in shape if s >= n]
        assert len(big) <= 1, (shape, prim, src)
        assert nbytes <= n * 9 * 8 * 2, (shape, prim, src)  # O(N d^2) bytes


@pytest.mark.slow
def test_million_point_end_to_end():
    """N=1M lml + gradient + forecast through the parallel path: runs, is
    finite, and the trace-level scaling contract holds at full size."""
    n = 1_000_000
    rng = np.random.default_rng(31)
    t = jnp.cumsum(jnp.asarray(rng.uniform(0.5e-5, 1.5e-5, n)))
    y = jnp.sin(2 * jnp.pi * t)[:, None] + 0.05 * jnp.asarray(
        rng.standard_normal((n, 1)))
    params = {"kern": _f64_matern(1.0, 0.3), "log_beta": jnp.asarray(3.0)}
    loss = _loss(gpk.Matern32(1), parallel=True)
    val, grads = jax.jit(jax.value_and_grad(loss))(params, t, y)
    assert np.isfinite(float(val))
    assert all(np.all(np.isfinite(g)) for g in
               jax.tree_util.tree_leaves(grads))
    # no (N, N): the trace of the full-size loss never materializes one
    for shape, *_ in trace_intermediates(loss, params, t, y):
        assert sum(1 for s in shape if s >= n) <= 1, shape

    m = TemporalGPRegression(gpk.Matern32(1))
    m.fit(t[:, None], y, steps=0, params=params)
    st = m.export_state()
    mean, var = forecast(m.kernel, st, t[-1] + jnp.linspace(0.1, 1, 8)[:, None])
    assert np.all(np.isfinite(np.asarray(mean)))
    assert np.all(np.asarray(var) > 0)
