"""The trip-count-aware HLO cost model vs XLA's own analysis and analytics."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.launch import hlo_cost


def test_matches_xla_on_scan_free_program():
    def f(a, b):
        return jnp.sum(jax.nn.relu(a @ b))

    a = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    b = jax.ShapeDtypeStruct((512, 1024), jnp.float32)
    compiled = jax.jit(f).lower(a, b).compile()
    ours = hlo_cost.analyze(compiled.as_text())
    xla = compat.xla_cost_analysis(compiled)
    assert abs(ours.flops - xla["flops"]) / xla["flops"] < 0.01
    assert abs(ours.bytes - xla["bytes accessed"]) / xla["bytes accessed"] < 0.05


def test_scan_bodies_multiplied_by_trip_count():
    def body(c, _):
        return c @ c, None

    def f(x):
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    compiled = jax.jit(f).lower(x).compile()
    ours = hlo_cost.analyze(compiled.as_text())
    expect = 10 * 2 * 128**3
    assert abs(ours.flops - expect) / expect < 0.02
    # XLA's own count misses the multiplier — that's why hlo_cost exists
    assert compat.xla_cost_analysis(compiled)["flops"] < expect / 5


def test_nested_scans():
    def f(x):
        def outer(c, _):
            def inner(ci, _):
                return ci @ ci, None

            ci, _ = jax.lax.scan(inner, c, None, length=4)
            return ci, None

        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    compiled = jax.jit(f).lower(x).compile()
    ours = hlo_cost.analyze(compiled.as_text())
    expect = 20 * 2 * 128**3
    assert abs(ours.flops - expect) / expect < 0.02


def test_sliced_loop_params_not_counted_full():
    """A scan that reads one slice of a big stacked array per step must not
    charge the whole array per step."""
    big = jax.ShapeDtypeStruct((64, 256, 256), jnp.float32)  # 16 MiB

    def f(ws, x):
        def body(c, w):
            return jnp.tanh(c @ w), None

        y, _ = jax.lax.scan(body, x, ws)
        return y

    x = jax.ShapeDtypeStruct((8, 256), jnp.float32)
    compiled = jax.jit(f).lower(big, x).compile()
    ours = hlo_cost.analyze(compiled.as_text())
    full_per_step = 64 * (64 * 256 * 256 * 4)  # trips x whole array
    assert ours.bytes < full_per_step / 4, ours.bytes


def test_collectives_scale_with_trip_count():
    import os
    import subprocess
    import sys

    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
sys.path.insert(0, %r)
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch import hlo_cost
from repro import compat
mesh = compat.make_mesh((4,), ("model",))
def f(w, x):
    def body(c, _):
        h = c @ w  # contraction over the sharded dim => all-reduce per step
        return jax.lax.with_sharding_constraint(jnp.tanh(h), NamedSharding(mesh, P(None, "model"))), None
    y, _ = jax.lax.scan(body, x, None, length=7)
    return jnp.sum(y)
w = jax.ShapeDtypeStruct((512, 512), jnp.float32)
x = jax.ShapeDtypeStruct((8, 512), jnp.float32)
with mesh:
    c = jax.jit(f, in_shardings=(NamedSharding(mesh, P("model", None)), NamedSharding(mesh, P(None, "model")))).lower(w, x).compile()
cost = hlo_cost.analyze(c.as_text())
n = sum(cost.coll_counts.values())
print("NCOLL", n)
assert n >= 7, cost.coll_counts
print("COLL-OK")
"""
    import repro

    src = repro.__file__.rsplit("/repro/", 1)[0]
    out = subprocess.run([sys.executable, "-c", script % src], capture_output=True,
                         text=True, timeout=300)
    assert "COLL-OK" in out.stdout, out.stdout + out.stderr
