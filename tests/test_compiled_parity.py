"""Compiled-vs-interpret kernel parity — the hardware lane.

Everything else in the suite validates the Pallas kernel BODIES in interpret
mode on CPU; what interpret mode cannot validate is the compiled artifact
itself (Mosaic lowering, MXU accumulation, the tiled memory movement). These
tests run each registered kernel twice — compiled on the accelerator and in
interpret mode — and demand agreement, in both differentiation directions
(the registry's forward kernels AND the hand-derived reverse kernels are
separate entries, so all seven get their own row).

Marked `compiled` and skipped cleanly on CPU-only hosts; CI runs
``pytest -m compiled`` as a hardware-gated lane (scripts/ci.sh).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.pallas_audit import KERNELS, Problem, registry_entry
from repro.kernels import ops

pytestmark = [
    pytest.mark.compiled,
    pytest.mark.skipif(
        jax.default_backend() not in ("tpu", "gpu", "cuda", "rocm"),
        reason="compiled-parity lane needs a TPU/GPU backend"),
]

# multi-tile in N and M at the default blocks, small enough to compile fast
PROBLEM = Problem(N=512, M=256, Q=3, D=2)

# compiled path computes in f32 either way; MXU-vs-VPU accumulation order
# differences bound the agreement
RTOL = 5e-5
ATOL = 1e-5


def _concrete(shapes, seed=0):
    """Positive, O(1)-magnitude inputs for every operand: valid variances /
    lengthscales / latent S, non-degenerate exponents, usable cotangents."""
    keys = jax.random.split(jax.random.PRNGKey(seed), len(shapes))
    return [
        jax.random.uniform(k, s.shape, jnp.float32, minval=0.5, maxval=1.5)
        for k, s in zip(keys, shapes)
    ]


@pytest.mark.parametrize("kernel_name", KERNELS)
def test_compiled_matches_interpret(kernel_name):
    fn, build = registry_entry(kernel_name)
    args = _concrete(build(PROBLEM, jnp.float32))
    compiled = fn(*args, interpret=False)
    interp = fn(*args, interpret=True)
    for c, i in zip(jax.tree.leaves(compiled), jax.tree.leaves(interp)):
        np.testing.assert_allclose(np.asarray(c), np.asarray(i),
                                   rtol=RTOL, atol=ATOL)


@pytest.mark.parametrize("kernel_name", KERNELS)
def test_compiled_matches_interpret_at_tuned_candidate(kernel_name):
    """A non-default admissible block must be numerically invisible in the
    compiled artifact too — the autotuner's core safety property on real
    hardware."""
    from repro import tune

    fn, build = registry_entry(kernel_name)
    args = _concrete(build(PROBLEM, jnp.float32), seed=1)
    cands = tune.candidate_blocks(kernel_name, problem=PROBLEM, limit=2)
    alt = next((c for c in cands
                if c != tune.default_blocks(kernel_name)), None)
    if alt is None:
        pytest.skip("no admissible non-default candidate at this problem")
    base = fn(*args, interpret=False)
    tuned = fn(*args, interpret=False, block=alt)
    for b, t in zip(jax.tree.leaves(base), jax.tree.leaves(tuned)):
        np.testing.assert_allclose(np.asarray(b), np.asarray(t),
                                   rtol=RTOL, atol=ATOL)


def test_ops_grad_compiled_matches_interpret(monkeypatch):
    """End-to-end: value+grad of the fused op, compiled vs forced-interpret
    through the public `ops.suffstats` entry point."""
    shapes = registry_entry("suffstats_pallas")[1](PROBLEM, jnp.float32)
    mu, S, Y, Z, var, ls = _concrete(shapes, seed=2)

    def loss(mu, S, Y, Z, var, ls):
        psi2, psiY = ops.suffstats(mu, S, Y, Z, var, ls)
        return psi2.sum() + psiY.sum()

    compiled = jax.value_and_grad(loss, argnums=(0, 1, 4, 5))(
        mu, S, Y, Z, var, ls)
    monkeypatch.setattr(ops, "_INTERPRET_OVERRIDE", True)
    interp = jax.value_and_grad(loss, argnums=(0, 1, 4, 5))(
        mu, S, Y, Z, var, ls)
    for c, i in zip(jax.tree.leaves(compiled), jax.tree.leaves(interp)):
        np.testing.assert_allclose(np.asarray(c), np.asarray(i),
                                   rtol=RTOL, atol=ATOL)
