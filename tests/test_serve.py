"""The `repro.serve` online-prediction subsystem: exported state vs facade
parity, online update vs from-scratch refold (all backends), downdate as the
monoid inverse (+ the condition guard), bucket-padded predict exactness, the
micro-batching server round-trip, the facade posterior cache, and the
million-point no-(N, M)-materialization guarantee — same trace-assertion
style as tests/test_streaming.py."""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import serve
from repro.core.psi_stats import SuffStats
from repro.gp import BayesianGPLVM, SparseGPRegression, get, suff_stats
from repro.gp.stats import ExactBatch
from repro.analysis import assert_no_scaling
from repro.serve import GPServer, online


def _f64(tree):
    return jax.tree.map(lambda x: x.astype(jnp.float64), tree)


def _data(key, N, Q=2, D=3, M=12):
    X = jax.random.normal(key, (N, Q), jnp.float64)
    w = jnp.arange(1, D + 1, dtype=jnp.float64)
    Y = jnp.sin(X.sum(axis=1))[:, None] * w + 0.05 * jax.random.normal(
        jax.random.fold_in(key, 1), (N, D), jnp.float64)
    Z = X[:: max(N // M, 1)][:M]
    return X, Y, Z


def _params(Z, *, log_beta=2.0):
    kern = _f64(get("rbf")(Z.shape[1]).init(1.3, 0.8))
    return {"kern": kern, "Z": Z, "log_beta": jnp.asarray(log_beta, jnp.float64)}


def _state_from(kernel, params, X, Y, **kw):
    stats = suff_stats(kernel, params["kern"], ExactBatch(X, Y, params["Z"]), **kw)
    return serve.build_state(kernel, params, stats)


def _assert_stats_close(a: SuffStats, b: SuffStats, rtol=1e-8, atol=1e-10):
    for x, y, name in zip(a, b, a._fields):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=rtol,
                                   atol=atol, err_msg=name)


def _fitted_gp(key, N=300, M=16, steps=60):
    X = jnp.sort(jax.random.uniform(key, (N, 1), jnp.float64, -3.0, 3.0), axis=0)
    Y = jnp.sin(2.0 * X) + 0.1 * jax.random.normal(
        jax.random.fold_in(key, 1), (N, 1), jnp.float64)
    gp = SparseGPRegression(kernel=get("rbf")(1), M=M).fit(X, Y, steps=steps)
    return gp, X, Y


# ---------------------------------------------------------------------------
# export_state: the cached posterior serves identically to the facade
# ---------------------------------------------------------------------------

def test_export_state_predicts_like_the_facade():
    gp, X, _ = _fitted_gp(jax.random.PRNGKey(0))
    st = gp.export_state()
    assert st.M == 16 and st.D == 1 and float(st.stats.n) == X.shape[0]
    mean_f, var_f = gp.predict(X[:17])
    mean_s, var_s = serve.predict(gp.kernel, st, X[:17])
    np.testing.assert_allclose(np.asarray(mean_s), np.asarray(mean_f),
                               rtol=1e-10, atol=1e-12)
    np.testing.assert_allclose(np.asarray(var_s), np.asarray(var_f),
                               rtol=1e-10, atol=1e-12)
    # full covariance: diagonal agrees with the marginal variance, and the
    # matrix is symmetric PSD-ish (small negative eigenvalues = roundoff)
    mean_c, cov = serve.predict(gp.kernel, st, X[:17], diag=False)
    np.testing.assert_allclose(np.asarray(mean_c), np.asarray(mean_f),
                               rtol=1e-10, atol=1e-12)
    np.testing.assert_allclose(np.diagonal(np.asarray(cov)), np.asarray(var_f),
                               rtol=1e-8, atol=1e-10)
    np.testing.assert_allclose(np.asarray(cov), np.asarray(cov).T, atol=1e-12)
    assert float(np.min(np.linalg.eigvalsh(np.asarray(cov)))) > -1e-8


def test_export_state_gplvm_decodes_like_the_facade():
    key = jax.random.PRNGKey(1)
    from repro.data.synthetic import gplvm_synthetic

    _, Y = gplvm_synthetic(key, N=96, D=3, Q=1)
    lvm = BayesianGPLVM(kernel=get("rbf")(1), M=10).fit(
        Y.astype(jnp.float64), steps=30, lr=5e-2, key=key)
    st = lvm.export_state()
    Xstar = jnp.linspace(-2.0, 2.0, 9)[:, None].astype(jnp.float64)
    mean_f, var_f = lvm.predict(Xstar)
    mean_s, var_s = serve.predict(lvm.kernel, st, Xstar)
    np.testing.assert_allclose(np.asarray(mean_s), np.asarray(mean_f),
                               rtol=1e-10, atol=1e-12)
    np.testing.assert_allclose(np.asarray(var_s), np.asarray(var_f),
                               rtol=1e-10, atol=1e-12)


# ---------------------------------------------------------------------------
# online update: monoid fold == from-scratch statistics build
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ("jnp", "pallas", "fused"))
def test_update_matches_from_scratch_build(backend):
    """Fitting on X[:n] then folding the remaining b points must equal the
    statistics (and refold) built from scratch on X[:n+b] — at 1e-8 in f64,
    on every statistics backend."""
    key = jax.random.PRNGKey(2)
    n, b = 200, 57  # non-dividing split
    X, Y, Z = _data(key, n + b)
    params = _params(Z)
    kernel = get("rbf")(2)
    st0 = _state_from(kernel, params, X[:n], Y[:n])
    up = online.update(kernel, st0, X[n:], Y[n:], backend=backend)
    scratch = _state_from(kernel, params, X, Y)
    _assert_stats_close(up.stats, scratch.stats)
    # the refold epilogue agrees too (conditioning can amplify the stats
    # delta into the factors, hence the looser bar)
    mean_u, var_u = serve.predict(kernel, up, X[:9])
    mean_s, var_s = serve.predict(kernel, scratch, X[:9])
    np.testing.assert_allclose(np.asarray(mean_u), np.asarray(mean_s),
                               rtol=1e-6, atol=1e-8)
    np.testing.assert_allclose(np.asarray(var_u), np.asarray(var_s),
                               rtol=1e-6, atol=1e-8)


def test_update_streams_and_composes():
    """chunk= streams the incremental batch; two sequential updates equal
    one combined update (monoid associativity)."""
    key = jax.random.PRNGKey(3)
    X, Y, Z = _data(key, 300)
    params = _params(Z)
    kernel = get("rbf")(2)
    st0 = _state_from(kernel, params, X[:100], Y[:100])
    one = online.update(kernel, st0, X[100:], Y[100:], chunk=64)
    two = online.update(kernel,
                        online.update(kernel, st0, X[100:200], Y[100:200]),
                        X[200:], Y[200:])
    _assert_stats_close(one.stats, two.stats, rtol=1e-10)
    scratch = _state_from(kernel, params, X, Y)
    _assert_stats_close(one.stats, scratch.stats)


def test_downdate_inverts_update():
    key = jax.random.PRNGKey(4)
    X, Y, Z = _data(key, 260)
    params = _params(Z)
    kernel = get("rbf")(2)
    st0 = _state_from(kernel, params, X[:200], Y[:200])
    round_trip = online.downdate(
        kernel, online.update(kernel, st0, X[200:], Y[200:]), X[200:], Y[200:])
    _assert_stats_close(round_trip.stats, st0.stats)
    mean_r, var_r = serve.predict(kernel, round_trip, X[:9])
    mean_0, var_0 = serve.predict(kernel, st0, X[:9])
    np.testing.assert_allclose(np.asarray(mean_r), np.asarray(mean_0),
                               rtol=1e-6, atol=1e-8)
    np.testing.assert_allclose(np.asarray(var_r), np.asarray(var_0),
                               rtol=1e-6, atol=1e-8)


def test_downdate_guard_raises_on_indefinite_statistics():
    """Subtracting statistics that were never added drives Kuu + beta Psi2
    indefinite; the guard escalates jitter, fails to repair it, and raises
    rather than serving NaN."""
    key = jax.random.PRNGKey(5)
    X, Y, Z = _data(key, 120)
    params = _params(Z)
    kernel = get("rbf")(2)
    st = _state_from(kernel, params, X[:40], Y[:40])
    with pytest.raises(FloatingPointError, match="indefinite"):
        online.downdate(kernel, st, X, 10.0 * Y)


def test_refit_recovers_perturbed_noise_from_stats_alone():
    """log_beta is the one hyperparameter the cached statistics don't
    depend on: refit must improve the bound from the stats, no data."""
    key = jax.random.PRNGKey(6)
    X, Y, Z = _data(key, 240)
    kernel = get("rbf")(2)
    good = _state_from(kernel, _params(Z, log_beta=2.0), X, Y)
    bad = _state_from(kernel, _params(Z, log_beta=-3.0), X, Y)
    refitted, history = online.refit(kernel, bad, steps=200, lr=5e-2)
    assert history[-1] < history[0] - 1e-3  # the bound improved
    # beta moved toward the well-fit value (within a decade)
    assert abs(float(refitted.log_beta) - 2.0) < abs(-3.0 - 2.0)
    # statistics are untouched: refit is an epilogue-only operation
    _assert_stats_close(refitted.stats, bad.stats, rtol=0.0, atol=0.0)
    del good


# ---------------------------------------------------------------------------
# GPServer: bucket padding + compile cache + micro-batching queue
# ---------------------------------------------------------------------------

def _assert_ulp_equal(a, b):
    # bucket padding must not leak into the real rows. XLA specializes
    # matmul codegen per shape, so cross-shape comparisons can differ in the
    # last ulp of the accumulated terms — and the variance is a cancelling
    # difference of O(0.1) terms, so one ulp there is ~1e-16 absolute.
    # Anything beyond that means the padding perturbed the math.
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-12,
                               atol=1e-14)


def test_bucketed_predict_matches_unpadded_exactly():
    gp, X, _ = _fitted_gp(jax.random.PRNGKey(7))
    st = gp.export_state()
    srv = GPServer(buckets=(4, 16, 64))
    srv.register("gp", gp)
    sizes = (1, 3, 4, 5, 16, 23, 64, 150)
    unpadded = {B: serve.predict(gp.kernel, st, X[:B]) for B in sizes}
    for B in sizes:
        mean_b, var_b = srv.predict("gp", X[:B])  # 150 > 64: bucket slices
        _assert_ulp_equal(mean_b, unpadded[B][0])
        _assert_ulp_equal(var_b, unpadded[B][1])
        # at exactly a bucket shape no padding happens at all: bit-identical
        if B in srv.buckets:
            np.testing.assert_array_equal(np.asarray(mean_b),
                                          np.asarray(unpadded[B][0]))
    # a full covariance cannot be stitched from largest-bucket slices
    with pytest.raises(ValueError, match="bucket"):
        srv.predict("gp", X[:150], diag=False)
    # the compile cache is bounded by the bucket set: 8 request shapes
    # mapped onto <= 3 jitted specializations of the entry's own closure
    # (owned per entry so dropped registrations free their executables)
    assert srv._models["gp"].fns[True]._cache_size() <= 3


def test_server_submit_round_trip_and_concurrency():
    gp, X, _ = _fitted_gp(jax.random.PRNGKey(8))
    st = gp.export_state()
    with GPServer() as srv:
        srv.register("gp", kernel=gp.kernel, state=st)
        # many concurrent submitters; the worker coalesces compatible
        # requests into shared device calls — answers must be per-request
        futs, errs = {}, []

        def client(i):
            try:
                futs[i] = srv.submit("gp", X[3 * i: 3 * i + 3])
            except Exception as e:  # pragma: no cover
                errs.append(e)

        threads = [threading.Thread(target=client, args=(i,)) for i in range(32)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not errs
        for i, fut in futs.items():
            mean, var = fut.result(timeout=30)
            mean_u, var_u = serve.predict(gp.kernel, st, X[3 * i: 3 * i + 3])
            np.testing.assert_allclose(np.asarray(mean), np.asarray(mean_u),
                                       rtol=1e-12, atol=1e-14)
            np.testing.assert_allclose(np.asarray(var), np.asarray(var_u),
                                       rtol=1e-12, atol=1e-14)
    with pytest.raises(RuntimeError, match="closed"):
        srv.submit("gp", X[:1])
    with pytest.raises(KeyError, match="registered"):
        srv.predict("nope", X[:1])


def test_malformed_requests_rejected_in_caller_and_worker_survives():
    gp, X, _ = _fitted_gp(jax.random.PRNGKey(12), steps=5)
    with GPServer() as srv:
        srv.register("gp", gp)
        # shape validation happens in the SUBMITTING thread, not the worker
        for bad in (X[:, 0], X[0, 0], X[:0]):
            with pytest.raises(ValueError, match="batches"):
                srv.submit("gp", bad)
            with pytest.raises(ValueError, match="batches"):
                srv.predict("gp", bad)
        # the worker is still alive and serving after the rejections
        mean, _ = srv.submit("gp", X[:3]).result(timeout=30)
        np.testing.assert_allclose(np.asarray(mean),
                                   np.asarray(srv.predict("gp", X[:3])[0]),
                                   rtol=1e-12, atol=1e-14)


def test_models_iteration_safe_under_concurrent_register():
    """Registry reads (`models()` / `state()` lookups) snapshot under the
    registry lock: a register() storm while another thread iterates must
    never raise "dictionary changed size during iteration" or hand back a
    torn view (regression for the unlocked reads)."""
    gp, X, _ = _fitted_gp(jax.random.PRNGKey(13), steps=5)
    st = gp.export_state()
    srv = GPServer()
    srv.register("base", kernel=gp.kernel, state=st)
    errs, stop = [], threading.Event()

    def reader():
        try:
            while not stop.is_set():
                names = srv.models()
                assert "base" in names
                assert srv.state("base") is not None
        except Exception as e:  # pragma: no cover - the regression itself
            errs.append(e)

    readers = [threading.Thread(target=reader) for _ in range(4)]
    for t in readers:
        t.start()
    try:
        for i in range(300):
            srv.register(f"m{i}", kernel=gp.kernel, state=st)
    finally:
        stop.set()
        for t in readers:
            t.join()
    assert not errs, errs
    assert len(srv.models()) == 301


def test_cancelled_future_does_not_poison_coalesced_group():
    """A caller cancelling its Future while the request waits in the queue
    must not break delivery for the rest of the coalesced group: the worker
    claims each dequeued future (set_running_or_notify_cancel) and skips
    the cancelled ones (regression for the InvalidStateError abort)."""
    from concurrent.futures import Future

    from repro.serve.server import _Request

    gp, X, _ = _fitted_gp(jax.random.PRNGKey(14), steps=5)
    st = gp.export_state()
    with GPServer() as srv:
        srv.register("gp", kernel=gp.kernel, state=st)
        # enqueue a request by hand BEFORE the worker exists, then cancel it:
        # the next submit() starts the worker, which drains both requests as
        # one group with the cancelled future first
        cancelled = Future()
        with srv._cv:
            srv._queue.append(_Request("gp", X[:2], True, cancelled))
        assert cancelled.cancel()
        live = [srv.submit("gp", X[3 * i: 3 * i + 3]) for i in range(3)]
        for i, fut in enumerate(live):
            mean, var = fut.result(timeout=30)  # InvalidStateError poisoned
            want_mean, want_var = srv.predict("gp", X[3 * i: 3 * i + 3])
            np.testing.assert_allclose(np.asarray(mean), np.asarray(want_mean),
                                       rtol=1e-12, atol=1e-14)
            np.testing.assert_allclose(np.asarray(var), np.asarray(want_var),
                                       rtol=1e-12, atol=1e-14)
        assert cancelled.cancelled()


def test_server_online_update_shifts_predictions():
    key = jax.random.PRNGKey(9)
    X, Y, Z = _data(key, 300, Q=1, D=1, M=10)
    kernel = get("rbf")(1)
    params = _params(Z)
    srv = GPServer()
    srv.register("m", kernel=kernel, state=_state_from(kernel, params,
                                                       X[:150], Y[:150]))
    before = srv.predict("m", X[:5])
    srv.update("m", X[150:], Y[150:])
    assert float(srv.state("m").stats.n) == 300
    after = srv.predict("m", X[:5])
    assert not np.allclose(np.asarray(before[1]), np.asarray(after[1]))
    srv.downdate("m", X[150:], Y[150:])
    restored = srv.predict("m", X[:5])
    np.testing.assert_allclose(np.asarray(restored[0]), np.asarray(before[0]),
                               rtol=1e-7, atol=1e-9)
    hist = srv.refit("m", steps=5)
    assert len(hist) >= 2 and np.isfinite(hist[-1])


# ---------------------------------------------------------------------------
# fault injection: a production queue must degrade per-request, never
# per-server (the worker survives everything a request can throw at it)
# ---------------------------------------------------------------------------

def _gate_model(srv, name):
    """Wrap a registered entry's predict closures behind a gate: the worker
    blocks inside the device call until `release.set()`, and `started` flags
    that the worker has dequeued (so the queue length is deterministic)."""
    entry = srv._models[name]
    orig = dict(entry.fns)
    started, release = threading.Event(), threading.Event()

    def gated(state, X):
        started.set()
        assert release.wait(30), "test gate never released"
        return orig[True](state, X)

    entry.fns = {True: gated, False: orig[False]}
    return started, release


def test_poisoned_device_call_fails_only_its_own_futures():
    """An exception out of one model's device call lands on that group's
    futures; other groups in the same drain complete, and the worker is
    alive for the next drain."""
    gp, X, _ = _fitted_gp(jax.random.PRNGKey(20), steps=5)
    st = gp.export_state()
    boom = RuntimeError("injected device failure")
    with GPServer() as srv:
        srv.register("ok", kernel=gp.kernel, state=st)
        srv.register("bad", kernel=gp.kernel, state=st)
        srv._models["bad"].fns = {True: _raiser(boom), False: _raiser(boom)}
        # hold the worker so both models' requests land in ONE drain
        started, release = _gate_model(srv, "ok")
        first = srv.submit("ok", X[:2])
        assert started.wait(30)
        bad_futs = [srv.submit("bad", X[:3]) for _ in range(3)]
        ok_futs = [srv.submit("ok", X[3 * i: 3 * i + 3]) for i in range(3)]
        release.set()
        for fut in bad_futs:  # the poisoned group: ITS futures fail
            with pytest.raises(RuntimeError, match="injected"):
                fut.result(timeout=30)
        for i, fut in enumerate(ok_futs):  # siblings in the drain complete
            mean, _ = fut.result(timeout=30)
            want, _ = serve.predict(gp.kernel, st, X[3 * i: 3 * i + 3])
            np.testing.assert_allclose(np.asarray(mean), np.asarray(want),
                                       rtol=1e-12, atol=1e-14)
        first.result(timeout=30)
        # worker survived: a fresh healthy request round-trips
        srv.submit("ok", X[:2]).result(timeout=30)


def _raiser(exc):
    def fn(state, X):
        raise exc

    return fn


def test_expired_deadline_fails_only_its_own_future():
    """A request that waits past its deadline gets TimeoutError on its own
    future at claim time; the rest of the coalesced group is served. Expiry
    happens AFTER set_running_or_notify_cancel, so it can never race a
    caller-side cancel() into InvalidStateError."""
    from concurrent.futures import Future

    from repro.serve.server import _Request

    gp, X, _ = _fitted_gp(jax.random.PRNGKey(21), steps=5)
    st = gp.export_state()
    with GPServer() as srv:
        srv.register("gp", kernel=gp.kernel, state=st)
        # enqueue an already-expired request by hand BEFORE the worker
        # exists (same trick as the cancelled-future regression test): the
        # next submit() starts the worker, which drains both as one group
        expired = Future()
        with srv._cv:
            srv._queue.append(_Request("gp", X[:2], True, expired,
                                       deadline=-1.0))
        live = [srv.submit("gp", X[3 * i: 3 * i + 3]) for i in range(3)]
        for i, fut in enumerate(live):
            mean, _ = fut.result(timeout=30)
            want, _ = srv.predict("gp", X[3 * i: 3 * i + 3])
            np.testing.assert_allclose(np.asarray(mean), np.asarray(want),
                                       rtol=1e-12, atol=1e-14)
        with pytest.raises(TimeoutError, match="deadline"):
            expired.result(timeout=30)
        assert srv.metrics()["expired"] == 1
        # the queue is not wedged: the next submit round-trips
        srv.submit("gp", X[:2]).result(timeout=30)


def test_admission_control_rejects_at_max_pending():
    """Submits past max_pending fail fast with QueueFullError in the CALLER
    (the request never enters the queue); accepted requests are unaffected
    and complete once the worker unblocks."""
    from repro.serve import QueueFullError

    gp, X, _ = _fitted_gp(jax.random.PRNGKey(22), steps=5)
    with GPServer(max_pending=2) as srv:
        srv.register("gp", gp)
        started, release = _gate_model(srv, "gp")
        first = srv.submit("gp", X[:2])  # worker dequeues this and blocks
        assert started.wait(30)
        accepted = [srv.submit("gp", X[:2]) for _ in range(2)]  # fills queue
        with pytest.raises(QueueFullError, match="max_pending"):
            srv.submit("gp", X[:2])
        assert srv.metrics()["rejected"] == 1
        release.set()
        for fut in (first, *accepted):  # rejection did not poison anyone
            mean, var = fut.result(timeout=30)
            assert mean.shape == (2, 1) and var.shape == (2,)
    # queue empties after the drain -> no lingering admission debt
    assert srv.metrics()["rejected"] == 1


def test_close_drains_inflight_submits_deterministically():
    """close() during in-flight submits: every accepted Future completes
    (graceful drain), late submits fail with ServerClosedError, and close()
    is idempotent."""
    from repro.serve import ServerClosedError

    gp, X, _ = _fitted_gp(jax.random.PRNGKey(23), steps=5)
    srv = GPServer()
    srv.register("gp", gp)
    started, release = _gate_model(srv, "gp")
    first = srv.submit("gp", X[:2])
    assert started.wait(30)
    queued = [srv.submit("gp", X[:3]) for _ in range(8)]  # sit in the queue
    closer = threading.Thread(target=srv.close)
    closer.start()
    release.set()
    closer.join(timeout=30)
    assert not closer.is_alive()
    for fut in (first, *queued):  # accepted before close() => completed
        mean, _ = fut.result(timeout=30)
        assert np.all(np.isfinite(np.asarray(mean)))
    srv.close()  # idempotent: second close is a no-op, not an error
    with pytest.raises(ServerClosedError, match="closed"):
        srv.submit("gp", X[:2])
    with pytest.raises(ServerClosedError, match="closed"):
        srv.register("gp2", gp)


def test_default_timeout_applies_to_submits(tmp_path):
    """ctor default_timeout stamps a deadline on every submit: a request
    stuck behind a blocked worker past it expires with TimeoutError."""
    gp, X, _ = _fitted_gp(jax.random.PRNGKey(24), steps=5)
    with GPServer(default_timeout=0.05) as srv:
        srv.register("gp", gp)
        started, release = _gate_model(srv, "gp")
        first = srv.submit("gp", X[:2], timeout=30.0)  # explicit override
        assert started.wait(30)
        doomed = srv.submit("gp", X[:2])  # inherits the 50ms default
        time.sleep(0.2)  # let the deadline lapse while queued
        release.set()
        first.result(timeout=30)
        with pytest.raises(TimeoutError, match="deadline"):
            doomed.result(timeout=30)
        assert srv.metrics()["expired"] == 1


# ---------------------------------------------------------------------------
# facade posterior cache (satellite): one statistics pass per fit
# ---------------------------------------------------------------------------

def test_facade_caches_statistics_across_predict_calls():
    gp, X, Y = _fitted_gp(jax.random.PRNGKey(10), steps=5)
    calls = []
    inner = gp._stats_fn()
    gp._stats_cache = (gp.kernel, lambda *a: (calls.append(1), inner(*a))[1])
    gp.predict(X[:7])
    gp.predict(X[9:20])
    gp.posterior()
    gp.export_state()
    assert len(calls) == 1  # one O(N M^2) pass serves them all
    gp.fit(X, Y, steps=1)  # fit invalidates both caches...
    gp._stats_cache = (gp.kernel, lambda *a: (calls.append(1), inner(*a))[1])
    gp.predict(X[:7])
    assert len(calls) == 2  # ...so the next predict recomputes once


# ---------------------------------------------------------------------------
# million-point scale: update + submit without any (N, M) intermediate
# ---------------------------------------------------------------------------

def _no_nm_intermediate(fn, *args, N, M):
    """The guarantee stated once, via the analyzer: no intermediate anywhere
    in the trace scales like O(N*M) (default margin 4 reads "nothing within
    4x of an (N, M) array" — streaming would be broken)."""
    assert_no_scaling(fn, *args, axis="N", worse_than="N*M",
                      sizes={"N": N, "M": M})


def test_million_point_online_serving_round_trip():
    """The acceptance scenario: a state over 1e6 total datapoints, reached
    by an online update, matching the from-scratch refold — plus the trace
    assertion that folding a million-point chunk materializes nothing of
    size (N, M), and a live submit() round-trip against the updated state."""
    N_total, b, M, chunk = 1_000_000, 8192, 100, 8192
    n0 = N_total - b
    key = jax.random.PRNGKey(11)
    X = jax.random.uniform(key, (N_total, 1), jnp.float64, -3.0, 3.0)
    Y = jnp.sin(2.0 * X) + 0.1 * jax.random.normal(
        jax.random.fold_in(key, 1), (N_total, 1), jnp.float64)
    kernel = get("rbf")(1)
    params = _params(X[:: N_total // M][:M])

    # trace-level guarantee first (traces only — nothing executes): folding
    # a MILLION-point batch into a served state stays chunk-sized
    st_small = _state_from(kernel, params, X[:512], Y[:512])

    def fold_million(st, Xb, Yb):
        return online.update(kernel, st, Xb, Yb, chunk=chunk)

    _no_nm_intermediate(fold_million, st_small, X, Y, N=N_total, M=M)

    # executed: (N_total - b) streamed base state + one online b-point fold
    # == the from-scratch build over all 1e6 points
    st0 = _state_from(kernel, params, X[:n0], Y[:n0], chunk=chunk)
    up = online.update(kernel, st0, X[n0:], Y[n0:], chunk=chunk)
    scratch = _state_from(kernel, params, X, Y, chunk=chunk)
    _assert_stats_close(up.stats, scratch.stats, rtol=1e-8, atol=1e-8)

    # live micro-batched serving against the million-point state
    with GPServer() as srv:
        srv.register("big", kernel=kernel, state=up)
        futs = [srv.submit("big", X[i * 16: (i + 1) * 16]) for i in range(8)]
        ref = serve.predict(kernel, up, X[: 8 * 16])
        for i, f in enumerate(futs):
            mean, var = f.result(timeout=60)
            np.testing.assert_allclose(
                np.asarray(mean), np.asarray(ref[0][i * 16: (i + 1) * 16]),
                rtol=1e-10, atol=1e-12)
            np.testing.assert_allclose(
                np.asarray(var), np.asarray(ref[1][i * 16: (i + 1) * 16]),
                rtol=1e-10, atol=1e-12)


# ---------------------------------------------------------------------------
# benchmark schema validation (satellite)
# ---------------------------------------------------------------------------

def test_committed_bench_files_carry_current_schema(tmp_path):
    from benchmarks.run import validate_bench_files

    names = validate_bench_files()  # the repo's committed BENCH_*.json
    assert {"BENCH_gp.json", "BENCH_serve.json"} <= set(names)

    bad = tmp_path / "BENCH_bad.json"
    bad.write_text('{"meta": {"schema_version": 0}, "rows": []}')
    with pytest.raises(ValueError, match="schema_version"):
        validate_bench_files(tmp_path)
    bad.write_text("not json")
    with pytest.raises(ValueError, match="parse"):
        validate_bench_files(tmp_path)
