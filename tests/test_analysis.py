"""The static-analysis subsystem itself: scaling classification against
seeded leaks (and clean on the real tree), the Pallas audit against a
kernel with a resident full-array block (and clean on the registry), the
AST lint rules ANL001-ANL004 against seeded sources (and clean on the
tree), plus the backward-compat `launch.memory` wrappers including the
dict-valued sub-jaxpr recursion the old walker missed."""
import importlib.util
import pathlib

import jax
import jax.numpy as jnp
import pytest

from repro import analysis
from repro.analysis import jaxpr_check, lint, pallas_audit
from repro.launch.memory import intermediate_report, peak_intermediate_bytes

FIXTURES = pathlib.Path(__file__).parent / "fixtures" / "analysis"


def _load_fixture(name):
    spec = importlib.util.spec_from_file_location(name, FIXTURES / f"{name}.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _xz(N=2048, M=64, Q=3):
    return (jax.ShapeDtypeStruct((N, Q), jnp.float32),
            jax.ShapeDtypeStruct((M, Q), jnp.float32))


# ---------------------------------------------------------------------------
# jaxpr invariant checker
# ---------------------------------------------------------------------------

def test_leaky_scan_fixture_flags_exactly_the_stacked_residual():
    mod = _load_fixture("leaky_scan")
    N, M, Q = 2048, 64, 3
    X, Z = _xz(N, M, Q)
    sizes = {"N": N, "M": M, "Q": Q}
    with pytest.raises(analysis.ScalingViolation) as exc:
        analysis.assert_no_scaling(mod.leaky_chunked_loss, X, Z,
                                   axis="N", worse_than="N*M", sizes=sizes)
    # the finding is the (N, M)-class stacked scan output, named with its
    # source line in the fixture — and it is the only O(N*M)-class entry
    viol = exc.value.violations
    assert all(v.growth_exp == 1 and v.coeff >= M / 4 for v in viol), viol
    assert any("leaky_scan.py" in v.source for v in viol), viol
    assert any(v.label == "O(N*M)" for v in viol), viol
    # the same loss without the leak passes the same bound
    analysis.assert_no_scaling(mod.clean_chunked_loss, X, Z,
                               axis="N", worse_than="N*M", sizes=sizes)


def test_scaling_report_classes_and_worst():
    X, Z = _xz()
    sizes = {"N": 2048, "M": 64, "Q": 3}

    def dense(X, Z):
        return jnp.exp(-((X[:, None, :] - Z[None, :, :]) ** 2).sum(-1)).sum()

    rep = analysis.scaling_report(dense, X, Z, axis="N", sizes=sizes)
    assert rep.worst_class == "O(N*M*Q)"
    assert rep.worst.growth_exp == 1
    assert "O(N*M*Q)" in rep.format(top=3)
    assert analysis.scaling_class(dense, X, Z, axis="N", sizes=sizes) == "O(N*M*Q)"


def test_margin_semantics_allow_the_output_cotangent_itself():
    """An exactly-(N, M) buffer violates the default margin=4 bound but
    passes margin=0.5 ("nothing beyond 2x the (N, M) output")."""
    X, Z = _xz()
    sizes = {"N": 2048, "M": 64, "Q": 3}

    def makes_nm(X, Z):
        return (X @ Z.T).sum()

    with pytest.raises(analysis.ScalingViolation):
        analysis.assert_no_scaling(makes_nm, X, Z, axis="N",
                                   worse_than="N*M", sizes=sizes)
    analysis.assert_no_scaling(makes_nm, X, Z, axis="N", worse_than="N*M",
                               margin=0.5, sizes=sizes)


def test_bound_parsing_rejects_unknown_names_and_axisless_bounds():
    X, Z = _xz()
    sizes = {"N": 2048, "M": 64}
    with pytest.raises(ValueError, match="neither the axis"):
        analysis.assert_no_scaling(lambda x, z: x.sum(), X, Z,
                                   axis="N", worse_than="N*K", sizes=sizes)
    with pytest.raises(ValueError, match="must involve the grown axis"):
        analysis.assert_no_scaling(lambda x, z: x.sum(), X, Z,
                                   axis="N", worse_than="M", sizes=sizes)
    with pytest.raises(ValueError, match="sizes="):
        analysis.assert_no_scaling(lambda x, z: x.sum(), X, Z, axis="N")


def test_structure_change_across_dispatch_boundary_is_an_analysis_error():
    """A size-dependent python branch between the two trace sizes cannot be
    classified — the analyzer must say so instead of mispairing equations."""
    def dispatching(x):
        if x.shape[0] > 1024:
            return (2.0 * x * x).sum()
        return x.sum()

    x = jax.ShapeDtypeStruct((1024, 2), jnp.float32)
    with pytest.raises(analysis.AnalysisError, match="structure changed"):
        analysis.scaling_report(dispatching, x, axis="N", sizes={"N": 1024})


def test_trace_intermediates_names_primitive_and_source():
    def f(x):
        return jnp.exp(x).sum()

    rows = analysis.trace_intermediates(f, jnp.ones((8, 3)))
    prims = [r[3] for r in rows]
    assert "exp" in prims and "reduce_sum" in prims
    exp_row = rows[prims.index("exp")]
    assert exp_row[0] == (8, 3) and "test_analysis.py" in exp_row[4]


def test_sub_jaxprs_recurses_into_dict_valued_params():
    """The old launch.memory walker skipped dict-valued eqn params; the
    shared walk must yield jaxprs from dicts (and nested containers)."""
    closed = jax.make_jaxpr(lambda x: x * 2.0)(jnp.ones(3))
    got = list(jaxpr_check.sub_jaxprs({"bwd": closed, "others": [closed]}))
    assert len(got) == 2 and all(hasattr(j, "eqns") for j in got)


def test_launch_memory_wrappers_still_serve_bytes():
    def f(x):
        return (x[:, None] * x[None, :]).sum()

    x = jnp.ones(64)
    rows = intermediate_report(f, x, top=2)
    assert rows[0][0] == (64, 64)
    assert peak_intermediate_bytes(f, x) == 64 * 64 * x.dtype.itemsize


# ---------------------------------------------------------------------------
# pallas kernel auditor
# ---------------------------------------------------------------------------

def test_clean_tree_kernel_registry_audits_clean():
    audits = pallas_audit.audit_kernels()
    assert [a.name for a in audits] == list(pallas_audit.KERNELS)
    for a in audits:
        assert a.fits and not a.findings, (a.name, a.findings)
        assert a.vmem_estimate_bytes > 0
    # the reverse kernels' dZ/dv/dl accumulators are detected as resident
    by_name = {a.name: a for a in audits}
    for name in ("suffstats_bwd_pallas", "psi1_bwd_pallas", "psi2_bwd_pallas"):
        assert by_name[name].resident_bytes > 0, name


def test_bloated_kernel_fixture_exceeds_mock_vmem_budget():
    mod = _load_fixture("bloated_kernel")
    N, M, Q = 4096, 256, 4
    args = (jax.ShapeDtypeStruct((N, Q), jnp.float32),
            jax.ShapeDtypeStruct((M, Q), jnp.float32))
    # under the real budget these sizes still fit (4 MB resident < 16 MiB)
    (ok,) = pallas_audit.audit_callable(mod.bloated_kfu, *args)
    assert ok.fits and not ok.findings
    assert ok.resident_bytes == N * M * 4  # the whole output, resident
    # under a mock 1 MiB budget the audit reports exactly the VMEM finding
    (bad,) = pallas_audit.audit_callable(mod.bloated_kfu, *args,
                                         vmem_budget_bytes=2 ** 20)
    assert [f.code for f in bad.findings] == ["VMEM001"]
    assert "resident" in bad.findings[0].message
    assert not bad.fits


def test_audit_flags_non_divisible_tiles_and_oob_index_maps():
    mod = _load_fixture("bloated_kernel")
    # N not a multiple of TILE_N and M not a multiple of TILE_M: the
    # fixture wrapper does NOT pad, so the audit must flag divisibility
    args = (jax.ShapeDtypeStruct((100, 4), jnp.float32),
            jax.ShapeDtypeStruct((192, 4), jnp.float32))
    (a,) = pallas_audit.audit_callable(mod.bloated_kfu, *args)
    assert any(f.code == "TILE001" for f in a.findings), a.findings


def test_vmem_table_rows_are_json_ready():
    import json

    audits = pallas_audit.audit_kernels(
        problem=pallas_audit.Problem(N=2048, M=256, Q=4, D=2))
    rows = pallas_audit.vmem_table(audits)
    assert len(rows) == len(pallas_audit.KERNELS)
    for row in rows:
        assert row["section"] == "vmem" and row["fits"] is True
        assert row["vmem_estimate_bytes"] == (2 * row["streamed_bytes"]
                                              + row["resident_bytes"]
                                              + row["body_workspace_bytes"])
    json.dumps(rows)  # must serialize as-is


# ---------------------------------------------------------------------------
# repo lint
# ---------------------------------------------------------------------------

def test_clean_tree_lints_clean():
    assert lint.lint_paths() == []


def test_import_time_dispatch_fixture_flags_exactly_anl001():
    src = (FIXTURES / "import_time_dispatch.py").read_text()
    findings = lint.lint_source(src, "repro/seeded/import_time_dispatch.py")
    assert [f.code for f in findings] == ["ANL001"]
    assert findings[0].line == 7  # the module-scope default_backend() call
    assert "import time" in findings[0].message
    assert "7" in findings[0].describe()


def test_anl002_generalized_registry_access_outside_lock():
    """The old hardcoded ANL002 is now guard inference: `put` writing
    `_models` under `_registry_lock` makes the attribute tracked, and the
    lock-free read in `bad` is flagged as ANL006 (`__init__` exempt)."""
    src = (
        "class S:\n"
        "    def __init__(self):\n"
        "        self._models = {}\n"          # exempt: __init__
        "    def put(self, k, v):\n"
        "        with self._registry_lock:\n"
        "            self._models[k] = v\n"    # guarded write: tracked
        "    def bad(self, k):\n"
        "        return self._models[k]\n"     # ANL006
        "    def good(self, k):\n"
        "        with self._registry_lock:\n"
        "            return self._models[k]\n"
    )
    findings = lint.lint_source(src, "repro/serve/server.py")
    assert [(f.code, f.line) for f in findings] == [("ANL006", 8)]
    assert "_registry_lock" in findings[0].message
    # the legacy rule ID still suppresses its generalized form
    suppressed = src.replace("return self._models[k]\n    def good",
                             "return self._models[k]  # noqa: ANL002\n"
                             "    def good")
    assert lint.lint_source(suppressed, "repro/serve/server.py") == []


def test_anl003_backward_registration_outside_dispatcher():
    src = "import jax\nmy_op.defvjp(fwd, bwd)\n_, vjp = jax.vjp(f, x)\n"
    findings = lint.lint_source(src, "repro/kernels/rogue.py")
    assert [f.code for f in findings] == ["ANL003", "ANL003"]
    # the same source is fine outside kernel files and in the dispatcher
    assert lint.lint_source(src, "repro/models/moe.py") == []
    assert lint.lint_source(src, "repro/kernels/ops.py") == []


def test_anl004_literal_dtypes_only_in_kernel_files_outside_helpers():
    src = (
        "import jax.numpy as jnp\n"
        "def k():\n"
        "    return jnp.zeros(3, dtype=jnp.float32)\n"       # ANL004
        "def promote_helper():\n"
        "    return jnp.zeros(3, dtype='float64')\n"          # exempt
        "def j(x):\n"
        "    return x.astype(jnp.float32)\n"                  # ANL004
    )
    findings = lint.lint_source(src, "repro/kernels/rogue.py")
    assert [(f.code, f.line) for f in findings] == [("ANL004", 3),
                                                    ("ANL004", 7)]
    assert lint.lint_source(src, "repro/core/inference.py") == []


def test_noqa_suppresses_a_named_finding():
    src = "import jax\nB = jax.default_backend()  # noqa: ANL001\n"
    assert lint.lint_source(src, "repro/foo.py") == []
    src2 = "import jax\nB = jax.default_backend()  # noqa: ANL002\n"
    assert [f.code for f in lint.lint_source(src2, "repro/foo.py")] == ["ANL001"]


def test_syntax_errors_surface_as_findings_not_crashes():
    findings = lint.lint_source("def broken(:\n", "repro/bad.py")
    assert [f.code for f in findings] == ["ANL000"]


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def test_cli_lint_and_pallas_pass_on_clean_tree(capsys):
    from repro.analysis.__main__ import main

    assert main(["--lint", "--pallas-audit"]) == 0
    out = capsys.readouterr().out
    assert "0 finding(s)" in out and "7 kernel(s) audited" in out


def test_cli_pallas_fails_under_tiny_budget(capsys):
    from repro.analysis.__main__ import main

    assert main(["--pallas-audit", "--vmem-budget", str(2 ** 18)]) > 0
    out = capsys.readouterr().out
    assert "VMEM001" in out and "FAIL" in out


def test_cli_lint_fails_on_seeded_fixture_with_file_and_line(capsys):
    from repro.analysis.__main__ import main

    fixture = FIXTURES / "import_time_dispatch.py"
    assert main(["--lint", str(fixture)]) == 1
    out = capsys.readouterr().out
    assert "import_time_dispatch.py:7: ANL001" in out
