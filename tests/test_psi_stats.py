"""Sufficient-statistic properties — the algebra the paper's distribution
scheme rests on (stats form a commutative monoid over datapoint subsets)."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core import psi_stats
from repro.core.gp_kernels import Linear, RBF
from repro.kernels import ref


def _qx(key, N, Q):
    k1, k2 = jax.random.split(key)
    mu = jax.random.normal(k1, (N, Q), jnp.float64)
    S = 0.05 + 0.2 * jax.random.uniform(k2, (N, Q), jnp.float64)
    return mu, S


def test_chunked_psi2_matches_oracle():
    key = jax.random.PRNGKey(0)
    mu, S = _qx(key, 217, 3)
    Z = jax.random.normal(jax.random.PRNGKey(1), (41, 3), jnp.float64)
    var = jnp.asarray(1.4, jnp.float64)
    ls = jnp.asarray([0.7, 1.1, 2.0], jnp.float64)
    a = psi_stats._psi2_rbf_chunked(mu, S, Z, var, ls, chunk=64)
    b = ref.psi2_rbf(mu, S, Z, var, ls)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-10, atol=1e-12)


@settings(max_examples=20, deadline=None)
@given(
    n1=st.integers(1, 40), n2=st.integers(1, 40), q=st.integers(1, 4),
    m=st.integers(1, 12), seed=st.integers(0, 2**16),
)
def test_stats_combine_equals_full(n1, n2, q, m, seed):
    """combine(stats(A), stats(B)) == stats(A ∪ B) — the paper's §2 claim."""
    key = jax.random.PRNGKey(seed)
    mu, S = _qx(key, n1 + n2, q)
    Y = jax.random.normal(jax.random.fold_in(key, 1), (n1 + n2, 2), jnp.float64)
    Z = jax.random.normal(jax.random.fold_in(key, 2), (m, q), jnp.float64)
    kp = {k: v.astype(jnp.float64) for k, v in RBF(q).init(1.3, 0.9).items()}

    full = psi_stats.expected_stats_rbf(kp, mu, S, Y, Z)
    a = psi_stats.expected_stats_rbf(kp, mu[:n1], S[:n1], Y[:n1], Z)
    b = psi_stats.expected_stats_rbf(kp, mu[n1:], S[n1:], Y[n1:], Z)
    combined = psi_stats.SuffStats.combine(a, b)
    for f, c in zip(full, combined):
        np.testing.assert_allclose(np.asarray(f), np.asarray(c), rtol=1e-9, atol=1e-10)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(2, 50), q=st.integers(1, 3), m=st.integers(1, 10),
       seed=st.integers(0, 2**16))
def test_psi1_bounded_by_variance(n, q, m, seed):
    """0 < Psi1 <= sigma^2 — expectations of a positive kernel bounded by its
    amplitude (catches sign/normalization bugs)."""
    key = jax.random.PRNGKey(seed)
    mu, S = _qx(key, n, q)
    Z = jax.random.normal(jax.random.fold_in(key, 5), (m, q), jnp.float64)
    var = jnp.asarray(2.1, jnp.float64)
    ls = jnp.full((q,), 0.8, jnp.float64)
    p1 = ref.psi1_rbf(mu, S, Z, var, ls)
    assert np.all(np.asarray(p1) > 0)
    assert np.all(np.asarray(p1) <= float(var) + 1e-12)


def test_psi2_positive_semidefinite():
    key = jax.random.PRNGKey(3)
    mu, S = _qx(key, 64, 2)
    Z = jax.random.normal(jax.random.fold_in(key, 1), (20, 2), jnp.float64)
    p2 = ref.psi2_rbf(mu, S, Z, jnp.asarray(1.0, jnp.float64), jnp.ones((2,), jnp.float64))
    evals = np.linalg.eigvalsh(np.asarray(p2))
    assert evals.min() > -1e-8, evals.min()


def test_linear_kernel_stats_match_monte_carlo():
    key = jax.random.PRNGKey(4)
    N, Q, M = 6, 2, 5
    mu, S = _qx(key, N, Q)
    Z = jax.random.normal(jax.random.fold_in(key, 1), (M, Q), jnp.float64)
    kp = {"log_ard": jnp.log(jnp.asarray([0.7, 1.8], jnp.float64))}
    ard = Linear.ard(kp)
    # Monte Carlo over q(X)
    n_mc = 200_000
    eps = jax.random.normal(jax.random.fold_in(key, 2), (n_mc, N, Q), jnp.float64)
    Xs = mu[None] + jnp.sqrt(S)[None] * eps
    kfu = jnp.einsum("snq,q,mq->snm", Xs, ard, Z)
    psi1_mc = jnp.mean(kfu, 0)
    psi2_mc = jnp.einsum("snm,snl->ml", kfu, kfu) / n_mc
    np.testing.assert_allclose(np.asarray(ref.psi1_linear(mu, S, Z, ard)),
                               np.asarray(psi1_mc), rtol=2e-2, atol=2e-2)
    np.testing.assert_allclose(np.asarray(ref.psi2_linear(mu, S, Z, ard)),
                               np.asarray(psi2_mc), rtol=3e-2, atol=3e-2)


def test_exact_stats_match_definition():
    key = jax.random.PRNGKey(5)
    X = jax.random.normal(key, (50, 3), jnp.float64)
    Y = jax.random.normal(jax.random.fold_in(key, 1), (50, 2), jnp.float64)
    Z = jax.random.normal(jax.random.fold_in(key, 2), (11, 3), jnp.float64)
    kp = {k: v.astype(jnp.float64) for k, v in RBF(3).init(1.2, 1.1).items()}
    stats = psi_stats.exact_stats_rbf(kp, X, Y, Z)
    Kfu = ref.kfu_rbf(X, Z, RBF.variance(kp), RBF.lengthscale(kp))
    np.testing.assert_allclose(np.asarray(stats.psi2), np.asarray(Kfu.T @ Kfu), rtol=1e-12)
    np.testing.assert_allclose(np.asarray(stats.psiY), np.asarray(Kfu.T @ Y), rtol=1e-12)
    assert float(stats.psi0) == 50 * float(RBF.variance(kp))
