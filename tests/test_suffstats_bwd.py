"""Pallas reverse-mode suffstats kernel: interpret-mode f64 parity against
jax.grad of the jnp reference, agreement with the hand-derived streaming jnp
VJP, the bwd_backend dispatch knob, the fused exact (S -> 0) path, and the
trace-level guarantee that the fully-kernelized grad path materializes no
(N, M) intermediate."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gplvm
from repro.gp import SparseGPRegression, get, suff_stats
from repro.gp.stats import ExactBatch
from repro.kernels import ops, ref
from repro.kernels.suffstats import (
    TILE_N,
    suffstats_bwd_pallas,
    suffstats_vjp_jnp,
)
from repro.analysis import assert_no_scaling

COTANGENT_NAMES = ("mu", "S", "Y", "Z", "variance", "lengthscale")


def _case(key, N, M=11, Q=2, D=3):
    ks = jax.random.split(key, 6)
    mu = jax.random.normal(ks[0], (N, Q), jnp.float64)
    S = 0.05 + jax.random.uniform(ks[1], (N, Q), jnp.float64)
    Y = jax.random.normal(ks[2], (N, D), jnp.float64)
    Z = jax.random.normal(ks[3], (M, Q), jnp.float64)
    var = jnp.asarray(1.3, jnp.float64)
    ls = 0.6 + jax.random.uniform(ks[4], (Q,), jnp.float64)
    g2 = jax.random.normal(ks[5], (M, M), jnp.float64)
    gY = jax.random.normal(jax.random.fold_in(key, 7), (M, D), jnp.float64)
    return mu, S, Y, Z, var, ls, g2, gY


def _ref_cotangents(mu, S, Y, Z, var, ls, g2, gY):
    """jax.grad of the dense jnp reference formulas (the parity oracle)."""

    def scalar(mu, S, Y, Z, var, ls):
        p2 = ref.psi2_rbf(mu, S, Z, var, ls)
        pY = ref.psi1_rbf(mu, S, Z, var, ls).T @ Y
        return jnp.sum(g2 * p2) + jnp.sum(gY * pY)

    return jax.grad(scalar, argnums=tuple(range(6)))(mu, S, Y, Z, var, ls)


# ---------------------------------------------------------------------------
# interpret-mode parity: the acceptance bar (<= 1e-8 vs jax.grad at f64)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("N", (64, 200))
def test_bwd_kernel_matches_reference_grad_f64(N):
    """The Pallas reverse kernel body (interpret mode, f64) reproduces
    jax.grad of the reference to <= 1e-8. N=64 divides TILE_N exactly;
    N=200 exercises the padded tail tile (pad weights must kill the padded
    datapoints' contributions to every cotangent, global ones included)."""
    assert (N % TILE_N == 0) == (N == 64)
    args = _case(jax.random.PRNGKey(0), N)
    got = suffstats_bwd_pallas(*args, interpret=True)
    want = _ref_cotangents(*args)
    for a, b, name in zip(got, want, COTANGENT_NAMES):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-8,
                                   atol=1e-10, err_msg=name)


def test_bwd_kernel_multi_tile_inducing_grid():
    """M > TILE_M: the (i, j) inducing-tile loops, the off-diagonal tiles'
    two distinct dZ slot updates, and the dynamic-slice accumulation into
    the resident dZ block all agree with the streaming jnp reverse pass."""
    args = _case(jax.random.PRNGKey(1), N=40, M=150, Q=1, D=2)
    got = suffstats_bwd_pallas(*args, interpret=True)
    want = suffstats_vjp_jnp(*args)
    for a, b, name in zip(got, want, COTANGENT_NAMES):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-9,
                                   atol=1e-11, err_msg=name)


# ---------------------------------------------------------------------------
# the custom_vjp dispatch knob
# ---------------------------------------------------------------------------

def _grads_via_op(args, bwd_backend):
    mu, S, Y, Z, var, ls, g2, gY = args

    def scalar(mu, S, Y, Z, var, ls):
        p2, pY = ops.suffstats(mu, S, Y, Z, var, ls, bwd_backend=bwd_backend)
        return jnp.sum(g2 * p2) + jnp.sum(gY * pY)

    return jax.grad(scalar, argnums=tuple(range(6)))(mu, S, Y, Z, var, ls)


@pytest.mark.parametrize("bwd_backend", ("auto", "pallas", "jnp"))
def test_op_bwd_backend_dispatch_parity(bwd_backend):
    """Every knob value routes jax.grad through a reverse pass that matches
    the reference oracle (off-TPU at N=200, "auto" and "pallas" both hit the
    interpret-mode Pallas reverse kernel; "jnp" the streaming scan)."""
    args = _case(jax.random.PRNGKey(2), N=200)
    assert 200 <= ops.FUSED_INTERPRET_MAX_N
    got = _grads_via_op(args, bwd_backend)
    want = _ref_cotangents(*args)
    for a, b, name in zip(got, want, COTANGENT_NAMES):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-8,
                                   atol=1e-10, err_msg=name)


def test_op_bwd_backend_validation():
    args = _case(jax.random.PRNGKey(3), N=64)
    with pytest.raises(ValueError, match="bwd_backend"):
        ops.suffstats(*args[:6], bwd_backend="cuda")


def test_auto_dispatch_streams_beyond_interpret_cap():
    """"auto" above FUSED_INTERPRET_MAX_N (off-TPU) falls back to the
    streaming jnp reverse scan and still matches the reference."""
    N = ops.FUSED_INTERPRET_MAX_N + 476
    args = _case(jax.random.PRNGKey(4), N)
    got = _grads_via_op(args, "auto")
    want = _ref_cotangents(*args)
    for a, b, name in zip(got, want, COTANGENT_NAMES):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-8,
                                   atol=1e-10, err_msg=name)


# ---------------------------------------------------------------------------
# fused exact statistics (S -> 0): the supervised path on the same kernel
# ---------------------------------------------------------------------------

def test_exact_fused_backend_matches_jnp_values_and_grads():
    key = jax.random.PRNGKey(5)
    N, Q, M = 300, 2, 9
    X = jax.random.normal(key, (N, Q), jnp.float64)
    Y = jax.random.normal(jax.random.fold_in(key, 1), (N, 3), jnp.float64)
    Z = jax.random.normal(jax.random.fold_in(key, 2), (M, Q), jnp.float64)
    kern = get("rbf")(Q)
    p = jax.tree.map(lambda x: x.astype(jnp.float64), kern.init(1.2, 0.7))

    a = suff_stats(kern, p, ExactBatch(X, Y, Z), backend="jnp")
    b = suff_stats(kern, p, ExactBatch(X, Y, Z), backend="fused")
    for x, y, name in zip(a, b, a._fields):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=1e-9,
                                   atol=1e-11, err_msg=name)

    def scalar(p, X, Z, backend):
        s = suff_stats(kern, p, ExactBatch(X, Y, Z), backend=backend)
        return s.psi0 + jnp.sum(jnp.cos(s.psi2)) + jnp.sum(jnp.sin(s.psiY))

    ga = jax.grad(scalar, argnums=(0, 1, 2))(p, X, Z, "jnp")
    gb = jax.grad(scalar, argnums=(0, 1, 2))(p, X, Z, "fused")
    jax.tree.map(lambda x, y: np.testing.assert_allclose(
        np.asarray(x), np.asarray(y), rtol=1e-8, atol=1e-10), ga, gb)


def test_sgpr_fused_backend_trains():
    """SparseGPRegression(backend="fused") fits through the fused kernel's
    custom VJP and the bound improves."""
    key = jax.random.PRNGKey(6)
    X = jnp.sort(jax.random.uniform(key, (256, 1), jnp.float64, -3.0, 3.0), axis=0)
    Y = jnp.sin(2.0 * X)
    gp = SparseGPRegression(kernel=get("rbf")(1), M=16, backend="fused")
    gp.fit(X, Y, steps=1, lr=3e-2)
    l0 = gp.history[-1]
    gp.fit(X, Y, steps=40, lr=3e-2)
    assert gp.history[-1] < l0 - 0.05, (l0, gp.history[-1])
    mean, var = gp.predict(X[:64])
    assert np.all(np.isfinite(np.asarray(mean)))
    assert np.all(np.asarray(var) > 0)


def test_matern_exact_stats_still_reject_fused():
    """Only the RBF hot path has the fused kernel; other kernels stay loud."""
    key = jax.random.PRNGKey(7)
    X = jax.random.normal(key, (32, 2), jnp.float64)
    Y = jax.random.normal(key, (32, 1), jnp.float64)
    kern = get("matern32")(2)
    with pytest.raises(ValueError, match="backend"):
        kern.exact_suff_stats(kern.init(), X, Y, X[:4], backend="fused")


# ---------------------------------------------------------------------------
# trace-level memory guarantee for the kernelized grad path
# ---------------------------------------------------------------------------

def _assert_no_nm_intermediate(fn, *args, N, M):
    """Stated once via the analyzer: no intermediate in the trace scales
    like O(N*M) (default margin 4 — "nothing within 4x of an (N, M) array",
    or the fused grad path is not streaming)."""
    assert_no_scaling(fn, *args, axis="N", worse_than="N*M",
                      sizes={"N": N, "M": M})


def test_fused_grad_path_materializes_no_nm_intermediate():
    """Traced (never executed) at N=1e6, M=128: value_and_grad through the
    fused op with the Pallas reverse kernel registers no intermediate
    anywhere near (N, M) — the backward tiles stream exactly like the
    forward's. The same holds for the GP-LVM loss on the auto dispatch."""
    N, M, Q, D = 1_000_000, 128, 2, 3
    key = jax.random.PRNGKey(8)
    mu = jax.random.normal(key, (N, Q), jnp.float32)
    S = jnp.full((N, Q), 0.1, jnp.float32)
    Y = jnp.ones((N, D), jnp.float32)
    Z = jax.random.normal(key, (M, Q), jnp.float32)
    var = jnp.asarray(1.0, jnp.float32)
    ls = jnp.ones((Q,), jnp.float32)

    def scalar(mu, S, Y, Z, var, ls):
        p2, pY = ops.suffstats(mu, S, Y, Z, var, ls, bwd_backend="pallas")
        return jnp.sum(p2) + jnp.sum(pY)

    _assert_no_nm_intermediate(jax.value_and_grad(scalar), mu, S, Y, Z, var,
                               ls, N=N, M=M)

    params = {
        "kern": get("rbf")(Q).init(),
        "Z": Z,
        "log_beta": jnp.asarray(2.0, jnp.float32),
        "q_mu": mu,
        "q_logS": jnp.log(S),
    }

    def lvm_loss(params, Y):
        return gplvm.loss(params, Y, kernel=get("rbf")(Q), backend="fused")

    _assert_no_nm_intermediate(jax.value_and_grad(lvm_loss), params, Y,
                               N=N, M=M)


# ---------------------------------------------------------------------------
# model-level: GP-LVM grads through the kernelized reverse pass
# ---------------------------------------------------------------------------

def test_gplvm_fused_pallas_bwd_matches_jnp_reference():
    """jax.grad of the GP-LVM loss with backend="fused", bwd_backend="pallas"
    (both directions through the Pallas kernel bodies, interpret mode)
    matches the jnp reference to <= 1e-4 per parameter leaf."""
    key = jax.random.PRNGKey(9)
    Y = jax.random.normal(jax.random.fold_in(key, 1), (300, 3), jnp.float64)
    params = jax.tree.map(lambda x: x.astype(jnp.float64),
                          gplvm.init_params(key, np.asarray(Y), Q=1, M=12))
    g_ref = jax.grad(gplvm.loss)(params, Y, backend="jnp")
    g_fused = jax.grad(gplvm.loss)(params, Y, backend="fused",
                                   bwd_backend="pallas")
    ref_leaves, _ = jax.tree_util.tree_flatten_with_path(g_ref)
    fused_leaves, _ = jax.tree_util.tree_flatten_with_path(g_fused)
    for (path, a), (_, b) in zip(ref_leaves, fused_leaves):
        a, b = np.asarray(a, np.float64), np.asarray(b, np.float64)
        rel = np.max(np.abs(a - b)) / (np.max(np.abs(a)) + 1e-12)
        assert rel <= 1e-4, (jax.tree_util.keystr(path), rel)


def test_suffstats_monoid_consistency_exact_vs_expected():
    """S -> 0 really is the exact path: the fused expected statistics with
    zero variances equal the exact K_fu statistics (paper_map.md row 5)."""
    key = jax.random.PRNGKey(10)
    X = jax.random.normal(key, (100, 2), jnp.float64)
    Y = jax.random.normal(jax.random.fold_in(key, 1), (100, 2), jnp.float64)
    Z = jax.random.normal(jax.random.fold_in(key, 2), (7, 2), jnp.float64)
    var = jnp.asarray(0.9, jnp.float64)
    ls = jnp.asarray([0.8, 1.1], jnp.float64)
    p2, pY = ops.suffstats(X, jnp.zeros_like(X), Y, Z, var, ls)
    Kfu = ref.kfu_rbf(X, Z, var, ls)
    np.testing.assert_allclose(np.asarray(p2), np.asarray(Kfu.T @ Kfu),
                               rtol=1e-9)
    np.testing.assert_allclose(np.asarray(pY), np.asarray(Kfu.T @ Y),
                               rtol=1e-9)
