"""Use real `hypothesis` when installed; otherwise a tiny deterministic
fallback so the property tests still collect AND run (satisfying the suite
on minimal images). The fallback draws a fixed pseudo-random sample per
strategy per example — far weaker than hypothesis (no shrinking, no database)
but it executes the same properties over a spread of inputs.

    from _hypothesis_compat import given, settings, st
"""
from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import random

    HAVE_HYPOTHESIS = False
    _FALLBACK_EXAMPLES = 10

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng: random.Random):
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def sampled_from(options):
            options = list(options)
            return _Strategy(lambda rng: rng.choice(options))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: rng.random() < 0.5)

    st = _Strategies()

    def settings(*, max_examples: int = _FALLBACK_EXAMPLES, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def given(**strategies):
        def deco(fn):
            # no functools.wraps: pytest would follow __wrapped__ / the copied
            # signature and demand the strategy names as fixtures
            def wrapper(*args, **kwargs):
                # read at call time: @settings sits ABOVE @given and tags the
                # wrapper after given() has already run
                n = getattr(wrapper, "_max_examples", _FALLBACK_EXAMPLES)
                # deterministic per-test stream: same examples every run
                rng = random.Random(fn.__name__)
                for _ in range(n):
                    drawn = {k: s.example(rng) for k, s in strategies.items()}
                    fn(*args, **{**kwargs, **drawn})

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper

        return deco
