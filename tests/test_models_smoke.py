"""Per-architecture smoke tests (the assignment's reduced-config requirement):
one forward/train step on CPU asserting output shapes + finiteness, plus
decode-after-prefill consistency for every family."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, ShapeCell, get_config, get_smoke_config
from repro.models import model_zoo

CELL = ShapeCell("smoke", 64, 2, "train")


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    model = model_zoo.build(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    batch = model_zoo.make_batch(key, cfg, CELL)
    loss, metrics = jax.jit(lambda p, b: model.train_loss(p, b))(params, batch)
    assert np.isfinite(float(loss)), arch
    assert float(loss) > 0
    # one adam step keeps everything finite
    from repro.optim import AdamConfig, adam_init, adam_update

    acfg = AdamConfig(lr=1e-3)
    g = jax.grad(lambda p: model.train_loss(p, batch)[0])(params)
    p2, _, gnorm = adam_update(g, adam_init(params, acfg), params, acfg)
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0
    assert all(np.all(np.isfinite(np.asarray(x, np.float32))) for x in jax.tree.leaves(p2))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_prefill_decode_shapes(arch):
    cfg = get_smoke_config(arch)
    model = model_zoo.build(cfg)
    key = jax.random.PRNGKey(1)
    params = model.init(key)
    batch = model_zoo.make_batch(key, cfg, CELL)
    logits, states = model.prefill(params, batch)
    assert logits.shape == (2, cfg.padded_vocab())
    pos = jnp.asarray(batch["tokens"].shape[1] + (cfg.frontend_tokens or 0), jnp.int32)
    logits2, _ = model.decode_step(params, batch["tokens"][:, :1], pos, states)
    assert logits2.shape == (2, cfg.padded_vocab())
    assert np.all(np.isfinite(np.asarray(logits2)))
    # padded vocab entries are masked out
    if cfg.padded_vocab() != cfg.vocab_size:
        assert np.all(np.asarray(logits2)[:, cfg.vocab_size :] < -1e29)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_full_forward(arch):
    cfg = get_smoke_config(arch)
    if cfg.num_experts:  # capacity-dropping differs between batch shapes
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    model = model_zoo.build(cfg)
    key = jax.random.PRNGKey(1)
    params = model.init(key)
    batch = model_zoo.make_batch(key, cfg, ShapeCell("p", 64, 2, "prefill"))
    bm1 = dict(batch)
    bm1["tokens"] = batch["tokens"][:, :-1]
    logits_full, _ = model.prefill(params, batch)
    _, states = model.prefill(params, bm1)
    pos = jnp.asarray(batch["tokens"].shape[1] - 1 + (cfg.frontend_tokens or 0), jnp.int32)
    logits_dec, _ = model.decode_step(params, batch["tokens"][:, -1:], pos, states)
    scale = float(jnp.max(jnp.abs(logits_full))) + 1e-6
    err = float(jnp.max(jnp.abs(logits_full - logits_dec)))
    assert err < 1e-3 * max(scale, 1.0), (arch, err, scale)


def test_full_configs_match_assignment():
    """The full (dry-run) configs carry the exact assigned hyperparameters."""
    expect = {
        "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
        "moonshot-v1-16b-a3b": (48, 2048, 16, 16, 1408, 163840),
        "whisper-small": (12, 768, 12, 12, 3072, 51865),
        "gemma3-4b": (34, 2560, 8, 4, 10240, 262144),
        "smollm-360m": (32, 960, 15, 5, 2560, 49152),
        "minicpm-2b": (40, 2304, 36, 36, 5760, 122753),
        "internlm2-20b": (48, 6144, 48, 8, 16384, 92544),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
        "rwkv6-7b": (32, 4096, 64, 64, 14336, 65536),
        "internvl2-2b": (24, 2048, 16, 8, 8192, 92553),
    }
    for arch, (L, d, H, kv, f, V) in expect.items():
        cfg = get_config(arch)
        got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
               cfg.d_ff, cfg.vocab_size)
        assert got == (L, d, H, kv, f, V), (arch, got)
    assert get_config("arctic-480b").num_experts == 128
    assert get_config("arctic-480b").num_experts_per_tok == 2
    assert get_config("arctic-480b").moe_dense_residual
    assert get_config("moonshot-v1-16b-a3b").num_experts_per_tok == 6
    assert get_config("gemma3-4b").window_pattern.count(-1) == 1  # 5 local : 1 global
    assert get_config("recurrentgemma-2b").mixer_pattern == ("rglru", "rglru", "attn")
    assert get_config("whisper-small").encoder_frames == 1500
    assert get_config("internvl2-2b").frontend_tokens == 256
