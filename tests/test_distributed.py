"""Distributed inference == single-device inference (paper §2), and the GP
head integration."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import distributed, gp_head, gplvm
from repro.core.gp_kernels import RBF


def _gplvm_problem(N=160, Q=2, D=3, M=20):
    key = jax.random.PRNGKey(0)
    Y = jax.random.normal(key, (N, D), jnp.float64)
    params = gplvm.init_params(key, np.asarray(Y), Q, M)
    params = jax.tree.map(lambda x: x.astype(jnp.float64), params)
    return params, Y


def test_distributed_gplvm_matches_local():
    params, Y = _gplvm_problem()
    mesh = distributed.make_gp_mesh()
    loss_d = jax.jit(distributed.gplvm_loss_dist(mesh))
    np.testing.assert_allclose(float(loss_d(params, Y)), float(gplvm.loss(params, Y)),
                               rtol=1e-7)


def test_distributed_gradients_match_local():
    params, Y = _gplvm_problem()
    mesh = distributed.make_gp_mesh()
    g_d = jax.jit(jax.grad(distributed.gplvm_loss_dist(mesh)))(params, Y)
    g_l = jax.grad(gplvm.loss)(params, Y)
    for (p, a), (_, b) in zip(
        jax.tree_util.tree_flatten_with_path(g_d)[0],
        jax.tree_util.tree_flatten_with_path(g_l)[0],
    ):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-6,
                                   err_msg=str(p))


def test_distributed_sgpr_runs_and_is_finite():
    key = jax.random.PRNGKey(1)
    N, Q, D, M = 120, 2, 2, 15
    X = jax.random.normal(key, (N, Q), jnp.float64)
    Y = jax.random.normal(jax.random.fold_in(key, 1), (N, D), jnp.float64)
    params = {
        "kern": {k: v.astype(jnp.float64) for k, v in RBF(Q).init().items()},
        "Z": X[:M],
        "log_beta": jnp.asarray(2.0, jnp.float64),
    }
    mesh = distributed.make_gp_mesh()
    loss = jax.jit(distributed.sgpr_loss_dist(mesh))(params, X, Y)
    assert np.isfinite(float(loss))
    g = jax.jit(jax.grad(distributed.sgpr_loss_dist(mesh)))(params, X, Y)
    assert all(np.all(np.isfinite(np.asarray(x))) for x in jax.tree.leaves(g))


def test_gp_head_trains_and_calibrates():
    """Deep-kernel head on synthetic features: loss decreases, predictive
    variance is higher off-manifold than on it."""
    key = jax.random.PRNGKey(2)
    N, F = 256, 16
    feats = jax.random.normal(key, (N, F), jnp.float64)
    targets = jnp.sin(feats[:, 0]) + 0.05 * jax.random.normal(jax.random.fold_in(key, 1), (N,), jnp.float64)
    params = gp_head.init_head(key, F, M=32)
    params = jax.tree.map(lambda x: x.astype(jnp.float64), params)
    l0 = float(gp_head.head_loss(params, feats, targets))

    from repro.core.inference import fit_adam

    params, hist = fit_adam(gp_head.head_loss, params, (feats, targets), steps=100, lr=3e-2)
    assert hist[-1] < l0
    pred = gp_head.head_predict(params, feats, targets, feats[:8])
    far = 20.0 + jax.random.normal(jax.random.fold_in(key, 3), (8, F), jnp.float64)
    pred_far = gp_head.head_predict(params, feats, targets, far)
    assert float(jnp.mean(pred_far.var)) > float(jnp.mean(pred.var))
