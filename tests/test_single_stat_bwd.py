"""Single-statistic reverse kernels (kfu/psi1/psi2): interpret-mode f64
parity against jax.grad of the jnp reference formulas, agreement between the
Pallas kernels and the streaming jnp twins, the per-op bwd_backend dispatch
knob, the call-time interpret-mode helper (+ its test-visible override), and
the trace-level guarantee that the kernelized grad paths materialize no
reference-VJP-sized cotangent intermediate — mirroring
tests/test_suffstats_bwd.py for the fused op."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gplvm, svgp
from repro.gp import get, suff_stats
from repro.gp.stats import ExactBatch
from repro.kernels import ops, ref
from repro.kernels.suffstats import (
    TILE_N,
    kfu_bwd_pallas,
    kfu_vjp_jnp,
    psi1_bwd_pallas,
    psi1_vjp_jnp,
    psi2_bwd_pallas,
    psi2_vjp_jnp,
)
from repro.analysis import ScalingViolation, assert_no_scaling

COTANGENT_NAMES = ("mu", "S", "Z", "variance", "lengthscale")


def _case(key, N, M=11, Q=2):
    ks = jax.random.split(key, 6)
    mu = jax.random.normal(ks[0], (N, Q), jnp.float64)
    S = 0.05 + jax.random.uniform(ks[1], (N, Q), jnp.float64)
    Z = jax.random.normal(ks[2], (M, Q), jnp.float64)
    var = jnp.asarray(1.3, jnp.float64)
    ls = 0.6 + jax.random.uniform(ks[3], (Q,), jnp.float64)
    g1 = jax.random.normal(ks[4], (N, M), jnp.float64)  # kfu/psi1 cotangent
    g2 = jax.random.normal(ks[5], (M, M), jnp.float64)  # psi2 cotangent
    return mu, S, Z, var, ls, g1, g2


# one row per op: (ref formula fn, Pallas reverse kernel, jnp reverse twin,
# op wrapper, argnums into (mu, S, Z, var, ls), uses g2)
OPS = {
    "kfu": (ref.kfu_rbf, kfu_bwd_pallas, kfu_vjp_jnp, ops.kfu,
            (0, 2, 3, 4), False),
    "psi1": (ref.psi1_rbf, psi1_bwd_pallas, psi1_vjp_jnp, ops.psi1,
             (0, 1, 2, 3, 4), False),
    "psi2": (ref.psi2_rbf, psi2_bwd_pallas, psi2_vjp_jnp, ops.psi2,
             (0, 1, 2, 3, 4), True),
}


def _op_args(name, case):
    mu, S, Z, var, ls, g1, g2 = case
    args = tuple((mu, S, Z, var, ls)[i] for i in OPS[name][4])
    g = g2 if OPS[name][5] else g1
    return args, g


def _ref_cotangents(name, args, g):
    """jax.grad of the dense jnp reference formula (the parity oracle)."""
    ref_fn = OPS[name][0]
    return jax.grad(lambda *a: jnp.sum(g * ref_fn(*a)),
                    argnums=tuple(range(len(args))))(*args)


def _names(name):
    return tuple(COTANGENT_NAMES[i] if name != "kfu" else
                 ("X", "Z", "variance", "lengthscale")[j]
                 for j, i in enumerate(OPS[name][4]))


# ---------------------------------------------------------------------------
# interpret-mode parity: the acceptance bar (<= 1e-8 vs jax.grad at f64)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("op_name", sorted(OPS))
@pytest.mark.parametrize("N", (64, 200))
def test_bwd_kernel_matches_reference_grad_f64(op_name, N):
    """Each single-statistic Pallas reverse kernel body (interpret mode,
    f64) reproduces jax.grad of its reference formula to <= 1e-8. N=64
    divides TILE_N exactly; N=200 exercises the padded tail tile (the
    zero-padded cotangent rows must kill the padded datapoints'
    contributions to every cotangent, global ones included)."""
    assert (N % TILE_N == 0) == (N == 64)
    args, g = _op_args(op_name, _case(jax.random.PRNGKey(0), N))
    got = OPS[op_name][1](*args, g, interpret=True)
    want = _ref_cotangents(op_name, args, g)
    for a, b, name in zip(got, want, _names(op_name)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-8,
                                   atol=1e-10, err_msg=f"{op_name} {name}")


@pytest.mark.parametrize("op_name", sorted(OPS))
def test_bwd_kernel_multi_tile_inducing_grid(op_name):
    """M > TILE_M: the inducing-tile loop (and, for psi2, the two distinct
    dZ slot updates into the resident block) agrees with the streaming jnp
    twin built on the same shared tile helpers."""
    args, g = _op_args(op_name, _case(jax.random.PRNGKey(1), N=40, M=150, Q=1))
    got = OPS[op_name][1](*args, g, interpret=True)
    want = OPS[op_name][2](*args, g, chunk=32)
    for a, b, name in zip(got, want, _names(op_name)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-9,
                                   atol=1e-11, err_msg=f"{op_name} {name}")


@pytest.mark.parametrize("op_name", sorted(OPS))
def test_jnp_twin_matches_reference_grad_f64(op_name):
    """The streaming jnp twins (the off-TPU large-N backward) hit the same
    <= 1e-8 bar, including a non-dividing chunking of N."""
    args, g = _op_args(op_name, _case(jax.random.PRNGKey(2), N=200))
    got = OPS[op_name][2](*args, g, chunk=64)
    want = _ref_cotangents(op_name, args, g)
    for a, b, name in zip(got, want, _names(op_name)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-8,
                                   atol=1e-10, err_msg=f"{op_name} {name}")


# ---------------------------------------------------------------------------
# the per-op custom_vjp dispatch knob
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("op_name", sorted(OPS))
@pytest.mark.parametrize("bwd_backend", ("auto", "pallas", "jnp"))
def test_op_bwd_backend_dispatch_parity(op_name, bwd_backend):
    """Every knob value routes jax.grad through a reverse pass that matches
    the reference oracle (off-TPU at N=200, "auto" and "pallas" both hit the
    interpret-mode Pallas reverse kernel; "jnp" the streaming scan)."""
    args, g = _op_args(op_name, _case(jax.random.PRNGKey(3), N=200))
    assert 200 <= ops.FUSED_INTERPRET_MAX_N
    op = OPS[op_name][3]
    got = jax.grad(lambda *a: jnp.sum(g * op(*a, bwd_backend=bwd_backend)),
                   argnums=tuple(range(len(args))))(*args)
    want = _ref_cotangents(op_name, args, g)
    for a, b, name in zip(got, want, _names(op_name)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-8,
                                   atol=1e-10, err_msg=f"{op_name} {name}")


@pytest.mark.parametrize("op_name", sorted(OPS))
def test_op_bwd_backend_validation(op_name):
    args, _ = _op_args(op_name, _case(jax.random.PRNGKey(4), N=64))
    with pytest.raises(ValueError, match="bwd_backend"):
        OPS[op_name][3](*args, bwd_backend="cuda")


def test_auto_dispatch_streams_beyond_interpret_cap():
    """"auto" above FUSED_INTERPRET_MAX_N (off-TPU) falls back to the
    streaming jnp twins and still matches the reference."""
    N = ops.FUSED_INTERPRET_MAX_N + 476
    case = _case(jax.random.PRNGKey(5), N)
    for op_name in sorted(OPS):
        args, g = _op_args(op_name, case)
        op = OPS[op_name][3]
        got = jax.grad(lambda *a: jnp.sum(g * op(*a, bwd_backend="auto")),
                       argnums=tuple(range(len(args))))(*args)
        want = _ref_cotangents(op_name, args, g)
        for a, b, name in zip(got, want, _names(op_name)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-8, atol=1e-10,
                                       err_msg=f"{op_name} {name}")


# ---------------------------------------------------------------------------
# call-time interpret-mode selection (the import-time-freeze fix)
# ---------------------------------------------------------------------------

def test_interpret_mode_reads_backend_at_call_time(monkeypatch):
    """`interpret_mode()` is a live read, not an import-time constant: the
    test-visible override flips it immediately, and clearing the override
    restores backend detection (off-TPU here, so True)."""
    assert ops.interpret_mode() is (jax.default_backend() != "tpu")
    monkeypatch.setattr(ops, "_INTERPRET_OVERRIDE", False)
    assert ops.interpret_mode() is False
    monkeypatch.setattr(ops, "_INTERPRET_OVERRIDE", True)
    assert ops.interpret_mode() is True
    monkeypatch.setattr(ops, "_INTERPRET_OVERRIDE", None)
    assert ops.interpret_mode() is (jax.default_backend() != "tpu")
    # back-compat attribute is call-time fresh too (it used to freeze)
    monkeypatch.setattr(ops, "_INTERPRET_OVERRIDE", False)
    assert ops.INTERPRET is False


# ---------------------------------------------------------------------------
# training losses: pallas-bwd grads == reference-VJP grads (<= 1e-8, f64)
# ---------------------------------------------------------------------------

def _sgpr_loss(params, X, Y, *, backend, bwd_backend="auto"):
    kern = get("rbf")(X.shape[1])
    stats = suff_stats(kern, params["kern"], ExactBatch(X, Y, params["Z"]),
                       backend=backend, bwd_backend=bwd_backend)
    Kuu = kern.K(params["kern"], params["Z"])
    terms = svgp.collapsed_bound(Kuu, stats, jnp.exp(params["log_beta"]),
                                 Y.shape[1])
    return -terms.bound / stats.n


def _assert_tree_close(ga, gb, rtol=1e-8, atol=1e-10):
    a_leaves, _ = jax.tree_util.tree_flatten_with_path(ga)
    b_leaves, _ = jax.tree_util.tree_flatten_with_path(gb)
    for (path, a), (_, b) in zip(a_leaves, b_leaves):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=rtol, atol=atol,
            err_msg=jax.tree_util.keystr(path))


def test_sgpr_loss_pallas_bwd_matches_reference_grads():
    """jax.grad of the supervised training loss through ops.kfu with
    bwd_backend="pallas" (kfu reverse kernel, interpret f64) equals the
    reference-VJP path to <= 1e-8."""
    key = jax.random.PRNGKey(6)
    N, Q, M = 200, 2, 9
    X = jax.random.normal(key, (N, Q), jnp.float64)
    Y = jax.random.normal(jax.random.fold_in(key, 1), (N, 2), jnp.float64)
    kern = get("rbf")(Q)
    params = {
        "kern": jax.tree.map(lambda x: x.astype(jnp.float64),
                             kern.init(1.2, 0.7)),
        "Z": jax.random.normal(jax.random.fold_in(key, 2), (M, Q), jnp.float64),
        "log_beta": jnp.asarray(2.0, jnp.float64),
    }
    g_ref = jax.grad(_sgpr_loss)(params, X, Y, backend="jnp")
    g_pal = jax.grad(_sgpr_loss)(params, X, Y, backend="pallas",
                                 bwd_backend="pallas")
    _assert_tree_close(g_ref, g_pal)


def test_gplvm_loss_pallas_bwd_matches_reference_grads():
    """jax.grad of the GP-LVM loss through ops.psi1 + ops.psi2 with
    bwd_backend="pallas" (both single-statistic reverse kernels, interpret
    f64) equals the reference-VJP path to <= 1e-8."""
    key = jax.random.PRNGKey(7)
    Y = jax.random.normal(jax.random.fold_in(key, 1), (200, 3), jnp.float64)
    params = jax.tree.map(lambda x: x.astype(jnp.float64),
                          gplvm.init_params(key, np.asarray(Y), Q=2, M=12))
    g_ref = jax.grad(gplvm.loss)(params, Y, backend="jnp")
    g_pal = jax.grad(gplvm.loss)(params, Y, backend="pallas",
                                 bwd_backend="pallas")
    _assert_tree_close(g_ref, g_pal)


# ---------------------------------------------------------------------------
# trace-level memory guarantees for the kernelized grad paths
# ---------------------------------------------------------------------------

def test_psi2_pallas_bwd_materializes_no_nm_intermediate_at_1m():
    """Traced (never executed) at N=1e6, M=128: value_and_grad through the
    psi2 op with the Pallas reverse kernel registers no intermediate
    anywhere near (N, M) — psi2's inputs are (N, Q) and its output (M, M),
    so the kernelized reverse streams end to end (the retired jax.vjp path
    re-derived per-chunk (chunk, M, M) reference residuals instead)."""
    N, M, Q = 1_000_000, 128, 2
    key = jax.random.PRNGKey(8)
    mu = jax.random.normal(key, (N, Q), jnp.float32)
    S = jnp.full((N, Q), 0.1, jnp.float32)
    Z = jax.random.normal(key, (M, Q), jnp.float32)
    var = jnp.asarray(1.0, jnp.float32)
    ls = jnp.ones((Q,), jnp.float32)

    def scalar(mu, S, Z, var, ls):
        return jnp.sum(ops.psi2(mu, S, Z, var, ls, bwd_backend="pallas"))

    # default margin 4: nothing within 4x of an (N, M) array, or the psi2
    # grad path is not streaming
    assert_no_scaling(jax.value_and_grad(scalar, argnums=(0, 1, 2, 3, 4)),
                      mu, S, Z, var, ls, axis="N", worse_than="N*M",
                      sizes={"N": N, "M": M, "Q": Q})


@pytest.mark.parametrize("op_name", ("kfu", "psi1"))
def test_nm_output_ops_pallas_bwd_peak_is_the_cotangent_itself(op_name):
    """kfu/psi1 OUTPUT an (N, M) matrix, so their cotangent is (N, M) by
    construction — the guarantee is that the pallas-bwd path materializes
    nothing BEYOND it: no (N, M, Q) reference-formula residual (Q x larger;
    exactly what the retired jax.vjp backward built, as the comparative
    trace below shows)."""
    N, M, Q = 1_000_000, 128, 8
    key = jax.random.PRNGKey(9)
    mu = jax.random.normal(key, (N, Q), jnp.float32)
    S = jnp.full((N, Q), 0.1, jnp.float32)
    Z = jax.random.normal(key, (M, Q), jnp.float32)
    var = jnp.asarray(1.0, jnp.float32)
    ls = jnp.ones((Q,), jnp.float32)
    if op_name == "kfu":
        args = (mu, Z, var, ls)
        op, ref_fn = ops.kfu, None  # kfu's ref VJP was already (N, M)-bound
    else:
        args = (mu, S, Z, var, ls)
        op, ref_fn = ops.psi1, ref.psi1_rbf

    def scalar(*a):
        return jnp.sum(op(*a, bwd_backend="pallas"))

    # margin=0.5 loosens the O(N*M) bound to "nothing beyond 2x the (N, M)
    # output/cotangent" — the cotangent itself is class O(N*M) and allowed
    sizes = {"N": N, "M": M, "Q": Q}
    assert_no_scaling(
        jax.value_and_grad(scalar, argnums=tuple(range(len(args)))), *args,
        axis="N", worse_than="N*M", margin=0.5, sizes=sizes)
    if ref_fn is not None:  # the retired jax.vjp path really was Q x worse
        with pytest.raises(ScalingViolation) as exc:
            assert_no_scaling(
                jax.value_and_grad(lambda *a: jnp.sum(ref_fn(*a)),
                                   argnums=tuple(range(len(args)))), *args,
                axis="N", worse_than="N*M", margin=0.5, sizes=sizes)
        # it violates with an (N, M, Q)-class residual, not a mere 2x buffer
        assert any(v.growth_exp == 1 and v.coeff >= M * Q / 2
                   for v in exc.value.violations), exc.value.violations


def test_gplvm_pallas_backend_grad_trace_has_no_nmq_residual():
    """Model-level: the GP-LVM training step on backend="pallas" with the
    Pallas reverse kernels peaks at the unavoidable (N, M) psi1 statistic,
    never the (N, M, Q) reference residuals of the retired VJP path."""
    N, M, Q, D = 1_000_000, 128, 4, 3
    key = jax.random.PRNGKey(10)
    Y = jnp.ones((N, D), jnp.float32)
    params = {
        "kern": get("rbf")(Q).init(),
        "Z": jax.random.normal(key, (M, Q), jnp.float32),
        "log_beta": jnp.asarray(2.0, jnp.float32),
        "q_mu": jax.random.normal(key, (N, Q), jnp.float32),
        "q_logS": jnp.full((N, Q), -2.0, jnp.float32),
    }

    def lvm_loss(params, Y):
        return gplvm.loss(params, Y, kernel=get("rbf")(Q), backend="pallas",
                          bwd_backend="pallas")

    # margin=0.5: the unavoidable (N, M) psi1 statistic passes, anything
    # reaching the (N, M, Q) reference-residual class fails
    assert_no_scaling(jax.value_and_grad(lvm_loss), params, Y,
                      axis="N", worse_than="N*M", margin=0.5,
                      sizes={"N": N, "M": M, "Q": Q})
