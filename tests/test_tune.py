"""The repro.tune autotuner: persistent-cache semantics (round-trip, schema
rejection, corrupt-file tolerance, concurrency), the zero-timing warm-cache
contract (in-process and across processes), tuned-block resolution through
`kernels.ops`, chunk="auto" parity, the bounded op-factory cache, and the
tune-cache-backed interpret-dispatch threshold."""
import json
import os
import subprocess
import sys
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import tune
from repro.analysis.pallas_audit import Problem, audit_candidate, vmem_estimate
from repro.kernels import ops
from repro.tune import autotune, cache, search

SMALL = Problem(N=64, M=128, Q=3, D=2)


@pytest.fixture
def tune_env(tmp_path, monkeypatch):
    """Isolated cache file + clean memo + tuning force-DISABLED."""
    path = str(tmp_path / "tune.json")
    monkeypatch.setenv("REPRO_TUNE_CACHE", path)
    monkeypatch.setattr(autotune, "_ENABLED_OVERRIDE", False)
    tune.clear_memo()
    yield path
    tune.clear_memo()


@pytest.fixture
def tuning_on(tune_env, monkeypatch):
    """Same isolation, but with the measuring path live."""
    monkeypatch.setattr(autotune, "_ENABLED_OVERRIDE", True)
    return tune_env


def _runs():
    return tune.timing_runs()


# ---------------------------------------------------------------------------
# persistent cache store
# ---------------------------------------------------------------------------

def test_cache_round_trip(tune_env):
    cache.store("k1", {"winner": [32, 128]}, tune_env)
    cache.store("k2", {"winner": 2048}, tune_env)
    assert cache.lookup("k1", tune_env) == {"winner": [32, 128]}
    assert cache.lookup("k2", tune_env) == {"winner": 2048}
    # the file itself is schema-stamped, whole-document JSON
    doc = json.load(open(tune_env))
    assert doc["schema_version"] == cache.SCHEMA_VERSION
    assert set(doc["entries"]) == {"k1", "k2"}


def test_cache_schema_mismatch_rejected(tune_env):
    with open(tune_env, "w") as f:
        json.dump({"schema_version": cache.SCHEMA_VERSION + 1,
                   "entries": {"k": {"winner": [8, 128]}}}, f)
    assert cache.load_entries(tune_env) == {}
    assert cache.lookup("k", tune_env) is None


@pytest.mark.parametrize("content", [
    "", "{", "[1, 2, 3]", '{"entries": {"k": 1}}', "\x00\x01garbage",
    '{"schema_version": 1, "entries": "not a dict"}',
])
def test_cache_corrupt_file_falls_back_without_raising(tune_env, content):
    with open(tune_env, "w") as f:
        f.write(content)
    assert cache.load_entries(tune_env) == {}
    # and a resolve over the corrupt file still answers (defaults)
    assert tune.best_blocks("kfu_pallas", dtype=jnp.float32, m=128,
                            q=3) is None
    assert _runs() == 0


def test_cache_store_over_corrupt_file_recovers(tune_env):
    with open(tune_env, "w") as f:
        f.write("definitely not json")
    cache.store("k", {"winner": [64, 128]}, tune_env)
    assert cache.lookup("k", tune_env) == {"winner": [64, 128]}


def test_cache_missing_file_is_empty(tune_env):
    assert not os.path.exists(tune_env)
    assert cache.load_entries(tune_env) == {}


def test_cache_path_env_override(tune_env):
    assert cache.cache_path() == tune_env


# ---------------------------------------------------------------------------
# resolution: disabled -> defaults with zero timing, cached -> winner
# ---------------------------------------------------------------------------

def test_disabled_resolution_returns_defaults_without_timing(tune_env):
    before = _runs()
    assert tune.best_blocks("psi1_pallas", dtype=jnp.float32, m=128,
                            q=3) is None
    assert tune.best_chunk(n=512, m=16, q=2, d=1) == tune.DEFAULT_CHUNK
    assert _runs() == before


def test_cached_winner_resolves_without_timing(tune_env):
    key = autotune.make_key("blocks", "kfu_pallas", jnp.float32, 128, 3)
    cache.store(key, {"winner": [64, 128]}, tune_env)
    tune.clear_memo()
    before = _runs()
    assert tune.best_blocks("kfu_pallas", dtype=jnp.float32, m=128,
                            q=3) == (64, 128)
    assert _runs() == before


def test_first_call_measures_and_persists(tuning_on, monkeypatch):
    timed = []
    monkeypatch.setattr(autotune, "_time_fn",
                        lambda fn: float(len(timed)) + (timed.append(1) or 1.0))
    monkeypatch.setenv("REPRO_TUNE_MAX_CANDIDATES", "2")
    before = _runs()
    win = tune.best_blocks("kfu_pallas", dtype=jnp.float32, m=SMALL.M,
                           q=SMALL.Q, problem=SMALL)
    assert win is not None and len(win) == 2
    assert _runs() == before + 2  # counted even with the fake stopwatch
    # persisted: a fresh memo resolves from the file with no new timing
    tune.clear_memo()
    assert tune.best_blocks("kfu_pallas", dtype=jnp.float32, m=SMALL.M,
                            q=SMALL.Q, problem=SMALL) == win
    assert _runs() == before + 2


def test_concurrent_first_call_resolves_to_one_winner(tuning_on, monkeypatch):
    calls = []

    def fake_time(fn):
        calls.append(1)
        return float(len(calls))  # monotone: first candidate always wins

    monkeypatch.setattr(autotune, "_time_fn", fake_time)
    monkeypatch.setenv("REPRO_TUNE_MAX_CANDIDATES", "2")
    results = []

    def worker():
        results.append(tune.best_blocks(
            "kfu_pallas", dtype=jnp.float32, m=SMALL.M, q=SMALL.Q,
            problem=SMALL))

    threads = [threading.Thread(target=worker) for _ in range(2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len(results) == 2 and results[0] == results[1]
    # exactly one thread measured: one 2-candidate sweep, not two
    assert len(calls) == 2
    entries = cache.load_entries(tuning_on)
    assert sum(1 for k in entries if k.startswith("blocks|")) == 1


def test_warm_cache_second_process_does_zero_timing_runs(tmp_path):
    path = str(tmp_path / "tune.json")
    env = dict(os.environ, REPRO_TUNE="1", REPRO_TUNE_CACHE=path,
               REPRO_TUNE_MAX_CANDIDATES="2", JAX_PLATFORMS="cpu")
    prog = (
        "import jax.numpy as jnp\n"
        "from repro import tune\n"
        "from repro.analysis.pallas_audit import Problem\n"
        "p = Problem(N=64, M=128, Q=3, D=2)\n"
        "w = tune.best_blocks('kfu_pallas', dtype=jnp.float32, m=128, q=3,"
        " problem=p)\n"
        "assert w is not None\n"
        "print('RUNS', tune.timing_runs())\n"
    )
    first = subprocess.run([sys.executable, "-c", prog], env=env,
                           capture_output=True, text=True)
    assert first.returncode == 0, first.stderr
    assert "RUNS 2" in first.stdout
    second = subprocess.run([sys.executable, "-c", prog], env=env,
                            capture_output=True, text=True)
    assert second.returncode == 0, second.stderr
    assert "RUNS 0" in second.stdout  # the warm-cache contract


# ---------------------------------------------------------------------------
# search space: auditor-gated candidates
# ---------------------------------------------------------------------------

def test_candidates_start_with_default_and_pass_audit():
    cands = search.candidate_blocks("kfu_pallas", problem=SMALL)
    assert cands[0] == search.default_blocks("kfu_pallas")
    for blk in cands:
        audit = audit_candidate("kfu_pallas", blk, problem=SMALL)
        assert audit.fits
        assert not any(f.code in ("TILE001", "IDX001")
                       for f in audit.findings)


def test_candidate_limit_env(monkeypatch):
    monkeypatch.setenv("REPRO_TUNE_MAX_CANDIDATES", "2")
    assert len(search.candidate_blocks("psi1_pallas", problem=SMALL)) == 2


def test_over_budget_candidates_are_filtered():
    # a tiny budget admits nothing: every candidate is gated by the
    # auditor's single VMEM model
    audit = audit_candidate("suffstats_pallas", (32, 128), problem=SMALL,
                            vmem_budget_bytes=1024)
    assert not audit.fits


def test_vmem_estimate_is_the_shared_model():
    assert vmem_estimate(100, 10, 5) == 2 * 100 + 10 + 5
    audit = audit_candidate("kfu_pallas", (32, 128), problem=SMALL)
    assert audit.vmem_estimate_bytes == vmem_estimate(
        audit.streamed_bytes, audit.resident_bytes,
        audit.body_workspace_bytes)


def test_chunk_candidates_respect_n():
    cands = search.candidate_chunks(1500)
    assert cands[0] == search.DEFAULT_CHUNK
    assert 1500 in cands
    assert all(c <= 1500 or c == search.DEFAULT_CHUNK for c in cands)


# ---------------------------------------------------------------------------
# ops integration: tuned blocks flow into the kernels, numerics unchanged
# ---------------------------------------------------------------------------

def _psi_args(n=24, m=16, q=3, seed=0):
    k = jax.random.split(jax.random.PRNGKey(seed), 5)
    return (jax.random.normal(k[0], (n, q)),
            jnp.exp(jax.random.normal(k[1], (n, q)) * 0.2),
            jax.random.normal(k[2], (m, q)),
            jnp.exp(jax.random.normal(k[3], ()) * 0.1),
            jnp.exp(jax.random.normal(k[4], (q,)) * 0.1))


def test_explicit_block_override_matches_defaults(tune_env):
    mu, S, Z, var, ls = _psi_args()
    base = ops.psi1(mu, S, Z, var, ls)
    alt = ops.psi1(mu, S, Z, var, ls, block=(64, 128), bwd_block=(64, 128))
    np.testing.assert_allclose(np.asarray(base), np.asarray(alt), rtol=1e-12)

    g = jax.grad(lambda *a: ops.psi2(*a).sum(), argnums=(0, 1))(mu, S, Z,
                                                                var, ls)
    g_alt = jax.grad(
        lambda *a: ops.psi2(*a, block=(64, 256), bwd_block=(64, 256)).sum(),
        argnums=(0, 1))(mu, S, Z, var, ls)
    for a, b in zip(g, g_alt):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-9)


def test_tuned_winner_is_consulted_by_ops(tune_env, monkeypatch):
    """A cached winner changes which block reaches the Pallas wrapper."""
    seen = {}
    real = ops.kfu_pallas

    def spy(*args, **kw):
        seen["block"] = kw.get("block")
        return real(*args, **kw)

    monkeypatch.setattr(ops, "kfu_pallas", spy)
    key = autotune.make_key("blocks", "kfu_pallas", jnp.float64, 16, 3)
    cache.store(key, {"winner": [64, 128]}, tune_env)
    tune.clear_memo()
    X = jnp.ones((8, 3)); Z = jnp.ones((16, 3))
    out = ops.kfu(X, Z, jnp.asarray(1.0), jnp.ones(3))
    assert seen["block"] == (64, 128)
    # ...and the numbers match the default-block path exactly
    np.testing.assert_allclose(
        np.asarray(out),
        np.asarray(real(X, Z, jnp.asarray(1.0), jnp.ones(3),
                        interpret=True)),
        rtol=1e-12)


def test_all_seven_kernels_resolve_through_tune(tune_env, monkeypatch):
    """Every registered kernel's entry point consults tune.best_blocks for
    its direction — forward AND reverse."""
    asked = []
    real = tune.best_blocks

    def spy(name, **kw):
        asked.append(name)
        return real(name, **kw)

    monkeypatch.setattr("repro.tune.best_blocks", spy)
    mu, S, Z, var, ls = _psi_args()
    Y = jnp.ones((mu.shape[0], 2), mu.dtype)
    X = mu
    jax.grad(lambda *a: ops.kfu(*a).sum())(X, Z, var, ls)
    jax.grad(lambda *a: ops.psi1(*a).sum())(mu, S, Z, var, ls)
    jax.grad(lambda *a: ops.psi2(*a).sum())(mu, S, Z, var, ls)
    jax.grad(lambda *a: sum(o.sum() for o in ops.suffstats(*a)))(
        mu, S, Y, Z, var, ls)
    assert set(asked) == {
        "kfu_pallas", "psi1_pallas", "psi2_pallas", "suffstats_pallas",
        "suffstats_bwd_pallas", "psi1_bwd_pallas", "psi2_bwd_pallas"}


def test_chunk_auto_matches_explicit(tune_env):
    from repro.gp.kernels import RBF
    from repro.gp.stats import ExpectedBatch, suff_stats

    kern = RBF(2)
    params = kern.init()
    k = jax.random.split(jax.random.PRNGKey(1), 3)
    batch = ExpectedBatch(
        jax.random.normal(k[0], (37, 2)),
        jnp.exp(jax.random.normal(k[1], (37, 2)) * 0.2),
        jax.random.normal(k[2], (37, 1)),
        jnp.linspace(-1, 1, 8)[:, None] * jnp.ones((8, 2)))
    auto = suff_stats(kern, params, batch, backend="jnp", chunk="auto")
    explicit = suff_stats(kern, params, batch, backend="jnp",
                          chunk=tune.DEFAULT_CHUNK)
    for a, b in zip(auto, explicit):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-10)
    with pytest.raises(ValueError, match="auto"):
        suff_stats(kern, params, batch, backend="jnp", chunk="turbo")


def test_chunk_auto_uses_cached_winner(tune_env, monkeypatch):
    from repro.gp import stats as gp_stats
    from repro.gp.kernels import RBF

    key = autotune.make_key("chunk", "streaming_suff_stats", jnp.float64,
                            8, 2, extra="backend=jnp")
    cache.store(key, {"winner": 7}, tune_env)
    tune.clear_memo()
    kern = RBF(2)
    params = kern.init()
    batch = gp_stats.ExpectedBatch(
        jnp.ones((21, 2)), jnp.full((21, 2), 0.4), jnp.ones((21, 1)),
        jnp.ones((8, 2)))

    # the facade accepts "auto" too (no int() coercion in the constructor)
    from repro.gp.models import BayesianGPLVM
    model = BayesianGPLVM(RBF(2), M=8, chunk="auto")
    assert model.chunk == "auto"

    resolved = tune.best_chunk(n=21, m=8, q=2, d=1, dtype=jnp.float64,
                               backend="jnp")
    assert resolved == 7
    auto = gp_stats.suff_stats(kern, params, batch, backend="jnp",
                               chunk="auto")
    explicit = gp_stats.suff_stats(kern, params, batch, backend="jnp",
                                   chunk=7)
    for a, b in zip(auto, explicit):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-10)


# ---------------------------------------------------------------------------
# satellite: bounded op-factory cache + debug hook
# ---------------------------------------------------------------------------

def test_op_factory_cache_is_bounded_with_info():
    info = ops.cache_info()
    assert set(info) == {"kfu", "psi1", "psi2", "suffstats"}
    for stats in info.values():
        assert stats.maxsize == ops._OP_CACHE_SIZE
    before = ops.cache_info()["kfu"].currsize
    X = jnp.ones((8, 3)); Z = jnp.ones((8, 3))
    # blocks no other test uses, so these two knob keys are fresh
    ops.kfu(X, Z, jnp.asarray(1.0), jnp.ones(3), block=(96, 128))
    ops.kfu(X, Z, jnp.asarray(1.0), jnp.ones(3), block=(160, 128))
    after = ops.cache_info()["kfu"]
    assert after.currsize == min(before + 2, ops._OP_CACHE_SIZE)
    assert after.currsize <= ops._OP_CACHE_SIZE


# ---------------------------------------------------------------------------
# satellite: interpret-dispatch threshold (named constant + hooks)
# ---------------------------------------------------------------------------

def test_interpret_threshold_default_and_module_getattr(tune_env):
    assert ops.fused_interpret_max_n() == ops.DEFAULT_FUSED_INTERPRET_MAX_N
    # back-compat attribute still reads (call-time fresh)
    assert ops.FUSED_INTERPRET_MAX_N == ops.DEFAULT_FUSED_INTERPRET_MAX_N


def test_interpret_threshold_override_hook(tune_env, monkeypatch):
    monkeypatch.setattr(ops, "_INTERPRET_MAX_N_OVERRIDE", 7)
    assert ops.fused_interpret_max_n() == 7
    assert ops.FUSED_INTERPRET_MAX_N == 7


def test_interpret_threshold_reads_tune_cache(tune_env):
    key = "|".join(["interpret_max_n", jax.default_backend()])
    cache.store(key, {"winner": 512}, tune_env)
    tune.clear_memo()
    assert tune.cached_interpret_max_n() == 512
    assert ops.fused_interpret_max_n() == 512
    assert ops.FUSED_INTERPRET_MAX_N == 512
