import os

# Tests must see the real device count (1 CPU) — the 512-device override is
# exclusively the dry-run's (see launch/dryrun.py). Subprocess-based tests
# set their own XLA_FLAGS.
os.environ.pop("XLA_FLAGS", None)

import jax  # noqa: E402
import pytest  # noqa: E402

# GP numerics tests compare against O(N^3) oracles: fp64 on CPU.
jax.config.update("jax_enable_x64", True)


@pytest.fixture(autouse=True)
def _lockdep_serve_battery(request):
    """Run the entire serve test battery under the lockdep runtime
    verifier: every lock the serving tier creates during a test_serve*
    test is instrumented, and any acquisition that inverts the declared
    hierarchy (repro.analysis.concurrency.LOCK_HIERARCHY) or an observed
    order fails the test — so each fault-injection and load test doubles
    as a deadlock check. Violations raised inside worker threads may be
    swallowed into Futures; the recorder keeps the evidence, asserted at
    teardown."""
    if not request.module.__name__.startswith("test_serve"):
        yield
        return
    from repro.analysis import lockdep

    with lockdep.watch() as rec:
        yield
    rec.assert_clean()
