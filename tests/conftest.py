import os

# Tests must see the real device count (1 CPU) — the 512-device override is
# exclusively the dry-run's (see launch/dryrun.py). Subprocess-based tests
# set their own XLA_FLAGS.
os.environ.pop("XLA_FLAGS", None)

import jax  # noqa: E402

# GP numerics tests compare against O(N^3) oracles: fp64 on CPU.
jax.config.update("jax_enable_x64", True)
