"""RWKV6 chunked form == step recurrence; RG-LRU associative scan == step
recurrence — train/decode state handoff exactness."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_smoke_config
from repro.models import rglru as rg
from repro.models import rwkv6 as rw


def test_rwkv_chunked_equals_stepwise():
    cfg = get_smoke_config("rwkv6-7b")
    key = jax.random.PRNGKey(0)
    params = rw.timemix_init(key, cfg)
    B, T = 2, 37  # deliberately not a chunk multiple
    x = jax.random.normal(jax.random.fold_in(key, 1), (B, T, cfg.d_model), jnp.float32)

    st = rw.timemix_state_init(cfg, B, jnp.float32)
    out_chunk, st_chunk = rw.timemix_apply_chunked(params, x, st, cfg)

    st2 = rw.timemix_state_init(cfg, B, jnp.float32)
    outs = []
    for t in range(T):
        o, st2 = rw.timemix_apply_decode(params, x[:, t : t + 1], st2, cfg)
        outs.append(o)
    out_step = jnp.concatenate(outs, axis=1)

    np.testing.assert_allclose(np.asarray(out_chunk), np.asarray(out_step),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_chunk.S), np.asarray(st2.S),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_chunk.x_prev), np.asarray(st2.x_prev))


def test_rwkv_state_carries_across_calls():
    """Processing [0:T] in one call == two calls [0:T/2], [T/2:T]."""
    cfg = get_smoke_config("rwkv6-7b")
    key = jax.random.PRNGKey(1)
    params = rw.timemix_init(key, cfg)
    B, T = 2, 64
    x = jax.random.normal(key, (B, T, cfg.d_model), jnp.float32)
    st = rw.timemix_state_init(cfg, B, jnp.float32)
    full, st_full = rw.timemix_apply_chunked(params, x, st, cfg)
    a, st_mid = rw.timemix_apply_chunked(params, x[:, :32], st, cfg)
    b, st_end = rw.timemix_apply_chunked(params, x[:, 32:], st_mid, cfg)
    np.testing.assert_allclose(np.asarray(full), np.asarray(jnp.concatenate([a, b], 1)),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_full.S), np.asarray(st_end.S), rtol=2e-4, atol=2e-4)


def test_rglru_scan_equals_stepwise():
    cfg = get_smoke_config("recurrentgemma-2b")
    key = jax.random.PRNGKey(2)
    params = rg.rglru_init(key, cfg)
    B, T = 2, 23
    x = jax.random.normal(key, (B, T, cfg.d_model), jnp.float32)
    st = rg.rglru_state_init(cfg, B, jnp.float32)
    out_scan, st_scan = rg.rglru_apply_train(params, x, st, cfg)

    st2 = rg.rglru_state_init(cfg, B, jnp.float32)
    outs = []
    for t in range(T):
        o, st2 = rg.rglru_apply_decode(params, x[:, t : t + 1], st2, cfg)
        outs.append(o)
    out_step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(out_scan), np.asarray(out_step), rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(st_scan.h), np.asarray(st2.h), rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(st_scan.conv), np.asarray(st2.conv), rtol=1e-5, atol=1e-6)


def test_rwkv_decay_in_unit_interval():
    cfg = get_smoke_config("rwkv6-7b")
    params = rw.timemix_init(jax.random.PRNGKey(3), cfg)
    x = jax.random.normal(jax.random.PRNGKey(4), (4, cfg.d_model), jnp.float32) * 3
    logw = rw._decays(params, x, cfg)
    w = np.asarray(jnp.exp(logw))
    assert np.all(w > 0) and np.all(w < 1)
