"""The concurrency analyzer and the lockdep runtime verifier: seeded
AB/BA, unguarded-write, and blocking-under-lock fixtures each trigger
exactly their rule; the real tree analyzes clean with every discovered
lock ranked in the declared hierarchy; lockdep instruments repo-created
locks under watch(), raises LockOrderViolation on declared-hierarchy and
observed-order inversions (check-before-acquire: no hang), and stays
transparent otherwise. The serve battery itself runs under lockdep via
the autouse conftest fixture — these tests cover the machinery."""
import pathlib
import threading

import pytest

from repro.analysis import concurrency, lockdep

FIXTURES = pathlib.Path(__file__).parent / "fixtures" / "analysis"


def _analyze_fixture(name):
    src = (FIXTURES / f"{name}.py").read_text()
    return concurrency.analyze_sources([(f"repro/seeded/{name}.py", src)])


# ---------------------------------------------------------------------------
# static pass: seeded violations
# ---------------------------------------------------------------------------

def test_lock_cycle_fixture_flags_exactly_anl005():
    model = _analyze_fixture("lock_cycle")
    codes = {f.code for f in model.findings}
    assert codes == {"ANL005"}, model.findings
    cyc = [f for f in model.findings if "cycle" in f.message]
    assert len(cyc) == 1
    # both edges named, with their source lines
    assert "_LEDGER_LOCK" in cyc[0].message
    assert "_JOURNAL_LOCK" in cyc[0].message
    assert "lock_cycle.py:13" in cyc[0].message  # ledger -> journal site
    assert "lock_cycle.py:19" in cyc[0].message  # the reverse edge


def test_unguarded_write_fixture_flags_exactly_anl006():
    model = _analyze_fixture("unguarded_write")
    assert [(f.code, f.line) for f in model.findings] == [("ANL006", 19)]
    f = model.findings[0]
    assert "self._table" in f.message and "Registry._lock" in f.message


def test_blocking_under_lock_fixture_flags_exactly_anl007():
    model = _analyze_fixture("blocking_under_lock")
    assert [f.code for f in model.findings] == ["ANL007"] * 3
    whats = [f.message for f in model.findings]
    assert any("open" in m for m in whats)
    assert any("json.dump" in m for m in whats)
    assert any("result" in m for m in whats)
    for f in model.findings:
        assert "_STATE_LOCK" in f.message


def test_self_deadlock_on_non_reentrant_lock_is_anl005():
    src = (
        "import threading\n"
        "_L = threading.Lock()\n"
        "def twice():\n"
        "    with _L:\n"
        "        with _L:\n"
        "            pass\n"
    )
    model = concurrency.analyze_sources([("repro/seeded/self.py", src)])
    assert [f.code for f in model.findings] == ["ANL005"]
    assert "self-deadlock" in model.findings[0].message
    # the same nesting on an RLock is re-entrant: clean
    rsrc = src.replace("threading.Lock()", "threading.RLock()")
    rmodel = concurrency.analyze_sources([("repro/seeded/self.py", rsrc)])
    assert rmodel.findings == []


def test_declared_hierarchy_inversion_without_a_cycle_is_anl005():
    """The declared order is the contract even before the reverse edge
    ships: budget-under-registry alone is a finding."""
    src = (
        "class GPServer:\n"
        "    def __init__(self):\n"
        "        import threading\n"
        "        self._registry_lock = threading.Lock()\n"
        "        self._budget_lock = threading.Lock()\n"
        "    def bad(self):\n"
        "        with self._registry_lock:\n"
        "            with self._budget_lock:\n"
        "                pass\n"
    )
    model = concurrency.analyze_sources([("repro/seeded/inv.py", src)])
    assert [f.code for f in model.findings] == ["ANL005"]
    assert "declared" in model.findings[0].message


def test_acquire_release_pairs_are_tracked_like_with_blocks():
    src = (
        "import threading\n"
        "_A = threading.Lock()\n"
        "_B = threading.Lock()\n"
        "def ab():\n"
        "    _A.acquire()\n"
        "    _B.acquire()\n"
        "    _B.release()\n"
        "    _A.release()\n"
        "def ba():\n"
        "    with _B:\n"
        "        _A.acquire()\n"
        "        _A.release()\n"
    )
    model = concurrency.analyze_sources([("repro/seeded/ar.py", src)])
    assert {f.code for f in model.findings} == {"ANL005"}
    assert any("cycle" in f.message for f in model.findings)


def test_locked_suffix_and_init_are_exempt_from_guard_inference():
    src = (
        "class Store:\n"
        "    def __init__(self):\n"
        "        import threading\n"
        "        self._lock = threading.Lock()\n"
        "        self._managers = {}\n"
        "    def save(self, k, v):\n"
        "        with self._lock:\n"
        "            self._managers[k] = v\n"
        "    def _manager_locked(self, k):\n"
        "        return self._managers[k]\n"   # caller holds the lock
    )
    model = concurrency.analyze_sources([("repro/seeded/st.py", src)])
    assert model.findings == []


def test_condition_wait_on_held_cv_is_not_blocking():
    src = (
        "class S:\n"
        "    def __init__(self):\n"
        "        import threading\n"
        "        self._cv = threading.Condition()\n"
        "        self._queue = []\n"
        "    def loop(self):\n"
        "        with self._cv:\n"
        "            while not self._queue:\n"
        "                self._cv.wait()\n"     # the CV pattern: exempt
        "            self._queue.pop()\n"
    )
    model = concurrency.analyze_sources([("repro/seeded/cv.py", src)])
    assert model.findings == []


def test_blocking_ok_locks_may_block():
    """StateStore._lock's documented job is serializing store I/O."""
    src = (
        "import json\n"
        "class StateStore:\n"
        "    def __init__(self):\n"
        "        import threading\n"
        "        self._lock = threading.Lock()\n"
        "    def save(self, path, doc):\n"
        "        with self._lock:\n"
        "            with open(path, 'w') as f:\n"
        "                json.dump(doc, f)\n"
    )
    model = concurrency.analyze_sources([("repro/seeded/ok.py", src)])
    assert model.findings == []


def test_noqa_alias_anl002_suppresses_anl006():
    src = (FIXTURES / "unguarded_write.py").read_text()
    muted = src.replace("# ANL006: lock-free write races put()",
                        "# noqa: ANL002")
    model = concurrency.analyze_sources([("repro/seeded/uw.py", muted)])
    assert model.findings == []


# ---------------------------------------------------------------------------
# static pass: the real tree
# ---------------------------------------------------------------------------

def test_src_tree_analyzes_clean_and_every_lock_is_ranked():
    model = concurrency.analyze_paths()
    assert model.findings == [], [f.describe() for f in model.findings]
    # the serving tier's whole lock population is declared in the
    # hierarchy — a new lock must take a rank before it ships
    assert set(model.defs) == set(concurrency.LOCK_HIERARCHY)
    # and every statically visible acquisition edge respects it
    rank = {n: i for i, n in enumerate(concurrency.LOCK_HIERARCHY)}
    for (a, b) in model.edges:
        assert rank[a] < rank[b], (a, b)
    # the documented serving chains are actually in the model
    assert ("GPServer._budget_lock", "_Entry.lock") in model.edges
    assert ("_Entry.lock", "GPServer._registry_lock") in model.edges


# ---------------------------------------------------------------------------
# lockdep: runtime verification
# ---------------------------------------------------------------------------

def test_watch_instruments_repo_locks_and_names_them():
    with lockdep.watch() as rec:
        class Holder:
            def __init__(self):
                self._lock = threading.Lock()

        h = Holder()
        assert isinstance(h._lock, lockdep._Instrumented)
        assert h._lock.name == "Holder._lock"
        with h._lock:
            pass
    assert rec.acquisitions == 1
    assert rec.violations == []
    # after watch() the factories are restored
    assert not isinstance(threading.Lock(), lockdep._Instrumented)


def test_watch_leaves_non_repo_locks_raw():
    """Locks created inside stdlib frames (Future conditions, Thread
    events) must not be wrapped — only repo-created locks count."""
    import concurrent.futures

    with lockdep.watch():
        fut = concurrent.futures.Future()
        assert not isinstance(fut._condition, lockdep._Instrumented)


def test_declared_hierarchy_inversion_raises_and_is_recorded():
    a = lockdep.named_lock("GPServer._budget_lock")
    b = lockdep.named_lock("GPServer._registry_lock")
    with lockdep.watch() as rec:
        with a:
            with b:
                pass  # declared order: fine
        with pytest.raises(lockdep.LockOrderViolation, match="declared"):
            with b:
                with a:
                    pass
    assert len(rec.violations) == 1
    assert rec.violations[0].lock == "GPServer._budget_lock"
    with pytest.raises(AssertionError, match="lock-order violation"):
        rec.assert_clean()


def test_observed_order_abba_raises_for_unranked_locks():
    """Locks outside the declared hierarchy still get the observed-order
    check: the first AB teaches the recorder, the BA attempt raises."""
    a = lockdep.named_lock("test.A")
    b = lockdep.named_lock("test.B")
    with lockdep.watch() as rec:
        with a:
            with b:
                pass
        with pytest.raises(lockdep.LockOrderViolation, match="opposite"):
            with b:
                with a:
                    pass
    assert ("test.A", "test.B") in rec.edges


def test_self_deadlock_raises_instead_of_hanging():
    lk = lockdep.named_lock("test.self")
    with lockdep.watch():
        with lk:
            with pytest.raises(lockdep.LockOrderViolation,
                               match="self-deadlock"):
                lk.acquire()
    # the rlock variant is re-entrant: no violation
    rl = lockdep.named_lock("test.rself", kind="rlock")
    with lockdep.watch() as rec:
        with rl:
            with rl:
                pass
    assert rec.violations == []


def test_condition_wait_releases_the_held_stack():
    """During cv.wait() the lock is NOT held: acquiring another lock from
    the waking path must not see the cv as held."""
    cv = lockdep.named_lock("test.cv", kind="condition")
    other = lockdep.named_lock("test.other")
    done = []

    def waker():
        with cv:
            cv.notify_all()
            done.append(True)

    with lockdep.watch() as rec:
        with cv:
            t = threading.Thread(target=waker)
            t.start()
            cv.wait(timeout=5.0)
        t.join(5.0)
        with other:
            pass
    assert done == [True]
    assert rec.violations == []


def test_watch_is_transparent_when_inactive_and_rejects_nesting():
    lk = lockdep.named_lock("test.plain")
    with lk:  # no watch: plain delegation
        assert lk.locked()
    assert not lk.locked()
    with lockdep.watch():
        with pytest.raises(RuntimeError, match="already active"):
            with lockdep.watch():
                pass


def test_serving_locks_run_clean_under_lockdep_end_to_end():
    """A miniature of what the conftest fixture does for the whole serve
    battery: build a real GPServer under watch(), exercise register /
    predict / close, and require zero violations."""
    import jax.numpy as jnp

    from repro.gp import SparseGPRegression, get
    from repro.serve import GPServer

    X = jnp.linspace(-2.0, 2.0, 64)[:, None]
    Y = jnp.sin(X)
    gp = SparseGPRegression(kernel=get("rbf")(1), M=8).fit(X, Y, steps=3)
    with lockdep.watch() as rec:
        server = GPServer()
        server.register("m", gp)
        mean, var = server.predict("m", X[:8])
        assert mean.shape == (8, 1)
        server.close()
    assert rec.violations == [], [str(v) for v in rec.violations]
    assert rec.acquisitions > 0


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def test_cli_concurrency_clean_on_src(capsys):
    from repro.analysis.__main__ import main

    assert main(["--concurrency"]) == 0
    out = capsys.readouterr().out
    assert "ANL005-ANL007" in out and "0 finding(s)" in out


@pytest.mark.parametrize("name,rule", [("lock_cycle", "ANL005"),
                                       ("unguarded_write", "ANL006"),
                                       ("blocking_under_lock", "ANL007")])
def test_cli_concurrency_fails_on_each_seeded_fixture(capsys, name, rule):
    from repro.analysis.__main__ import main

    assert main(["--concurrency", str(FIXTURES / f"{name}.py")]) == 1
    out = capsys.readouterr().out
    assert rule in out and f"{name}.py" in out


def test_cli_json_format_is_machine_readable(capsys):
    import json

    from repro.analysis.__main__ import main

    rc = main(["--concurrency", "--format", "json",
               str(FIXTURES / "lock_cycle.py")])
    assert rc == 1
    doc = json.loads(capsys.readouterr().out)
    assert doc["ok"] is False and doc["failures"] == 1
    conc = doc["passes"]["concurrency"]
    assert conc["hierarchy"] == list(concurrency.LOCK_HIERARCHY)
    assert any(f["code"] == "ANL005" for f in conc["findings"])
    # lint emits json too
    rc = main(["--lint", "--format", "json"])
    assert rc == 0
    doc = json.loads(capsys.readouterr().out)
    assert doc["passes"]["lint"]["findings"] == []
