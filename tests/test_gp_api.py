"""The `repro.gp` facade: registry round-trips, kernel protocol, composite
psi statistics vs dense references, and facade-vs-hand-wired parity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import distributed, gplvm, svgp
from repro.gp import BayesianGPLVM, SparseGPRegression, available, get, kernels, suff_stats
from repro.gp.kernels import RBF, Linear, Matern32, Product, Sum
from repro.gp.stats import ExactBatch, ExpectedBatch

ALL_NAMES = ("rbf", "linear", "matern12", "matern32", "matern52", "sum", "product")


def _f64(params):
    return jax.tree.map(lambda x: x.astype(jnp.float64), params)


def _make(name, Q=2):
    cls = get(name)
    if name in ("sum", "product"):
        return cls(RBF(Q), Linear(Q))
    return cls(Q)


def _qx(key, N, Q):
    k1, k2 = jax.random.split(key)
    mu = jax.random.normal(k1, (N, Q), jnp.float64)
    S = 0.05 + 0.2 * jax.random.uniform(k2, (N, Q), jnp.float64)
    return mu, S


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_registry_round_trip_every_name():
    assert set(ALL_NAMES) <= set(available())
    for name in available():
        cls = get(name)
        assert cls.name == name
        kern = _make(name)
        params = kern.init()
        # every kernel evaluates K and Kdiag
        X = jax.random.normal(jax.random.PRNGKey(0), (7, kern.input_dim), jnp.float64)
        K = kern.K(_f64(params), X)
        assert K.shape == (7, 7)
        np.testing.assert_allclose(np.asarray(jnp.diagonal(K)),
                                   np.asarray(kern.Kdiag(_f64(params), X)),
                                   rtol=1e-9, atol=1e-9)


def test_registry_unknown_name_lists_available():
    with pytest.raises(KeyError, match="rbf"):
        get("no-such-kernel")


# ---------------------------------------------------------------------------
# kernel protocol: exact statistics are kernel-generic
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ALL_NAMES)
def test_exact_stats_match_dense_kfu_reference(name):
    """SuffStats from any kernel == the dense K_fu-based definition."""
    key = jax.random.PRNGKey(1)
    kern = _make(name)
    p = _f64(kern.init())
    X = jax.random.normal(key, (40, 2), jnp.float64)
    Y = jax.random.normal(jax.random.fold_in(key, 1), (40, 3), jnp.float64)
    Z = jax.random.normal(jax.random.fold_in(key, 2), (9, 2), jnp.float64)
    stats = kern.exact_suff_stats(p, X, Y, Z)
    Kfu = kern.K(p, X, Z)
    np.testing.assert_allclose(np.asarray(stats.psi2), np.asarray(Kfu.T @ Kfu), rtol=1e-10)
    np.testing.assert_allclose(np.asarray(stats.psiY), np.asarray(Kfu.T @ Y), rtol=1e-10)
    np.testing.assert_allclose(float(stats.psi0), float(jnp.sum(kern.Kdiag(p, X))), rtol=1e-10)
    assert float(stats.n) == 40


@pytest.mark.parametrize("name", ("matern12", "matern32", "matern52"))
def test_matern_expected_stats_raise_cleanly(name):
    kern = _make(name)
    p = _f64(kern.init())
    mu, S = _qx(jax.random.PRNGKey(2), 10, 2)
    Y = jnp.ones((10, 1), jnp.float64)
    Z = mu[:4]
    with pytest.raises(NotImplementedError, match="psi statistics"):
        kern.expected_suff_stats(p, mu, S, Y, Z)


def test_matern_bound_below_exact_marginal():
    """The collapsed bound through a Matern kernel is still a lower bound."""
    key = jax.random.PRNGKey(3)
    kern = Matern32(2)
    p = _f64(kern.init(1.2, 0.9))
    X = jax.random.normal(key, (120, 2), jnp.float64)
    Y = jnp.sin(X @ jnp.ones((2, 2), jnp.float64)) \
        + 0.1 * jax.random.normal(jax.random.fold_in(key, 1), (120, 2), jnp.float64)
    beta = jnp.asarray(50.0, jnp.float64)
    exact = svgp.exact_gp_log_marginal(kern.K(p, X), Y, beta)
    stats = kern.exact_suff_stats(p, X, Y, X[:25])
    terms = svgp.collapsed_bound(kern.K(p, X[:25]), stats, beta, Y.shape[1])
    assert float(terms.bound) <= float(exact)


# ---------------------------------------------------------------------------
# composite kernels: expected (psi) statistics vs dense per-point reference
# ---------------------------------------------------------------------------

def _psi2_reference_quadrature(kern, params, mu, S, Z, n_grid=600):
    """Dense reference: <k_fu^T k_fu> by Gauss-ish quadrature per datapoint,
    valid for any 1-D latent kernel. Independent of the closed forms."""
    N, Q = mu.shape
    assert Q == 1
    # midpoint rule over +-8 sigma per point
    t = jnp.linspace(-8.0, 8.0, n_grid)
    w = (t[1] - t[0]) * jnp.exp(-0.5 * t**2) / jnp.sqrt(2.0 * jnp.pi)
    total = jnp.zeros((Z.shape[0], Z.shape[0]), jnp.float64)
    psi1 = jnp.zeros((N, Z.shape[0]), jnp.float64)
    for n in range(N):
        x = (mu[n, 0] + jnp.sqrt(S[n, 0]) * t)[:, None]  # (G, 1)
        Kf = kern.K(params, x, Z)  # (G, M)
        psi1 = psi1.at[n].set(jnp.einsum("g,gm->m", w, Kf))
        total = total + jnp.einsum("g,gm,gl->ml", w, Kf, Kf)
    return psi1, total


@pytest.mark.parametrize("make_kern", [
    lambda: Sum(RBF(1), Linear(1)),
    lambda: Sum(Linear(1), RBF(1)),
    lambda: Sum(Linear(1), Linear(1)),
    lambda: Product(RBF(1), RBF(1)),
])
def test_composite_expected_stats_match_dense_reference(make_kern):
    key = jax.random.PRNGKey(4)
    kern = make_kern()
    p = _f64(kern.init())
    mu, S = _qx(key, 6, 1)
    Z = jax.random.normal(jax.random.fold_in(key, 1), (5, 1), jnp.float64)
    Y = jax.random.normal(jax.random.fold_in(key, 2), (6, 2), jnp.float64)
    psi1_ref, psi2_ref = _psi2_reference_quadrature(kern, p, mu, S, Z)
    stats = kern.expected_suff_stats(p, mu, S, Y, Z)
    np.testing.assert_allclose(np.asarray(kern.psi1(p, mu, S, Z)), np.asarray(psi1_ref),
                               rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(np.asarray(stats.psi2), np.asarray(psi2_ref),
                               rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(np.asarray(stats.psiY), np.asarray(psi1_ref.T @ Y),
                               rtol=1e-5, atol=1e-7)


def test_sum_cross_terms_unsupported_pair_raises():
    kern = Sum(RBF(1), Matern32(1))
    p = _f64(kern.init())
    mu, S = _qx(jax.random.PRNGKey(5), 4, 1)
    with pytest.raises(NotImplementedError):
        kern.psi2(p, mu, S, mu[:2])


def test_non_rbf_backend_pallas_raises_not_silently_falls_back():
    kern = Sum(RBF(1), Linear(1))
    p = _f64(kern.init())
    X = jnp.ones((4, 1), jnp.float64)
    with pytest.raises(ValueError, match="backend"):
        kern.exact_suff_stats(p, X, X, X[:2], backend="pallas")


def test_gplvm_q_kernel_mismatch_raises():
    with pytest.raises(ValueError, match="input_dim"):
        BayesianGPLVM(kernel=get("rbf")(2), Q=3)


def test_product_non_rbf_expected_raises():
    kern = Product(RBF(1), Linear(1))
    p = _f64(kern.init())
    mu, S = _qx(jax.random.PRNGKey(6), 4, 1)
    with pytest.raises(NotImplementedError, match="all-RBF"):
        kern.psi1(p, mu, S, mu[:2])


# ---------------------------------------------------------------------------
# suff_stats dispatch
# ---------------------------------------------------------------------------

def test_suff_stats_dispatches_on_batch_type():
    key = jax.random.PRNGKey(7)
    kern = RBF(2)
    p = _f64(kern.init(1.3, 0.8))
    X = jax.random.normal(key, (30, 2), jnp.float64)
    Y = jax.random.normal(jax.random.fold_in(key, 1), (30, 2), jnp.float64)
    Z = X[:8]
    a = suff_stats(kern, p, ExactBatch(X, Y, Z))
    b = kern.exact_suff_stats(p, X, Y, Z)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    mu, S = _qx(key, 30, 2)
    c = suff_stats(kern, p, ExpectedBatch(mu, S, Y, Z))
    d = kern.expected_suff_stats(p, mu, S, Y, Z)
    for x, y in zip(c, d):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    with pytest.raises(TypeError):
        suff_stats(kern, p, (X, Y, Z))


# ---------------------------------------------------------------------------
# facade vs hand-wired parity
# ---------------------------------------------------------------------------

def _quickstart_data(N=600):
    key = jax.random.PRNGKey(0)
    X = jnp.sort(jax.random.uniform(key, (N, 1), minval=-3.0, maxval=3.0), axis=0)
    f = jnp.sin(2.0 * X[:, 0]) + 0.3 * jnp.cos(5.0 * X[:, 0])
    Y = (f + 0.1 * jax.random.normal(jax.random.fold_in(key, 1), (N,)))[:, None]
    return X.astype(jnp.float64), Y.astype(jnp.float64), f


def test_facade_matches_hand_wired_sgpr_bound():
    """SparseGPRegression reproduces the hand-wired distributed loss to 1e-5
    (same data, same init) with and without a mesh."""
    X, Y, _ = _quickstart_data()
    mesh = distributed.make_gp_mesh()
    gp = SparseGPRegression(kernel=get("rbf")(1), M=32, mesh=mesh)
    p0 = _f64(gp.init_params(X, Y))
    hand_wired = distributed.sgpr_loss_dist(mesh)
    a = float(hand_wired(p0, X, Y))
    b = float(gp._loss_fn()(p0, X, Y))
    assert abs(a - b) < 1e-5, (a, b)
    local = float(SparseGPRegression(kernel=get("rbf")(1), M=32)._loss_fn()(p0, X, Y))
    assert abs(a - local) < 1e-5, (a, local)


def test_facade_matches_hand_wired_gplvm_bound():
    key = jax.random.PRNGKey(0)
    from repro.data.synthetic import gplvm_synthetic

    _, Y = gplvm_synthetic(key, N=96, D=3, Q=1)
    Y = Y.astype(jnp.float64)
    params = _f64(gplvm.init_params(key, np.asarray(Y), Q=1, M=12))
    mesh = distributed.make_gp_mesh()
    a = float(distributed.gplvm_loss_dist(mesh)(params, Y))
    lvm = BayesianGPLVM(kernel=get("rbf")(1), M=12, mesh=mesh)
    b = float(lvm._loss_fn()(params, Y))
    assert abs(a - b) < 1e-5, (a, b)
    c = float(gplvm.loss(params, Y))
    assert abs(a - c) < 1e-5, (a, c)


def test_facade_quickstart_fit_and_predict():
    """The 10-line quickstart through the facade: RMSE < 0.1 like the paper
    example, calibrated variance, pallas backend selectable."""
    X, Y, f = _quickstart_data(N=800)
    mesh = distributed.make_gp_mesh()
    gp = SparseGPRegression(kernel=get("rbf")(1), M=32, mesh=mesh).fit(
        X, Y, steps=200, lr=3e-2)
    mean, var = gp.predict(X)
    rmse = float(jnp.sqrt(jnp.mean((mean[:, 0] - f) ** 2)))
    assert rmse < 0.1, rmse
    assert np.all(np.asarray(var) > 0)
    assert np.isfinite(gp.elbo())


def test_facade_backend_pallas_matches_jnp():
    X, Y, _ = _quickstart_data(N=300)
    X32, Y32 = X.astype(jnp.float32), Y.astype(jnp.float32)
    base = SparseGPRegression(kernel=get("rbf")(1), M=16)
    p0 = base.init_params(X32, Y32)
    a = float(base._loss_fn()(p0, X32, Y32))
    pal = SparseGPRegression(kernel=get("rbf")(1), M=16, backend="pallas")
    pal.kernel = pal.kernel or get("rbf")(1)
    b = float(pal._loss_fn()(p0, X32, Y32))
    assert abs(a - b) < 1e-4 * max(1.0, abs(a)), (a, b)


def test_facade_fit_with_matern_and_lbfgs():
    X, Y, f = _quickstart_data(N=300)
    gp = SparseGPRegression(kernel=get("matern52")(1), M=24).fit(
        X, Y, optimizer="lbfgs", steps=40)
    mean, _ = gp.predict(X)
    rmse = float(jnp.sqrt(jnp.mean((mean[:, 0] - f) ** 2)))
    assert rmse < 0.2, rmse


def test_facade_gplvm_recovers_latent():
    key = jax.random.PRNGKey(0)
    from repro.data.synthetic import gplvm_synthetic

    X_true, Y = gplvm_synthetic(key, N=192, D=3, Q=1)
    lvm = BayesianGPLVM(kernel=get("rbf")(1), M=16, mesh=distributed.make_gp_mesh())
    lvm.fit(Y.astype(jnp.float64), steps=150, lr=5e-2, key=key)
    mu, S = lvm.latent()
    corr = abs(np.corrcoef(np.asarray(mu[:, 0]), np.asarray(X_true[:, 0]))[0, 1])
    assert corr > 0.9, corr
    assert np.all(np.asarray(S) > 0)


def test_param_spec_table_covers_model_params():
    """The declarative role table is the single source of truth for specs."""
    mesh = distributed.make_gp_mesh()
    specs = distributed.make_param_specs(distributed.GPLVM_PARAM_NAMES, mesh)
    assert set(specs) == set(distributed.GPLVM_PARAM_NAMES)
    from jax.sharding import PartitionSpec as P

    assert specs["q_mu"] == P(("data",))
    assert specs["kern"] == P()
    assert distributed.PARAM_ROLES["q_logS"] == "local"
