"""Checkpoint manager: roundtrip, retention, atomicity, async, train-loop
resume, elastic reshard across device counts (subprocess)."""
import json
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager


def _tree(seed=0):
    key = jax.random.PRNGKey(seed)
    return {
        "a": jax.random.normal(key, (8, 4), jnp.float32),
        "nested": {"b": jnp.arange(6, dtype=jnp.int32), "c": jnp.ones((3,), jnp.bfloat16)},
    }


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path)
    t = _tree()
    mgr.save(3, t, extra={"step": 3, "data": {"seed": 0, "step": 7}})
    restored, extra = mgr.restore(jax.tree.map(lambda x: jnp.zeros_like(x), t))
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))
    assert extra == {"step": 3, "data": {"seed": 0, "step": 7}}


def test_retention_keeps_newest(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in range(5):
        mgr.save(s, _tree(s))
    assert mgr.steps() == [3, 4]


def test_keep_every_survives_retention(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=1, keep_every=2)
    for s in range(5):
        mgr.save(s, _tree(s))
    assert set(mgr.steps()) == {0, 2, 4}


def test_async_save_then_wait(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, _tree(), blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 1
    # no tmp dirs left behind
    assert not list(Path(tmp_path).glob("*.tmp"))


def test_restore_rejects_shape_mismatch(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, _tree())
    bad = {"a": jnp.zeros((2, 2)), "nested": {"b": jnp.zeros((6,), jnp.int32),
                                              "c": jnp.zeros((3,), jnp.bfloat16)}}
    with pytest.raises(ValueError):
        mgr.restore(bad)


def test_train_loop_resume(tmp_path):
    """Interrupt a loop, restart it, confirm it continues from the step and
    data position (exactly the node-failure recovery path)."""
    from repro.configs.base import ShapeCell, get_smoke_config
    from repro.data.synthetic import TokenStream
    from repro.launch.mesh import make_host_mesh
    from repro.launch.steps import default_adam, make_train_step
    from repro.models.model_zoo import build
    from repro.optim import adam_init
    from repro.runtime.train_loop import LoopConfig, TrainLoop

    cfg = get_smoke_config("smollm-360m")
    shape = ShapeCell("t", 32, 2, "train")
    mesh = make_host_mesh()
    with mesh:
        bundle = make_train_step(cfg, shape, mesh, batch=2)
        step_fn = bundle.jitted()
        params = build(cfg).init(jax.random.PRNGKey(0))
        opt = adam_init(params, default_adam(cfg))
        lc = LoopConfig(ckpt_dir=str(tmp_path), ckpt_every=2, log_every=0, async_save=False)

        loop1 = TrainLoop(step_fn, params, opt, TokenStream(cfg, shape, batch=2), lc)
        loop1.run(3)
        assert loop1.step == 3

        loop2 = TrainLoop(step_fn, params, opt, TokenStream(cfg, shape, batch=2), lc)
        loop2.run(5)
        assert loop2.step == 5
        # data stream resumed from saved position, not from scratch
        assert loop2.data.state.step >= 5


ELASTIC_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={ndev}"
import sys
sys.path.insert(0, "{src}")
import jax, jax.numpy as jnp, numpy as np
from repro.checkpoint.manager import CheckpointManager
from repro.parallel import sharding as shd
from repro.runtime.elastic import reshard_for_mesh
from repro.configs.base import get_smoke_config
from repro.models.model_zoo import build

cfg = get_smoke_config("smollm-360m")
params = build(cfg).init(jax.random.PRNGKey(7))
from repro import compat
mesh = compat.make_mesh(({dshape}), ("data", "model"))
if "{phase}" == "save":
    sharded = jax.device_put(params, shd.to_shardings(shd.param_specs(params, mesh), mesh))
    CheckpointManager("{dir}").save(11, {{"params": sharded}}, extra={{"step": 11}})
    print("SAVED", float(jax.tree.leaves(sharded)[0].sum()))
else:
    restored, extra = reshard_for_mesh("{dir}", jax.eval_shape(lambda: params), mesh)
    assert extra["step"] == 11
    a = jax.tree.leaves(params); b = jax.tree.leaves(restored)
    ok = all(np.allclose(np.asarray(x, np.float32), np.asarray(y, np.float32)) for x, y in zip(a, b))
    print("RESTORED-OK" if ok else "MISMATCH")
"""


@pytest.mark.slow
def test_elastic_reshard_across_meshes(tmp_path):
    """Save on a (2,2) mesh, restore on (4,2) — elastic scale-up resumes
    bit-exactly."""
    import repro

    src = repro.__file__.rsplit("/repro/", 1)[0]
    save = ELASTIC_SCRIPT.format(ndev=4, dshape="2, 2", phase="save", dir=tmp_path, src=src)
    out = subprocess.run([sys.executable, "-c", save], capture_output=True, text=True,
                         timeout=600)
    assert "SAVED" in out.stdout, out.stdout + out.stderr
    load = ELASTIC_SCRIPT.format(ndev=8, dshape="4, 2", phase="load", dir=tmp_path, src=src)
    out = subprocess.run([sys.executable, "-c", load], capture_output=True, text=True,
                         timeout=600)
    assert "RESTORED-OK" in out.stdout, out.stdout + out.stderr
